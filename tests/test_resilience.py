"""Crash-safe long-horizon runs: the resilience acceptance surface.

  * guardrail ladder: healthy batches bitwise-unchanged, a poisoned warm
    start heals through plain_restart, and a NaN-poisoned spec rides the
    full ladder into per-spec quarantine while the sweep COMPLETES
  * durable sweeps: checkpoint/resume is bitwise vs the uninterrupted
    checkpointed run, fingerprint rejects foreign checkpoints
  * FleetStream.save/resume: every aggregate (n_epochs included) equals
    the uninterrupted stream, per arrival mode incl. the belief posterior
  * kill-and-resume drills: subprocess runs SIGKILLed mid-sweep and
    mid-stream resume to results equal to a never-killed run, and a
    SIGTERM mid-sweep raises SweepPreempted with durable progress
"""
import dataclasses
import os
import shutil
import signal
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro.core import (
    GOOGLENET_P4_ENERGY,
    GOOGLENET_P4_LATENCY,
    ServiceModel,
    SMDPSpec,
    SweepPreempted,
    build_smdp_batched,
    relative_value_iteration_batched,
    sweep_solve,
)
from repro.core.policies import q_policy
from repro.serving import FleetStream, simulate_fleet
from repro.serving.arrivals import MMPP2, PhaseBeliefFilter
from repro.serving.metrics import P2Quantile

ROOT = Path(__file__).resolve().parent.parent

SVC = ServiceModel(latency=GOOGLENET_P4_LATENCY, family="det")
BMAX = 16
MEANS = np.array([0.0] + [float(SVC.mean(b)) for b in range(1, BMAX + 1)])
ENERGY = np.array(
    [0.0] + [float(GOOGLENET_P4_ENERGY(b)) for b in range(1, BMAX + 1)]
)
LAM = 0.7 * BMAX / float(SVC.mean(BMAX))


def spec_for(rho=0.3, w2=1.0, s_max=48, b_max=16):
    lam = rho * b_max / float(SVC.mean(b_max))
    return SMDPSpec(
        lam=lam, service=SVC, energy=GOOGLENET_P4_ENERGY,
        b_min=1, b_max=b_max, w1=1.0, w2=w2, s_max=s_max, c_o=100.0,
    )


def _grid(n=6, s_max=48):
    base = spec_for(s_max=s_max)
    return [
        dataclasses.replace(base, w2=float(w))
        for w in np.linspace(0.0, 5.0, n)
    ]


def _assert_results_bitwise(got, ref):
    assert len(got) == len(ref)
    for a, b in zip(got, ref):
        assert a.spec.s_max == b.spec.s_max
        assert np.array_equal(a.rvi.policy, b.rvi.policy)
        assert a.rvi.g == b.rvi.g
        assert np.array_equal(a.rvi.h, b.rvi.h)
        assert a.eval.g == b.eval.g
        assert np.array_equal(a.eval.w_bar, b.eval.w_bar)
        assert np.array_equal(a.eval.p_bar, b.eval.p_bar)


# ---------------------------------------------------------------------------
# Solver guardrail ladder
# ---------------------------------------------------------------------------


class TestGuardLadder:
    def test_healthy_batch_bitwise_identical_to_unguarded(self):
        batch = build_smdp_batched(_grid())
        plain = relative_value_iteration_batched(batch, guard=False)
        guarded = relative_value_iteration_batched(batch, guard=True)
        np.testing.assert_array_equal(guarded.policies, plain.policies)
        np.testing.assert_array_equal(
            np.asarray(guarded.g), np.asarray(plain.g)
        )
        np.testing.assert_array_equal(
            np.asarray(guarded.h), np.asarray(plain.h)
        )
        rep = guarded.report
        assert rep is not None and rep.healthy.all() and not rep.any_fired

    def test_poisoned_warm_start_heals_via_plain_restart(self):
        specs = _grid(4)
        batch = build_smdp_batched(specs)
        clean = relative_value_iteration_batched(batch, guard=False)
        h0 = np.zeros_like(np.asarray(clean.h))
        h0[1, :] = np.nan  # a poisoned anchor NaNs every backup of row 1
        res = relative_value_iteration_batched(batch, h0=h0, guard=True)
        rep = res.report
        assert rep.healthy.all()
        assert 1 in rep.rungs.get("plain_restart", [])
        assert not rep.quarantined and not rep.failed
        np.testing.assert_array_equal(res.policies, clean.policies)
        # the healed row re-converges from scratch: same fixed point to
        # solver tolerance, not the same iterate
        np.testing.assert_allclose(
            np.asarray(res.g), np.asarray(clean.g), rtol=1e-5
        )

    def test_nan_spec_quarantined_and_sweep_completes(self):
        """ISSUE acceptance: a grid with one NaN-poisoned spec completes,
        the poisoned row quarantined (and failed — nothing can solve a
        NaN objective) in the SolveReport, every other row healthy."""
        specs = _grid(7)
        specs[3] = dataclasses.replace(specs[3], w2=float("nan"))
        sink = []
        res = sweep_solve(
            specs, delta=None, auto_c_o=False, report_sink=sink,
            chunk_size=4,
        )
        assert len(res) == len(specs)
        rep = sink[0]
        assert 3 in rep.quarantined
        assert 3 in rep.failed
        assert "quarantine" in rep.rungs
        assert not rep.healthy[3]
        assert not np.isfinite(res[3].rvi.g)
        for i, r in enumerate(res):
            if i == 3:
                continue
            assert rep.healthy[i]
            assert np.isfinite(r.rvi.g) and r.rvi.converged


# ---------------------------------------------------------------------------
# Durable sweeps (in-process crash simulation)
# ---------------------------------------------------------------------------


class TestSweepCheckpointResume:
    SWEEP_KW = dict(delta=None, auto_c_o=False, chunk_size=2)

    def _run(self, d, specs, **over):
        kw = {**self.SWEEP_KW, "checkpoint_dir": str(d), **over}
        return sweep_solve(specs, **kw)

    def test_resume_after_lost_steps_is_bitwise(self, tmp_path):
        """Deleting the later committed steps simulates dying mid-run;
        re-running the identical call resumes and matches the
        uninterrupted checkpointed run bitwise."""
        specs = _grid(6)
        ref = self._run(tmp_path / "ref", specs, keep_last_k=99)
        crash = tmp_path / "crash"
        self._run(crash, specs, keep_last_k=99)
        steps = sorted(crash.glob("step_*"))
        assert len(steps) == 3  # 6 specs / chunk_size=2
        for p in steps[1:]:
            shutil.rmtree(p)
        resumed = self._run(crash, specs, keep_last_k=99)
        _assert_results_bitwise(resumed, ref)

    def test_completed_checkpoint_short_circuits(self, tmp_path):
        specs = _grid(4)
        first = self._run(tmp_path, specs)
        again = self._run(tmp_path, specs)
        _assert_results_bitwise(again, first)

    def test_foreign_fingerprint_rejected(self, tmp_path):
        specs = _grid(4)
        self._run(tmp_path, specs)
        with pytest.raises(ValueError, match="different sweep"):
            self._run(tmp_path, specs, eps=5e-3)
        with pytest.raises(ValueError, match="different sweep"):
            self._run(tmp_path, _grid(5))

    def test_sigterm_raises_preempted_with_durable_progress(self, tmp_path):
        """SIGTERM mid-sweep: the current chunk commits, SweepPreempted
        names the directory and step, and the same call resumes to a
        bitwise match of the uninterrupted run.  The signal is raised
        from the first chunk's own checkpoint commit, so delivery is
        deterministic (no timer race)."""
        from repro.core import sweep as sweep_mod

        specs = _grid(6)
        ref = self._run(tmp_path / "ref", specs)
        orig = sweep_mod._SweepCheckpointer.save
        fired = []

        def kick(self, tree):
            orig(self, tree)
            if not fired:
                fired.append(True)
                os.kill(os.getpid(), signal.SIGTERM)

        sweep_mod._SweepCheckpointer.save = kick
        try:
            with pytest.raises(SweepPreempted) as ei:
                self._run(tmp_path / "pre", specs)
        finally:
            sweep_mod._SweepCheckpointer.save = orig
        assert ei.value.checkpoint_dir == str(tmp_path / "pre")
        assert ei.value.step == 0
        resumed = self._run(tmp_path / "pre", specs)
        _assert_results_bitwise(resumed, ref)


# ---------------------------------------------------------------------------
# FleetStream save/resume (in-process)
# ---------------------------------------------------------------------------


def _stream_inputs(mode, n=3000, seed=11):
    """(tables, chunk list, stream kwargs) per arrival mode."""
    rng = np.random.default_rng(seed)
    lam = 2 * LAM  # M=2 fleets below
    kw = dict(means=MEANS, zeta=ENERGY, b_max=BMAX, slo=3.0)
    if mode == "poisson":
        tr = np.cumsum(rng.exponential(1.0 / lam, n))
        tabs = np.stack([q_policy(q, 96, BMAX) for q in (4, 8)])
        chunks = [
            dict(times=tr[lo:lo + 400]) for lo in range(0, len(tr), 400)
        ]
        kw["router"] = "pow2"  # exercises the router RNG round-trip
        return tabs, chunks, kw
    stacks = np.stack(
        [np.stack([q_policy(4, 96, BMAX), q_policy(10, 96, BMAX)])] * 2
    )  # (M=2, K=2, L)
    m = MMPP2(lam1=0.3 * lam, lam2=1.3 * lam, dwell1=60.0, dwell2=30.0)
    tr, switches = m.sample_arrivals(n / m.mean_rate, rng)
    sw_t = np.array([s[0] for s in switches])
    sw_p = np.array([s[1] for s in switches], dtype=np.int64)
    ph = sw_p[np.searchsorted(sw_t, tr, side="right") - 1]
    kw["router"] = "jsq"
    if mode == "mmpp2":
        chunks = [
            dict(times=tr[lo:lo + 400], phases=ph[lo:lo + 400])
            for lo in range(0, len(tr), 400)
        ]
        return stacks, chunks, kw
    assert mode == "belief"
    kw["phase_mode"] = "belief_argmax"
    kw["belief_filter"] = PhaseBeliefFilter(
        rates=[0.3 * lam, 1.3 * lam],
        gen=[[-1 / 60.0, 1 / 60.0], [1 / 30.0, -1 / 30.0]],
    )
    chunks = [dict(times=tr[lo:lo + 400]) for lo in range(0, len(tr), 400)]
    return stacks, chunks, kw


def _fresh_stream(mode):
    tabs, chunks, kw = _stream_inputs(mode)
    if "belief_filter" in kw:  # filters are stateful; never share one
        kw = dict(kw)
        f = kw["belief_filter"]
        kw["belief_filter"] = PhaseBeliefFilter(f.rates, f.gen)
    return FleetStream(tabs, **kw), chunks


def _assert_streams_equal(got, ref):
    a, b = got.result(), ref.result()
    for f in (
        "t_final", "n_served", "n_batches", "n_epochs", "n_admitted",
        "energy", "lat_sum", "slo_miss", "n_crashes", "n_dropped", "n_shed",
    ):
        assert getattr(a, f) == getattr(b, f), f
    np.testing.assert_array_equal(a.hist, b.hist)
    np.testing.assert_array_equal(a.qlen, b.qlen)
    np.testing.assert_array_equal(a.busy, b.busy)
    np.testing.assert_array_equal(a.n_routed, b.n_routed)
    np.testing.assert_array_equal(a.n_served_m, b.n_served_m)
    ra, rb = got.report(), ref.report()
    assert set(ra) == set(rb)
    for k in ra:
        assert ra[k] == rb[k] or (np.isnan(ra[k]) and np.isnan(rb[k])), k


class TestFleetStreamSaveResume:
    @pytest.mark.parametrize("mode", ["poisson", "mmpp2", "belief"])
    def test_save_resume_matches_uninterrupted(self, mode, tmp_path):
        ref, chunks = _fresh_stream(mode)
        for c in chunks:
            ref.push(**c)
        ref.finish()

        fs, chunks = _fresh_stream(mode)
        cut = len(chunks) // 2
        for c in chunks[:cut]:
            fs.push(**c)
        fs.save(tmp_path)
        del fs
        back = FleetStream.resume(tmp_path)
        for c in chunks[cut:]:
            back.push(**c)
        back.finish()
        _assert_streams_equal(back, ref)

    def test_repeated_saves_resume_from_latest(self, tmp_path):
        ref, chunks = _fresh_stream("poisson")
        for c in chunks:
            ref.push(**c)
        ref.finish()
        fs, chunks = _fresh_stream("poisson")
        for c in chunks[:3]:  # save after every chunk, like a real run
            fs.push(**c)
            fs.save(tmp_path)
        back = FleetStream.resume(tmp_path)
        for c in chunks[3:]:
            back.push(**c)
        back.finish()
        _assert_streams_equal(back, ref)

    def test_p2_snapshot_restore_is_bitwise(self):
        rng = np.random.default_rng(3)
        xs = rng.exponential(1.0, 400)
        est = P2Quantile(0.95)
        for x in xs[:200]:
            est.update(x)
        twin = P2Quantile(0.5)
        twin.restore(est.snapshot())
        assert twin.q == est.q
        for x in xs[200:]:
            est.update(x)
            twin.update(x)
        assert twin.value == est.value
        assert twin.heights == est.heights
        assert twin.ns == est.ns


# ---------------------------------------------------------------------------
# Kill-and-resume subprocess drills
# ---------------------------------------------------------------------------

#: child sweep: checkpointed, 6 specs, chunk_size=1, saves throttled so the
#: parent can land a signal mid-run deterministically after the first commit
_CHILD_SWEEP = r"""
import dataclasses, sys, time
import numpy as np
from repro.core import (GOOGLENET_P4_ENERGY, GOOGLENET_P4_LATENCY,
                        ServiceModel, SMDPSpec, SweepPreempted, sweep_solve)
from repro.core import sweep as _sweep_mod

ckpt, out = sys.argv[1], sys.argv[2]
_orig = _sweep_mod._SweepCheckpointer.save
def _slow(self, tree):
    _orig(self, tree)
    time.sleep(0.25)
_sweep_mod._SweepCheckpointer.save = _slow

svc = ServiceModel(latency=GOOGLENET_P4_LATENCY, family="det")
base = SMDPSpec(lam=0.3 * 16 / float(svc.mean(16)), service=svc,
                energy=GOOGLENET_P4_ENERGY, b_min=1, b_max=16,
                w1=1.0, w2=1.0, s_max=48, c_o=100.0)
specs = [dataclasses.replace(base, w2=float(w))
         for w in np.linspace(0.0, 5.0, 6)]
try:
    res = sweep_solve(specs, delta=None, auto_c_o=False,
                      checkpoint_dir=ckpt, chunk_size=1)
except SweepPreempted as e:
    print("PREEMPTED", e.step, flush=True)
    sys.exit(0)
np.savez(out, policies=np.stack([r.rvi.policy for r in res]),
         g=np.array([r.rvi.g for r in res]),
         h=np.stack([r.rvi.h for r in res]))
print("COMPLETED", flush=True)
"""

#: child fleet stream: M=2 jsq fleet, saves after every chunk (throttled);
#: "resume" mode restores and pushes only the chunks past the saved seam
_CHILD_FLEET = r"""
import sys, time
import numpy as np
from repro.core import GOOGLENET_P4_ENERGY, GOOGLENET_P4_LATENCY, ServiceModel
from repro.core.policies import q_policy
from repro.serving import FleetStream

ckpt, out, mode = sys.argv[1], sys.argv[2], sys.argv[3]
svc = ServiceModel(latency=GOOGLENET_P4_LATENCY, family="det")
BMAX = 16
MEANS = np.array([0.0] + [float(svc.mean(b)) for b in range(1, BMAX + 1)])
ZETA = np.array([0.0] + [float(GOOGLENET_P4_ENERGY(b))
                         for b in range(1, BMAX + 1)])
lam = 2 * 0.7 * BMAX / float(svc.mean(BMAX))
tr = np.cumsum(np.random.default_rng(7).exponential(1.0 / lam, 4000))
tabs = np.stack([q_policy(q, 96, BMAX) for q in (4, 8)])
chunks = [tr[lo:lo + 400] for lo in range(0, len(tr), 400)]

if mode == "resume":
    fs = FleetStream.resume(ckpt)
    todo = [c for c in chunks if c[0] > fs._t_hwm]
else:
    fs = FleetStream(tabs, router="jsq", means=MEANS, zeta=ZETA,
                     b_max=BMAX, slo=3.0)
    todo = chunks
for c in todo:
    fs.push(c)
    fs.save(ckpt)
    time.sleep(0.25)
res = fs.finish()
rep = fs.report()
np.savez(out, n_served=res.n_served, n_batches=res.n_batches,
         n_epochs=res.n_epochs, n_admitted=res.n_admitted,
         energy=res.energy, lat_sum=res.lat_sum, slo_miss=res.slo_miss,
         hist=res.hist, t_final=res.t_final, n_routed=res.n_routed,
         n_served_m=res.n_served_m, p50=rep["P50"], p95=rep["P95"])
print("COMPLETED", flush=True)
"""


def _env():
    return {
        "PYTHONPATH": str(ROOT / "src"),
        "PATH": "/usr/bin:/bin",
        "HOME": "/root",
        **{k: v for k, v in os.environ.items() if k.startswith("JAX_")},
    }


def _committed_steps(d):
    return sorted(
        p for p in Path(d).glob("step_*") if not p.name.endswith(".tmp")
    )


def _spawn(script, *argv):
    return subprocess.Popen(
        [sys.executable, "-c", script, *map(str, argv)],
        env=_env(), cwd=ROOT, stdout=subprocess.PIPE,
        stderr=subprocess.PIPE, text=True,
    )


def _wait_first_commit(proc, ckpt, deadline_s=240):
    t1 = time.time() + deadline_s
    while time.time() < t1:
        if _committed_steps(ckpt):
            return
        if proc.poll() is not None:
            out, err = proc.communicate()
            raise AssertionError(
                f"child exited before first checkpoint:\n{out}\n{err}"
            )
        time.sleep(0.01)
    proc.kill()
    raise AssertionError("no checkpoint committed within deadline")


def _rerun(script, *argv):
    r = subprocess.run(
        [sys.executable, "-c", script, *map(str, argv)],
        env=_env(), cwd=ROOT, capture_output=True, text=True, timeout=300,
    )
    assert r.returncode == 0, r.stderr
    assert "COMPLETED" in r.stdout, r.stdout
    return r


class TestKillAndResume:
    def test_sigkill_mid_sweep_resumes_bitwise(self, tmp_path):
        ckpt, out = tmp_path / "ck", tmp_path / "out.npz"
        proc = _spawn(_CHILD_SWEEP, ckpt, out)
        _wait_first_commit(proc, ckpt)
        proc.kill()
        proc.wait()
        assert not out.exists()  # the kill landed mid-run
        _rerun(_CHILD_SWEEP, ckpt, out)
        got = np.load(out)
        ref = sweep_solve(
            _grid(6), delta=None, auto_c_o=False,
            checkpoint_dir=str(tmp_path / "ref"), chunk_size=1,
        )
        np.testing.assert_array_equal(
            got["policies"], np.stack([r.rvi.policy for r in ref])
        )
        np.testing.assert_array_equal(
            got["g"], np.array([r.rvi.g for r in ref])
        )
        np.testing.assert_array_equal(
            got["h"], np.stack([r.rvi.h for r in ref])
        )

    def test_sigterm_mid_sweep_preempts_then_resumes(self, tmp_path):
        ckpt, out = tmp_path / "ck", tmp_path / "out.npz"
        proc = _spawn(_CHILD_SWEEP, ckpt, out)
        _wait_first_commit(proc, ckpt)
        proc.send_signal(signal.SIGTERM)
        stdout, stderr = proc.communicate(timeout=120)
        assert proc.returncode == 0, stderr
        assert "PREEMPTED" in stdout, stdout + stderr
        assert _committed_steps(ckpt)  # progress survived the signal
        assert not out.exists()
        _rerun(_CHILD_SWEEP, ckpt, out)
        got = np.load(out)
        ref = sweep_solve(
            _grid(6), delta=None, auto_c_o=False,
            checkpoint_dir=str(tmp_path / "ref"), chunk_size=1,
        )
        np.testing.assert_array_equal(
            got["policies"], np.stack([r.rvi.policy for r in ref])
        )

    def test_sigkill_mid_stream_resumes_exactly(self, tmp_path):
        ckpt, out = tmp_path / "ck", tmp_path / "out.npz"
        proc = _spawn(_CHILD_FLEET, ckpt, out, "run")
        _wait_first_commit(proc, ckpt)
        proc.kill()
        proc.wait()
        assert not out.exists()
        _rerun(_CHILD_FLEET, ckpt, out, "resume")
        got = np.load(out)
        # uninterrupted reference, same construction as the child
        lam = 2 * LAM
        tr = np.cumsum(np.random.default_rng(7).exponential(1.0 / lam, 4000))
        tabs = np.stack([q_policy(q, 96, BMAX) for q in (4, 8)])
        fs = FleetStream(
            tabs, router="jsq", means=MEANS, zeta=ENERGY, b_max=BMAX,
            slo=3.0,
        )
        for lo in range(0, len(tr), 400):
            fs.push(tr[lo:lo + 400])
        res = fs.finish()
        rep = fs.report()
        for f in ("n_served", "n_batches", "n_epochs", "n_admitted",
                  "energy", "lat_sum", "slo_miss", "t_final"):
            assert float(got[f]) == float(getattr(res, f)), f
        np.testing.assert_array_equal(got["hist"], res.hist)
        np.testing.assert_array_equal(got["n_routed"], res.n_routed)
        np.testing.assert_array_equal(got["n_served_m"], res.n_served_m)
        assert float(got["p50"]) == rep["P50"]
        assert float(got["p95"]) == rep["P95"]
