"""Phase-modulated SMDP: exact MMPP-aware solve + phase-indexed serving.

The refactor's safety rail: the K = 1 modulated pipeline must reproduce
the scalar float64 solve() oracle bit-for-bit at the policy level.  On top:
K = 2 exactness (the exact product-chain policy beats the per-phase
heuristic *on the chain it optimizes*), the compiled phase-indexed lane
(decision-for-decision vs the Python oracle-phase path per arrival mode),
the belief-tracking non-oracle counterpart, phase-axis banks driven by the
AdaptiveController, and the DiurnalProcess arrival mode.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    GOOGLENET_P4_ENERGY,
    GOOGLENET_P4_LATENCY,
    PhaseConfig,
    ServiceModel,
    SMDPSpec,
    build_smdp_batched,
    build_smdp_modulated,
    evaluate_policy_modulated,
    modulated_spec,
    solve,
    solve_modulated,
    sweep_solve_modulated,
)
from repro.core.rvi import relative_value_iteration_modulated
from repro.serving import (
    AdaptiveController,
    BeliefPhaseScheduler,
    DiurnalProcess,
    OraclePhaseScheduler,
    PhaseBeliefFilter,
    ServingEngine,
    SMDPScheduler,
    SMDPSchedulerBank,
    TraceProcess,
    as_action_table,
    run_grid,
    verify_backends,
)
from repro.serving.arrivals import MMPP2, diurnal_times_jax, mmpp2_times_jax
from repro.serving.compiled import pad_arrivals, pad_arrivals_batch

SVC = ServiceModel(latency=GOOGLENET_P4_LATENCY, family="det")
BMAX = 16
EN = np.array(
    [0.0] + [float(GOOGLENET_P4_ENERGY(b)) for b in range(1, BMAX + 1)]
)


def spec_at(lam, w2=1.0, s_max=64, family="det"):
    return SMDPSpec(
        lam=lam,
        service=ServiceModel(latency=GOOGLENET_P4_LATENCY, family=family),
        energy=GOOGLENET_P4_ENERGY,
        b_min=1, b_max=BMAX, w1=1.0, w2=w2, s_max=s_max,
    )


def rho_lam(rho):
    return rho * BMAX / float(SVC.mean(BMAX))


def mmpp_at(r1=0.15, r2=0.75, d1=600.0, d2=300.0):
    return PhaseConfig.mmpp2(rho_lam(r1), rho_lam(r2), d1, d2)


class TestModulatedBuild:
    def test_k1_banded_data_matches_scalar(self):
        """K = 1 degenerates bitwise: D_{n,k} = delta_nk makes the
        phase-coupled pmfs exactly the Poisson arrival pmfs."""
        spec = spec_at(rho_lam(0.6), s_max=32)
        mb = build_smdp_modulated(spec, PhaseConfig.poisson(spec.lam))
        sb = build_smdp_batched([spec])
        np.testing.assert_array_equal(
            mb.pmfs_banded[0, :, 0, 0, :], sb.pmfs_banded[0]
        )
        np.testing.assert_array_equal(mb.tails[0, :, 0, 0, :], sb.tails[0])
        np.testing.assert_array_equal(mb.y[0, 0], sb.y[0])
        assert mb.eta[0] == sb.eta[0]
        np.testing.assert_array_equal(mb.scale[0, 0], sb.scale[0])
        assert mb.wait_m[0, 0, 0] == 1.0
        mask = np.isfinite(sb.c_tilde[0])
        np.testing.assert_allclose(
            mb.c_tilde[0, 0][mask], sb.c_tilde[0][mask], rtol=1e-11
        )

    @pytest.mark.parametrize("family", ["expo", "erlang", "hyperexpo"])
    def test_k1_pmfs_exact_per_family(self, family):
        spec = spec_at(rho_lam(0.5), s_max=32, family=family)
        mb = build_smdp_modulated(spec, PhaseConfig.poisson(spec.lam))
        sb = build_smdp_batched([spec])
        np.testing.assert_array_equal(
            mb.pmfs_banded[0, :, 0, 0, :], sb.pmfs_banded[0]
        )

    def test_k2_transition_rows_stochastic(self):
        ph = mmpp_at()
        spec = modulated_spec(spec_at(1.0, s_max=32), ph)
        mb = build_smdp_modulated(spec, ph)
        # wait-phase matrix is a proper phase law
        np.testing.assert_allclose(mb.wait_m[0].sum(axis=1), 1.0, atol=1e-12)
        # serve mass: band + tails == 1 per (action, start phase)
        a = 5
        tot = mb.pmfs_banded[0, a].sum(axis=(1, 2)) + mb.tails[
            0, a, :, :, 0
        ].sum(axis=1)
        np.testing.assert_allclose(tot, 1.0, atol=1e-10)
        # embedded chain rows under a feasible policy
        from repro.core.policies import greedy_policy

        pol = np.tile(greedy_policy(spec.s_max, 1, BMAX)[None], (2, 1))[None]
        p = mb.policy_transitions_batched(pol)
        np.testing.assert_allclose(p.sum(axis=-1), 1.0, atol=1e-12)

    def test_lam_mean_rate_mismatch_raises(self):
        ph = mmpp_at()
        with pytest.raises(ValueError, match="mean rate"):
            build_smdp_modulated(spec_at(1.0, s_max=32), ph)

    def test_with_c_o_is_row_patch(self):
        ph = mmpp_at()
        spec = modulated_spec(spec_at(1.0, s_max=32), ph)
        mb = build_smdp_modulated(spec, ph)
        patched = mb.with_c_o([250.0])
        rebuilt = build_smdp_modulated(
            dataclasses.replace(spec, c_o=250.0), ph
        )
        np.testing.assert_allclose(
            patched.c_tilde[0], rebuilt.c_tilde[0], rtol=1e-12
        )
        np.testing.assert_array_equal(patched.pmfs_banded, rebuilt.pmfs_banded)


class TestModulatedSolve:
    @pytest.mark.parametrize("rho", [0.3, 0.7])
    def test_k1_policy_bit_identical_to_solve_oracle(self, rho):
        """ISSUE acceptance: the degenerate K = 1 modulated solve (full
        pipeline: c_o calibration + adaptive truncation + RVI) reproduces
        the scalar f64 solve() oracle policy bit-for-bit."""
        spec = spec_at(rho_lam(rho))
        r_scalar = solve(spec)
        r_mod = solve_modulated(spec, PhaseConfig.poisson(spec.lam))
        assert r_mod.spec.s_max == r_scalar.spec.s_max
        np.testing.assert_array_equal(r_mod.policy[0], r_scalar.policy)
        np.testing.assert_allclose(r_mod.eval.g, r_scalar.eval.g, rtol=1e-9)
        np.testing.assert_allclose(
            r_mod.eval.w_bar, r_scalar.eval.w_bar, rtol=1e-9
        )

    def test_k1_accel_none_matches_oracle_too(self):
        spec = spec_at(rho_lam(0.7))
        r_scalar = solve(spec)
        r_mod = solve_modulated(
            spec, PhaseConfig.poisson(spec.lam), accel="none"
        )
        np.testing.assert_array_equal(r_mod.policy[0], r_scalar.policy)

    def test_k2_mpi_matches_plain(self):
        ph = mmpp_at()
        spec = modulated_spec(spec_at(1.0, w2=0.5), ph)
        mb = build_smdp_modulated(spec, ph)
        r_plain = relative_value_iteration_modulated(mb, accel="none")
        r_mpi = relative_value_iteration_modulated(mb, accel="mpi")
        np.testing.assert_array_equal(r_plain.policies, r_mpi.policies)
        np.testing.assert_allclose(r_plain.g, r_mpi.g, rtol=1e-8)
        # the polish must pay: strictly fewer backups in the slow-mixing case
        assert r_mpi.iterations[0] < r_plain.iterations[0]

    def test_k2_exact_beats_phase_heuristic_on_chain(self):
        """ISSUE acceptance (chain half): the exact product-chain policy's
        average cost is <= the per-phase heuristic's on the same chain."""
        ph = mmpp_at()
        spec = modulated_spec(spec_at(1.0, w2=0.5), ph)
        exact = solve_modulated(spec, ph)
        s_max = exact.spec.s_max
        heur_rows = []
        for lam in ph.rates:
            t = solve(
                dataclasses.replace(exact.spec, lam=float(lam))
            ).action_table(s_max)
            heur_rows.append(np.append(t, t[-1]))
        heur_pol = np.stack(heur_rows)
        mb = build_smdp_modulated(exact.spec, ph)
        g_heur = evaluate_policy_modulated(mb, 0, heur_pol).g
        assert exact.eval.g <= g_heur * (1.0 + 1e-9)
        # and the phase rows genuinely differ (the burst phase batches more)
        assert not np.array_equal(exact.policy[0], exact.policy[1])

    def test_sweep_matches_serial_and_orders_back(self):
        ph = mmpp_at()
        base = spec_at(1.0, w2=0.5, s_max=48)
        pairs = [(modulated_spec(base, p), p)
                 for p in (ph.scaled(f) for f in (1.2, 0.6, 1.0))]
        res = sweep_solve_modulated([s for s, _ in pairs], [p for _, p in pairs])
        for (sp, p), r in zip(pairs, res):
            assert r.spec.lam == sp.lam
            serial = solve_modulated(sp, p)
            np.testing.assert_array_equal(
                r.action_table(), serial.action_table()
            )


class TestPhaseAxisBankAndSchedulers:
    def _stack_bank(self):
        lo = np.array([[0, 1, 2, 2, 2], [0, 2, 3, 4, 4]])
        hi = np.array([[0, 1, 4, 6, 8], [0, 4, 6, 8, 8]])
        return SMDPSchedulerBank(
            {(1.0,): lo, (10.0,): hi}, key_names=("lam",)
        )

    def test_bank_accepts_phase_stacks(self):
        bank = self._stack_bank()
        assert bank.n_phases == 2
        ks, stacked = bank.stacked()
        assert stacked.shape == (2, 2, 5)
        sch = bank.scheduler(lam=1.0)
        assert sch.n_phases == 2
        assert sch.decide(3) == 2  # phase 0 row
        sch.phase = 1
        assert sch.decide(3) == 4  # phase 1 row

    def test_out_of_range_phase_fails_loudly(self):
        """Both backends reject a phase outside the stack — no silent
        clamping divergence between decide() and the compiled lane."""
        sch = SMDPScheduler.from_table(np.array([[0, 1], [0, 2]]))
        sch.phase = 5
        with pytest.raises(ValueError, match="outside table stack"):
            sch.decide(1)
        sch.phase = -1
        with pytest.raises(ValueError, match="outside table stack"):
            sch.decide(1)

    def test_bank_rejects_mixed_phase_axes(self):
        with pytest.raises(ValueError, match="phase axis"):
            SMDPSchedulerBank(
                {(1.0,): np.array([0, 1, 2]),
                 (2.0,): np.array([[0, 1], [0, 2]])},
                key_names=("lam",),
            )

    def test_as_action_table_phase_stack(self):
        sched = SMDPScheduler.from_table(
            np.array([[0, 1, 2], [0, 2, 3]])
        )
        tab = as_action_table(sched, BMAX)
        assert tab.shape == (2, 3)
        np.testing.assert_array_equal(
            sched.phase_at(np.arange(4.0)), np.zeros(4)
        )
        oracle = OraclePhaseScheduler(
            {0: np.array([0, 1]), 1: np.array([0, 2, 3])}, [(0.0, 0), (5.0, 1)]
        )
        tab = as_action_table(oracle, BMAX)
        assert tab.shape == (2, 3)
        np.testing.assert_array_equal(tab[0], [0, 1, 1])  # padded by last
        np.testing.assert_array_equal(
            oracle.phase_at(np.array([1.0, 6.0])), [0, 1]
        )

    def test_adaptive_controller_drives_phase_axis_bank(self):
        """ISSUE satellite: retune + hysteresis when BOTH the lambda
        estimate and the phase belief move."""
        bank = self._stack_bank()
        filt = PhaseBeliefFilter(
            rates=[1.0, 10.0], gen=[[-0.01, 0.01], [0.01, -0.01]]
        )
        ctrl = AdaptiveController(
            bank, ewma=0.3, margin=0.0, phase_filter=filt, init_rate=1.0
        )
        t = 0.0
        for _ in range(60):  # slow arrivals: rate ~1, belief -> phase 0
            t += 1.0
            ctrl.observe_arrival(t)
        assert ctrl.key == (1.0,)
        assert ctrl.scheduler.phase == 0
        assert ctrl.decide(3) == 2  # lo stack, phase-0 row
        for _ in range(120):  # fast arrivals: rate ~10, belief -> phase 1
            t += 0.1
            ctrl.observe_arrival(t)
        assert ctrl.key == (10.0,)
        assert ctrl.scheduler.phase == 1
        assert ctrl.decide(3) == 8  # hi stack, phase-1 row
        assert ctrl.n_switches >= 1

    def test_adaptive_phase_hysteresis_blocks_midpoint(self):
        """A wide margin must block the bank swap even while the belief
        keeps tracking the phase — the two adaptation axes are independent."""
        bank = self._stack_bank()
        filt = PhaseBeliefFilter(
            rates=[1.0, 10.0], gen=[[-0.01, 0.01], [0.01, -0.01]]
        )
        ctrl = AdaptiveController(
            bank, ewma=1.0, margin=0.5, phase_filter=filt, init_rate=1.0
        )
        t = 0.0
        for _ in range(40):  # rate 6: just past the key midpoint
            t += 1.0 / 6.0
            ctrl.observe_arrival(t)
        assert ctrl.key == (1.0,)  # hysteresis holds the table
        assert ctrl.scheduler.phase == 1  # belief still moved

    def test_adaptive_phase_snapshot_restore(self):
        bank = self._stack_bank()
        filt = PhaseBeliefFilter(
            rates=[1.0, 10.0], gen=[[-0.01, 0.01], [0.01, -0.01]]
        )
        ctrl = AdaptiveController(bank, ewma=0.5, phase_filter=filt)
        t = 0.0
        for _ in range(30):
            t += 0.1
            ctrl.observe_arrival(t)
        snap = ctrl.snapshot()
        key, phase, belief = ctrl.key, ctrl.scheduler.phase, filt.belief.copy()
        for _ in range(30):
            t += 1.0
            ctrl.observe_arrival(t)
        ctrl.restore(snap)
        assert ctrl.key == key
        assert ctrl.scheduler.phase == phase
        np.testing.assert_allclose(filt.belief, belief)

    def test_belief_scheduler_tracks_oracle(self):
        m = MMPP2(lam1=0.3, lam2=4.0, dwell1=400.0, dwell2=200.0)
        trace, switches = m.sample_arrivals(3000.0, np.random.default_rng(4))
        filt = PhaseBeliefFilter(
            rates=[m.lam1, m.lam2],
            gen=[[-1 / m.dwell1, 1 / m.dwell1],
                 [1 / m.dwell2, -1 / m.dwell2]],
        )
        tabs = np.array([[0, 1, 1], [0, 2, 2]])
        belief = BeliefPhaseScheduler(tabs, filt)
        oracle = OraclePhaseScheduler({0: tabs[0], 1: tabs[1]}, switches)
        agree = 0
        for t_a in trace:
            belief.observe_arrival(t_a)
            oracle.observe_arrival(t_a)
            agree += belief.phase == oracle.phase
        assert agree / len(trace) > 0.9

    def test_belief_scheduler_compiled_matches_python(self):
        """BeliefPhaseScheduler now lowers to the compiled belief lane
        (posterior precomputed by one jitted scan, argmax row in-kernel):
        both backends agree decision-for-decision (it used to be rejected
        with a TypeError)."""
        m = MMPP2(lam1=0.3, lam2=4.0, dwell1=40.0, dwell2=20.0)
        gen = [[-1 / m.dwell1, 1 / m.dwell1], [1 / m.dwell2, -1 / m.dwell2]]

        def mk():
            filt = PhaseBeliefFilter(rates=[m.lam1, m.lam2], gen=gen)
            sched = BeliefPhaseScheduler(np.array([[0, 1, 1], [0, 2, 2]]), filt)
            return ServingEngine(
                sched, arrivals=m, b_max=BMAX, service=SVC, energy_table=EN,
                seed=5,
            )

        r_py = mk().run(600)
        r_c = mk().run(600, backend="compiled")
        np.testing.assert_array_equal(r_py.batch_sizes, r_c.batch_sizes)
        np.testing.assert_allclose(r_py.latencies, r_c.latencies, atol=1e-9)
        np.testing.assert_allclose(r_py.energy, r_c.energy)


class TestCompiledPhaseLane:
    """ISSUE acceptance: compiled phase lane decision-for-decision equal to
    the Python engine's oracle-phase path at equal seeds."""

    def _mmpp_trace(self, n=2000, seed=0):
        lam = rho_lam(0.7)
        m = MMPP2(lam1=0.3 * lam, lam2=1.3 * lam, dwell1=60.0, dwell2=30.0)
        trace, switches = m.sample_arrivals(
            n / m.mean_rate, np.random.default_rng(seed)
        )
        st = np.array([t for t, _ in switches])
        sp = np.array([p for _, p in switches], dtype=np.int64)
        phases = sp[np.maximum(np.searchsorted(st, trace, "right") - 1, 0)]
        return trace, phases, switches

    def _stack(self):
        from repro.core.policies import q_policy

        return np.stack(
            [q_policy(4, 128, BMAX), q_policy(12, 128, BMAX)]
        )

    @pytest.mark.parametrize("mode", ["mmpp2", "poisson", "diurnal"])
    def test_verify_backends_phase_lane_per_arrival_mode(self, mode):
        if mode == "mmpp2":
            trace, phases, _ = self._mmpp_trace()
        elif mode == "poisson":
            rng = np.random.default_rng(1)
            trace = np.cumsum(rng.exponential(1.0 / rho_lam(0.7), 1500))
            # synthetic block phases over a Poisson trace
            phases = (trace // 25.0).astype(np.int64) % 2
        else:
            proc = DiurnalProcess(
                base=rho_lam(0.5), amp=0.8 * rho_lam(0.5), period=300.0
            )
            from repro.serving.arrivals import take

            evs, _ = take(proc, np.random.default_rng(2), n=1500)
            trace = np.array([e.time for e in evs])
            phases = (proc.rate(trace) > proc.base).astype(np.int64)
        out = verify_backends(
            self._stack(), trace, service=SVC, energy_table=EN, b_max=BMAX,
            phases=phases,
        )
        assert out["n_decisions"] > 0
        assert out["max_latency_err"] <= 1e-9

    def test_verify_backends_phase_lane_stochastic_service(self):
        trace, phases, _ = self._mmpp_trace(1200, seed=3)
        verify_backends(
            self._stack(), trace,
            service=ServiceModel(latency=GOOGLENET_P4_LATENCY, family="expo"),
            energy_table=EN, b_max=BMAX, phases=phases,
        )

    def test_verify_backends_phase_lane_budget_and_slo(self):
        trace, phases, _ = self._mmpp_trace(1200, seed=5)
        verify_backends(
            self._stack(), trace, service=SVC, energy_table=EN, b_max=BMAX,
            phases=phases, n_epochs=400, slo=8.0,
        )

    def test_engine_oracle_phase_backend_parity(self):
        trace, _, switches = self._mmpp_trace(1500, seed=7)
        stack = self._stack()

        def eng():
            sched = OraclePhaseScheduler(
                {0: stack[0], 1: stack[1]}, switches
            )
            return ServingEngine(
                sched, arrivals=TraceProcess(trace), b_max=BMAX,
                service=SVC, energy_table=EN, seed=11,
            )

        r_py = eng().run(n_epochs=None)
        r_c = eng().run(n_epochs=None, backend="compiled")
        np.testing.assert_array_equal(r_py.batch_sizes, r_c.batch_sizes)
        np.testing.assert_allclose(r_py.latencies, r_c.latencies, atol=1e-9)
        np.testing.assert_allclose(r_py.energy, r_c.energy)

    def test_escalation_preserves_phase_stream(self):
        """Epoch-budgeted MMPP2 run: the compiled path may extend the
        pre-drawn stream (doubling escalation); the sampler phase carry and
        the recomputed per-arrival phases must stay consistent with the
        lazy path."""
        lam = rho_lam(0.7)
        m = MMPP2(lam1=0.3 * lam, lam2=1.3 * lam, dwell1=60.0, dwell2=30.0)
        trace, switches = m.sample_arrivals(
            3000 / m.mean_rate, np.random.default_rng(13)
        )
        stack = self._stack()

        def eng():
            sched = OraclePhaseScheduler(
                {0: stack[0], 1: stack[1]}, switches
            )
            return ServingEngine(
                sched, arrivals=TraceProcess(trace), b_max=BMAX,
                service=SVC, energy_table=EN, seed=1,
            )

        r_py = eng().run(900)
        r_c = eng().run(900, backend="compiled")
        np.testing.assert_array_equal(r_py.batch_sizes, r_c.batch_sizes)
        np.testing.assert_allclose(r_py.latencies, r_c.latencies, atol=1e-9)

    def test_phase_table_without_phases_raises(self):
        from repro.serving.compiled import simulate_compiled

        with pytest.raises(ValueError, match="phases"):
            simulate_compiled(
                self._stack(), np.arange(1.0, 10.0),
                means=np.array([0.0] + [1.0] * BMAX), b_max=BMAX,
            )
        with pytest.raises(ValueError, match="phases"):
            run_grid(
                self._stack()[None], np.stack([pad_arrivals(np.arange(5.0))[0]]),
                means=np.array([0.0] + [1.0] * BMAX), b_max=BMAX,
            )

    def test_run_grid_phase_stacks_match_python(self):
        traces, phase_streams = [], []
        for s in (0, 1):
            tr, ph, _ = self._mmpp_trace(900, seed=20 + s)
            traces.append(tr)
            phase_streams.append(ph)
        arrs = pad_arrivals_batch(traces)
        phs = np.stack(
            [
                pad_arrivals(t, phases=p, size=arrs.shape[1])[2]
                for t, p in zip(traces, phase_streams)
            ]
        )
        stack = self._stack()
        tables = np.stack([stack, stack[::-1]])  # two contenders
        means = np.array(
            [0.0] + [float(SVC.mean(b)) for b in range(1, BMAX + 1)]
        )
        g = run_grid(
            tables, arrs, phases=phs, means=means, zeta=EN, b_max=BMAX
        )
        for s in (0, 1):
            st = np.array([0.0])
            for p in (0, 1):
                log = [(traces[s][0], int(phase_streams[s][0]))] + [
                    (float(t), int(a))
                    for t, a, b in zip(
                        traces[s][1:], phase_streams[s][1:],
                        phase_streams[s][:-1],
                    )
                    if a != b
                ]
                sched = OraclePhaseScheduler(
                    {0: tables[p][0], 1: tables[p][1]}, log
                )
                rep = ServingEngine(
                    sched, arrivals=TraceProcess(traces[s]), b_max=BMAX,
                    service=SVC, energy_table=EN,
                ).run(n_epochs=None)
                np.testing.assert_allclose(
                    g["w_mean"][s, p], rep.latencies.mean(), atol=1e-9
                )
                assert g["n_served"][s, p] == rep.n_served

    def test_jax_mmpp_sampler_phases_feed_grid(self):
        """The sampler-carry phases drive the compiled lane end to end."""
        import jax

        lam = rho_lam(0.6)
        m = MMPP2(lam1=0.4 * lam, lam2=1.4 * lam, dwell1=80.0, dwell2=40.0)
        times, mask, phases = mmpp2_times_jax(
            jax.random.PRNGKey(3), m, 2048, with_phases=True
        )
        times, mask, phases = (np.asarray(x) for x in (times, mask, phases))
        n = int(mask.sum())
        arr, _, ph = pad_arrivals(times[:n], phases=phases[:n])
        means = np.array(
            [0.0] + [float(SVC.mean(b)) for b in range(1, BMAX + 1)]
        )
        g = run_grid(
            self._stack()[None], arr[None], phases=ph[None],
            means=means, zeta=EN, b_max=BMAX,
        )
        assert int(g["n_served"][0, 0]) == n


class TestCompiledOnlineLanes:
    """ISSUE acceptance: the deployable (non-oracle) lanes — belief-argmax,
    belief-mixture, and the in-carry adaptive controller — certify
    decision-for-decision against the Python engine via
    ``verify_backends(scheduler=...)`` on every arrival family."""

    MODES = ("poisson", "mmpp2", "diurnal", "trace")

    def _trace(self, mode, n=1200, seed=0):
        lam = rho_lam(0.7)
        rng = np.random.default_rng(seed)
        if mode == "poisson":
            return np.cumsum(rng.exponential(1.0 / lam, n))
        if mode == "mmpp2":
            m = MMPP2(
                lam1=0.3 * lam, lam2=1.3 * lam, dwell1=60.0, dwell2=30.0
            )
            trace, _ = m.sample_arrivals(n / m.mean_rate, rng)
            return np.asarray(trace)
        if mode == "diurnal":
            from repro.serving.arrivals import take

            proc = DiurnalProcess(base=lam, amp=0.8 * lam, period=200.0)
            evs, _ = take(proc, rng, n=n)
            return np.array([e.time for e in evs])
        # "trace": a recorded stream — bursty clumps over long quiet
        # stretches, a shape no renewal model in the zoo generates
        gaps = np.where(
            rng.random(n) < 0.15,
            rng.exponential(6.0 / lam, n),
            rng.exponential(0.4 / lam, n),
        )
        return np.cumsum(gaps)

    def _stack(self):
        from repro.core.policies import q_policy

        return np.stack([q_policy(4, 128, BMAX), q_policy(12, 128, BMAX)])

    def _belief_factory(self, mode="argmax"):
        lam = rho_lam(0.7)
        stack = self._stack()

        def mk():
            filt = PhaseBeliefFilter(
                rates=[0.3 * lam, 1.3 * lam],
                gen=[[-1 / 60.0, 1 / 60.0], [1 / 30.0, -1 / 30.0]],
            )
            return BeliefPhaseScheduler(stack, filt, mode=mode)

        return mk

    def _adaptive_factory(self, with_filter=False):
        from repro.core.policies import q_policy

        lam = rho_lam(0.7)
        if with_filter:
            lo = np.stack([q_policy(4, 128, BMAX), q_policy(8, 128, BMAX)])
            hi = np.stack([q_policy(10, 128, BMAX), q_policy(14, 128, BMAX)])
        else:
            lo = q_policy(4, 128, BMAX)
            hi = q_policy(12, 128, BMAX)
        bank = SMDPSchedulerBank(
            {(0.4 * lam,): lo, (1.2 * lam,): hi}, key_names=("lam",)
        )

        def mk():
            filt = (
                PhaseBeliefFilter(
                    rates=[0.3 * lam, 1.3 * lam],
                    gen=[[-1 / 60.0, 1 / 60.0], [1 / 30.0, -1 / 30.0]],
                )
                if with_filter
                else None
            )
            return AdaptiveController(
                bank, ewma=0.2, margin=0.1, min_dwell=5.0, phase_filter=filt
            )

        return mk

    @pytest.mark.parametrize("mode", MODES)
    def test_belief_argmax_lane_certified(self, mode):
        out = verify_backends(
            None, self._trace(mode), service=SVC, energy_table=EN,
            b_max=BMAX, scheduler=self._belief_factory("argmax"),
        )
        assert out["n_decisions"] > 0
        assert out["max_latency_err"] <= 1e-9

    @pytest.mark.parametrize("mode", MODES)
    def test_adaptive_lane_certified(self, mode):
        out = verify_backends(
            None, self._trace(mode, seed=3), service=SVC, energy_table=EN,
            b_max=BMAX, scheduler=self._adaptive_factory(),
        )
        assert out["n_decisions"] > 0
        assert out["max_latency_err"] <= 1e-9

    @pytest.mark.parametrize("mode", ("mmpp2", "trace"))
    def test_belief_mix_lane_certified(self, mode):
        out = verify_backends(
            None, self._trace(mode, seed=5), service=SVC, energy_table=EN,
            b_max=BMAX, scheduler=self._belief_factory("mix"),
        )
        assert out["n_decisions"] > 0

    def test_adaptive_with_belief_filter_certified(self):
        """Both adaptation axes live at once: the in-carry estimator swaps
        the bank entry while the precomputed posterior picks the row."""
        verify_backends(
            None, self._trace("mmpp2", seed=7), service=SVC,
            energy_table=EN, b_max=BMAX,
            scheduler=self._adaptive_factory(with_filter=True),
        )

    def test_adaptive_lane_stochastic_service(self):
        verify_backends(
            None, self._trace("mmpp2", n=900, seed=11),
            service=ServiceModel(latency=GOOGLENET_P4_LATENCY, family="expo"),
            energy_table=EN, b_max=BMAX, scheduler=self._adaptive_factory(),
        )

    @pytest.mark.parametrize("mode", MODES)
    def test_adaptive_snapshot_restore_mid_dwell_replays(self, mode):
        """ISSUE satellite: snapshot() taken *inside* the dwell window —
        right at a switch, when the hysteresis clock is hot — restores to
        an identical replay (same keys, decisions, switch counts)."""
        trace = self._trace(mode, n=900, seed=9)
        ctrl = self._adaptive_factory()()
        cut = None
        for i, t in enumerate(trace):
            ctrl.observe_arrival(float(t))
            if ctrl.n_switches >= 1:
                cut = i + 1
                break
        assert cut is not None, "stream never tripped a bank switch"
        assert trace[cut - 1] - ctrl._last_switch < ctrl.min_dwell
        snap = ctrl.snapshot()
        tail = trace[cut:]

        def replay():
            out = []
            for i, t in enumerate(tail):
                ctrl.observe_arrival(float(t))
                out.append(
                    (ctrl.key, ctrl.decide(1 + i % 7), ctrl.n_switches)
                )
            return out, ctrl.estimator.snapshot()

        run1, est1 = replay()
        ctrl.restore(snap)
        run2, est2 = replay()
        assert run1 == run2
        assert est1 == est2

    def test_belief_forward_jax_matches_filter(self):
        """The jitted scan reproduces the Python filter fold draw for draw
        (same guarded renormalization) and leaves the filter untouched."""
        from repro.serving.arrivals import belief_forward_jax

        lam = rho_lam(0.7)
        trace = self._trace("mmpp2", n=800, seed=21)
        mk = lambda: PhaseBeliefFilter(
            rates=[0.3 * lam, 1.3 * lam],
            gen=[[-1 / 60.0, 1 / 60.0], [1 / 30.0, -1 / 30.0]],
        )
        ref_filt = mk()
        ref = np.empty((len(trace), 2))
        for i, t in enumerate(trace):
            ref_filt.observe(t)
            ref[i] = ref_filt.belief
        filt = mk()
        b0 = filt.belief.copy()
        bel, (b_fin, t_fin) = belief_forward_jax(trace, filt)
        np.testing.assert_allclose(np.asarray(bel), ref, atol=1e-12)
        np.testing.assert_allclose(np.asarray(b_fin), ref[-1], atol=1e-12)
        assert float(t_fin) == trace[-1]
        np.testing.assert_array_equal(filt.belief, b0)  # not mutated
        assert filt.n_observed == 0
        # batched lane: two stacked traces, same rows per lane
        two = np.stack([trace, trace + 0.5])
        bel2, _ = belief_forward_jax(two, mk())
        np.testing.assert_allclose(np.asarray(bel2)[0], ref, atol=1e-12)

    def test_run_grid_adaptive_matches_python_engines(self):
        from repro.serving.compiled import AdaptiveLane, run_grid_adaptive

        factory = self._adaptive_factory()
        traces = [self._trace("mmpp2", n=700, seed=30 + s) for s in (0, 1)]
        arrs = pad_arrivals_batch(traces)
        means = np.array(
            [0.0] + [float(SVC.mean(b)) for b in range(1, BMAX + 1)]
        )
        g = run_grid_adaptive(
            arrs, adaptive=AdaptiveLane.from_controller(factory()),
            means=means, zeta=EN, b_max=BMAX,
        )
        for s, tr in enumerate(traces):
            ctrl = factory()
            rep = ServingEngine(
                ctrl, arrivals=TraceProcess(tr), b_max=BMAX,
                service=SVC, energy_table=EN,
            ).run(n_epochs=None)
            np.testing.assert_allclose(
                g["w_mean"][s], rep.latencies.mean(), atol=1e-9
            )
            assert int(g["n_served"][s]) == rep.n_served
            np.testing.assert_allclose(g["energy"][s], rep.energy)
            assert int(g["ad_n_switches"][s]) == ctrl.n_switches

    def test_run_grid_belief_modes_match_python_engines(self):
        """run_grid's belief_argmax / belief_mix modes vs per-trace Python
        BeliefPhaseScheduler engines."""
        from repro.serving.arrivals import belief_forward_jax

        lam = rho_lam(0.7)
        traces = [self._trace("mmpp2", n=700, seed=40 + s) for s in (0, 1)]
        arrs = pad_arrivals_batch(traces)
        stack = self._stack()
        means = np.array(
            [0.0] + [float(SVC.mean(b)) for b in range(1, BMAX + 1)]
        )
        mk_filt = lambda: PhaseBeliefFilter(
            rates=[0.3 * lam, 1.3 * lam],
            gen=[[-1 / 60.0, 1 / 60.0], [1 / 30.0, -1 / 30.0]],
        )
        bels = np.stack([
            np.asarray(
                belief_forward_jax(
                    pad_arrivals(t, size=arrs.shape[1])[0], mk_filt()
                )[0]
            )
            for t in traces
        ])
        for pm in ("belief_argmax", "belief_mix"):
            g = run_grid(
                stack[None], arrs, means=means, zeta=EN, b_max=BMAX,
                phase_mode=pm, beliefs=bels,
            )
            mode = "argmax" if pm == "belief_argmax" else "mix"
            for s, tr in enumerate(traces):
                sched = BeliefPhaseScheduler(stack, mk_filt(), mode=mode)
                rep = ServingEngine(
                    sched, arrivals=TraceProcess(tr), b_max=BMAX,
                    service=SVC, energy_table=EN,
                ).run(n_epochs=None)
                np.testing.assert_allclose(
                    g["w_mean"][s, 0], rep.latencies.mean(), atol=1e-9
                )
                assert int(g["n_served"][s, 0]) == rep.n_served


class TestDiurnalProcess:
    def test_mean_rate_sine_and_ramp(self):
        from repro.serving.arrivals import take

        p = DiurnalProcess(base=2.0, amp=1.5, period=200.0)
        evs, _ = take(p, np.random.default_rng(1), horizon=4000.0)
        assert abs(len(evs) / 4000.0 - 2.0) / 2.0 < 0.1
        r = DiurnalProcess(ramp=[(0.0, 1.0), (100.0, 3.0)], period=200.0)
        assert r.rate_max == 3.0
        assert 1.0 < r.mean_rate < 3.0

    def test_rate_must_stay_positive(self):
        with pytest.raises(ValueError, match="positive"):
            DiurnalProcess(base=1.0, amp=1.5, period=10.0)

    def test_snapshot_restore_replays(self):
        p = DiurnalProcess(base=2.0, amp=1.0, period=100.0)
        rng = np.random.default_rng(7)
        for _ in range(10):
            p.next(rng)
        snap, state = p.snapshot(), rng.bit_generator.state
        a = [p.next(rng).time for _ in range(5)]
        p.restore(snap)
        rng.bit_generator.state = state
        b = [p.next(rng).time for _ in range(5)]
        assert a == b

    def test_engine_backend_parity_diurnal(self):
        def eng():
            return ServingEngine(
                SMDPScheduler.from_table(
                    np.minimum(np.arange(130), 8)
                ),
                arrivals=DiurnalProcess(base=1.5, amp=1.0, period=300.0),
                b_max=8, service=SVC, energy_table=np.zeros(9), seed=5,
            )

        r_py = eng().run(800)
        r_c = eng().run(800, backend="compiled")
        np.testing.assert_array_equal(r_py.batch_sizes, r_c.batch_sizes)
        np.testing.assert_allclose(r_py.latencies, r_c.latencies, atol=1e-9)

    def test_jax_sampler_sorted_and_rate(self):
        import jax

        p = DiurnalProcess(base=2.0, amp=1.2, period=150.0)
        t, m = diurnal_times_jax(jax.random.PRNGKey(0), p, 16384)
        t, m = np.asarray(t), np.asarray(m)
        n = int(m.sum())
        assert np.all(np.isinf(t[n:]))
        assert np.all(np.diff(t[:n]) >= 0)
        assert abs(n / t[n - 1] - 2.0) / 2.0 < 0.1


class TestDeprecationShim:
    def test_mmpp_module_reexports_warn_on_access(self):
        # the import itself is warning-clean (module __getattr__ shim);
        # only touching a moved name warns — once
        import importlib
        import sys
        import warnings

        sys.modules.pop("repro.serving.mmpp", None)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            mod = importlib.import_module("repro.serving.mmpp")
        with pytest.warns(DeprecationWarning, match="deprecated"):
            mod.MMPP2
        for name in (
            "MMPP2", "MMPP2Process", "OraclePhaseScheduler",
            "PhaseAwareScheduler", "solve_phase_policies", "run_mmpp",
        ):
            assert hasattr(mod, name)
