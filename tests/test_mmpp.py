"""Beyond-paper: phase-aware SMDP scheduling under bursty (MMPP) traffic.

The paper's Sec.-VIII proposal made executable: under MMPP(2) arrivals,
per-phase SMDP policies selected by an online rate estimator should beat a
single SMDP policy solved for the mean rate.
"""
import numpy as np
import pytest

from repro.core import (
    GOOGLENET_P4_ENERGY,
    GOOGLENET_P4_LATENCY,
    ServiceModel,
    SMDPSpec,
    solve,
)
from repro.serving.mmpp import (
    MMPP2,
    PhaseAwareScheduler,
    run_mmpp,
    solve_phase_policies,
)
from repro.serving.scheduler import SMDPScheduler

SVC = ServiceModel(latency=GOOGLENET_P4_LATENCY, family="det")
BMAX = 32
EN = np.array([0.0] + [float(GOOGLENET_P4_ENERGY(b)) for b in range(1, BMAX + 1)])


def base_spec(lam):
    return SMDPSpec(lam=lam, service=SVC, energy=GOOGLENET_P4_ENERGY,
                    b_min=1, b_max=BMAX, w1=1.0, w2=1.0, s_max=128)


class TestMMPP:
    def test_mean_rate(self):
        m = MMPP2(lam1=0.5, lam2=2.5, dwell1=300.0, dwell2=100.0)
        arr, _ = m.sample_arrivals(200_000.0, np.random.default_rng(0))
        np.testing.assert_allclose(len(arr) / 200_000.0, m.mean_rate, rtol=0.05)

    def test_phase_aware_beats_mean_rate_policy(self):
        """Latency-focused objective (w2=0): phase policies differ in their
        control limits, so phase-awareness should gain >5% (measured ~15%;
        with large w2 both phases converge to max-batching and the gain
        vanishes — see benchmarks/mmpp_bursty.py)."""
        import dataclasses

        mu_max = BMAX / float(SVC.mean(BMAX))
        m = MMPP2(lam1=0.05 * mu_max, lam2=0.90 * mu_max,
                  dwell1=1000.0, dwell2=1000.0)
        rates = {0: m.lam1, 1: m.lam2}
        spec0 = dataclasses.replace(base_spec(m.mean_rate), w2=0.0)
        tables = solve_phase_policies(spec0, rates)
        phase_sched = PhaseAwareScheduler(tables, rates, ewma=0.1)
        mean_sched = SMDPScheduler(solve(spec0))

        horizon = 60_000.0
        lat_p, _, _ = run_mmpp(phase_sched, m, SVC, EN, BMAX, horizon, seed=1)
        lat_m, _, _ = run_mmpp(mean_sched, m, SVC, EN, BMAX, horizon, seed=1)
        assert len(lat_p) > 10_000
        assert lat_p.mean() < lat_m.mean() * 0.97, (lat_p.mean(), lat_m.mean())

    def test_estimator_tracks_phase(self):
        rates = {0: 0.5, 1: 5.0}
        sched = PhaseAwareScheduler({0: np.zeros(4), 1: np.zeros(4)}, rates)
        t = 0.0
        for _ in range(50):  # fast arrivals -> phase 1
            t += 0.2
            sched.observe_arrival(t)
        assert sched.current_phase() == 1
        for _ in range(50):  # slow arrivals -> phase 0
            t += 2.0
            sched.observe_arrival(t)
        assert sched.current_phase() == 0


class TestAdaptiveController:
    def _bank(self):
        from repro.serving.scheduler import SMDPSchedulerBank

        return SMDPSchedulerBank(
            {(1.0,): np.full(9, 2), (10.0,): np.full(9, 8)},
            key_names=("lam",),
        )

    def _drive(self, ctrl, gap, n, t0=0.0):
        t = t0
        for _ in range(n):
            t += gap
            ctrl.observe_arrival(t)
        return t

    def test_retunes_to_observed_rate(self):
        from repro.serving.scheduler import AdaptiveController

        ctrl = AdaptiveController(self._bank(), ewma=0.3, margin=0.0)
        t = self._drive(ctrl, 0.1, 50)  # rate 10
        assert ctrl.key == (10.0,)
        assert ctrl.decide(5) == 8  # fast-rate table (engine caps at queue)
        self._drive(ctrl, 1.0, 50, t)  # rate 1
        assert ctrl.key == (1.0,)
        assert ctrl.decide(5) == 2
        assert ctrl.n_switches >= 1

    def test_custom_estimator_without_data_starts_mid_bank(self):
        from repro.serving.metrics import RateEstimator
        from repro.serving.scheduler import AdaptiveController

        # estimator rate is NaN before any arrivals: fall back to the
        # bank-midpoint init_rate, not an arbitrary first key
        ctrl = AdaptiveController(
            self._bank(), estimator=RateEstimator(ewma=0.2), init_rate=9.0
        )
        assert ctrl.key == (10.0,)

    def test_min_dwell_blocks_thrash(self):
        from repro.serving.scheduler import AdaptiveController

        ctrl = AdaptiveController(
            self._bank(), ewma=0.9, margin=0.0, min_dwell=1e9, init_rate=1.0
        )
        self._drive(ctrl, 0.1, 100)
        assert ctrl.n_switches <= 1  # the first switch uses the -inf default

    def test_margin_hysteresis_near_midpoint(self):
        from repro.serving.scheduler import AdaptiveController

        # estimate hovers just past the midpoint (5.5): with a wide margin
        # the candidate is not decisively closer, so no switch happens
        ctrl = AdaptiveController(
            self._bank(), ewma=1.0, margin=0.5, init_rate=1.0
        )
        self._drive(ctrl, 1.0 / 6.0, 40)  # rate 6: just past midpoint
        assert ctrl.key == (1.0,)
        ctrl2 = AdaptiveController(
            self._bank(), ewma=1.0, margin=0.0, init_rate=1.0
        )
        self._drive(ctrl2, 1.0 / 6.0, 40)
        assert ctrl2.key == (10.0,)


class TestSweepBank:
    def test_bank_grid_and_retune(self):
        from repro.core.sweep import sweep_bank

        lams = [0.3 * BMAX / float(SVC.mean(BMAX)),
                0.7 * BMAX / float(SVC.mean(BMAX))]
        bank = sweep_bank(base_spec(lams[0]), lams, w2s=[0.5, 2.0])
        assert len(bank) == 4
        assert bank.key_names == ("lam", "w2")
        sch = bank.scheduler(lam=lams[0], w2=0.5)
        assert sch.decide(0) == 0
        key = sch.retune(lam=lams[1], w2=2.0)
        assert key == (pytest.approx(lams[1]), 2.0)

    def test_bank_tables_match_serial_solver(self):
        from repro.core.sweep import sweep_bank

        lam = 0.5 * BMAX / float(SVC.mean(BMAX))
        bank = sweep_bank(base_spec(lam), [lam])
        serial = solve(base_spec(lam)).action_table()
        key = bank.nearest(lam=lam)
        np.testing.assert_array_equal(bank.tables[key], serial)


class TestOracleScheduler:
    def test_phase_lookup(self):
        from repro.serving.mmpp import OraclePhaseScheduler

        sched = OraclePhaseScheduler(
            {0: np.full(5, 1), 1: np.full(5, 4)},
            [(0.0, 0), (10.0, 1), (25.0, 0)],
        )
        sched.observe_arrival(5.0)
        assert sched.phase == 0 and sched.decide(4) == 1
        sched.observe_arrival(12.0)
        assert sched.phase == 1 and sched.decide(4) == 4
        sched.observe_arrival(30.0)
        assert sched.phase == 0

    def test_empty_switch_log(self):
        from repro.serving.mmpp import OraclePhaseScheduler

        sched = OraclePhaseScheduler({0: np.full(5, 2)}, [])
        sched.observe_arrival(1.0)  # no switches known: stay in phase 0
        assert sched.phase == 0 and sched.decide(3) == 2


class TestDeprecationShim:
    """The mmpp shim warns on attribute access, never on bare import."""

    def test_import_serving_is_warning_clean(self):
        import subprocess
        import sys
        from pathlib import Path

        import os
        env = {k: v for k, v in os.environ.items() if k.startswith("JAX_")}
        root = Path(__file__).resolve().parent.parent
        r = subprocess.run(
            [sys.executable, "-W", "error", "-c",
             "import repro.serving, repro.serving.mmpp; print('clean')"],
            env={"PYTHONPATH": str(root / "src"), "PATH": "/usr/bin:/bin",
                 "HOME": "/root", **env},
            capture_output=True, text=True, timeout=120,
        )
        assert r.returncode == 0, r.stderr
        assert "clean" in r.stdout

    def test_attribute_access_warns_once(self):
        import repro.serving.mmpp as shim

        shim._WARNED = False
        shim.__dict__.pop("MMPP2Process", None)  # drop the resolve cache
        with pytest.warns(DeprecationWarning, match="deprecated"):
            _ = shim.MMPP2Process
        # cached + already-warned: silent on re-access
        import warnings as w

        with w.catch_warnings():
            w.simplefilter("error")
            _ = shim.MMPP2Process

    def test_unknown_attribute_raises(self):
        import repro.serving.mmpp as shim

        with pytest.raises(AttributeError):
            shim.no_such_name
