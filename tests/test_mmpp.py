"""Beyond-paper: phase-aware SMDP scheduling under bursty (MMPP) traffic.

The paper's Sec.-VIII proposal made executable: under MMPP(2) arrivals,
per-phase SMDP policies selected by an online rate estimator should beat a
single SMDP policy solved for the mean rate.
"""
import numpy as np

from repro.core import (
    GOOGLENET_P4_ENERGY,
    GOOGLENET_P4_LATENCY,
    ServiceModel,
    SMDPSpec,
    solve,
)
from repro.serving.mmpp import (
    MMPP2,
    PhaseAwareScheduler,
    run_mmpp,
    solve_phase_policies,
)
from repro.serving.scheduler import SMDPScheduler

SVC = ServiceModel(latency=GOOGLENET_P4_LATENCY, family="det")
BMAX = 32
EN = np.array([0.0] + [float(GOOGLENET_P4_ENERGY(b)) for b in range(1, BMAX + 1)])


def base_spec(lam):
    return SMDPSpec(lam=lam, service=SVC, energy=GOOGLENET_P4_ENERGY,
                    b_min=1, b_max=BMAX, w1=1.0, w2=1.0, s_max=128)


class TestMMPP:
    def test_mean_rate(self):
        m = MMPP2(lam1=0.5, lam2=2.5, dwell1=300.0, dwell2=100.0)
        arr, _ = m.sample_arrivals(200_000.0, np.random.default_rng(0))
        np.testing.assert_allclose(len(arr) / 200_000.0, m.mean_rate, rtol=0.05)

    def test_phase_aware_beats_mean_rate_policy(self):
        """Latency-focused objective (w2=0): phase policies differ in their
        control limits, so phase-awareness should gain >5% (measured ~15%;
        with large w2 both phases converge to max-batching and the gain
        vanishes — see benchmarks/mmpp_bursty.py)."""
        import dataclasses

        mu_max = BMAX / float(SVC.mean(BMAX))
        m = MMPP2(lam1=0.05 * mu_max, lam2=0.90 * mu_max,
                  dwell1=1000.0, dwell2=1000.0)
        rates = {0: m.lam1, 1: m.lam2}
        spec0 = dataclasses.replace(base_spec(m.mean_rate), w2=0.0)
        tables = solve_phase_policies(spec0, rates)
        phase_sched = PhaseAwareScheduler(tables, rates, ewma=0.1)
        mean_sched = SMDPScheduler(solve(spec0))

        horizon = 60_000.0
        lat_p, _, _ = run_mmpp(phase_sched, m, SVC, EN, BMAX, horizon, seed=1)
        lat_m, _, _ = run_mmpp(mean_sched, m, SVC, EN, BMAX, horizon, seed=1)
        assert len(lat_p) > 10_000
        assert lat_p.mean() < lat_m.mean() * 0.97, (lat_p.mean(), lat_m.mean())

    def test_estimator_tracks_phase(self):
        rates = {0: 0.5, 1: 5.0}
        sched = PhaseAwareScheduler({0: np.zeros(4), 1: np.zeros(4)}, rates)
        t = 0.0
        for _ in range(50):  # fast arrivals -> phase 1
            t += 0.2
            sched.observe_arrival(t)
        assert sched.current_phase() == 1
        for _ in range(50):  # slow arrivals -> phase 0
            t += 2.0
            sched.observe_arrival(t)
        assert sched.current_phase() == 0
