"""End-to-end behaviour tests for the paper's system.

The full pipeline: profile -> SMDP solve -> policy -> serving engine,
and the paper's central empirical claims as executable assertions.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    GOOGLENET_P4_ENERGY,
    GOOGLENET_P4_LATENCY,
    IDEAL_PARALLEL_LATENCY,
    LOG_ENERGY,
    ServiceModel,
    SMDPSpec,
    build_smdp,
    evaluate_policy,
    greedy_policy,
    solve,
    static_policy,
)
from repro.core.profiles import tpu_service_model, workload_for_arch
from repro.core.tradeoff import benchmark_points, smdp_tradeoff_curve
from repro.serving import ServingEngine, SMDPScheduler

SVC = ServiceModel(latency=GOOGLENET_P4_LATENCY, family="det")
BMAX = 32


def spec(rho=0.7, w2=1.0, **kw):
    lam = rho * BMAX / float(SVC.mean(BMAX))
    base = dict(
        lam=lam, service=SVC, energy=GOOGLENET_P4_ENERGY, b_min=1,
        b_max=BMAX, w1=1.0, w2=w2, s_max=128, c_o=100.0,
    )
    base.update(kw)
    return SMDPSpec(**base)


class TestPaperClaims:
    def test_pareto_dominance_of_smdp_curve(self):
        """Fig. 5: no benchmark policy strictly dominates any SMDP point."""
        s = spec(rho=0.7)
        curve = smdp_tradeoff_curve(s, w2_values=[0.0, 0.5, 1.0, 2.0, 5.0, 15.0])
        bench = benchmark_points(s)
        for name, (w_b, p_b) in bench.items():
            for pt in curve:
                assert not (w_b < pt.w_bar - 1e-6 and p_b < pt.p_bar - 1e-6), name

    def test_tradeoff_monotone_in_w2(self):
        """Fig. 5a: increasing w2 lowers power, raises response time."""
        curve = smdp_tradeoff_curve(spec(rho=0.3), w2_values=[0.0, 1.0, 5.0, 20.0])
        p = [pt.p_bar for pt in curve]
        w = [pt.w_bar for pt in curve]
        assert all(p[i + 1] <= p[i] + 1e-9 for i in range(len(p) - 1))
        assert all(w[i + 1] >= w[i] - 1e-9 for i in range(len(w) - 1))

    def test_maximum_batching_is_tradeoff_endpoint(self):
        """Sec. VII-B-2: static-Bmax pins the high-w2 end of the curve."""
        s = spec(rho=0.7, w2=200.0)
        res = solve(s)
        mdp = build_smdp(s)
        ev_max = evaluate_policy(mdp, static_policy(BMAX, s.s_max))
        np.testing.assert_allclose(res.eval.p_bar, ev_max.p_bar, rtol=0.01)

    def test_greedy_near_smdp_when_w2_zero(self):
        s = spec(rho=0.3, w2=0.0)
        res = solve(s)
        mdp = build_smdp(s)
        g = evaluate_policy(mdp, greedy_policy(s.s_max, 1, BMAX))
        assert res.eval.g <= g.g <= res.eval.g * 1.15

    def test_static8_unstable_at_high_load(self):
        """Sec. VII-B-2: static-8 cannot stabilize rho >= 0.8."""
        s = spec(rho=0.85)
        theta8 = 8 / float(SVC.mean(8))
        assert s.lam > theta8

    def test_cov_degrades_latency(self):
        """Fig. 9: higher service-time CoV worsens W at fixed power weight."""
        w_by_fam = {}
        for fam in ("det", "erlang", "expo", "hyperexpo"):
            svc = ServiceModel(latency=GOOGLENET_P4_LATENCY, family=fam)
            lam = 0.7 * BMAX / float(svc.mean(BMAX))
            sp = SMDPSpec(lam=lam, service=svc, energy=GOOGLENET_P4_ENERGY,
                          b_max=BMAX, w1=1.0, w2=1.0, s_max=160, c_o=100.0)
            w_by_fam[fam] = solve(sp).eval.w_bar
        assert (
            w_by_fam["det"] < w_by_fam["erlang"] < w_by_fam["expo"] < w_by_fam["hyperexpo"]
        )

    def test_ideal_parallelism_scenario_runs(self):
        """Sec. VII-C-1 setting solves and still beats greedy."""
        svc = ServiceModel(latency=IDEAL_PARALLEL_LATENCY, family="det")
        lam = 0.5 * BMAX / float(svc.mean(BMAX))
        sp = SMDPSpec(lam=lam, service=svc, energy=GOOGLENET_P4_ENERGY,
                      b_max=BMAX, w1=1.0, w2=1.0, s_max=128, c_o=100.0)
        res = solve(sp)
        mdp = build_smdp(sp)
        g = evaluate_policy(mdp, greedy_policy(sp.s_max, 1, BMAX)).g
        assert res.eval.g <= g + 1e-9


class TestTPUProfileIntegration:
    """Beyond-paper: SMDP policies on TPU-roofline-derived profiles."""

    def test_arch_profile_to_policy(self):
        w = workload_for_arch(
            n_params_active=3e9, n_layers=32, kv_heads=40, head_dim=64,
            context_len=8192, n_tokens=16, state_bytes=32 * 40 * 64 * 64 * 4,
        )
        svc, energy = tpu_service_model(w)
        lam = 0.5 * BMAX / float(svc.mean(BMAX))
        sp = SMDPSpec(lam=lam, service=svc, energy=energy, b_max=BMAX,
                      w1=1.0, w2=1.0, s_max=128, c_o=100.0)
        res = solve(sp)
        mdp = build_smdp(sp)
        for pol in [greedy_policy(sp.s_max, 1, BMAX), static_policy(8, sp.s_max)]:
            assert res.eval.g <= evaluate_policy(mdp, pol).g + 1e-9

    def test_roofline_latency_monotone(self):
        w = workload_for_arch(n_params_active=7e9, n_layers=28, kv_heads=4,
                              head_dim=128, context_len=32768)
        svc, energy = tpu_service_model(w)
        l = svc.mean(np.arange(1, 65))
        assert (np.diff(l) >= -1e-12).all()
        theta = np.arange(1, 65) / l
        assert (np.diff(theta) >= -1e-9).all()  # paper's theta monotonicity


class TestEndToEndServing:
    def test_full_pipeline(self):
        """profile -> solve -> schedule -> serve -> SLO accounting."""
        s = spec(rho=0.7, w2=1.6)
        sol = solve(s)
        energy = np.array(
            [0.0] + [float(GOOGLENET_P4_ENERGY(b)) for b in range(1, BMAX + 1)]
        )
        eng = ServingEngine(SMDPScheduler(sol), lam=s.lam, b_max=BMAX,
                            service=SVC, energy_table=energy, slo=12.0, seed=0)
        rep = eng.run(40_000)
        assert rep.n_served > 100_000
        np.testing.assert_allclose(rep.latencies.mean(), sol.eval.w_bar, rtol=0.03)
        np.testing.assert_allclose(rep.power, sol.eval.p_bar, rtol=0.03)
        assert rep.n_slo_miss / rep.n_served < 0.10
