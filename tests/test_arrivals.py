"""Arrival processes + online rate estimation (serving.arrivals / .metrics)."""
import numpy as np
import pytest

from repro.serving.arrivals import (
    ArrivalProcess,
    MMPP2,
    MMPP2Process,
    PoissonProcess,
    TraceProcess,
    as_process,
)
from repro.serving.metrics import RateEstimator


class TestPoissonProcess:
    def test_rate(self):
        proc = PoissonProcess(2.5)
        rng = np.random.default_rng(0)
        times = [proc.next(rng).time for _ in range(20_000)]
        gaps = np.diff([0.0] + times)
        np.testing.assert_allclose(gaps.mean(), 1 / 2.5, rtol=0.05)
        assert proc.mean_rate == 2.5

    def test_snapshot_resumes_identically(self):
        proc = PoissonProcess(1.0)
        rng = np.random.default_rng(1)
        for _ in range(100):
            proc.next(rng)
        snap, rng_state = proc.snapshot(), rng.bit_generator.state
        a = [proc.next(rng).time for _ in range(50)]
        proc.restore(snap)
        rng.bit_generator.state = rng_state
        b = [proc.next(rng).time for _ in range(50)]
        assert a == b


class TestMMPP2Process:
    def test_matches_eager_sample_arrivals(self):
        """The lazy generator and the eager trace share one draw sequence."""
        m = MMPP2(lam1=0.5, lam2=4.0, dwell1=200.0, dwell2=50.0)
        horizon = 5_000.0
        eager, switches = m.sample_arrivals(horizon, np.random.default_rng(3))
        proc = MMPP2Process(m)
        rng = np.random.default_rng(3)
        lazy = []
        while True:
            t = proc.next(rng).time
            if t >= horizon:
                break
            lazy.append(t)
        np.testing.assert_array_equal(eager, np.asarray(lazy))
        assert switches[0] == (0.0, 0)
        assert all(p0 != p1 for (_, p0), (_, p1) in zip(switches, switches[1:]))

    def test_mean_rate(self):
        m = MMPP2(lam1=0.5, lam2=2.5, dwell1=300.0, dwell2=100.0)
        np.testing.assert_allclose(m.mean_rate, (3 * 0.5 + 1 * 2.5) / 4)

    def test_snapshot_restores_switch_log(self):
        m = MMPP2(lam1=0.2, lam2=3.0, dwell1=10.0, dwell2=10.0)
        proc = MMPP2Process(m, log_switches=True)
        rng = np.random.default_rng(5)
        for _ in range(300):
            proc.next(rng)
        snap, rng_state = proc.snapshot(), rng.bit_generator.state
        for _ in range(300):
            proc.next(rng)
        proc.restore(snap)
        rng.bit_generator.state = rng_state
        for _ in range(300):
            proc.next(rng)
        times = [t for t, _ in proc.switch_log]
        assert times == sorted(times) and len(set(times)) == len(times)

    def test_snapshot_resumes_identically(self):
        m = MMPP2(lam1=0.2, lam2=3.0, dwell1=30.0, dwell2=30.0)
        proc = MMPP2Process(m)
        rng = np.random.default_rng(7)
        for _ in range(500):
            proc.next(rng)
        snap, rng_state = proc.snapshot(), rng.bit_generator.state
        a = [proc.next(rng).time for _ in range(200)]
        proc.restore(snap)
        rng.bit_generator.state = rng_state
        b = [proc.next(rng).time for _ in range(200)]
        assert a == b


class TestTraceProcess:
    def test_sorts_and_exhausts(self):
        proc = TraceProcess([3.0, 1.0, 2.0])
        rng = np.random.default_rng(0)
        assert [proc.next(rng).time for _ in range(3)] == [1.0, 2.0, 3.0]
        assert proc.next(rng) is None

    def test_request_attributes_pass_through(self):
        from repro.serving.engine import Request

        reqs = [Request(5, 1.5, deadline=9.0, payload="p")]
        ev = TraceProcess(reqs).next(np.random.default_rng(0))
        assert (ev.time, ev.rid, ev.deadline, ev.payload) == (1.5, 5, 9.0, "p")

    def test_mean_rate(self):
        proc = TraceProcess(np.arange(11) * 0.5)  # 11 arrivals over 5s
        np.testing.assert_allclose(proc.mean_rate, 2.0)


class TestAsProcess:
    def test_coercions(self):
        assert isinstance(as_process(1.5), PoissonProcess)
        assert isinstance(as_process(MMPP2(1, 2, 3, 4)), MMPP2Process)
        assert isinstance(as_process([1.0, 2.0]), TraceProcess)
        p = PoissonProcess(1.0)
        assert as_process(p) is p
        with pytest.raises(TypeError):
            as_process(object())


class TestRateEstimator:
    @pytest.mark.parametrize("lam", [0.5, 4.0])
    def test_ewma_converges_on_poisson(self, lam):
        rng = np.random.default_rng(0)
        est = RateEstimator(ewma=0.02)
        t = 0.0
        for _ in range(20_000):
            t += rng.exponential(1.0 / lam)
            est.observe(t)
        np.testing.assert_allclose(est.rate, lam, rtol=0.10)

    @pytest.mark.parametrize("lam", [0.5, 4.0])
    def test_window_converges_on_poisson(self, lam):
        rng = np.random.default_rng(1)
        est = RateEstimator(window=2_000)
        t = 0.0
        for _ in range(10_000):
            t += rng.exponential(1.0 / lam)
            est.observe(t)
        np.testing.assert_allclose(est.rate, lam, rtol=0.10)

    def test_init_rate_before_data(self):
        est = RateEstimator(ewma=0.1, init=3.0)
        assert est.rate == 3.0
        assert np.isnan(RateEstimator(ewma=0.1).rate)

    def test_snapshot_round_trip(self):
        est = RateEstimator(ewma=0.3)
        for t in (1.0, 2.5, 3.0):
            est.observe(t)
        snap = est.snapshot()
        rate = est.rate
        est.observe(10.0)
        est.restore(snap)
        assert est.rate == rate
        est2 = RateEstimator(window=4)
        for t in (1.0, 2.0, 4.0):
            est2.observe(t)
        snap2 = est2.snapshot()
        rate2 = est2.rate
        est2.observe(9.0)
        est2.restore(snap2)
        assert est2.rate == rate2


class TestPhaseBeliefFilterGuard:
    """ISSUE satellite: the forward filter must survive long observation
    gaps — exp((R - Lambda) * gap) underflows to the zero matrix, which
    used to propagate a degenerate (all-zero / NaN) belief.  The guarded
    observe renormalizes every step and falls back to the stationary
    prior when the propagated mass vanishes."""

    def _filt(self):
        from repro.serving.arrivals import PhaseBeliefFilter

        return PhaseBeliefFilter(
            rates=[5.0, 50.0], gen=[[-0.5, 0.5], [1.0, -1.0]]
        )

    def test_long_gap_falls_back_to_stationary(self):
        filt = self._filt()
        filt.observe(0.1)
        filt.observe(0.2)
        filt.observe(1e7)  # e^{(R - Lambda) gap} == 0 in float64
        assert np.all(np.isfinite(filt.belief))
        np.testing.assert_allclose(filt.belief.sum(), 1.0)
        want = filt._b0 * filt.rates
        np.testing.assert_allclose(filt.belief, want / want.sum())
        # and the filter keeps tracking after the reset
        filt.observe(1e7 + 0.01)
        assert np.all(np.isfinite(filt.belief))
        np.testing.assert_allclose(filt.belief.sum(), 1.0)

    def test_every_gap_scale_stays_normalized(self):
        filt = self._filt()
        t = 0.0
        for gap in 10.0 ** np.arange(-9, 9, 0.5):
            t += gap
            filt.observe(t)
            assert np.all(np.isfinite(filt.belief)), gap
            assert np.all(filt.belief >= 0.0), gap
            np.testing.assert_allclose(filt.belief.sum(), 1.0)

    def test_jax_forward_matches_guarded_filter_on_long_gaps(self):
        from repro.serving.arrivals import belief_forward_jax

        times = np.cumsum(
            np.r_[10.0 ** np.arange(-6, 8, 0.5), [0.01] * 20]
        )
        ref_filt = self._filt()
        ref = np.empty((len(times), 2))
        for i, t in enumerate(times):
            ref_filt.observe(t)
            ref[i] = ref_filt.belief
        bel, _ = belief_forward_jax(times, self._filt())
        np.testing.assert_allclose(np.asarray(bel), ref, atol=1e-12)
