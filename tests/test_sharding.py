"""Sharding rules: divisibility guards, axis-once, per-arch coverage.

Uses AbstractMesh — no devices needed to validate the rule tables against
the production (16, 16) and (2, 16, 16) topologies.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.distributed import meshcompat
from repro.distributed import sharding as SH
from repro.models import model as M


def abstract_mesh(multi_pod=False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return meshcompat.abstract_mesh(shape, axes)


def _check_tree(specs, shapes):
    flat_s, _ = jax.tree_util.tree_flatten_with_path(specs)
    flat_a, _ = jax.tree_util.tree_flatten_with_path(shapes)
    mesh_sizes = {"pod": 2, "data": 16, "model": 16}
    for (path, sh), (_, leaf) in zip(flat_s, flat_a):
        spec = sh.spec
        used = set()
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for ax in axes:
                assert ax not in used, f"{path}: axis {ax} used twice"
                used.add(ax)
            size = int(np.prod([mesh_sizes[a] for a in axes]))
            assert leaf.shape[dim] % size == 0, (
                f"{path}: dim {dim} ({leaf.shape[dim]}) not divisible by {size}"
            )


@pytest.mark.parametrize("name", sorted(ARCHS))
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_shardings_valid(name, multi_pod):
    cfg = ARCHS[name]
    mesh = abstract_mesh(multi_pod)
    params = M.abstract_params(cfg, jnp.bfloat16)
    specs = SH.param_shardings(mesh, params, cfg.n_experts)
    _check_tree(specs, params)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_cache_shardings_valid(name):
    cfg = ARCHS[name]
    mesh = abstract_mesh()
    cache = M.abstract_cache(cfg, 128, 32768, dtype=jnp.bfloat16)
    specs = SH.cache_shardings(mesh, cache)
    _check_tree(specs, cache)


def test_big_weights_are_sharded():
    """No >64MB/device replicated weight: FSDP x TP must bite."""
    mesh = abstract_mesh()
    for name, cfg in ARCHS.items():
        params = M.abstract_params(cfg, jnp.bfloat16)
        specs = SH.param_shardings(mesh, params, cfg.n_experts)
        flat_p, _ = jax.tree_util.tree_flatten_with_path(params)
        flat_s, _ = jax.tree_util.tree_flatten_with_path(specs)
        for (path, leaf), (_, sh) in zip(flat_p, flat_s):
            n_shards = 1
            for entry in sh.spec:
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                for ax in axes:
                    n_shards *= {"pod": 2, "data": 16, "model": 16}[ax]
            per_dev = leaf.size * 2 / n_shards
            # either it's small enough, or it is FULLY sharded (256-way) —
            # a 314B model's expert stacks are large even at 1/256th
            assert per_dev < 256 * 2**20 or n_shards == 256, (
                name, path, per_dev / 2**20, n_shards
            )


def test_batch_axis_fallbacks():
    mesh_s = abstract_mesh(False)
    mesh_m = abstract_mesh(True)
    assert SH.batch_axis(mesh_s, 256) == ("data",)
    assert SH.batch_axis(mesh_m, 256) == ("pod", "data")
    assert SH.batch_axis(mesh_m, 16) == ("data",)
    assert SH.batch_axis(mesh_s, 1) is None


def test_hint_noop_outside_mesh():
    from repro.distributed.hints import hint

    x = jnp.ones((4, 4))
    y = hint(x, "data", "model")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
