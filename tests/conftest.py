import os

# Smoke tests and benches must see ONE device; the 512-device flag is set
# only inside launch/dryrun.py (subprocess-tested in test_dryrun.py).
os.environ.setdefault("XLA_FLAGS", "")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)  # core solver fidelity (see core/__init__)
