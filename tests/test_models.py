"""Per-architecture smoke tests + model-layer correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import layers as L
from repro.models import model as M
from repro.kernels import ref

KEY = jax.random.PRNGKey(11)


def _reduced(name):
    cfg = ARCHS[name].reduced()
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    return cfg


def _batch(cfg, B=2, S=48):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(KEY, (B, cfg.encoder_len, cfg.d_model), jnp.float32)
    if cfg.n_patches:
        batch["patches"] = jax.random.normal(KEY, (B, cfg.n_patches, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
class TestArchSmoke:
    def test_train_step_and_decode(self, name):
        """One forward/train step on CPU: shapes + no NaNs (assignment req)."""
        cfg = _reduced(name)
        params = M.init_params(cfg, KEY)
        batch = _batch(cfg)
        loss = M.lm_loss(cfg, params, batch, remat=True)
        assert np.isfinite(float(loss))
        # grads flow
        g = jax.grad(lambda p: M.lm_loss(cfg, p, batch, remat=False))(params)
        gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
        assert np.isfinite(gn) and gn > 0
        # serve path
        logits, cache = M.prefill(cfg, params, batch, max_len=56, cache_dtype=jnp.float32)
        assert logits.shape == (2, 1, cfg.vocab_size)
        logits2, cache = M.decode_step(cfg, params, cache, batch["tokens"][:, :1])
        assert logits2.shape == (2, 1, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))

    def test_decode_matches_full_forward(self, name):
        cfg = _reduced(name)
        params = M.init_params(cfg, KEY)
        B, S = 2, 32
        batch = _batch(cfg, B, S)
        h, _ = M.forward(cfg, params, batch)
        full = M._unembed(cfg, params, h[:, -1:, :])
        pre = {k: (v[:, : S - 1] if k == "tokens" else v) for k, v in batch.items()}
        _, cache = M.prefill(cfg, params, pre, max_len=S + 4, cache_dtype=jnp.float32)
        dec, _ = M.decode_step(cfg, params, cache, batch["tokens"][:, S - 1 : S])
        np.testing.assert_allclose(np.asarray(full), np.asarray(dec), atol=3e-4)


class TestBlockwiseAttention:
    @pytest.mark.parametrize("window,chunk", [(None, None), (16, None), (None, 16)])
    def test_masks_vs_naive(self, window, chunk):
        B, S, H, KV, D = 2, 64, 4, 2, 16
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)
        got = L.flash_attention(q, k, v, causal=True, window=window, chunk=chunk,
                                chunk_kv=16, chunk_q=16)
        # naive with the same mask
        G = H // KV
        qg = q.reshape(B, S, KV, G, D)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k) / np.sqrt(D)
        pos = jnp.arange(S)
        mask = pos[None, :] <= pos[:, None]
        if window is not None:
            mask &= pos[:, None] - pos[None, :] < window
        if chunk is not None:
            mask &= (pos[:, None] // chunk) == (pos[None, :] // chunk)
        s = jnp.where(mask[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        want = jnp.einsum("bkgqs,bskd->bqkgd", p, v).reshape(B, S, H, D)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)

    def test_kv_cache_valid_length_mask(self):
        B, S, H, D = 2, 32, 4, 16
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, 1, H, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
        out_full = L.flash_attention(q, k, v, causal=True, q_offset=S - 1, kv_len=S, chunk_kv=8)
        # zeroing the invalid tail must not change the masked result
        k2 = k.at[:, 20:].set(9999.0)
        out_masked = L.flash_attention(q, k2, v, causal=True, q_offset=19, kv_len=20, chunk_kv=8)
        want = ref.attention_ref(q, k[:, :20], v[:, :20], causal=False)
        np.testing.assert_allclose(out_masked, want, atol=2e-5)
        assert not np.allclose(out_full, out_masked)


class TestMoE:
    def test_single_expert_equals_mlp(self):
        cfg = dataclasses.replace(
            _reduced("grok-1-314b"), n_experts=1, top_k=1, moe_capacity_factor=4.0
        )
        d, ff = cfg.d_model, cfg.d_ff
        ks = jax.random.split(KEY, 4)
        p = {
            "router": jnp.zeros((d, 1)),
            "w1": jax.random.normal(ks[0], (1, d, ff)) * 0.05,
            "w3": jax.random.normal(ks[1], (1, d, ff)) * 0.05,
            "w2": jax.random.normal(ks[2], (1, ff, d)) * 0.05,
        }
        x = jax.random.normal(ks[3], (2, 16, d), jnp.float32)
        got = L.moe_ffn(cfg, p, x)
        want = L.mlp(cfg, {"w1": p["w1"][0], "w3": p["w3"][0], "w2": p["w2"][0]}, x)
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)

    def test_topk_gates_sum(self):
        """Every token contributes at most top_k gate entries."""
        cfg = _reduced("grok-1-314b")  # top_k = 2
        d = cfg.d_model
        ks = jax.random.split(KEY, 5)
        p = {
            "router": jax.random.normal(ks[0], (d, cfg.n_experts)),
            "w1": jax.random.normal(ks[1], (cfg.n_experts, d, cfg.d_ff)) * 0.05,
            "w3": jax.random.normal(ks[2], (cfg.n_experts, d, cfg.d_ff)) * 0.05,
            "w2": jax.random.normal(ks[3], (cfg.n_experts, cfg.d_ff, d)) * 0.05,
        }
        x = jax.random.normal(ks[4], (1, 8, d), jnp.float32)
        out = L.moe_ffn(cfg, p, x)
        assert out.shape == x.shape
        assert bool(jnp.all(jnp.isfinite(out)))


class TestRoPE:
    def test_rope_preserves_norm(self):
        x = jax.random.normal(KEY, (2, 16, 4, 32), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
        y = L.apply_rope(x, pos, 10_000.0)
        np.testing.assert_allclose(
            jnp.linalg.norm(x, axis=-1), jnp.linalg.norm(y, axis=-1), rtol=1e-5
        )

    def test_rope_relative_shift_invariance(self):
        """<rope(q,i), rope(k,j)> depends only on i - j."""
        ks = jax.random.split(KEY, 2)
        q = jax.random.normal(ks[0], (1, 1, 1, 32), jnp.float32)
        k = jax.random.normal(ks[1], (1, 1, 1, 32), jnp.float32)

        def dot(i, j):
            qi = L.apply_rope(q, jnp.full((1, 1), i), 1e4)
            kj = L.apply_rope(k, jnp.full((1, 1), j), 1e4)
            return float(jnp.sum(qi * kj))

        np.testing.assert_allclose(dot(5, 3), dot(105, 103), rtol=1e-4)

    def test_mrope_equals_rope_when_positions_equal(self):
        x = jax.random.normal(KEY, (2, 16, 4, 32), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
        pos3 = jnp.stack([pos, pos, pos])
        got = L.apply_mrope(x, pos3, 1e4, (6, 5, 5))
        want = L.apply_rope(x, pos, 1e4)
        np.testing.assert_allclose(got, want, atol=1e-5)


class TestSSM:
    def test_mamba2_chunked_equals_stepwise(self):
        """Chunked SSD == per-token recurrence (prefill/decode consistency)."""
        cfg = _reduced("zamba2-1.2b")
        params = M.init_params(cfg, KEY)
        p0 = jax.tree.map(lambda x: x[0], params["blocks"])
        p0 = {k: v for k, v in p0.items() if k not in ("ln1",)}
        B, S = 2, 24
        x = jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32) * 0.3
        y_full, ssm_f, conv_f = L.mamba2_block(cfg, p0, x, chunk=8)
        # token-by-token
        ssm = conv = None
        outs = []
        for t in range(S):
            y, ssm, conv = L.mamba2_block(cfg, p0, x[:, t : t + 1], ssm_state=ssm, conv_state=conv, chunk=8)
            outs.append(y)
        y_step = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step), atol=2e-4)
        np.testing.assert_allclose(np.asarray(ssm_f), np.asarray(ssm), atol=2e-4)

    def test_rwkv_scan_equals_stepwise(self):
        cfg = _reduced("rwkv6-3b")
        params = M.init_params(cfg, KEY)
        p0 = jax.tree.map(lambda x: x[0], params["blocks"])
        B, S = 2, 12
        x = jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32) * 0.3
        y_full, st_f, sh_f = L.rwkv6_time_mix(cfg, p0, x)
        st = sh = None
        outs = []
        for t in range(S):
            y, st, sh = L.rwkv6_time_mix(cfg, p0, x[:, t : t + 1], state=st, shift_state=sh)
            outs.append(y)
        y_step = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step), atol=2e-4)


class TestConfigFidelity:
    """Parameter counts should match the published model names (order 1x)."""

    EXPECTED_B = {
        "qwen2.5-32b": 32.8, "command-r-plus-104b": 104.0, "gemma2-9b": 9.2,
        "gemma2-27b": 27.2, "whisper-small": 0.24, "grok-1-314b": 314.0,
        "llama4-scout-17b-a16e": 108.0, "rwkv6-3b": 3.1, "qwen2-vl-7b": 7.6,
        "zamba2-1.2b": 1.2,
    }

    def test_param_counts_match_names(self):
        from repro.configs import ARCHS

        for name, want_b in self.EXPECTED_B.items():
            got_b = ARCHS[name].n_params() / 1e9
            assert 0.5 * want_b <= got_b <= 1.7 * want_b, (name, got_b, want_b)

    def test_moe_active_params(self):
        from repro.configs import ARCHS

        scout = ARCHS["llama4-scout-17b-a16e"]
        assert 10 <= scout.n_params_active() / 1e9 <= 25  # "17B active"
        grok = ARCHS["grok-1-314b"]
        assert grok.n_params_active() < 0.4 * grok.n_params()
