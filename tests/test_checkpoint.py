"""Checkpoint manager: roundtrip, integrity, GC, atomicity."""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def tree(key, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "a": jax.random.normal(ks[0], (8, 16), dtype),
        "nested": {"b": jax.random.normal(ks[1], (4,), jnp.float32),
                   "c": jnp.asarray(3, jnp.int32)},
    }


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep_last_k=2)
        t = tree(jax.random.PRNGKey(0))
        mgr.save(7, t)
        out = mgr.restore(jax.eval_shape(lambda: t))
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_bfloat16_roundtrip(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        t = tree(jax.random.PRNGKey(1), jnp.bfloat16)
        mgr.save(1, t)
        out = mgr.restore(jax.eval_shape(lambda: t))
        assert out["a"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(
            np.asarray(t["a"]).view(np.uint16), np.asarray(out["a"]).view(np.uint16)
        )

    def test_keep_last_k(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep_last_k=2)
        t = tree(jax.random.PRNGKey(0))
        for s in (1, 2, 3, 4):
            mgr.save(s, t)
        assert mgr.all_steps() == [3, 4]

    def test_corruption_detected(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        t = tree(jax.random.PRNGKey(0))
        mgr.save(1, t)
        d = Path(tmp_path) / "step_0000000001"
        manifest = json.loads((d / "manifest.json").read_text())
        manifest["arrays"]["a"]["crc32"] ^= 0xDEADBEEF
        (d / "manifest.json").write_text(json.dumps(manifest))
        with pytest.raises(IOError):
            mgr.restore(jax.eval_shape(lambda: t))

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        t = tree(jax.random.PRNGKey(0))
        mgr.save(5, t, async_=True)
        mgr.wait()
        assert mgr.latest_step() == 5

    def test_no_tmp_dir_left(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, tree(jax.random.PRNGKey(0)))
        assert not list(Path(tmp_path).glob("*.tmp"))

    def test_restore_with_shardings(self, tmp_path):
        """Elastic restart path: device_put onto an explicit sharding."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh()
        mgr = CheckpointManager(tmp_path)
        t = tree(jax.random.PRNGKey(0))
        mgr.save(1, t)
        sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
        out = mgr.restore(jax.eval_shape(lambda: t), shardings=sh)
        np.testing.assert_array_equal(np.asarray(t["a"]), np.asarray(out["a"]))
