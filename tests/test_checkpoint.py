"""Checkpoint manager: roundtrip, integrity, GC, atomicity."""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointCorruptError, CheckpointManager


def tree(key, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "a": jax.random.normal(ks[0], (8, 16), dtype),
        "nested": {"b": jax.random.normal(ks[1], (4,), jnp.float32),
                   "c": jnp.asarray(3, jnp.int32)},
    }


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep_last_k=2)
        t = tree(jax.random.PRNGKey(0))
        mgr.save(7, t)
        out = mgr.restore(jax.eval_shape(lambda: t))
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_bfloat16_roundtrip(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        t = tree(jax.random.PRNGKey(1), jnp.bfloat16)
        mgr.save(1, t)
        out = mgr.restore(jax.eval_shape(lambda: t))
        assert out["a"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(
            np.asarray(t["a"]).view(np.uint16), np.asarray(out["a"]).view(np.uint16)
        )

    def test_keep_last_k(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep_last_k=2)
        t = tree(jax.random.PRNGKey(0))
        for s in (1, 2, 3, 4):
            mgr.save(s, t)
        assert mgr.all_steps() == [3, 4]

    def test_corruption_detected(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        t = tree(jax.random.PRNGKey(0))
        mgr.save(1, t)
        d = Path(tmp_path) / "step_0000000001"
        manifest = json.loads((d / "manifest.json").read_text())
        manifest["arrays"]["a"]["crc32"] ^= 0xDEADBEEF
        (d / "manifest.json").write_text(json.dumps(manifest))
        with pytest.raises(IOError):
            mgr.restore(jax.eval_shape(lambda: t))

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        t = tree(jax.random.PRNGKey(0))
        mgr.save(5, t, async_=True)
        mgr.wait()
        assert mgr.latest_step() == 5

    def test_no_tmp_dir_left(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, tree(jax.random.PRNGKey(0)))
        assert not list(Path(tmp_path).glob("*.tmp"))

    def test_orphan_tmp_swept_on_startup(self, tmp_path):
        """A crash mid-save leaves step_<n>.tmp/ behind; the next manager
        construction sweeps it (it never shadows a committed step)."""
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, tree(jax.random.PRNGKey(0)))
        orphan = Path(tmp_path) / "step_0000000002.tmp"
        orphan.mkdir()
        (orphan / "arrays.npz").write_bytes(b"partial write")
        mgr2 = CheckpointManager(tmp_path)
        assert not orphan.exists()
        assert mgr2.all_steps() == [1]

    def test_corrupt_error_names_the_array(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        t = tree(jax.random.PRNGKey(0))
        mgr.save(1, t)
        d = Path(tmp_path) / "step_0000000001"
        manifest = json.loads((d / "manifest.json").read_text())
        manifest["arrays"]["nested//b"]["crc32"] ^= 0xDEADBEEF
        (d / "manifest.json").write_text(json.dumps(manifest))
        with pytest.raises(CheckpointCorruptError, match="nested//b"):
            mgr.restore(jax.eval_shape(lambda: t))
        # unverified restore still reads (operator escape hatch)
        mgr.restore(jax.eval_shape(lambda: t), verify=False)

    def test_truncated_manifest_is_corrupt_not_cryptic(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, tree(jax.random.PRNGKey(0)))
        d = Path(tmp_path) / "step_0000000001"
        full = (d / "manifest.json").read_text()
        (d / "manifest.json").write_text(full[: len(full) // 2])
        with pytest.raises(CheckpointCorruptError, match="manifest"):
            mgr.restore_flat()

    def test_restore_flat_roundtrip(self, tmp_path):
        """Flat restore: the saved keys ARE the structure — no abstract
        tree needed (resumable sweeps have data-dependent trees)."""
        mgr = CheckpointManager(tmp_path)
        t = tree(jax.random.PRNGKey(2))
        mgr.save(3, t)
        flat = mgr.restore_flat()
        assert set(flat) == {"a", "nested//b", "nested//c"}
        np.testing.assert_array_equal(flat["a"], np.asarray(t["a"]))
        with pytest.raises(FileNotFoundError):
            CheckpointManager(tmp_path / "empty").restore_flat()

    def test_restore_with_shardings(self, tmp_path):
        """Elastic restart path: device_put onto an explicit sharding."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh()
        mgr = CheckpointManager(tmp_path)
        t = tree(jax.random.PRNGKey(0))
        mgr.save(1, t)
        sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
        out = mgr.restore(jax.eval_shape(lambda: t), shardings=sh)
        np.testing.assert_array_equal(np.asarray(t["a"]), np.asarray(out["a"]))
