"""Degraded-mode serving: fault injection, failover routing, shedding.

Certification (ISSUE acceptance): verify_faults runs every router on
Poisson AND MMPP2 traces, PythonFleet vs the compiled kernel
decision-for-decision under one shared FaultSchedule.  Plus the crash /
requeue / bounded-retry-drop semantics on handcrafted schedules, finite
waiting-room shedding (including the starved B = 0 NaN-with-count-zero
guards), snapshot()/restore() mid-fault, chunked streaming (beliefs and
faults carried across chunk seams) vs one-shot, and the single-engine
admission-control knobs (buffer= / shed_expired=).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import GOOGLENET_P4_ENERGY, GOOGLENET_P4_LATENCY, ServiceModel
from repro.core.policies import q_policy
from repro.serving import (
    FaultModel,
    FaultSchedule,
    FleetStream,
    PythonFleet,
    QPolicyScheduler,
    ServingEngine,
    simulate_fleet,
    verify_faults,
    verify_fleet,
)
from repro.serving.arrivals import MMPP2, PhaseBeliefFilter, belief_forward_jax

SVC = ServiceModel(latency=GOOGLENET_P4_LATENCY, family="det")
BMAX = 16
LAM = 0.7 * BMAX / float(SVC.mean(BMAX))
ENERGY = np.array(
    [0.0] + [float(GOOGLENET_P4_ENERGY(b)) for b in range(1, BMAX + 1)]
)
MEANS = np.array([0.0] + [float(SVC.mean(b)) for b in range(1, BMAX + 1)])
TABLES = np.stack([q_policy(q, 96, BMAX) for q in (4, 6, 8)])
ROUTER_NAMES = ["rr", "jsq", "pow2", "batch_aware"]
#: MTBF ~ tens of batches, repairs a few service times long: every router
#: sees failovers, crashes, and recoveries within a 1200-arrival trace
FAULTS = FaultModel(mtbf=40.0, mttr=6.0, p_straggle=0.1, straggle_mult=3.0)


def _trace(mode: str, n: int = 1200, seed: int = 0, lam: float = 3 * LAM):
    rng = np.random.default_rng(seed)
    if mode == "poisson":
        return np.cumsum(rng.exponential(1.0 / lam, n))
    assert mode == "mmpp2"
    m = MMPP2(lam1=0.3 * lam, lam2=1.3 * lam, dwell1=60.0, dwell2=30.0)
    times, _ = m.sample_arrivals(n / m.mean_rate, rng)
    return times


def _schedule(trace, M=3, seed=1):
    return FAULTS.materialize(M, float(trace[-1]) + 50.0, seed=seed)


class TestFaultSchedule:
    def test_materialize_layout(self):
        sch = FAULTS.materialize(3, 200.0, seed=0)
        assert sch.n_replicas == 3
        fin = sch.bounds[np.isfinite(sch.bounds)]
        with np.errstate(invalid="ignore"):  # inf-padded tails
            d = np.diff(sch.bounds, axis=1)
        assert (d[np.isfinite(d)] >= 0).all()
        assert (fin >= 0).all()
        assert (sch.mult > 0).all()

    def test_down_at_parity(self):
        sch = FaultSchedule(
            bounds=np.array([[2.0, 5.0, 9.0, np.inf]]), mult=np.ones((1, 1))
        )
        assert not sch.down_at(1.0)[0]
        assert sch.down_at(2.0)[0]  # start-inclusive
        assert not sch.down_at(5.0)[0]
        assert sch.down_at(9.5)[0]  # unrepaired tail

    def test_none_rail_is_always_up(self):
        sch = FaultSchedule.none(4)
        assert not sch.down_at(1e9).any()
        assert sch.attempt_mult(2, 123) == 1.0

    def test_validation(self):
        with pytest.raises(ValueError, match="non-decreasing"):
            FaultSchedule(
                bounds=np.array([[5.0, 2.0]]), mult=np.ones((1, 1))
            )
        with pytest.raises(ValueError, match="> 0"):
            FaultSchedule(
                bounds=np.zeros((1, 0)), mult=np.zeros((1, 1))
            )
        with pytest.raises(ValueError):
            FaultModel(mtbf=-1.0)
        with pytest.raises(TypeError, match="FaultSchedule"):
            verify_faults(
                TABLES, _trace("poisson", 50), faults=None, service=SVC,
                b_max=BMAX,
            )


class TestVerifyFaults:
    """ISSUE acceptance: every router certifies on Poisson and MMPP2."""

    @pytest.mark.parametrize("router", ROUTER_NAMES)
    @pytest.mark.parametrize("mode", ["poisson", "mmpp2"])
    def test_certified_per_router_and_family(self, router, mode):
        tr = _trace(mode)
        out = verify_faults(
            TABLES, tr, faults=_schedule(tr), service=SVC, b_max=BMAX,
            router=router, buffer=24, energy_table=ENERGY, slo=2.0,
        )
        # the scenario must actually exercise the degraded paths
        assert out["n_crashes"] > 0
        assert out["n_shed"] > 0 or out["n_dropped"] > 0

    def test_m1_certifies(self):
        tr = _trace("poisson", 600, lam=LAM)
        sch = FAULTS.materialize(1, float(tr[-1]) + 50.0, seed=3)
        out = verify_faults(
            TABLES[:1], tr, faults=sch, service=SVC, b_max=BMAX,
            energy_table=ENERGY,
        )
        assert out["n_crashes"] > 0

    def test_none_schedule_matches_fault_free_run(self):
        tr = _trace("poisson", 600)
        base = verify_fleet(
            TABLES, tr, router="jsq", service=SVC, b_max=BMAX,
            energy_table=ENERGY,
        )
        none = verify_faults(
            TABLES, tr, faults=FaultSchedule.none(3), service=SVC,
            b_max=BMAX, router="jsq", energy_table=ENERGY,
        )
        assert none["n_crashes"] == 0
        assert none["n_dropped"] == 0 and none["n_shed"] == 0
        b, f = base["compiled"], none["compiled"]
        np.testing.assert_array_equal(b.batch_sizes, f.batch_sizes)
        np.testing.assert_allclose(b.energy, f.energy)


class TestCrashSemantics:
    """Handcrafted schedules pin the crash / requeue / drop contract."""

    def _run(self, bounds, max_retries, trace=(0.1, 0.2), **kw):
        sch = FaultSchedule(
            bounds=np.asarray(bounds, dtype=np.float64),
            mult=np.ones((1, 1)), max_retries=max_retries,
        )
        table = q_policy(2, 96, BMAX)
        return simulate_fleet(
            table[None], np.asarray(trace), router="jsq", means=MEANS,
            zeta=ENERGY, draws=np.ones(1), b_max=BMAX, faults=sch,
            record=True, **kw,
        )

    def test_down_interval_crashes_inflight_batch(self):
        # batch of 2 dispatches at t=0.2, service ~ MEANS[2] >> 0.1; the
        # replica dies at 0.3 and recovers at 5.0 -> one crash, requeue,
        # re-serve after repair
        res = self._run([[0.3, 5.0]], max_retries=2)
        assert res.n_crashes == 1
        assert res.n_dropped == 0
        assert res.n_served == 2
        # the retry serves at the repair boundary, not before
        assert res.latencies.min() >= 5.0 - 0.2

    def test_bounded_retries_drop_the_batch(self):
        res = self._run([[0.3, 5.0]], max_retries=0)
        assert res.n_crashes == 1
        assert res.n_dropped == 2
        assert res.n_served == 0
        assert res.dropped[:2].all() and not res.served[:2].any()

    def test_crashed_attempt_energy_is_prorated(self):
        clean = self._run([[np.inf, np.inf]], max_retries=2)
        crashed = self._run([[0.3, 5.0]], max_retries=0)
        # partial burn only: strictly positive, strictly below one zeta(2)
        assert 0.0 < crashed.energy < float(ENERGY[2])
        assert clean.energy == pytest.approx(float(ENERGY[2]))

    def test_retry_counter_resets_after_success(self):
        # two separate down windows, each crashing one batch once, with a
        # successful serve in between: max_retries=1 must never drop
        res = self._run(
            [[0.3, 4.0, 10.25, 14.0]], max_retries=1,
            trace=(0.1, 0.2, 10.05, 10.1),
        )
        assert res.n_crashes == 2
        assert res.n_dropped == 0
        assert res.n_served == 4


class TestShedding:
    def test_buffer_sheds_only_when_full(self):
        tr = _trace("poisson", 800)
        full = simulate_fleet(
            TABLES, tr, router="jsq", means=MEANS, zeta=ENERGY,
            b_max=BMAX, record=True,
        )
        finite = simulate_fleet(
            TABLES, tr, router="jsq", means=MEANS, zeta=ENERGY,
            b_max=BMAX, buffer=4, record=True,
        )
        assert full.n_shed == 0
        assert finite.n_shed > 0
        assert finite.shed.sum() == finite.n_shed
        assert finite.n_served + finite.n_shed == len(tr)

    def test_starved_b0_sheds_everything_nan_guards(self):
        tr = _trace("poisson", 300)
        st = FleetStream(
            TABLES, router="jsq", means=MEANS, zeta=ENERGY, b_max=BMAX,
            buffer=0,
        )
        st.push(tr)
        res = st.finish()
        assert res.n_served == 0 and res.n_shed == len(tr)
        assert res.hist.sum() == 0
        rep = st.report()
        assert rep["drop_rate"] == 1.0 and rep["goodput"] == 0.0
        # count-zero convention: empty aggregates report NaN, not 0/0
        assert np.isnan(rep["W_mean"]) and np.isnan(rep["mean_batch"])

    def test_buffer_certified_python_vs_compiled(self):
        tr = _trace("poisson", 800)
        verify_fleet(
            TABLES, tr, router="pow2", service=SVC, b_max=BMAX,
            energy_table=ENERGY, buffer=6,
        )


class TestStreamingDegraded:
    """Chunked FleetStream == one-shot under faults, buffers, beliefs."""

    FIELDS = ("n_served", "n_batches", "n_epochs", "slo_miss",
              "n_crashes", "n_dropped", "n_shed")

    def _assert_match(self, st, one):
        for f in self.FIELDS:
            assert getattr(st, f) == getattr(one, f), f
        np.testing.assert_allclose(st.energy, one.energy, atol=1e-9)
        np.testing.assert_allclose(st.lat_sum, one.lat_sum, atol=1e-9)
        np.testing.assert_allclose(st.t_final, one.t_final, atol=1e-9)
        np.testing.assert_array_equal(st.hist, one.hist)

    def test_chunked_matches_one_shot_under_faults(self):
        tr = _trace("poisson", 1000)
        sch = _schedule(tr)
        kw = dict(router="jsq", means=MEANS, zeta=ENERGY, b_max=BMAX,
                  slo=2.0, faults=sch, buffer=24)
        st = FleetStream(TABLES, **kw)
        for i in range(0, len(tr), 311):
            st.push(tr[i:i + 311])
        self._assert_match(
            st.finish(), simulate_fleet(TABLES, tr, **kw)
        )

    @pytest.mark.parametrize("mode", ["belief_argmax", "belief_mix"])
    def test_chunked_belief_forwarding_matches_one_shot(self, mode):
        # the stream carries the posterior across chunk seams; aggregates
        # (n_epochs included: pending-decision flags carry too) must equal
        # a one-shot run over the pre-forwarded full-trace posterior
        tr = _trace("mmpp2", 1000)
        lam = 3 * LAM
        rates = np.array([0.3 * lam, 1.3 * lam])
        gen = np.array([[-1 / 60, 1 / 60], [1 / 30, -1 / 30]])
        lo, hi = q_policy(4, 96, BMAX), q_policy(10, 96, BMAX)
        stacks = np.stack([np.stack([lo, hi]), np.stack([hi, lo]),
                           np.stack([lo, lo])])
        sch = _schedule(tr)
        kw = dict(router="jsq", means=MEANS, zeta=ENERGY, b_max=BMAX,
                  slo=2.0, faults=sch, buffer=24)
        st = FleetStream(
            stacks, phase_mode=mode,
            belief_filter=PhaseBeliefFilter(rates=rates, gen=gen), **kw,
        )
        for i in range(0, len(tr), 193):
            st.push(tr[i:i + 193])
        bel, _ = belief_forward_jax(
            tr, PhaseBeliefFilter(rates=rates, gen=gen)
        )
        self._assert_match(
            st.finish(),
            simulate_fleet(stacks, tr, phase_mode=mode,
                           beliefs=np.asarray(bel), **kw),
        )

    def test_stream_filter_state_advances(self):
        tr = _trace("mmpp2", 400)
        lam = 3 * LAM
        filt = PhaseBeliefFilter(
            rates=[0.3 * lam, 1.3 * lam],
            gen=[[-1 / 60, 1 / 60], [1 / 30, -1 / 30]],
        )
        st = FleetStream(
            np.stack([np.stack([q_policy(4, 96, BMAX)] * 2)] * 2),
            router="jsq", means=MEANS, b_max=BMAX,
            phase_mode="belief_argmax", belief_filter=filt,
        )
        st.push(tr)
        assert filt.n_observed == len(tr)
        ref = PhaseBeliefFilter(rates=filt.rates, gen=filt.gen)
        for t in tr:
            ref.observe(t)
        np.testing.assert_allclose(filt.belief, ref.belief, atol=1e-9)


class TestSnapshotRestoreMidFault:
    """Satellite: crash a replica, snapshot between failure and recovery,
    restore, and continue to the exact uninterrupted outcome."""

    @pytest.mark.parametrize("mode", ["poisson", "mmpp2"])
    def test_restore_mid_outage_continues_exactly(self, mode):
        tr = _trace(mode, 600)
        sch = _schedule(tr)
        kw = dict(router="jsq", means=MEANS, zeta=ENERGY, b_max=BMAX,
                  slo=2.0, faults=sch, buffer=24)
        base = PythonFleet(TABLES, tr, **kw).run()
        assert base.n_crashes > 0  # the scenario really faults

        fleet = PythonFleet(TABLES, tr, **kw)
        snap = None
        while fleet.step():
            crashed = fleet.n_crashes > 0 or any(fleet.infl_req)
            if snap is None and crashed and any(
                fleet._down(m) for m in range(fleet.M)
            ):
                snap = fleet.snapshot()  # mid-outage, retry pending
        assert snap is not None
        resumed = PythonFleet(TABLES, tr, **kw)
        resumed.restore(snap)
        resumed.run()
        np.testing.assert_array_equal(
            np.asarray(resumed.decisions), np.asarray(base.decisions)
        )
        np.testing.assert_array_equal(resumed.served, base.served)
        np.testing.assert_array_equal(resumed.dropped, base.dropped)
        np.testing.assert_array_equal(resumed.shed, base.shed)
        np.testing.assert_allclose(
            resumed.latencies, base.latencies, atol=1e-12
        )
        assert resumed.n_crashes == base.n_crashes
        assert resumed.energy == pytest.approx(base.energy)


class TestEngineShedding:
    """Single-server admission control (Python backend)."""

    def _engine(self, **kw):
        return ServingEngine(
            QPolicyScheduler(q=4, b_max=8), b_max=8,
            lam=1.2 * 8 / float(SVC.mean(8)), service=SVC, slo=0.5,
            seed=1, **kw,
        )

    def test_buffer_sheds_under_overload(self):
        base = self._engine().run(1500)
        shed = self._engine(buffer=12).run(1500)
        assert base.n_shed == 0 and shed.n_shed > 0
        assert shed.n_served < base.n_served

    def test_shed_expired_drops_stale_requests(self):
        base = self._engine().run(1500)
        shed = self._engine(shed_expired=True).run(1500)
        assert base.n_expired == 0 and shed.n_expired > 0
        # what still gets served missed its SLO less often
        assert shed.n_slo_miss / max(shed.n_served, 1) <= (
            base.n_slo_miss / base.n_served
        )

    def test_b0_starves_with_nan_guards(self):
        rep = self._engine(buffer=0).run(200)
        assert rep.n_served == 0 and rep.n_shed > 0
        assert np.isnan(rep.percentile(50))
        assert rep.mean_batch == 0.0

    def test_unbounded_buffer_is_a_noop(self):
        base = self._engine().run(1500)
        huge = self._engine(buffer=1 << 20).run(1500)
        np.testing.assert_array_equal(base.latencies, huge.latencies)
        assert base.n_served == huge.n_served

    def test_compiled_backend_matches_python_shedding(self):
        """The compiled managed-queue lane reproduces the Python loop's
        door refusals and expiry sweeps decision-for-decision (this
        combination used to raise NotImplementedError)."""
        for kw in (
            dict(buffer=12),
            dict(shed_expired=True),
            dict(buffer=12, shed_expired=True),
        ):
            r_py = self._engine(**kw).run(400)
            r_c = self._engine(**kw).run(400, backend="compiled")
            np.testing.assert_array_equal(r_py.batch_sizes, r_c.batch_sizes)
            np.testing.assert_allclose(
                r_py.latencies, r_c.latencies, atol=1e-9
            )
            assert r_py.n_shed == r_c.n_shed
            assert r_py.n_expired == r_c.n_expired

    def test_negative_buffer_rejected(self):
        with pytest.raises(ValueError, match="buffer"):
            self._engine(buffer=-1)

    def test_snapshot_restore_with_shedding(self):
        eng = self._engine(buffer=12, shed_expired=True)
        eng.run(400)
        snap = eng.snapshot()
        cont = eng.run(400)
        eng2 = self._engine(buffer=12, shed_expired=True)
        eng2.restore(snap)
        rerun = eng2.run(400)
        np.testing.assert_array_equal(cont.latencies, rerun.latencies)
        assert cont.n_shed == rerun.n_shed
        assert cont.n_expired == rerun.n_expired
