"""Serving engine + schedulers: agreement with analytics, restart safety."""
import numpy as np

from repro.core import (
    GOOGLENET_P4_ENERGY,
    GOOGLENET_P4_LATENCY,
    ServiceModel,
    SMDPSpec,
    build_smdp,
    evaluate_policy,
    solve,
    static_policy,
)
from repro.core.simulate import simulate
from repro.serving import (
    GreedyScheduler,
    QPolicyScheduler,
    Request,
    ServingEngine,
    SMDPScheduler,
    StaticScheduler,
)

SVC = ServiceModel(latency=GOOGLENET_P4_LATENCY, family="det")
BMAX = 32
LAM = 0.7 * BMAX / float(SVC.mean(BMAX))
ENERGY = np.array([0.0] + [float(GOOGLENET_P4_ENERGY(b)) for b in range(1, BMAX + 1)])


def spec(w2=1.0, s_max=128):
    return SMDPSpec(
        lam=LAM, service=SVC, energy=GOOGLENET_P4_ENERGY,
        b_min=1, b_max=BMAX, w1=1.0, w2=w2, s_max=s_max, c_o=100.0,
    )


class TestSchedulers:
    def test_decisions(self):
        assert StaticScheduler(8).decide(7) == 0
        assert StaticScheduler(8).decide(9) == 8
        assert GreedyScheduler(1, 32).decide(0) == 0
        assert GreedyScheduler(1, 32).decide(40) == 32
        assert QPolicyScheduler(5, 32).decide(4) == 0
        assert QPolicyScheduler(5, 32).decide(6) == 6

    def test_smdp_scheduler_extends_table(self):
        sol = solve(spec())
        sch = SMDPScheduler(sol)
        assert sch.decide(10**6) == sch.decide(sch.s_max)


class TestEngineVsAnalytics:
    def test_engine_matches_exact_evaluation(self):
        """Profiled-clock engine reproduces the eq.-(21) analytics."""
        sol = solve(spec(w2=1.6))
        mdp = sol.mdp
        ev = sol.eval
        eng = ServingEngine(
            SMDPScheduler(sol), lam=LAM, b_max=BMAX, service=SVC,
            energy_table=ENERGY, seed=0,
        )
        rep = eng.run(60_000)
        np.testing.assert_allclose(rep.latencies.mean(), ev.w_bar, rtol=0.02)
        np.testing.assert_allclose(rep.power, ev.p_bar, rtol=0.02)

    def test_engine_matches_lax_scan_simulator(self):
        """Two independent implementations of the queue agree."""
        pol = static_policy(8, 128)
        mdp = build_smdp(spec())
        ev = evaluate_policy(mdp, pol)
        sim = simulate(pol[:-1], SVC, ENERGY, LAM, BMAX, n_epochs=60_000, seed=1)
        eng = ServingEngine(
            StaticScheduler(8), lam=LAM, b_max=BMAX, service=SVC,
            energy_table=ENERGY, seed=2,
        )
        rep = eng.run(60_000)
        np.testing.assert_allclose(sim.w_bar, ev.w_bar, rtol=0.02)
        np.testing.assert_allclose(rep.latencies.mean(), ev.w_bar, rtol=0.02)
        np.testing.assert_allclose(rep.power, sim.p_bar, rtol=0.02)

    def test_littles_law_in_simulator(self):
        pol = static_policy(8, 128)
        sim = simulate(pol[:-1], SVC, ENERGY, LAM, BMAX, n_epochs=60_000, seed=3)
        np.testing.assert_allclose(sim.l_bar / LAM, sim.w_bar, rtol=0.02)


class TestEngineRestart:
    def test_snapshot_restore_continues_identically(self):
        sol = solve(spec())
        e1 = ServingEngine(SMDPScheduler(sol), lam=LAM, b_max=BMAX,
                           service=SVC, energy_table=ENERGY, seed=5)
        e1.run(1000)
        snap = e1.snapshot()
        r_cont = e1.run(1000)
        e2 = ServingEngine(SMDPScheduler(sol), lam=LAM, b_max=BMAX,
                           service=SVC, energy_table=ENERGY, seed=99)
        e2.restore(snap)
        r_rest = e2.run(1000)
        np.testing.assert_allclose(r_cont.latencies, r_rest.latencies)
        np.testing.assert_allclose(r_cont.energy, r_rest.energy)

    def test_executor_mode_runs(self):
        """Wall-clock mode with a trivial executor serves all requests."""
        calls = []
        eng = ServingEngine(
            GreedyScheduler(1, 8), lam=1000.0, b_max=8,
            executor=lambda batch: calls.append(len(batch)),
        )
        reqs = [Request(i, arrival=i * 1e-4) for i in range(50)]
        rep = eng.run_executor(reqs)
        assert rep.n_served == 50
        assert sum(calls) == 50
        assert max(calls) <= 8


class TestKVCachePool:
    def test_claim_release_cycle(self):
        from repro.configs import ARCHS
        from repro.serving.kv_cache import KVCachePool

        pool = KVCachePool(ARCHS["qwen2.5-32b"].reduced(), n_slots=8, max_len=32)
        a = pool.claim(3)
        b = pool.claim(5)
        assert pool.claim(1) is None  # exhausted
        assert pool.stats().utilization == 1.0
        pool.release(a)
        assert pool.stats().in_use == 5
        c = pool.claim(2)
        assert len(set(c) & set(b)) == 0
        import pytest as _pytest
        with _pytest.raises(ValueError):
            pool.release(b + b[:1])  # double release detected

    def test_bytes_per_slot_positive(self):
        from repro.configs import ARCHS
        from repro.serving.kv_cache import KVCachePool

        pool = KVCachePool(ARCHS["rwkv6-3b"].reduced(), n_slots=2, max_len=16)
        assert pool.bytes_per_slot() > 0


class TestStreamingMetrics:
    def test_p2_quantile_accuracy(self):
        from repro.serving.metrics import P2Quantile

        rng = np.random.default_rng(0)
        data = rng.exponential(5.0, 20_000)
        est = P2Quantile(0.95)
        for x in data:
            est.update(float(x))
        true = np.percentile(data, 95)
        assert abs(est.value - true) / true < 0.05

    def test_serving_metrics_report(self):
        from repro.serving.metrics import ServingMetrics

        m = ServingMetrics()
        rng = np.random.default_rng(1)
        t = 0.0
        for _ in range(300):
            t += 1.0
            m.observe_batch(rng.exponential(3.0, 8), zeta=50.0, t_now=t)
        rep = m.report()
        assert abs(rep["W_mean"] - 3.0) < 0.3
        assert abs(rep["power"] - 50.0) < 1e-6
        assert rep["mean_batch"] == 8.0
