"""Serving engine + schedulers: agreement with analytics, restart safety,
one kernel behind every mode (profiled / wall-clock / trace replay)."""
import numpy as np
import pytest

from repro.core import (
    GOOGLENET_P4_ENERGY,
    GOOGLENET_P4_LATENCY,
    ServiceModel,
    SMDPSpec,
    build_smdp,
    evaluate_policy,
    solve,
    static_policy,
)
from repro.core.simulate import simulate
from repro.serving import (
    GreedyScheduler,
    QPolicyScheduler,
    Request,
    ServingEngine,
    SMDPScheduler,
    StaticScheduler,
)
from repro.serving.scheduler import Scheduler

SVC = ServiceModel(latency=GOOGLENET_P4_LATENCY, family="det")
BMAX = 32
LAM = 0.7 * BMAX / float(SVC.mean(BMAX))
ENERGY = np.array([0.0] + [float(GOOGLENET_P4_ENERGY(b)) for b in range(1, BMAX + 1)])


def spec(w2=1.0, s_max=128):
    return SMDPSpec(
        lam=LAM, service=SVC, energy=GOOGLENET_P4_ENERGY,
        b_min=1, b_max=BMAX, w1=1.0, w2=w2, s_max=s_max, c_o=100.0,
    )


class TestSchedulers:
    def test_decisions(self):
        assert StaticScheduler(8).decide(7) == 0
        assert StaticScheduler(8).decide(9) == 8
        assert GreedyScheduler(1, 32).decide(0) == 0
        assert GreedyScheduler(1, 32).decide(40) == 32
        assert QPolicyScheduler(5, 32).decide(4) == 0
        assert QPolicyScheduler(5, 32).decide(6) == 6

    def test_smdp_scheduler_extends_table(self):
        sol = solve(spec())
        sch = SMDPScheduler(sol)
        assert sch.decide(10**6) == sch.decide(sch.s_max)


class TestEngineVsAnalytics:
    def test_engine_matches_exact_evaluation(self):
        """Profiled-clock engine reproduces the eq.-(21) analytics."""
        sol = solve(spec(w2=1.6))
        mdp = sol.mdp
        ev = sol.eval
        eng = ServingEngine(
            SMDPScheduler(sol), lam=LAM, b_max=BMAX, service=SVC,
            energy_table=ENERGY, seed=0,
        )
        rep = eng.run(60_000)
        np.testing.assert_allclose(rep.latencies.mean(), ev.w_bar, rtol=0.02)
        np.testing.assert_allclose(rep.power, ev.p_bar, rtol=0.02)

    def test_engine_matches_lax_scan_simulator(self):
        """Two independent implementations of the queue agree."""
        pol = static_policy(8, 128)
        mdp = build_smdp(spec())
        ev = evaluate_policy(mdp, pol)
        sim = simulate(pol[:-1], SVC, ENERGY, LAM, BMAX, n_epochs=60_000, seed=1)
        eng = ServingEngine(
            StaticScheduler(8), lam=LAM, b_max=BMAX, service=SVC,
            energy_table=ENERGY, seed=2,
        )
        rep = eng.run(60_000)
        np.testing.assert_allclose(sim.w_bar, ev.w_bar, rtol=0.02)
        np.testing.assert_allclose(rep.latencies.mean(), ev.w_bar, rtol=0.02)
        np.testing.assert_allclose(rep.power, sim.p_bar, rtol=0.02)

    def test_littles_law_in_simulator(self):
        pol = static_policy(8, 128)
        sim = simulate(pol[:-1], SVC, ENERGY, LAM, BMAX, n_epochs=60_000, seed=3)
        np.testing.assert_allclose(sim.l_bar / LAM, sim.w_bar, rtol=0.02)


class _FakeClock:
    """Deterministic wall clock for executor-mode tests."""

    def __init__(self):
        self.t = 0.0

    def timer(self) -> float:
        return self.t

    def sleep(self, dt: float) -> None:
        self.t += dt


class NeverServe(Scheduler):
    """Always waits; forces the kernel's tail-drain path."""

    name = "never"

    def decide(self, queue_len: int) -> int:
        return 0


class TestUnifiedKernel:
    """run(), run_executor() and trace replay are ONE event loop."""

    def test_profiled_and_wallclock_identical_decisions(self):
        """The same arrival trace through the virtual-clock profiled mode
        and the wall-clock executor mode (deterministic fake timer whose
        executor takes exactly l(b)) makes identical batching decisions."""
        sol = solve(spec(w2=1.6))
        rng = np.random.default_rng(4)
        times = np.cumsum(rng.exponential(1.0 / LAM, 400))

        e_virtual = ServingEngine(
            SMDPScheduler(sol), arrivals=times, b_max=BMAX, service=SVC,
            energy_table=ENERGY, seed=0,
        )
        rep_v = e_virtual.run(n_epochs=None)

        clock = _FakeClock()

        def executor(batch):
            clock.t += float(SVC.mean(len(batch)))

        e_wall = ServingEngine(
            SMDPScheduler(sol), b_max=BMAX, executor=executor,
            energy_model=lambda a, svc: float(ENERGY[a]),
            timer=clock.timer, sleeper=clock.sleep, lam=LAM,
        )
        reqs = [Request(i, float(t)) for i, t in enumerate(times)]
        rep_w = e_wall.run_executor(reqs, poll=1e12)

        np.testing.assert_array_equal(rep_v.batch_sizes, rep_w.batch_sizes)
        np.testing.assert_allclose(rep_v.latencies, rep_w.latencies)
        np.testing.assert_allclose(rep_v.energy, rep_w.energy)
        assert rep_v.n_served == rep_w.n_served == 400

    def test_executor_drain_capped_at_b_max(self):
        """Tail drain serves in b_max-sized chunks, never one mega-batch."""
        calls = []
        clock = _FakeClock()
        eng = ServingEngine(
            NeverServe(), lam=1.0, b_max=8,
            executor=lambda batch: (calls.append(len(batch)),
                                    clock.sleep(1e-3))[0],
            timer=clock.timer, sleeper=clock.sleep,
        )
        reqs = [Request(i, arrival=0.0) for i in range(50)]
        rep = eng.run_executor(reqs)
        assert rep.n_served == 50
        assert max(calls) <= 8
        assert len(calls) == 7  # ceil(50 / 8)

    def test_trace_drain_capped_at_b_max(self):
        eng = ServingEngine(
            NeverServe(), arrivals=np.zeros(20) + 0.5, b_max=4,
            service=SVC, energy_table=ENERGY, lam=LAM,
        )
        rep = eng.run(n_epochs=None)
        assert rep.n_served == 20
        assert rep.batch_sizes.max() <= 4

    def test_executor_reuse_is_fresh_replay(self):
        """A second run_executor on the same engine reproduces the first
        (arrival times are relative to the call, not the engine's past)."""
        clock = _FakeClock()
        eng = ServingEngine(
            GreedyScheduler(1, 4), lam=10.0, b_max=4,
            executor=lambda batch: clock.sleep(0.05),
            timer=clock.timer, sleeper=clock.sleep,
        )

        def replay():
            reqs = [Request(i, arrival=0.1 * i) for i in range(10)]
            return eng.run_executor(reqs, poll=1e12)

        r1, r2 = replay(), replay()
        np.testing.assert_allclose(r1.latencies, r2.latencies)
        np.testing.assert_array_equal(r1.batch_sizes, r2.batch_sizes)
        np.testing.assert_allclose(r1.span, r2.span)

    def test_executor_energy_accounting(self):
        """Executor mode accounts energy via the per-batch callback."""
        clock = _FakeClock()
        eng = ServingEngine(
            GreedyScheduler(1, 8), lam=1000.0, b_max=8,
            executor=lambda batch: clock.sleep(2e-3),
            energy_model=lambda a, svc: 5.0 * a,
            timer=clock.timer, sleeper=clock.sleep,
        )
        reqs = [Request(i, arrival=i * 1e-4) for i in range(40)]
        rep = eng.run_executor(reqs)
        np.testing.assert_allclose(rep.energy, 5.0 * 40)
        assert np.isfinite(rep.power) and rep.power > 0

    def test_executor_without_energy_source_reports_nan(self):
        clock = _FakeClock()
        eng = ServingEngine(
            GreedyScheduler(1, 8), lam=1000.0, b_max=8,
            executor=lambda batch: clock.sleep(1e-3),
            timer=clock.timer, sleeper=clock.sleep,
        )
        rep = eng.run_executor([Request(0, 0.0)])
        assert np.isnan(rep.energy) and np.isnan(rep.power)
        # pure-latency objective stays finite without an energy source
        assert np.isfinite(rep.weighted_cost(0.0))

    def test_streaming_metrics_in_report(self):
        sol = solve(spec())
        eng = ServingEngine(SMDPScheduler(sol), lam=LAM, b_max=BMAX,
                            service=SVC, energy_table=ENERGY, seed=3)
        rep = eng.run(20_000)
        assert set(rep.metrics) >= {"W_mean", "P50", "P95", "P99", "power"}
        np.testing.assert_allclose(rep.metrics["W_mean"],
                                   rep.latencies.mean(), rtol=1e-9)
        np.testing.assert_allclose(rep.metrics["P50"],
                                   np.percentile(rep.latencies, 50), rtol=0.05)
        np.testing.assert_allclose(rep.metrics["power"], rep.power, rtol=1e-9)

    def test_simulate_events_delegates_to_kernel(self):
        """core.simulate_events (the general path) matches the analytic
        evaluator like the scan fast path does."""
        from repro.core.simulate import simulate_events

        pol = static_policy(8, 128)
        mdp = build_smdp(spec())
        ev = evaluate_policy(mdp, pol)
        sim = simulate_events(pol, SVC, ENERGY, LAM, BMAX, n_epochs=60_000,
                              seed=4)
        np.testing.assert_allclose(sim.w_bar, ev.w_bar, rtol=0.02)
        np.testing.assert_allclose(sim.p_bar, ev.p_bar, rtol=0.02)
        # Little's law holds exactly by construction on the event path
        np.testing.assert_allclose(sim.l_bar / LAM, sim.w_bar, rtol=0.02)


class TestEngineRestart:
    def _engine(self, sol, arrivals, seed):
        kw = dict(b_max=BMAX, service=SVC, energy_table=ENERGY, seed=seed)
        if arrivals == "poisson":
            return ServingEngine(SMDPScheduler(sol), lam=LAM, **kw)
        if arrivals == "mmpp":
            from repro.serving.arrivals import MMPP2

            m = MMPP2(lam1=0.3 * LAM, lam2=1.2 * LAM, dwell1=50.0, dwell2=50.0)
            return ServingEngine(SMDPScheduler(sol), arrivals=m, **kw)
        times = np.cumsum(np.full(4000, 1.0 / LAM))
        return ServingEngine(SMDPScheduler(sol), arrivals=times, **kw)

    @pytest.mark.parametrize("arrivals", ["poisson", "mmpp", "trace"])
    def test_snapshot_restore_continues_identically(self, arrivals):
        """Mid-run snapshot/restore reproduces the exact EngineReport of an
        uninterrupted run, in every arrival mode."""
        sol = solve(spec())
        e1 = self._engine(sol, arrivals, seed=5)
        e1.run(1000)
        snap = e1.snapshot()
        r_cont = e1.run(1000)
        e2 = self._engine(sol, arrivals, seed=99)
        e2.restore(snap)
        r_rest = e2.run(1000)
        np.testing.assert_allclose(r_cont.latencies, r_rest.latencies)
        np.testing.assert_allclose(r_cont.energy, r_rest.energy)
        np.testing.assert_array_equal(r_cont.batch_sizes, r_rest.batch_sizes)
        assert r_cont.span == r_rest.span

    def test_adaptive_controller_restart_safe(self):
        """Snapshot covers the estimator + active bank key."""
        from repro.serving import AdaptiveController
        from repro.serving.arrivals import MMPP2
        from repro.serving.scheduler import SMDPSchedulerBank

        tables = {
            (0.5 * LAM,): np.minimum(np.arange(129), 8),
            (1.2 * LAM,): np.minimum(np.arange(129), BMAX),
        }
        def make():
            ctrl = AdaptiveController(
                SMDPSchedulerBank(tables, key_names=("lam",)),
                ewma=0.2, margin=0.1,
            )
            m = MMPP2(lam1=0.5 * LAM, lam2=1.2 * LAM, dwell1=40.0,
                      dwell2=40.0)
            return ServingEngine(ctrl, arrivals=m, b_max=BMAX, service=SVC,
                                 energy_table=ENERGY, seed=11)

        e1 = make()
        e1.run(1500)
        snap = e1.snapshot()
        r_cont = e1.run(1500)
        e2 = make()
        e2.restore(snap)
        r_rest = e2.run(1500)
        np.testing.assert_allclose(r_cont.latencies, r_rest.latencies)
        np.testing.assert_array_equal(r_cont.batch_sizes, r_rest.batch_sizes)

    def test_executor_mode_runs(self):
        """Wall-clock mode with a trivial executor serves all requests."""
        calls = []
        eng = ServingEngine(
            GreedyScheduler(1, 8), lam=1000.0, b_max=8,
            executor=lambda batch: calls.append(len(batch)),
        )
        reqs = [Request(i, arrival=i * 1e-4) for i in range(50)]
        rep = eng.run_executor(reqs)
        assert rep.n_served == 50
        assert sum(calls) == 50
        assert max(calls) <= 8


class TestKVCachePool:
    def test_claim_release_cycle(self):
        from repro.configs import ARCHS
        from repro.serving.kv_cache import KVCachePool

        pool = KVCachePool(ARCHS["qwen2.5-32b"].reduced(), n_slots=8, max_len=32)
        a = pool.claim(3)
        b = pool.claim(5)
        assert pool.claim(1) is None  # exhausted
        assert pool.stats().utilization == 1.0
        pool.release(a)
        assert pool.stats().in_use == 5
        c = pool.claim(2)
        assert len(set(c) & set(b)) == 0
        import pytest as _pytest
        with _pytest.raises(ValueError):
            pool.release(b + b[:1])  # double release detected

    def test_bytes_per_slot_positive(self):
        from repro.configs import ARCHS
        from repro.serving.kv_cache import KVCachePool

        pool = KVCachePool(ARCHS["rwkv6-3b"].reduced(), n_slots=2, max_len=16)
        assert pool.bytes_per_slot() > 0


class TestStreamingMetrics:
    def test_p2_quantile_accuracy(self):
        from repro.serving.metrics import P2Quantile

        rng = np.random.default_rng(0)
        data = rng.exponential(5.0, 20_000)
        est = P2Quantile(0.95)
        for x in data:
            est.update(float(x))
        true = np.percentile(data, 95)
        assert abs(est.value - true) / true < 0.05

    @pytest.mark.parametrize("dist", ["expo", "normal", "lognormal", "uniform"])
    @pytest.mark.parametrize("q", [0.5, 0.9, 0.99])
    def test_p2_quantile_random_streams(self, dist, q):
        """P² tracks np.percentile within a tolerance band across stream
        shapes and quantiles."""
        import zlib

        from repro.serving.metrics import P2Quantile

        rng = np.random.default_rng(zlib.crc32(f"{dist}:{q}".encode()))
        n = 30_000
        data = {
            "expo": lambda: rng.exponential(2.0, n),
            "normal": lambda: rng.normal(10.0, 3.0, n),
            "lognormal": lambda: rng.lognormal(0.0, 0.8, n),
            "uniform": lambda: rng.uniform(-1.0, 5.0, n),
        }[dist]()
        est = P2Quantile(q)
        for x in data:
            est.update(float(x))
        true = np.percentile(data, q * 100)
        scale = max(abs(true), data.std())
        assert abs(est.value - true) / scale < 0.05, (est.value, true)

    def test_serving_metrics_report(self):
        from repro.serving.metrics import ServingMetrics

        m = ServingMetrics()
        rng = np.random.default_rng(1)
        t = 0.0
        for _ in range(300):
            t += 1.0
            m.observe_batch(rng.exponential(3.0, 8), zeta=50.0, t_now=t)
        rep = m.report()
        assert abs(rep["W_mean"] - 3.0) < 0.3
        assert abs(rep["power"] - 50.0) < 1e-6
        assert rep["mean_batch"] == 8.0
