"""Fleet serving lane: M=1 equivalence with the single-server compiled
kernel per arrival mode, Python-reference router agreement per routing
policy, conservation/dominance invariants, snapshot()/restore() through
router state, chunked streaming vs materialized record, the record-slot
cap, the count-zero metrics convention, and the mesh-sharded grid."""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import GOOGLENET_P4_ENERGY, GOOGLENET_P4_LATENCY, ServiceModel
from repro.core.policies import q_policy
from repro.serving import (
    FleetStream,
    PythonFleet,
    ServingMetrics,
    histogram_quantiles,
    pad_arrivals_batch,
    run_fleet_grid,
    simulate_compiled,
    simulate_fleet,
    simulate_fleet_stream,
    threshold_gaps,
    verify_fleet,
)
from repro.serving.arrivals import MMPP2, DiurnalProcess

ROOT = Path(__file__).resolve().parent.parent

SVC = ServiceModel(latency=GOOGLENET_P4_LATENCY, family="det")
BMAX = 16
#: per-replica load ~0.7 at M=1 (each M-replica test scales lam by M)
LAM = 0.7 * BMAX / float(SVC.mean(BMAX))
ENERGY = np.array(
    [0.0] + [float(GOOGLENET_P4_ENERGY(b)) for b in range(1, BMAX + 1)]
)
MEANS = np.array([0.0] + [float(SVC.mean(b)) for b in range(1, BMAX + 1)])
TABLE = q_policy(6, 96, BMAX)
#: heterogeneous fleet: each replica its own control limit
HET_QS = (4, 6, 8, 12)
HET_TABLES = np.stack([q_policy(q, 96, BMAX) for q in HET_QS])
ROUTER_NAMES = ["rr", "jsq", "pow2", "batch_aware"]


def _trace(mode: str, n: int = 1200, seed: int = 0, lam: float = LAM):
    rng = np.random.default_rng(seed)
    if mode == "poisson":
        return np.cumsum(rng.exponential(1.0 / lam, n))
    if mode == "mmpp2":
        m = MMPP2(lam1=0.3 * lam, lam2=1.3 * lam, dwell1=60.0, dwell2=30.0)
        times, _ = m.sample_arrivals(n / m.mean_rate, rng)
        return times
    assert mode == "diurnal"
    proc = DiurnalProcess(base=lam, amp=0.6 * lam, period=120.0)
    return np.array([proc.next(rng).time for _ in range(n)])


class TestM1Equivalence:
    """ISSUE acceptance: the M=1 fleet lane is decision-for-decision
    identical to serving/compiled.py on Poisson, MMPP2, and diurnal."""

    @pytest.mark.parametrize("mode", ["poisson", "mmpp2", "diurnal"])
    def test_matches_single_server_kernel(self, mode):
        out = verify_fleet(
            TABLE, _trace(mode), router="jsq", service=SVC,
            energy_table=ENERGY, b_max=BMAX,
        )
        assert out["n_decisions"] > 0

    @pytest.mark.parametrize("router", ROUTER_NAMES)
    def test_every_router_degenerates_at_m1(self, router):
        verify_fleet(
            TABLE, _trace("poisson"), router=router, service=SVC,
            energy_table=ENERGY, b_max=BMAX,
        )

    def test_m1_bitwise_vs_compiled(self):
        tr = _trace("poisson")
        res = simulate_fleet(
            TABLE, tr, router="rr", means=MEANS, zeta=ENERGY, b_max=BMAX,
            record=True,
        )
        ref = simulate_compiled(
            TABLE, tr, means=MEANS, zeta=ENERGY, b_max=BMAX, record=True,
        )
        assert np.array_equal(res.batch_sizes, ref.actions[ref.actions > 0])
        assert np.array_equal(
            res.latencies[res.served], np.asarray(ref.latencies)
        )
        assert res.t_final == ref.t_final
        assert res.energy == ref.energy
        assert res.n_epochs == ref.n_epochs


class TestFleetVerify:
    """Python reference router loop == compiled lane, per routing policy,
    on a heterogeneous 4-replica fleet."""

    @pytest.mark.parametrize("router", ROUTER_NAMES)
    def test_router_agreement(self, router):
        out = verify_fleet(
            HET_TABLES, _trace("poisson", lam=4 * LAM), router=router,
            service=SVC, energy_table=ENERGY, b_max=BMAX, slo=3.0,
        )
        assert out["n_decisions"] > 0

    @pytest.mark.parametrize("router", ["jsq", "pow2"])
    def test_budget_and_horizon_cuts(self, router):
        tr = _trace("poisson", lam=4 * LAM)
        verify_fleet(
            HET_TABLES, tr, router=router, service=SVC,
            energy_table=ENERGY, b_max=BMAX, n_epochs=500, drain=False,
        )
        verify_fleet(
            HET_TABLES, tr, router=router, service=SVC,
            energy_table=ENERGY, b_max=BMAX,
            horizon=float(tr[len(tr) // 2]),
        )

    def test_stochastic_service_shared_draws(self):
        svc = ServiceModel(latency=GOOGLENET_P4_LATENCY, family="expo")
        verify_fleet(
            HET_TABLES, _trace("poisson", lam=4 * LAM), router="jsq",
            service=svc, energy_table=ENERGY, b_max=BMAX,
        )


class TestThresholdGaps:
    def test_control_limit_gaps(self):
        tab = q_policy(4, 16, 8)
        g = threshold_gaps(tab[None, None, :])[0, 0]
        # queue q: arrivals still needed (beyond the next one) to reach
        # the table's first serving state — 4-long countdown, then 0
        assert np.array_equal(g[:5], [3, 2, 1, 0, 0])
        assert (g[5:] == 0).all()

    def test_never_serving_row_gets_max_gap(self):
        tab = np.zeros((1, 1, 8), dtype=np.int64)
        g = threshold_gaps(tab)
        assert (g == 8).all()  # clamped to L: worst-ranked target


class TestFleetInvariants:
    def test_request_conservation_per_router(self):
        traces = [_trace("poisson", seed=s, lam=4 * LAM) for s in range(2)]
        arr = pad_arrivals_batch(traces)
        cut = float(traces[0][800])
        out = run_fleet_grid(
            np.stack([TABLE, q_policy(10, 96, BMAX)]), arr,
            routers=ROUTER_NAMES, n_replicas=4, means=MEANS, zeta=ENERGY,
            b_max=BMAX, horizon=cut, drain=False,
        )
        # admitted = routed = served + still-queued, per (S, P, R) lane
        assert (out["n_route"].sum(axis=-1) == out["n_admitted"]).all()
        assert (
            out["n_served"] + out["qlen"].sum(axis=-1) == out["n_admitted"]
        ).all()
        # the horizon cut dropped the unadmitted tail, same for every lane
        n_in = np.array([(t < cut).sum() for t in traces])
        assert (out["n_admitted"] == n_in[:, None, None]).all()

    def test_jsq_dominates_pow2_at_high_rho(self):
        """Stochastic dominance on time-averaged backlog at rho = 0.9,
        averaged over seeds: JSQ < pow2 (classic supermarket-model
        ordering; q_time_avg = lat_sum / span by Little's law).  The
        regime matters: with GoogLeNet-style sublinear batch latency,
        LESS-informed routing batches better (JSQ herds arrivals onto
        just-idled replicas, shattering batches), so the classic ordering
        needs linear per-request latency and stochastic service."""
        bmax, c, M = 4, 0.05, 8
        means = np.array([0.0] + [c * b for b in range(1, bmax + 1)])
        lam = 0.9 * M / c
        traces, draws = [], []
        for s in range(6):
            r = np.random.default_rng(s)
            traces.append(np.cumsum(r.exponential(1.0 / lam, 4000)))
            draws.append(r.exponential(1.0, 2 * 4000 + M + 8))
        out = run_fleet_grid(
            q_policy(1, 64, bmax)[None], pad_arrivals_batch(traces),
            routers=("jsq", "pow2", "rr"), n_replicas=M, means=means,
            b_max=bmax, draws=np.stack(draws),
        )
        q = out["q_time_avg"][:, 0, :].mean(axis=0)  # (R,) seed-avg
        assert q[0] < q[1], q  # jsq beats pow2
        assert q[0] < q[2], q  # ...and blind round-robin

    @pytest.mark.parametrize("router", ["pow2", "batch_aware"])
    def test_snapshot_restore_through_router_state(self, router):
        tr = _trace("poisson", lam=4 * LAM)
        fl = PythonFleet(
            HET_TABLES, tr, router=router, means=MEANS, zeta=ENERGY,
            b_max=BMAX, slo=3.0,
        )
        for _ in range(400):
            if not fl.step():
                break
        snap = fl.snapshot()
        fl.run()
        ref = (
            list(fl.decisions), fl.latencies.copy(), fl.energy,
            fl.arr_server.copy(), fl.slo_miss, fl.t,
        )
        fl.restore(snap)
        fl.run()
        assert list(fl.decisions) == ref[0]
        assert np.array_equal(fl.latencies, ref[1], equal_nan=True)
        assert fl.energy == ref[2]
        assert np.array_equal(fl.arr_server, ref[3])
        assert (fl.slo_miss, fl.t) == (ref[4], ref[5])


class TestStreaming:
    """ISSUE acceptance: chunked streaming reproduces the materialized-
    record aggregates at >= 10x the chunk size."""

    def test_stream_matches_one_shot_exactly(self):
        tr = _trace("poisson", n=6000, lam=4 * LAM)
        one = simulate_fleet(
            HET_TABLES, tr, router="jsq", means=MEANS, zeta=ENERGY,
            b_max=BMAX, slo=3.0,
        )
        st = simulate_fleet_stream(
            HET_TABLES, tr, chunk_size=512, router="jsq", means=MEANS,
            zeta=ENERGY, b_max=BMAX, slo=3.0,
        )
        assert st.n_served == one.n_served == 6000
        assert st.n_batches == one.n_batches
        assert np.isclose(st.lat_sum, one.lat_sum, rtol=1e-12)
        assert np.isclose(st.energy, one.energy, rtol=1e-12)
        assert st.slo_miss == one.slo_miss
        assert st.t_final == one.t_final
        assert np.array_equal(st.hist, one.hist)

    def test_p2_quantiles_within_sketch_tolerance(self):
        # homogeneous fleet: a heterogeneous one has multimodal latency,
        # where the P2 marker sketch is known-biased at the tails
        tabs = np.tile(TABLE[None], (4, 1))
        tr = _trace("poisson", n=6000, lam=4 * LAM)
        one = simulate_fleet(
            tabs, tr, router="jsq", means=MEANS, b_max=BMAX, record=True
        )
        true_q = np.percentile(one.latencies[one.served], [50, 95])
        fs = FleetStream(tabs, router="jsq", means=MEANS, b_max=BMAX)
        for lo in range(0, len(tr), 512):
            fs.push(tr[lo:lo + 512])
        res = fs.finish()
        rep = fs.report()
        hq = histogram_quantiles(res.hist, res.hist_edges, [0.5, 0.95])
        for sketch in (rep["P50"], hq[0]):
            assert abs(sketch - true_q[0]) / true_q[0] < 0.05
        for sketch in (rep["P95"], hq[1]):
            assert abs(sketch - true_q[1]) / true_q[1] < 0.05
        assert rep["W_mean"] == pytest.approx(res.lat_sum / res.n_served)

    def test_pow2_stream_shares_router_uniforms(self):
        tr = _trace("poisson", n=3000, lam=4 * LAM)
        ru = np.random.default_rng(5).random((len(tr), 2))
        one = simulate_fleet(
            HET_TABLES, tr, router="pow2", means=MEANS, b_max=BMAX,
            router_u=ru,
        )
        st = simulate_fleet_stream(
            HET_TABLES, tr, chunk_size=700, router="pow2", means=MEANS,
            b_max=BMAX, router_u=ru,
        )
        assert st.n_batches == one.n_batches
        assert np.isclose(st.lat_sum, one.lat_sum, rtol=1e-12)
        assert np.array_equal(st.n_routed, one.n_routed)


class TestRecordSlotCap:
    def test_cap_raises_with_streaming_pointer(self):
        arr = np.cumsum(np.full(200, 0.01))
        with pytest.raises(ValueError, match="FleetStream"):
            simulate_compiled(
                TABLE, arr, means=MEANS, b_max=BMAX, record=True,
                max_record_slots=64,
            )

    def test_cap_ignores_aggregate_only_runs(self):
        arr = np.cumsum(np.full(200, 0.01))
        res = simulate_compiled(
            TABLE, arr, means=MEANS, b_max=BMAX, record=False,
            max_record_slots=64,
        )
        assert res.n_served == 200


class TestCountZeroMetrics:
    """ISSUE satellite: empty / single-event lanes report NaN with count
    zero, on both the Python sketches and the compiled aggregate path."""

    def test_serving_metrics_empty(self):
        rep = ServingMetrics().report()
        for k in ("W_mean", "P50", "P95", "P99", "mean_batch"):
            assert np.isnan(rep[k]), k
        assert rep["n_served"] == 0.0

    def test_serving_metrics_single_event(self):
        m = ServingMetrics()
        m.observe_batch([1.5], zeta=2.0, t_now=3.0)
        rep = m.report()
        assert rep["W_mean"] == 1.5 and rep["P50"] == 1.5
        assert rep["mean_batch"] == 1.0

    def test_histogram_quantiles_empty_and_poisoned(self):
        edges = np.linspace(0.0, 10.0, 9)
        assert np.isnan(
            histogram_quantiles(np.zeros(10), edges, [0.5, 0.99])
        ).all()
        bad = np.zeros(10)
        bad[3] = np.nan
        assert np.isnan(histogram_quantiles(bad, edges, [0.5])).all()

    def test_starved_lane_compiled_path(self):
        # horizon before the first arrival: nothing admitted or served
        tr = 10.0 + np.cumsum(np.full(50, 0.1))
        out = run_fleet_grid(
            TABLE[None], pad_arrivals_batch([tr]), routers=("jsq",),
            n_replicas=2, means=MEANS, zeta=ENERGY, b_max=BMAX,
            horizon=1.0, drain=False,
        )
        assert out["n_served"][0, 0, 0] == 0
        assert np.isnan(out["w_mean"][0, 0, 0])
        assert np.isnan(out["power"][0, 0, 0])
        assert np.isnan(
            histogram_quantiles(
                out["hist"][0, 0, 0], out["hist_edges"], [0.5]
            )
        ).all()

    def test_starved_replicas_in_fleet(self):
        # 2 arrivals round-robined across 4 replicas: two never serve
        res = simulate_fleet(
            np.tile(TABLE[None], (4, 1)), np.array([0.1, 0.2]),
            router="rr", means=MEANS, zeta=ENERGY, b_max=BMAX,
        )
        assert res.n_served == 2
        assert (res.n_served_m == [1, 1, 0, 0]).all()
        assert int(res.hist.sum()) == 2


class TestFleetBeliefLane:
    """phase_mode="belief_argmax" lowers the posterior to the fleet's
    phase stream — same plumbing as simulate_compiled's belief lane."""

    def _stack_and_beliefs(self, n=900, seed=31):
        from repro.serving.arrivals import PhaseBeliefFilter, belief_forward_jax

        trace = _trace("mmpp2", n=n, seed=seed, lam=2 * LAM)
        filt = PhaseBeliefFilter(
            rates=[0.3 * 2 * LAM, 1.3 * 2 * LAM],
            gen=[[-1 / 60.0, 1 / 60.0], [1 / 30.0, -1 / 30.0]],
        )
        bel = np.asarray(belief_forward_jax(trace, filt)[0])
        stacks = np.stack([
            np.stack([q_policy(4, 96, BMAX), q_policy(10, 96, BMAX)])
            for _ in range(2)
        ])  # (M=2, K=2, L)
        return trace, bel, stacks

    def test_belief_argmax_equals_explicit_phases(self):
        trace, bel, stacks = self._stack_and_beliefs()
        kw = dict(
            router="jsq", means=MEANS, zeta=ENERGY, b_max=BMAX, record=True
        )
        r_bel = simulate_fleet(
            stacks, trace, phase_mode="belief_argmax", beliefs=bel, **kw
        )
        r_ph = simulate_fleet(
            stacks, trace, phases=np.argmax(bel, axis=-1), **kw
        )
        np.testing.assert_array_equal(r_bel.actions, r_ph.actions)
        np.testing.assert_array_equal(r_bel.servers, r_ph.servers)
        np.testing.assert_allclose(r_bel.lat_sum, r_ph.lat_sum)
        assert r_bel.n_served == r_ph.n_served

    def test_belief_mix_m1_matches_single_server_kernel(self):
        # an M=1 belief-mix fleet replays simulate_compiled's mix lane
        from repro.serving.compiled import simulate_compiled

        trace, bel, stacks = self._stack_and_beliefs(n=600)
        kw = dict(means=MEANS, zeta=ENERGY, b_max=BMAX, record=True)
        r = simulate_fleet(
            stacks[:1], trace, phase_mode="belief_mix", beliefs=bel,
            router="rr", **kw
        )
        s = simulate_compiled(
            stacks[0], trace, phase_mode="belief_mix", beliefs=bel, **kw
        )
        np.testing.assert_array_equal(
            r.actions[r.actions > 0], s.batch_sizes
        )
        assert r.n_served == s.n_served
        np.testing.assert_allclose(
            r.latencies[r.served], s.latencies
        )
        np.testing.assert_allclose(r.energy, s.energy)
        np.testing.assert_allclose(r.t_final, s.t_final)

    def test_belief_mix_certified_python_vs_compiled(self):
        trace, bel, stacks = self._stack_and_beliefs(n=500)
        svc = ServiceModel(latency=GOOGLENET_P4_LATENCY, family="det")
        for router in ("jsq", "batch_aware"):
            verify_fleet(
                stacks, trace, router=router, service=svc,
                energy_table=ENERGY, b_max=BMAX,
                phase_mode="belief_mix", beliefs=bel,
            )

    def test_belief_mix_differs_from_argmax_somewhere(self):
        # a mixed posterior between distant per-phase thresholds must
        # produce at least one action the MAP row would not
        trace, bel, stacks = self._stack_and_beliefs(n=900)
        kw = dict(
            router="jsq", means=MEANS, zeta=ENERGY, b_max=BMAX, record=True
        )
        r_mix = simulate_fleet(
            stacks, trace, phase_mode="belief_mix", beliefs=bel, **kw
        )
        r_map = simulate_fleet(
            stacks, trace, phase_mode="belief_argmax", beliefs=bel, **kw
        )
        assert r_mix.n_served == r_map.n_served == len(trace)
        assert len(r_mix.actions) != len(r_map.actions) or (
            (r_mix.actions != r_map.actions).any()
        )

    def test_grid_belief_argmax_equals_explicit_phases(self):
        trace, bel, stacks = self._stack_and_beliefs(n=700)
        arr = pad_arrivals_batch([trace])
        bels = np.zeros(arr.shape + (2,))
        bels[0, : len(trace)] = bel
        bels[0, len(trace):, 0] = 1.0  # pad rows: any valid posterior
        g_bel = run_fleet_grid(
            stacks[None], arr, routers=("jsq",), means=MEANS, zeta=ENERGY,
            b_max=BMAX, phase_mode="belief_argmax", beliefs=bels,
        )
        g_ph = run_fleet_grid(
            stacks[None], arr, routers=("jsq",), means=MEANS, zeta=ENERGY,
            b_max=BMAX, phases=np.argmax(bels, axis=-1),
        )
        for k in ("n_served", "lat_sum", "energy", "t_final"):
            np.testing.assert_allclose(g_bel[k], g_ph[k])

    def test_oracle_mode_rejects_beliefs(self):
        trace, bel, stacks = self._stack_and_beliefs(n=50)
        with pytest.raises(ValueError, match="belief"):
            simulate_fleet(
                stacks, trace, beliefs=bel, means=MEANS, b_max=BMAX
            )


class TestFleetGrid:
    def test_grid_cell_matches_simulate_fleet(self):
        traces = [_trace("poisson", seed=s, lam=4 * LAM) for s in range(2)]
        arr = pad_arrivals_batch(traces)
        policies = np.stack([TABLE, q_policy(10, 96, BMAX)])
        out = run_fleet_grid(
            policies, arr, routers=ROUTER_NAMES, n_replicas=4,
            means=MEANS, zeta=ENERGY, b_max=BMAX, router_seed=7,
        )
        ru = np.random.default_rng(7).random(arr.shape + (2,))
        ref = simulate_fleet(
            np.tile(policies[1][None], (4, 1)), traces[1], router="pow2",
            means=MEANS, zeta=ENERGY, b_max=BMAX,
            router_u=ru[1][: len(traces[1])],
        )
        i = ROUTER_NAMES.index("pow2")
        assert out["n_served"][1, 1, i] == ref.n_served
        assert out["n_batches"][1, 1, i] == ref.n_batches
        assert np.isclose(out["lat_sum"][1, 1, i], ref.lat_sum)
        assert np.isclose(out["energy"][1, 1, i], ref.energy)
        assert np.isclose(out["t_final"][1, 1, i], ref.t_final)

    def test_one_device_mesh_parity(self):
        from repro.launch.mesh import make_sim_mesh

        traces = [_trace("poisson", seed=s, lam=4 * LAM) for s in range(2)]
        arr = pad_arrivals_batch(traces)
        policies = np.stack([TABLE, q_policy(10, 96, BMAX)])
        kw = dict(
            routers=("jsq", "rr"), n_replicas=4, means=MEANS, zeta=ENERGY,
            b_max=BMAX, router_seed=7,
        )
        plain = run_fleet_grid(policies, arr, **kw)
        mesh = run_fleet_grid(policies, arr, mesh=make_sim_mesh(), **kw)
        for k, v in plain.items():
            assert np.allclose(v, mesh[k], equal_nan=True), k


_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from repro.core import GOOGLENET_P4_LATENCY, ServiceModel
from repro.core.policies import q_policy
from repro.launch.mesh import make_sim_mesh
from repro.serving import pad_arrivals_batch, run_fleet_grid

assert jax.device_count() == 8
SVC = ServiceModel(latency=GOOGLENET_P4_LATENCY, family="det")
BMAX = 16
lam = 0.7 * 4 * BMAX / float(SVC.mean(BMAX))
means = np.array([0.0] + [float(SVC.mean(b)) for b in range(1, BMAX + 1)])
# 3 lanes on 8 devices: exercises the pad-to-multiple + trim path
traces = [np.cumsum(np.random.default_rng(s).exponential(1.0 / lam, 600))
          for s in range(3)]
arr = pad_arrivals_batch(traces)
tabs = np.stack([q_policy(6, 96, BMAX), q_policy(10, 96, BMAX)])
kw = dict(routers=("jsq", "pow2"), n_replicas=4, means=means, b_max=BMAX)
plain = run_fleet_grid(tabs, arr, **kw)
shard = run_fleet_grid(tabs, arr, mesh=make_sim_mesh(), **kw)
for k, v in plain.items():
    assert np.allclose(v, shard[k], equal_nan=True), k
print("OK sharded == plain")
"""

_JAX_ENV = {k: v for k, v in os.environ.items() if k.startswith("JAX_")}


@pytest.mark.slow
def test_fleet_grid_sharded_8dev():
    r = subprocess.run(
        [sys.executable, "-c", _SHARD_SCRIPT],
        cwd=ROOT,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root", **_JAX_ENV},
        capture_output=True,
        text=True,
        timeout=500,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK sharded == plain" in r.stdout
