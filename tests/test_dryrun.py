"""Dry-run smoke (subprocess: needs a fresh jax with 512 host devices)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent

# propagate platform selection (e.g. JAX_PLATFORMS=cpu): without it the
# fresh jax probes for accelerators and can hang in sandboxes
_JAX_ENV = {k: v for k, v in os.environ.items() if k.startswith("JAX_")}


@pytest.mark.slow
def test_dryrun_cell_compiles(tmp_path):
    """One representative cell lowers + compiles on the production mesh."""
    r = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", "rwkv6-3b", "--shape", "decode_32k",
            "--mesh", "single", "--out", str(tmp_path),
        ],
        cwd=ROOT,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
             **_JAX_ENV},
        capture_output=True,
        text=True,
        timeout=500,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads(
        (tmp_path / "rwkv6-3b__decode_32k__single_pod.json").read_text()
    )
    assert rec["status"] == "ok"
    assert rec["chips"] == 256
    assert rec["memory"]["temp_size_in_bytes"] > 0
    assert rec["roofline"]["bottleneck"] in ("compute", "memory", "collective")


def test_sweep_artifacts_complete():
    """The committed sweep covers every (arch x shape x mesh) cell."""
    art = ROOT / "artifacts" / "dryrun"
    if not art.exists():
        pytest.skip("sweep artifacts not generated yet")
    recs = [json.loads(p.read_text()) for p in art.glob("*.json")]
    assert len(recs) >= 80  # 10 archs x 4 shapes x 2 meshes
    bad = [r for r in recs if r["status"] == "error"]
    assert not bad, [(r["arch"], r["shape"], r["mesh"]) for r in bad]
    ok = [r for r in recs if r["status"] == "ok"]
    assert len(ok) == 64  # 32 runnable cells x 2 meshes
    skips = [r for r in recs if r["status"] == "skipped"]
    assert all("full-attention" in r["reason"] for r in skips)
