"""Training substrate: optimizers, microbatching, resume, compression."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models import model as M
from repro.training.data import DataConfig, batch_at_step
from repro.training.optimizer import (
    AdafactorConfig,
    AdamWConfig,
    opt_init,
    opt_update,
)
from repro.training.train_loop import Trainer, TrainerConfig
from repro.training.train_step import make_train_step

KEY = jax.random.PRNGKey(0)
CFG = ARCHS["qwen2.5-32b"].reduced()
DATA = DataConfig(vocab_size=CFG.vocab_size, seq_len=32, global_batch=4, seed=3)


class TestOptimizers:
    def _loss_decreases(self, opt_cfg, steps=8):
        params = M.init_params(CFG, KEY)
        opt_state = opt_init(params, opt_cfg)
        step = jax.jit(make_train_step(CFG, opt_cfg, remat=False))
        losses = []
        for i in range(steps):
            params, opt_state, m = step(params, opt_state, batch_at_step(DATA, i % 2))
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], losses
        return losses

    def test_adamw_decreases_loss(self):
        self._loss_decreases(AdamWConfig(lr=2e-3))

    def test_adamw_bf16_moments(self):
        self._loss_decreases(AdamWConfig(lr=2e-3, moment_dtype=jnp.bfloat16))

    def test_adafactor_decreases_loss(self):
        self._loss_decreases(AdafactorConfig(lr=2e-2))

    def test_adafactor_state_is_factored(self):
        params = M.init_params(CFG, KEY)
        st = opt_init(params, AdafactorConfig())
        n_p = sum(x.size for x in jax.tree.leaves(params))
        n_s = sum(x.size for x in jax.tree.leaves(st["f"]))
        assert n_s < 0.2 * n_p  # factored: O(rows+cols), not O(rows*cols)


class TestMicrobatching:
    def test_grad_accumulation_matches_full_batch(self):
        opt = AdamWConfig(lr=1e-3)
        params = M.init_params(CFG, KEY)
        batch = batch_at_step(DATA, 0)
        s1 = jax.jit(make_train_step(CFG, opt, remat=False, n_micro=1))
        s2 = jax.jit(make_train_step(CFG, opt, remat=False, n_micro=2))
        p1, _, m1 = s1(params, opt_init(params, opt), batch)
        p2, _, m2 = s2(params, opt_init(params, opt), batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


class TestTrainerResume:
    def test_resume_reproduces_uninterrupted_run(self, tmp_path):
        """Crash/restart at step 3 must land exactly where a straight 6-step
        run lands (checkpoint + step-indexed data => bitwise-determinism)."""
        opt = AdamWConfig(lr=1e-3)
        t_all = Trainer(
            CFG, DATA, opt,
            TrainerConfig(steps=6, ckpt_every=100, ckpt_dir=str(tmp_path / "a"), log_every=100),
            log_fn=lambda s: None,
        )
        p_all, _, losses_all = t_all.run(seed=0)

        t_first = Trainer(
            CFG, DATA, opt,
            TrainerConfig(steps=3, ckpt_every=3, ckpt_dir=str(tmp_path / "b"), log_every=100),
            log_fn=lambda s: None,
        )
        t_first.run(seed=0)
        t_resume = Trainer(
            CFG, DATA, opt,
            TrainerConfig(steps=6, ckpt_every=3, ckpt_dir=str(tmp_path / "b"), log_every=100),
            log_fn=lambda s: None,
        )
        p_res, _, losses_res = t_resume.run(seed=0)
        np.testing.assert_allclose(losses_all[3:], losses_res, rtol=1e-6)
        for a, b in zip(jax.tree.leaves(p_all), jax.tree.leaves(p_res)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


class TestCompressedTraining:
    def test_int8_grads_still_learn(self):
        """Quantized-with-error-feedback gradients reach a similar loss."""
        from repro.distributed.compression import (
            compress_with_error_feedback,
            init_error_feedback,
        )

        opt = AdamWConfig(lr=2e-3)
        params = M.init_params(CFG, KEY)
        opt_state = opt_init(params, opt)
        err = init_error_feedback(params)
        loss_fn = lambda p, b: M.lm_loss(CFG, p, b, remat=False)
        grad_fn = jax.jit(jax.value_and_grad(loss_fn))
        losses = []
        for i in range(8):
            batch = batch_at_step(DATA, i % 2)
            l, g = grad_fn(params, batch)
            g, err = compress_with_error_feedback(g, err)
            params, opt_state, _ = opt_update(g, opt_state, params, opt)
            losses.append(float(l))
        assert losses[-1] < losses[0]
