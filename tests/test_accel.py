"""Accelerated batched RVI vs the scalar float64 solve() oracle.

Covers the acceptance surface of the solver accelerants:
  * accel="mpi" / "anderson" across a rho x w2 grid — greedy policies
    bit-identical to the scalar oracle, |g - g_oracle| < 1e-6
  * iteration-count regression: MPI at rho = 0.85 needs <= 1/3 of plain
    RVI's lockstep backups (measured: ~1/40)
  * the Anderson safeguard: on a slow-mixing spec the unsafeguarded
    secant step increases the span residual and stalls, the safe path
    rejects those steps and still converges
  * the MPI building blocks: banded policy matrix / gauge-fixed linear
    policy evaluation against the dense constructions
  * batched infrastructure: policy_transitions_batched, with_c_o,
    stationary_distribution_batched against their scalar counterparts
  * the spec-batched Pallas backup wired into the batched loops
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    GOOGLENET_P4_ENERGY,
    GOOGLENET_P4_LATENCY,
    ServiceModel,
    SMDPSpec,
    build_smdp_batched,
    evaluate_policy,
    relative_value_iteration,
    relative_value_iteration_batched,
    solve,
    sweep_solve,
)
from repro.core.evaluate import (
    evaluate_policy_banded,
    policy_eval_linear,
    policy_matrix_banded,
    stationary_distribution_batched,
)
from repro.core.policies import greedy_policy
from repro.core.rvi import trimmed_band


def spec_for(rho=0.3, w2=1.0, s_max=96, b_max=32, family="det"):
    svc = ServiceModel(latency=GOOGLENET_P4_LATENCY, family=family)
    lam = rho * b_max / float(svc.mean(b_max))
    return SMDPSpec(
        lam=lam, service=svc, energy=GOOGLENET_P4_ENERGY,
        b_min=1, b_max=b_max, w1=1.0, w2=w2, s_max=s_max, c_o=100.0,
    )


W2S = (0.0, 1.0, 5.0)


class TestAccelOracleGrid:
    @pytest.mark.parametrize("rho", [0.3, 0.7, 0.9])
    @pytest.mark.parametrize("accel", ["mpi", "anderson"])
    def test_matches_scalar_oracle(self, rho, accel):
        base = spec_for(rho=rho, s_max=96, b_max=16)
        specs = [dataclasses.replace(base, w2=w) for w in W2S]
        batch = build_smdp_batched(specs)
        res = relative_value_iteration_batched(batch, accel=accel)
        assert res.converged.all()
        for i, sp in enumerate(specs):
            # the untouched exact oracle at the same truncation
            oracle = solve(sp, auto_c_o=False, delta=None)
            assert np.array_equal(res.policies[i], oracle.policy), (rho, sp.w2)
            assert abs(res.g[i] - oracle.eval.g) < 1e-6

    def test_scalar_entry_point(self):
        sp = spec_for(rho=0.7, s_max=64, b_max=16)
        oracle = solve(sp, auto_c_o=False, delta=None)
        for accel in ("mpi", "anderson"):
            res = solve(sp, auto_c_o=False, delta=None, accel=accel)
            assert np.array_equal(res.policy, oracle.policy)
            assert abs(res.rvi.g - oracle.eval.g) < 1e-6
            assert res.rvi.converged

    def test_sweep_solve_accel_matches_plain(self):
        # the sweep default (accel="auto" -> "mpi" at this rho) returns the
        # same solved sweep as the plain path, auto-grow rounds included
        base = spec_for(rho=0.85, s_max=32, b_max=16)
        specs = [dataclasses.replace(base, w2=w) for w in (0.0, 2.0)]
        plain = sweep_solve(specs, accel="none")
        accel = sweep_solve(specs)  # default accel="auto"
        for p, a in zip(plain, accel):
            assert p.spec.s_max == a.spec.s_max  # same truncation decisions
            assert np.array_equal(p.policy, a.policy)
            np.testing.assert_allclose(p.eval.g, a.eval.g, rtol=1e-9)


class TestIterationRegression:
    def test_mpi_beats_plain_by_3x_at_high_rho(self):
        base = spec_for(rho=0.85, s_max=128, b_max=32)
        specs = [dataclasses.replace(base, w2=w) for w in W2S]
        batch = build_smdp_batched(specs)
        plain = relative_value_iteration_batched(batch, accel="none")
        mpi = relative_value_iteration_batched(batch, accel="mpi")
        assert plain.converged.all() and mpi.converged.all()
        assert np.array_equal(plain.policies, mpi.policies)
        # the tentpole claim: the mixing wall (hundreds of lockstep
        # backups) falls to tens; regression-guard at 1/3
        assert mpi.iterations.max() <= plain.iterations.max() / 3, (
            plain.iterations, mpi.iterations
        )


class TestAndersonSafeguard:
    def test_unsafeguarded_secant_increases_span_and_stalls(self):
        # slow-mixing spec: the known failure mode of textbook Anderson on
        # the span seminorm (see rvi module docstring)
        sp = spec_for(rho=0.85, w2=1.0, s_max=96)
        batch = build_smdp_batched([sp])
        unsafe = relative_value_iteration_batched(
            batch,
            accel="anderson",
            accel_safeguard=False,
            max_iter=600,
            mixed_precision=False,
        )
        # the unsafeguarded path TAKES span-increasing secant steps ...
        assert int(unsafe.accel_rejects[0]) > 0
        # ... and fails to converge within a budget the safe path beats
        assert not unsafe.converged[0]

        safe = relative_value_iteration_batched(
            batch, accel="anderson", mixed_precision=False
        )
        assert safe.converged[0]
        # the safeguard actually engaged (same pathological steps refused)
        assert int(safe.accel_rejects[0]) > 0
        assert int(safe.iterations[0]) < 600
        oracle = solve(sp, auto_c_o=False, delta=None)
        assert np.array_equal(safe.policies[0], oracle.policy)


class TestMPIBuildingBlocks:
    def _batch(self):
        specs = [
            spec_for(rho=0.4, w2=0.5, s_max=48, b_max=16),
            spec_for(rho=0.7, w2=3.0, s_max=48, b_max=16, family="expo"),
        ]
        return build_smdp_batched(specs), specs

    def test_policy_matrix_matches_dense_m_tilde(self):
        batch, specs = self._batch()
        rng = np.random.default_rng(1)
        for i in range(batch.n_specs):
            m_tilde = batch.m_tilde_dense(i)
            S = batch.n_states
            s_val = np.minimum(np.arange(S), specs[i].s_max)
            pol = np.where(rng.random(S) < 0.4, 0, rng.integers(1, 17, S))
            pol = np.minimum(pol, s_val).astype(np.int64)
            got = np.asarray(
                policy_matrix_banded(
                    jnp.asarray(batch.pmfs_banded[i]),
                    jnp.asarray(batch.tails[i]),
                    jnp.asarray(batch.scale[i]),
                    specs[i].s_max,
                    jnp.asarray(pol),
                )
            )
            want = m_tilde[np.arange(S), pol, :]
            np.testing.assert_allclose(got, want, atol=1e-12)

    def test_policy_matrix_with_trimmed_band(self):
        # the MPI polish runs on the band-trimmed pmfs; the induced row
        # defect must stay at the trimming tolerance
        batch, specs = self._batch()
        pm = batch.pmfs_banded
        kb = trimmed_band(pm)
        pol = greedy_policy(specs[0].s_max, specs[0].b_min, specs[0].b_max)
        got = np.asarray(
            policy_matrix_banded(
                jnp.asarray(pm[0, :, :kb]),
                jnp.asarray(batch.tails[0]),
                jnp.asarray(batch.scale[0]),
                specs[0].s_max,
                jnp.asarray(pol),
            )
        )
        S = batch.n_states
        want = batch.m_tilde_dense(0)[np.arange(S), pol, :]
        np.testing.assert_allclose(got, want, atol=1e-12)

    def test_linear_eval_matches_stationary_eval(self):
        batch, specs = self._batch()
        for i, sp in enumerate(specs):
            pol = greedy_policy(sp.s_max, sp.b_min, sp.b_max)
            m_pi = policy_matrix_banded(
                jnp.asarray(batch.pmfs_banded[i]),
                jnp.asarray(batch.tails[i]),
                jnp.asarray(batch.scale[i]),
                sp.s_max,
                jnp.asarray(pol),
            )
            S = batch.n_states
            c_pi = jnp.asarray(batch.c_tilde[i][np.arange(S), pol])
            g, h = policy_eval_linear(c_pi, m_pi)
            # the DTMDP gain of a policy equals its SMDP gain (eq. 21/25)
            ev = evaluate_policy_banded(batch, i, pol)
            np.testing.assert_allclose(float(g), ev.g, rtol=1e-9)
            assert float(h[0]) == 0.0  # gauge pinned


class TestBatchedEvalInfrastructure:
    def _batch(self):
        specs = [
            spec_for(rho=0.3, w2=0.0, s_max=48, b_max=16),
            spec_for(rho=0.6, w2=2.0, s_max=48, b_max=16, family="erlang"),
            spec_for(rho=0.8, w2=5.0, s_max=48, b_max=16),
        ]
        return build_smdp_batched(specs), specs

    def test_policy_transitions_batched_matches_scalar(self):
        batch, specs = self._batch()
        rng = np.random.default_rng(2)
        S = batch.n_states
        pols = []
        for i in range(batch.n_specs):
            s_val = np.minimum(np.arange(S), specs[i].s_max)
            pol = np.where(rng.random(S) < 0.5, 0, rng.integers(1, 17, S))
            pols.append(np.minimum(pol, s_val).astype(np.int64))
        got = batch.policy_transitions_batched(np.stack(pols))
        for i in range(batch.n_specs):
            want = batch.policy_transitions(i, pols[i])
            np.testing.assert_allclose(got[i], want, atol=1e-12)

    def test_stationary_batched_matches_scalar(self):
        batch, specs = self._batch()
        pols = np.stack(
            [greedy_policy(sp.s_max, sp.b_min, sp.b_max) for sp in specs]
        )
        p = batch.policy_transitions_batched(pols)
        mu, ok = stationary_distribution_batched(p)
        assert ok.all()
        from repro.core.evaluate import stationary_distribution

        for i in range(batch.n_specs):
            np.testing.assert_allclose(
                mu[i], stationary_distribution(p[i]), atol=1e-10
            )

    def test_with_c_o_matches_rebuild(self):
        batch, specs = self._batch()
        new_cos = [150.0, 400.0, 212.5]
        patched = batch.with_c_o(new_cos)
        rebuilt = build_smdp_batched(
            [
                dataclasses.replace(sp, c_o=c)
                for sp, c in zip(specs, new_cos)
            ]
        )
        np.testing.assert_allclose(patched.c_hat, rebuilt.c_hat, atol=1e-12)
        finite = rebuilt.feasible
        np.testing.assert_allclose(
            patched.c_tilde[finite], rebuilt.c_tilde[finite], atol=1e-12
        )
        np.testing.assert_allclose(patched.eta, rebuilt.eta, rtol=1e-15)
        assert [sp.c_o for sp in patched.specs] == new_cos


class TestPallasBatchedLoop:
    def test_plain_loop_with_pallas_backup_matches_banded(self):
        base = spec_for(rho=0.5, s_max=48, b_max=16)
        specs = [dataclasses.replace(base, w2=w) for w in (0.0, 2.0)]
        batch = build_smdp_batched(specs)
        banded = relative_value_iteration_batched(batch)
        pallas = relative_value_iteration_batched(batch, backup="pallas")
        assert np.array_equal(banded.policies, pallas.policies)
        np.testing.assert_allclose(banded.g, pallas.g, rtol=1e-6)

    def test_mpi_loop_with_pallas_backup_matches_banded(self):
        base = spec_for(rho=0.7, s_max=48, b_max=16)
        specs = [dataclasses.replace(base, w2=w) for w in (0.0, 2.0)]
        batch = build_smdp_batched(specs)
        banded = relative_value_iteration_batched(batch, accel="mpi")
        pallas = relative_value_iteration_batched(
            batch, accel="mpi", backup="pallas"
        )
        assert np.array_equal(banded.policies, pallas.policies)
        np.testing.assert_allclose(banded.g, pallas.g, rtol=1e-9)
