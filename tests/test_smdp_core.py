"""Unit tests for the SMDP core: construction, solving, paper anchors."""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    GOOGLENET_P4_ENERGY,
    GOOGLENET_P4_LATENCY,
    ConstantProfile,
    ServiceModel,
    SMDPSpec,
    build_smdp,
    evaluate_policy,
    greedy_policy,
    optimal_q_closed_form,
    q_policy,
    relative_value_iteration,
    solve,
    static_policy,
)
from repro.core.policies import is_control_limit


def paper_spec(rho=0.7, w2=1.0, s_max=128, b_max=32, c_o=100.0, family="det"):
    svc = ServiceModel(latency=GOOGLENET_P4_LATENCY, family=family)
    lam = rho * b_max / float(svc.mean(b_max))
    return SMDPSpec(
        lam=lam, service=svc, energy=GOOGLENET_P4_ENERGY,
        b_min=1, b_max=b_max, w1=1.0, w2=w2, s_max=s_max, c_o=c_o,
    )


class TestBuild:
    def test_transition_rows_sum_to_one(self):
        mdp = build_smdp(paper_spec())
        rows = mdp.m_hat[mdp.feasible]
        np.testing.assert_allclose(rows.sum(-1), 1.0, atol=1e-9)
        rows_t = mdp.m_tilde[mdp.feasible]
        np.testing.assert_allclose(rows_t.sum(-1), 1.0, atol=1e-9)

    def test_transitions_nonnegative(self):
        mdp = build_smdp(paper_spec())
        assert (mdp.m_hat >= 0).all()
        assert (mdp.m_tilde >= -1e-12).all()

    def test_feasibility_mask(self):
        spec = paper_spec()
        mdp = build_smdp(spec)
        # a > s is infeasible; wait always feasible
        assert mdp.feasible[:, 0].all()
        for s in range(spec.s_max + 1):
            for a in range(1, spec.b_max + 1):
                assert mdp.feasible[s, a] == (a <= s)
        assert mdp.feasible[-1, :].all()  # S_o counts as s_max >= b_max

    def test_eta_within_puterman_bound(self):
        mdp = build_smdp(paper_spec())
        diag = mdp.m_hat[
            np.arange(mdp.n_states)[:, None],
            np.arange(mdp.n_actions)[None, :],
            np.arange(mdp.n_states)[:, None],
        ]
        ok = (diag < 1.0) & mdp.feasible
        bound = (mdp.y / np.maximum(1.0 - diag, 1e-300))[ok].min()
        assert 0 < mdp.eta < bound

    def test_stability_guard(self):
        svc = ServiceModel(latency=GOOGLENET_P4_LATENCY, family="det")
        lam_unstable = 1.1 * 32 / float(svc.mean(32))
        with pytest.raises(ValueError):
            SMDPSpec(lam=lam_unstable, service=svc, energy=GOOGLENET_P4_ENERGY)

    def test_arrival_pmf_mean_matches_lambda_l(self):
        spec = paper_spec()
        for fam in ("det", "erlang", "expo", "hyperexpo"):
            svc = dataclasses.replace(spec.service, family=fam)
            for b in (1, 8, 32):
                pmf = svc.arrival_pmf(b, spec.lam, 4000)
                mean = (np.arange(4001) * pmf).sum()
                want = spec.lam * float(svc.mean(b))
                np.testing.assert_allclose(mean, want, rtol=1e-6)


class TestRVI:
    def test_dense_banded_pallas_agree(self):
        mdp = build_smdp(paper_spec(rho=0.5, s_max=64))
        rd = relative_value_iteration(mdp, backup="dense")
        rb = relative_value_iteration(mdp, backup="banded")
        rp = relative_value_iteration(mdp, backup="pallas", max_iter=2000)
        assert np.array_equal(rd.policy, rb.policy)
        assert np.array_equal(rd.policy, rp.policy)
        np.testing.assert_allclose(rd.g, rb.g, rtol=1e-8)
        np.testing.assert_allclose(rd.g, rp.g, rtol=1e-5)

    def test_smdp_beats_benchmarks(self):
        for rho in (0.1, 0.3, 0.7):
            for w2 in (0.0, 1.0, 5.0):
                spec = paper_spec(rho=rho, w2=w2)
                mdp = build_smdp(spec)
                res = relative_value_iteration(mdp)
                g_smdp = evaluate_policy(mdp, res.policy).g
                for pol in [
                    greedy_policy(spec.s_max, 1, spec.b_max),
                    static_policy(8, spec.s_max),
                    static_policy(16, spec.s_max),
                    static_policy(32, spec.s_max),
                ]:
                    g_bench = evaluate_policy(mdp, pol).g
                    assert g_smdp <= g_bench + 1e-6, (rho, w2)

    def test_policy_feasible(self):
        mdp = build_smdp(paper_spec())
        res = relative_value_iteration(mdp)
        s_val = np.minimum(np.arange(mdp.n_states), mdp.spec.s_max)
        assert (res.policy <= s_val).all()


class TestPaperAnchors:
    """Quantitative agreement with the paper's own published numbers."""

    def test_table1_static8_anchor(self):
        # Paper Table I (rho=0.7): static-8 -> W=6.85 ms, P=46.27 W
        spec = paper_spec(rho=0.7, w2=1.6)
        mdp = build_smdp(spec)
        ev = evaluate_policy(mdp, static_policy(8, spec.s_max))
        np.testing.assert_allclose(ev.w_bar, 6.85, atol=0.01)
        np.testing.assert_allclose(ev.p_bar, 46.27, atol=0.05)

    def test_table1_smdp_w2_16_anchor(self):
        # Paper Table I: SMDP (w2=1.6) -> P=44.96 W, W=6.90 ms
        spec = paper_spec(rho=0.7, w2=1.6)
        res = solve(spec)
        np.testing.assert_allclose(res.eval.p_bar, 44.96, atol=0.05)
        np.testing.assert_allclose(res.eval.w_bar, 6.90, atol=0.02)

    def test_prop4_closed_form_agreement(self):
        # Cases 2/3 of Fig. 3: exponential size-independent service, Bmax=8
        for l_const in (2.4252, 1.7465):
            svc = ServiceModel(latency=ConstantProfile(l_const), family="expo")
            mu = 1.0 / l_const
            for rho in (0.1, 0.5, 0.9):
                for w2 in (0.0, 1.0):
                    lam = rho * 8 * mu
                    spec = SMDPSpec(
                        lam=lam, service=svc, energy=GOOGLENET_P4_ENERGY,
                        b_min=1, b_max=8, w1=1.0, w2=w2, s_max=100, c_o=100.0,
                    )
                    res = relative_value_iteration(build_smdp(spec))
                    is_cl, q = is_control_limit(res.policy, 100, 8)
                    assert is_cl
                    q_star = optimal_q_closed_form(
                        lam, mu, 8, w1=1.0, w2=w2,
                        zeta0=GOOGLENET_P4_ENERGY.intercept,
                    )
                    assert q == q_star

    def test_abstract_cost_reduces_required_smax(self):
        # Table II trend: with c_o=100 a much smaller s_max is acceptable
        spec_co = paper_spec(rho=0.9, w2=1.0, s_max=70, c_o=100.0)
        res = solve(spec_co, delta=1e-3, max_s_max=70, auto_c_o=False)
        assert res.eval.delta < 1e-3
        spec_0 = paper_spec(rho=0.9, w2=1.0, s_max=70, c_o=0.0)
        res0 = solve(spec_0, delta=None, max_s_max=70, auto_c_o=False)
        # without the abstract cost the same s_max under-serves: the policy
        # waits too long and the tail mass is *not* negligible
        assert res0.eval.g < res.eval.g or res0.eval.delta > res.eval.delta


class TestPolicies:
    def test_greedy_feasible_at_zero(self):
        pol = greedy_policy(32, 4, 16)
        assert pol[0] == 0 and pol[3] == 0 and pol[4] == 4

    def test_q_policy_structure_detection(self):
        pol = q_policy(5, 64, 32)
        is_cl, q = is_control_limit(pol, 64, 32)
        assert is_cl and q == 5
        pol[10] = 0  # break the structure
        is_cl, _ = is_control_limit(pol, 64, 32)
        assert not is_cl


class TestFiniteBuffer:
    """Finite waiting room B == s_max: exact fold, no abstract tail."""

    def _finite_spec(self, rho=0.7, b_max=16, B=48, c_drop=0.0, w2=1.0):
        svc = ServiceModel(latency=GOOGLENET_P4_LATENCY, family="det")
        lam = rho * b_max / float(svc.mean(b_max))
        return SMDPSpec(
            lam=lam, service=svc, energy=GOOGLENET_P4_ENERGY,
            b_min=1, b_max=b_max, w1=1.0, w2=w2, s_max=B,
            buffer=B, c_drop=c_drop,
        )

    def test_spec_validation(self):
        with pytest.raises(ValueError, match="buffer == s_max"):
            dataclasses.replace(self._finite_spec(), buffer=47)
        with pytest.raises(ValueError, match="c_drop"):
            self._finite_spec(c_drop=-1.0)
        # overload is a valid finite-buffer regime (shedding absorbs it) ...
        self._finite_spec(rho=1.3)
        # ... but stays rejected for the tail-abstracted chain
        with pytest.raises(ValueError, match="instability"):
            paper_spec(rho=1.3)

    def test_mixed_batch_keeps_tail_specs_byte_identical(self):
        from repro.core import build_smdp_batched

        tail = paper_spec(rho=0.7, s_max=48, b_max=16)
        fin = self._finite_spec(c_drop=25.0)
        alone = build_smdp_batched([tail])
        mixed = build_smdp_batched([tail, fin])
        for field in ("c_hat", "c_hold", "c_energy", "c_tilde", "y"):
            a = getattr(alone, field)[0]
            b = getattr(mixed, field)[0]
            assert np.array_equal(a, b, equal_nan=True), field
        np.testing.assert_array_equal(alone.eta[0], mixed.eta[0])

    def test_s_o_is_exact_alias_of_B(self):
        mdp = build_smdp(self._finite_spec(c_drop=25.0))
        B = mdp.spec.s_max
        np.testing.assert_array_equal(mdp.c_hat[mdp.s_o], mdp.c_hat[B])
        np.testing.assert_array_equal(mdp.c_hold[mdp.s_o], mdp.c_hold[B])
        # transition rows of the alias serve from base B as well
        np.testing.assert_allclose(
            mdp.m_hat[mdp.s_o, 1:], mdp.m_hat[B, 1:], atol=1e-12
        )

    def test_capped_holding_never_exceeds_unbounded(self):
        fin = self._finite_spec(c_drop=0.0)
        tail = dataclasses.replace(fin, buffer=None, c_drop=0.0)
        m_f = build_smdp(fin)
        m_t = build_smdp(tail)
        B = fin.s_max
        serve = m_f.feasible[:B + 1, 1:]
        assert (
            m_f.c_hold[:B + 1, 1:][serve] <= m_t.c_hold[:B + 1, 1:][serve] + 1e-12
        ).all()
        # the cap binds hardest near the full buffer
        assert m_f.c_hold[B, 1] < m_t.c_hold[B, 1]

    def test_zero_drop_light_load_matches_tail_policy(self):
        # with c_drop = 0 and light load the buffer is effectively
        # invisible below the truncation region: the policies agree on
        # the occupied band
        fin = solve(self._finite_spec(rho=0.5, B=64, c_drop=0.0))
        tail = solve(paper_spec(rho=0.5, s_max=64, b_max=16), delta=None,
                     auto_c_o=False)
        np.testing.assert_array_equal(
            fin.action_table(upto=32), tail.action_table(upto=32)
        )

    def test_drop_cost_serves_earlier_under_overload(self):
        blind = solve(self._finite_spec(rho=1.2, c_drop=0.0))
        aware = solve(self._finite_spec(rho=1.2, c_drop=50.0))

        def serve_from(res):
            tab = res.action_table()
            hits = np.nonzero(tab > 0)[0]
            return int(hits[0]) if hits.size else np.inf

        # free drops under overload: shedding absorbs the excess, serving
        # only burns energy, so the blind policy parks much longer (or
        # forever); pricing drops pulls the serve threshold down
        assert serve_from(aware) < serve_from(blind), (
            serve_from(aware), serve_from(blind),
        )

    def test_sweep_rejects_mixed_flavours(self):
        from repro.core import sweep_solve

        with pytest.raises(ValueError, match="mix"):
            sweep_solve([paper_spec(s_max=48, b_max=16),
                         self._finite_spec()])

    def test_modulated_build_rejects_finite_buffer(self):
        from repro.core.smdp import PhaseConfig, build_smdp_modulated

        ph = PhaseConfig(rates=(0.5, 1.5), gen=((-0.1, 0.1), (0.2, -0.2)))
        sp = self._finite_spec()
        lam = float(np.dot(
            [2 / 3, 1 / 3], ph.rates
        ))
        sp = dataclasses.replace(sp, lam=sp.lam)
        with pytest.raises(NotImplementedError, match="Poisson-only"):
            build_smdp_modulated(sp, ph)
