"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.bellman import bellman_banded, bellman_banded_batched
from repro.kernels.flash_attention import flash_attention as flash_pallas


KEY = jax.random.PRNGKey(0)


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


class TestBellmanKernel:
    @pytest.mark.parametrize("T,A,K", [(64, 9, 40), (200, 33, 170), (128, 33, 128), (300, 17, 513)])
    def test_matches_ref(self, T, A, K):
        ks = jax.random.split(jax.random.fold_in(KEY, T * A), 3)
        h_main = jax.random.normal(ks[0], (T + K,)) * 10
        pmfs = jax.nn.softmax(jax.random.normal(ks[1], (A, K)), axis=-1)
        tails = jax.random.uniform(ks[2], (T, A))
        got = bellman_banded(h_main, pmfs, tails, 2.5)
        want = ref.bellman_banded_ref(h_main, pmfs, tails, 2.5)
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-5)

    @pytest.mark.parametrize("N,T,A,K", [(1, 64, 9, 40), (3, 130, 33, 130), (4, 128, 17, 260)])
    def test_batched_matches_ref(self, N, T, A, K):
        ks = jax.random.split(jax.random.fold_in(KEY, N * T + K), 4)
        h = jax.random.normal(ks[0], (N, T + K)) * 10
        pmfs = jax.nn.softmax(jax.random.normal(ks[1], (N, A, K)), axis=-1)
        tails = jax.random.uniform(ks[2], (N, T, A))
        hso = jax.random.normal(ks[3], (N,)) * 3
        got = bellman_banded_batched(h, pmfs, tails, hso)
        assert got.shape == (N, T, A)
        for n in range(N):
            want = ref.bellman_banded_ref(h[n], pmfs[n], tails[n], hso[n])
            np.testing.assert_allclose(got[n], want, atol=1e-4, rtol=1e-5)
            scalar = bellman_banded(h[n], pmfs[n], tails[n], hso[n])
            np.testing.assert_allclose(got[n], scalar, atol=1e-5, rtol=1e-6)

    def test_rvi_with_pallas_backup_matches_banded(self):
        from repro.core import (GOOGLENET_P4_ENERGY, GOOGLENET_P4_LATENCY,
                                ServiceModel, SMDPSpec, build_smdp,
                                relative_value_iteration)
        svc = ServiceModel(latency=GOOGLENET_P4_LATENCY, family="det")
        lam = 0.3 * 32 / float(svc.mean(32))
        spec = SMDPSpec(lam=lam, service=svc, energy=GOOGLENET_P4_ENERGY,
                        b_max=32, s_max=48, w2=1.0)
        mdp = build_smdp(spec)
        rb = relative_value_iteration(mdp, backup="banded")
        rp = relative_value_iteration(mdp, backup="pallas", max_iter=2000)
        assert np.array_equal(rb.policy, rp.policy)


class TestFlashKernel:
    @pytest.mark.parametrize("B,Sq,Sk,H,KV,D,causal,cap", [
        (2, 64, 64, 4, 2, 16, True, None),
        (1, 33, 70, 4, 4, 8, False, None),
        (2, 128, 128, 8, 2, 32, True, 50.0),
        (1, 17, 128, 2, 1, 64, True, None),
    ])
    def test_matches_ref(self, B, Sq, Sk, H, KV, D, causal, cap):
        ks = jax.random.split(jax.random.fold_in(KEY, Sq * Sk + H), 3)
        q = jax.random.normal(ks[0], (B, Sq, H, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, Sk, KV, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, Sk, KV, D), jnp.float32)
        got = flash_pallas(q, k, v, causal=causal, softcap=cap, block_q=32, block_k=32)
        want = ref.attention_ref(q, k, v, causal=causal, softcap=cap)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (2, 64, 4, 32), dtype)
        k = jax.random.normal(ks[1], (2, 64, 2, 32), dtype)
        v = jax.random.normal(ks[2], (2, 64, 2, 32), dtype)
        got = ops.flash_attention(q, k, v, block_q=32, block_k=32)
        want = ref.attention_ref(q, k, v)
        assert got.dtype == dtype
        np.testing.assert_allclose(
            got.astype(jnp.float32), want.astype(jnp.float32), **tol(dtype)
        )

    def test_matches_model_blockwise_attention(self):
        """Kernel agrees with the jnp blockwise attention used by the models."""
        from repro.models import layers as L
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (2, 96, 8, 32), jnp.float32)
        k = jax.random.normal(ks[1], (2, 96, 4, 32), jnp.float32)
        v = jax.random.normal(ks[2], (2, 96, 4, 32), jnp.float32)
        got = flash_pallas(q, k, v, causal=True, block_q=32, block_k=32)
        want = L.flash_attention(q, k, v, causal=True, chunk_kv=32, chunk_q=32)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


class TestDecodeKernel:
    @pytest.mark.parametrize("B,S,H,KV,D", [
        (2, 300, 8, 2, 16), (3, 128, 4, 4, 32), (1, 77, 8, 1, 64), (4, 64, 16, 4, 8),
    ])
    def test_matches_ref(self, B, S, H, KV, D):
        ks = jax.random.split(jax.random.fold_in(KEY, B * S), 4)
        q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
        kc = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
        vc = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)
        lens = jax.random.randint(ks[3], (B,), 1, S + 1)
        got = ops.decode_attention(q, kc, vc, lens, block_k=64)
        want = ref.decode_attention_ref(q, kc, vc, lens)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        ks = jax.random.split(KEY, 4)
        q = jax.random.normal(ks[0], (2, 8, 32), dtype)
        kc = jax.random.normal(ks[1], (2, 160, 2, 32), dtype)
        vc = jax.random.normal(ks[2], (2, 160, 2, 32), dtype)
        lens = jnp.asarray([100, 160], jnp.int32)
        got = ops.decode_attention(q, kc, vc, lens, block_k=64)
        want = ref.decode_attention_ref(q, kc, vc, lens)
        np.testing.assert_allclose(
            got.astype(jnp.float32), want.astype(jnp.float32), **tol(dtype)
        )
