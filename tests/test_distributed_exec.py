"""Multi-device execution tests (subprocess: fresh jax with 8 host devices).

Verifies the shard_map flash-decode (§Perf B1) is EXACT against the plain
single-device decode path, including gemma2 sliding-window and llama4
chunked masks.
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent

# propagate platform selection (e.g. JAX_PLATFORMS=cpu): without it the
# fresh jax probes for accelerators and can hang in sandboxes
_JAX_ENV = {k: v for k, v in os.environ.items() if k.startswith("JAX_")}

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, jax, jax.numpy as jnp
from repro.configs import ARCHS
from repro.distributed import meshcompat
from repro.models import model as M

mesh = meshcompat.make_mesh((2, 4), ("data", "model"))
worst = 0.0
for name in ["qwen2.5-32b", "gemma2-9b"]:
    cfg = ARCHS[name].reduced()
    cfg = dataclasses.replace(
        cfg, sliding_window=16 if cfg.sliding_window else None)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S, MAXLEN = 4, 31, 64
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    _, cache = M.prefill(cfg, params, {"tokens": toks}, max_len=MAXLEN,
                         cache_dtype=jnp.float32)
    nxt = toks[:, :1]
    base_cfg = dataclasses.replace(cfg, sharded_decode_attn=False)
    logits_plain, _ = M.decode_step(base_cfg, params, cache, nxt)
    with meshcompat.set_mesh(mesh):
        logits_shard, _ = jax.jit(
            lambda p, c, t: M.decode_step(cfg, p, c, t))(params, cache, nxt)
    worst = max(worst, float(jnp.max(jnp.abs(logits_plain - logits_shard))))
assert worst < 1e-3, worst
print(f"OK worst={worst:.2e}")
"""


@pytest.mark.slow
def test_sharded_flash_decode_exact():
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        cwd=ROOT,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root", **_JAX_ENV},
        capture_output=True,
        text=True,
        timeout=500,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
