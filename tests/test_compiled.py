"""Compiled serving backend: decision-for-decision equivalence with the
Python engine per arrival mode, sketch-quantile tolerance, the vmapped
seeds x tables grid, bank stacking, and the service-profile bank axis."""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    GOOGLENET_P4_ENERGY,
    GOOGLENET_P4_LATENCY,
    AffineProfile,
    ServiceModel,
)
from repro.core.policies import greedy_policy, q_policy, static_policy
from repro.serving import (
    GreedyScheduler,
    QPolicyScheduler,
    ServingEngine,
    SMDPScheduler,
    StaticScheduler,
    as_action_table,
    histogram_quantiles,
    pad_arrivals,
    pad_arrivals_batch,
    run_grid,
    simulate_compiled,
    verify_backends,
)
from repro.serving.arrivals import (
    MMPP2,
    mmpp2_times_jax,
    poisson_times_jax,
)

SVC = ServiceModel(latency=GOOGLENET_P4_LATENCY, family="det")
BMAX = 32
LAM = 0.7 * BMAX / float(SVC.mean(BMAX))
ENERGY = np.array(
    [0.0] + [float(GOOGLENET_P4_ENERGY(b)) for b in range(1, BMAX + 1)]
)
TABLE = q_policy(8, 128, BMAX)


def _trace(mode: str, n: int = 2500, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if mode == "poisson":
        return np.cumsum(rng.exponential(1.0 / LAM, n))
    if mode == "mmpp2":
        m = MMPP2(lam1=0.3 * LAM, lam2=1.3 * LAM, dwell1=60.0, dwell2=30.0)
        times, _ = m.sample_arrivals(n / m.mean_rate, rng)
        return times
    # deterministic trace with bursts and gaps (exercises waits + drain)
    gaps = np.tile([0.1, 0.1, 0.1, 5.0, 0.5], n // 5)
    return np.cumsum(gaps)


class TestBackendEquivalence:
    """ISSUE acceptance: identical schedules + latencies on shared traces."""

    @pytest.mark.parametrize("mode", ["poisson", "mmpp2", "trace"])
    def test_decisions_and_latencies_identical(self, mode):
        out = verify_backends(
            TABLE, _trace(mode), service=SVC, energy_table=ENERGY,
            b_max=BMAX,
        )
        assert out["n_decisions"] > 0
        assert out["max_latency_err"] <= 1e-9

    @pytest.mark.parametrize("mode", ["poisson", "mmpp2", "trace"])
    def test_epoch_bounded_and_horizon_runs(self, mode):
        tr = _trace(mode)
        verify_backends(
            TABLE, tr, service=SVC, energy_table=ENERGY, b_max=BMAX,
            n_epochs=700,
        )
        verify_backends(
            TABLE, tr, service=SVC, energy_table=ENERGY, b_max=BMAX,
            horizon=float(tr[len(tr) // 2]), n_epochs=None,
        )

    @pytest.mark.parametrize("family", ["expo", "erlang", "hyperexpo"])
    def test_stochastic_service_shared_draws(self, family):
        """A shared unit-draw sequence aligns both backends for every
        service family (each is a scale mixture around the batch mean)."""
        svc = ServiceModel(latency=GOOGLENET_P4_LATENCY, family=family)
        verify_backends(
            TABLE, _trace("poisson", 1500), service=svc,
            energy_table=ENERGY, b_max=BMAX,
        )

    def test_slo_miss_accounting_identical(self):
        out = verify_backends(
            TABLE, _trace("poisson", 1500), service=SVC,
            energy_table=ENERGY, b_max=BMAX, slo=8.0,
        )
        assert out["python"].n_slo_miss == out["compiled"].n_slo_miss > 0

    def test_drain_capped_at_b_max(self):
        """Tail drain serves in b_max-capped batches, like the Python
        kernel (never one mega-batch)."""
        never = np.zeros(130, dtype=np.int64)  # always wait -> forced drain
        res = simulate_compiled(
            never, np.full(20, 0.5),
            means=np.array([0.0] + [float(SVC.mean(b)) for b in range(1, 5)]),
            b_max=4, record=True,
        )
        assert res.n_served == 20
        assert res.batch_sizes.max() <= 4
        assert len(res.batch_sizes) == 5


class TestEngineBackendParity:
    def _engine(self, **kw):
        return ServingEngine(
            SMDPScheduler.from_table(TABLE), b_max=BMAX, service=SVC,
            energy_table=ENERGY, seed=11, **kw,
        )

    def test_poisson_det_is_draw_for_draw(self):
        """Deterministic service consumes no service randomness, so the
        eagerly pre-generated arrival stream is the exact lazy stream:
        both backends reproduce each other at equal seeds."""
        r_py = self._engine(lam=LAM).run(1500)
        r_c = self._engine(lam=LAM).run(1500, backend="compiled")
        np.testing.assert_array_equal(r_py.batch_sizes, r_c.batch_sizes)
        np.testing.assert_allclose(r_py.latencies, r_c.latencies, atol=1e-9)
        np.testing.assert_allclose(r_py.energy, r_c.energy)
        np.testing.assert_allclose(r_py.span, r_c.span)

    def test_run_after_compiled_continues_the_stream(self):
        """Over-drawn arrivals are buffered: a python run after a compiled
        run sees the same stream as two python runs."""
        e1, e2 = self._engine(lam=LAM), self._engine(lam=LAM)
        e1.run(800)
        ref = e1.run(800)
        e2.run(800, backend="compiled")
        cont = e2.run(800)
        np.testing.assert_array_equal(ref.batch_sizes, cont.batch_sizes)
        np.testing.assert_allclose(ref.latencies, cont.latencies, atol=1e-9)

    def test_no_serve_compiled_run_preserves_queue_rids(self):
        """A compiled run that serves nothing must not re-mint rids for
        requests admitted before it (state-sync regression)."""
        e_ref = self._engine(lam=LAM)
        e_cmp = self._engine(lam=LAM)
        e_ref.run(40)
        e_cmp.run(40)  # identical prefix: some requests now queued
        assert [r.rid for r in e_cmp.queue]
        never = SMDPScheduler.from_table(np.zeros(130, dtype=np.int64))
        e_ref.scheduler = never
        e_cmp.scheduler = never
        e_ref.run(5)
        e_cmp.run(5, backend="compiled")  # wait-only: serves nothing
        assert [r.rid for r in e_cmp.queue] == [r.rid for r in e_ref.queue]
        # continuation still numbers future admissions identically (the
        # python loop pre-assigns its peeked request's rid, the compiled
        # path assigns on the later peek — same sequence either way)
        e_ref.run(20)
        e_cmp.run(20)
        assert [r.rid for r in e_cmp.queue] == [r.rid for r in e_ref.queue]
        assert e_cmp.next_rid == e_ref.next_rid

    def test_adaptive_scheduler_compiled_matches_python(self):
        """AdaptiveController now lowers to the in-carry adaptive lane:
        the compiled backend reproduces the Python engine decision-for-
        decision (it used to be rejected with a TypeError)."""
        from repro.serving import AdaptiveController, SMDPSchedulerBank

        bank = SMDPSchedulerBank(
            {(LAM,): TABLE, (2 * LAM,): static_policy(8, 128)},
            key_names=("lam",),
        )

        def mk():
            return ServingEngine(
                AdaptiveController(bank, ewma=0.2, margin=0.1, min_dwell=5.0),
                lam=LAM, b_max=BMAX, service=SVC, energy_table=ENERGY,
                seed=11,
            )

        e_py, e_c = mk(), mk()
        r_py = e_py.run(1200)
        r_c = e_c.run(1200, backend="compiled")
        np.testing.assert_array_equal(r_py.batch_sizes, r_c.batch_sizes)
        np.testing.assert_allclose(r_py.latencies, r_c.latencies, atol=1e-9)
        np.testing.assert_allclose(r_py.energy, r_c.energy)
        # post-run controller state is synced from the kernel carry
        assert e_c.scheduler.key == e_py.scheduler.key
        assert e_c.scheduler.n_switches == e_py.scheduler.n_switches
        np.testing.assert_allclose(
            e_c.scheduler.estimator.rate, e_py.scheduler.estimator.rate,
            rtol=1e-12,
        )

    def test_window_estimator_stays_python_only(self):
        """Window-mode estimators have no O(1) scan carry: the compiled
        lowering refuses them loudly."""
        from repro.serving import AdaptiveController, SMDPSchedulerBank
        from repro.serving.metrics import RateEstimator

        bank = SMDPSchedulerBank(
            {(LAM,): TABLE, (2 * LAM,): static_policy(8, 128)},
            key_names=("lam",),
        )
        eng = ServingEngine(
            AdaptiveController(bank, estimator=RateEstimator(window=16)),
            lam=LAM, b_max=BMAX, service=SVC, energy_table=ENERGY,
        )
        with pytest.raises(TypeError, match="EWMA"):
            eng.run(100, backend="compiled")

    def test_sketch_metrics_in_report(self):
        rep = self._engine(lam=LAM).run(4000, backend="compiled")
        assert set(rep.metrics) >= {"W_mean", "P50", "P95", "P99", "power"}
        np.testing.assert_allclose(
            rep.metrics["W_mean"], rep.latencies.mean(), rtol=1e-9
        )
        np.testing.assert_allclose(
            rep.metrics["P95"], np.percentile(rep.latencies, 95), rtol=0.05
        )


class TestQuantileSketch:
    """ISSUE acceptance: sketch vs np.percentile tolerance band."""

    @pytest.mark.parametrize("dist", ["expo", "lognormal", "uniform"])
    @pytest.mark.parametrize("q", [0.5, 0.95, 0.99])
    def test_histogram_quantiles_tolerance(self, dist, q):
        import zlib

        rng = np.random.default_rng(zlib.crc32(f"{dist}:{q}".encode()))
        n = 40_000
        data = {
            "expo": lambda: rng.exponential(5.0, n),
            "lognormal": lambda: rng.lognormal(1.0, 0.7, n),
            "uniform": lambda: rng.uniform(1.0, 30.0, n),
        }[dist]()
        edges = np.geomspace(data.min() * 0.5, data.max() * 2.0, 257)
        counts = np.zeros(258)
        idx = np.clip(np.searchsorted(edges, data, side="right"), 0, 257)
        np.add.at(counts, idx, 1)
        got = histogram_quantiles(counts, edges, q)[0]
        true = np.percentile(data, q * 100)
        assert abs(got - true) / true < 0.05, (got, true)

    def test_engine_sketch_matches_exact_percentiles(self):
        tr = _trace("poisson", 4000)
        rep = ServingEngine(
            SMDPScheduler.from_table(TABLE), arrivals=tr, b_max=BMAX,
            service=SVC, energy_table=ENERGY,
        ).run(n_epochs=None, backend="compiled")
        for q, key in ((50, "P50"), (95, "P95"), (99, "P99")):
            true = np.percentile(rep.latencies, q)
            assert abs(rep.metrics[key] - true) / true < 0.05

    def test_under_and_overflow_clamp_to_edges(self):
        edges = np.geomspace(1.0, 10.0, 11)
        counts = np.zeros(12)
        counts[0] = 100  # all mass below edges[0]
        assert histogram_quantiles(counts, edges, [0.5])[0] == edges[0]
        counts = np.zeros(12)
        counts[-1] = 100
        assert histogram_quantiles(counts, edges, [0.5])[0] == edges[-1]


class TestSheddingBackendEquivalence:
    """Managed-queue lane certification: buffer= / shed_expired= on the
    compiled backend reproduce the Python loop's refusals, expiry sweeps,
    decisions and latencies exactly, per arrival mode."""

    @staticmethod
    def _otrace(mode: str, n: int = 1500) -> np.ndarray:
        # the plain fixture runs at 0.7x capacity; compress gaps so the
        # waiting room actually fills and deadlines actually lapse
        return _trace(mode, n) * 0.55

    @pytest.mark.parametrize("mode", ["poisson", "mmpp2", "trace"])
    def test_buffer_refusals_identical(self, mode):
        # the deterministic trace arrives in 3-bursts: only a shallow
        # room ever refuses there
        out = verify_backends(
            TABLE, self._otrace(mode), service=SVC, energy_table=ENERGY,
            b_max=BMAX, buffer=2 if mode == "trace" else 10,
        )
        assert out["python"].n_shed > 0
        assert out["python"].n_shed == out["compiled"].n_shed

    @pytest.mark.parametrize("mode", ["poisson", "mmpp2", "trace"])
    def test_expiry_sweeps_identical(self, mode):
        out = verify_backends(
            TABLE, self._otrace(mode), service=SVC, energy_table=ENERGY,
            b_max=BMAX, slo=4.0, shed_expired=True,
        )
        assert out["python"].n_expired > 0
        assert out["python"].n_expired == out["compiled"].n_expired

    @pytest.mark.parametrize("mode", ["poisson", "mmpp2", "trace"])
    def test_buffer_and_expiry_together(self, mode):
        verify_backends(
            TABLE, self._otrace(mode), service=SVC, energy_table=ENERGY,
            b_max=BMAX, buffer=14, slo=5.0, shed_expired=True,
        )

    def test_stochastic_service_with_shedding(self):
        svc = ServiceModel(latency=GOOGLENET_P4_LATENCY, family="expo")
        verify_backends(
            TABLE, self._otrace("poisson"), service=svc,
            energy_table=ENERGY, b_max=BMAX, buffer=12, slo=5.0,
            shed_expired=True,
        )

    def test_epoch_budget_and_horizon_with_shedding(self):
        tr = self._otrace("poisson")
        verify_backends(
            TABLE, tr, service=SVC, b_max=BMAX, n_epochs=250, buffer=10,
            slo=4.0, shed_expired=True,
        )
        verify_backends(
            TABLE, tr, service=SVC, b_max=BMAX,
            horizon=float(tr[len(tr) // 2]), buffer=10, slo=4.0,
            shed_expired=True,
        )

    def test_phase_stack_with_shedding(self):
        tr = self._otrace("poisson")
        tabs = np.stack([q_policy(4, 128, BMAX), q_policy(12, 128, BMAX)])
        ph = (np.arange(len(tr)) // 150) % 2
        verify_backends(
            tabs, tr, service=SVC, b_max=BMAX, phases=ph, buffer=16,
            slo=5.0, shed_expired=True,
        )

    def test_buffer_zero_starves_both_backends(self):
        out = verify_backends(
            TABLE, self._otrace("poisson", 400), service=SVC, b_max=BMAX,
            buffer=0,
        )
        assert out["python"].n_served == out["compiled"].n_served == 0
        assert out["compiled"].n_shed == 400

    def test_surviving_queue_accounting(self):
        """queue_slots + counters partition every door-seen arrival."""
        tr = self._otrace("poisson", 600)
        res = simulate_compiled(
            q_policy(20, 128, BMAX), tr,
            means=np.array(
                [0.0] + [float(SVC.mean(b)) for b in range(1, BMAX + 1)]
            ),
            b_max=BMAX, buffer=40, deadlines=tr + 25.0, shed_expired=True,
            drain=False, record=True,
        )
        assert res.queue_slots is not None
        assert (
            res.n_served + res.n_expired + len(res.queue_slots)
            == res.n_admitted - res.n_shed
        )

    def test_non_monotone_deadlines_rejected(self):
        tr = np.arange(1.0, 9.0)
        dl = tr + np.array([20.0, 16.0, 12.0, 8.0, 4.0, 2.0, 1.0, 0.5])
        with pytest.raises(ValueError, match="nondecreasing"):
            simulate_compiled(
                TABLE, tr, means=np.array([0.0, 1.0]), b_max=1,
                deadlines=dl, shed_expired=True,
            )

    def test_belief_mode_with_buffer_rejected(self):
        with pytest.raises(ValueError, match="belief"):
            simulate_compiled(
                np.stack([TABLE, TABLE]), np.arange(1.0, 5.0),
                means=np.array([0.0, 1.0]), b_max=1, buffer=4,
                phase_mode="belief_mix",
                beliefs=np.full((4, 2), 0.5),
            )


class TestGridRunner:
    def test_grid_matches_python_engines(self):
        """One vmapped dispatch == the seeds x tables python loop."""
        traces = [_trace("poisson", 1200, seed=s) for s in (1, 2)]
        arrs = pad_arrivals_batch(traces)
        tabs = np.stack(
            [q_policy(8, 128, BMAX), static_policy(8, 128),
             greedy_policy(128, 1, BMAX)]
        )
        means = np.array(
            [0.0] + [float(SVC.mean(b)) for b in range(1, BMAX + 1)]
        )
        g = run_grid(tabs, arrs, means=means, zeta=ENERGY, b_max=BMAX)
        assert g["w_mean"].shape == (2, 3)
        for s, tr in enumerate(traces):
            for p in range(3):
                rep = ServingEngine(
                    SMDPScheduler.from_table(tabs[p]), arrivals=tr,
                    b_max=BMAX, service=SVC, energy_table=ENERGY,
                ).run(n_epochs=None)
                np.testing.assert_allclose(
                    g["w_mean"][s, p], rep.latencies.mean(), atol=1e-9
                )
                np.testing.assert_allclose(
                    g["energy"][s, p], rep.energy, atol=1e-9
                )
                assert g["n_served"][s, p] == rep.n_served

    def test_grid_power_nan_without_energy_source(self):
        """run_grid follows the engine's have_energy convention: no zeta
        source (or no served batch) reports NaN power, never 0."""
        arrs = np.stack([pad_arrivals(_trace("poisson", 300))[0]])
        tabs = np.stack([TABLE])
        means = np.array(
            [0.0] + [float(SVC.mean(b)) for b in range(1, BMAX + 1)]
        )
        g = run_grid(tabs, arrs, means=means, b_max=BMAX)
        assert np.isnan(g["power"]).all()
        g = run_grid(tabs, arrs, means=means, zeta=ENERGY, b_max=BMAX)
        assert np.isfinite(g["power"]).all() and (g["power"] > 0).all()

    def test_step_escalation_completes_short_initial_guess(self):
        """A lane needing more steps than the initial bucket re-dispatches
        doubled and still finishes (serve-one-at-a-time: epochs ~ 2n)."""
        from repro.serving import compiled as C

        C._NSTEPS_CACHE.clear()
        tab = q_policy(1, 128, 1)  # b_max=1: one serve per arrival
        tr = _trace("poisson", 900)
        res = simulate_compiled(
            tab, tr, means=np.array([0.0, float(SVC.mean(1))]), b_max=1,
            record=True,
        )
        assert res.n_served == 900
        assert res.terminated
        assert len(res.batch_sizes) == 900  # b_max=1: one serve per request


class TestSchedulerLowering:
    @pytest.mark.parametrize(
        "sched",
        [
            StaticScheduler(8),
            GreedyScheduler(2, BMAX),
            QPolicyScheduler(12, BMAX),
            SMDPScheduler.from_table(TABLE),
        ],
        ids=lambda s: s.name,
    )
    def test_as_action_table_matches_decide(self, sched):
        table = as_action_table(sched, BMAX)
        for q in list(range(0, 64)) + [200, 10**6]:
            a_tab = int(table[min(q, len(table) - 1)])
            a_tab = max(0, min(a_tab, q, BMAX))
            a_dec = max(0, min(sched.decide(q), q, BMAX))
            assert a_tab == a_dec, (sched.name, q)

    def test_bank_stacked_pads_with_last_entry(self):
        from repro.serving import SMDPSchedulerBank

        bank = SMDPSchedulerBank(
            {(1.0,): np.array([0, 1, 2]), (2.0,): np.array([0, 1, 2, 3, 4])},
            key_names=("lam",),
        )
        keys, stacked = bank.stacked()
        assert stacked.shape == (2, 5)
        np.testing.assert_array_equal(stacked[0], [0, 1, 2, 2, 2])
        np.testing.assert_array_equal(stacked[1], [0, 1, 2, 3, 4])
        # padded row decides identically to the original table (eq. 30)
        sch = SMDPScheduler.from_table(np.array([0, 1, 2]))
        for q in range(8):
            assert int(stacked[0][min(q, 4)]) == sch.decide(q)


class TestProfileAxis:
    """ROADMAP open item: service-profile id wired into bank + serving."""

    def _bank(self):
        from repro.core.sweep import sweep_bank
        from repro.configs.googlenet_p4 import paper_spec

        base = paper_spec(rho=0.4, w2=1.0, s_max=48)
        base = dataclasses.replace(
            base, b_max=8, lam=0.4 * 8 / float(base.service.mean(8))
        )
        fast = ServiceModel(
            latency=AffineProfile(slope=0.1, intercept=0.6), family="det"
        )
        profiles = {
            0: {},
            1: {"service": fast,
                "energy": AffineProfile(slope=10.0, intercept=8.0)},
        }
        return sweep_bank(base, [0.5 * base.lam, base.lam],
                          profiles=profiles), base

    def test_profile_keyed_bank_and_lookup(self):
        bank, base = self._bank()
        assert bank.key_names == ("lam", "w2", "profile")
        assert len(bank) == 4
        t0 = bank.scheduler(lam=base.lam, w2=1.0, profile=0.0).table
        t1 = bank.scheduler(lam=base.lam, w2=1.0, profile=1.0).table
        assert not np.array_equal(t0, t1)

    def test_adaptive_controller_pins_profile(self):
        from repro.serving import AdaptiveController

        bank, base = self._bank()
        ctrl = AdaptiveController(bank, w2=1.0, profile=1.0, ewma=0.5)
        assert ctrl.key[2] == 1.0
        # drive the estimator across the rate regimes: the retuned key
        # moves along lam but stays inside the pinned profile slice
        t = 0.0
        for gap in [2.0] * 50 + [0.1] * 200:
            t += gap
            ctrl.observe_arrival(t)
        assert ctrl.key[2] == 1.0
        eng = ServingEngine(
            ctrl, lam=base.lam, b_max=8,
            service=base.service, energy_table=np.zeros(9),
        )
        rep = eng.run(300)
        assert rep.n_served > 0


class TestJaxSamplers:
    def test_poisson_times_statistics(self):
        import jax

        t = np.asarray(poisson_times_jax(jax.random.PRNGKey(0), 2.0, 20000))
        assert np.all(np.diff(t) > 0)
        assert abs(len(t) / t[-1] - 2.0) / 2.0 < 0.05

    def test_mmpp2_times_sorted_and_rate(self):
        import jax

        m = MMPP2(lam1=1.0, lam2=5.0, dwell1=50.0, dwell2=50.0)
        times, mask = mmpp2_times_jax(jax.random.PRNGKey(1), m, 30000)
        times, mask = np.asarray(times), np.asarray(mask)
        n = int(mask.sum())
        assert np.all(np.isinf(times[n:]))
        assert np.all(np.diff(times[:n]) >= 0)
        rate = n / times[n - 1]
        assert abs(rate - m.mean_rate) / m.mean_rate < 0.1

    def test_mmpp2_feeds_compiled_kernel(self):
        """jax-sampled MMPP2 arrivals drop straight into the scan kernel."""
        import jax

        m = MMPP2(lam1=0.3 * LAM, lam2=1.3 * LAM, dwell1=60.0, dwell2=30.0)
        times, mask = mmpp2_times_jax(jax.random.PRNGKey(2), m, 4096)
        means = np.array(
            [0.0] + [float(SVC.mean(b)) for b in range(1, BMAX + 1)]
        )
        res = simulate_compiled(
            TABLE, np.asarray(times), means=means, zeta=ENERGY, b_max=BMAX
        )
        assert res.n_served == int(np.asarray(mask).sum())
        assert res.terminated
