"""TRAINING-loop fault tolerance: straggler watchdog, preemption, elastic
restore of the Trainer.  Serving-side fault injection (replica outages,
crash/requeue/drop, failover routing, overload shedding) lives in
test_faults_serving.py against serving.faults / serving.fleet."""
import time

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.training.data import DataConfig
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import Trainer, TrainerConfig

CFG = ARCHS["rwkv6-3b"].reduced()
DATA = DataConfig(vocab_size=CFG.vocab_size, seq_len=16, global_batch=2, seed=5)


class TestWatchdog:
    def test_straggler_flagged(self, tmp_path):
        logs = []
        t = Trainer(
            CFG, DATA, AdamWConfig(lr=1e-3),
            TrainerConfig(steps=1, ckpt_dir=str(tmp_path), straggler_window=16,
                          straggler_zscore=3.0),
            log_fn=logs.append,
        )
        # feed a synthetic step-time series with one straggler
        for _ in range(15):
            t._watch_straggler(0.100 + np.random.default_rng(0).normal() * 1e-4, 0)
        t._watch_straggler(0.500, 16)  # 5x slower
        assert any("straggler" in m for m in logs), logs

    def test_normal_steps_not_flagged(self, tmp_path):
        logs = []
        t = Trainer(
            CFG, DATA, AdamWConfig(lr=1e-3),
            TrainerConfig(steps=1, ckpt_dir=str(tmp_path)),
            log_fn=logs.append,
        )
        rng = np.random.default_rng(1)
        for i in range(40):
            t._watch_straggler(0.1 + float(rng.normal()) * 0.005, i)
        assert not any("straggler" in m for m in logs)


class TestPreemption:
    def test_preempt_flag_saves_and_stops(self, tmp_path):
        t = Trainer(
            CFG, DATA, AdamWConfig(lr=1e-3),
            TrainerConfig(steps=50, ckpt_every=100, ckpt_dir=str(tmp_path),
                          log_every=1000),
            log_fn=lambda s: None,
        )
        orig = t._watch_straggler

        def trip_after_3(dt, step):
            orig(dt, step)
            if step >= 2:
                t._preempted = True  # simulate SIGTERM delivery

        t._watch_straggler = trip_after_3
        _, _, losses = t.run(seed=0)
        assert len(losses) < 50  # stopped early
        assert t.manager.latest_step() == len(losses)  # state saved at exit
        # a fresh trainer resumes exactly where the preempted one stopped
        t2 = Trainer(
            CFG, DATA, AdamWConfig(lr=1e-3),
            TrainerConfig(steps=len(losses) + 2, ckpt_every=100,
                          ckpt_dir=str(tmp_path), log_every=1000),
            log_fn=lambda s: None,
        )
        _, _, losses2 = t2.run(seed=0)
        assert len(losses2) == 2


class TestElasticRestore:
    def test_restore_across_device_counts(self, tmp_path):
        """Checkpoint written under one topology restores under another
        (subprocess pair with different host-device counts)."""
        import subprocess
        import sys
        from pathlib import Path

        root = Path(__file__).resolve().parent.parent
        script = """
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager
from repro.distributed import meshcompat

mesh = meshcompat.make_mesh((%d,), ("data",))
mgr = CheckpointManager(sys.argv[1])
tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
sh = {"w": NamedSharding(mesh, P("data", None))}
if sys.argv[2] == "save":
    arr = jax.device_put(tree["w"], sh["w"])
    mgr.save(1, {"w": arr})
    print("SAVED")
else:
    out = mgr.restore(jax.eval_shape(lambda: tree), shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
    assert len(out["w"].sharding.device_set) == %d
    print("RESTORED")
"""
        import os

        # propagate platform selection (e.g. JAX_PLATFORMS=cpu): without it
        # the fresh jax probes for accelerators and can hang in sandboxes
        env = {"PYTHONPATH": str(root / "src"), "PATH": "/usr/bin:/bin",
               "HOME": "/root",
               **{k: v for k, v in os.environ.items() if k.startswith("JAX_")}}
        r1 = subprocess.run(
            [sys.executable, "-c", script % (8, 8, 8), str(tmp_path), "save"],
            env=env, capture_output=True, text=True, timeout=300, cwd=root,
        )
        assert "SAVED" in r1.stdout, r1.stderr
        r2 = subprocess.run(
            [sys.executable, "-c", script % (4, 4, 4), str(tmp_path), "load"],
            env=env, capture_output=True, text=True, timeout=300, cwd=root,
        )
        assert "RESTORED" in r2.stdout, r2.stderr
