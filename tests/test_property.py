"""Property-based tests (hypothesis) for system invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    AffineProfile,
    GOOGLENET_P4_ENERGY,
    ServiceModel,
    SMDPSpec,
    build_smdp,
    evaluate_policy,
    greedy_policy,
    relative_value_iteration,
)
from repro.distributed.compression import (
    compress_with_error_feedback,
    init_error_feedback,
)

FAMILIES = ("det", "erlang", "expo", "hyperexpo")


@st.composite
def smdp_specs(draw):
    rho = draw(st.floats(0.05, 0.95))
    b_max = draw(st.sampled_from([4, 8, 16]))
    b_min = draw(st.integers(1, max(1, b_max // 4)))
    family = draw(st.sampled_from(FAMILIES))
    slope = draw(st.floats(0.0, 1.0))
    intercept = draw(st.floats(0.1, 5.0))
    w2 = draw(st.floats(0.0, 10.0))
    svc = ServiceModel(latency=AffineProfile(slope, intercept), family=family)
    lam = rho * b_max / float(svc.mean(b_max))
    return SMDPSpec(
        lam=lam, service=svc, energy=GOOGLENET_P4_ENERGY,
        b_min=b_min, b_max=b_max, w1=1.0, w2=w2,
        s_max=draw(st.sampled_from([24, 40, 64])), c_o=100.0,
    )


class TestSMDPInvariants:
    @settings(max_examples=25, deadline=None)
    @given(smdp_specs())
    def test_transition_stochasticity(self, spec):
        mdp = build_smdp(spec)
        rows = mdp.m_hat[mdp.feasible]
        assert np.all(rows >= -1e-12)
        np.testing.assert_allclose(rows.sum(-1), 1.0, atol=1e-8)
        rows_t = mdp.m_tilde[mdp.feasible]
        np.testing.assert_allclose(rows_t.sum(-1), 1.0, atol=1e-8)
        assert np.all(rows_t >= -1e-10)

    @settings(max_examples=15, deadline=None)
    @given(smdp_specs())
    def test_rvi_policy_feasible_and_beats_greedy(self, spec):
        mdp = build_smdp(spec)
        res = relative_value_iteration(mdp, eps=1e-2)
        s_val = np.minimum(np.arange(mdp.n_states), spec.s_max)
        pol = res.policy
        assert np.all((pol == 0) | ((pol >= spec.b_min) & (pol <= np.minimum(s_val, spec.b_max))))
        g_smdp = evaluate_policy(mdp, pol).g
        g_greedy = evaluate_policy(
            mdp, greedy_policy(spec.s_max, spec.b_min, spec.b_max)
        ).g
        assert g_smdp <= g_greedy + 1e-6

    @settings(max_examples=15, deadline=None)
    @given(smdp_specs(), st.integers(0, 10_000))
    def test_backup_equivalence_on_random_h(self, spec, seed):
        """banded backup == dense backup for arbitrary value vectors."""
        from repro.core.rvi import banded_backup, dense_backup, make_banded_inputs

        mdp = build_smdp(spec)
        h = jnp.asarray(np.random.default_rng(seed).normal(size=mdp.n_states) * 10)
        qd = dense_backup(jnp.asarray(mdp.c_tilde), jnp.asarray(mdp.m_tilde), h)
        pm, tl, sc = make_banded_inputs(mdp)
        qb = banded_backup(jnp.asarray(mdp.c_tilde), pm, tl, sc, spec.s_max, h)
        feas = mdp.feasible
        np.testing.assert_allclose(
            np.asarray(qd)[feas], np.asarray(qb)[feas], rtol=1e-8, atol=1e-8
        )

    @settings(max_examples=10, deadline=None)
    @given(smdp_specs())
    def test_w2_monotonicity(self, spec):
        """Raising the energy weight never increases optimal power draw."""
        lo = dataclasses.replace(spec, w2=0.0)
        hi = dataclasses.replace(spec, w2=spec.w2 + 5.0)
        p_lo = evaluate_policy(
            build_smdp(lo), relative_value_iteration(build_smdp(lo)).policy
        ).p_bar
        p_hi = evaluate_policy(
            build_smdp(hi), relative_value_iteration(build_smdp(hi)).policy
        ).p_bar
        assert p_hi <= p_lo + 1e-6


class TestCompression:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.floats(1e-3, 1e3))
    def test_error_feedback_residual_bound(self, seed, scale):
        rng = np.random.default_rng(seed)
        g = {"w": jnp.asarray(rng.normal(size=(32, 16)) * scale)}
        e = init_error_feedback(g)
        deq, err = compress_with_error_feedback(g, e)
        # quantization residual bounded by half an int8 step of the max-abs
        step = float(jnp.max(jnp.abs(g["w"]))) / 127.0
        assert float(jnp.max(jnp.abs(err["w"]))) <= step * 0.51 + 1e-9
        # deq + err reconstructs the corrected gradient exactly
        np.testing.assert_allclose(
            np.asarray(deq["w"] + err["w"]), np.asarray(g["w"]), rtol=1e-6, atol=1e-8
        )

    def test_error_feedback_converges_in_mean(self):
        """Across steps, accumulated quantized sum tracks the true sum."""
        rng = np.random.default_rng(0)
        g_true = jnp.asarray(rng.normal(size=(64,)))
        e = init_error_feedback({"g": g_true})
        acc = np.zeros(64)
        for _ in range(50):
            deq, e = compress_with_error_feedback({"g": g_true}, e)
            acc += np.asarray(deq["g"])
        np.testing.assert_allclose(acc / 50, np.asarray(g_true), atol=1e-2)


class TestDataPipeline:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 1000), st.integers(0, 2**31 - 1))
    def test_determinism(self, step, seed):
        from repro.training.data import DataConfig, batch_at_step

        cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=2, seed=seed)
        a = batch_at_step(cfg, step)["tokens"]
        b = batch_at_step(cfg, step)["tokens"]
        assert (np.asarray(a) == np.asarray(b)).all()
        c = batch_at_step(cfg, step + 1)["tokens"]
        assert not (np.asarray(a) == np.asarray(c)).all()
        assert int(a.max()) < 128 and int(a.min()) >= 0
