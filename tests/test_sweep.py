"""Batched sweep engine: serial equivalence, policy structure, stochasticity.

Covers the acceptance surface of the sweep solver:
  * sweep_solve over a >= 16-point w2 grid matches per-spec solve()
  * monotone control-limit structure of the resulting policies
  * row-stochasticity of the batched m_tilde / m_hat
  * banded policy evaluation == dense policy evaluation
  * scheduler bank built from a solved sweep (hot-swap on retune)
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    GOOGLENET_P4_ENERGY,
    GOOGLENET_P4_LATENCY,
    ConstantProfile,
    ServiceModel,
    SMDPSpec,
    build_smdp,
    build_smdp_batched,
    evaluate_policy,
    pad_specs,
    solve,
    sweep_solve,
)
from repro.core.evaluate import evaluate_policy_banded
from repro.core.policies import is_control_limit
from repro.serving import SMDPScheduler, SMDPSchedulerBank


def spec_for(rho=0.3, w2=1.0, s_max=64, b_max=16, family="det", latency=None):
    svc = ServiceModel(latency=latency or GOOGLENET_P4_LATENCY, family=family)
    lam = rho * b_max / float(svc.mean(b_max))
    return SMDPSpec(
        lam=lam, service=svc, energy=GOOGLENET_P4_ENERGY,
        b_min=1, b_max=b_max, w1=1.0, w2=w2, s_max=s_max, c_o=100.0,
    )


W2_GRID = [float(w) for w in np.linspace(0.0, 15.0, 16)]


class TestSerialEquivalence:
    def test_w2_grid_matches_serial_solve(self):
        base = spec_for(rho=0.3)
        specs = [dataclasses.replace(base, w2=w2) for w2 in W2_GRID]
        batched = sweep_solve(specs)
        assert len(batched) == len(specs)
        for sp, res in zip(specs, batched):
            serial = solve(sp)
            assert res.spec.s_max == serial.spec.s_max
            assert np.array_equal(res.policy, serial.policy), sp.w2
            np.testing.assert_allclose(res.eval.g, serial.eval.g, rtol=1e-9)
            np.testing.assert_allclose(
                res.eval.w_bar, serial.eval.w_bar, rtol=1e-9
            )
            np.testing.assert_allclose(
                res.eval.p_bar, serial.eval.p_bar, rtol=1e-9
            )
            # the batched RVI's own gain estimate is eps-close to serial's
            np.testing.assert_allclose(res.rvi.g, serial.rvi.g, rtol=1e-3)

    def test_mixed_s_max_is_padded(self):
        base = spec_for(rho=0.3)
        specs = [
            dataclasses.replace(base, w2=w2, s_max=s)
            for w2, s in [(0.0, 48), (1.0, 64), (5.0, 56)]
        ]
        padded = pad_specs(specs)
        assert all(sp.s_max == 64 for sp in padded)
        results = sweep_solve(specs)
        for sp, res in zip(padded, results):
            serial = solve(sp)
            assert np.array_equal(res.policy, serial.policy)

    def test_b_max_mismatch_rejected(self):
        base = spec_for()
        bad = spec_for(b_max=8)
        with pytest.raises(ValueError):
            sweep_solve([base, bad])

    def test_auto_grow_matches_serial(self):
        # rho high + tiny truncation: the delta rule must grow s_max
        base = spec_for(rho=0.85, s_max=16, b_max=16)
        specs = [dataclasses.replace(base, w2=w2) for w2 in (0.0, 1.0)]
        results = sweep_solve(specs, delta=1e-3)
        for sp, res in zip(specs, results):
            serial = solve(sp, delta=1e-3)
            assert res.spec.s_max == serial.spec.s_max
            assert res.spec.s_max > 16
            assert res.eval.delta < 1e-3
            assert np.array_equal(res.policy, serial.policy)


class TestPolicyStructure:
    def test_control_limit_and_monotone_in_w2(self):
        # Prop.-4 setting: size-independent exponential service
        svc_latency = ConstantProfile(2.4252)
        base = spec_for(
            rho=0.5, b_max=8, s_max=64, family="expo", latency=svc_latency
        )
        specs = [
            dataclasses.replace(base, w2=w2)
            for w2 in np.linspace(0.0, 10.0, 11)
        ]
        results = sweep_solve(specs)
        qs, p_bars = [], []
        for res in results:
            is_cl, q = is_control_limit(res.policy, res.spec.s_max, 8)
            assert is_cl, res.spec.w2
            qs.append(q)
            p_bars.append(res.eval.p_bar)
        # raising the energy weight never lowers the control limit and
        # never raises the optimal average power draw (up to the tiny
        # evaluation shift that different auto-grown truncations induce)
        assert all(q2 >= q1 for q1, q2 in zip(qs, qs[1:]))
        assert all(
            p2 <= p1 * (1.0 + 1e-4) for p1, p2 in zip(p_bars, p_bars[1:])
        )


class TestBatchedConstruction:
    def _mixed_batch(self):
        specs = [
            spec_for(rho=0.3, w2=0.0),
            spec_for(rho=0.3, w2=5.0),
            spec_for(rho=0.6, w2=1.0, family="expo"),
            spec_for(rho=0.45, w2=2.0, family="erlang"),
        ]
        return build_smdp_batched(specs)

    def test_m_tilde_rows_stochastic(self):
        batch = self._mixed_batch()
        m_tilde = batch.m_tilde_dense()
        assert m_tilde.shape == (
            batch.n_specs, batch.n_states, batch.n_actions, batch.n_states
        )
        rows = m_tilde[batch.feasible]
        np.testing.assert_allclose(rows.sum(-1), 1.0, atol=1e-8)
        assert (rows >= -1e-10).all()
        m_hat = batch.m_hat_dense()
        rows_h = m_hat[batch.feasible]
        np.testing.assert_allclose(rows_h.sum(-1), 1.0, atol=1e-8)
        assert (rows_h >= 0).all()

    def test_dense_slice_matches_scalar_build(self):
        batch = self._mixed_batch()
        for i, sp in enumerate(batch.specs):
            mdp = build_smdp(sp)
            np.testing.assert_allclose(
                batch.m_hat_dense(i), mdp.m_hat, atol=1e-12
            )
            np.testing.assert_allclose(
                batch.m_tilde_dense(i), mdp.m_tilde, atol=1e-12
            )
            np.testing.assert_allclose(batch.eta[i], mdp.eta, rtol=1e-12)
            finite = batch.feasible[i]
            np.testing.assert_allclose(
                batch.c_tilde[i][finite], mdp.c_tilde[finite], rtol=1e-12
            )

    def test_policy_transitions_matches_dense_rows(self):
        batch = self._mixed_batch()
        rng = np.random.default_rng(0)
        S = batch.n_states
        for i in range(batch.n_specs):
            s_val = np.minimum(np.arange(S), batch.specs[i].s_max)
            policy = np.where(
                rng.random(S) < 0.5, 0, rng.integers(1, 17, S)
            )
            policy = np.minimum(policy, s_val).astype(np.int64)
            rows = batch.policy_transitions(i, policy)
            dense = batch.m_hat_dense(i)[np.arange(S), policy, :]
            np.testing.assert_allclose(rows, dense, atol=1e-12)

    def test_banded_eval_matches_dense_eval(self):
        batch = self._mixed_batch()
        for i in range(batch.n_specs):
            mdp = batch.dense(i)
            sp = batch.specs[i]
            from repro.core.policies import greedy_policy

            pol = greedy_policy(sp.s_max, sp.b_min, sp.b_max)
            ev_b = evaluate_policy_banded(batch, i, pol)
            ev_d = evaluate_policy(mdp, pol)
            np.testing.assert_allclose(ev_b.g, ev_d.g, rtol=1e-10)
            np.testing.assert_allclose(ev_b.delta, ev_d.delta, atol=1e-12)
            np.testing.assert_allclose(ev_b.w_bar, ev_d.w_bar, rtol=1e-10)
            np.testing.assert_allclose(ev_b.p_bar, ev_d.p_bar, rtol=1e-10)


class TestSchedulerBank:
    def _bank(self):
        base = spec_for(rho=0.3, b_max=8, s_max=48)
        specs = [
            dataclasses.replace(base, w2=w2) for w2 in (0.0, 2.0, 8.0)
        ]
        results = sweep_solve(specs)
        return SMDPScheduler.bank(results), results

    def test_bank_keys_and_nearest(self):
        bank, results = self._bank()
        assert isinstance(bank, SMDPSchedulerBank)
        assert len(bank) == 3
        lam = results[0].spec.lam
        assert bank.nearest(lam=lam, w2=1.9) == (lam, 2.0)
        assert bank.nearest(w2=100.0) == (lam, 8.0)
        with pytest.raises(ValueError):
            bank.nearest(nope=1.0)

    def test_scheduler_hot_swap(self):
        bank, results = self._bank()
        sch = bank.scheduler(w2=0.0)
        assert np.array_equal(sch.table, results[0].action_table())
        before = [sch.decide(s) for s in range(sch.s_max + 1)]
        key = sch.retune(w2=8.0)
        assert key[1] == 8.0
        assert np.array_equal(sch.table, results[2].action_table())
        after = [sch.decide(s) for s in range(sch.s_max + 1)]
        # a much higher energy price must not make batching less patient
        assert after != before

    def test_bank_requires_attachment(self):
        _, results = self._bank()
        sch = SMDPScheduler(results[0])
        with pytest.raises(RuntimeError):
            sch.retune(w2=1.0)

    def test_bank_rejects_duplicate_keys(self):
        # a family sweep yields identical (lam, w2) keys: must not silently
        # collapse to the last table — callers pass explicit keys instead
        _, results = self._bank()
        with pytest.raises(ValueError, match="duplicate bank key"):
            SMDPScheduler.bank([results[0], results[0]])
        bank = SMDPScheduler.bank(
            [results[0], results[0]],
            keys=[(0.0,), (1.0,)],
            key_names=("profile",),
        )
        assert len(bank) == 2
