"""§Perf ablation: baseline vs optimized substrate per dry-run cell.

Reads artifacts/dryrun_baseline (pre-optimization) and artifacts/dryrun
(optimized) and emits the before/after dominant-term comparison that backs
EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import json
from pathlib import Path

from .common import emit

ROOT = Path(__file__).resolve().parent.parent
BASE = ROOT / "artifacts" / "dryrun_baseline"
OPT = ROOT / "artifacts" / "dryrun"


def _load(d: Path):
    out = {}
    if not d.exists():
        return out
    for p in d.glob("*__single_pod.json"):
        r = json.loads(p.read_text())
        if r.get("status") == "ok":
            out[(r["arch"], r["shape"])] = r
    return out


def run(smoke: bool = False) -> None:
    del smoke  # already CPU-reduced: uniform interface for run.py --smoke
    base = _load(BASE)
    opt = _load(OPT)
    if not base or not opt:
        emit("perf_ablation", 0.0, "need both artifacts/dryrun_baseline and artifacts/dryrun")
        return
    total_speedup = []
    for key in sorted(set(base) & set(opt)):
        b, o = base[key]["roofline"], opt[key]["roofline"]
        b_dom = max(b["compute_s"], b["memory_s"], b["collective_s"])
        o_dom = max(o["compute_s"], o["memory_s"], o["collective_s"])
        speedup = b_dom / o_dom if o_dom > 0 else float("inf")
        total_speedup.append(speedup)
        if speedup >= 1.15 or speedup <= 0.87:
            emit(
                f"perf_{key[0]}_{key[1]}",
                0.0,
                f"bound:{b_dom:.3g}s({b['bottleneck']})->"
                f"{o_dom:.3g}s({o['bottleneck']});speedup={speedup:.1f}x",
            )
    import numpy as np

    emit(
        "perf_ablation_geomean",
        0.0,
        f"step-bound_geomean_speedup={float(np.exp(np.mean(np.log(total_speedup)))):.2f}x"
        f"_over_{len(total_speedup)}_cells",
    )


if __name__ == "__main__":
    run()
