"""Paper Fig. 9: impact of service-time distribution (CoV sweep).

The four service families share (s_max, b_max), so the whole figure is one
sweep_solve batch — service distributions, not weights, are the swept axis.
"""
from __future__ import annotations

from repro.core import ServiceModel
from repro.core.sweep import sweep_solve

from .common import emit, paper_spec, timed

FAMILIES = ("det", "erlang", "expo", "hyperexpo")


def run(smoke: bool = False) -> None:
    for rho in (0.3,) if smoke else (0.3, 0.7):
        specs = [
            paper_spec(rho=rho, family=fam, s_max=128 if smoke else 192)
            for fam in FAMILIES
        ]
        results, us = timed(sweep_solve, specs)
        ws = {fam: res.eval.w_bar for fam, res in zip(FAMILIES, results)}
        ordered = ws["det"] <= ws["erlang"] <= ws["expo"] <= ws["hyperexpo"]
        emit(
            f"fig9_cov_rho{rho}",
            us / len(FAMILIES),
            f"W_monotone_in_CoV={ordered};" +
            ";".join(f"{k}={v:.2f}ms" for k, v in ws.items()),
        )


if __name__ == "__main__":
    run()
