"""Paper Fig. 9: impact of service-time distribution (CoV sweep)."""
from __future__ import annotations

import dataclasses

from repro.core import ServiceModel, solve, GOOGLENET_P4_LATENCY

from .common import emit, paper_spec, timed


def run() -> None:
    for rho in (0.3, 0.7):
        ws = {}
        def sweep():
            for fam in ("det", "erlang", "expo", "hyperexpo"):
                spec = paper_spec(rho=rho, family=fam, s_max=192)
                ws[fam] = solve(spec).eval.w_bar
        _, us = timed(sweep)
        ordered = ws["det"] <= ws["erlang"] <= ws["expo"] <= ws["hyperexpo"]
        emit(
            f"fig9_cov_rho{rho}",
            us / 4,
            f"W_monotone_in_CoV={ordered};" +
            ";".join(f"{k}={v:.2f}ms" for k, v in ws.items()),
        )


if __name__ == "__main__":
    run()
