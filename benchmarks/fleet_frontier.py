"""Fleet frontier: M routed small servers vs one fat server, equal silicon.

The paper (and the whole solver stack) prices ONE batch-service queue;
this benchmark asks the deployment question the fleet lane exists for
(cf. Kar et al., arXiv 2009.09433): at equal aggregate service capacity,
is it better to run M small replicas behind a router — each solving its
own SMDP at lambda/M — or one M-times-faster fat server solving at
lambda?  At equal joules-per-batch the fat server amortizes energy over
bigger batches and drains faster; the routed fleet pays a latency and
power premium whose size the router sets — batch_aware (queue closest to
its table's next admission threshold) narrows the gap over jsq.

Scenarios: Poisson / MMPP2 / diurnal arrivals at per-replica rho 0.7.
Per (scenario, router) the compiled fleet grid averages seeds in one
vmapped dispatch; the fat server runs the single-server compiled kernel
on the same traces.  A streaming section pushes a >= 10x-chunk horizon
through FleetStream and checks the O(chunk)-memory aggregates against a
one-shot run of the same trace.

Usage:  PYTHONPATH=src python -m benchmarks.fleet_frontier [--smoke]
            [--json BENCH_fleet.json]
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import GOOGLENET_P4_LATENCY, solve
from repro.serving import (
    FleetStream,
    histogram_quantiles,
    pad_arrivals_batch,
    run_fleet_grid,
    simulate_compiled,
    simulate_fleet,
)
from repro.serving.arrivals import MMPP2, DiurnalProcess

from .common import BMAX, emit, emit_json, energy_table, paper_spec, timed

M = 4
RHO = 0.7
ROUTERS = ("jsq", "batch_aware", "rr", "pow2")


def _traces(mode: str, lam: float, n: int, n_seeds: int):
    out = []
    for s in range(n_seeds):
        rng = np.random.default_rng(1000 + s)
        if mode == "poisson":
            out.append(np.cumsum(rng.exponential(1.0 / lam, n)))
        elif mode == "mmpp2":
            m = MMPP2(
                lam1=0.3 * lam, lam2=1.3 * lam, dwell1=60.0, dwell2=30.0
            )
            times, _ = m.sample_arrivals(n / m.mean_rate, rng)
            out.append(times)
        else:
            proc = DiurnalProcess(base=lam, amp=0.6 * lam, period=300.0)
            out.append(np.array([proc.next(rng).time for _ in range(n)]))
    return out


def _lane_summary(out, i_router):
    """Seed-averaged (W_mean, P95, power, mean_batch) of one router lane."""
    w = np.nanmean(out["w_mean"][:, 0, i_router])
    power = np.nanmean(out["power"][:, 0, i_router])
    mb = (
        out["n_served"][:, 0, i_router].sum()
        / out["n_batches"][:, 0, i_router].sum()
    )
    p95 = np.mean([
        histogram_quantiles(
            out["hist"][s, 0, i_router], out["hist_edges"], [0.95]
        )[0]
        for s in range(out["hist"].shape[0])
    ])
    return w, p95, power, mb


def run(smoke: bool = False, json_path: str | None = None) -> None:
    n = 4000 if smoke else 20000
    n_seeds = 2 if smoke else 4

    # equal silicon: each replica is a GoogLeNet/P4 card solved at its
    # lambda/M share; the fat server is an M-times-faster card (latency/M)
    # solved at the aggregate lambda.  Energy per batch is the card's own.
    spec_small = paper_spec(rho=RHO)
    spec_fat = paper_spec(
        rho=RHO, latency=lambda b: GOOGLENET_P4_LATENCY(b) / M
    )
    tab_small = solve(spec_small).policy
    tab_fat = solve(spec_fat).policy
    en_small = energy_table(spec_small)
    en_fat = en_small  # same joules per batch: the fat card is faster,
    # not thriftier — the frontier isolates batching behavior
    means_small = np.array(
        [0.0] + [float(spec_small.service.mean(b)) for b in range(1, BMAX + 1)]
    )
    means_fat = means_small / M
    lam_agg = M * spec_small.lam

    sections: dict = {}
    for mode in ("poisson", "mmpp2", "diurnal"):
        traces, us_tr = timed(_traces, mode, lam_agg, n, n_seeds)
        arr = pad_arrivals_batch(traces)
        (out, _), us_fleet = timed(
            lambda: (
                run_fleet_grid(
                    tab_small[None], arr, routers=ROUTERS, n_replicas=M,
                    means=means_small, zeta=en_small, b_max=BMAX,
                ),
                None,
            )
        )
        fat_w, fat_p95, fat_power, fat_mb = [], [], [], []
        for tr in traces:
            res = simulate_compiled(
                tab_fat, tr, means=means_fat, zeta=en_fat, b_max=BMAX
            )
            fat_w.append(res.lat_sum / res.n_served)
            fat_p95.append(
                histogram_quantiles(res.hist, res.hist_edges, [0.95])[0]
            )
            fat_power.append(res.energy / res.t_final)
            fat_mb.append(res.n_served / res.n_batches)
        sec = {
            "n_arrivals": n, "n_seeds": n_seeds, "M": M, "rho": RHO,
            "lam_aggregate": float(lam_agg),
            "fat_server": {
                "W_mean": float(np.mean(fat_w)),
                "P95": float(np.mean(fat_p95)),
                "power": float(np.mean(fat_power)),
                "mean_batch": float(np.mean(fat_mb)),
            },
            "fleet": {},
        }
        for i, router in enumerate(ROUTERS):
            w, p95, power, mb = _lane_summary(out, i)
            sec["fleet"][router] = {
                "W_mean": float(w), "P95": float(p95),
                "power": float(power), "mean_batch": float(mb),
                "energy_ratio_vs_fat": float(power / np.mean(fat_power)),
                "latency_ratio_vs_fat": float(w / np.mean(fat_w)),
            }
        best = min(
            ROUTERS, key=lambda r: sec["fleet"][r]["W_mean"]
        )
        sec["best_router"] = best
        emit(
            f"fleet_{mode}",
            us_fleet,
            f"fat:W={sec['fat_server']['W_mean']:.2f}ms"
            f",P={sec['fat_server']['power']:.1f}W;"
            + ";".join(
                f"{r}:W={sec['fleet'][r]['W_mean']:.2f}ms"
                f",P={sec['fleet'][r]['power']:.1f}W"
                for r in ROUTERS[:2]
            )
            + f";best_router={best}",
        )
        sections[mode] = sec
        del us_tr

    # --- streaming: O(chunk) memory at a >= 10x-chunk horizon ----------
    chunk = 1024 if smoke else 8192
    n_stream = 16 * chunk
    lam = lam_agg
    tr = np.cumsum(
        np.random.default_rng(7).exponential(1.0 / lam, n_stream)
    )
    tabs = np.tile(tab_small[None], (M, 1))

    def _stream():
        fs = FleetStream(
            tabs, router="jsq", means=means_small, zeta=en_small, b_max=BMAX
        )
        for lo in range(0, n_stream, chunk):
            fs.push(tr[lo:lo + chunk])
        return fs

    fs, us_stream = timed(_stream)
    st = fs.finish()
    one = simulate_fleet(
        tabs, tr, router="jsq", means=means_small, zeta=en_small, b_max=BMAX
    )
    lat_err = abs(st.lat_sum - one.lat_sum) / one.lat_sum
    assert lat_err < 1e-9, lat_err
    assert st.n_served == one.n_served == n_stream
    rep = fs.report()
    ev_per_s = n_stream / (us_stream / 1e6)
    emit(
        "fleet_stream",
        us_stream,
        f"horizon/chunk={n_stream // chunk}x;events/s={ev_per_s:.3g};"
        f"lat_sum_err={lat_err:.1e};P95={rep['P95']:.2f}ms",
    )
    sections["streaming"] = {
        "chunk_size": chunk, "n_stream": n_stream,
        "horizon_over_chunk": n_stream // chunk,
        "events_per_sec": float(ev_per_s),
        "lat_sum_relative_err_vs_one_shot": float(lat_err),
        "report": {k: float(v) for k, v in rep.items()},
    }

    if json_path:
        emit_json(json_path, "fleet_frontier", sections)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced traces/seeds for CI")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="merge results into this JSON artifact")
    args = ap.parse_args()
    run(smoke=args.smoke, json_path=args.json)


if __name__ == "__main__":
    main()
