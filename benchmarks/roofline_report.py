"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import json
from pathlib import Path

from .common import emit

ART = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def rows(mesh: str = "single_pod"):
    out = []
    if not ART.exists():
        return out
    for p in sorted(ART.glob(f"*__{mesh}.json")):
        rec = json.loads(p.read_text())
        if rec.get("status") != "ok":
            continue
        out.append(rec)
    return out


def run(smoke: bool = False) -> None:
    del smoke  # already CPU-reduced: uniform interface for run.py --smoke
    recs = rows()
    if not recs:
        emit("roofline_report", 0.0, "no_artifacts_run_launch.dryrun_first")
        return
    worst = None
    for rec in recs:
        r = rec["roofline"]
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / dom if dom > 0 else 0.0
        name = f"roofline_{rec['arch']}_{rec['shape']}"
        emit(
            name,
            rec["compile_s"] * 1e6,
            f"compute={r['compute_s']:.2e}s;memory={r['memory_s']:.2e}s;"
            f"collective={r['collective_s']:.2e}s;bottleneck={r['bottleneck']};"
            f"compute_fraction={frac:.2%}",
        )
        if worst is None or frac < worst[1]:
            worst = (name, frac)
    emit("roofline_worst_compute_fraction", 0.0, f"{worst[0]}={worst[1]:.2%}")


if __name__ == "__main__":
    run()
