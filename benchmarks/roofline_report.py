"""Roofline profile of the compiled serving kernels, from live dispatches.

The old report read dry-run artifacts of the retired eager path; this one
profiles the executables the serving layer actually dispatches today:

  * ``event_kernel``   — one `simulate_compiled` trace (`_simulate_jit`);
  * ``run_grid``       — the seeds x tables vmapped fixed-bank dispatch;
  * ``run_grid_belief``— the same grid rowed by the MMPP posterior
    (``phase_mode="belief_argmax"``, beliefs from `belief_forward_jax`);
  * ``run_grid_adaptive`` — the in-carry `AdaptiveController` lane.

Each kernel is captured at its real call site (the module-level jit is
wrapped for one call, the recorded arguments are re-lowered), so the XLA
cost analysis — flops and bytes accessed — describes the exact compiled
artifact, escalated scan length and all.  Machine peaks are measured
in-process (dense f64 matmul for compute, big-array streaming for
bandwidth), which turns the counts into a roofline: predicted compute- and
memory-time, the binding side, and the fraction of the roofline the
measured wall-clock attains.  Event-loop kernels are latency chains, not
dense math, so low attained fractions with a memory bound are the expected
signature — the number to watch across commits is events/s next to it.

Render the markdown table with ``python -m benchmarks.gen_roofline_md``.
"""
from __future__ import annotations

import argparse
import contextlib
import time

import numpy as np

from repro.configs.googlenet_p4 import B_MAX, energy_table, service
from repro.serving import (
    AdaptiveController,
    PhaseBeliefFilter,
    SMDPSchedulerBank,
    belief_forward_jax,
)
from repro.serving.arrivals import MMPP2
import repro.serving.compiled as C

from .common import emit, emit_json

SVC = service()
EN = energy_table()


@contextlib.contextmanager
def _capture(jit_name):
    """Record the last argument tuple a module-level jit is called with.

    The serving entry points own all argument prep (padding, bucketed scan
    lengths, lane lowering); wrapping the jit for one call and re-lowering
    the captured tuple profiles the exact executable they dispatch without
    duplicating that prep here.
    """
    orig = getattr(C, jit_name)
    box = {}

    def spy(*a, **k):
        box["args"], box["kw"] = a, k
        return orig(*a, **k)

    setattr(C, jit_name, spy)
    try:
        yield box
    finally:
        setattr(C, jit_name, orig)


def _best_of(fn, n=3):
    t = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        out = fn()
        t = min(t, time.perf_counter() - t0)
    return out, t


def measure_peaks():
    """In-process machine peaks: f64 matmul GFLOP/s + streaming GB/s."""
    import jax
    import jax.numpy as jnp

    n = 1024
    a = jnp.ones((n, n), dtype=jnp.float64)
    mm = jax.jit(lambda x, y: x @ y)
    mm(a, a).block_until_ready()
    _, t_mm = _best_of(lambda: mm(a, a).block_until_ready())
    m = 1 << 23  # 64 MiB of f64: past any cache, a pure stream
    x = jnp.ones(m, dtype=jnp.float64)
    cp = jax.jit(lambda v: v * 2.0)
    cp(x).block_until_ready()
    _, t_cp = _best_of(lambda: cp(x).block_until_ready())
    return {
        "peak_flops_per_s": 2.0 * n**3 / t_mm,
        "peak_bytes_per_s": 2.0 * 8.0 * m / t_cp,
        "matmul_n": n,
        "stream_bytes": 2 * 8 * m,
    }


def _workloads(smoke):
    """(label, jit name, dispatch thunk, events-of-result) per kernel."""
    horizon = 4_000.0 if smoke else 20_000.0
    n_seeds = 3 if smoke else 6
    mu_max = B_MAX / float(SVC.mean(B_MAX))
    m = MMPP2(lam1=0.1 * mu_max, lam2=0.8 * mu_max, dwell1=400.0,
              dwell2=150.0)
    traces = [
        m.sample_arrivals(horizon, np.random.default_rng(40 + s))[0]
        for s in range(n_seeds)
    ]
    means = np.array([0.0] + [float(SVC.mean(b)) for b in range(1, B_MAX + 1)])
    L = B_MAX + 2
    qs = np.arange(L)

    def q_table(q):
        return np.where(qs >= q, np.minimum(qs, B_MAX), 0).astype(np.int64)

    tables = np.stack([q_table(q) for q in (2, 6, 12, 20, B_MAX)])
    arrs = C.pad_arrivals_batch(traces)
    kw = dict(means=means, zeta=EN, b_max=B_MAX)

    gen = [[-1 / m.dwell1, 1 / m.dwell1], [1 / m.dwell2, -1 / m.dwell2]]
    filt = PhaseBeliefFilter(rates=[m.lam1, m.lam2], gen=gen)
    bels = np.asarray(belief_forward_jax(arrs, filt)[0])
    stacks = np.stack(
        [np.stack([q_table(2), q_table(12)]), np.stack([q_table(6), q_table(20)])]
    )

    bank = SMDPSchedulerBank(
        {(m.lam1,): q_table(4), (m.mean_rate,): q_table(10),
         (m.lam2,): q_table(16)},
        key_names=("lam",),
    )
    lane = C.AdaptiveLane.from_controller(
        AdaptiveController(bank, ewma=0.15, margin=0.2, min_dwell=20.0)
    )

    def grid_events(g):
        return int(g["events_total"])

    return [
        (
            "event_kernel", "_simulate_jit",
            lambda: C.simulate_compiled(tables[2], traces[0], **kw),
            lambda r: int(r.n_served + r.n_epochs),
        ),
        (
            "run_grid", "_grid_jit",
            lambda: C.run_grid(tables, arrs, **kw),
            grid_events,
        ),
        (
            "run_grid_belief", "_grid_jit",
            lambda: C.run_grid(
                stacks, arrs, phase_mode="belief_argmax", beliefs=bels, **kw
            ),
            grid_events,
        ),
        (
            "run_grid_adaptive", "_grid_adaptive_jit",
            lambda: C.run_grid_adaptive(arrs, adaptive=lane, **kw),
            grid_events,
        ),
    ]


def profile(smoke: bool = False):
    """Roofline rows for every compiled serving kernel + measured peaks."""
    peaks = measure_peaks()
    rows = []
    for label, jit_name, call, events_of in _workloads(smoke):
        with _capture(jit_name) as box:
            res = call()  # warms up, compiles, records the dispatch args
        lowered = getattr(C, jit_name).lower(*box["args"], **box["kw"])
        d = lowered.compile().cost_analysis()
        d = d[0] if isinstance(d, (list, tuple)) else d
        flops = float(d.get("flops", 0.0))
        nbytes = float(d.get("bytes accessed", 0.0))
        res, t = _best_of(call)
        compute_s = flops / peaks["peak_flops_per_s"]
        memory_s = nbytes / peaks["peak_bytes_per_s"]
        model_s = max(compute_s, memory_s)
        events = events_of(res)
        rows.append({
            "kernel": label,
            "flops": flops,
            "bytes": nbytes,
            "intensity_flops_per_byte": flops / nbytes if nbytes else 0.0,
            "compute_s": compute_s,
            "memory_s": memory_s,
            "bottleneck": "compute" if compute_s >= memory_s else "memory",
            "measured_s": t,
            "roofline_fraction": model_s / t if t else 0.0,
            "events": events,
            "events_per_sec": events / t if t else 0.0,
        })
    return {"peaks": peaks, "kernels": rows}


def run(smoke: bool = False, json_path: str | None = None) -> None:
    prof = profile(smoke=smoke)
    p = prof["peaks"]
    emit(
        "roofline_peaks",
        0.0,
        f"peak_gflops={p['peak_flops_per_s'] / 1e9:.1f};"
        f"peak_gbps={p['peak_bytes_per_s'] / 1e9:.1f}",
    )
    for r in prof["kernels"]:
        emit(
            f"roofline_{r['kernel']}",
            r["measured_s"] * 1e6,
            f"flops={r['flops']:.3g};bytes={r['bytes']:.3g};"
            f"intensity={r['intensity_flops_per_byte']:.2f};"
            f"bottleneck={r['bottleneck']};"
            f"roofline_fraction={r['roofline_fraction']:.2%};"
            f"ev/s={r['events_per_sec']:.3g}",
        )
    if json_path:
        emit_json(json_path, "roofline", prof)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced horizon/seeds for CI")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="merge results into this JSON artifact")
    args = ap.parse_args()
    run(smoke=args.smoke, json_path=args.json)


if __name__ == "__main__":
    main()
