"""Paper Fig. 6 + Table I: latency distribution and percentile analysis.

Paper anchors (rho = 0.7): static-8 -> (P, W, P50, P90, P95) =
(46.27, 6.85, 6.51, 9.85, 11.34); SMDP w2=1.6 -> (44.96, 6.90, 6.83, 9.23,
9.96); SMDP w2=2.2 -> (44.41, 7.81, 7.72, 10.45, 11.24).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import build_smdp, relative_value_iteration, static_policy
from repro.core.simulate import simulate

from .common import emit, energy_table, paper_spec, timed

PAPER = {
    "static8": (46.27, 6.85, 6.51, 9.85, 11.34),
    "smdp_w2_1.6": (44.96, 6.90, 6.83, 9.23, 9.96),
    "smdp_w2_2.2": (44.41, 7.81, 7.72, 10.45, 11.24),
}


def run(n_epochs: int = 150_000, smoke: bool = False) -> None:
    if smoke:
        n_epochs = min(n_epochs, 25_000)
    spec = paper_spec(rho=0.7)
    en = energy_table(spec)
    policies = {"static8": static_policy(8, spec.s_max)}
    for w2 in (1.6, 2.2):
        sp = dataclasses.replace(spec, w2=w2)
        policies[f"smdp_w2_{w2}"] = relative_value_iteration(build_smdp(sp)).policy

    for name, pol in policies.items():
        sim, us = timed(
            simulate, pol[:-1], spec.service, en, spec.lam, spec.b_max,
            n_epochs=n_epochs, seed=0,
        )
        p50, p90, p95 = sim.percentile([50, 90, 95])
        want = PAPER[name]
        got = (sim.p_bar, sim.w_bar, p50, p90, p95)
        max_rel = max(abs(g - w) / w for g, w in zip(got, want))
        emit(
            f"table1_{name}",
            us / n_epochs,
            f"P={sim.p_bar:.2f}W;W={sim.w_bar:.2f}ms;P50={p50:.2f};"
            f"P90={p90:.2f};P95={p95:.2f};max_rel_err_vs_paper={max_rel:.1%}",
        )


if __name__ == "__main__":
    run()
