"""Render EXPERIMENTS.md §Roofline final table from artifacts (run once)."""
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def main():
    rows = []
    for p in sorted((ROOT / "artifacts" / "dryrun").glob("*__single_pod.json")):
        r = json.loads(p.read_text())
        if r.get("status") != "ok":
            continue
        rl = r["roofline"]
        dom = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        frac = rl["compute_s"] / dom if dom else 0.0
        ur = r.get("useful_flops_ratio")
        rows.append(
            (r["arch"], r["shape"], rl["compute_s"], rl["memory_s"],
             rl["collective_s"], rl["bottleneck"], frac,
             "-" if ur is None else f"{min(ur, 9.99):.2f}")
        )
    print("| arch | shape | compute (s) | memory (s) | collective (s) | bottleneck | compute-fraction | 6ND/HLO |")
    print("|---|---|---|---|---|---|---|---|")
    for a, s, c, m, co, b, f, u in rows:
        print(f"| {a} | {s} | {c:.3g} | {m:.3g} | {co:.3g} | {b} | {f:.1%} | {u} |")


if __name__ == "__main__":
    main()
