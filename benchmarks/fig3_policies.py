"""Paper Fig. 3: SMDP policy structure in Cases 1-3 (+ Prop. 4 agreement)."""
from __future__ import annotations

import numpy as np

from repro.core import ConstantProfile, ServiceModel, SMDPSpec, solve, \
    optimal_q_closed_form, GOOGLENET_P4_ENERGY
from repro.core.policies import is_control_limit

from .common import emit, timed

#: (name, latency profile, family) — size-INdependent service (Assumption 1).
CASES = [
    ("case1_det", ConstantProfile(2.4252), "det"),
    ("case2_expo", ConstantProfile(2.4252), "expo"),
    ("case3_expo", ConstantProfile(1.7465), "expo"),
]
B = 8


def run(smoke: bool = False) -> None:
    rhos = (0.3, 0.7) if smoke else (0.1, 0.3, 0.5, 0.7, 0.9)
    w2s = (0.0, 1.0) if smoke else (0.0, 0.5, 1.0, 100.0)
    total = 0
    control_limit_ok = 0
    prop4_ok = 0
    prop4_applicable = 0

    def solve_grid():
        nonlocal total, control_limit_ok, prop4_ok, prop4_applicable
        for name, lat, family in CASES:
            svc = ServiceModel(latency=lat, family=family)
            mu = 1.0 / float(svc.mean(B))
            for rho in rhos:
                for w2 in w2s:
                    spec = SMDPSpec(
                        lam=rho * B * mu, service=svc,
                        energy=GOOGLENET_P4_ENERGY, b_min=1, b_max=B,
                        w1=1.0, w2=w2, s_max=100, c_o=100.0,
                    )
                    # paper shows CONVERGED results (consistent under
                    # increased s_max): the Delta-acceptance loop grows the
                    # truncation until the tail is negligible
                    res = solve(spec, delta=1e-3, max_s_max=1024)
                    total += 1
                    is_cl, q = is_control_limit(
                        res.rvi.policy, res.spec.s_max, B
                    )
                    control_limit_ok += int(is_cl)
                    if family == "expo":
                        prop4_applicable += 1
                        q_star = optimal_q_closed_form(
                            spec.lam, mu, B, w1=1.0, w2=w2,
                            zeta0=GOOGLENET_P4_ENERGY.intercept,
                        )
                        prop4_ok += int(is_cl and q == q_star)

    _, us = timed(solve_grid)
    emit("fig3_control_limit_structure", us / max(total, 1),
         f"{control_limit_ok}/{total}_control_limit")
    emit("fig3_prop4_agreement", us / max(total, 1),
         f"{prop4_ok}/{prop4_applicable}_Q_match")


if __name__ == "__main__":
    run()
