"""Paper Fig. 8: logarithmic energy consumption (strong energy batching).

Each rho's w2 curve is one batched sweep (smdp_tradeoff_curve ->
sweep.sweep_solve).
"""
from __future__ import annotations

from repro.core import LOG_ENERGY
from repro.core.tradeoff import benchmark_points, smdp_tradeoff_curve

from .common import emit, paper_spec, timed

W2S = [0.0, 0.3, 1.0, 3.0, 10.0]


def run(smoke: bool = False) -> None:
    w2s = [0.0, 1.0, 10.0] if smoke else W2S
    for rho in (0.3, 0.7):
        spec = paper_spec(rho=rho, energy=LOG_ENERGY)
        curve, us = timed(smdp_tradeoff_curve, spec, w2s)
        bench = benchmark_points(spec)
        dominated = sum(
            1 for pt in curve for (w_b, p_b) in bench.values()
            if w_b < pt.w_bar - 1e-6 and p_b < pt.p_bar - 1e-6
        )
        # paper claim: tradeoff is much steeper (big power range)
        p_range = max(pt.p_bar for pt in curve) - min(pt.p_bar for pt in curve)
        emit(
            f"fig8_log_energy_rho{rho}",
            us / len(w2s),
            f"dominated={dominated};power_range={p_range:.2f}W",
        )


if __name__ == "__main__":
    run()
