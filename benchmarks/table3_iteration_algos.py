"""Paper Table III (App. F): RVI(+abstract cost) vs AVI / API baselines."""
from __future__ import annotations

import dataclasses

from repro.core import build_smdp, evaluate_policy, relative_value_iteration
from repro.core.rvi import api, avi

from .common import emit, paper_spec, timed


def run() -> None:
    # paper setting: basic scenario, rho=0.5, w1=w2=1
    eval_smax = 160
    spec = paper_spec(rho=0.5, w2=1.0, s_max=eval_smax, c_o=0.0)
    mdp0 = build_smdp(spec)
    spec100 = dataclasses.replace(spec, c_o=100.0)
    mdp100 = build_smdp(spec100)

    for name, runner in [
        ("rvi_co0", lambda: relative_value_iteration(mdp0, eps=1e-2)),
        ("rvi_co100", lambda: relative_value_iteration(mdp100, eps=1e-2)),
        ("avi_schemeI", lambda: avi(spec, n_outer=400, eval_s_max=eval_smax)),
        ("api_schemeIV", lambda: api(spec, n_outer=8, eval_s_max=eval_smax)),
    ]:
        res, us = timed(runner)
        # evaluate every policy on the SAME truncated chain (c_o = 0 costs)
        ev = evaluate_policy(mdp0, res.policy)
        emit(
            f"table3_{name}",
            us,
            f"g={ev.g:.4f};wall={res.wall_time_s:.2f}s;iters={res.iterations}",
        )


if __name__ == "__main__":
    run()
