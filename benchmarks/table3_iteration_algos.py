"""Paper Table III (App. F) + solver accelerants: RVI vs AVI / API vs accel.

Two parts:

  * the paper's comparison — RVI (with/without abstract cost) against the
    Thomas–Stengos AVI / API schemes on the rho = 0.5 basic scenario
    (skipped in --smoke: the expanding-window numpy loops dominate CI time);
  * the solver-acceleration ladder — for rho in {0.3, 0.7, 0.85}, plain
    lockstep RVI vs accel="mpi" vs accel="anderson" on the batched engine,
    each checked against the scalar float64 solve() oracle (bit-identical
    greedy policy, |g - g_oracle|).  --json merges an
    {iterations, wall time, g-gap, policy match} table per rho into
    BENCH_solver.json (section "solver"), the artifact the bench-smoke CI
    job tracks across commits — mirroring mmpp_bursty's BENCH_serving.json.
"""
from __future__ import annotations

import argparse
import dataclasses

import numpy as np

from repro.core import (
    build_smdp,
    build_smdp_batched,
    evaluate_policy,
    relative_value_iteration,
    relative_value_iteration_batched,
    solve,
)
from repro.core.rvi import api, avi

from .common import emit, emit_json, paper_spec, timed

ACCEL_RHOS = (0.3, 0.7, 0.85)
ACCEL_MODES = ("none", "mpi", "anderson")


def run_paper_baselines() -> None:
    # paper setting: basic scenario, rho=0.5, w1=w2=1
    eval_smax = 160
    spec = paper_spec(rho=0.5, w2=1.0, s_max=eval_smax, c_o=0.0)
    mdp0 = build_smdp(spec)
    spec100 = dataclasses.replace(spec, c_o=100.0)
    mdp100 = build_smdp(spec100)

    for name, runner in [
        ("rvi_co0", lambda: relative_value_iteration(mdp0, eps=1e-2)),
        ("rvi_co100", lambda: relative_value_iteration(mdp100, eps=1e-2)),
        ("avi_schemeI", lambda: avi(spec, n_outer=400, eval_s_max=eval_smax)),
        ("api_schemeIV", lambda: api(spec, n_outer=8, eval_s_max=eval_smax)),
    ]:
        res, us = timed(runner)
        # evaluate every policy on the SAME truncated chain (c_o = 0 costs)
        ev = evaluate_policy(mdp0, res.policy)
        emit(
            f"table3_{name}",
            us,
            f"g={ev.g:.4f};wall={res.wall_time_s:.2f}s;iters={res.iterations}",
        )


def run_accel(smoke: bool = False) -> dict:
    """Accelerated-solver ladder vs the scalar f64 oracle, per rho."""
    s_max = 96 if smoke else 128
    sections = {}
    for rho in ACCEL_RHOS:
        spec = paper_spec(rho=rho, w2=1.0, s_max=s_max)
        # the untouched exact oracle: scalar float64 solve() at the SAME
        # truncation (delta=None -> no auto-grow, c_o fixed) — accelerated
        # results must reproduce its greedy policy bit-for-bit
        oracle = solve(spec, auto_c_o=False, delta=None)
        batch = build_smdp_batched([spec])
        rows = {}
        for mode in ACCEL_MODES:
            relative_value_iteration_batched(batch, accel=mode)  # compile
            res, us = timed(
                lambda m=mode: relative_value_iteration_batched(batch, accel=m),
                repeat=2,
            )
            match = bool(np.array_equal(res.policies[0], oracle.policy))
            g_gap = float(abs(res.g[0] - oracle.eval.g))
            iters = int(res.iterations[0])
            emit(
                f"table3_accel_rho{rho}_{mode}",
                us,
                f"iters={iters};g_gap={g_gap:.2e};policy_match={match}",
            )
            rows[mode] = {
                "iterations": iters,
                "wall_s": us / 1e6,
                "g_gap_vs_oracle": g_gap,
                "policy_match": match,
            }
        rows["speedup_iters_mpi_vs_none"] = (
            rows["none"]["iterations"] / max(rows["mpi"]["iterations"], 1)
        )
        sections[f"rho={rho}"] = rows
    return sections


def run(smoke: bool = False, json_path: str | None = None) -> None:
    if not smoke:
        run_paper_baselines()
    sections = run_accel(smoke=smoke)
    if json_path:
        emit_json(json_path, "solver", sections)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes, skip AVI/API baselines (CI)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="merge results into this JSON artifact")
    args = ap.parse_args()
    run(smoke=args.smoke, json_path=args.json)


if __name__ == "__main__":
    main()
