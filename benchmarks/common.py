"""Shared scenario builders + CSV emission for the benchmark suite.

Every benchmark prints ``name,us_per_call,derived`` rows (one per paper
artifact it reproduces); `derived` carries the headline quantity that the
paper's table/figure conveys.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable

import numpy as np

from repro.core import (
    GOOGLENET_P4_ENERGY,
    GOOGLENET_P4_LATENCY,
    ServiceModel,
    SMDPSpec,
)

BMAX = 32


def paper_spec(rho=0.7, w2=1.0, s_max=128, b_max=BMAX, c_o=100.0,
               family="det", latency=None, energy=None, b_min=1):
    svc = ServiceModel(latency=latency or GOOGLENET_P4_LATENCY, family=family)
    lam = rho * b_max / float(svc.mean(b_max))
    return SMDPSpec(
        lam=lam, service=svc, energy=energy or GOOGLENET_P4_ENERGY,
        b_min=b_min, b_max=b_max, w1=1.0, w2=w2, s_max=s_max, c_o=c_o,
    )


def energy_table(spec: SMDPSpec) -> np.ndarray:
    return np.array(
        [0.0] + [float(spec.energy(b)) for b in range(1, spec.b_max + 1)]
    )


def timed(fn: Callable, *args, repeat: int = 1, **kw):
    """Returns (result, microseconds per call)."""
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6


def emit(name: str, us: float, derived) -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)


def emit_json(path: str, section: str, payload) -> None:
    """Merge `payload` under `section` into a JSON artifact file.

    Benchmarks that share one artifact (e.g. BENCH_serving.json in CI) each
    write their own section; existing sections from earlier steps survive.
    """
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data[section] = payload
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
