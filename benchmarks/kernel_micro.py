"""Kernel microbenchmarks (interpret mode on CPU: correctness-path timing).

On-TPU wall times are NOT measurable in this container; the derived column
reports the analytic FLOPs/bytes per call used by the §Roofline analysis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops

from .common import emit, timed


def run(smoke: bool = False) -> None:
    del smoke  # already CPU-reduced: uniform interface for run.py --smoke
    key = jax.random.PRNGKey(0)

    # bellman: paper-size backup (s_max=192, Bmax=32)
    T, A, K = 193, 33, 193
    ks = jax.random.split(key, 3)
    h = jax.random.normal(ks[0], (T + K,))
    pmfs = jax.nn.softmax(jax.random.normal(ks[1], (A, K)), -1)
    tails = jax.random.uniform(ks[2], (T, A))
    ops.bellman_backup(h, pmfs, tails, 1.0)  # compile
    _, us = timed(lambda: jax.block_until_ready(
        ops.bellman_backup(h, pmfs, tails, 1.0)), repeat=3)
    flops = 2 * T * A * K
    emit("kernel_bellman_192x33", us, f"flops/call={flops:.2e};banded_vs_dense_flops_ratio={K/ (T):.2f}")

    # bellman, spec-batched: the sweep-engine lockstep shape (17-point grid)
    N = 17
    ks = jax.random.split(jax.random.fold_in(key, 7), 3)
    hb = jax.random.normal(ks[0], (N, T + K))
    pmfb = jax.nn.softmax(jax.random.normal(ks[1], (N, A, K)), -1)
    tailb = jax.random.uniform(ks[2], (N, T, A))
    hso = jax.random.normal(jax.random.fold_in(key, 8), (N,))
    ops.bellman_backup_batched(hb, pmfb, tailb, hso)  # compile
    _, us = timed(lambda: jax.block_until_ready(
        ops.bellman_backup_batched(hb, pmfb, tailb, hso)), repeat=3)
    emit("kernel_bellman_batched_17x192x33", us, f"flops/call={N * flops:.2e}")

    # interpret vs lowered: on a real TPU/GPU the Mosaic/Triton lowering is
    # *validated* against interpret mode (identical inputs, max |diff|); on
    # the CPU CI box there is no lowering, so the case records the skip —
    # a TPU run of this benchmark is the acceptance check for the kernel.
    if jax.default_backend() in ("tpu", "gpu"):
        lowered = ops.bellman_backup(h, pmfs, tails, 1.0, interpret=False)
        interp = ops.bellman_backup(h, pmfs, tails, 1.0, interpret=True)
        diff = float(jnp.max(jnp.abs(lowered - interp)))
        lowered_b = ops.bellman_backup_batched(hb, pmfb, tailb, hso, interpret=False)
        interp_b = ops.bellman_backup_batched(hb, pmfb, tailb, hso, interpret=True)
        diff_b = float(jnp.max(jnp.abs(lowered_b - interp_b)))
        emit("kernel_bellman_lowered_vs_interpret", 0.0,
             f"max_abs_diff={diff:.2e};max_abs_diff_batched={diff_b:.2e}")
    else:
        emit("kernel_bellman_lowered_vs_interpret", 0.0,
             "skipped=cpu-backend-has-no-mosaic-lowering")

    # flash attention: 1k x 1k, 8 heads
    B, S, H, KV, D = 1, 1024, 8, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, KV, D), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, KV, D), jnp.bfloat16)
    ops.flash_attention(q, k, v)
    _, us = timed(lambda: jax.block_until_ready(ops.flash_attention(q, k, v)), repeat=1)
    emit("kernel_flash_1k", us, f"flops/call={4 * B * H * S * S * D:.2e}")

    # decode: 32k cache
    B, S, H, KV, D = 4, 4096, 8, 2, 64
    ks = jax.random.split(key, 4)
    q1 = jax.random.normal(ks[0], (B, H, D), jnp.bfloat16)
    kc = jax.random.normal(ks[1], (B, S, KV, D), jnp.bfloat16)
    vc = jax.random.normal(ks[2], (B, S, KV, D), jnp.bfloat16)
    lens = jnp.full((B,), S, jnp.int32)
    ops.decode_attention(q1, kc, vc, lens)
    _, us = timed(lambda: jax.block_until_ready(
        ops.decode_attention(q1, kc, vc, lens)), repeat=1)
    bytes_moved = 2 * B * S * KV * D * 2
    emit("kernel_decode_4k", us, f"hbm_bytes/call={bytes_moved:.2e}")


if __name__ == "__main__":
    run()
