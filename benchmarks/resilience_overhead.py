"""Resilience overhead: what durable checkpointing costs on the hot path.

Crash-safety is only free if nobody pays for it when nothing crashes.
Two measurements, one gate:

1. **Sweep checkpointing** — `sweep_solve` over a w2 grid with and
   without ``checkpoint_dir=`` at the *same* chunking (chunk_size is
   honored either way, so the solve work is identical and the delta is
   purely the per-chunk atomic save).  The run asserts the checkpointed
   sweep stays within 5% wall-clock of the uncheckpointed one — the CI
   resilience gate.
2. **FleetStream.save()** — per-save cost of persisting the full chunk
   seam (queues, sketches, RNG), reported as ms/save and as relative
   overhead at the worst-case save-every-chunk cadence (informational:
   real deployments save every N chunks and divide this by N).

Usage:  PYTHONPATH=src python -m benchmarks.resilience_overhead [--smoke]
            [--json BENCH_resilience.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core import sweep_solve
from repro.core.policies import q_policy
from repro.serving import FleetStream

from .common import emit, emit_json, paper_spec

MAX_SWEEP_OVERHEAD = 0.05  # CI gate: durable sweeps within 5% wall-clock


def _grid(n, rho=0.88, s_max=384, b_max=16):
    # slow-mixing, realistically-sized chunks: the async save overlaps the
    # next chunk's solve, so the gate measures the steady-state cost, not
    # an fsync against a toy 20 ms solve
    base = paper_spec(rho=rho, s_max=s_max, b_max=b_max)
    return [
        dataclasses.replace(base, w2=float(w))
        for w in np.linspace(0.0, 12.0, n)
    ]


def _time_sweep(specs, chunk_size, ckpt_dir, repeat):
    best = np.inf
    for r in range(repeat):
        kw = dict(chunk_size=chunk_size)
        if ckpt_dir is not None:
            d = Path(ckpt_dir) / f"rep{r}"  # fresh dir: no resume shortcut
            kw["checkpoint_dir"] = str(d)
        t0 = time.perf_counter()
        sweep_solve(specs, **kw)
        best = min(best, time.perf_counter() - t0)
    return best


def bench_sweep(n_specs, chunk_size, repeat):
    specs = _grid(n_specs)
    sweep_solve(specs, chunk_size=chunk_size)  # compile warm-up
    t_plain = _time_sweep(specs, chunk_size, None, repeat)
    with tempfile.TemporaryDirectory() as td:
        t_ck = _time_sweep(specs, chunk_size, td, repeat)
    overhead = t_ck / t_plain - 1.0
    n_saves = -(-n_specs // chunk_size)
    emit("sweep_plain", t_plain * 1e6, f"{n_specs} specs")
    emit("sweep_checkpointed", t_ck * 1e6, f"{n_saves} saves")
    emit("sweep_overhead", (t_ck - t_plain) * 1e6, f"{overhead:+.2%}")
    return {
        "n_specs": n_specs,
        "chunk_size": chunk_size,
        "wall_s_plain": t_plain,
        "wall_s_checkpointed": t_ck,
        "overhead_frac": overhead,
        "gate_frac": MAX_SWEEP_OVERHEAD,
        "within_gate": overhead <= MAX_SWEEP_OVERHEAD,
    }


def bench_stream(n_arrivals, chunk, repeat):
    b_max = 16
    from repro.core import GOOGLENET_P4_LATENCY, ServiceModel

    svc = ServiceModel(latency=GOOGLENET_P4_LATENCY, family="det")
    means = np.array(
        [0.0] + [float(svc.mean(b)) for b in range(1, b_max + 1)]
    )
    lam = 2 * 0.7 * b_max / float(svc.mean(b_max))
    tr = np.cumsum(
        np.random.default_rng(0).exponential(1.0 / lam, n_arrivals)
    )
    tabs = np.stack([q_policy(q, 96, b_max) for q in (4, 8)])
    kw = dict(router="jsq", means=means, b_max=b_max, slo=3.0)

    def run(save_dir):
        fs = FleetStream(tabs, **kw)
        for lo in range(0, len(tr), chunk):
            fs.push(tr[lo:lo + chunk])
            if save_dir is not None:
                fs.save(save_dir)
        return fs.finish()

    run(None)  # compile warm-up
    t_plain = np.inf
    for _ in range(repeat):
        t0 = time.perf_counter()
        run(None)
        t_plain = min(t_plain, time.perf_counter() - t0)
    t_saved = np.inf
    n_saves = -(-len(tr) // chunk)
    for _ in range(repeat):
        with tempfile.TemporaryDirectory() as td:
            t0 = time.perf_counter()
            run(td)
            t_saved = min(t_saved, time.perf_counter() - t0)
    ms_per_save = (t_saved - t_plain) / n_saves * 1e3
    emit("stream_plain", t_plain * 1e6, f"{n_arrivals} arrivals")
    emit("stream_save_every_chunk", t_saved * 1e6, f"{n_saves} saves")
    emit("stream_ms_per_save", ms_per_save * 1e3, f"{ms_per_save:.2f} ms")
    return {
        "n_arrivals": n_arrivals,
        "chunk": chunk,
        "n_saves": n_saves,
        "wall_s_plain": t_plain,
        "wall_s_save_every_chunk": t_saved,
        "ms_per_save": ms_per_save,
        "overhead_frac_worst_cadence": t_saved / t_plain - 1.0,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    if args.smoke:
        sweep = bench_sweep(n_specs=32, chunk_size=8, repeat=3)
        stream = bench_stream(n_arrivals=20_000, chunk=2000, repeat=2)
    else:
        sweep = bench_sweep(n_specs=64, chunk_size=16, repeat=3)
        stream = bench_stream(n_arrivals=200_000, chunk=8000, repeat=3)
    payload = {"sweep": sweep, "stream": stream}
    if args.json:
        emit_json(args.json, "resilience_overhead", payload)
    assert sweep["within_gate"], (
        f"checkpointed sweep overhead {sweep['overhead_frac']:+.2%} exceeds "
        f"the {MAX_SWEEP_OVERHEAD:.0%} gate"
    )
    print(
        f"resilience gate: sweep overhead {sweep['overhead_frac']:+.2%} "
        f"<= {MAX_SWEEP_OVERHEAD:.0%}; stream save "
        f"{stream['ms_per_save']:.2f} ms/save"
    )


if __name__ == "__main__":
    main()
