"""Paper Fig. 5: latency-energy tradeoff curves + Pareto dominance.

Each rho's w2 curve is one batched sweep (smdp_tradeoff_curve ->
sweep.sweep_solve): a single jitted RVI call per truncation round.
"""
from __future__ import annotations

from repro.core.tradeoff import benchmark_points, smdp_tradeoff_curve

from .common import emit, paper_spec, timed

W2S = [0.0, 0.3, 0.8, 1.3, 1.6, 2.2, 5.0, 15.0, 50.0]
W2S_SMOKE = [0.0, 1.6, 15.0]


def run(smoke: bool = False) -> None:
    w2s = W2S_SMOKE if smoke else W2S
    for rho in (0.3, 0.7):
        spec = paper_spec(rho=rho)
        curve, us = timed(smdp_tradeoff_curve, spec, w2s)
        bench = benchmark_points(spec)
        dominated_by_bench = 0
        for pt in curve:
            for w_b, p_b in bench.values():
                if w_b < pt.w_bar - 1e-6 and p_b < pt.p_bar - 1e-6:
                    dominated_by_bench += 1
        pts = ";".join(f"w2={p.w2}:W={p.w_bar:.2f}ms:P={p.p_bar:.2f}W" for p in curve[:4])
        emit(
            f"fig5_tradeoff_rho{rho}",
            us / len(w2s),
            f"smdp_points_dominated={dominated_by_bench}/ {len(curve)};{pts}",
        )


if __name__ == "__main__":
    run()
