"""Paper Fig. 10 + Table II: abstract cost c_o vs required truncation s_max.

Reproduces the paper's headline efficiency claim: with c_o ~ 100 the
smallest acceptable s_max (Delta < 1e-3) drops dramatically vs c_o = 0,
cutting space complexity ~63% and time complexity ~98%.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import build_smdp, evaluate_policy, relative_value_iteration

from .common import emit, paper_spec, timed

DELTA = 1e-3
S_GRID = list(range(36, 260, 8))
C_OS = (10000.0, 1000.0, 100.0, 10.0, 0.0)


def min_smax(c_o: float, rho: float = 0.9, w2: float = 1.0):
    """Smallest s_max whose solution is Delta-acceptable (paper Sec. V-A)."""
    for s_max in S_GRID:
        spec = paper_spec(rho=rho, w2=w2, s_max=s_max, c_o=c_o)
        mdp = build_smdp(spec)
        res = relative_value_iteration(mdp, eps=1e-2, max_iter=10_000)
        ev = evaluate_policy(mdp, res.policy)
        if ev.delta < DELTA:
            return s_max, res, ev
    return None, None, None


def run(smoke: bool = False) -> None:
    results = {}
    for c_o in (100.0, 0.0) if smoke else C_OS:
        (s_min, res, ev), us = timed(min_smax, c_o)
        if s_min is None:
            emit(f"table2_co_{c_o:g}", us, "no_acceptable_smax<=256")
            continue
        space = (res.policy.shape[0] - 1) * 33 * 2  # ~ B_max * s_max * 2
        time_c = res.iterations * 33 * s_min**2
        results[c_o] = (s_min, res.iterations, space, time_c)
        emit(
            f"table2_co_{c_o:g}",
            us,
            f"min_smax={s_min};iters={res.iterations};"
            f"space~{space};time~{time_c:.2e};g={ev.g:.4f}",
        )
    if 0.0 in results and 100.0 in results:
        s0, i0, sp0, t0 = results[0.0]
        s1, i1, sp1, t1 = results[100.0]
        emit(
            "table2_reduction_co100_vs_co0",
            0.0,
            f"smax:{s0}->{s1};space_saved={1-sp1/sp0:.1%};time_saved={1-t1/t0:.1%}",
        )


if __name__ == "__main__":
    run()
