# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark suite entry point: every paper table/figure + beyond-paper runs.

Usage:  PYTHONPATH=src python -m benchmarks.run [--only substring] [--smoke]

--smoke runs every suite in its reduced mode (smaller grids / horizons /
epoch counts — each module's ``run(smoke=True)``), the same modes the CI
bench-smoke job exercises; a full pass in minutes instead of hours.
Serving-side suites route through the unified engine (and its compiled
backend where the contender is table-static); solver-side suites route
through the batched sweep engine.
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter on module names")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced mode for every benchmark (CI-sized)")
    args = ap.parse_args()

    from . import (
        appE_structure_breaks,
        degraded_frontier,
        perf_ablation,
        fig3_policies,
        fig4_cost,
        fig5_tradeoff,
        fig6_percentiles,
        fig7_ideal_parallel,
        fig8_log_energy,
        fig9_cov,
        fig10_abstract_cost,
        fleet_frontier,
        kernel_micro,
        mmpp_bursty,
        roofline_report,
        sweep_scaling,
        table3_iteration_algos,
        tpu_profile_scenario,
    )

    suites = [
        ("fig3_policies", fig3_policies.run),
        ("fig4_cost", fig4_cost.run),
        ("fig5_tradeoff", fig5_tradeoff.run),
        ("fig6_percentiles", fig6_percentiles.run),
        ("fig7_ideal_parallel", fig7_ideal_parallel.run),
        ("fig8_log_energy", fig8_log_energy.run),
        ("fig9_cov", fig9_cov.run),
        ("fig10_abstract_cost", fig10_abstract_cost.run),
        ("sweep_scaling", sweep_scaling.run),
        ("table3_iteration_algos", table3_iteration_algos.run),
        ("appE_structure_breaks", appE_structure_breaks.run),
        ("tpu_profile_scenario", tpu_profile_scenario.run),
        ("mmpp_bursty", mmpp_bursty.run),
        ("fleet_frontier", fleet_frontier.run),
        ("degraded_frontier", degraded_frontier.run),
        ("kernel_micro", kernel_micro.run),
        ("roofline_report", roofline_report.run),
        ("perf_ablation", perf_ablation.run),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        kw = {}
        if args.smoke:
            if "smoke" not in inspect.signature(fn).parameters:
                raise SystemExit(
                    f"{name}.run() has no reduced mode; every benchmark "
                    "must accept smoke= (see --smoke)"
                )
            kw["smoke"] = True
        t0 = time.perf_counter()
        try:
            fn(**kw)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},nan,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
        print(
            f"# {name} finished in {time.perf_counter() - t0:.1f}s",
            file=sys.stderr,
            flush=True,
        )
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
