# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark suite entry point: every paper table/figure + beyond-paper runs.

Usage:  PYTHONPATH=src python -m benchmarks.run [--only substring]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter on module names")
    args = ap.parse_args()

    from . import (
        appE_structure_breaks,
        perf_ablation,
        fig3_policies,
        fig4_cost,
        fig5_tradeoff,
        fig6_percentiles,
        fig7_ideal_parallel,
        fig8_log_energy,
        fig9_cov,
        fig10_abstract_cost,
        kernel_micro,
        mmpp_bursty,
        roofline_report,
        sweep_scaling,
        table3_iteration_algos,
        tpu_profile_scenario,
    )

    suites = [
        ("fig3_policies", fig3_policies.run),
        ("fig4_cost", fig4_cost.run),
        ("fig5_tradeoff", fig5_tradeoff.run),
        ("fig6_percentiles", fig6_percentiles.run),
        ("fig7_ideal_parallel", fig7_ideal_parallel.run),
        ("fig8_log_energy", fig8_log_energy.run),
        ("fig9_cov", fig9_cov.run),
        ("fig10_abstract_cost", fig10_abstract_cost.run),
        ("sweep_scaling", sweep_scaling.run),
        ("table3_iteration_algos", table3_iteration_algos.run),
        ("appE_structure_breaks", appE_structure_breaks.run),
        ("tpu_profile_scenario", tpu_profile_scenario.run),
        ("mmpp_bursty", mmpp_bursty.run),
        ("kernel_micro", kernel_micro.run),
        ("roofline_report", roofline_report.run),
        ("perf_ablation", perf_ablation.run),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},nan,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
        print(
            f"# {name} finished in {time.perf_counter() - t0:.1f}s",
            file=sys.stderr,
            flush=True,
        )
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
