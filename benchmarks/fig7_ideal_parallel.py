"""Paper Fig. 7: batch-size independent service time (ideal parallelism)."""
from __future__ import annotations

from repro.core import IDEAL_PARALLEL_LATENCY
from repro.core.tradeoff import benchmark_points, smdp_tradeoff_curve

from .common import emit, paper_spec, timed

W2S = [0.0, 0.5, 1.5, 5.0, 20.0]


def run(smoke: bool = False) -> None:
    w2s = [0.0, 1.5, 20.0] if smoke else W2S
    for rho in (0.3, 0.7):
        spec = paper_spec(rho=rho, latency=IDEAL_PARALLEL_LATENCY)
        curve, us = timed(smdp_tradeoff_curve, spec, w2s)
        bench = benchmark_points(spec)
        # paper claim: with constant l(b), max batching approaches greedy
        # latency at high load; SMDP still never dominated
        dominated = sum(
            1 for pt in curve for (w_b, p_b) in bench.values()
            if w_b < pt.w_bar - 1e-6 and p_b < pt.p_bar - 1e-6
        )
        g_w = bench.get("greedy", (float("nan"),) * 2)[0]
        m_w = bench.get("static_32", (float("nan"),) * 2)[0]
        emit(
            f"fig7_ideal_parallel_rho{rho}",
            us / len(w2s),
            f"dominated={dominated};greedy_W={g_w:.2f};max_batch_W={m_w:.2f}",
        )


if __name__ == "__main__":
    run()
