"""Beyond-paper scenario: SMDP batching on TPU-v5e roofline profiles.

For each assigned architecture we derive l(b), zeta(b) from the roofline
model (core/profiles.py), solve the SMDP, and report the policy gain over
greedy/static batching — the paper's technique applied to OUR model zoo on
OUR target hardware.
"""
from __future__ import annotations

import numpy as np

from repro.configs import ARCHS
from repro.core import SMDPSpec, build_smdp, evaluate_policy, greedy_policy, \
    relative_value_iteration, static_policy
from repro.core.profiles import tpu_service_model, workload_for_arch

from .common import emit, timed

BMAX = 32


def arch_workload(cfg, chips=8):
    state_bytes = None
    if cfg.sub_quadratic:
        state_bytes = (
            cfg.n_layers * cfg.n_ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
            if cfg.ssm_state
            else cfg.n_layers * cfg.n_heads * cfg.head_dim**2 * 4
        )
    return workload_for_arch(
        n_params_active=cfg.n_params_active(),
        n_layers=cfg.n_layers,
        kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim,
        context_len=8192,
        n_tokens=32,
        chips=chips,
        state_bytes=state_bytes,
    )


def run(smoke: bool = False) -> None:
    archs = list(ARCHS.items())
    if smoke:
        archs = archs[:3]
    for name, cfg in archs:
        svc, energy = tpu_service_model(arch_workload(cfg))
        lam = 0.6 * BMAX / float(svc.mean(BMAX))

        def solve_and_compare():
            spec = SMDPSpec(lam=lam, service=svc, energy=energy, b_min=1,
                            b_max=BMAX, w1=1.0, w2=1.0, s_max=128, c_o=100.0)
            mdp = build_smdp(spec)
            res = relative_value_iteration(mdp)
            ev = evaluate_policy(mdp, res.policy)
            g_greedy = evaluate_policy(mdp, greedy_policy(128, 1, BMAX)).g
            g_static8 = evaluate_policy(mdp, static_policy(8, 128)).g
            return ev, g_greedy, g_static8

        (ev, g_greedy, g_static8), us = timed(solve_and_compare)
        gain_g = (g_greedy - ev.g) / g_greedy
        gain_s = (g_static8 - ev.g) / g_static8
        emit(
            f"tpu_profile_{name}",
            us,
            f"W={ev.w_bar*1e0:.3f}ms;P={ev.p_bar:.1f}W;"
            f"gain_vs_greedy={gain_g:.1%};gain_vs_static8={gain_s:.1%}",
        )


if __name__ == "__main__":
    run()
