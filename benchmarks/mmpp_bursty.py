"""Bursty MMPP(2) serving on the unified engine: bank retuning vs the field.

The paper's Sec.-VIII proposal, measured end-to-end: solve a lambda-grid
sweep bank once (core.sweep.sweep_bank), replay the SAME MMPP(2) arrival
trace through the one serving kernel under every contender, and compare
mean weighted cost (W_mean + w2 * power):

  * adaptive    — AdaptiveController: online rate estimate retunes the
    bank table, hysteresis at regime boundaries;
  * fixed_*     — every single fixed-lambda SMDP table from the same bank
    (the mean-rate table is the strongest of these);
  * oracle      — per-phase tables selected by the true phase trace (the
    estimation-free upper bound);
  * greedy      — largest feasible batch now.

Headline claims (tracked in BENCH_serving.json):
  * adaptive beats every fixed table from its own bank on the bursty
    scenario (section per scenario, Python engine: adaptive is stateful);
  * the "simulator" section is the perf trajectory of the compiled backend
    (serving.compiled): the multi-seed seeds x tables fixed-bank
    comparison as ONE vmapped scan dispatch vs the Python event loop —
    equal decision sequences (asserted via serving.engine.verify_backends)
    at a >= 25x wall-clock target, with events/sec for both backends;
  * the "compiled_adaptive" section is the same trajectory for the
    DEPLOYABLE policy: the AdaptiveController folded into the scan carry
    (serving.compiled.AdaptiveLane / run_grid_adaptive) vs the stateful
    Python engine — decision-for-decision certified, per-seed cost parity
    at rtol 1e-9, gated at a >= 10x wall-clock floor (smoke size too);
  * the "exact_modulated" section quantifies the phase-decomposition
    heuristic's gap (the ROADMAP open item): the exact MMPP-aware solve
    (core.solve_modulated, (phase, queue) product chain) vs the per-phase
    heuristic bank vs the single mean-rate table — provably on the
    modulated chain (g ordering) and measured on simulated traces through
    the compiled phase-indexed lane (verify_backends-gated).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs.googlenet_p4 import B_MAX, energy_table, paper_spec, service
from repro.core.smdp import PhaseConfig, build_smdp_modulated, modulated_spec
from repro.core.sweep import solve_modulated, sweep_bank
from repro.core.evaluate import evaluate_policy_modulated
from repro.serving import (
    AdaptiveController,
    GreedyScheduler,
    OraclePhaseScheduler,
    ServingEngine,
    SMDPScheduler,
    as_action_table,
    run_grid,
    verify_backends,
)
from repro.serving.arrivals import MMPP2, TraceProcess
from repro.serving.compiled import (
    AdaptiveLane,
    pad_arrivals,
    pad_arrivals_batch,
    run_grid_adaptive,
)

from .common import emit, emit_json, timed

SVC = service()
EN = energy_table()

#: (scenario, rho slow phase, rho fast phase, w2, dwell slow, dwell fast)
#: "bursty" is the headline: a quiet floor with short intense bursts, where
#: every fixed table loses structurally at one end (measured: adaptive
#: beats the best fixed table by 2-10% across trace seeds and configs);
#: "balanced" documents the large-w2 finding carried over from the old
#: benchmark: energy weight pushes every rate's policy toward max-batching,
#: so a single high-rate table is already near-optimal and adaptation can
#: only tie.
SCENARIOS = (
    ("bursty", 0.08, 0.85, 0.5, 4000.0, 800.0),
    ("balanced", 0.10, 0.85, 1.0, 1500.0, 1500.0),
)


def run_scenario(name, r1, r2, w2, dwell1, dwell2, *, horizon, grid_points,
                 seed=7):
    mu_max = B_MAX / float(SVC.mean(B_MAX))
    m = MMPP2(lam1=r1 * mu_max, lam2=r2 * mu_max, dwell1=dwell1,
              dwell2=dwell2)
    lam_grid = sorted(
        {round(float(x), 9)
         for x in [*np.linspace(m.lam1, m.lam2, grid_points), m.mean_rate]}
    )
    bank = sweep_bank(paper_spec(rho=0.5, w2=w2), lam_grid)
    trace, switches = m.sample_arrivals(horizon, np.random.default_rng(2))
    phase_tables = {
        0: bank.tables[bank.nearest(lam=m.lam1, w2=w2)],
        1: bank.tables[bank.nearest(lam=m.lam2, w2=w2)],
    }
    scheds = {
        "adaptive": AdaptiveController(
            bank, ewma=0.15, margin=0.2, min_dwell=20.0, w2=w2
        ),
        "oracle": OraclePhaseScheduler(phase_tables, switches),
        "greedy": GreedyScheduler(1, B_MAX),
    }
    for lam in lam_grid:
        scheds[f"fixed_lam={lam:.4f}"] = bank.scheduler(lam=lam, w2=w2)
    out = {}
    for sname, sched in scheds.items():
        eng = ServingEngine(
            sched, arrivals=TraceProcess(trace), b_max=B_MAX, service=SVC,
            energy_table=EN, seed=seed,
        )
        rep = eng.run(n_epochs=None)
        out[sname] = {
            "cost": float(rep.weighted_cost(w2)),
            "W_mean": float(rep.latencies.mean()),
            "P95": float(rep.percentile(95)),
            "power": float(rep.power),
            "mean_batch": float(rep.mean_batch),
            "n_served": int(rep.n_served),
        }
    return m, lam_grid, bank, out


def simulator_throughput(m, bank, w2, *, horizon, n_seeds, verify_all):
    """Seeds x tables fixed-bank comparison: Python loop vs one dispatch.

    The same work both ways — every (seed trace, fixed table or greedy)
    pair run to trace exhaustion + drain — with decision-sequence equality
    asserted on shared traces, so the speedup is at equal schedules.
    Compiled timing excludes the one-off jit compile (warm-up dispatch),
    matching how the solver benchmarks report steady-state throughput.
    """
    keys, tables = bank.stacked()
    greedy_tab = as_action_table(GreedyScheduler(1, B_MAX), B_MAX)
    L = max(tables.shape[1], len(greedy_tab))

    def pad(t):
        return np.concatenate([t, np.full(L - len(t), t[-1], dtype=np.int64)])

    tables = np.stack([pad(t) for t in tables] + [pad(greedy_tab)])
    labels = [f"fixed_lam={k[0]:.4f}" for k in keys] + ["greedy"]
    traces = [
        m.sample_arrivals(horizon, np.random.default_rng(100 + s))[0]
        for s in range(n_seeds)
    ]
    means = np.array([0.0] + [float(SVC.mean(b)) for b in range(1, B_MAX + 1)])
    arrs = pad_arrivals_batch(traces)

    # equal decision sequences on shared traces (the acceptance gate):
    # every table on the first seed trace, or the two extremes in smoke
    pairs = (
        [(0, p) for p in range(len(tables))]
        if verify_all
        else [(0, 0), (0, len(tables) - 1)]
    )
    for s, p in pairs:
        verify_backends(
            tables[p], traces[s], service=SVC, energy_table=EN, b_max=B_MAX
        )

    # Python loop over the grid
    t0 = time.perf_counter()
    py_cost = np.empty((n_seeds, len(tables)))
    for s, tr in enumerate(traces):
        for p, tab in enumerate(tables):
            eng = ServingEngine(
                SMDPScheduler.from_table(tab), arrivals=TraceProcess(tr),
                b_max=B_MAX, service=SVC, energy_table=EN,
            )
            rep = eng.run(n_epochs=None)
            py_cost[s, p] = rep.weighted_cost(w2)
    t_python = time.perf_counter() - t0

    # one vmapped dispatch (warm-up compiles, best-of-3 steady state — the
    # same discipline as the solver benchmarks; the Python loop above is
    # long enough to self-average)
    kw = dict(means=means, zeta=EN, b_max=B_MAX)
    run_grid(tables, arrs, **kw)
    t_compiled = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        g = run_grid(tables, arrs, **kw)
        t_compiled = min(t_compiled, time.perf_counter() - t0)
    # decision sequences are identical (verified above), so both backends
    # processed the same events: served requests + decision epochs
    events = g["events_total"]
    c_cost = g["w_mean"] + w2 * g["power"]
    np.testing.assert_allclose(c_cost, py_cost, rtol=1e-9)
    return {
        "n_seeds": n_seeds,
        "n_tables": int(len(tables)),
        "labels": labels,
        "horizon": horizon,
        "n_requests": int(g["n_served"].sum()),
        "events": events,
        "t_python_s": t_python,
        "t_compiled_s": t_compiled,
        "events_per_sec_python": events / t_python,
        "events_per_sec_compiled": events / t_compiled,
        "speedup": t_python / t_compiled,
        "decisions_equal": True,  # verify_backends raised otherwise
        "verified_pairs": len(pairs),
    }


def compiled_adaptive_throughput(m, bank, w2, *, horizon, n_seeds):
    """The deployable policy at scan throughput: AdaptiveController both ways.

    The headline scheduler of the ``bursty`` scenario — the online
    EWMA-estimate / hysteresis bank retuner — run over n_seeds fresh MMPP
    traces twice: the stateful Python engine per seed, and ONE
    run_grid_adaptive dispatch with the controller folded into the scan
    carry (serving.compiled.AdaptiveLane).  Decision-for-decision equality
    is certified on the first trace via verify_backends(scheduler=...),
    per-seed weighted cost is asserted equal across backends (rtol 1e-9),
    and the wall-clock ratio is gated at the >= 10x floor — at smoke size
    too, so CI trips if the adaptive lane ever falls off the compiled path.
    """
    ctrl_kw = dict(ewma=0.15, margin=0.2, min_dwell=20.0, w2=w2)
    traces = [
        m.sample_arrivals(horizon, np.random.default_rng(300 + s))[0]
        for s in range(n_seeds)
    ]
    means = np.array([0.0] + [float(SVC.mean(b)) for b in range(1, B_MAX + 1)])

    # decision-sequence equality on the first trace (the acceptance gate):
    # fresh controller per backend, same trace, every action compared
    verify_backends(
        None, traces[0], service=SVC, energy_table=EN, b_max=B_MAX,
        scheduler=lambda: AdaptiveController(bank, **ctrl_kw),
    )

    # Python loop: one stateful engine per seed trace
    t0 = time.perf_counter()
    py_cost = np.empty(n_seeds)
    py_switches = np.empty(n_seeds, dtype=np.int64)
    for s, tr in enumerate(traces):
        ctrl = AdaptiveController(bank, **ctrl_kw)
        eng = ServingEngine(
            ctrl, arrivals=TraceProcess(tr), b_max=B_MAX, service=SVC,
            energy_table=EN,
        )
        rep = eng.run(n_epochs=None)
        py_cost[s] = rep.weighted_cost(w2)
        py_switches[s] = ctrl.n_switches
    t_python = time.perf_counter() - t0

    # one seeds-vmapped dispatch, controller in the carry (warm-up
    # compiles, best-of-3 steady state — same discipline as "simulator")
    lane = AdaptiveLane.from_controller(AdaptiveController(bank, **ctrl_kw))
    arrs = pad_arrivals_batch(traces)
    kw = dict(adaptive=lane, means=means, zeta=EN, b_max=B_MAX)
    run_grid_adaptive(arrs, **kw)
    t_compiled = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        g = run_grid_adaptive(arrs, **kw)
        t_compiled = min(t_compiled, time.perf_counter() - t0)
    c_cost = g["w_mean"] + w2 * g["power"]
    np.testing.assert_allclose(c_cost, py_cost, rtol=1e-9)
    np.testing.assert_array_equal(g["ad_n_switches"], py_switches)
    events = g["events_total"]
    speedup = t_python / t_compiled
    assert speedup >= 10.0, (
        f"compiled adaptive lane below the 10x floor: {speedup:.1f}x"
    )
    return {
        "n_seeds": n_seeds,
        "horizon": horizon,
        "controller": {k: float(v) for k, v in ctrl_kw.items()},
        "n_bank_tables": int(lane.tables.shape[0]),
        "n_requests": int(g["n_served"].sum()),
        "events": events,
        "n_switches": [int(x) for x in py_switches],
        "cost_mean": float(py_cost.mean()),
        "t_python_s": t_python,
        "t_compiled_s": t_compiled,
        "events_per_sec_python": events / t_python,
        "events_per_sec_compiled": events / t_compiled,
        "speedup": speedup,
        "cost_parity_rtol": 1e-9,
        "decisions_equal": True,  # verify_backends raised otherwise
        "meets_10x_floor": True,  # asserted above
    }


def exact_modulated_gap(m, bank, w2, *, horizon, n_seeds, s_cap):
    """Exact MMPP-aware policy vs phase-heuristic bank vs single table.

    Two comparisons, both recorded:
      * *chain* — all three policies evaluated on the SAME modulated
        product chain (core.evaluate_policy_modulated).  The exact policy
        optimizes this chain, so g_exact <= g_heuristic is a theorem (up
        to solver eps); the recorded gap is the heuristic's true loss.
      * *simulated* — the same three policies replayed over n_seeds MMPP
        traces through the compiled phase-indexed lane (true-phase row
        selection for all three, so the gap isolates the *policy*, not
        the phase detector), gated by verify_backends on the first trace.
    """
    phases = PhaseConfig.from_mmpp(m)
    spec = modulated_spec(paper_spec(rho=0.5, w2=w2), phases)
    exact = solve_modulated(spec, phases, max_s_max=s_cap)
    s_max = exact.spec.s_max
    K = phases.n_phases

    def lift(tab):
        """1-D bank table -> feasible (S,) policy on the grown chain."""
        t = np.asarray(tab, dtype=np.int64)
        pol = np.array(
            [t[min(s, len(t) - 1)] for s in range(s_max + 1)], dtype=np.int64
        )
        return np.append(pol, pol[s_max])  # S_o row mirrors s_max (eq. 30)

    heur_pol = np.stack(
        [
            lift(bank.tables[bank.nearest(lam=m.lam1, w2=w2)]),
            lift(bank.tables[bank.nearest(lam=m.lam2, w2=w2)]),
        ]
    )
    single_pol = np.tile(
        lift(bank.tables[bank.nearest(lam=m.mean_rate, w2=w2)])[None], (K, 1)
    )
    mb = build_smdp_modulated(exact.spec, phases)
    g_exact = float(exact.eval.g)
    g_heur = float(evaluate_policy_modulated(mb, 0, heur_pol).g)
    g_single = float(evaluate_policy_modulated(mb, 0, single_pol).g)

    # simulated replay: (3, K, L) stack through the compiled phase lane
    tables = np.stack(
        [exact.action_table(s_max), heur_pol[:, : s_max + 1],
         single_pol[:, : s_max + 1]]
    )
    labels = ["exact_modulated", "phase_heuristic", "single_table"]
    traces, phase_streams = [], []
    for s in range(n_seeds):
        tr, sw = m.sample_arrivals(horizon, np.random.default_rng(500 + s))
        st = np.array([t for t, _ in sw])
        sp = np.array([p for _, p in sw], dtype=np.int64)
        traces.append(tr)
        phase_streams.append(
            sp[np.maximum(np.searchsorted(st, tr, side="right") - 1, 0)]
        )
    # compiled phase lane == python oracle path on the first trace (gate)
    verify_backends(
        tables[0], traces[0], service=SVC, energy_table=EN, b_max=B_MAX,
        phases=phase_streams[0],
    )
    arrs = pad_arrivals_batch(traces)
    phs = np.stack(
        [
            pad_arrivals(t, phases=p, size=arrs.shape[1])[2]
            for t, p in zip(traces, phase_streams)
        ]
    )
    means = np.array([0.0] + [float(SVC.mean(b)) for b in range(1, B_MAX + 1)])
    g = run_grid(tables, arrs, phases=phs, means=means, zeta=EN, b_max=B_MAX)
    sim_cost = g["w_mean"] + w2 * g["power"]  # (n_seeds, 3)
    sim_mean = sim_cost.mean(axis=0)
    return {
        "w2": w2,
        "s_max": int(s_max),
        "lam_grid_heuristic": [
            float(bank.nearest(lam=m.lam1, w2=w2)[0]),
            float(bank.nearest(lam=m.lam2, w2=w2)[0]),
        ],
        "g_exact": g_exact,
        "g_heuristic": g_heur,
        "g_single": g_single,
        "chain_gap_heuristic_vs_exact": (g_heur - g_exact) / g_heur,
        "chain_gap_single_vs_exact": (g_single - g_exact) / g_single,
        "exact_beats_or_ties_heuristic_chain": bool(
            g_exact <= g_heur * (1.0 + 1e-9)
        ),
        "labels": labels,
        "n_seeds": n_seeds,
        "horizon": horizon,
        "sim_cost_mean": {k: float(v) for k, v in zip(labels, sim_mean)},
        "sim_gap_heuristic_vs_exact": float(
            (sim_mean[1] - sim_mean[0]) / sim_mean[1]
        ),
        "sim_gap_single_vs_exact": float(
            (sim_mean[2] - sim_mean[0]) / sim_mean[2]
        ),
        "sim_exact_wins_per_seed": int(
            (sim_cost[:, 0] <= sim_cost[:, 1]).sum()
        ),
        "verified_compiled_phase_lane": True,  # verify_backends raised else
    }


def run(smoke: bool = False, json_path: str | None = None) -> None:
    horizon = 10_000.0 if smoke else 40_000.0
    grid_points = 3 if smoke else 5
    sections = {}
    sim_inputs = None
    for name, r1, r2, w2, dwell1, dwell2 in SCENARIOS:
        (m, lam_grid, bank, out), us = timed(
            run_scenario, name, r1, r2, w2, dwell1, dwell2,
            horizon=horizon, grid_points=grid_points,
        )
        if name == "bursty":
            sim_inputs = (m, bank, w2)
        fixed = {k: v["cost"] for k, v in out.items() if k.startswith("fixed_")}
        best_fixed_key = min(fixed, key=fixed.get)
        best_fixed = fixed[best_fixed_key]
        adaptive = out["adaptive"]["cost"]
        beats_all = adaptive < min(fixed.values())
        gain = (best_fixed - adaptive) / best_fixed
        emit(
            f"mmpp_{name}",
            us,
            f"adaptive={adaptive:.3f};best_fixed={best_fixed:.3f}"
            f"({best_fixed_key});oracle={out['oracle']['cost']:.3f};"
            f"greedy={out['greedy']['cost']:.3f};"
            f"beats_all_fixed={beats_all};gain_vs_best_fixed={gain:.1%}",
        )
        sections[name] = {
            "w2": w2,
            "lam_grid": [float(x) for x in lam_grid],
            "mmpp": {"lam1": m.lam1, "lam2": m.lam2,
                     "dwell1": m.dwell1, "dwell2": m.dwell2},
            "horizon": horizon,
            "schedulers": out,
            "adaptive_beats_all_fixed": bool(beats_all),
            "adaptive_gain_vs_best_fixed": float(gain),
        }
    m, bank, w2 = sim_inputs
    sim = simulator_throughput(
        m, bank, w2,
        horizon=horizon,
        n_seeds=4 if smoke else 6,
        verify_all=not smoke,
    )
    emit(
        "mmpp_sim_throughput",
        sim["t_compiled_s"] * 1e6,
        f"speedup={sim['speedup']:.1f}x;"
        f"ev/s_python={sim['events_per_sec_python']:.3g};"
        f"ev/s_compiled={sim['events_per_sec_compiled']:.3g};"
        f"seeds x tables={sim['n_seeds']}x{sim['n_tables']};"
        f"decisions_equal={sim['decisions_equal']}",
    )
    sections["simulator"] = sim
    ca = compiled_adaptive_throughput(
        m, bank, w2, horizon=horizon, n_seeds=3 if smoke else 6,
    )
    emit(
        "mmpp_compiled_adaptive",
        ca["t_compiled_s"] * 1e6,
        f"speedup={ca['speedup']:.1f}x;"
        f"ev/s_python={ca['events_per_sec_python']:.3g};"
        f"ev/s_compiled={ca['events_per_sec_compiled']:.3g};"
        f"switches={ca['n_switches']};"
        f"cost_parity_rtol={ca['cost_parity_rtol']:g};"
        f"decisions_equal={ca['decisions_equal']}",
    )
    sections["compiled_adaptive"] = ca
    gap, us = timed(
        exact_modulated_gap, m, bank, w2,
        horizon=horizon,
        n_seeds=2 if smoke else 5,
        s_cap=256 if smoke else 384,
    )
    emit(
        "mmpp_exact_modulated",
        us,
        f"chain_gap_heur={gap['chain_gap_heuristic_vs_exact']:.2%};"
        f"chain_gap_single={gap['chain_gap_single_vs_exact']:.2%};"
        f"sim_gap_heur={gap['sim_gap_heuristic_vs_exact']:.2%};"
        f"exact<=heur_chain={gap['exact_beats_or_ties_heuristic_chain']};"
        f"compiled_lane_verified={gap['verified_compiled_phase_lane']}",
    )
    sections["exact_modulated"] = gap
    if json_path:
        emit_json(json_path, "mmpp_bursty", sections)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced horizon/grid for CI")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="merge results into this JSON artifact")
    args = ap.parse_args()
    run(smoke=args.smoke, json_path=args.json)


if __name__ == "__main__":
    main()
