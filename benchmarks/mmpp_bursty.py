"""Beyond-paper (paper Sec. VIII): phase-aware SMDP under MMPP(2) traffic."""
from __future__ import annotations

import numpy as np

from repro.configs.googlenet_p4 import B_MAX, energy_table, paper_spec, service
from repro.core import solve
from repro.serving.mmpp import (
    MMPP2,
    PhaseAwareScheduler,
    run_mmpp,
    solve_phase_policies,
)
from repro.serving.scheduler import GreedyScheduler, SMDPScheduler

from .common import emit, timed

SVC = service()
EN = energy_table()


def run() -> None:
    """Finding (documented in EXPERIMENTS.md): phase-awareness pays on
    LATENCY-focused objectives (w2=0: +15% — phase policies differ in their
    control limits); with large w2 both phase policies converge towards
    max-batching and a single mean-rate policy is already near-optimal."""
    mu_max = B_MAX / float(SVC.mean(B_MAX))
    for name, r1, r2, w2 in (
        ("latency_focus", 0.05, 0.90, 0.0),
        ("balanced", 0.10, 0.85, 1.0),
    ):
        m = MMPP2(lam1=r1 * mu_max, lam2=r2 * mu_max,
                  dwell1=1000.0, dwell2=1000.0)
        rates = {0: m.lam1, 1: m.lam2}

        def compare():
            tables = solve_phase_policies(paper_spec(rho=0.5, w2=w2), rates)
            scheds = {
                "phase_aware": PhaseAwareScheduler(tables, rates, ewma=0.1),
                "mean_rate": SMDPScheduler(
                    solve(paper_spec(rho=m.mean_rate / mu_max, w2=w2))
                ),
                "greedy": GreedyScheduler(1, B_MAX),
            }
            out = {}
            for sname, sched in scheds.items():
                lat, en, span = run_mmpp(sched, m, SVC, EN, B_MAX, 40_000.0, seed=2)
                out[sname] = lat.mean() + w2 * en / span
            return out

        costs, us = timed(compare)
        gain = (costs["mean_rate"] - costs["phase_aware"]) / costs["mean_rate"]
        emit(
            f"mmpp_{name}",
            us,
            f"phase={costs['phase_aware']:.2f};mean={costs['mean_rate']:.2f};"
            f"greedy={costs['greedy']:.2f};phase_gain_vs_mean={gain:.1%}",
        )


if __name__ == "__main__":
    run()
