"""Paper Fig. 4: average cost per unit time, SMDP vs benchmarks.

The SMDP column of each rho's w2 grid is solved by one batched sweep
(tradeoff.average_cost_grid -> sweep.sweep_solve).
"""
from __future__ import annotations

import numpy as np

from repro.core.tradeoff import average_cost_grid

from .common import emit, paper_spec, timed


def run(smoke: bool = False) -> None:
    w2s = [0.0, 1.0, 7.0] if smoke else [0.0, 1.0, 3.0, 7.0, 15.0]
    for rho in (0.3, 0.7) if smoke else (0.1, 0.3, 0.7):
        grid, us = timed(average_cost_grid, paper_spec(rho=rho), w2s)
        smdp = np.asarray(grid["smdp"])
        worst_violation = 0.0
        best_gap = 0.0
        for name, costs in grid.items():
            if name == "smdp":
                continue
            c = np.asarray(costs)
            worst_violation = max(worst_violation, float((smdp - c).max()))
            best_gap = max(best_gap, float(np.nanmax((c - smdp) / smdp)))
        emit(
            f"fig4_avg_cost_rho{rho}",
            us / len(w2s),
            f"smdp_always_best={worst_violation <= 1e-9};"
            f"max_bench_excess={best_gap:.1%}",
        )


if __name__ == "__main__":
    run()
