"""Paper Appendix E: control-limit structure breaks in general cases.

Cases 4-7: B_min > 1, nonlinear energy, size-dependent service — the SMDP
solutions need NOT be control-limit policies (which is the argument for the
general solver over threshold search).
"""
from __future__ import annotations

from repro.core import ConstantProfile, LOG_ENERGY, ServiceModel, SMDPSpec, \
    solve, GOOGLENET_P4_LATENCY, GOOGLENET_P4_ENERGY
from repro.core.policies import is_control_limit

from .common import emit, timed

B = 8


def run(smoke: bool = False) -> None:
    rhos = (0.3, 0.7) if smoke else (0.1, 0.3, 0.5, 0.7, 0.9)
    w2s = (0.0, 1.0) if smoke else (0.0, 0.5, 1.0)
    cases = {
        # case 4: B_min = 5 (violates Assumption 2)
        "case4_bmin5": dict(latency=ConstantProfile(2.4252), family="det",
                            b_min=5, energy=GOOGLENET_P4_ENERGY),
        # case 5: log energy (violates Assumption 3)
        "case5_log_energy": dict(latency=ConstantProfile(2.4252), family="det",
                                 b_min=1, energy=LOG_ENERGY),
        # case 6/7: size-dependent service time (violates Assumption 1)
        "case6_size_dep": dict(latency=GOOGLENET_P4_LATENCY, family="det",
                               b_min=1, energy=GOOGLENET_P4_ENERGY),
        "case7_general": dict(latency=GOOGLENET_P4_LATENCY, family="expo",
                              b_min=3, energy=LOG_ENERGY),
    }
    for name, kw in cases.items():
        broke = 0
        total = 0

        def sweep():
            nonlocal broke, total
            svc = ServiceModel(latency=kw["latency"], family=kw["family"])
            mu = 1.0 / float(svc.mean(B))
            for rho in rhos:
                for w2 in w2s:
                    spec = SMDPSpec(
                        lam=rho * B * mu, service=svc, energy=kw["energy"],
                        b_min=kw["b_min"], b_max=B, w1=1.0, w2=w2,
                        s_max=100, c_o=100.0,
                    )
                    res = solve(spec, delta=1e-3, max_s_max=1024)
                    total += 1
                    is_cl, _ = is_control_limit(res.rvi.policy, res.spec.s_max, B)
                    broke += int(not is_cl)

        _, us = timed(sweep)
        emit(f"appE_{name}", us / max(total, 1), f"non_control_limit={broke}/{total}")


if __name__ == "__main__":
    run()
