"""Serial-vs-batched sweep scaling: the whole point of core/sweep.py.

Solves the same >=16-point w2 grid twice — once with the pre-batched
per-point loop (tradeoff.solve_serial) and once with the batched engine
(sweep_solve, one jitted vmapped RVI call per truncation round) — and
reports wall-clock plus the speedup.  Both paths are warmed up on a tiny
grid first so jit compilation is excluded from the comparison.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.sweep import sweep_solve
from repro.core.tradeoff import solve_serial

from .common import emit, paper_spec

import dataclasses

W2S = list(np.linspace(0.0, 15.0, 17))


def run() -> None:
    for rho in (0.3, 0.7):
        base = paper_spec(rho=rho)
        # warm-up: compile both paths' kernels at the sweep shapes (the
        # banded RVI specializes on the trimmed pmf band, which depends on
        # the arrival rate, so the warm-up must run the full grid)
        solve_serial(base, W2S)
        sweep_solve([dataclasses.replace(base, w2=float(w)) for w in W2S])

        # best-of-2: this box is small enough that scheduler noise is real
        t_serial = float("inf")
        for _ in range(2):
            t0 = time.perf_counter()
            serial = solve_serial(base, W2S)
            t_serial = min(t_serial, time.perf_counter() - t0)

        specs = [dataclasses.replace(base, w2=float(w)) for w in W2S]
        t_batched = float("inf")
        for _ in range(2):
            t0 = time.perf_counter()
            batched = sweep_solve(specs)
            t_batched = min(t_batched, time.perf_counter() - t0)

        worst_g = max(
            abs(s.eval.g - b.eval.g) / max(abs(s.eval.g), 1e-12)
            for s, b in zip(serial, batched)
        )
        emit(
            f"sweep_scaling_rho{rho}",
            t_batched * 1e6 / len(W2S),
            f"n={len(W2S)};serial_s={t_serial:.3f};batched_s={t_batched:.3f};"
            f"speedup={t_serial / t_batched:.1f}x;worst_rel_g_diff={worst_g:.2e}",
        )


if __name__ == "__main__":
    run()
