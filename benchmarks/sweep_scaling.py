"""Serial-vs-batched sweep scaling: the whole point of core/sweep.py.

Solves the same >=16-point w2 grid twice — once with the pre-batched
per-point loop (tradeoff.solve_serial) and once with the batched engine
(sweep_solve, one jitted vmapped RVI call per truncation round) — and
reports wall-clock plus the speedup.  Both paths are warmed up on a tiny
grid first so jit compilation is excluded from the comparison.  --smoke
shrinks the grid (one rho, 6 points) for the CI perf-trajectory job, which
collects the numbers into BENCH_serving.json via --json.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.sweep import sweep_solve
from repro.core.tradeoff import solve_serial

from .common import emit, emit_json, paper_spec

import dataclasses

W2S = list(np.linspace(0.0, 15.0, 17))
W2S_SMOKE = list(np.linspace(0.0, 15.0, 6))  # CI smoke: same span, 6 points


def run(smoke: bool = False, json_path: str | None = None) -> None:
    w2s = W2S_SMOKE if smoke else W2S
    rhos = (0.3,) if smoke else (0.3, 0.7)
    sections = {}
    for rho in rhos:
        base = paper_spec(rho=rho)
        # warm-up: compile both paths' kernels at the sweep shapes (the
        # banded RVI specializes on the trimmed pmf band, which depends on
        # the arrival rate, so the warm-up must run the full grid)
        solve_serial(base, w2s)
        sweep_solve([dataclasses.replace(base, w2=float(w)) for w in w2s])

        # best-of-2: this box is small enough that scheduler noise is real
        t_serial = float("inf")
        for _ in range(2):
            t0 = time.perf_counter()
            serial = solve_serial(base, w2s)
            t_serial = min(t_serial, time.perf_counter() - t0)

        specs = [dataclasses.replace(base, w2=float(w)) for w in w2s]
        t_batched = float("inf")
        for _ in range(2):
            t0 = time.perf_counter()
            batched = sweep_solve(specs)
            t_batched = min(t_batched, time.perf_counter() - t0)

        worst_g = max(
            abs(s.eval.g - b.eval.g) / max(abs(s.eval.g), 1e-12)
            for s, b in zip(serial, batched)
        )
        emit(
            f"sweep_scaling_rho{rho}",
            t_batched * 1e6 / len(w2s),
            f"n={len(w2s)};serial_s={t_serial:.3f};batched_s={t_batched:.3f};"
            f"speedup={t_serial / t_batched:.1f}x;worst_rel_g_diff={worst_g:.2e}",
        )
        sections[f"rho={rho}"] = {
            "n_specs": len(w2s),
            "serial_s": t_serial,
            "batched_s": t_batched,
            "speedup": t_serial / t_batched,
            "worst_rel_g_diff": worst_g,
        }
    if json_path:
        emit_json(json_path, "sweep_scaling", sections)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced grid (one rho, 6 w2 points) for CI")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="merge results into this JSON artifact")
    args = ap.parse_args()
    run(smoke=args.smoke, json_path=args.json)


if __name__ == "__main__":
    main()
