"""Serial vs batched vs accelerated sweep scaling (core/sweep.py).

Solves the same >=16-point w2 grid three ways — the pre-batched per-point
loop (tradeoff.solve_serial), the plain batched engine (sweep_solve with
accel="none", one jitted vmapped RVI call per truncation round), and the
accelerated default (accel="mpi": modified-policy-iteration polish) — and
reports wall-clock, speedups, and the lockstep backup counts.  All paths
are warmed up first so jit compilation is excluded.  --smoke shrinks the
grid to 6 w2 points (both rhos stay: 0.7 is where the accelerated solver
earns its keep) for the CI perf-trajectory job, which collects the
numbers into BENCH_serving.json via --json.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.sweep import sweep_solve
from repro.core.tradeoff import solve_serial

from .common import emit, emit_json, paper_spec

import dataclasses

W2S = list(np.linspace(0.0, 15.0, 17))
W2S_SMOKE = list(np.linspace(0.0, 15.0, 6))  # CI smoke: same span, 6 points


def _best_of(fn, repeat: int = 3) -> tuple:
    t_best, out = float("inf"), None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn()
        t_best = min(t_best, time.perf_counter() - t0)
    return out, t_best


def run(smoke: bool = False, json_path: str | None = None) -> None:
    w2s = W2S_SMOKE if smoke else W2S
    # smoke shrinks the grid but keeps the rho=0.7 point: that is where the
    # accelerated solver earns its keep, and CI should track it per commit
    rhos = (0.3, 0.7)
    sections = {}
    for rho in rhos:
        base = paper_spec(rho=rho)
        specs = [dataclasses.replace(base, w2=float(w)) for w in w2s]
        # warm-up: compile all paths' kernels at the sweep shapes (the
        # banded RVI specializes on the trimmed pmf band, which depends on
        # the arrival rate, so the warm-up must run the full grid)
        solve_serial(base, w2s)
        sweep_solve(specs, accel="none")
        sweep_solve(specs, accel="mpi")

        # best-of-3: this box is small enough that scheduler noise is real
        serial, t_serial = _best_of(lambda: solve_serial(base, w2s))
        batched, t_batched = _best_of(lambda: sweep_solve(specs, accel="none"))
        accel, t_accel = _best_of(lambda: sweep_solve(specs, accel="mpi"))

        worst_g = max(
            abs(s.eval.g - b.eval.g) / max(abs(s.eval.g), 1e-12)
            for s, b in zip(serial, batched)
        )
        worst_g_accel = max(
            abs(s.eval.g - a.eval.g) / max(abs(s.eval.g), 1e-12)
            for s, a in zip(serial, accel)
        )
        policies_equal = all(
            np.array_equal(b.policy, a.policy)
            for b, a in zip(batched, accel)
        )
        iters_plain = max(r.rvi.iterations for r in batched)
        iters_accel = max(r.rvi.iterations for r in accel)
        emit(
            f"sweep_scaling_rho{rho}",
            t_batched * 1e6 / len(w2s),
            f"n={len(w2s)};serial_s={t_serial:.3f};batched_s={t_batched:.3f};"
            f"accel_s={t_accel:.3f};speedup={t_serial / t_batched:.1f}x;"
            f"accel_vs_plain={t_batched / t_accel:.1f}x;"
            f"iters_plain={iters_plain};iters_accel={iters_accel};"
            f"worst_rel_g_diff={worst_g:.2e}",
        )
        sections[f"rho={rho}"] = {
            "n_specs": len(w2s),
            "serial_s": t_serial,
            "batched_s": t_batched,
            "accel_s": t_accel,
            "speedup": t_serial / t_batched,
            "speedup_accel": t_serial / t_accel,
            "accel_vs_plain": t_batched / t_accel,
            "iters_plain": iters_plain,
            "iters_accel": iters_accel,
            "accel_policies_match_plain": policies_equal,
            "worst_rel_g_diff": worst_g,
            "worst_rel_g_diff_accel": worst_g_accel,
        }
    if json_path:
        emit_json(json_path, "sweep_scaling", sections)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced grid (6 w2 points, both rhos) for CI")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="merge results into this JSON artifact")
    args = ap.parse_args()
    run(smoke=args.smoke, json_path=args.json)


if __name__ == "__main__":
    main()
