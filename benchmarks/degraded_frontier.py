"""Degraded-mode frontier: faults x failover routing x SMDP shedding.

Three questions the serving stack answers once fault injection exists:

1. **Certification** — do the Python reference loop and the compiled fleet
   kernel agree decision-for-decision under one shared FaultSchedule?
   `verify_faults` runs every router on Poisson AND MMPP2 traces; a
   mismatch raises and fails the job (this is the CI smoke gate).
2. **Fault matrix** — how do goodput / drop rate / P95 / power degrade as
   outages get harsher, per router?  Failover-aware routing (DOWN replicas
   masked, crashed batches requeued with bounded retries) keeps the fleet
   serving through moderate outage regimes.
3. **Overload-aware shedding** — under sustained overload (rho ~ 1.2) with
   a finite waiting room, does the drop-cost-aware finite-buffer SMDP
   policy (buffer == s_max, c_drop > 0) beat the blind tail-abstracted
   table solved for design load?  On bursty MMPP2 arrivals the aware
   policy serves earlier (serve-from threshold pulled down by the drop
   price), keeping buffer headroom for bursts: higher goodput, lower drop
   rate, lower mean wait.  The run asserts the seed-averaged MMPP2 win —
   the degraded-mode acceptance gate.

Usage:  PYTHONPATH=src python -m benchmarks.degraded_frontier [--smoke]
            [--json BENCH_degraded.json]
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import GOOGLENET_P4_ENERGY, GOOGLENET_P4_LATENCY, ServiceModel
from repro.core import SMDPSpec, solve
from repro.core.policies import q_policy
from repro.serving import (
    FaultModel,
    FaultSchedule,
    histogram_quantiles,
    simulate_fleet,
    verify_faults,
)
from repro.serving.arrivals import MMPP2

from .common import emit, emit_json, timed

#: small-card scale: the shedding question is per-replica, B = 16 keeps
#: the finite-buffer solve (B + 2 states) trivially fast
BMAX = 16
SVC = ServiceModel(latency=GOOGLENET_P4_LATENCY, family="det")
MEANS = np.array([0.0] + [float(SVC.mean(b)) for b in range(1, BMAX + 1)])
ZETA = np.array(
    [0.0] + [float(GOOGLENET_P4_ENERGY(b)) for b in range(1, BMAX + 1)]
)
ROUTERS = ("jsq", "batch_aware", "rr", "pow2")
#: severity ladder: MTBF in units of ~batch services, MTTR a few services
SEVERITIES = {
    "none": None,
    "moderate": FaultModel(mtbf=60.0, mttr=5.0, p_straggle=0.05,
                           straggle_mult=3.0),
    "severe": FaultModel(mtbf=25.0, mttr=8.0, p_straggle=0.15,
                         straggle_mult=4.0),
}


def _spec(rho: float, **kw) -> SMDPSpec:
    lam = rho * BMAX / float(SVC.mean(BMAX))
    return SMDPSpec(
        lam=lam, service=SVC, energy=GOOGLENET_P4_ENERGY, b_min=1,
        b_max=BMAX, w1=1.0, w2=1.0, **kw,
    )


def _trace(mode: str, lam: float, n: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if mode == "poisson":
        return np.cumsum(rng.exponential(1.0 / lam, n))
    m = MMPP2(lam1=0.25 * lam, lam2=1.75 * lam, dwell1=40.0, dwell2=40.0)
    times, _ = m.sample_arrivals(n / m.mean_rate, rng)
    return np.asarray(times)


def _stats(res) -> dict:
    """Goodput / drop / tail-latency summary of one FleetResult."""
    span = res.t_final
    offered = res.n_served + res.n_dropped + res.n_shed
    return {
        "goodput": float(res.n_served / span) if span > 0 else float("nan"),
        "drop_rate": (
            float((res.n_dropped + res.n_shed) / offered)
            if offered else float("nan")
        ),
        "W_mean": (
            float(res.lat_sum / res.n_served)
            if res.n_served else float("nan")
        ),
        "P95": float(
            histogram_quantiles(res.hist, res.hist_edges, [0.95])[0]
        ),
        "power": float(res.energy / span) if span > 0 else float("nan"),
        "n_crashes": int(res.n_crashes),
        "n_dropped": int(res.n_dropped),
        "n_shed": int(res.n_shed),
    }


def _certify(n: int) -> dict:
    """verify_faults across every router and both arrival families."""
    tables = np.stack([q_policy(q, 96, BMAX) for q in (4, 6, 8)])
    lam = 3 * 0.7 * BMAX / float(SVC.mean(BMAX))
    out: dict = {}
    for mode in ("poisson", "mmpp2"):
        tr = _trace(mode, lam, n, seed=0)
        sch = SEVERITIES["moderate"].materialize(
            3, float(tr[-1]) + 50.0, seed=1
        )
        for router in ROUTERS:
            res = verify_faults(
                tables, tr, faults=sch, service=SVC, b_max=BMAX,
                router=router, buffer=24, energy_table=ZETA, slo=2.0,
            )
            out[f"{mode}/{router}"] = {
                "n_decisions": int(res["n_decisions"]),
                "n_crashes": res["n_crashes"],
                "n_dropped": res["n_dropped"],
                "n_shed": res["n_shed"],
            }
    # the no-fault rail certifies too (counters must stay zero)
    rail = verify_faults(
        tables, _trace("poisson", lam, n, seed=2),
        faults=FaultSchedule.none(3), service=SVC, b_max=BMAX,
        energy_table=ZETA,
    )
    assert rail["n_crashes"] == 0 and rail["n_shed"] == 0
    out["certified"] = True
    return out


def run(smoke: bool = False, json_path: str | None = None) -> None:
    n_cert = 500 if smoke else 1200
    n = 1200 if smoke else 8000
    n_seeds = 3 if smoke else 4
    sections: dict = {}

    # --- 1. certification: the degraded-mode smoke gate ----------------
    cert, us_cert = timed(_certify, n_cert)
    sections["certification"] = cert
    emit(
        "degraded_certify", us_cert,
        f"routers={len(ROUTERS)}x2families;"
        f"crashes={sum(v['n_crashes'] for k, v in cert.items() if '/' in k)}"
        ";decision-identical",
    )

    # --- 2. fault matrix: severity x router, M = 3 ----------------------
    M = 3
    tables = np.stack([q_policy(q, 96, BMAX) for q in (4, 6, 8)])
    lam = M * 0.7 * BMAX / float(SVC.mean(BMAX))
    matrix: dict = {}
    for sev_name, model in SEVERITIES.items():
        for router in ROUTERS:
            agg = []
            for s in range(n_seeds):
                tr = _trace("mmpp2", lam, n, seed=200 + s)
                sch = (
                    FaultSchedule.none(M) if model is None
                    else model.materialize(M, float(tr[-1]) + 50.0,
                                           seed=300 + s)
                )
                res, us = timed(
                    simulate_fleet, tables, tr, router=router,
                    means=MEANS, zeta=ZETA, b_max=BMAX, slo=2.0,
                    faults=sch, buffer=24,
                )
                agg.append(_stats(res))
            matrix[f"{sev_name}/{router}"] = {
                k: (
                    float(np.nanmean([a[k] for a in agg]))
                    if not k.startswith("n_")
                    else int(np.sum([a[k] for a in agg]))
                )
                for k in agg[0]
            }
    sections["fault_matrix"] = {
        "M": M, "n_arrivals": n, "n_seeds": n_seeds, "buffer": 24,
        "cells": matrix,
    }
    best = min(
        ROUTERS, key=lambda r: matrix[f"severe/{r}"]["drop_rate"]
    )
    emit(
        "degraded_matrix", us,
        ";".join(
            f"severe/{r}:gp={matrix[f'severe/{r}']['goodput']:.2f}"
            f",dr={matrix[f'severe/{r}']['drop_rate']:.3f}"
            for r in ROUTERS[:2]
        )
        + f";best_severe_router={best}",
    )

    # --- 3. overload-aware shedding: aware vs blind ---------------------
    B = 24
    lam_over = 1.2 * BMAX / float(SVC.mean(BMAX))
    blind_tab = solve(_spec(0.7, s_max=128)).action_table()
    (aware_res,), us_solve = timed(
        lambda: (solve(_spec(1.2, s_max=B, buffer=B, c_drop=50.0)),)
    )
    aware_tab = aware_res.action_table()
    serve_from = {
        "aware": int(np.argmax(aware_tab > 0)),
        "blind": int(np.argmax(blind_tab > 0)),
    }
    shed: dict = {"buffer": B, "rho": 1.2, "c_drop": 50.0,
                  "serve_from": serve_from}
    for mode in ("mmpp2", "poisson"):
        rows = {"aware": [], "blind": []}
        for s in range(n_seeds):
            tr = _trace(mode, lam_over, n, seed=400 + s)
            for name, tab in (("aware", aware_tab), ("blind", blind_tab)):
                res = simulate_fleet(
                    tab[None], tr, router="jsq", means=MEANS, zeta=ZETA,
                    b_max=BMAX, buffer=B,
                )
                rows[name].append(_stats(res))
        shed[mode] = {
            name: {
                k: float(np.nanmean([r[k] for r in rs]))
                for k in rs[0] if not k.startswith("n_")
            }
            for name, rs in rows.items()
        }
    # acceptance: pricing drops wins goodput on the bursty overload —
    # the aware policy's lower serve-from threshold buys burst headroom
    aware_gp = shed["mmpp2"]["aware"]["goodput"]
    blind_gp = shed["mmpp2"]["blind"]["goodput"]
    shed["aware_beats_blind"] = bool(aware_gp > blind_gp)
    assert serve_from["aware"] < serve_from["blind"], serve_from
    assert shed["aware_beats_blind"], (aware_gp, blind_gp)
    sections["shedding"] = shed
    emit(
        "degraded_shedding", us_solve,
        f"serve_from:aware={serve_from['aware']},blind={serve_from['blind']}"
        f";mmpp2_goodput:aware={aware_gp:.3f},blind={blind_gp:.3f}"
        f";margin={100 * (aware_gp / blind_gp - 1):.2f}%",
    )

    if json_path:
        emit_json(json_path, "degraded_frontier", sections)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced traces/seeds for CI")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="merge results into this JSON artifact")
    args = ap.parse_args()
    run(smoke=args.smoke, json_path=args.json)


if __name__ == "__main__":
    main()
