"""int8 + error-feedback gradient all-reduce (bandwidth-bound DP sync).

Cross-pod gradient all-reduce over DCI is the slowest collective at
1000-node scale.  This module provides an explicitly-scheduled shard_map DP
reduction that quantizes each gradient leaf to int8 with a per-leaf scale
before the wire, with an error-feedback accumulator so the quantization
noise is re-injected next step (Karimireddy et al., 2019 — convergence-safe).

Wire volume: 4x less than f32 / 2x less than bf16 per step.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

PyTree = Any


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(tree: PyTree, axis_name: str) -> PyTree:
    """int8-quantized psum over `axis_name` (call inside shard_map)."""

    def one(x):
        q, scale = _quantize(x.astype(jnp.float32))
        # int8 would overflow when summed across N replicas: widen to int32
        # on-wire semantics; the 4x saving is modeled on the int8 payload +
        # per-leaf scalar scale (documented in DESIGN.md).
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        scale_sum = jax.lax.psum(scale, axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return _dequantize(total, scale_sum / n) / n  # mean gradient

    return jax.tree.map(one, tree)


def compress_with_error_feedback(
    grads: PyTree, error: PyTree
) -> Tuple[PyTree, PyTree]:
    """Quantize (grads + error) leaf-wise; return (dequantized, new_error).

    Single-device building block (the psum happens outside); the returned
    new_error carries the quantization residual into the next step.
    """

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = _quantize(corrected)
        deq = _dequantize(q, scale)
        return deq, corrected - deq

    out = jax.tree.map(one, grads, error)
    is_pair = lambda t: isinstance(t, tuple)
    deq = jax.tree.map(lambda t: t[0], out, is_leaf=is_pair)
    new_err = jax.tree.map(lambda t: t[1], out, is_leaf=is_pair)
    return deq, new_err


def init_error_feedback(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
