"""Activation sharding hints that no-op outside a mesh context.

Model code calls hint(x, BATCH, None, "model", ...) — entries are mesh axis
names (or tuples of them) per dim.  Under `jax.sharding.set_mesh(mesh)` (the
dry-run / launcher path) this emits with_sharding_constraint; in single-device
smoke tests it is a no-op.  Every entry is divisibility-guarded so the same
model code serves every arch on the fixed production meshes.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

from .meshcompat import get_abstract_mesh

#: batch-dim axes (pod-major); filtered to the axes the current mesh has
BATCH: Tuple[str, ...] = ("pod", "data")

Entry = Union[None, str, Tuple[str, ...]]


def hint(x, *entries: Entry):
    """with_sharding_constraint(x, P(*entries)) guarded by mesh context."""
    am = get_abstract_mesh()
    if am is None or am.empty:
        return x
    if len(entries) != x.ndim:
        raise ValueError(f"hint arity {len(entries)} != ndim {x.ndim}")
    resolved = []
    used: set = set()
    for dim, e in enumerate(entries):
        if e is None:
            resolved.append(None)
            continue
        cand = e if isinstance(e, tuple) else (e,)
        axes = tuple(a for a in cand if a in am.axis_names and a not in used)
        # greedily drop leading axes until the product divides the dim
        while axes:
            size = math.prod(am.shape[a] for a in axes)
            if size > 1 and x.shape[dim] % size == 0:
                break
            axes = axes[1:]
        if not axes:
            resolved.append(None)
            continue
        used.update(axes)
        resolved.append(axes if len(axes) > 1 else axes[0])
    return jax.lax.with_sharding_constraint(x, P(*resolved))
