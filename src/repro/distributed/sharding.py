"""Sharding rules: FSDP('data') x TP('model') x EP(MoE) x SP(sequence).

Design (DESIGN.md Sec. 4):
  * params     — FSDP over 'data' + tensor-parallel over 'model'; replicated
                 across 'pod' (gradient all-reduce crosses pods once/step).
  * batch      — sharded over ('pod','data') when divisible.
  * attention  — head-parallel when head counts divide 'model'; otherwise the
                 KV cache / sequence dim is sharded over 'model' (SP); XLA
                 inserts the partial-softmax collectives.
  * MoE        — expert-parallel over 'model' when n_experts divides it,
                 else tensor-parallel inside each expert.

Every rule is divisibility-guarded: a dim is only sharded when evenly
divisible by the axis size, so the same rules drive every assigned arch on
the fixed (16, 16) / (2, 16, 16) meshes.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# params whose last path segment means "replicate"
_REPLICATED_NAMES = {
    "s", "b", "ln_x", "mu_r", "mu_k", "mu_v", "mu_g", "mu_w", "mu_ck", "mu_cr",
    "dt_bias", "a_log", "d_skip", "w_base", "u_bonus", "enc_pos", "step",
}


def _axis_size(mesh: Mesh, axis: str) -> int:
    return mesh.shape[axis]


def _spec(mesh: Mesh, shape, assignments) -> P:
    """Build a PartitionSpec from (dim, axis) assignments with divisibility
    and one-use-per-axis guards.  Negative dims allowed."""
    entries: list = [None] * len(shape)
    used = set()
    for dim, axis in assignments:
        d = dim % len(shape)
        if axis in used or entries[d] is not None:
            continue
        if shape[d] % _axis_size(mesh, axis) == 0 and shape[d] >= _axis_size(mesh, axis):
            entries[d] = axis
            used.add(axis)
    return P(*entries)


def _path_names(path) -> list:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return out


def param_spec(mesh: Mesh, path, shape, n_experts: int = 0) -> P:
    names = _path_names(path)
    name = names[-1]
    if name.startswith("x_"):
        name = name[2:]  # whisper cross-attention mirrors self-attention
    nd = len(shape)
    if name in _REPLICATED_NAMES or nd <= 1:
        return P()
    if name == "embed":
        return _spec(mesh, shape, [(0, "model"), (1, "data")])
    if name == "out":
        return _spec(mesh, shape, [(0, "data"), (1, "model")])
    if name in ("wq", "wk", "wv"):  # (..., d, H, hd)
        return _spec(mesh, shape, [(-3, "data"), (-2, "model")])
    if name in ("bq", "bk", "bv"):  # (..., H, hd)
        return _spec(mesh, shape, [(-2, "model")])
    if name == "wo":  # (..., H, hd, d)
        return _spec(mesh, shape, [(-3, "model"), (-1, "data")])
    if name in ("w1", "w3"):
        if nd == 4 and n_experts:  # (L, E, d, ff): EP else TP-ff
            return _spec(mesh, shape, [(1, "model"), (2, "data"), (3, "model")])
        return _spec(mesh, shape, [(-2, "data"), (-1, "model")])
    if name == "w2":
        if nd == 4 and n_experts:  # (L, E, ff, d)
            return _spec(mesh, shape, [(1, "model"), (2, "model"), (3, "data")])
        return _spec(mesh, shape, [(-2, "model"), (-1, "data")])
    if name in ("sw1", "sw3", "ck"):
        return _spec(mesh, shape, [(-2, "data"), (-1, "model")])
    if name in ("sw2", "cv"):
        return _spec(mesh, shape, [(-2, "model"), (-1, "data")])
    if name == "router":  # (L, d, E)
        return _spec(mesh, shape, [(-2, "data")])
    if name == "in_proj":  # (L, d, proj)
        return _spec(mesh, shape, [(-2, "data"), (-1, "model")])
    if name == "out_proj":  # (L, d_inner, d)
        return _spec(mesh, shape, [(-2, "model"), (-1, "data")])
    if name == "conv_w":  # (L, K, C)
        return _spec(mesh, shape, [(-1, "model")])
    if name in ("wr", "wg"):  # rwkv (L, d, H, P)
        return _spec(mesh, shape, [(-3, "data"), (-2, "model"), (-1, "model")])
    if name == "cr":  # (L, d, d)
        return _spec(mesh, shape, [(-2, "data"), (-1, "model")])
    if name == "w_lora_a":
        return _spec(mesh, shape, [(-2, "data")])
    if name == "w_lora_b":
        return _spec(mesh, shape, [(-1, "model")])
    # default: try to shard the two largest trailing dims
    return _spec(mesh, shape, [(-2, "data"), (-1, "model")])


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))


def batch_axis(mesh: Mesh, batch: int):
    """The mesh axes to shard the batch dim over (largest divisible prefix)."""
    axes = dp_axes(mesh)
    if batch % int(np.prod([mesh.shape[a] for a in axes])) == 0:
        return axes
    if len(axes) == 2 and batch % mesh.shape[axes[1]] == 0:
        return (axes[1],)
    return None


def param_shardings(mesh: Mesh, params_abstract: PyTree, n_experts: int = 0) -> PyTree:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_spec(mesh, path, leaf.shape, n_experts)
        ),
        params_abstract,
    )


def serving_param_shardings(
    mesh: Mesh, params_abstract: PyTree, n_experts: int = 0
) -> PyTree:
    """Decode/serving layout: TP over 'model', REPLICATED over 'data'.

    FSDP is a training optimization (weights amortize against optimizer
    state); at decode it forces an all-gather of every layer's weights per
    token.  When the TP-sharded weights fit HBM, each data-rank keeps a full
    copy — 16 independent serving replicas per pod, zero weight collectives.
    """

    model_n = mesh.shape["model"]

    def strip_data(sh: NamedSharding, leaf) -> NamedSharding:
        entries = []
        for e in sh.spec:
            if e == "data":
                entries.append(None)
            elif isinstance(e, tuple):
                kept = tuple(a for a in e if a != "data")
                entries.append(kept if len(kept) > 1 else (kept[0] if kept else None))
            else:
                entries.append(e)
        while len(entries) < len(leaf.shape):
            entries.append(None)
        # a big leaf left fully replicated (e.g. 40 q-heads don't divide the
        # 16-way model axis): shard its d_model/contraction dim over 'model'
        # instead — GSPMD then emits a tiny per-layer psum of the projection
        # output rather than holding GBs of replicated weights
        if all(x is None for x in entries) and leaf.size * 2 > (1 << 26):
            for dim in range(1, len(leaf.shape)):
                if leaf.shape[dim] % model_n == 0 and leaf.shape[dim] >= model_n:
                    entries[dim] = "model"
                    break
        return NamedSharding(mesh, P(*entries))

    base = param_shardings(mesh, params_abstract, n_experts)
    return jax.tree.map(strip_data, base, params_abstract)


def batch_shardings(mesh: Mesh, batch_abstract: PyTree) -> PyTree:
    def spec(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        ba = batch_axis(mesh, leaf.shape[0])
        entries = [ba] + [None] * (leaf.ndim - 1)
        return NamedSharding(mesh, P(*entries))

    return jax.tree_util.tree_map_with_path(spec, batch_abstract)


def cache_shardings(mesh: Mesh, cache_abstract: PyTree) -> PyTree:
    """KV/state caches: batch over dp when divisible; seq/state over 'model'."""

    def spec(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        if leaf.ndim == 0 or name == "length":
            return NamedSharding(mesh, P())
        if name in ("k", "v"):
            # (L, B, S, KV, hd) stacked or (B, S, KV, hd) per-occurrence
            off = leaf.ndim - 4
            entries = [None] * leaf.ndim
            ba = batch_axis(mesh, leaf.shape[off])
            entries[off] = ba
            if leaf.shape[off + 1] % _axis_size(mesh, "model") == 0:
                entries[off + 1] = "model"
            return NamedSharding(mesh, P(*entries))
        if name == "enc_out":  # (B, T, d)
            ba = batch_axis(mesh, leaf.shape[0])
            return NamedSharding(mesh, P(ba, None, None))
        if name == "ssm":  # (L, B, H, P, N)
            ba = batch_axis(mesh, leaf.shape[1])
            h_ok = leaf.shape[2] % _axis_size(mesh, "model") == 0
            return NamedSharding(mesh, P(None, ba, "model" if h_ok else None, None, None))
        if name == "conv":  # (L, B, K-1, C)
            ba = batch_axis(mesh, leaf.shape[1])
            c_ok = leaf.shape[3] % _axis_size(mesh, "model") == 0
            return NamedSharding(mesh, P(None, ba, None, "model" if c_ok else None))
        if name == "wkv":  # (L, B, H, P, P)
            ba = batch_axis(mesh, leaf.shape[1])
            p_ok = leaf.shape[3] % _axis_size(mesh, "model") == 0
            return NamedSharding(mesh, P(None, ba, None, "model" if p_ok else None, None))
        if name in ("tshift", "cshift"):  # (L, B, 1, d)
            ba = batch_axis(mesh, leaf.shape[1])
            d_ok = leaf.shape[3] % _axis_size(mesh, "model") == 0
            return NamedSharding(mesh, P(None, ba, None, "model" if d_ok else None))
        # fallback: replicate
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(spec, cache_abstract)


def replicated(mesh: Mesh, tree: PyTree) -> PyTree:
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
