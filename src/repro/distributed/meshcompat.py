"""Version-tolerant wrappers around JAX's mesh-context APIs.

The mesh-context surface moved between JAX releases: newer versions expose
``jax.sharding.get_abstract_mesh`` / ``jax.sharding.set_mesh`` /
``jax.sharding.AxisType``; the pinned 0.4.x series keeps the first two under
``jax._src.mesh`` (where the unset abstract-mesh context is a bare ``()``
sentinel rather than an empty ``AbstractMesh``) and has no ``AxisType`` at
all.  Every mesh-context consumer in this repo goes through this module so
the version probing lives in exactly one place.
"""
from __future__ import annotations

import contextlib

import jax
from jax.sharding import AbstractMesh, Mesh


def get_abstract_mesh():
    """The ambient AbstractMesh, or ``None`` when no mesh context is set."""
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        am = getter()
    else:
        from jax._src import mesh as _mesh_impl

        am = _mesh_impl.get_abstract_mesh()
    if not isinstance(am, AbstractMesh) or not am.axis_names:
        return None
    return am


def set_mesh(mesh: Mesh):
    """Context manager installing ``mesh`` as the ambient (abstract) mesh."""
    setter = getattr(jax.sharding, "set_mesh", None) or getattr(
        jax, "set_mesh", None
    )
    if setter is not None:
        return setter(mesh)
    # 0.4.x: combine the thread-resources context (what
    # with_sharding_constraint's bare PartitionSpecs resolve against) with
    # the abstract-mesh context (what hint()/collectives read).  The
    # internal jax._src.mesh.set_mesh is deliberately NOT used here: it
    # also flips the experimental sharding_in_types flag, which breaks
    # jax.random on this release.
    return _legacy_set_mesh(mesh)


@contextlib.contextmanager
def _legacy_set_mesh(mesh: Mesh):
    from jax._src import mesh as _mesh_impl

    am = getattr(mesh, "abstract_mesh", None)
    with contextlib.ExitStack() as stack:
        stack.enter_context(mesh)
        if am is not None and hasattr(_mesh_impl, "set_abstract_mesh"):
            stack.enter_context(_mesh_impl.set_abstract_mesh(am))
        yield mesh


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with explicit Auto axis types where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            axis_shapes, axis_names, axis_types=(axis_type.Auto,) * len(axis_names)
        )
    return jax.make_mesh(axis_shapes, axis_names)


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` with the pre-0.5 experimental fallback."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def abstract_mesh(axis_shapes, axis_names) -> AbstractMesh:
    """Construct an AbstractMesh across both constructor generations.

    Newer JAX takes ``AbstractMesh((("data", 16), ("model", 16)))``-style
    (name, size) pairs; older releases took ``AbstractMesh(shape, names)``.
    """
    try:
        return AbstractMesh(tuple(zip(axis_names, axis_shapes)))
    except TypeError:
        return AbstractMesh(tuple(axis_shapes), tuple(axis_names))
