"""Explicitly-scheduled distributed attention (shard_map).

sharded_flash_decode — decode attention over a sequence-sharded KV cache.

Baseline XLA behaviour (measured in the dry-run, EXPERIMENTS.md §Perf):
with the cache sharded (batch x seq) over (data x model), GSPMD all-gathers
the FULL KV cache to every model rank per layer — ~2 GB/layer/step for a
32k cache (the decode cells are 250x collective-bound).

This path instead computes per-rank partial attention over the LOCAL seq
shard and combines online-softmax stats (m, l, acc) with pmax/psum — the
wire cost drops from O(B*S*KV*D) to O(B*H*D) per layer (~5 orders of
magnitude at 32k), the tree-attention / flash-decode scheme.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .hints import BATCH
from .meshcompat import get_abstract_mesh, shard_map

NEG_INF = -1e30


def _batch_entry(am, b: int):
    axes = tuple(a for a in BATCH if a in am.axis_names)
    while axes:
        size = math.prod(am.shape[a] for a in axes)
        if size > 1 and b % size == 0:
            return axes if len(axes) > 1 else axes[0]
        axes = axes[1:]
    return None


def sharded_decode_applicable(q_shape, cache_len: int) -> bool:
    """True when the mesh context allows the seq-sharded decode path."""
    am = get_abstract_mesh()
    if am is None or am.empty or "model" not in am.axis_names:
        return False
    n = am.shape["model"]
    return n > 1 and cache_len % n == 0 and q_shape[1] == 1


def sharded_flash_decode(
    q,  # (B, 1, H, D) — one new token, post-RoPE
    kbuf,  # (B, Smax, KV, D) — seq-sharded over 'model'
    vbuf,
    kv_len,  # scalar int32: valid prefix (includes the new token)
    *,
    softcap: Optional[float] = None,
    window: Optional[int] = None,
    chunk: Optional[int] = None,
):
    """Returns (B, 1, H, D).  Collective: pmax+psum of (B,KV,G,D) stats."""
    am = get_abstract_mesh()
    B, _, H, D = q.shape
    Smax, KV = kbuf.shape[1], kbuf.shape[2]
    G = H // KV
    n = am.shape["model"]
    shard = Smax // n
    be = _batch_entry(am, B)
    q_spec = P(be, None, None, None)
    kv_spec = P(be, "model", None, None)

    def local(q_l, k_l, v_l, kv_len_l):
        rank = jax.lax.axis_index("model")
        base = rank * shard
        pos = base + jnp.arange(shard)  # global positions of this shard
        qg = q_l.reshape(q_l.shape[0], KV, G, D)
        s = jnp.einsum(
            "bkgd,bskd->bkgs", qg, k_l, preferred_element_type=jnp.float32
        ) * (1.0 / math.sqrt(D))
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        valid = pos[None, :] < kv_len_l  # (1, shard)
        if window is not None:
            valid &= pos[None, :] >= kv_len_l - window
        if chunk is not None:
            valid &= (pos[None, :] // chunk) == ((kv_len_l - 1) // chunk)
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m = jnp.max(s, axis=-1)  # (b, KV, G)
        p = jnp.exp(s - m[..., None])
        l = jnp.sum(p, axis=-1)
        acc = jnp.einsum(
            "bkgs,bskd->bkgd", p.astype(v_l.dtype), v_l,
            preferred_element_type=jnp.float32,
        )
        # online-softmax combine across seq shards: tiny collectives
        m_g = jax.lax.pmax(m, "model")
        corr = jnp.exp(m - m_g)
        num = jax.lax.psum(acc * corr[..., None], "model")
        den = jax.lax.psum(l * corr, "model")
        out = num / jnp.maximum(den, 1e-30)[..., None]
        return out.reshape(q_l.shape[0], 1, H, D).astype(q_l.dtype)

    fn = shard_map(
        local,
        mesh=am,
        in_specs=(q_spec, kv_spec, kv_spec, P()),
        out_specs=q_spec,
    )
    return fn(q, kbuf, vbuf, jnp.asarray(kv_len, jnp.int32))


def sharded_window_applicable(cfg_window, seq_len: int) -> int:
    """Returns n_prev halo shards (>0) when the halo path applies, else 0."""
    am = get_abstract_mesh()
    if am is None or am.empty or "model" not in am.axis_names:
        return 0
    n = am.shape["model"]
    if n <= 1 or seq_len % n:
        return 0
    shard = seq_len // n
    n_prev = -(-(cfg_window - 1) // shard)  # ceil
    if n_prev >= n - 1:
        return 0  # halo as big as a full gather: not worth it
    return n_prev


def sharded_window_prefill_attention(
    q,  # (B, S, H, D) — seq-sharded over 'model'
    k,  # (B, S, KV, D)
    v,
    *,
    window: int,
    n_prev: int,
    softcap: Optional[float] = None,
):
    """Sliding-window causal attention with halo exchange (prefill/train).

    Each model-rank holds a contiguous seq shard; a window of W tokens only
    needs ceil((W-1)/shard) predecessor shards of K/V, fetched with chained
    collective_permutes — vs GSPMD's full-sequence all-gather per layer.
    For gemma2 (W=4096, shard=2048, 16 ranks) that is 8x less gather volume
    AND ~5x less attention compute on every local layer (§Perf E).
    """
    am = get_abstract_mesh()
    B, S, H, D = q.shape
    KV = k.shape[2]
    n = am.shape["model"]
    shard = S // n
    be = _batch_entry(am, B)
    spec = P(be, "model", None, None)

    def local(q_l, k_l, v_l):
        b_l, s_l = q_l.shape[0], q_l.shape[1]  # LOCAL batch/seq shard sizes
        rank = jax.lax.axis_index("model")
        # halo: bring in the n_prev predecessor shards (ring; masked at edges)
        perm = [(i, (i + 1) % n) for i in range(n)]  # src -> src+1
        k_parts = [k_l]
        v_parts = [v_l]
        kp, vp = k_l, v_l
        for _ in range(n_prev):
            kp = jax.lax.ppermute(kp, "model", perm)
            vp = jax.lax.ppermute(vp, "model", perm)
            k_parts.insert(0, kp)
            v_parts.insert(0, vp)
        kcat = jnp.concatenate(k_parts, axis=1)  # (b, (n_prev+1)*s_l, KV, D)
        vcat = jnp.concatenate(v_parts, axis=1)
        # global positions; wrapped-ring entries get pos < 0 and mask out
        base = (rank - n_prev) * s_l
        k_pos = base + jnp.arange((n_prev + 1) * s_l)
        q_pos = rank * s_l + jnp.arange(s_l)
        qg = q_l.reshape(b_l, s_l, KV, H // KV, D)
        s = jnp.einsum(
            "bqkgd,bskd->bkgqs", qg, kcat, preferred_element_type=jnp.float32
        ) * (1.0 / math.sqrt(D))
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = (k_pos[None, :] >= 0) & (k_pos[None, :] <= q_pos[:, None])
        mask &= q_pos[:, None] - k_pos[None, :] < window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum(
            "bkgqs,bskd->bkgqd", (p / jnp.maximum(l, 1e-30)).astype(vcat.dtype),
            vcat, preferred_element_type=jnp.float32,
        )
        return o.transpose(0, 3, 1, 2, 4).reshape(b_l, s_l, H, D).astype(q_l.dtype)

    fn = shard_map(
        local, mesh=am, in_specs=(spec, spec, spec), out_specs=spec
    )
    return fn(q, k, v)
