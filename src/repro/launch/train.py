"""Training driver: --arch <id> on the local device or the production mesh.

Local (CPU smoke, reduced config):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-32b --steps 20

Production lowering check (512 host placeholders, full config, no execution):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-32b --dry-run
"""
import argparse
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="checkpoints/train")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile the FULL config on the production mesh")
    args = ap.parse_args()

    if args.dry_run:
        # delegate: dryrun.py must own process start (device-count env var)
        from repro.launch import dryrun  # noqa: F401  (sets XLA_FLAGS first)
        import subprocess

        return subprocess.call([
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", args.arch, "--shape", "train_4k", "--mesh", "both",
        ])

    from repro.configs import get_config
    from repro.training.data import DataConfig
    from repro.training.optimizer import AdamWConfig
    from repro.training.train_loop import Trainer, TrainerConfig

    cfg = get_config(args.arch).reduced()
    print(f"[train] {args.arch} (reduced: ~{cfg.n_params()/1e6:.1f}M params) "
          f"{args.steps} steps of {args.batch}x{args.seq}")
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, seed=0)
    tcfg = TrainerConfig(steps=args.steps, ckpt_every=max(5, args.steps // 4),
                         ckpt_dir=args.ckpt_dir, log_every=5,
                         n_micro=args.n_micro)
    trainer = Trainer(cfg, data, AdamWConfig(lr=args.lr), tcfg)
    _, _, losses = trainer.run(seed=0)
    print(f"[train] loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
