"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (data, model).
Multi-pod: 2 pods x 256 = 512 chips (pod, data, model).
"""
from __future__ import annotations

from repro.distributed.meshcompat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the production axis names (smoke tests)."""
    return make_mesh((1, 1), ("data", "model"))


def make_sim_mesh():
    """1-D ("data",) mesh over every visible device.

    The serving-side sweeps (`serving.fleet.run_fleet_grid`,
    `serving.compiled.run_grid`) shard their scenario/seed lane axis over
    a single mesh axis; this builds that mesh without hard-coding a
    device count, so the same call works on 1 CPU host or a TPU slice.
    """
    import jax

    return make_mesh((jax.device_count(),), ("data",))
