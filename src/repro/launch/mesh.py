"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (data, model).
Multi-pod: 2 pods x 256 = 512 chips (pod, data, model).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=types)


def make_local_mesh():
    """1-device mesh with the production axis names (smoke tests)."""
    return jax.make_mesh(
        (1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2
    )
