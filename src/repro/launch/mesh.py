"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (data, model).
Multi-pod: 2 pods x 256 = 512 chips (pod, data, model).
"""
from __future__ import annotations

from repro.distributed.meshcompat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the production axis names (smoke tests)."""
    return make_mesh((1, 1), ("data", "model"))
