"""Trip-count-aware roofline analysis of partitioned HLO text.

XLA's HloCostAnalysis (compiled.cost_analysis()) counts while-loop bodies
ONCE — a 64-layer lax.scan model under-reports FLOPs by ~64x.  This module
re-derives per-device roofline numerators from the compiled module text:

  * computations are parsed into ops with a local symbol table (shapes);
  * `while` ops get static trip counts (scan bounds appear as s32 constants
    in the loop condition); multipliers propagate down the call graph;
  * FLOPs   — 2 * prod(out_dims) * prod(contracting_dims) per dot op;
  * HBM traffic — fusion-boundary bytes (operands + outputs of top-level
    ops, skipping no-traffic ops like tuple/bitcast/get-tuple-element);
  * collective bytes — per op kind, trip-multiplied.

Caveat (documented in EXPERIMENTS.md): on the CPU dry-run backend, bf16
arithmetic is legalized to f32, which inflates byte counts vs real TPU by
<= 2x on bf16-heavy programs; FLOP counts are dtype-independent.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# ops that move no HBM bytes of their own
_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "opt-barrier", "optimization-barrier", "partition-id",
    "replica-id", "iota", "while", "conditional", "call", "custom-call",
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# shape: either a tuple type "(s32[], bf16[..]{..}, ...)" or a single array type
_TUPLE_SHAPE = r"\((?:[^()]|\([^()]*\))*\)"
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(" + _TUPLE_SHAPE + r"|\S+)\s+([\w\-]+)\((.*)$"
)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s*\(")
_OPERAND_RE = re.compile(r"(%[\w.\-]+)")


def _split_params(region: str):
    """Split 'a: shape, b: (tuple, shape)' on top-level commas."""
    parts, depth, cur = [], 0, []
    for ch in region:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return [p.strip() for p in parts if p.strip()]


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of possibly-tuple shape string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> Tuple[int, ...]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return ()
    return tuple(int(d) for d in m.group(2).split(",") if d)


@dataclasses.dataclass
class Op:
    name: str
    shape: str  # output shape string
    opcode: str
    rest: str  # operand list + attributes (raw)


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    params: Dict[str, str]  # %param name -> shape string


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        is_header = (
            ("->" in stripped)
            and stripped.endswith("{")
            and "=" not in stripped.split("->")[0].split("(")[0]
            and _COMP_HDR_RE.match(stripped)
        )
        if is_header:
            hdr = _COMP_HDR_RE.match(stripped)
            name = hdr.group(1).lstrip("%")
            lparen = stripped.index("(")
            arrow = stripped.rfind("->")
            region = stripped[lparen + 1 : stripped.rfind(")", lparen, arrow)]
            params = {}
            for part in _split_params(region):
                m = re.match(r"([\w.\-]+)\s*:\s*(.+)", part)
                if m:
                    params["%" + m.group(1)] = m.group(2)
            cur = Computation(name=name, ops=[], params=params)
            comps[name] = cur
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            cur.ops.append(Op(name=m.group(1), shape=m.group(2),
                              opcode=m.group(3), rest=m.group(4)))
    return comps


def _symbol_table(comp: Computation) -> Dict[str, str]:
    table = dict(comp.params)
    for op in comp.ops:
        table[op.name] = op.shape
    return table


def _while_info(comp: Computation) -> List[Tuple[str, str, str]]:
    """(while_op_name, body_comp, condition_comp) triples in `comp`."""
    out = []
    for op in comp.ops:
        if op.opcode == "while":
            bm = re.search(r"body=(%?[\w.\-]+)", op.rest)
            cm = re.search(r"condition=(%?[\w.\-]+)", op.rest)
            if bm and cm:
                out.append((op.name, bm.group(1).lstrip("%"), cm.group(1).lstrip("%")))
    return out


def _trip_count(cond: Computation) -> int:
    """Largest s32 scalar constant in the condition — the scan bound."""
    best = 1
    for op in cond.ops:
        if op.opcode == "constant" and op.shape.startswith("s32[]"):
            m = re.search(r"constant\((-?\d+)\)", "constant(" + op.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _fusion_callees(comp: Computation) -> List[str]:
    out = []
    for op in comp.ops:
        if op.opcode == "fusion":
            m = re.search(r"calls=(%?[\w.\-]+)", op.rest)
            if m:
                out.append(m.group(1).lstrip("%"))
    return out


def _dot_flops(op: Op, table: Dict[str, str]) -> float:
    out_elems = max(1, math.prod(_shape_dims(op.shape)))
    lhs_m = _OPERAND_RE.search(op.rest)
    if not lhs_m:
        return 0.0
    lhs_shape = table.get(lhs_m.group(1))
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    contract = 1
    if lhs_shape and cm:
        dims = _shape_dims(lhs_shape)
        for d in cm.group(1).split(","):
            if d and int(d) < len(dims):
                contract *= dims[int(d)]
    return 2.0 * out_elems * contract


@dataclasses.dataclass
class RooflineCounts:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: dict = dataclasses.field(default_factory=dict)
    dot_flops_by_comp: dict = dataclasses.field(default_factory=dict)


def analyze(hlo: str, entry_hint: str = "main") -> RooflineCounts:
    comps = parse_computations(hlo)
    # multipliers: start at 1 for the entry; propagate through whiles/fusions
    mult: Dict[str, float] = defaultdict(float)
    entry = None
    for name in comps:
        if entry_hint in name:
            entry = name
            break
    if entry is None and comps:
        entry = next(iter(comps))
    # BFS through the call graph
    mult[entry] = 1.0
    frontier = [entry]
    seen = set()
    while frontier:
        cname = frontier.pop()
        if cname in seen:
            continue
        seen.add(cname)
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for (_, body, cond) in _while_info(comp):
            trips = _trip_count(comps.get(cond, Computation(cond, [], {})))
            mult[body] = max(mult[body], m * trips)
            mult[cond] = max(mult[cond], m * trips)
            frontier.append(body)
        for callee in _fusion_callees(comp):
            mult[callee] = max(mult[callee], m)
            # fusion bodies are not traversed for traffic, but their dots
            # still execute: traverse for flops only (handled below)
            frontier.append(callee)
        for op in comp.ops:
            for attr in ("to_apply", "body", "condition", "calls"):
                for mm in re.finditer(attr + r"=(%?[\w.\-]+)", op.rest):
                    callee = mm.group(1).lstrip("%")
                    if callee in comps and callee not in seen:
                        mult[callee] = max(mult[callee], m)
                        frontier.append(callee)

    counts = RooflineCounts()
    counts.collectives = {k: {"count": 0, "bytes": 0.0} for k in _COLLECTIVES}
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        table = _symbol_table(comp)
        is_fusion_body = cname.startswith("fused_") or ".fused" in cname
        for op in comp.ops:
            if op.opcode in ("dot", "convolution"):
                f = _dot_flops(op, table) * m
                counts.flops += f
                counts.dot_flops_by_comp[cname] = (
                    counts.dot_flops_by_comp.get(cname, 0.0) + f
                )
            if is_fusion_body:
                continue  # traffic counted at the fusion boundary
            if op.opcode in _NO_TRAFFIC:
                continue
            out_b = _shape_bytes(op.shape)
            operand_bytes = []
            for om in _OPERAND_RE.finditer(op.rest.split(")")[0]):
                shp = table.get(om.group(1))
                if shp:
                    operand_bytes.append(_shape_bytes(shp))
            # slice-like ops read only the sliced region, not the whole
            # operand (a lax.scan slicing stacked weights per layer would
            # otherwise count the full stack once per iteration)
            if op.opcode in ("slice", "dynamic-slice", "gather"):
                in_b = out_b
            elif op.opcode in ("dynamic-update-slice", "scatter"):
                upd = operand_bytes[1] if len(operand_bytes) > 1 else out_b
                in_b = 2 * upd  # read region + read update; write counted below
                out_b = upd  # in-place write of the region
            elif op.opcode == "fusion":
                # fusion bodies may slice big operands internally; cap each
                # operand's contribution (elementwise/matmul fusions are
                # unaffected; stack-slicing fusions stop overcounting)
                cap = max(8 * out_b, 1 << 20)
                in_b = sum(min(b, cap) for b in operand_bytes)
            else:
                in_b = sum(operand_bytes)
            kind = None
            for c in _COLLECTIVES:
                if op.opcode == c or op.opcode.startswith(c + "-"):
                    kind = c
                    break
            if kind and not op.opcode.endswith("-done"):
                counts.collectives[kind]["count"] += int(m)
                counts.collectives[kind]["bytes"] += out_b * m
                counts.collective_bytes += out_b * m
            counts.bytes += (out_b + in_b) * m
    return counts
