import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first backend init, and the dry-run needs 512 host placeholders.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we record:
  * memory_analysis()  — per-device argument/output/temp bytes (fits-check)
  * cost_analysis()    — HLO FLOPs and bytes accessed (roofline numerator)
  * collective bytes   — parsed from the partitioned HLO text per op kind
  * roofline terms     — compute / memory / collective seconds (TPU v5e)

Results go to artifacts/dryrun/<arch>__<shape>__<mesh>.json and feed
EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--mesh single|multi|both] [--out artifacts/dryrun]
"""
import argparse
import dataclasses
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.launch import hlo_analysis
from repro.distributed import meshcompat
from repro.distributed import sharding as SH
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import SHAPES, cell_applicable, input_specs
from repro.models import model as M
from repro.training.optimizer import AdamWConfig, AdafactorConfig, opt_init
from repro.training.train_step import make_train_step

# --- TPU v5e hardware constants (per chip) ---
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # B/s
LINK_BW = 50e9  # B/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device output bytes of each collective op kind.

    The partitioned module's shapes are per-device; the output shape of a
    collective is what lands in each chip's memory — we use it as the
    transferred-bytes proxy (documented in EXPERIMENTS.md).
    """
    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?\S+\s*=\s*(.*?)\s*(\w[\w-]*)\(", ls)
        if not m:
            continue
        op = m.group(2)
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-"):  # e.g. all-reduce-start
                kind = c
                break
        if kind is None:
            continue
        if op.endswith("-done"):
            continue  # avoid double counting async pairs
        shapes = _SHAPE_RE.findall(m.group(1))
        nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        out[kind]["count"] += 1
        out[kind]["bytes"] += nbytes
    return out


_CONVERT_RE = re.compile(r"(%\S+)\s*=\s*f32\[([\d,]+)\]\S*\s+convert\(")


def cpu_upcast_artifact_bytes(hlo_text: str, min_bytes: int = 1 << 26) -> int:
    """Estimate of XLA:CPU float-normalization inflation.

    The CPU backend legalizes bf16 arithmetic to f32, materializing f32
    copies of large bf16 stacks (weights carried through lax.scan, KV
    caches).  These copies do NOT exist on TPU (native bf16).  We sum f32
    convert outputs > 64 MiB as the artifact estimate; EXPERIMENTS.md
    reports both raw and adjusted per-device memory.
    """
    total = 0
    seen = set()
    for m in _CONVERT_RE.finditer(hlo_text):
        name = m.group(1)
        if name in seen:
            continue  # computation bodies reprint op definitions
        seen.add(name)
        b = _shape_bytes("f32", m.group(2))
        if b >= min_bytes:
            total += b
    return total


def _mem_dict(mem) -> dict:
    keys = (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    )
    return {k: int(getattr(mem, k, 0)) for k in keys}


def analytic_memory_bytes(cfg, cell, mem: dict, chips: int) -> float:
    """Per-device HBM traffic model (see EXPERIMENTS.md §Roofline method).

    The CPU dry-run backend legalizes bf16 to f32 with whole-buffer convert
    fusions inside loop bodies, so HLO-derived byte counts are inflated by
    backend artifacts that do not exist on TPU.  Instead we model traffic
    from the *measured* per-device buffer assignment:

      A (args)   read once       — params, optimizer state, KV cache
      O (out)    written once
      T (temp)   written + read  — activation transients (TPU-adjusted)
      attention  re-reads the per-layer KV working set once per q-chunk
                 (blockwise attention), x3 for fwd+bwd+remat in training
    """
    A = mem["argument_size_in_bytes"]
    O = mem["output_size_in_bytes"]
    T = mem.get("temp_tpu_adjusted_bytes", mem["temp_size_in_bytes"])
    base = float(A + O + 2.0 * T)
    B, S = cell.global_batch, cell.seq_len
    if cell.kind in ("train", "prefill") and not cfg.rwkv:
        dp = 16 if B % 16 == 0 else 1
        n_micro = (16 if cfg.n_params() > 5e10 else 4) if cell.kind == "train" else 1
        b_loc = max(1, B // (dp * n_micro))
        nq = max(1, S // 1024)
        kv_layer = 2 * b_loc * S * cfg.n_kv_heads * cfg.head_dim * 2
        passes = 3 if cell.kind == "train" else 1
        base += float(cfg.n_layers * nq * kv_layer) * passes * n_micro
    return base


def model_flops_estimate(cfg, cell) -> float:
    """6*N*D (train) / 2*N*D (prefill) / 2*N_active*B (decode) + attention."""
    n_act = cfg.n_params_active()
    B, S = cell.global_batch, cell.seq_len
    L, H, hd = cfg.n_layers, cfg.n_heads, cfg.head_dim
    if cell.kind == "train":
        attn = 0.5 * 12 * L * B * S * S * H * hd  # causal fwd+bwd qk+pv
        return 6.0 * n_act * B * S + attn
    if cell.kind == "prefill":
        attn = 0.5 * 4 * L * B * S * S * H * hd
        return 2.0 * n_act * B * S + attn
    attn = 4.0 * L * B * S * H * hd  # decode reads S-deep cache
    return 2.0 * n_act * B + (attn if not cfg.sub_quadratic else 0.0)


def opt_state_shardings(mesh, opt_abs, n_experts):
    """Moments/factors inherit the param sharding rules (same tree paths)."""
    out = {}
    for key, sub in opt_abs.items():
        if key == "step":
            out[key] = SH.replicated(mesh, sub)
        else:
            out[key] = SH.param_shardings(mesh, sub, n_experts)
    return out


def build_cell(cfg, shape_name: str, mesh, baseline: bool = False):
    """Returns (fn, args, in_shardings, donate) for jit lowering.

    baseline=True reproduces the pre-§Perf substrate: XLA-auto decode
    attention (no shard_map flash-decode) and FSDP weight layout at decode.
    """
    if baseline:
        cfg = dataclasses.replace(cfg, sharded_decode_attn=False)
    cell = SHAPES[shape_name]
    specs = input_specs(cfg, shape_name)
    params_abs = M.abstract_params(cfg, jnp.bfloat16)
    p_sh = SH.param_shardings(mesh, params_abs, cfg.n_experts)

    if cell.kind == "train":
        n = cfg.n_params()
        if n > 2e11:  # 314B-class: factored second moments or it cannot fit
            opt_cfg = AdafactorConfig()
        else:
            opt_cfg = AdamWConfig(
                moment_dtype=jnp.bfloat16 if n > 5e10 else jnp.float32
            )
        # FSDP weight gathers scale with n_micro x (fwd+bwd+remat): use the
        # smallest microbatch count whose activations fit (TPU-adjusted) —
        # measured in EXPERIMENTS.md §Perf D
        n_micro = 8 if n > 5e10 else 4
        accum_dtype = jnp.bfloat16 if n > 2e11 else jnp.float32
        opt_abs = jax.eval_shape(lambda p: opt_init(p, opt_cfg), params_abs)
        o_sh = opt_state_shardings(mesh, opt_abs, cfg.n_experts)
        b_sh = SH.batch_shardings(mesh, specs["batch"])
        step_fn = make_train_step(
            cfg, opt_cfg, remat=True, n_micro=n_micro, accum_dtype=accum_dtype
        )
        return (
            step_fn,
            (params_abs, opt_abs, specs["batch"]),
            (p_sh, o_sh, b_sh),
            (0, 1),
        )
    if cell.kind == "prefill":
        b_sh = SH.batch_shardings(mesh, specs["batch"])

        def prefill_fn(params, batch):
            return M.prefill(cfg, params, batch, max_len=cell.seq_len)

        return prefill_fn, (params_abs, specs["batch"]), (p_sh, b_sh), ()
    # decode: replicate weights over 'data' (independent serving replicas)
    # when the TP-sharded copy fits v5e HBM alongside the KV cache
    if not baseline and cfg.n_params() * 2 / mesh.shape["model"] <= 6e9:
        p_sh = SH.serving_param_shardings(mesh, params_abs, cfg.n_experts)
    c_sh = SH.cache_shardings(mesh, specs["cache"])
    t_sh = SH.batch_shardings(mesh, {"tokens": specs["tokens"]})["tokens"]

    def decode_fn(params, cache, tokens):
        return M.decode_step(cfg, params, cache, tokens)

    return decode_fn, (params_abs, specs["cache"], specs["tokens"]), (p_sh, c_sh, t_sh), (1,)


def run_cell(
    arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
    baseline: bool = False,
) -> dict:
    cfg = ARCHS[arch]
    cell = SHAPES[shape_name]
    mesh_name = "multi_pod" if multi_pod else "single_pod"
    rec: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": cell.kind, "seq_len": cell.seq_len, "batch": cell.global_batch,
    }
    runs, why = cell_applicable(cfg, shape_name)
    if not runs:
        rec["status"] = "skipped"
        rec["reason"] = why
        out_dir.mkdir(parents=True, exist_ok=True)
        path = out_dir / f"{arch.replace('/', '_')}__{shape_name}__{mesh_name}.json"
        path.write_text(json.dumps(rec, indent=2, default=str))
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    try:
        with meshcompat.set_mesh(mesh):  # enables model-side sharding hints
            fn, args, in_sh, donate = build_cell(
                cfg, shape_name, mesh, baseline=baseline
            )
            t0 = time.perf_counter()
            lowered = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate).lower(*args)
            t1 = time.perf_counter()
            compiled = lowered.compile()
            t2 = time.perf_counter()
        hlo_text = compiled.as_text()
        mem = _mem_dict(compiled.memory_analysis())
        artifact = cpu_upcast_artifact_bytes(hlo_text)
        mem["cpu_upcast_artifact_bytes"] = artifact
        # bf16->f32 legalization at most doubles live bytes: clamp at temp/2
        mem["temp_tpu_adjusted_bytes"] = max(
            mem["temp_size_in_bytes"] // 2,
            mem["temp_size_in_bytes"] - artifact,
        )
        cost_raw = compiled.cost_analysis() or {}
        if isinstance(cost_raw, (list, tuple)):  # pre-0.5 returns [dict]
            cost_raw = cost_raw[0] if cost_raw else {}
        cost = dict(cost_raw)
        cost = {k: float(v) for k, v in cost.items() if isinstance(v, (int, float))}
        colls = parse_collectives(hlo_text)
        # XLA's cost_analysis counts while bodies ONCE; the trip-count-aware
        # analyzer (hlo_analysis.py) re-derives per-device numerators.
        ana = hlo_analysis.analyze(hlo_text)
        flops = float(ana.flops)
        bytes_accessed = analytic_memory_bytes(cfg, cell, mem, chips)
        coll_bytes = float(ana.collective_bytes)
        compute_s = flops / PEAK_FLOPS
        memory_s = bytes_accessed / HBM_BW
        collective_s = coll_bytes / LINK_BW
        mf = model_flops_estimate(cfg, cell)
        rec.update(
            status="ok",
            chips=chips,
            lower_s=round(t1 - t0, 2),
            compile_s=round(t2 - t1, 2),
            memory=mem,
            flops_per_device=flops,
            bytes_per_device=bytes_accessed,
            hlo_bytes_per_device_raw=float(ana.bytes),
            collectives=ana.collectives,
            collectives_uncorrected=colls,
            collective_bytes_per_device=coll_bytes,
            cost_analysis_raw={
                "flops": float(cost.get("flops", 0.0)),
                "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            },
            roofline={
                "compute_s": compute_s,
                "memory_s": memory_s,
                "collective_s": collective_s,
                "bottleneck": max(
                    ("compute", compute_s),
                    ("memory", memory_s),
                    ("collective", collective_s),
                    key=lambda kv: kv[1],
                )[0],
            },
            model_flops_total=mf,
            model_flops_per_device=mf / chips,
            useful_flops_ratio=(mf / chips) / flops if flops else None,
        )
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"{arch.replace('/', '_')}__{shape_name}__{mesh_name}.json"
    path.write_text(json.dumps(rec, indent=2, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--baseline", action="store_true",
                    help="pre-optimization substrate (EXPERIMENTS.md §Perf)")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    out_dir = Path(args.out)

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, out_dir, baseline=args.baseline)
                tag = rec["status"]
                if tag == "ok":
                    n_ok += 1
                    r = rec["roofline"]
                    print(
                        f"[OK]   {arch:24s} {shape:12s} {rec['mesh']:10s} "
                        f"compile={rec['compile_s']:7.1f}s "
                        f"temp={rec['memory']['temp_size_in_bytes']/2**30:6.2f}GiB "
                        f"(tpu~{rec['memory']['temp_tpu_adjusted_bytes']/2**30:6.2f}) "
                        f"args={rec['memory']['argument_size_in_bytes']/2**30:7.2f}GiB "
                        f"bottleneck={r['bottleneck']}"
                    , flush=True)
                elif tag == "skipped":
                    n_skip += 1
                    print(f"[SKIP] {arch:24s} {shape:12s} {rec['mesh']:10s} {rec['reason']}", flush=True)
                else:
                    n_err += 1
                    print(f"[ERR]  {arch:24s} {shape:12s} {rec['mesh']:10s} {rec['error']}", flush=True)
    print(f"done: ok={n_ok} skip={n_skip} err={n_err}")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
