"""Serving driver: SMDP-batched serving of --arch <id>.

Profiled-clock mode (default) runs the paper's queue against the TPU-v5e
roofline profile of the chosen architecture; --executor runs a real reduced
model under wall clock (see examples/serve_llm.py for the guided version).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --rho 0.6
"""
import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--rho", type=float, default=0.6)
    ap.add_argument("--w2", type=float, default=1.0)
    ap.add_argument("--b-max", type=int, default=32)
    ap.add_argument("--chips", type=int, default=8, help="serving replica size")
    ap.add_argument("--epochs", type=int, default=50_000)
    ap.add_argument("--slo-ms", type=float, default=None)
    args = ap.parse_args()

    from benchmarks.tpu_profile_scenario import arch_workload  # reuse
    from repro.configs import get_config
    from repro.core import SMDPSpec, solve
    from repro.core.profiles import tpu_service_model
    from repro.serving import (GreedyScheduler, ServingEngine, SMDPScheduler,
                               StaticScheduler)

    cfg = get_config(args.arch)
    svc, energy = tpu_service_model(arch_workload(cfg, chips=args.chips))
    lam = args.rho * args.b_max / float(svc.mean(args.b_max))
    print(f"[serve] {args.arch} on {args.chips} v5e chips; "
          f"l(1)={float(svc.mean(1)):.2f}ms l({args.b_max})="
          f"{float(svc.mean(args.b_max)):.2f}ms lambda={lam:.4f}/ms")
    spec = SMDPSpec(lam=lam, service=svc, energy=energy, b_min=1,
                    b_max=args.b_max, w1=1.0, w2=args.w2, s_max=128)
    sol = solve(spec)
    print(f"[serve] SMDP policy head: {sol.action_table(24).tolist()}")
    en = np.array([0.0] + [float(energy(b)) for b in range(1, args.b_max + 1)])

    for sched in [SMDPScheduler(sol), GreedyScheduler(1, args.b_max),
                  StaticScheduler(8)]:
        eng = ServingEngine(sched, lam=lam, b_max=args.b_max, service=svc,
                            energy_table=en, slo=args.slo_ms, seed=0)
        rep = eng.run(args.epochs)
        slo = (f" slo_miss={rep.n_slo_miss / max(rep.n_served, 1):.2%}"
               if args.slo_ms else "")
        print(f"[serve] {sched.name:9s} W={rep.latencies.mean():8.3f}ms "
              f"P95={rep.percentile(95):8.3f}ms P={rep.power:6.1f}W "
              f"mean_batch={rep.mean_batch:5.1f}{slo}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
