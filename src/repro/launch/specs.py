"""Assigned input-shape cells and their ShapeDtypeStruct stand-ins.

Every (arch x shape) pair is a dry-run cell:
  train_4k    : seq 4,096   global_batch 256  -> train_step
  prefill_32k : seq 32,768  global_batch 32   -> prefill
  decode_32k  : seq 32,768  global_batch 128  -> serve_step (1 new token)
  long_500k   : seq 524,288 global_batch 1    -> serve_step; SSM/hybrid only
                (full-attention archs skip this cell; see DESIGN.md)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def cell_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """(runs?, reason-if-skip).  long_500k needs sub-quadratic decode state."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 500k-context cell skipped (DESIGN.md)"
    return True, ""


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(
    cfg: ModelConfig, shape: str, dtype=jnp.bfloat16
) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cell = SHAPES[shape]
    B, S = cell.global_batch, cell.seq_len
    if cell.kind in ("train", "prefill"):
        batch: Dict[str, Any] = {"tokens": sds((B, S), jnp.int32)}
        if cfg.family == "encdec":
            batch["frames"] = sds((B, cfg.encoder_len, cfg.d_model), dtype)
        if cfg.n_patches:
            batch["patches"] = sds((B, cfg.n_patches, cfg.d_model), dtype)
        return {"batch": batch}
    # decode: one new token against a seq_len-deep cache
    cache = M.abstract_cache(cfg, B, S, dtype=dtype)
    if cfg.family == "encdec":
        cache["enc_out"] = sds((B, cfg.encoder_len, cfg.d_model), dtype)
    return {"tokens": sds((B, 1), jnp.int32), "cache": cache}
