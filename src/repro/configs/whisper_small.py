"""Whisper-small [audio] — enc-dec; conv frontend is a STUB (precomputed frame
embeddings are an input) [arXiv:2212.04356]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    act="gelu",
    norm="layernorm",
    n_encoder_layers=12,
    encoder_len=1500,
    rope_theta=0.0,  # learned positions (stubbed as sinusoidal table)
    tie_embeddings=True,
)
