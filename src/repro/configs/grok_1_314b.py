"""Grok-1 314B [moe] — 8 experts top-2, GQA, attention softcap [hf:xai-org/grok-1]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    n_experts=8,
    top_k=2,
    attn_softcap=30.0,
    act="geglu",
    norm="rmsnorm",
    tie_embeddings=True,
)
