"""Qwen2-VL-7B [vlm] — M-RoPE, dynamic resolution; the vision tower is a STUB
(precomputed patch embeddings are an input) [arXiv:2409.12191]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    act="swiglu",
    norm="rmsnorm",
    n_patches=256,
    tie_embeddings=False,
)
