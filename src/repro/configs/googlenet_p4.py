"""The paper's own serving scenario: GoogLeNet inference on a TESLA P4.

Not an LM architecture — this is the queueing-side config (Sec. VII basic
scenario): deterministic service, l(b) = 0.3051 b + 1.0524 ms,
zeta(b) = 19.899 b + 19.603 mJ, B in [1, 32].

    from repro.configs.googlenet_p4 import paper_spec
    spec = paper_spec(rho=0.7, w2=1.6)
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    GOOGLENET_P4_ENERGY,
    GOOGLENET_P4_LATENCY,
    ServiceModel,
    SMDPSpec,
)

B_MIN, B_MAX = 1, 32


def service(family: str = "det") -> ServiceModel:
    return ServiceModel(latency=GOOGLENET_P4_LATENCY, family=family)


def paper_spec(
    rho: float = 0.7,
    w1: float = 1.0,
    w2: float = 1.0,
    s_max: int = 128,
    c_o: float = 100.0,
    family: str = "det",
) -> SMDPSpec:
    svc = service(family)
    lam = rho * B_MAX / float(svc.mean(B_MAX))
    return SMDPSpec(
        lam=lam, service=svc, energy=GOOGLENET_P4_ENERGY,
        b_min=B_MIN, b_max=B_MAX, w1=w1, w2=w2, s_max=s_max, c_o=c_o,
    )


def energy_table() -> np.ndarray:
    return np.array(
        [0.0] + [float(GOOGLENET_P4_ENERGY(b)) for b in range(B_MIN, B_MAX + 1)]
    )
