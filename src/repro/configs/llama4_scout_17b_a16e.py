"""Llama-4 Scout 17B-active/16E [moe] — top-1 routing + shared expert, chunked
local attention on 3/4 layers [hf:meta-llama/Llama-4-Scout-17B-16E]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    n_experts=16,
    top_k=1,
    n_shared_experts=1,
    layer_pattern="chunked_full",
    chunk_size=8192,
    rope_theta=500_000.0,
    act="swiglu",
    norm="rmsnorm",
    tie_embeddings=False,
)
