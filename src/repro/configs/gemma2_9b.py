"""Gemma2-9B [dense] — local/global alternating attention, logit softcaps [arXiv:2408.00118]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    attn_softcap=50.0,
    final_softcap=30.0,
    sliding_window=4096,
    layer_pattern="local_global",
    act="geglu",
    norm="rmsnorm",
    post_block_norm=True,
    embed_scale=True,
    tie_embeddings=True,
)
