"""RWKV6-3B 'Finch' [ssm] — attention-free, data-dependent decay [arXiv:2404.05892]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,      # time-mix heads, head_dim 64
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    rwkv=True,
    norm="layernorm",
    tie_embeddings=False,
)
