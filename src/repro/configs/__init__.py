"""Architecture registry: --arch <id> resolves here."""
from __future__ import annotations

from repro.models.config import ModelConfig

from .qwen2_5_32b import CONFIG as qwen2_5_32b
from .command_r_plus_104b import CONFIG as command_r_plus_104b
from .gemma2_9b import CONFIG as gemma2_9b
from .gemma2_27b import CONFIG as gemma2_27b
from .whisper_small import CONFIG as whisper_small
from .zamba2_1_2b import CONFIG as zamba2_1_2b
from .grok_1_314b import CONFIG as grok_1_314b
from .llama4_scout_17b_a16e import CONFIG as llama4_scout_17b_a16e
from .rwkv6_3b import CONFIG as rwkv6_3b
from .qwen2_vl_7b import CONFIG as qwen2_vl_7b

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        qwen2_5_32b,
        command_r_plus_104b,
        gemma2_9b,
        gemma2_27b,
        whisper_small,
        zamba2_1_2b,
        grok_1_314b,
        llama4_scout_17b_a16e,
        rwkv6_3b,
        qwen2_vl_7b,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(ARCHS)}")
    return ARCHS[name]
