"""Latency/energy profiles: the paper's GPU tables + TPU-v5e roofline-derived.

The paper profiles l(b), zeta(b) on NVIDIA GPUs.  Our target is TPU v5e, so
we *derive* per-architecture profiles from the roofline model:

    l(b)    = n_tokens * max( b * flops_tok / PEAK_FLOPS,
                              (param_bytes + b * kv_bytes) / HBM_BW )
    zeta(b) = P_STATIC * l(b) + E_FLOP * n_tokens * b * flops_tok

Both satisfy the paper's monotonicity assumptions (theta, eta non-decreasing):
l is a max of affines with non-negative intercepts; zeta is static power over
a non-decreasing time plus a linear term.

Hardware constants (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM.
Power: ~60 W idle/static, ~200 W at full MXU utilization (modeled).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .service_models import PiecewiseMaxProfile, ServiceModel

PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
P_STATIC = 60.0  # W
P_PEAK = 200.0  # W at full utilization
E_FLOP = (P_PEAK - P_STATIC) / PEAK_FLOPS  # J per FLOP (dynamic)


@dataclasses.dataclass(frozen=True)
class DecodeWorkload:
    """One unit of batch service = decoding `n_tokens` tokens per request."""

    flops_per_token: float  # ~2 * N_active params
    param_bytes: float  # weight bytes streamed per decode step
    kv_bytes_per_request: float  # KV/state bytes read per step per request
    n_tokens: int = 32  # tokens per service segment
    chips: int = 1  # chips the model is sharded over


def tpu_decode_latency(w: DecodeWorkload) -> PiecewiseMaxProfile:
    """l(b) in milliseconds (matching the paper's units)."""
    compute_slope = w.n_tokens * w.flops_per_token / (PEAK_FLOPS * w.chips) * 1e3
    mem_intercept = w.n_tokens * w.param_bytes / (HBM_BW * w.chips) * 1e3
    mem_slope = w.n_tokens * w.kv_bytes_per_request / (HBM_BW * w.chips) * 1e3
    return PiecewiseMaxProfile(
        slope1=compute_slope,
        intercept1=0.0,
        slope2=mem_slope,
        intercept2=mem_intercept,
    )


@dataclasses.dataclass(frozen=True)
class TPUEnergyProfile:
    """zeta(b) in millijoules: static power * l(b) + dynamic per-FLOP energy."""

    latency: PiecewiseMaxProfile
    dyn_mj_per_batch: float  # E_FLOP * n_tokens * flops_tok (per request) * 1e3
    p_static: float = P_STATIC

    def __call__(self, b):
        import numpy as np

        barr = np.asarray(b, dtype=np.float64)
        # l is in ms -> static energy in mJ = W * ms
        return self.p_static * self.latency(barr) + self.dyn_mj_per_batch * barr


def tpu_service_model(
    w: DecodeWorkload, family: str = "det", **kw
) -> tuple[ServiceModel, TPUEnergyProfile]:
    lat = tpu_decode_latency(w)
    energy = TPUEnergyProfile(
        latency=lat,
        dyn_mj_per_batch=E_FLOP * w.n_tokens * w.flops_per_token / w.chips * w.chips * 1e3,
    )
    return ServiceModel(latency=lat, family=family, **kw), energy


def workload_for_arch(
    n_params_active: float,
    n_layers: int,
    kv_heads: int,
    head_dim: int,
    context_len: int = 8192,
    n_tokens: int = 32,
    chips: int = 1,
    state_bytes: Optional[float] = None,  # for SSM archs: per-request state
    dtype_bytes: int = 2,
) -> DecodeWorkload:
    kv = (
        state_bytes
        if state_bytes is not None
        else 2 * n_layers * kv_heads * head_dim * context_len * dtype_bytes
    )
    return DecodeWorkload(
        flops_per_token=2.0 * n_params_active,
        param_bytes=n_params_active * dtype_bytes,
        kv_bytes_per_request=float(kv),
        n_tokens=n_tokens,
        chips=chips,
    )
