"""Service-time models G_b, latency l(b) and energy zeta(b) profiles.

The paper (Sec. III) assumes:
  * l(b) = E[G_b] monotone non-decreasing, theta(b) = b/l(b) non-decreasing;
  * zeta(b) monotone with eta(b) = b/zeta(b) non-decreasing;
  * arbitrary service distribution G_b with finite second moment.

We implement the paper's families (deterministic / Erlang-2 / exponential /
hyper-exponential, Sec. VII-C-3) plus an empirical atom-mixture family so
profiled latency histograms can be plugged in directly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Latency / energy profiles (deterministic functions of batch size)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AffineProfile:
    """f(b) = slope * b + intercept (the paper's fitted form, Fig. 2)."""

    slope: float
    intercept: float

    def __call__(self, b):
        return self.slope * np.asarray(b, dtype=np.float64) + self.intercept


@dataclasses.dataclass(frozen=True)
class ConstantProfile:
    """f(b) = c (ideal parallelism, paper Sec. VII-C-1)."""

    value: float

    def __call__(self, b):
        return np.full_like(np.asarray(b, dtype=np.float64), self.value)


@dataclasses.dataclass(frozen=True)
class LogProfile:
    """f(b) = a * log(b) + c (paper Sec. VII-C-2 energy scenario)."""

    scale: float
    intercept: float

    def __call__(self, b):
        return self.scale * np.log(np.asarray(b, dtype=np.float64)) + self.intercept


@dataclasses.dataclass(frozen=True)
class TableProfile:
    """f(b) from a profiled lookup table, b in [1, len(table)]."""

    table: Tuple[float, ...]

    def __call__(self, b):
        arr = np.asarray(b)
        return np.asarray(self.table, dtype=np.float64)[arr - 1]


@dataclasses.dataclass(frozen=True)
class PiecewiseMaxProfile:
    """f(b) = max(a1*b + c1, a2*b + c2) — roofline-shaped latency.

    This is the TPU-native form: compute-term vs memory-term maximum.  It is
    monotone non-decreasing and theta(b)=b/f(b) is non-decreasing whenever
    both branches individually satisfy it (affine with positive intercept).
    """

    slope1: float
    intercept1: float
    slope2: float
    intercept2: float

    def __call__(self, b):
        barr = np.asarray(b, dtype=np.float64)
        return np.maximum(
            self.slope1 * barr + self.intercept1,
            self.slope2 * barr + self.intercept2,
        )


Profile = Callable[[np.ndarray], np.ndarray]

# ---------------------------------------------------------------------------
# Service-time distribution families
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServiceModel:
    """Distribution family of the batch service time G_b.

    ``latency`` gives the mean l(b); the family shapes the distribution
    around that mean.  ``family`` in {'det', 'erlang', 'expo', 'hyperexpo',
    'atoms'}.

    * det       : Pr[G_b = l(b)] = 1                                (CoV 0)
    * erlang    : Erlang-k with mean l(b) (default k=2)             (CoV 1/sqrt(k))
    * expo      : exponential with mean l(b)                        (CoV 1)
    * hyperexpo : mixture of exponentials, means scales_i * l(b),
                  weights w_i (paper: w=(2/3,1/3), scales=(0.5,2))  (CoV > 1)
    * atoms     : Pr[G_b = atom_scales_i * l(b)] = atom_weights_i   (empirical)
    """

    latency: Profile
    family: str = "det"
    erlang_k: int = 2
    hyper_weights: Tuple[float, ...] = (2.0 / 3.0, 1.0 / 3.0)
    hyper_scales: Tuple[float, ...] = (0.5, 2.0)
    atom_weights: Tuple[float, ...] = (1.0,)
    atom_scales: Tuple[float, ...] = (1.0,)

    # -- moments ------------------------------------------------------------
    def mean(self, b) -> np.ndarray:
        return np.asarray(self.latency(b), dtype=np.float64)

    def second_moment(self, b) -> np.ndarray:
        m = self.mean(b)
        if self.family == "det":
            return m**2
        if self.family == "erlang":
            k = self.erlang_k
            return m**2 * (1.0 + 1.0 / k)
        if self.family == "expo":
            return 2.0 * m**2
        if self.family == "hyperexpo":
            w = np.asarray(self.hyper_weights)
            s = np.asarray(self.hyper_scales)
            # mixture of exponentials with means s_i * m — but the mixture
            # mean is sum(w_i s_i) m; we renormalize scales so E = m exactly.
            norm = float(np.sum(w * s))
            s = s / norm
            return 2.0 * m**2 * float(np.sum(w * s**2))
        if self.family == "atoms":
            w = np.asarray(self.atom_weights)
            s = np.asarray(self.atom_scales)
            norm = float(np.sum(w * s))
            s = s / norm
            return m**2 * float(np.sum(w * s**2))
        raise ValueError(f"unknown family {self.family!r}")

    def cov(self, b) -> np.ndarray:
        m = self.mean(b)
        var = self.second_moment(b) - m**2
        return np.sqrt(np.maximum(var, 0.0)) / m

    # -- P(k arrivals during service of batch b), Poisson(lam) arrivals ------
    def arrival_pmf(self, b: int, lam: float, k_max: int) -> np.ndarray:
        """p_k^{[b]} for k = 0..k_max (eq. 4); tail mass is 1 - sum.

        Closed forms per family (all exact):
          det       : Poisson(k; lam * l(b))
          erlang-k  : NegBin: C(n+k-1, n) q^n (1-q)^k with q = lam/(lam+nu),
                      nu = k_stages / l(b)   [k arrivals across k_stages]
          expo      : geometric, q = lam/(lam+1/l(b))
          hyperexpo : mixture of geometrics
          atoms     : mixture of Poissons
        """
        m = float(self.mean(b))
        ks = np.arange(k_max + 1)
        if self.family == "det":
            return _poisson_pmf(ks, lam * m)
        if self.family == "erlang":
            stages = self.erlang_k
            nu = stages / m  # per-stage rate
            q = lam / (lam + nu)
            return _negbin_pmf(ks, stages, q)
        if self.family == "expo":
            q = lam / (lam + 1.0 / m)
            return _negbin_pmf(ks, 1, q)
        if self.family == "hyperexpo":
            w = np.asarray(self.hyper_weights, dtype=np.float64)
            s = np.asarray(self.hyper_scales, dtype=np.float64)
            s = s / float(np.sum(w * s))
            out = np.zeros(k_max + 1)
            for wi, si in zip(w, s):
                qi = lam / (lam + 1.0 / (si * m))
                out += wi * _negbin_pmf(ks, 1, qi)
            return out
        if self.family == "atoms":
            w = np.asarray(self.atom_weights, dtype=np.float64)
            s = np.asarray(self.atom_scales, dtype=np.float64)
            s = s / float(np.sum(w * s))
            out = np.zeros(k_max + 1)
            for wi, si in zip(w, s):
                out += wi * _poisson_pmf(ks, lam * si * m)
            return out
        raise ValueError(f"unknown family {self.family!r}")

    # -- sampling (for the event-driven simulator) ---------------------------
    def unit_draws(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """n unit-scale draws U with sample(b) ~ mean(b) * U for every b.

        Every family is a scale mixture around the batch mean, so a single
        a-independent draw sequence parameterizes the whole service law —
        the compiled simulator (serving.compiled) consumes one draw per
        serve epoch, and a shared sequence makes the compiled and Python
        backends decision-for-decision identical.  `det` consumes no rng
        state (matching sample(), which never touches the generator).
        """
        if self.family == "det":
            return np.ones(n)
        if self.family == "erlang":
            k = self.erlang_k
            return rng.gamma(shape=k, scale=1.0 / k, size=n)
        if self.family == "expo":
            return rng.exponential(scale=1.0, size=n)
        if self.family == "hyperexpo":
            w = np.asarray(self.hyper_weights)
            s = np.asarray(self.hyper_scales)
            s = s / float(np.sum(w * s))
            comp = rng.choice(len(w), size=n, p=w / w.sum())
            return rng.exponential(scale=s[comp], size=n)
        if self.family == "atoms":
            w = np.asarray(self.atom_weights)
            s = np.asarray(self.atom_scales)
            s = s / float(np.sum(w * s))
            comp = rng.choice(len(w), size=n, p=w / w.sum())
            return s[comp]
        raise ValueError(f"unknown family {self.family!r}")

    def sample(self, b: int, rng: np.random.Generator, n: int) -> np.ndarray:
        m = float(self.mean(b))
        if self.family == "det":
            return np.full(n, m)
        if self.family == "erlang":
            k = self.erlang_k
            return rng.gamma(shape=k, scale=m / k, size=n)
        if self.family == "expo":
            return rng.exponential(scale=m, size=n)
        if self.family == "hyperexpo":
            w = np.asarray(self.hyper_weights)
            s = np.asarray(self.hyper_scales)
            s = s / float(np.sum(w * s))
            comp = rng.choice(len(w), size=n, p=w / w.sum())
            return rng.exponential(scale=s[comp] * m, size=n)
        if self.family == "atoms":
            w = np.asarray(self.atom_weights)
            s = np.asarray(self.atom_scales)
            s = s / float(np.sum(w * s))
            comp = rng.choice(len(w), size=n, p=w / w.sum())
            return s[comp] * m
        raise ValueError(f"unknown family {self.family!r}")


def _poisson_pmf(ks: np.ndarray, rate: float) -> np.ndarray:
    """Numerically stable Poisson pmf via log-space recurrence."""
    if rate <= 0.0:
        out = np.zeros_like(ks, dtype=np.float64)
        out[ks == 0] = 1.0
        return out
    logs = ks * math.log(rate) - rate - _log_factorial(ks)
    return np.exp(logs)


def _negbin_pmf(ks: np.ndarray, r: int, q: float) -> np.ndarray:
    """P(K=k) = C(k+r-1, k) (1-q)^r q^k  (arrivals across r expo stages)."""
    log_comb = _log_factorial(ks + r - 1) - _log_factorial(ks) - _log_factorial(
        np.full_like(ks, r - 1)
    )
    logs = log_comb + r * math.log(max(1.0 - q, 1e-300)) + ks * math.log(max(q, 1e-300))
    return np.exp(logs)


def _log_factorial(ks: np.ndarray) -> np.ndarray:
    from scipy.special import gammaln

    return gammaln(np.asarray(ks, dtype=np.float64) + 1.0)


# ---------------------------------------------------------------------------
# Paper's fitted profiles (Sec. VII preamble)
# ---------------------------------------------------------------------------

#: GoogLeNet on TESLA P4 (ms / mJ), fitted from NVIDIA measurements [7].
GOOGLENET_P4_LATENCY = AffineProfile(slope=0.3051, intercept=1.0524)
GOOGLENET_P4_ENERGY = AffineProfile(slope=19.899, intercept=19.603)

#: Sec. VII-C-1 — ideal parallelism (constant batch latency).
IDEAL_PARALLEL_LATENCY = ConstantProfile(value=6.0859)

#: Sec. VII-C-2 — logarithmic energy.
LOG_ENERGY = LogProfile(scale=105.0, intercept=60.0)
