"""SMDP construction: truncation, abstract cost, discretization (paper Sec. IV-V).

State space  S_hat = {0, 1, ..., s_max, S_o}; index S_o = s_max + 1.
Action space A     = {0} U {B_min..B_max}; action index == batch size.

Pipeline (paper Sec. V):
  build_smdp()         -> truncated continuous-time SMDP  (m_hat, c_hat, y)  [eq. 18-19]
  discretize           -> associated discrete-time MDP    (m_tilde, c_tilde) [eq. 23-25]
  build_smdp_batched() -> a stack of specs sharing (s_max, b_max), assembled
                          with one broadcast pass; the scalar path is the
                          N == 1 slice of the same construction.

All tensors are dense numpy on the host (S ~ O(100), A ~ O(33)); the iteration
itself (rvi.py) runs in JAX.  The batched container keeps only the *banded*
transition data (arrival pmfs + overflow tails) — the (N, S, A, S) dense
tensors are materialized per spec on demand, so a wide sweep stays O(N*S*A)
in memory.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from .service_models import ServiceModel, Profile


@dataclasses.dataclass(frozen=True)
class SMDPSpec:
    """Problem definition (paper Sec. III-IV)."""

    lam: float  # Poisson arrival rate
    service: ServiceModel  # G_b family + l(b)
    energy: Profile  # zeta(b)
    b_min: int = 1
    b_max: int = 32
    w1: float = 1.0  # weight on average response time (via holding cost)
    w2: float = 0.0  # weight on average power
    s_max: int = 128  # truncation level (>= b_max)
    c_o: float = 100.0  # abstract overflow-cost rate (paper Sec. V-A)

    def __post_init__(self):
        if self.s_max < self.b_max:
            raise ValueError("s_max must be >= b_max (paper Sec. V-A)")
        if not (0 < self.b_min <= self.b_max):
            raise ValueError("need 0 < b_min <= b_max")
        rho = self.rho
        if not (0.0 < rho < 1.0):
            raise ValueError(f"instability: rho={rho:.3f} not in (0,1)")

    @property
    def rho(self) -> float:
        """Normalized traffic intensity lam / (B_max * mu^[B_max])."""
        return self.lam * float(self.service.mean(self.b_max)) / self.b_max


@dataclasses.dataclass
class TruncatedSMDP:
    """Dense truncated SMDP (eq. 18-19) and its discretized MDP (eq. 23)."""

    spec: SMDPSpec
    n_states: int  # s_max + 2
    n_actions: int  # b_max + 1
    feasible: np.ndarray  # (S, A) bool
    y: np.ndarray  # (S, A) expected sojourn times
    c_hat: np.ndarray  # (S, A) expected costs (with abstract cost at S_o)
    m_hat: np.ndarray  # (S, A, S) transition probs
    # discretized
    eta: float
    c_tilde: np.ndarray  # (S, A)
    m_tilde: np.ndarray  # (S, A, S)
    # component costs for objective decomposition (same layout as c_hat)
    c_hold: np.ndarray  # w1-free holding cost integral  E[int s(t) dt]/lam
    c_energy: np.ndarray  # zeta(a) (0 for a=0)
    arrival_pmfs: np.ndarray  # (A, K+1) p_k per action (0 row for a=0)

    @property
    def s_o(self) -> int:
        return self.n_states - 1


@dataclasses.dataclass
class BatchedSMDP:
    """A stack of truncated SMDPs sharing (s_max, b_max).

    Leading axis N indexes specs; the layout of every per-spec slice matches
    TruncatedSMDP.  Transition structure is stored banded — ``pmfs_banded``
    (arrival pmfs truncated to k <= s_max) plus ``tails`` (overflow mass
    towards S_o per base state) — exactly the inputs of rvi.banded_backup.
    """

    specs: List[SMDPSpec]
    n_specs: int
    n_states: int  # S = s_max + 2
    n_actions: int  # A = b_max + 1
    feasible: np.ndarray  # (N, S, A) bool
    y: np.ndarray  # (N, S, A)
    c_hat: np.ndarray  # (N, S, A)
    eta: np.ndarray  # (N,)
    c_tilde: np.ndarray  # (N, S, A), +inf at infeasible
    c_hold: np.ndarray  # (N, S, A)
    c_energy: np.ndarray  # (N, S, A)
    arrival_pmfs: np.ndarray  # (N, A, K+1), K = s_max + 1
    pmfs_banded: np.ndarray  # (N, A, s_max+1): columns k <= s_max
    tails: np.ndarray  # (N, A, s_max+1): overflow mass per base state t
    scale: np.ndarray  # (N, S, A) = eta / y

    @property
    def s_max(self) -> int:
        return self.specs[0].s_max

    @property
    def s_o(self) -> int:
        return self.n_states - 1

    def m_hat_dense(self, i: Optional[int] = None) -> np.ndarray:
        """Materialize the dense (eq. 18) transition tensor.

        Returns (N, S, A, S), or (S, A, S) for a single spec ``i``.
        """
        sel = slice(None) if i is None else slice(i, i + 1)
        m = _dense_m_hat(
            self.specs[0].s_max,
            self.arrival_pmfs[sel],
            self.tails[sel],
            self.feasible[sel],
        )
        return m if i is None else m[0]

    def m_tilde_dense(self, i: Optional[int] = None) -> np.ndarray:
        """Materialize the discretized (eq. 23) transition tensor."""
        sel = slice(None) if i is None else slice(i, i + 1)
        m = _dense_m_tilde(
            self.m_hat_dense()[sel] if i is None else self.m_hat_dense(i)[None],
            self.scale[sel],
            self.feasible[sel],
        )
        return m if i is None else m[0]

    def take(self, indices: Sequence[int]) -> "BatchedSMDP":
        """Sub-batch view over the given spec indices (no re-building)."""
        idx = list(indices)
        return BatchedSMDP(
            specs=[self.specs[i] for i in idx],
            n_specs=len(idx),
            n_states=self.n_states,
            n_actions=self.n_actions,
            feasible=self.feasible[idx],
            y=self.y[idx],
            c_hat=self.c_hat[idx],
            eta=self.eta[idx],
            c_tilde=self.c_tilde[idx],
            c_hold=self.c_hold[idx],
            c_energy=self.c_energy[idx],
            arrival_pmfs=self.arrival_pmfs[idx],
            pmfs_banded=self.pmfs_banded[idx],
            tails=self.tails[idx],
            scale=self.scale[idx],
        )

    def with_c_o(self, c_os: Sequence[float]) -> "BatchedSMDP":
        """Copy of the batch with new per-spec abstract overflow costs.

        c_o only enters the S_o row of c_hat (eq. 19) and its discretized
        c_tilde — transitions, eta and scale are untouched — so swapping it
        is a row patch, not a rebuild.  This is how sweep_solve reuses the
        c_o = 0 probe batch of the abstract-cost calibration as the first
        solve batch.
        """
        c_os = np.asarray(c_os, dtype=np.float64)
        if c_os.shape != (self.n_specs,):
            raise ValueError(f"need {self.n_specs} c_o values")
        old = np.array([sp.c_o for sp in self.specs])
        s_o = self.s_o
        c_hat = self.c_hat.copy()
        c_hat[:, s_o, :] += (c_os - old)[:, None] * self.y[:, s_o, :]
        c_tilde = self.c_tilde.copy()
        with np.errstate(invalid="ignore"):
            c_tilde[:, s_o, :] = np.where(
                self.feasible[:, s_o, :],
                c_hat[:, s_o, :] / self.y[:, s_o, :],
                np.inf,
            )
        return dataclasses.replace(
            self,
            specs=[
                dataclasses.replace(sp, c_o=float(c))
                for sp, c in zip(self.specs, c_os)
            ],
            c_hat=c_hat,
            c_tilde=c_tilde,
        )

    def policy_transitions_batched(self, policies: np.ndarray) -> np.ndarray:
        """(N, S, S) m_hat rows under per-spec policies — no dense tensor.

        The batch-wide form of policy_transitions: one broadcast gather
        instead of N python loops, feeding the batched stationary solve of
        evaluate.evaluate_policy_batched.
        """
        s_max = self.specs[0].s_max
        S = self.n_states
        s_o = S - 1
        N = self.n_specs
        acts = np.asarray(policies, dtype=np.int64)  # (N, S)
        if acts.shape != (N, S):
            raise ValueError(f"policies shape {acts.shape} != ({N}, {S})")
        s_val = _state_values(s_max).astype(np.int64)
        s_idx = np.arange(S)
        serve = acts >= 1
        base = np.clip(s_val[None, :] - acts, 0, s_max)  # (N, S)
        k = np.arange(s_max + 1)[None, None, :] - base[..., None]  # (N, S, K)
        nn = np.arange(N)[:, None, None]
        gathered = self.pmfs_banded[nn, acts[..., None], np.clip(k, 0, s_max)]
        p = np.zeros((N, S, S))
        p[:, :, : s_max + 1] = np.where((k >= 0) & serve[..., None], gathered, 0.0)
        p[:, :, s_o] += np.where(
            serve, self.tails[np.arange(N)[:, None], acts, base], 0.0
        )
        nxt = np.where(s_idx < s_max, s_idx + 1, s_o)
        onehot = np.zeros((S, S))
        onehot[s_idx, nxt] = 1.0
        p = np.where(serve[..., None], p, onehot[None])
        # normalize tiny numerical drift (same rule as the dense path)
        row_sums = p.sum(axis=-1, keepdims=True)
        np.divide(p, row_sums, out=p, where=row_sums > 1e-12)
        return p

    def policy_transitions(self, i: int, policy: np.ndarray) -> np.ndarray:
        """(S, S) m_hat rows of spec ``i`` under ``policy`` — no dense tensor.

        Row s is the arrival-pmf window of the chosen action (eq. 18), so
        policy evaluation over a whole sweep never materializes (S, A, S).
        """
        s_max = self.specs[0].s_max
        S = self.n_states
        s_o = S - 1
        acts = np.asarray(policy, dtype=np.int64)
        s_val = _state_values(s_max).astype(np.int64)
        p = np.zeros((S, S))
        s_idx = np.arange(S)
        wait = acts == 0
        nxt = np.where(s_idx < s_max, s_idx + 1, s_o)
        p[s_idx[wait], nxt[wait]] = 1.0
        serve = ~wait
        if serve.any():
            a_s = acts[serve]
            base = s_val[serve] - a_s  # >= 0 for feasible actions
            k = np.arange(s_max + 1)[None, :] - base[:, None]
            pm = self.pmfs_banded[i]  # (A, s_max+1)
            window = np.where(k >= 0, pm[a_s[:, None], np.clip(k, 0, s_max)], 0.0)
            p[serve, : s_max + 1] = window
            p[serve, s_o] = self.tails[i][a_s, base]
        # normalize tiny numerical drift (same rule as the dense path)
        row_sums = p.sum(axis=-1, keepdims=True)
        np.divide(p, row_sums, out=p, where=row_sums > 1e-12)
        return p

    def dense(self, i: int) -> TruncatedSMDP:
        """Per-spec TruncatedSMDP view with materialized dense tensors."""
        m_hat = self.m_hat_dense(i)
        m_tilde = _dense_m_tilde(
            m_hat[None], self.scale[i : i + 1], self.feasible[i : i + 1]
        )[0]
        return TruncatedSMDP(
            spec=self.specs[i],
            n_states=self.n_states,
            n_actions=self.n_actions,
            feasible=self.feasible[i],
            y=self.y[i],
            c_hat=self.c_hat[i],
            m_hat=m_hat,
            eta=float(self.eta[i]),
            c_tilde=self.c_tilde[i],
            m_tilde=m_tilde,
            c_hold=self.c_hold[i],
            c_energy=self.c_energy[i],
            arrival_pmfs=self.arrival_pmfs[i],
        )


# ---------------------------------------------------------------------------
# Broadcast assembly
# ---------------------------------------------------------------------------


def _state_values(s_max: int) -> np.ndarray:
    """Requests represented by each state index; S_o counts as s_max."""
    s_val = np.arange(s_max + 2, dtype=np.float64)
    s_val[-1] = s_max
    return s_val


def _dense_m_hat(
    s_max: int,
    pmfs: np.ndarray,  # (N, A, K+1)
    tails: np.ndarray,  # (N, A, s_max+1)
    feasible: np.ndarray,  # (N, S, A)
    pmf_tol: float = 1e-12,
) -> np.ndarray:
    """Broadcast construction of the (N, S, A, S) transition tensor (eq. 18)."""
    N, A = pmfs.shape[0], pmfs.shape[1]
    S = s_max + 2
    s_o = S - 1
    K = pmfs.shape[2] - 1
    s_val = _state_values(s_max)
    acts = np.arange(A)

    m = np.zeros((N, S, A, S))
    # a = 0: deterministic +1 (S_o self-loops; s_max -> S_o)
    rows = np.arange(s_max)
    m[:, rows, 0, rows + 1] = 1.0
    m[:, s_max, 0, s_o] = 1.0
    m[:, s_o, 0, s_o] = 1.0
    # a != 0: base state t = s_val(s) - a; arrivals k land at j = t + k
    base = s_val[:, None] - acts[None, :]  # (S, A)
    j = np.arange(s_max + 1)
    k = j[None, None, :] - base[:, :, None]  # (S, A, s_max+1)
    serve = feasible & (acts[None, None, :] >= 1)  # (N, S, A)
    valid = (k >= 0) & serve[..., None]  # (N, S, A, s_max+1)
    k_idx = np.clip(k, 0, K).astype(np.int64)
    gathered = pmfs[:, acts[:, None], k_idx]  # (N, S, A, J)
    m[..., : s_max + 1] += np.where(valid, gathered, 0.0)
    # overflow mass towards S_o
    t_idx = np.clip(base, 0, s_max).astype(np.int64)  # (S, A)
    tail_gather = tails[:, acts, t_idx]  # (N, S, A)
    m[..., s_o] += np.where(serve, tail_gather, 0.0)
    # normalize tiny numerical drift
    row_sums = m.sum(axis=-1, keepdims=True)
    np.divide(m, row_sums, out=m, where=row_sums > pmf_tol)
    return m


def _dense_m_tilde(
    m_hat: np.ndarray,  # (N, S, A, S)
    scale: np.ndarray,  # (N, S, A)
    feasible: np.ndarray,  # (N, S, A)
) -> np.ndarray:
    """Discretized transitions (eq. 23): scale towards eta-uniformization."""
    N, S, A = scale.shape
    idx = np.arange(S)
    m = m_hat * scale[..., None]
    m[:, idx[:, None], np.arange(A)[None, :], idx[:, None]] += 1.0 - scale
    # infeasible rows: harmless self-loop (masked out in the backup anyway)
    inf_mask = ~feasible
    m[inf_mask] = 0.0
    nI, sI, aI = np.nonzero(inf_mask)
    m[nI, sI, aI, sI] = 1.0
    return m


def build_smdp_batched(specs: Sequence[SMDPSpec]) -> BatchedSMDP:
    """Construct a stacked batch of truncated SMDPs (eq. 18-19, 23-25).

    All specs must share (s_max, b_max) — use sweep.pad_specs to lift a
    mixed-truncation list to a common level.  Arrival rates, weights,
    service families, energy profiles and b_min may vary freely.
    """
    specs = list(specs)
    if not specs:
        raise ValueError("empty spec batch")
    s_max = specs[0].s_max
    b_max = specs[0].b_max
    for sp in specs[1:]:
        if sp.s_max != s_max or sp.b_max != b_max:
            raise ValueError(
                "batched specs must share (s_max, b_max); got "
                f"({sp.s_max}, {sp.b_max}) vs ({s_max}, {b_max})"
            )
    N = len(specs)
    S = s_max + 2
    A = b_max + 1
    s_o = S - 1
    K = s_max + 1
    s_val = _state_values(s_max)
    acts = np.arange(A)
    bs = np.arange(1, A)

    lam = np.array([sp.lam for sp in specs])
    b_min = np.array([sp.b_min for sp in specs])
    w1 = np.array([sp.w1 for sp in specs])
    w2 = np.array([sp.w2 for sp in specs])
    c_o = np.array([sp.c_o for sp in specs])

    # --- per-spec action profiles (vectorized over b; closed-form pmfs) ---
    y_a = np.zeros((N, A))
    e2 = np.zeros((N, A))
    zeta = np.zeros((N, A))
    pmfs = np.zeros((N, A, K + 1))
    for i, sp in enumerate(specs):
        y_a[i, 0] = 1.0 / sp.lam
        y_a[i, 1:] = sp.service.mean(bs)
        e2[i, 1:] = sp.service.second_moment(bs)
        zeta[i, 1:] = sp.energy(bs)
        for a in range(1, A):
            pmfs[i, a] = sp.service.arrival_pmf(a, sp.lam, K)

    # --- feasibility: wait always; serve iff b_min <= a <= s (eq. 8) ---
    feasible = (s_val[None, :, None] >= acts[None, None, :]) & (
        acts[None, None, :] >= b_min[:, None, None]
    )
    feasible[:, :, 0] = True

    # --- sojourn times y(s, a)  (eq. 9): s-independent ---
    y = np.broadcast_to(y_a[:, None, :], (N, S, A)).copy()

    # --- costs (eq. 11, 19) ---
    c_hold = np.zeros((N, S, A))  # = E[int_0^gamma s(t) dt] / lam (w1 term)
    c_hold[:, :, 0] = s_val[None, :] / lam[:, None] ** 2
    c_hold[:, :, 1:] = (
        s_val[None, :, None] * y_a[:, None, 1:] / lam[:, None, None]
        + 0.5 * e2[:, None, 1:]
    )
    c_energy = np.broadcast_to(zeta[:, None, :], (N, S, A)).copy()  # w2 term
    c_hat = w1[:, None, None] * c_hold + w2[:, None, None] * c_energy
    # abstract cost at the overflow state (eq. 19): + c_o * y(s, a)
    c_hat[:, s_o, :] += c_o[:, None] * y[:, s_o, :]

    # --- banded transition data ---
    pm = pmfs[:, :, : s_max + 1].copy()  # k > s_max always lands in S_o
    csum = np.cumsum(pm, axis=-1)
    # tails[i, a, t] = 1 - sum_{k <= s_max - t} p_k  (overflow from base t)
    tails = np.maximum(0.0, 1.0 - csum[:, :, ::-1])
    tails[:, 0, :] = 0.0

    # --- discretization (eq. 23-25) ---
    # structured self-transition probabilities: for feasible (s, a != 0) the
    # diagonal entry is p^{[a]}_a (k = a puts the chain back at s); at S_o it
    # is the overflow tail from base s_max - a; waiting self-loops only at S_o
    diag = np.zeros((N, S, A))
    pm_diag = pm[:, acts, np.minimum(acts, s_max)]  # (N, A): p^{[a]}_a
    diag[:, : s_max + 1, :] = np.where(
        feasible[:, : s_max + 1, :] & (acts[None, None, :] >= 1),
        pm_diag[:, None, :],
        0.0,
    )
    diag[:, s_o, 1:] = tails[:, bs, s_max - bs]
    diag[:, s_o, 0] = 1.0

    with np.errstate(divide="ignore"):
        bound = np.where(
            (diag < 1.0) & feasible, y / np.maximum(1.0 - diag, 1e-300), np.inf
        )
    eta = 0.999 * bound.reshape(N, -1).min(axis=1)
    if not np.all(np.isfinite(eta)) or np.any(eta <= 0):
        raise RuntimeError("degenerate eta bound")

    with np.errstate(invalid="ignore"):
        c_tilde = np.where(feasible, c_hat / y, np.inf)
    scale = eta[:, None, None] / y

    return BatchedSMDP(
        specs=specs,
        n_specs=N,
        n_states=S,
        n_actions=A,
        feasible=feasible,
        y=y,
        c_hat=c_hat,
        eta=eta,
        c_tilde=c_tilde,
        c_hold=c_hold,
        c_energy=c_energy,
        arrival_pmfs=pmfs,
        pmfs_banded=pm,
        tails=tails,
        scale=scale,
    )


def build_smdp(spec: SMDPSpec, pmf_tol: float = 1e-12) -> TruncatedSMDP:
    """Construct the truncated SMDP per eq. (18)-(19).

    The scalar path is the N == 1 slice of the broadcast batched assembly.
    """
    del pmf_tol  # drift normalization is part of the dense materialization
    return build_smdp_batched([spec]).dense(0)
