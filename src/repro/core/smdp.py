"""SMDP construction: truncation, abstract cost, discretization (paper Sec. IV-V).

State space  S_hat = {0, 1, ..., s_max, S_o}; index S_o = s_max + 1.
Action space A     = {0} U {B_min..B_max}; action index == batch size.

Pipeline (paper Sec. V):
  build_smdp()         -> truncated continuous-time SMDP  (m_hat, c_hat, y)  [eq. 18-19]
  discretize           -> associated discrete-time MDP    (m_tilde, c_tilde) [eq. 23-25]
  build_smdp_batched() -> a stack of specs sharing (s_max, b_max), assembled
                          with one broadcast pass; the scalar path is the
                          N == 1 slice of the same construction.

All tensors are dense numpy on the host (S ~ O(100), A ~ O(33)); the iteration
itself (rvi.py) runs in JAX.  The batched container keeps only the *banded*
transition data (arrival pmfs + overflow tails) — the (N, S, A, S) dense
tensors are materialized per spec on demand, so a wide sweep stays O(N*S*A)
in memory.

Phase-modulated extension (beyond-paper, ROADMAP "true MMPP-aware solve")
-------------------------------------------------------------------------

build_smdp_modulated() generalizes the state space from ``queue`` to
``(phase, queue)`` for a K-phase Markov-modulated Poisson arrival process
(PhaseConfig: per-phase rates lambda_z and a phase generator R).  The
transition data stays banded — per action the joint law of (arrivals k
during one service, end phase z') is a K x K matrix-valued pmf over the
same k <= s_max band, plus phase-resolved overflow tails and a K x K
arrival-phase matrix for the wait action — computed *exactly* by
uniformizing the marked Markov process at theta >= max_z(lambda_z + q_z):

    D_{n,k} = D_{n-1,k} U0 + D_{n-1,k-1} U1,   D_{0,0} = I,
    U0 = I + (R - Lambda)/theta  (no arrival),  U1 = Lambda/theta  (arrival),
    p^{[a]}_k = sum_n  P(Poisson(theta G_a) = n)  D_{n,k},

where the step-count mixture P(Poisson(theta G_a) = n) is exactly
ServiceModel.arrival_pmf(a, theta, .) — every service family already has it
in closed form.  The phase-modulated holding cost uses the uniformization
identity E[int_0^t f(X_u) du] = (1/theta) sum_n P(N_theta(t) > n) E[f(X_n)].
With K = 1 every quantity degenerates bitwise to the Poisson construction
above (U0 = 0, U1 = 1 makes D_{n,k} = delta_{nk}), which is the refactor's
safety rail: the K = 1 modulated solve must reproduce the scalar oracle.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .service_models import ServiceModel, Profile


@dataclasses.dataclass(frozen=True)
class SMDPSpec:
    """Problem definition (paper Sec. III-IV)."""

    lam: float  # Poisson arrival rate
    service: ServiceModel  # G_b family + l(b)
    energy: Profile  # zeta(b)
    b_min: int = 1
    b_max: int = 32
    w1: float = 1.0  # weight on average response time (via holding cost)
    w2: float = 0.0  # weight on average power
    s_max: int = 128  # truncation level (>= b_max)
    c_o: float = 100.0  # abstract overflow-cost rate (paper Sec. V-A)
    buffer: Optional[int] = None  # finite waiting room B (None = abstract tail)
    c_drop: float = 0.0  # per-dropped-request cost (finite buffer only)

    def __post_init__(self):
        if self.s_max < self.b_max:
            raise ValueError("s_max must be >= b_max (paper Sec. V-A)")
        if not (0 < self.b_min <= self.b_max):
            raise ValueError("need 0 < b_min <= b_max")
        if self.c_drop < 0:
            raise ValueError("c_drop must be >= 0")
        if self.buffer is not None:
            if self.buffer != self.s_max:
                raise ValueError(
                    "finite-buffer specs fold exactly at the truncation "
                    f"level: need buffer == s_max, got buffer={self.buffer}, "
                    f"s_max={self.s_max}"
                )
            if self.lam <= 0:
                raise ValueError("need lam > 0")
            # overload (rho >= 1) is allowed: a finite-buffer chain is
            # always stable, and shedding is the regime of interest
            return
        rho = self.rho
        if not (0.0 < rho < 1.0):
            raise ValueError(f"instability: rho={rho:.3f} not in (0,1)")

    @property
    def rho(self) -> float:
        """Normalized traffic intensity lam / (B_max * mu^[B_max])."""
        return self.lam * float(self.service.mean(self.b_max)) / self.b_max


@dataclasses.dataclass
class TruncatedSMDP:
    """Dense truncated SMDP (eq. 18-19) and its discretized MDP (eq. 23)."""

    spec: SMDPSpec
    n_states: int  # s_max + 2
    n_actions: int  # b_max + 1
    feasible: np.ndarray  # (S, A) bool
    y: np.ndarray  # (S, A) expected sojourn times
    c_hat: np.ndarray  # (S, A) expected costs (with abstract cost at S_o)
    m_hat: np.ndarray  # (S, A, S) transition probs
    # discretized
    eta: float
    c_tilde: np.ndarray  # (S, A)
    m_tilde: np.ndarray  # (S, A, S)
    # component costs for objective decomposition (same layout as c_hat)
    c_hold: np.ndarray  # w1-free holding cost integral  E[int s(t) dt]/lam
    c_energy: np.ndarray  # zeta(a) (0 for a=0)
    arrival_pmfs: np.ndarray  # (A, K+1) p_k per action (0 row for a=0)

    @property
    def s_o(self) -> int:
        return self.n_states - 1


@dataclasses.dataclass
class BatchedSMDP:
    """A stack of truncated SMDPs sharing (s_max, b_max).

    Leading axis N indexes specs; the layout of every per-spec slice matches
    TruncatedSMDP.  Transition structure is stored banded — ``pmfs_banded``
    (arrival pmfs truncated to k <= s_max) plus ``tails`` (overflow mass
    towards S_o per base state) — exactly the inputs of rvi.banded_backup.
    """

    specs: List[SMDPSpec]
    n_specs: int
    n_states: int  # S = s_max + 2
    n_actions: int  # A = b_max + 1
    feasible: np.ndarray  # (N, S, A) bool
    y: np.ndarray  # (N, S, A)
    c_hat: np.ndarray  # (N, S, A)
    eta: np.ndarray  # (N,)
    c_tilde: np.ndarray  # (N, S, A), +inf at infeasible
    c_hold: np.ndarray  # (N, S, A)
    c_energy: np.ndarray  # (N, S, A)
    arrival_pmfs: np.ndarray  # (N, A, K+1), K = s_max + 1
    pmfs_banded: np.ndarray  # (N, A, s_max+1): columns k <= s_max
    tails: np.ndarray  # (N, A, s_max+1): overflow mass per base state t
    scale: np.ndarray  # (N, S, A) = eta / y

    @property
    def s_max(self) -> int:
        return self.specs[0].s_max

    @property
    def s_o(self) -> int:
        return self.n_states - 1

    def m_hat_dense(self, i: Optional[int] = None) -> np.ndarray:
        """Materialize the dense (eq. 18) transition tensor.

        Returns (N, S, A, S), or (S, A, S) for a single spec ``i``.
        """
        sel = slice(None) if i is None else slice(i, i + 1)
        m = _dense_m_hat(
            self.specs[0].s_max,
            self.arrival_pmfs[sel],
            self.tails[sel],
            self.feasible[sel],
        )
        return m if i is None else m[0]

    def m_tilde_dense(self, i: Optional[int] = None) -> np.ndarray:
        """Materialize the discretized (eq. 23) transition tensor."""
        sel = slice(None) if i is None else slice(i, i + 1)
        m = _dense_m_tilde(
            self.m_hat_dense()[sel] if i is None else self.m_hat_dense(i)[None],
            self.scale[sel],
            self.feasible[sel],
        )
        return m if i is None else m[0]

    def take(self, indices: Sequence[int]) -> "BatchedSMDP":
        """Sub-batch view over the given spec indices (no re-building)."""
        idx = list(indices)
        return BatchedSMDP(
            specs=[self.specs[i] for i in idx],
            n_specs=len(idx),
            n_states=self.n_states,
            n_actions=self.n_actions,
            feasible=self.feasible[idx],
            y=self.y[idx],
            c_hat=self.c_hat[idx],
            eta=self.eta[idx],
            c_tilde=self.c_tilde[idx],
            c_hold=self.c_hold[idx],
            c_energy=self.c_energy[idx],
            arrival_pmfs=self.arrival_pmfs[idx],
            pmfs_banded=self.pmfs_banded[idx],
            tails=self.tails[idx],
            scale=self.scale[idx],
        )

    def with_c_o(self, c_os: Sequence[float]) -> "BatchedSMDP":
        """Copy of the batch with new per-spec abstract overflow costs.

        c_o only enters the S_o row of c_hat (eq. 19) and its discretized
        c_tilde — transitions, eta and scale are untouched — so swapping it
        is a row patch, not a rebuild.  This is how sweep_solve reuses the
        c_o = 0 probe batch of the abstract-cost calibration as the first
        solve batch.
        """
        c_os = np.asarray(c_os, dtype=np.float64)
        if c_os.shape != (self.n_specs,):
            raise ValueError(f"need {self.n_specs} c_o values")
        old = np.array([sp.c_o for sp in self.specs])
        # finite-buffer specs have no abstract tail: S_o is an exact alias
        # of state B and carries no c_o term, so the patch is a no-op there
        finite = np.array([sp.buffer is not None for sp in self.specs])
        c_os = np.where(finite, old, c_os)
        s_o = self.s_o
        c_hat = self.c_hat.copy()
        c_hat[:, s_o, :] += (c_os - old)[:, None] * self.y[:, s_o, :]
        c_tilde = self.c_tilde.copy()
        with np.errstate(invalid="ignore"):
            c_tilde[:, s_o, :] = np.where(
                self.feasible[:, s_o, :],
                c_hat[:, s_o, :] / self.y[:, s_o, :],
                np.inf,
            )
        return dataclasses.replace(
            self,
            specs=[
                dataclasses.replace(sp, c_o=float(c))
                for sp, c in zip(self.specs, c_os)
            ],
            c_hat=c_hat,
            c_tilde=c_tilde,
        )

    def policy_transitions_batched(self, policies: np.ndarray) -> np.ndarray:
        """(N, S, S) m_hat rows under per-spec policies — no dense tensor.

        The batch-wide form of policy_transitions: one broadcast gather
        instead of N python loops, feeding the batched stationary solve of
        evaluate.evaluate_policy_batched.
        """
        s_max = self.specs[0].s_max
        S = self.n_states
        s_o = S - 1
        N = self.n_specs
        acts = np.asarray(policies, dtype=np.int64)  # (N, S)
        if acts.shape != (N, S):
            raise ValueError(f"policies shape {acts.shape} != ({N}, {S})")
        s_val = _state_values(s_max).astype(np.int64)
        s_idx = np.arange(S)
        serve = acts >= 1
        base = np.clip(s_val[None, :] - acts, 0, s_max)  # (N, S)
        k = np.arange(s_max + 1)[None, None, :] - base[..., None]  # (N, S, K)
        nn = np.arange(N)[:, None, None]
        gathered = self.pmfs_banded[nn, acts[..., None], np.clip(k, 0, s_max)]
        p = np.zeros((N, S, S))
        p[:, :, : s_max + 1] = np.where((k >= 0) & serve[..., None], gathered, 0.0)
        p[:, :, s_o] += np.where(
            serve, self.tails[np.arange(N)[:, None], acts, base], 0.0
        )
        nxt = np.where(s_idx < s_max, s_idx + 1, s_o)
        onehot = np.zeros((S, S))
        onehot[s_idx, nxt] = 1.0
        p = np.where(serve[..., None], p, onehot[None])
        # normalize tiny numerical drift (same rule as the dense path)
        row_sums = p.sum(axis=-1, keepdims=True)
        np.divide(p, row_sums, out=p, where=row_sums > 1e-12)
        return p

    def policy_transitions(self, i: int, policy: np.ndarray) -> np.ndarray:
        """(S, S) m_hat rows of spec ``i`` under ``policy`` — no dense tensor.

        Row s is the arrival-pmf window of the chosen action (eq. 18), so
        policy evaluation over a whole sweep never materializes (S, A, S).
        """
        s_max = self.specs[0].s_max
        S = self.n_states
        s_o = S - 1
        acts = np.asarray(policy, dtype=np.int64)
        s_val = _state_values(s_max).astype(np.int64)
        p = np.zeros((S, S))
        s_idx = np.arange(S)
        wait = acts == 0
        nxt = np.where(s_idx < s_max, s_idx + 1, s_o)
        p[s_idx[wait], nxt[wait]] = 1.0
        serve = ~wait
        if serve.any():
            a_s = acts[serve]
            base = s_val[serve] - a_s  # >= 0 for feasible actions
            k = np.arange(s_max + 1)[None, :] - base[:, None]
            pm = self.pmfs_banded[i]  # (A, s_max+1)
            window = np.where(k >= 0, pm[a_s[:, None], np.clip(k, 0, s_max)], 0.0)
            p[serve, : s_max + 1] = window
            p[serve, s_o] = self.tails[i][a_s, base]
        # normalize tiny numerical drift (same rule as the dense path)
        row_sums = p.sum(axis=-1, keepdims=True)
        np.divide(p, row_sums, out=p, where=row_sums > 1e-12)
        return p

    def dense(self, i: int) -> TruncatedSMDP:
        """Per-spec TruncatedSMDP view with materialized dense tensors."""
        m_hat = self.m_hat_dense(i)
        m_tilde = _dense_m_tilde(
            m_hat[None], self.scale[i : i + 1], self.feasible[i : i + 1]
        )[0]
        return TruncatedSMDP(
            spec=self.specs[i],
            n_states=self.n_states,
            n_actions=self.n_actions,
            feasible=self.feasible[i],
            y=self.y[i],
            c_hat=self.c_hat[i],
            m_hat=m_hat,
            eta=float(self.eta[i]),
            c_tilde=self.c_tilde[i],
            m_tilde=m_tilde,
            c_hold=self.c_hold[i],
            c_energy=self.c_energy[i],
            arrival_pmfs=self.arrival_pmfs[i],
        )


# ---------------------------------------------------------------------------
# Broadcast assembly
# ---------------------------------------------------------------------------


def _state_values(s_max: int) -> np.ndarray:
    """Requests represented by each state index; S_o counts as s_max."""
    s_val = np.arange(s_max + 2, dtype=np.float64)
    s_val[-1] = s_max
    return s_val


def _dense_m_hat(
    s_max: int,
    pmfs: np.ndarray,  # (N, A, K+1)
    tails: np.ndarray,  # (N, A, s_max+1)
    feasible: np.ndarray,  # (N, S, A)
    pmf_tol: float = 1e-12,
) -> np.ndarray:
    """Broadcast construction of the (N, S, A, S) transition tensor (eq. 18)."""
    N, A = pmfs.shape[0], pmfs.shape[1]
    S = s_max + 2
    s_o = S - 1
    K = pmfs.shape[2] - 1
    s_val = _state_values(s_max)
    acts = np.arange(A)

    m = np.zeros((N, S, A, S))
    # a = 0: deterministic +1 (S_o self-loops; s_max -> S_o)
    rows = np.arange(s_max)
    m[:, rows, 0, rows + 1] = 1.0
    m[:, s_max, 0, s_o] = 1.0
    m[:, s_o, 0, s_o] = 1.0
    # a != 0: base state t = s_val(s) - a; arrivals k land at j = t + k
    base = s_val[:, None] - acts[None, :]  # (S, A)
    j = np.arange(s_max + 1)
    k = j[None, None, :] - base[:, :, None]  # (S, A, s_max+1)
    serve = feasible & (acts[None, None, :] >= 1)  # (N, S, A)
    valid = (k >= 0) & serve[..., None]  # (N, S, A, s_max+1)
    k_idx = np.clip(k, 0, K).astype(np.int64)
    gathered = pmfs[:, acts[:, None], k_idx]  # (N, S, A, J)
    m[..., : s_max + 1] += np.where(valid, gathered, 0.0)
    # overflow mass towards S_o
    t_idx = np.clip(base, 0, s_max).astype(np.int64)  # (S, A)
    tail_gather = tails[:, acts, t_idx]  # (N, S, A)
    m[..., s_o] += np.where(serve, tail_gather, 0.0)
    # normalize tiny numerical drift
    row_sums = m.sum(axis=-1, keepdims=True)
    np.divide(m, row_sums, out=m, where=row_sums > pmf_tol)
    return m


def _dense_m_tilde(
    m_hat: np.ndarray,  # (N, S, A, S)
    scale: np.ndarray,  # (N, S, A)
    feasible: np.ndarray,  # (N, S, A)
) -> np.ndarray:
    """Discretized transitions (eq. 23): scale towards eta-uniformization."""
    N, S, A = scale.shape
    idx = np.arange(S)
    m = m_hat * scale[..., None]
    m[:, idx[:, None], np.arange(A)[None, :], idx[:, None]] += 1.0 - scale
    # infeasible rows: harmless self-loop (masked out in the backup anyway)
    inf_mask = ~feasible
    m[inf_mask] = 0.0
    nI, sI, aI = np.nonzero(inf_mask)
    m[nI, sI, aI, sI] = 1.0
    return m


def _finite_buffer_patches(
    s_max: int,
    lam: np.ndarray,  # (N,)
    y_a: np.ndarray,  # (N, A) E[G_a] (1/lam in column 0, unused here)
    e2: np.ndarray,  # (N, A) E[G_a^2]
    pmfs: np.ndarray,  # (N, A, K+1) arrival pmfs
    feasible: np.ndarray,  # (N, S, A)
) -> Tuple[np.ndarray, np.ndarray]:
    """Exact capped-holding corrections and drop counts for B = s_max.

    Serving a from state s leaves t = s - a waiting and c = B - t free
    slots; with N the arrivals during the service (pmf p_k, E[N] =
    lam E[G_a], E[N^2] = lam E[G_a] + lam^2 E[G_a^2]):

      E[drops]  = E[max(0, N - c)] = E[N] - c + sum_{k<=c} (c - k) p_k
      E[excess] = E[int_0^G max(0, N(u) - c) du]
                = (1/lam) sum_{k>c} (k - c) Q_k,      Q_k = P(N > k),

    the excess integral via the Poisson identity E[lam T_k] = Q_k for
    T_k = time spent at count k (exactly one arrival occurs while the
    count sits at k iff N ends above k), closed with sum_k Q_k = E[N]
    and sum_k k Q_k = (E[N^2] - E[N]) / 2:

      sum_{k>c} (k-c) Q_k
        = (E[N^2] - E[N])/2 - c E[N] + sum_{k<=c} (c - k) Q_k.

    Both prefix sums stop at c <= s_max, inside the exactly-known pmf
    band, so no truncation enters.  Returns ``(hold_corr, drops)`` as
    (N, S, A) arrays, zero at wait / infeasible entries; hold_corr is in
    c_hold units (E[int . du] / lam, hence the extra 1/lam).
    """
    N, A = y_a.shape
    S = s_max + 2
    T = s_max + 1
    s_val = _state_values(s_max)
    acts = np.arange(A)
    ks = np.arange(T, dtype=np.float64)
    pm = pmfs[:, :, :T]
    P0 = np.cumsum(pm, axis=-1)  # (N, A, T): sum_{k<=c} p_k
    P1 = np.cumsum(pm * ks, axis=-1)  # sum_{k<=c} k p_k
    Q = np.maximum(0.0, 1.0 - P0)  # Q_c = P(N > c)
    S0 = np.cumsum(Q, axis=-1)  # sum_{k<=c} Q_k
    S1 = np.cumsum(Q * ks, axis=-1)  # sum_{k<=c} k Q_k
    EN = lam[:, None] * y_a  # (N, A) = lam E[G_a]
    EN2 = EN + lam[:, None] ** 2 * e2
    base = s_val[:, None] - acts[None, :]  # (S, A): waiting after dispatch
    c_cap = np.clip(s_max - base, 0, s_max).astype(np.int64)  # free slots
    cf = c_cap.astype(np.float64)
    a_idx = np.broadcast_to(acts[None, :], (S, A))
    P0g = P0[:, a_idx, c_cap]  # (N, S, A)
    P1g = P1[:, a_idx, c_cap]
    S0g = S0[:, a_idx, c_cap]
    S1g = S1[:, a_idx, c_cap]
    drops = EN[:, None, :] - cf[None] + cf[None] * P0g - P1g
    excess = (
        0.5 * (EN2 - EN)[:, None, :]
        - cf[None] * EN[:, None, :]
        + cf[None] * S0g
        - S1g
    )
    serve = feasible & (acts[None, None, :] >= 1)
    drops = np.where(serve, np.maximum(0.0, drops), 0.0)
    hold_corr = np.where(
        serve, np.maximum(0.0, excess) / lam[:, None, None] ** 2, 0.0
    )
    return hold_corr, drops


def build_smdp_batched(specs: Sequence[SMDPSpec]) -> BatchedSMDP:
    """Construct a stacked batch of truncated SMDPs (eq. 18-19, 23-25).

    All specs must share (s_max, b_max) — use sweep.pad_specs to lift a
    mixed-truncation list to a common level.  Arrival rates, weights,
    service families, energy profiles and b_min may vary freely.
    """
    specs = list(specs)
    if not specs:
        raise ValueError("empty spec batch")
    s_max = specs[0].s_max
    b_max = specs[0].b_max
    for sp in specs[1:]:
        if sp.s_max != s_max or sp.b_max != b_max:
            raise ValueError(
                "batched specs must share (s_max, b_max); got "
                f"({sp.s_max}, {sp.b_max}) vs ({s_max}, {b_max})"
            )
    N = len(specs)
    S = s_max + 2
    A = b_max + 1
    s_o = S - 1
    K = s_max + 1
    s_val = _state_values(s_max)
    acts = np.arange(A)
    bs = np.arange(1, A)

    lam = np.array([sp.lam for sp in specs])
    b_min = np.array([sp.b_min for sp in specs])
    w1 = np.array([sp.w1 for sp in specs])
    w2 = np.array([sp.w2 for sp in specs])
    c_o = np.array([sp.c_o for sp in specs])

    # --- per-spec action profiles (vectorized over b; closed-form pmfs) ---
    y_a = np.zeros((N, A))
    e2 = np.zeros((N, A))
    zeta = np.zeros((N, A))
    pmfs = np.zeros((N, A, K + 1))
    for i, sp in enumerate(specs):
        y_a[i, 0] = 1.0 / sp.lam
        y_a[i, 1:] = sp.service.mean(bs)
        e2[i, 1:] = sp.service.second_moment(bs)
        zeta[i, 1:] = sp.energy(bs)
        for a in range(1, A):
            pmfs[i, a] = sp.service.arrival_pmf(a, sp.lam, K)

    # --- feasibility: wait always; serve iff b_min <= a <= s (eq. 8) ---
    feasible = (s_val[None, :, None] >= acts[None, None, :]) & (
        acts[None, None, :] >= b_min[:, None, None]
    )
    feasible[:, :, 0] = True

    # --- sojourn times y(s, a)  (eq. 9): s-independent ---
    y = np.broadcast_to(y_a[:, None, :], (N, S, A)).copy()

    # --- costs (eq. 11, 19) ---
    c_hold = np.zeros((N, S, A))  # = E[int_0^gamma s(t) dt] / lam (w1 term)
    c_hold[:, :, 0] = s_val[None, :] / lam[:, None] ** 2
    c_hold[:, :, 1:] = (
        s_val[None, :, None] * y_a[:, None, 1:] / lam[:, None, None]
        + 0.5 * e2[:, None, 1:]
    )
    c_energy = np.broadcast_to(zeta[:, None, :], (N, S, A)).copy()  # w2 term
    # finite-buffer specs: S_o becomes an exact alias of state B = s_max
    # (the banded backup already serves S_o from base s_max and folds the
    # overflow tail back onto S_o, so duplicating B's cost rows makes the
    # tail-fold the *physical* fold-at-B — an exact chain, not a
    # truncation).  Serve costs get the exact capped-holding correction
    # and the exact expected drop count; waiting at a full buffer sheds
    # the next arrival.  Patches are indexed so tail-abstracted specs in
    # the same batch stay byte-identical to the plain construction.
    finite = np.array([sp.buffer is not None for sp in specs])
    fin_idx = np.nonzero(finite)[0]
    if fin_idx.size:
        c_drop_arr = np.array([sp.c_drop for sp in specs])
        hold_corr, drops = _finite_buffer_patches(
            s_max, lam, y_a, e2, pmfs, feasible
        )
        c_hold[fin_idx] -= hold_corr[fin_idx]
    c_hat = w1[:, None, None] * c_hold + w2[:, None, None] * c_energy
    if fin_idx.size:
        c_hat[fin_idx] += c_drop_arr[fin_idx, None, None] * drops[fin_idx]
        c_hat[fin_idx, s_max, 0] += c_drop_arr[fin_idx]  # wait at B: 1 shed
        c_hat[fin_idx, s_o, 0] += c_drop_arr[fin_idx]  # S_o aliases B
    # abstract cost at the overflow state (eq. 19): + c_o * y(s, a) —
    # tail-abstracted specs only (finite buffers have no abstract tail)
    inf_idx = np.nonzero(~finite)[0]
    c_hat[inf_idx, s_o, :] += c_o[inf_idx, None] * y[inf_idx, s_o, :]

    # --- banded transition data ---
    pm = pmfs[:, :, : s_max + 1].copy()  # k > s_max always lands in S_o
    csum = np.cumsum(pm, axis=-1)
    # tails[i, a, t] = 1 - sum_{k <= s_max - t} p_k  (overflow from base t)
    tails = np.maximum(0.0, 1.0 - csum[:, :, ::-1])
    tails[:, 0, :] = 0.0

    # --- discretization (eq. 23-25) ---
    # structured self-transition probabilities: for feasible (s, a != 0) the
    # diagonal entry is p^{[a]}_a (k = a puts the chain back at s); at S_o it
    # is the overflow tail from base s_max - a; waiting self-loops only at S_o
    diag = np.zeros((N, S, A))
    pm_diag = pm[:, acts, np.minimum(acts, s_max)]  # (N, A): p^{[a]}_a
    diag[:, : s_max + 1, :] = np.where(
        feasible[:, : s_max + 1, :] & (acts[None, None, :] >= 1),
        pm_diag[:, None, :],
        0.0,
    )
    diag[:, s_o, 1:] = tails[:, bs, s_max - bs]
    diag[:, s_o, 0] = 1.0

    with np.errstate(divide="ignore"):
        bound = np.where(
            (diag < 1.0) & feasible, y / np.maximum(1.0 - diag, 1e-300), np.inf
        )
    eta = 0.999 * bound.reshape(N, -1).min(axis=1)
    if not np.all(np.isfinite(eta)) or np.any(eta <= 0):
        raise RuntimeError("degenerate eta bound")

    with np.errstate(invalid="ignore"):
        c_tilde = np.where(feasible, c_hat / y, np.inf)
    scale = eta[:, None, None] / y

    return BatchedSMDP(
        specs=specs,
        n_specs=N,
        n_states=S,
        n_actions=A,
        feasible=feasible,
        y=y,
        c_hat=c_hat,
        eta=eta,
        c_tilde=c_tilde,
        c_hold=c_hold,
        c_energy=c_energy,
        arrival_pmfs=pmfs,
        pmfs_banded=pm,
        tails=tails,
        scale=scale,
    )


def build_smdp(spec: SMDPSpec, pmf_tol: float = 1e-12) -> TruncatedSMDP:
    """Construct the truncated SMDP per eq. (18)-(19).

    The scalar path is the N == 1 slice of the broadcast batched assembly.
    """
    del pmf_tol  # drift normalization is part of the dense materialization
    return build_smdp_batched([spec]).dense(0)


# ---------------------------------------------------------------------------
# Phase-modulated (MMPP-K) product chain
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PhaseConfig:
    """K-phase MMPP arrival modulation: per-phase rates + phase generator.

    ``rates[z]`` is the Poisson arrival rate while the modulating chain sits
    in phase z; ``gen`` is the K x K generator of that (autonomous) chain —
    rows sum to zero, off-diagonals non-negative.  Arrivals never switch the
    phase (MMPP, not MAP).  K = 1 with gen = ((0,),) is plain Poisson.
    """

    rates: Tuple[float, ...]
    gen: Tuple[Tuple[float, ...], ...]

    def __post_init__(self):
        rates = np.asarray(self.rates, dtype=np.float64)
        R = np.asarray(self.gen, dtype=np.float64)
        K = len(rates)
        if R.shape != (K, K):
            raise ValueError(f"gen shape {R.shape} != ({K}, {K})")
        if np.any(rates < 0) or not np.any(rates > 0):
            raise ValueError("phase rates must be >= 0 with at least one > 0")
        off = R - np.diag(np.diag(R))
        if np.any(off < -1e-12):
            raise ValueError("generator off-diagonals must be >= 0")
        if np.any(np.abs(R.sum(axis=1)) > 1e-9 * max(1.0, np.abs(R).max())):
            raise ValueError("generator rows must sum to 0")

    @property
    def n_phases(self) -> int:
        return len(self.rates)

    @property
    def rates_arr(self) -> np.ndarray:
        return np.asarray(self.rates, dtype=np.float64)

    @property
    def gen_arr(self) -> np.ndarray:
        return np.asarray(self.gen, dtype=np.float64)

    def stationary(self) -> np.ndarray:
        """Stationary distribution pi of the phase generator (pi R = 0)."""
        K = self.n_phases
        if K == 1:
            return np.ones(1)
        a = self.gen_arr.T.copy()
        a[-1, :] = 1.0
        b = np.zeros(K)
        b[-1] = 1.0
        pi = np.linalg.solve(a, b)
        pi = np.clip(pi, 0.0, None)
        return pi / pi.sum()

    @property
    def mean_rate(self) -> float:
        """Long-run average arrival rate sum_z pi_z lambda_z."""
        return float(self.stationary() @ self.rates_arr)

    def scaled(self, factor: float) -> "PhaseConfig":
        """Copy with every phase rate scaled (same burst structure).

        The lambda axis of a modulated sweep bank: scaling rates (not
        dwells) moves the mean rate while preserving the rate *ratio* and
        the switching dynamics.
        """
        return PhaseConfig(
            rates=tuple(float(r) * float(factor) for r in self.rates),
            gen=self.gen,
        )

    @classmethod
    def mmpp2(
        cls, lam1: float, lam2: float, dwell1: float, dwell2: float
    ) -> "PhaseConfig":
        """Two-phase MMPP from rates + mean dwell times (serving.MMPP2)."""
        return cls(
            rates=(float(lam1), float(lam2)),
            gen=(
                (-1.0 / dwell1, 1.0 / dwell1),
                (1.0 / dwell2, -1.0 / dwell2),
            ),
        )

    @classmethod
    def from_mmpp(cls, m) -> "PhaseConfig":
        """Coerce an MMPP2-like object (lam1/lam2/dwell1/dwell2 attrs)."""
        return cls.mmpp2(m.lam1, m.lam2, m.dwell1, m.dwell2)

    @classmethod
    def poisson(cls, lam: float) -> "PhaseConfig":
        """The degenerate K = 1 config (the bit-identity safety rail)."""
        return cls(rates=(float(lam),), gen=((0.0,),))


def modulated_spec(base: SMDPSpec, phases: PhaseConfig) -> SMDPSpec:
    """Pin the spec's lam to the modulation's mean rate (rho bookkeeping)."""
    return dataclasses.replace(base, lam=phases.mean_rate)


def phase_rho(spec: SMDPSpec, phases: PhaseConfig) -> float:
    """Worst *within-phase* traffic intensity of a modulated spec.

    The burst phase sets the solver's mixing wall even when the mean rho
    is small, so acceleration decisions key on this, not on spec.rho.
    """
    return (
        float(phases.rates_arr.max())
        * float(spec.service.mean(spec.b_max))
        / spec.b_max
    )


@dataclasses.dataclass
class ModulatedBatchedSMDP:
    """A stack of phase-modulated truncated SMDPs sharing (s_max, b_max, K).

    The product state space per spec is (phase z, queue state s) with
    s in {0..s_max, S_o}; the flattened index order is z * S + s (phase
    blocks).  Transition data stays banded and phase-coupled:

      * ``pmfs_banded[n, a, z, w, k]`` — P(k arrivals in band, end phase w |
        start phase z, serve a);
      * ``tails[n, a, z, w, t]``       — overflow mass to (w, S_o) from base
        state t;
      * ``wait_m[n, z, w]``            — P(next arrival occurs in phase w |
        start phase z) for the wait action (sojourn ``y[., z, :, 0]``).

    Feasibility is phase-independent ((N, S, A), same rule as the scalar
    chain); costs/sojourns/scales carry the phase axis ((N, K, S, A)).
    There is deliberately no dense materialization — every consumer
    (rvi/evaluate/sweep) operates on the K*S banded system.
    """

    specs: List[SMDPSpec]
    phases: List[PhaseConfig]
    n_specs: int
    n_phases: int  # K
    n_states: int  # S = s_max + 2 (per phase)
    n_actions: int  # A = b_max + 1
    feasible: np.ndarray  # (N, S, A) bool — phase-independent
    y: np.ndarray  # (N, K, S, A)
    c_hat: np.ndarray  # (N, K, S, A)
    eta: np.ndarray  # (N,)
    c_tilde: np.ndarray  # (N, K, S, A), +inf at infeasible
    c_hold: np.ndarray  # (N, K, S, A)
    c_energy: np.ndarray  # (N, K, S, A)
    scale: np.ndarray  # (N, K, S, A) = eta / y
    pmfs_banded: np.ndarray  # (N, A, K, K, s_max+1)
    tails: np.ndarray  # (N, A, K, K, s_max+1)
    wait_m: np.ndarray  # (N, K, K)
    lam_eff: np.ndarray  # (N,) mean arrival rates

    @property
    def s_max(self) -> int:
        return self.specs[0].s_max

    @property
    def s_o(self) -> int:
        return self.n_states - 1

    def take(self, indices: Sequence[int]) -> "ModulatedBatchedSMDP":
        """Sub-batch view over the given spec indices (no re-building)."""
        idx = list(indices)
        return ModulatedBatchedSMDP(
            specs=[self.specs[i] for i in idx],
            phases=[self.phases[i] for i in idx],
            n_specs=len(idx),
            n_phases=self.n_phases,
            n_states=self.n_states,
            n_actions=self.n_actions,
            feasible=self.feasible[idx],
            y=self.y[idx],
            c_hat=self.c_hat[idx],
            eta=self.eta[idx],
            c_tilde=self.c_tilde[idx],
            c_hold=self.c_hold[idx],
            c_energy=self.c_energy[idx],
            scale=self.scale[idx],
            pmfs_banded=self.pmfs_banded[idx],
            tails=self.tails[idx],
            wait_m=self.wait_m[idx],
            lam_eff=self.lam_eff[idx],
        )

    def with_c_o(self, c_os: Sequence[float]) -> "ModulatedBatchedSMDP":
        """Copy with new per-spec abstract overflow costs (row patch).

        Exactly the BatchedSMDP.with_c_o trick: c_o only enters the S_o rows
        of c_hat (every phase's overflow state) and their c_tilde.
        """
        c_os = np.asarray(c_os, dtype=np.float64)
        if c_os.shape != (self.n_specs,):
            raise ValueError(f"need {self.n_specs} c_o values")
        old = np.array([sp.c_o for sp in self.specs])
        s_o = self.s_o
        c_hat = self.c_hat.copy()
        c_hat[:, :, s_o, :] += (c_os - old)[:, None, None] * self.y[:, :, s_o, :]
        c_tilde = self.c_tilde.copy()
        with np.errstate(invalid="ignore"):
            c_tilde[:, :, s_o, :] = np.where(
                self.feasible[:, None, s_o, :],
                c_hat[:, :, s_o, :] / self.y[:, :, s_o, :],
                np.inf,
            )
        return dataclasses.replace(
            self,
            specs=[
                dataclasses.replace(sp, c_o=float(c))
                for sp, c in zip(self.specs, c_os)
            ],
            c_hat=c_hat,
            c_tilde=c_tilde,
        )

    def policy_transitions_batched(self, policies: np.ndarray) -> np.ndarray:
        """(N, K*S, K*S) embedded-chain (m_hat) rows under per-spec policies.

        ``policies`` is (N, K, S) int.  Feeds the batched stationary solve
        of evaluate.evaluate_policy_modulated_batched; rows are normalized
        against the ~1e-13 uniformization-truncation drift, the same rule
        as the scalar banded path.
        """
        N, K, S = self.n_specs, self.n_phases, self.n_states
        s_max = self.s_max
        s_o = S - 1
        acts = np.asarray(policies, dtype=np.int64)
        if acts.shape != (N, K, S):
            raise ValueError(f"policies shape {acts.shape} != ({N}, {K}, {S})")
        s_val = _state_values(s_max).astype(np.int64)  # (S,)
        serve = acts >= 1  # (N, K, S)
        base = np.clip(s_val[None, None, :] - acts, 0, s_max)  # (N, K, S)
        k = (
            np.arange(s_max + 1)[None, None, None, :] - base[..., None]
        )  # (N, K, S, s_max+1)
        nn = np.arange(N)[:, None, None, None, None]
        zz = np.arange(K)[None, :, None, None, None]
        ww = np.arange(K)[None, None, None, :, None]
        a_idx = acts[:, :, :, None, None]
        k_idx = np.clip(k, 0, s_max)[:, :, :, None, :]
        # window[n, z, s, w, j] = p^{[a]}_{j - base}[z -> w]
        window = np.where(
            (k[:, :, :, None, :] >= 0) & serve[..., None, None],
            self.pmfs_banded[nn, a_idx, zz, ww, k_idx],
            0.0,
        )  # (N, K, S, K, s_max+1)
        p = np.zeros((N, K, S, K, S))
        p[..., : s_max + 1] = window
        tail = self.tails[
            nn[..., 0], acts[..., None], zz[..., 0], ww[..., 0],
            base[..., None],
        ]  # (N, K, S, K)
        p[..., s_o] += np.where(serve[..., None], tail, 0.0)
        # wait rows: (z, s) -> (w, s + 1) (S_o absorbs) with wait_m weights
        s_idx = np.arange(S)
        nxt = np.where(s_idx < s_max, s_idx + 1, s_o)
        wait_rows = np.zeros((N, K, S, K, S))
        # advanced indices split by a slice put the broadcast (S,) axis first
        wait_rows[:, :, s_idx, :, nxt] = self.wait_m[None]
        p = np.where(serve[..., None, None], p, wait_rows)
        p = p.reshape(N, K * S, K * S)
        row_sums = p.sum(axis=-1, keepdims=True)
        np.divide(p, row_sums, out=p, where=row_sums > 1e-12)
        return p


def _modulated_action_data(
    spec: SMDPSpec,
    phases: PhaseConfig,
    tol: float = 1e-13,
    n_cap: int = 1 << 15,
    chunk: int = 128,
):
    """Exact per-action phase-coupled arrival law via marked uniformization.

    Returns (pmfs (A, K, K, T), tails (A, K, K, T), wait_m (K, K),
    y_wait (K,), c_extra (A, K), lam_eff) for one spec; see the module
    docstring for the recursion.  ``c_extra[a, z]`` is
    E[int_0^{G_a} N(u) du | phase z at start] — the arrivals' holding-cost
    integral during one service (the modulated analogue of lam E[G^2]/2).
    """
    rates = phases.rates_arr
    R = phases.gen_arr
    K = len(rates)
    s_max = spec.s_max
    T = s_max + 1
    A = spec.b_max + 1
    theta = float(np.max(rates - np.diag(R)))
    if theta <= 0:
        raise ValueError("degenerate modulation: all rates and switching 0")
    Lam = np.diag(rates)
    U0 = np.eye(K) + (R - Lam) / theta
    U1 = Lam / theta
    Pi = U0 + U1  # phase-marginal uniformized step, = I + R/theta

    # steps-per-service mixture: P(Poisson(theta * G_a) = n), exact per family
    n_hi = 256
    while True:
        W = np.zeros((A, n_hi + 1))
        for a in range(1, A):
            W[a] = spec.service.arrival_pmf(a, theta, n_hi)
        miss = 1.0 - W[1:].sum(axis=1)
        if miss.max() <= tol or n_hi >= n_cap:
            break
        n_hi *= 2
    if miss.max() > 1e-9:
        raise RuntimeError(
            f"uniformized step distribution not captured at n = {n_hi} "
            f"(missing mass {miss.max():.2e}); theta * l(b_max) too large"
        )

    # recursion over uniformized steps, chunked einsum accumulation
    P = np.zeros((A, T, K, K))  # p^{[a]}_k[z, w], k <= s_max
    Phi_a = np.zeros((A, K, K))  # E[Pi^steps] per action (end-phase law)
    E = np.zeros((n_hi + 1, K))  # e_n[z] = E[N_n | z]
    Dk = np.zeros((T, K, K))
    Dk[0] = np.eye(K)
    Mn = np.eye(K)
    uv = rates / theta  # u_m = Pi^m (lambda/theta), m = 0
    e = np.zeros(K)
    d_buf, m_buf, n0 = [], [], [0]

    def flush(n_end):
        if not d_buf:
            return
        Ds = np.stack(d_buf)  # (C, T, K, K)
        Ms = np.stack(m_buf)  # (C, K, K)
        Wc = W[:, n0[0]:n_end]  # (A, C)
        np.add(P, np.einsum("ac,ctzw->atzw", Wc, Ds), out=P)
        np.add(Phi_a, np.einsum("ac,czw->azw", Wc, Ms), out=Phi_a)
        d_buf.clear()
        m_buf.clear()
        n0[0] = n_end

    for n in range(n_hi + 1):
        E[n] = e
        d_buf.append(Dk.copy())
        m_buf.append(Mn.copy())
        if len(d_buf) >= chunk:
            flush(n + 1)
        if n == n_hi:
            break
        # advance: D_{n+1,k} = D_{n,k} U0 + D_{n,k-1} U1; M_{n+1} = M_n Pi
        Dn = Dk @ U0
        Dn[1:] += Dk[:-1] @ U1
        Dk = Dn
        Mn = Mn @ Pi
        e = e + uv
        uv = Pi @ uv
    flush(n_hi + 1)

    # normalize the captured phase-transition law row-stochastic (the
    # missing <= tol step mass redistributes proportionally; K = 1 divides
    # by itself, keeping the Poisson path bit-identical)
    row = Phi_a.sum(axis=-1, keepdims=True)
    Phi_n = np.divide(Phi_a, row, out=np.zeros_like(Phi_a), where=row > 1e-12)

    # overflow tails per base state t: what the band k <= s_max - t misses
    csum = np.cumsum(P, axis=1)  # (A, T, K, K) cumulative over k
    tails = np.maximum(0.0, Phi_n[:, None] - csum[:, ::-1])  # index t
    tails[0] = 0.0
    P[0] = 0.0

    # holding-cost integral of in-service arrivals (uniformization identity)
    tail_w = np.maximum(0.0, 1.0 - np.cumsum(W, axis=1))  # (A, n_hi+1)
    c_extra = (tail_w @ E) / theta  # (A, K)
    c_extra[0] = 0.0

    # wait action: time-to-next-arrival phase law
    y_wait = np.linalg.solve(Lam - R, np.ones(K))
    wait_m = np.linalg.solve(Lam - R, Lam)
    if np.any(y_wait <= 0) or not np.all(np.isfinite(wait_m)):
        raise RuntimeError("degenerate wait-time law; check rates/generator")

    lam_eff = phases.mean_rate
    return (
        P.transpose(0, 2, 3, 1),  # (A, K, K, T)
        tails.transpose(0, 2, 3, 1),  # (A, K, K, T)
        wait_m,
        y_wait,
        c_extra,
        lam_eff,
    )


def build_smdp_modulated_batched(
    specs: Sequence[SMDPSpec],
    phases: Sequence[PhaseConfig],
) -> ModulatedBatchedSMDP:
    """Construct a stacked batch of phase-modulated truncated SMDPs.

    ``specs`` and ``phases`` align; all specs must share (s_max, b_max) and
    all phase configs the same K.  Each spec's ``lam`` must equal its
    modulation's mean rate (use ``modulated_spec``) so rho bookkeeping — and
    hence sweep ordering/acceleration thresholds — stays meaningful.
    """
    specs = list(specs)
    phases = list(phases)
    if not specs:
        raise ValueError("empty spec batch")
    if len(phases) != len(specs):
        raise ValueError(f"{len(phases)} phase configs for {len(specs)} specs")
    s_max = specs[0].s_max
    b_max = specs[0].b_max
    K = phases[0].n_phases
    for sp, ph in zip(specs, phases):
        if sp.buffer is not None:
            raise NotImplementedError(
                "finite-buffer builds are Poisson-only; use "
                "build_smdp_batched (the overload-aware serving tables)"
            )
        if sp.s_max != s_max or sp.b_max != b_max:
            raise ValueError("modulated batch must share (s_max, b_max)")
        if ph.n_phases != K:
            raise ValueError("modulated batch must share the phase count K")
        if abs(sp.lam - ph.mean_rate) > 1e-9 * max(1.0, ph.mean_rate):
            raise ValueError(
                f"spec.lam = {sp.lam} != modulation mean rate "
                f"{ph.mean_rate}; build specs via modulated_spec()"
            )
    N = len(specs)
    S = s_max + 2
    A = b_max + 1
    s_o = S - 1
    T = s_max + 1
    s_val = _state_values(s_max)
    acts = np.arange(A)
    bs = np.arange(1, A)

    pmfs = np.zeros((N, A, K, K, T))
    tails = np.zeros((N, A, K, K, T))
    wait_m = np.zeros((N, K, K))
    y_wait = np.zeros((N, K))
    c_extra = np.zeros((N, A, K))
    lam_eff = np.zeros(N)
    for i, (sp, ph) in enumerate(zip(specs, phases)):
        (
            pmfs[i],
            tails[i],
            wait_m[i],
            y_wait[i],
            c_extra[i],
            lam_eff[i],
        ) = _modulated_action_data(sp, ph)

    b_min = np.array([sp.b_min for sp in specs])
    w1 = np.array([sp.w1 for sp in specs])
    w2 = np.array([sp.w2 for sp in specs])
    c_o = np.array([sp.c_o for sp in specs])

    y_a = np.zeros((N, A))
    zeta = np.zeros((N, A))
    for i, sp in enumerate(specs):
        y_a[i, 1:] = sp.service.mean(bs)
        zeta[i, 1:] = sp.energy(bs)

    # feasibility: phase-independent, same rule as the scalar chain (eq. 8)
    feasible = (s_val[None, :, None] >= acts[None, None, :]) & (
        acts[None, None, :] >= b_min[:, None, None]
    )
    feasible[:, :, 0] = True

    # sojourn times: wait depends on the phase, service does not
    y = np.broadcast_to(y_a[:, None, None, :], (N, K, S, A)).copy()
    y[..., 0] = y_wait[:, :, None]

    # costs: holding integral / lam_eff (Little), energy, abstract overflow
    c_hold = np.zeros((N, K, S, A))
    c_hold[..., 0] = (
        s_val[None, None, :] * y_wait[:, :, None] / lam_eff[:, None, None]
    )
    c_extra_t = c_extra.transpose(0, 2, 1)  # (N, K, A)
    c_hold[..., 1:] = (
        s_val[None, None, :, None] * y_a[:, None, None, 1:]
        + c_extra_t[:, :, None, 1:]
    ) / lam_eff[:, None, None, None]
    c_energy = np.broadcast_to(zeta[:, None, None, :], (N, K, S, A)).copy()
    c_hat = w1[:, None, None, None] * c_hold + w2[:, None, None, None] * c_energy
    c_hat[:, :, s_o, :] += c_o[:, None, None] * y[:, :, s_o, :]

    # eta bound from structured self-transition probabilities
    diag = np.zeros((N, K, S, A))
    # serve at s <= s_max: return iff k = a and the phase is unchanged
    zz = np.arange(K)
    for a in range(1, A):
        diag[:, :, : s_max + 1, a] = np.where(
            feasible[:, None, : s_max + 1, a],
            pmfs[:, a, zz, zz, min(a, s_max)][:, :, None],
            0.0,
        )
        diag[:, :, s_o, a] = tails[:, a, zz, zz, s_max - a]
    diag[:, :, s_o, 0] = wait_m[:, zz, zz]

    feas_k = np.broadcast_to(feasible[:, None], (N, K, S, A))
    with np.errstate(divide="ignore"):
        bound = np.where(
            (diag < 1.0) & feas_k, y / np.maximum(1.0 - diag, 1e-300), np.inf
        )
    eta = 0.999 * bound.reshape(N, -1).min(axis=1)
    if not np.all(np.isfinite(eta)) or np.any(eta <= 0):
        raise RuntimeError("degenerate eta bound (modulated)")

    with np.errstate(invalid="ignore"):
        c_tilde = np.where(feas_k, c_hat / y, np.inf)
    scale = eta[:, None, None, None] / y

    return ModulatedBatchedSMDP(
        specs=specs,
        phases=phases,
        n_specs=N,
        n_phases=K,
        n_states=S,
        n_actions=A,
        feasible=feasible,
        y=y,
        c_hat=c_hat,
        eta=eta,
        c_tilde=c_tilde,
        c_hold=c_hold,
        c_energy=c_energy,
        scale=scale,
        pmfs_banded=pmfs,
        tails=tails,
        wait_m=wait_m,
        lam_eff=lam_eff,
    )


def build_smdp_modulated(
    spec: SMDPSpec, phases: PhaseConfig
) -> ModulatedBatchedSMDP:
    """The N == 1 modulated build (banded container; never densified)."""
    return build_smdp_modulated_batched([spec], [phases])
