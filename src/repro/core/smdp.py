"""SMDP construction: truncation, abstract cost, discretization (paper Sec. IV-V).

State space  S_hat = {0, 1, ..., s_max, S_o}; index S_o = s_max + 1.
Action space A     = {0} U {B_min..B_max}; action index == batch size.

Pipeline (paper Sec. V):
  build_smdp()   -> truncated continuous-time SMDP  (m_hat, c_hat, y)  [eq. 18-19]
  discretize()   -> associated discrete-time MDP    (m_tilde, c_tilde) [eq. 23-25]

All tensors are dense numpy on the host (S ~ O(100), A ~ O(33)); the iteration
itself (rvi.py) runs in JAX.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .service_models import ServiceModel, Profile


@dataclasses.dataclass(frozen=True)
class SMDPSpec:
    """Problem definition (paper Sec. III-IV)."""

    lam: float  # Poisson arrival rate
    service: ServiceModel  # G_b family + l(b)
    energy: Profile  # zeta(b)
    b_min: int = 1
    b_max: int = 32
    w1: float = 1.0  # weight on average response time (via holding cost)
    w2: float = 0.0  # weight on average power
    s_max: int = 128  # truncation level (>= b_max)
    c_o: float = 100.0  # abstract overflow-cost rate (paper Sec. V-A)

    def __post_init__(self):
        if self.s_max < self.b_max:
            raise ValueError("s_max must be >= b_max (paper Sec. V-A)")
        if not (0 < self.b_min <= self.b_max):
            raise ValueError("need 0 < b_min <= b_max")
        rho = self.rho
        if not (0.0 < rho < 1.0):
            raise ValueError(f"instability: rho={rho:.3f} not in (0,1)")

    @property
    def rho(self) -> float:
        """Normalized traffic intensity lam / (B_max * mu^[B_max])."""
        return self.lam * float(self.service.mean(self.b_max)) / self.b_max


@dataclasses.dataclass
class TruncatedSMDP:
    """Dense truncated SMDP (eq. 18-19) and its discretized MDP (eq. 23)."""

    spec: SMDPSpec
    n_states: int  # s_max + 2
    n_actions: int  # b_max + 1
    feasible: np.ndarray  # (S, A) bool
    y: np.ndarray  # (S, A) expected sojourn times
    c_hat: np.ndarray  # (S, A) expected costs (with abstract cost at S_o)
    m_hat: np.ndarray  # (S, A, S) transition probs
    # discretized
    eta: float
    c_tilde: np.ndarray  # (S, A)
    m_tilde: np.ndarray  # (S, A, S)
    # component costs for objective decomposition (same layout as c_hat)
    c_hold: np.ndarray  # w1-free holding cost integral  E[int s(t) dt]/lam
    c_energy: np.ndarray  # zeta(a) (0 for a=0)
    arrival_pmfs: np.ndarray  # (A, K+1) p_k per action (0 row for a=0)

    @property
    def s_o(self) -> int:
        return self.n_states - 1


def build_smdp(spec: SMDPSpec, pmf_tol: float = 1e-12) -> TruncatedSMDP:
    """Construct the truncated SMDP per eq. (18)-(19)."""
    S = spec.s_max + 2
    A = spec.b_max + 1
    s_o = S - 1
    lam = spec.lam

    # state value (number of requests) represented by each state index
    s_val = np.arange(S, dtype=np.float64)
    s_val[s_o] = spec.s_max  # S_o counts as s_max requests (paper Sec. V-A)

    actions = np.arange(A)
    feasible = np.zeros((S, A), dtype=bool)
    feasible[:, 0] = True
    for a in range(spec.b_min, spec.b_max + 1):
        feasible[:, a] = s_val >= a  # a <= s; S_o has s_val = s_max >= b_max

    # --- sojourn times y(s, a)  (eq. 9) ---
    y = np.zeros((S, A))
    y[:, 0] = 1.0 / lam
    for a in range(1, A):
        y[:, a] = float(spec.service.mean(a))

    # --- arrival pmfs p_k^{[a]} ---
    # k support: transitions only distinguish k <= s_max (rest lumps into S_o),
    # but we keep enough mass for tail accounting.
    K = spec.s_max + 1
    pmfs = np.zeros((A, K + 1))
    for a in range(1, A):
        pmfs[a] = spec.service.arrival_pmf(a, lam, K)

    # --- transitions m_hat (eq. 18) ---
    m_hat = np.zeros((S, A, S))
    # a = 0: deterministic +1 (S_o self-loops; s_max -> S_o)
    for s in range(S):
        if s < spec.s_max:
            m_hat[s, 0, s + 1] = 1.0
        else:  # s == s_max or S_o
            m_hat[s, 0, s_o] = 1.0
    # a != 0: base state s - a, arrivals k land at j = base + k
    for s in range(S):
        base_val = int(s_val[s])
        for a in range(1, A):
            if not feasible[s, a]:
                continue
            base = base_val - a
            # j in [base, s_max] gets p_{j - base}; rest to S_o
            kmax_in = spec.s_max - base
            ks = np.arange(0, kmax_in + 1)
            m_hat[s, a, base : spec.s_max + 1] = pmfs[a, ks]
            m_hat[s, a, s_o] = max(0.0, 1.0 - pmfs[a, : kmax_in + 1].sum())
    # normalize tiny numerical drift
    row_sums = m_hat.sum(axis=-1, keepdims=True)
    np.divide(m_hat, row_sums, out=m_hat, where=row_sums > pmf_tol)

    # --- costs (eq. 11, 19) ---
    e2 = np.zeros(A)
    zeta = np.zeros(A)
    for a in range(1, A):
        e2[a] = float(spec.service.second_moment(a))
        zeta[a] = float(spec.energy(a))

    c_hold = np.zeros((S, A))  # = E[int_0^gamma s(t) dt] / lam  (w1 multiplies)
    c_energy = np.zeros((S, A))  # = zeta(a)                    (w2 multiplies)
    # a = 0: c = s / lam^2
    c_hold[:, 0] = s_val / lam**2
    for a in range(1, A):
        # c = w2 zeta(a) + w1 (s l(a)/lam + E[G^2]/2)
        c_hold[:, a] = s_val * y[:, a] / lam + 0.5 * e2[a]
        c_energy[:, a] = zeta[a]

    c_hat = spec.w1 * c_hold + spec.w2 * c_energy
    # abstract cost at the overflow state (eq. 19): + c_o * y(s, a)
    c_hat[s_o, :] = c_hat[s_o, :] + spec.c_o * y[s_o, :]

    # --- discretization (eq. 23-25) ---
    diag = m_hat[np.arange(S)[:, None], actions[None, :], np.arange(S)[:, None]]
    with np.errstate(divide="ignore"):
        bound = np.where(
            (diag < 1.0) & feasible, y / np.maximum(1.0 - diag, 1e-300), np.inf
        )
    eta = 0.999 * float(bound.min())
    if not np.isfinite(eta) or eta <= 0:
        raise RuntimeError("degenerate eta bound")

    c_tilde = np.where(feasible, c_hat / y, np.inf)
    scale = eta / y  # (S, A)
    m_tilde = m_hat * scale[:, :, None]
    idx = np.arange(S)
    m_tilde[idx[:, None], actions[None, :], idx[:, None]] += 1.0 - scale
    # infeasible rows: harmless self-loop (masked out in the backup anyway)
    inf_mask = ~feasible
    m_tilde[inf_mask] = 0.0
    sI, aI = np.nonzero(inf_mask)
    m_tilde[sI, aI, sI] = 1.0

    return TruncatedSMDP(
        spec=spec,
        n_states=S,
        n_actions=A,
        feasible=feasible,
        y=y,
        c_hat=c_hat,
        m_hat=m_hat,
        eta=eta,
        c_tilde=c_tilde,
        m_tilde=m_tilde,
        c_hold=c_hold,
        c_energy=c_energy,
        arrival_pmfs=pmfs,
    )
