"""Latency-energy tradeoff sweeps (paper Fig. 5/7/8/9) and benchmark grids.

All weight grids route through sweep.sweep_solve: the whole w2 axis is
stacked into one BatchedSMDP and solved by a single jitted banded-RVI call,
instead of re-building and re-dispatching per point.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

from .evaluate import evaluate_policy
from .policies import greedy_policy, static_policy
from .smdp import SMDPSpec, build_smdp
from .solve import SolveResult, solve
from .sweep import sweep_solve


@dataclasses.dataclass
class TradeoffPoint:
    w2: float
    w_bar: float
    p_bar: float
    g: float
    policy: np.ndarray


def smdp_tradeoff_curve(
    base: SMDPSpec,
    w2_values: Sequence[float],
    eps: float = 1e-2,
    delta: float = 1e-3,
) -> List[TradeoffPoint]:
    """Sweep w2 (w1 fixed) -> (W_bar, P_bar) pairs of SMDP solutions."""
    specs = [dataclasses.replace(base, w2=float(w2)) for w2 in w2_values]
    results = sweep_solve(specs, eps=eps, delta=delta)
    return [
        TradeoffPoint(
            w2=float(w2),
            w_bar=res.eval.w_bar,
            p_bar=res.eval.p_bar,
            g=res.eval.g,
            policy=res.policy,
        )
        for w2, res in zip(w2_values, results)
    ]


def benchmark_points(
    spec: SMDPSpec, static_sizes: Iterable[int] = (8, 16, 32)
) -> Dict[str, Tuple[float, float]]:
    """(W_bar, P_bar) for greedy + static-b benchmark policies."""
    mdp = build_smdp(spec)
    out: Dict[str, Tuple[float, float]] = {}
    g = greedy_policy(spec.s_max, spec.b_min, spec.b_max)
    ev = evaluate_policy(mdp, g)
    out["greedy"] = (ev.w_bar, ev.p_bar)
    for b in static_sizes:
        if b > spec.b_max:
            continue
        pol = static_policy(b, spec.s_max)
        try:
            ev = evaluate_policy(mdp, pol)
        except RuntimeError:
            continue  # unstable under this static size
        out[f"static_{b}"] = (ev.w_bar, ev.p_bar)
    return out


def average_cost_grid(
    base: SMDPSpec,
    w2_values: Sequence[float],
    static_sizes: Iterable[int] = (8, 16, 32),
    eps: float = 1e-2,
    delta: float = 1e-3,
) -> Dict[str, List[float]]:
    """Paper Fig. 4: average cost per unit time of each policy vs w2.

    Benchmark policies are weight-independent; their *cost* depends on the
    weights through the objective.  g(policy) = w1 * W_bar_term + w2 * P_bar
    where W_bar_term re-uses the evaluator's decomposition.  The SMDP column
    solves the entire w2 grid in one batched call.
    """
    mdp = build_smdp(base)
    bench: Dict[str, Tuple[float, float]] = {}
    gp = greedy_policy(base.s_max, base.b_min, base.b_max)
    ev = evaluate_policy(mdp, gp)
    bench["greedy"] = (ev.w_bar, ev.p_bar)
    for b in static_sizes:
        pol = static_policy(b, base.s_max)
        try:
            ev = evaluate_policy(mdp, pol)
            bench[f"static_{b}"] = (ev.w_bar, ev.p_bar)
        except RuntimeError:
            bench[f"static_{b}"] = (float("inf"), float("inf"))

    specs = [dataclasses.replace(base, w2=float(w2)) for w2 in w2_values]
    results = sweep_solve(specs, eps=eps, delta=delta)

    out: Dict[str, List[float]] = {k: [] for k in bench}
    out["smdp"] = []
    for w2, res in zip(w2_values, results):
        out["smdp"].append(base.w1 * res.eval.w_bar + float(w2) * res.eval.p_bar)
        for k, (w_bar, p_bar) in bench.items():
            out[k].append(base.w1 * w_bar + float(w2) * p_bar)
    return out


def solve_serial(
    base: SMDPSpec,
    w2_values: Sequence[float],
    eps: float = 1e-2,
    delta: float = 1e-3,
) -> List[SolveResult]:
    """Per-point serial loop (the pre-batched path); kept as the benchmark
    baseline for benchmarks/sweep_scaling.py and for equivalence tests."""
    results = []
    s_max = base.s_max
    for w2 in w2_values:
        spec = dataclasses.replace(base, w2=float(w2), s_max=s_max)
        res = solve(spec, eps=eps, delta=delta)
        s_max = res.spec.s_max  # warm-start truncation level for next weight
        results.append(res)
    return results
