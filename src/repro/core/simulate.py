"""Simulation of the batch-service queue: two backends, one queue semantics.

Simulates the exact SMDP dynamics epoch-by-epoch (decision epochs = service
completions, or arrivals while idle) under an arbitrary policy table, and
records *per-request* response times so that latency CDFs / percentiles
(paper Fig. 6, Table I) can be measured — the analytic evaluator only gives
averages.

Backends (cross-checked decision-for-decision in the test suite):

  * Python event loop (repro.serving.engine._run_events) — the reference
    kernel.  Arrivals from any ArrivalProcess, stateful online schedulers,
    wall-clock executors.  Interpreter-speed: right for moderate horizons
    and anything adaptive.
  * Compiled scan (repro.serving.compiled) — the SAME decision-epoch
    semantics as one jitted `jax.lax.scan`, vmappable across
    seeds x scenarios x policy tables.  Right for measurement-grade
    replication sweeps and million-event horizons; placeable on TPU/GPU
    unchanged.

Entry points here:
  * simulate()        — the historical jax.lax.scan specialization for
    Poisson arrivals (randomness from jax.random, request FIFO as a ring
    buffer, one jitted scan).  Kept as the independent cross-check
    implementation — it draws arrivals *during* service from Poisson
    counts, where the compiled backend replays a pre-generated stream —
    and as the l_bar time-integral reference.
  * simulate_events() — the general path for any arrival process (MMPP,
    traces, ...): a thin wrapper over the unified serving engine, so the
    event-driven queue semantics exists exactly once in the repo.
    ``backend="compiled"`` routes it through the scan kernel.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .service_models import ServiceModel

BUF_LOG2 = 15
BUF = 1 << BUF_LOG2  # circular arrival-time buffer (plenty for stable queues)


@dataclasses.dataclass
class SimResult:
    response_times: np.ndarray  # (n_samples,) per-request response times
    w_bar: float  # mean response time
    p_bar: float  # energy / time
    l_bar: float  # time-average queue length (includes in-service)
    total_time: float
    n_served: int
    n_clipped_arrivals: int  # diagnostics: Poisson draws clipped at KMAX

    def percentile(self, q) -> np.ndarray:
        return np.percentile(self.response_times, q)


def simulate_events(
    policy_table: np.ndarray,
    service: ServiceModel,
    energy_table: np.ndarray,
    arrivals,  # rate / MMPP2 / trace / ArrivalProcess (serving.arrivals)
    b_max: int,
    n_epochs: int | None = 100_000,
    horizon: float | None = None,
    seed: int = 0,
    backend: str = "python",
) -> SimResult:
    """General event-driven simulation via the unified serving engine.

    Same decision-epoch semantics as simulate(), but arrivals come from any
    serving.arrivals.ArrivalProcess instead of being fixed to Poisson, and
    the queue loop is the serving engine's — not a duplicate.  l_bar is
    exact by Little's law on the served set (the scan keeps its independent
    time-integral as a cross-check).  ``backend="compiled"`` runs the jitted
    scan kernel instead of the Python loop (identical decisions; see
    serving.engine.run).
    """
    from repro.serving.arrivals import as_process
    from repro.serving.engine import ServingEngine
    from repro.serving.scheduler import SMDPScheduler

    eng = ServingEngine(
        SMDPScheduler.from_table(policy_table),
        arrivals=as_process(arrivals),
        b_max=b_max,
        service=service,
        energy_table=energy_table,
        seed=seed,
    )
    rep = eng.run(n_epochs=n_epochs, horizon=horizon, backend=backend)
    lat_sum = float(rep.latencies.sum())
    return SimResult(
        response_times=rep.latencies,
        w_bar=float(rep.latencies.mean()) if rep.n_served else float("nan"),
        p_bar=rep.power,
        l_bar=lat_sum / rep.span if rep.span > 0 else float("nan"),
        total_time=rep.span,
        n_served=rep.n_served,
        n_clipped_arrivals=0,
    )


def _sampler(service: ServiceModel, b_max: int):
    """Return a jax-side service-time sampler: (key, a) -> T."""
    means = jnp.asarray(
        [0.0] + [float(service.mean(b)) for b in range(1, b_max + 1)]
    )
    fam = service.family
    if fam == "det":
        return lambda key, a: means[a]
    if fam == "expo":
        return lambda key, a: means[a] * jax.random.exponential(key)
    if fam == "erlang":
        k = service.erlang_k
        return lambda key, a: means[a] / k * jax.random.gamma(key, k)
    if fam == "hyperexpo":
        w = np.asarray(service.hyper_weights, dtype=np.float64)
        s = np.asarray(service.hyper_scales, dtype=np.float64)
        s = s / float(np.sum(w * s))
        wj = jnp.asarray(w / w.sum())
        sj = jnp.asarray(s)

        def sample(key, a):
            k1, k2 = jax.random.split(key)
            comp = jax.random.choice(k1, len(wj), p=wj)
            return means[a] * sj[comp] * jax.random.exponential(k2)

        return sample
    if fam == "atoms":
        w = np.asarray(service.atom_weights, dtype=np.float64)
        s = np.asarray(service.atom_scales, dtype=np.float64)
        s = s / float(np.sum(w * s))
        wj = jnp.asarray(w / w.sum())
        sj = jnp.asarray(s)

        def sample(key, a):
            comp = jax.random.choice(key, len(wj), p=wj)
            return means[a] * sj[comp]

        return sample
    raise ValueError(fam)


def simulate(
    policy_table: np.ndarray,  # (L,) action per state; s >= L uses last entry
    service: ServiceModel,
    energy_table: np.ndarray,  # (b_max + 1,) zeta(a), zeta(0) = 0
    lam: float,
    b_max: int,
    n_epochs: int = 100_000,
    seed: int = 0,
    k_max: int | None = None,
) -> SimResult:
    """Run the queue for n_epochs decision epochs under `policy_table`."""
    if k_max is None:
        mean_arr = lam * float(service.mean(b_max))
        k_max = int(max(64, 8 * mean_arr))
    pol = jnp.asarray(np.asarray(policy_table, dtype=np.int64))
    en = jnp.asarray(np.asarray(energy_table, dtype=np.float64))
    sample_service = _sampler(service, b_max)
    L = pol.shape[0]

    def step(carry, key):
        s, t, buf, head, tail, q_integral, clipped = carry
        a = pol[jnp.minimum(s, L - 1)]
        a = jnp.where(a <= s, a, 0)  # safety: never serve more than available

        k_wait, k_svc, k_pois, k_unif = jax.random.split(key, 4)

        # ---- branch a == 0: wait for one arrival -------------------------
        dt_wait = jax.random.exponential(k_wait) / lam

        # ---- branch a > 0: serve a batch of size a -----------------------
        svc_t = sample_service(k_svc, jnp.maximum(a, 1))
        n_arr_raw = jax.random.poisson(k_pois, lam * svc_t)
        n_arr = jnp.minimum(n_arr_raw, k_max).astype(jnp.int32)
        u = jax.random.uniform(k_unif, (k_max,), dtype=jnp.float64)
        u = jnp.where(jnp.arange(k_max) < n_arr, u, jnp.inf)
        offs = jnp.sort(u) * svc_t  # sorted arrival offsets within service

        serving = a > 0
        dt = jnp.where(serving, svc_t, dt_wait)
        t_next = t + dt

        # responses for the a requests served (completion - arrival)
        ridx = (head + jnp.arange(b_max)) % BUF
        r_mask = jnp.arange(b_max) < a
        resp = jnp.where(r_mask, t_next - buf[ridx], 0.0)

        # enqueue arrivals: either the single waited-for arrival, or the
        # n_arr arrivals that landed during service
        widx = (tail + jnp.arange(k_max)) % BUF
        w_mask = jnp.where(serving, jnp.arange(k_max) < n_arr, jnp.arange(k_max) < 1)
        w_times = jnp.where(serving, t + offs, t_next)
        buf = buf.at[widx].set(jnp.where(w_mask, w_times, buf[widx]))

        n_in = jnp.where(serving, n_arr, 1)
        head = (head + a) % BUF
        tail = (tail + n_in) % BUF
        s_next = s - a + n_in

        # exact queue-length time integral over this sojourn
        # wait: s constant for dt; serve: s for T plus sum_i (T - off_i)
        arr_contrib = jnp.sum(jnp.where(w_mask & serving, svc_t - offs, 0.0))
        q_int = jnp.where(serving, s * svc_t + arr_contrib, s * dt_wait)

        energy = jnp.where(serving, en[a], 0.0)
        clipped = clipped + jnp.where(serving, (n_arr_raw > k_max).astype(jnp.int32), 0)
        carry = (s_next, t_next, buf, head, tail, q_integral + q_int, clipped)
        out = (resp, r_mask, energy, a)
        return carry, out

    keys = jax.random.split(jax.random.PRNGKey(seed), n_epochs)
    buf0 = jnp.zeros(BUF, dtype=jnp.float64)
    carry0 = (
        jnp.asarray(0, dtype=jnp.int64),
        jnp.asarray(0.0, dtype=jnp.float64),
        buf0,
        jnp.asarray(0, dtype=jnp.int64),
        jnp.asarray(0, dtype=jnp.int64),
        jnp.asarray(0.0, dtype=jnp.float64),
        jnp.asarray(0, dtype=jnp.int32),
    )
    (s, t, buf, head, tail, q_integral, clipped), (resp, mask, energy, acts) = (
        jax.lax.scan(step, carry0, keys)
    )

    resp = np.asarray(resp)
    mask = np.asarray(mask)
    samples = resp[mask]
    total_time = float(t)
    total_energy = float(np.asarray(energy).sum())
    return SimResult(
        response_times=samples,
        w_bar=float(samples.mean()) if samples.size else float("nan"),
        p_bar=total_energy / total_time,
        l_bar=float(q_integral) / total_time,
        total_time=total_time,
        n_served=int(samples.size),
        n_clipped_arrivals=int(clipped),
    )
