"""Benchmark batching policies + Prop.-4 closed-form control limit.

All policies are represented as action tables over the truncated state space
{0..s_max, S_o} (length s_max + 2), matching RVIResult.policy, so that
evaluate.py and simulate.py treat SMDP and benchmark policies uniformly.
The infinite-state extension is eq. (30): pi(s > s_max) = pi(s_max).
"""
from __future__ import annotations

import numpy as np


def _table(s_max: int) -> np.ndarray:
    return np.zeros(s_max + 2, dtype=np.int64)


def _s_values(s_max: int) -> np.ndarray:
    s = np.arange(s_max + 2)
    s[-1] = s_max  # S_o counts as s_max requests
    return s


def static_policy(b: int, s_max: int) -> np.ndarray:
    """pi(s) = b if s >= b else 0 (Definition 1)."""
    s = _s_values(s_max)
    return np.where(s >= b, b, 0).astype(np.int64)


def greedy_policy(s_max: int, b_min: int, b_max: int) -> np.ndarray:
    """pi(s) = max(min(s, B_max), B_min) when feasible, else wait (Def. 2)."""
    s = _s_values(s_max)
    act = np.maximum(np.minimum(s, b_max), b_min)
    return np.where(s >= b_min, act, 0).astype(np.int64)


def q_policy(q: int, s_max: int, b_max: int) -> np.ndarray:
    """Control-limit policy (Definition 3): serve min(s, B_max) iff s >= Q."""
    s = _s_values(s_max)
    return np.where(s >= q, np.minimum(s, b_max), 0).astype(np.int64)


def is_control_limit(policy: np.ndarray, s_max: int, b_max: int):
    """Check the Def.-3 structure; returns (True, Q) or (False, None)."""
    s = _s_values(s_max)
    serve = policy > 0
    if not serve.any():
        return False, None
    q = int(np.argmax(serve))
    expected = q_policy(q, s_max, b_max)
    return bool(np.array_equal(policy, expected)), (q if np.array_equal(policy, expected) else None)


def optimal_q_closed_form(
    lam: float, mu: float, b_max: int, w1: float = 1.0, w2: float = 0.0, zeta0: float = 0.0
) -> int:
    """Proposition 4 (Deb–Serfozo): optimal control limit for M/M-type service.

    Requires size-independent exponential service (Assumptions 1-4).
    """
    psi = lam / (lam + mu)

    # unique root of (1 - psi) xi^{B+1} - xi + psi = 0 in (0, 1)
    def f(x):
        return (1.0 - psi) * x ** (b_max + 1) - x + psi

    lo, hi = 1e-12, 1.0 - 1e-12
    # f(0) = psi > 0; f(1-) -> 0 from below for stable systems; bisect on sign
    flo = f(lo)
    xi = None
    # scan for a sign change to bracket the interior root
    grid = np.linspace(lo, hi, 4096)
    vals = f(grid)
    sign_change = np.nonzero(np.diff(np.sign(vals)) != 0)[0]
    if len(sign_change) == 0:
        raise RuntimeError("no interior root for xi — check stability")
    a_, b_ = grid[sign_change[0]], grid[sign_change[0] + 1]
    for _ in range(200):
        mid = 0.5 * (a_ + b_)
        if f(a_) * f(mid) <= 0:
            b_ = mid
        else:
            a_ = mid
    xi = 0.5 * (a_ + b_)

    chi = lam / mu
    r = xi / (1.0 - xi)
    for q in range(1, b_max + 1):
        d_q = (
            q * (0.5 * (q + 1) + chi - r)
            - r**2 * xi**q
            + r * (r - chi)
            - w2 * zeta0 * lam**2 / w1
        )
        if d_q >= 0:
            return q
    return b_max
