"""Exact policy evaluation on the truncated SMDP (eq. 21-22).

Given a stationary deterministic policy (action table over S_hat), compute
the stationary distribution of the induced semi-Markov chain and derive

  g_hat  = sum_s mu_s c^(s, pi(s)) / sum_s mu_s y(s, pi(s))        (eq. 21)
  Delta  = mu_{S_o} c^(S_o, pi(S_o)) / sum_s mu_s y(s, pi(s))      (eq. 22)
  W_bar  = average request response time  (w1-term with w1 = 1)
  P_bar  = average power                  (w2-term with w2 = 1)
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from .smdp import BatchedSMDP, TruncatedSMDP


@dataclasses.dataclass
class PolicyEval:
    g: float  # average weighted cost per unit time (with spec's w1, w2)
    delta: float  # tail-state contribution (approximation quality, eq. 22)
    w_bar: float  # average response time
    p_bar: float  # average power consumption
    mu: np.ndarray  # stationary distribution over S_hat
    mean_batch: float  # average served batch size
    throughput: float  # served requests per unit time


def stationary_distribution(p: np.ndarray, tol: float = 1e-12) -> np.ndarray:
    """Solve mu P = mu, sum(mu) = 1 via a dense linear solve."""
    n = p.shape[0]
    a = p.T - np.eye(n)
    a[-1, :] = 1.0
    b = np.zeros(n)
    b[-1] = 1.0
    try:
        mu = np.linalg.solve(a, b)
    except np.linalg.LinAlgError:
        mu = np.linalg.lstsq(a, b, rcond=None)[0]
    mu = np.clip(mu, 0.0, None)
    s = mu.sum()
    if s <= tol:
        raise RuntimeError("degenerate stationary distribution")
    return mu / s


def _check_feasible(feasible: np.ndarray, acts: np.ndarray) -> np.ndarray:
    S = feasible.shape[0]
    if acts.shape != (S,):
        raise ValueError(f"policy shape {acts.shape} != ({S},)")
    rows = np.arange(S)
    feas = feasible[rows, acts]
    if not feas.all():
        bad = rows[~feas]
        raise ValueError(f"policy takes infeasible actions at states {bad[:5]}")
    return rows


def _finish_eval(
    mu: np.ndarray,
    acts: np.ndarray,
    y_pi: np.ndarray,
    c_pi: np.ndarray,
    hold_pi: np.ndarray,
    energy_pi: np.ndarray,
) -> PolicyEval:
    denom = float(mu @ y_pi)
    g = float(mu @ c_pi) / denom
    delta = float(mu[-1] * c_pi[-1]) / denom

    # objective decomposition (abstract cost excluded — it is a solver device,
    # not part of the physical objective)
    w_bar = float(mu @ hold_pi) / denom  # = L_bar / lam = W_bar (Little)
    p_bar = float(mu @ energy_pi) / denom

    served = acts.astype(np.float64)
    mean_batch = float(mu @ (served * (served > 0))) / max(
        float(mu @ (served > 0)), 1e-300
    )
    throughput = float(mu @ served) / denom
    return PolicyEval(
        g=g,
        delta=delta,
        w_bar=w_bar,
        p_bar=p_bar,
        mu=mu,
        mean_batch=mean_batch,
        throughput=throughput,
    )


def evaluate_policy(mdp: TruncatedSMDP, policy: np.ndarray) -> PolicyEval:
    acts = np.asarray(policy, dtype=np.int64)
    rows = _check_feasible(mdp.feasible, acts)
    p_pi = mdp.m_hat[rows, acts, :]
    mu = stationary_distribution(p_pi)
    return _finish_eval(
        mu,
        acts,
        mdp.y[rows, acts],
        mdp.c_hat[rows, acts],
        mdp.c_hold[rows, acts],
        mdp.c_energy[rows, acts],
    )


def evaluate_policy_banded(
    batch: BatchedSMDP, i: int, policy: np.ndarray
) -> PolicyEval:
    """evaluate_policy for spec ``i`` of a batch, from banded data only.

    Mathematically identical to evaluating batch.dense(i) but never
    materializes the (S, A, S) transition tensor — the hot path of sweeps.
    """
    acts = np.asarray(policy, dtype=np.int64)
    rows = _check_feasible(batch.feasible[i], acts)
    p_pi = batch.policy_transitions(i, acts)
    mu = stationary_distribution(p_pi)
    return _finish_eval(
        mu,
        acts,
        batch.y[i, rows, acts],
        batch.c_hat[i, rows, acts],
        batch.c_hold[i, rows, acts],
        batch.c_energy[i, rows, acts],
    )


def evaluate_policy_batched(
    batch: BatchedSMDP, policies: Sequence[np.ndarray]
) -> List[PolicyEval]:
    """Per-spec policy evaluation across a BatchedSMDP (aligned with specs)."""
    if len(policies) != batch.n_specs:
        raise ValueError(f"{len(policies)} policies for {batch.n_specs} specs")
    return [
        evaluate_policy_banded(batch, i, pol) for i, pol in enumerate(policies)
    ]
