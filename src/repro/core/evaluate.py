"""Exact policy evaluation on the truncated SMDP (eq. 21-22).

Given a stationary deterministic policy (action table over S_hat), compute
the stationary distribution of the induced semi-Markov chain and derive

  g_hat  = sum_s mu_s c^(s, pi(s)) / sum_s mu_s y(s, pi(s))        (eq. 21)
  Delta  = mu_{S_o} c^(S_o, pi(S_o)) / sum_s mu_s y(s, pi(s))      (eq. 22)
  W_bar  = average request response time  (w1-term with w1 = 1)
  P_bar  = average power                  (w2-term with w2 = 1)

Two families of routines live here:

  * numpy evaluation of a *solved* policy on the physical chain
    (stationary distribution -> g / Delta / W_bar / P_bar);
  * JAX evaluation of the *discretized* MDP under a frozen policy
    (policy_matrix_banded / policy_eval_linear) — the linear-solve
    polish step of the accelerated batched RVI (rvi.accel="mpi").
    Both are dense-free: the (S, A, S) tensor is never materialized,
    only the (S, S) matrix of the frozen policy.

Both families have phase-modulated counterparts operating on the K*S
product chain of smdp.ModulatedBatchedSMDP (phase-blocked flattening,
z * S + s): evaluate_policy_modulated(_batched) for the physical chain —
delta sums over *every* phase's overflow state — and
policy_matrix_banded_modulated feeding the same policy_eval_linear for
the MPI polish of the modulated RVI.  Nothing is ever densified beyond
the (K*S, K*S) matrix of one frozen policy.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from .smdp import BatchedSMDP, ModulatedBatchedSMDP, TruncatedSMDP


@dataclasses.dataclass
class PolicyEval:
    g: float  # average weighted cost per unit time (with spec's w1, w2)
    delta: float  # tail-state contribution (approximation quality, eq. 22)
    w_bar: float  # average response time
    p_bar: float  # average power consumption
    mu: np.ndarray  # stationary distribution over S_hat
    mean_batch: float  # average served batch size
    throughput: float  # served requests per unit time


def stationary_distribution(p: np.ndarray, tol: float = 1e-12) -> np.ndarray:
    """Solve mu P = mu, sum(mu) = 1 via a dense linear solve."""
    n = p.shape[0]
    a = p.T - np.eye(n)
    a[-1, :] = 1.0
    b = np.zeros(n)
    b[-1] = 1.0
    try:
        mu = np.linalg.solve(a, b)
    except np.linalg.LinAlgError:
        mu = np.linalg.lstsq(a, b, rcond=None)[0]
    mu = np.clip(mu, 0.0, None)
    s = mu.sum()
    if s <= tol:
        raise RuntimeError("degenerate stationary distribution")
    return mu / s


def _check_feasible(feasible: np.ndarray, acts: np.ndarray) -> np.ndarray:
    S = feasible.shape[0]
    if acts.shape != (S,):
        raise ValueError(f"policy shape {acts.shape} != ({S},)")
    rows = np.arange(S)
    feas = feasible[rows, acts]
    if not feas.all():
        bad = rows[~feas]
        raise ValueError(f"policy takes infeasible actions at states {bad[:5]}")
    return rows


def _finish_eval(
    mu: np.ndarray,
    acts: np.ndarray,
    y_pi: np.ndarray,
    c_pi: np.ndarray,
    hold_pi: np.ndarray,
    energy_pi: np.ndarray,
    overflow: Optional[np.ndarray] = None,
) -> PolicyEval:
    """Aggregate (g, Delta, W_bar, P_bar, ...) from mu and gathered rows.

    ``overflow`` marks the overflow state(s) for the Delta term; default is
    the last state (the scalar chain).  The modulated chain passes a mask
    over every phase's S_o.
    """
    denom = float(mu @ y_pi)
    g = float(mu @ c_pi) / denom
    if overflow is None:
        delta = float(mu[-1] * c_pi[-1]) / denom
    else:
        delta = float(mu[overflow] @ c_pi[overflow]) / denom

    # objective decomposition (abstract cost excluded — it is a solver device,
    # not part of the physical objective)
    w_bar = float(mu @ hold_pi) / denom  # = L_bar / lam = W_bar (Little)
    p_bar = float(mu @ energy_pi) / denom

    served = acts.astype(np.float64)
    mean_batch = float(mu @ (served * (served > 0))) / max(
        float(mu @ (served > 0)), 1e-300
    )
    throughput = float(mu @ served) / denom
    return PolicyEval(
        g=g,
        delta=delta,
        w_bar=w_bar,
        p_bar=p_bar,
        mu=mu,
        mean_batch=mean_batch,
        throughput=throughput,
    )


def evaluate_policy(mdp: TruncatedSMDP, policy: np.ndarray) -> PolicyEval:
    acts = np.asarray(policy, dtype=np.int64)
    rows = _check_feasible(mdp.feasible, acts)
    p_pi = mdp.m_hat[rows, acts, :]
    mu = stationary_distribution(p_pi)
    return _finish_eval(
        mu,
        acts,
        mdp.y[rows, acts],
        mdp.c_hat[rows, acts],
        mdp.c_hold[rows, acts],
        mdp.c_energy[rows, acts],
    )


def evaluate_policy_banded(
    batch: BatchedSMDP, i: int, policy: np.ndarray
) -> PolicyEval:
    """evaluate_policy for spec ``i`` of a batch, from banded data only.

    Mathematically identical to evaluating batch.dense(i) but never
    materializes the (S, A, S) transition tensor — the hot path of sweeps.
    """
    acts = np.asarray(policy, dtype=np.int64)
    rows = _check_feasible(batch.feasible[i], acts)
    p_pi = batch.policy_transitions(i, acts)
    mu = stationary_distribution(p_pi)
    return _finish_eval(
        mu,
        acts,
        batch.y[i, rows, acts],
        batch.c_hat[i, rows, acts],
        batch.c_hold[i, rows, acts],
        batch.c_energy[i, rows, acts],
    )


def stationary_distribution_batched(p: np.ndarray, tol: float = 1e-12):
    """Batched mu P = mu, sum(mu) = 1: one LAPACK call for the whole stack.

    Returns (mu (N, S), ok (N,) bool); rows with ``ok`` False (singular or
    degenerate chains) carry no meaning and must be re-solved per spec —
    evaluate_policy_batched falls back to the scalar path for those.
    """
    n = p.shape[-1]
    a = np.swapaxes(p, -1, -2) - np.eye(n)[None]
    a[:, -1, :] = 1.0
    b = np.zeros((p.shape[0], n))
    b[:, -1] = 1.0
    try:
        mu = np.linalg.solve(a, b[..., None])[..., 0]
    except np.linalg.LinAlgError:
        # one singular matrix poisons the batched call; mark all for retry
        return np.zeros_like(b), np.zeros(p.shape[0], dtype=bool)
    ok = np.isfinite(mu).all(axis=-1)
    mu = np.clip(mu, 0.0, None)
    s = mu.sum(axis=-1)
    ok &= s > tol
    mu = mu / np.where(s > tol, s, 1.0)[:, None]
    return mu, ok


def _finish_from_batch(
    batch: BatchedSMDP, i: int, acts: np.ndarray, mu: np.ndarray
) -> PolicyEval:
    rows = np.arange(batch.n_states)
    return _finish_eval(
        mu,
        acts,
        batch.y[i, rows, acts],
        batch.c_hat[i, rows, acts],
        batch.c_hold[i, rows, acts],
        batch.c_energy[i, rows, acts],
    )


def evaluate_policy_batched(
    batch: BatchedSMDP, policies: Sequence[np.ndarray]
) -> List[PolicyEval]:
    """Per-spec policy evaluation across a BatchedSMDP (aligned with specs).

    The stationary distributions of the whole stack come from ONE batched
    linear solve (the per-spec loop was a visible fixed cost of sweeps now
    that the accelerated RVI converges in tens of backups); specs whose
    batched solve degenerates fall back to the scalar path, preserving its
    error behaviour.
    """
    if len(policies) != batch.n_specs:
        raise ValueError(f"{len(policies)} policies for {batch.n_specs} specs")
    acts = np.asarray(policies, dtype=np.int64)
    for i in range(batch.n_specs):
        _check_feasible(batch.feasible[i], acts[i])
    p = batch.policy_transitions_batched(acts)
    mu, ok = stationary_distribution_batched(p)
    return [
        _finish_from_batch(batch, i, acts[i], mu[i])
        if ok[i]
        else evaluate_policy_banded(batch, i, acts[i])
        for i in range(batch.n_specs)
    ]


# ---------------------------------------------------------------------------
# JAX dense-free policy evaluation of the *discretized* MDP (m_tilde under a
# frozen policy).  These are the building blocks of the modified-policy-
# iteration polish in rvi.py: jit/vmap-friendly, one spec per call.
# ---------------------------------------------------------------------------


def policy_matrix_banded(pmfs, tails, scale, s_max: int, policy):
    """(S, S) discretized transition matrix m_tilde(. | s, pi(s)).

    Built from the banded data only (arrival pmfs possibly trimmed to a
    band narrower than s_max + 1, overflow tails, eta / y scale) — the same
    inputs as rvi.banded_backup, and mathematically the rows of
    smdp._dense_m_tilde selected by ``policy``.  The trimmed in-band mass
    (< rvi.BAND_TOL per row) is the only deviation from row-stochasticity.

    pmfs: (A, Kb); tails: (A, s_max+1); scale: (S, A); policy: (S,) int.
    """
    S = scale.shape[0]
    Kb = pmfs.shape[1]
    s_o = S - 1
    s_idx = jnp.arange(S)
    s_val = jnp.minimum(s_idx, s_max)
    a = policy
    sc = scale[s_idx, a]  # (S,)
    serve = a >= 1
    base = jnp.clip(s_val - a, 0, s_max)
    # serve rows: window pmf over columns 0..s_max plus tail mass to S_o
    k = jnp.arange(s_max + 1)[None, :] - base[:, None]  # (S, s_max+1)
    in_band = (k >= 0) & (k < Kb)
    window = jnp.where(
        in_band & serve[:, None], pmfs[a[:, None], jnp.clip(k, 0, Kb - 1)], 0.0
    )
    m_hat = jnp.zeros((S, S), dtype=scale.dtype)
    m_hat = m_hat.at[:, : s_max + 1].set(window)
    m_hat = m_hat.at[:, s_o].add(jnp.where(serve, tails[a, base], 0.0))
    # wait rows: deterministic +1 (S_o self-loops)
    nxt = jnp.where(s_idx < s_max, s_idx + 1, s_o)
    wait_rows = jnp.zeros((S, S), dtype=scale.dtype).at[s_idx, nxt].set(1.0)
    m_hat = jnp.where(serve[:, None], m_hat, wait_rows)
    # discretize (eq. 23): scale towards eta-uniformization
    return sc[:, None] * m_hat + (1.0 - sc) * jnp.eye(S, dtype=scale.dtype)


# ---------------------------------------------------------------------------
# Phase-modulated product chain (K*S states, phase-blocked flattening)
# ---------------------------------------------------------------------------


def _gather_modulated(mbatch: ModulatedBatchedSMDP, i: int, acts: np.ndarray):
    """Flattened (K*S,) per-state rows of y/c/hold/energy under a policy."""
    K, S = mbatch.n_phases, mbatch.n_states
    zz = np.arange(K)[:, None]
    ss = np.arange(S)[None, :]
    gather = lambda arr: arr[i, zz, ss, acts].reshape(-1)  # noqa: E731
    return (
        gather(mbatch.y),
        gather(mbatch.c_hat),
        gather(mbatch.c_hold),
        gather(mbatch.c_energy),
    )


def _check_feasible_modulated(
    mbatch: ModulatedBatchedSMDP, i: int, acts: np.ndarray
) -> None:
    K, S = mbatch.n_phases, mbatch.n_states
    if acts.shape != (K, S):
        raise ValueError(f"policy shape {acts.shape} != ({K}, {S})")
    feas = mbatch.feasible[i][np.arange(S)[None, :], acts]
    if not feas.all():
        bad = np.argwhere(~feas)
        raise ValueError(
            f"policy takes infeasible actions at (phase, state) {bad[:5]}"
        )


def _overflow_mask(K: int, S: int) -> np.ndarray:
    m = np.zeros((K, S), dtype=bool)
    m[:, -1] = True
    return m.reshape(-1)


def _finish_modulated(
    mbatch: ModulatedBatchedSMDP, i: int, acts: np.ndarray, mu: np.ndarray
) -> PolicyEval:
    y_pi, c_pi, hold_pi, energy_pi = _gather_modulated(mbatch, i, acts)
    return _finish_eval(
        mu,
        acts.reshape(-1),
        y_pi,
        c_pi,
        hold_pi,
        energy_pi,
        overflow=_overflow_mask(mbatch.n_phases, mbatch.n_states),
    )


def evaluate_policy_modulated(
    mbatch: ModulatedBatchedSMDP, i: int, policy: np.ndarray
) -> PolicyEval:
    """evaluate_policy on the (phase, queue) product chain of spec ``i``.

    ``policy`` is a (K, S) phase-indexed action table.  Delta (the paper's
    tail-tolerance, eq. 22) sums the contribution of every phase's overflow
    state, so the adaptive-truncation rule carries over unchanged.
    """
    acts = np.asarray(policy, dtype=np.int64)
    _check_feasible_modulated(mbatch, i, acts)
    p_pi = mbatch.take([i]).policy_transitions_batched(acts[None])[0]
    mu = stationary_distribution(p_pi)
    return _finish_modulated(mbatch, i, acts, mu)


def evaluate_policy_modulated_batched(
    mbatch: ModulatedBatchedSMDP, policies: np.ndarray
) -> List[PolicyEval]:
    """Per-spec evaluation of (N, K, S) policies: one batched K*S solve.

    Specs whose batched stationary solve degenerates fall back to the
    scalar-path solver, mirroring evaluate_policy_batched.
    """
    acts = np.asarray(policies, dtype=np.int64)
    if acts.shape[0] != mbatch.n_specs:
        raise ValueError(f"{acts.shape[0]} policies for {mbatch.n_specs} specs")
    for i in range(mbatch.n_specs):
        _check_feasible_modulated(mbatch, i, acts[i])
    p = mbatch.policy_transitions_batched(acts)
    mu, ok = stationary_distribution_batched(p)
    out = []
    for i in range(mbatch.n_specs):
        if ok[i]:
            out.append(_finish_modulated(mbatch, i, acts[i], mu[i]))
        else:
            out.append(
                _finish_modulated(
                    mbatch, i, acts[i], stationary_distribution(p[i])
                )
            )
    return out


def policy_matrix_banded_modulated(
    pmfs, tails, wait_m, scale, s_max: int, policy
):
    """(K*S, K*S) discretized transition matrix of a frozen (K, S) policy.

    The modulated analogue of policy_matrix_banded: built from the
    phase-coupled banded data only (pmfs possibly band-trimmed), feeding
    the same policy_eval_linear for the MPI polish and the exact final
    gain of the modulated RVI.  Flattened index = z * S + s.

    pmfs: (A, K, K, Kb); tails: (A, K, K, s_max+1); wait_m: (K, K);
    scale: (K, S, A); policy: (K, S) int.
    """
    K, S, A = scale.shape
    Kb = pmfs.shape[-1]
    s_o = S - 1
    s_idx = jnp.arange(S)
    s_val = jnp.minimum(s_idx, s_max)
    a = policy  # (K, S)
    sc = jnp.take_along_axis(scale, a[..., None], axis=-1)[..., 0]  # (K, S)
    serve = a >= 1
    base = jnp.clip(s_val[None, :] - a, 0, s_max)  # (K, S)
    k = jnp.arange(s_max + 1)[None, None, :] - base[..., None]  # (K, S, s_max+1)
    in_band = (k >= 0) & (k < Kb)
    zi = jnp.arange(K)
    # window[z, s, w, j] = pmfs[a[z,s], z, w, k[z,s,j]]
    window = jnp.where(
        in_band[:, :, None, :] & serve[:, :, None, None],
        pmfs[
            a[:, :, None, None],
            zi[:, None, None, None],
            zi[None, None, :, None],
            jnp.clip(k, 0, Kb - 1)[:, :, None, :],
        ],
        0.0,
    )  # (K, S, K, s_max+1)
    m_hat = jnp.zeros((K, S, K, S), dtype=scale.dtype)
    m_hat = m_hat.at[..., : s_max + 1].set(window)
    tail = tails[
        a[:, :, None], zi[:, None, None], zi[None, None, :], base[:, :, None]
    ]  # (K, S, K)
    m_hat = m_hat.at[..., s_o].add(jnp.where(serve[..., None], tail, 0.0))
    # wait rows: (z, s) -> (w, s + 1) (S_o self-block) weighted by wait_m
    nxt = jnp.where(s_idx < s_max, s_idx + 1, s_o)
    onehot = jnp.zeros((S, S), dtype=scale.dtype).at[s_idx, nxt].set(1.0)
    wait_rows = wait_m[:, None, :, None] * onehot[None, :, None, :]
    m_hat = jnp.where(serve[:, :, None, None], m_hat, wait_rows)
    m_flat = m_hat.reshape(K * S, K * S)
    sc_flat = sc.reshape(-1)
    return sc_flat[:, None] * m_flat + jnp.diag(1.0 - sc_flat)


def policy_eval_linear(c_pi, m_pi, ref_state: int = 0):
    """Exact average-cost evaluation of a frozen policy: solve for (g, h).

    The gauge-fixed evaluation equations  h + g*1 = c_pi + M_pi h,
    h[ref] = 0  collapse to one (S, S) linear system by storing g in the
    slot of the pinned unknown: A = (I - M_pi) with column ``ref_state``
    replaced by ones.  Unichain policies give a nonsingular A; a multichain
    (or otherwise degenerate) policy surfaces as non-finite output, which
    the MPI safeguard in rvi.py rejects.
    """
    S = c_pi.shape[0]
    a = jnp.eye(S, dtype=c_pi.dtype) - m_pi
    a = a.at[:, ref_state].set(1.0)
    x = jnp.linalg.solve(a, c_pi[..., None])[..., 0]
    g = x[ref_state]
    h = x.at[ref_state].set(0.0)
    return g, h
