"""Exact policy evaluation on the truncated SMDP (eq. 21-22).

Given a stationary deterministic policy (action table over S_hat), compute
the stationary distribution of the induced semi-Markov chain and derive

  g_hat  = sum_s mu_s c^(s, pi(s)) / sum_s mu_s y(s, pi(s))        (eq. 21)
  Delta  = mu_{S_o} c^(S_o, pi(S_o)) / sum_s mu_s y(s, pi(s))      (eq. 22)
  W_bar  = average request response time  (w1-term with w1 = 1)
  P_bar  = average power                  (w2-term with w2 = 1)
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .smdp import TruncatedSMDP


@dataclasses.dataclass
class PolicyEval:
    g: float  # average weighted cost per unit time (with spec's w1, w2)
    delta: float  # tail-state contribution (approximation quality, eq. 22)
    w_bar: float  # average response time
    p_bar: float  # average power consumption
    mu: np.ndarray  # stationary distribution over S_hat
    mean_batch: float  # average served batch size
    throughput: float  # served requests per unit time


def stationary_distribution(p: np.ndarray, tol: float = 1e-12) -> np.ndarray:
    """Solve mu P = mu, sum(mu) = 1 via a dense linear solve."""
    n = p.shape[0]
    a = p.T - np.eye(n)
    a[-1, :] = 1.0
    b = np.zeros(n)
    b[-1] = 1.0
    try:
        mu = np.linalg.solve(a, b)
    except np.linalg.LinAlgError:
        mu = np.linalg.lstsq(a, b, rcond=None)[0]
    mu = np.clip(mu, 0.0, None)
    s = mu.sum()
    if s <= tol:
        raise RuntimeError("degenerate stationary distribution")
    return mu / s


def evaluate_policy(mdp: TruncatedSMDP, policy: np.ndarray) -> PolicyEval:
    spec = mdp.spec
    S = mdp.n_states
    rows = np.arange(S)
    acts = np.asarray(policy, dtype=np.int64)
    if acts.shape != (S,):
        raise ValueError(f"policy shape {acts.shape} != ({S},)")
    feas = mdp.feasible[rows, acts]
    if not feas.all():
        bad = rows[~feas]
        raise ValueError(f"policy takes infeasible actions at states {bad[:5]}")

    p_pi = mdp.m_hat[rows, acts, :]
    mu = stationary_distribution(p_pi)

    y_pi = mdp.y[rows, acts]
    c_pi = mdp.c_hat[rows, acts]
    denom = float(mu @ y_pi)
    g = float(mu @ c_pi) / denom
    delta = float(mu[-1] * c_pi[-1]) / denom

    # objective decomposition (abstract cost excluded — it is a solver device,
    # not part of the physical objective)
    hold_pi = mdp.c_hold[rows, acts]
    energy_pi = mdp.c_energy[rows, acts]
    w_bar = float(mu @ hold_pi) / denom  # = L_bar / lam = W_bar (Little)
    p_bar = float(mu @ energy_pi) / denom

    served = acts.astype(np.float64)
    mean_batch = float(mu @ (served * (served > 0))) / max(
        float(mu @ (served > 0)), 1e-300
    )
    throughput = float(mu @ served) / denom
    return PolicyEval(
        g=g,
        delta=delta,
        w_bar=w_bar,
        p_bar=p_bar,
        mu=mu,
        mean_batch=mean_batch,
        throughput=throughput,
    )
