"""Batched spec sweeps: solve a whole w2 / lambda / profile grid at once.

Every figure in the paper (Fig. 4/5/8/9, Table III) is a sweep over some
spec parameter.  Solving the points serially rebuilds dense (S, A, S)
tensors and re-dispatches RVI per point; here the grid is stacked into one
BatchedSMDP (smdp.build_smdp_batched) and solved by a single jitted,
vmapped banded-RVI while_loop (rvi.relative_value_iteration_batched).
Policy evaluation and the abstract-cost calibration run on the banded
transition structure too, so nothing on the sweep path is O(S^2) per spec.

The paper's adaptive truncation rule (Sec. V: accept when the tail
tolerance Delta^pi < delta, else grow s_max) is applied batch-wide: after
each batched solve only the specs whose Delta still exceeds delta are
regrown and re-solved together, so a sweep costs O(#rounds) jitted calls
instead of O(#specs x #rounds).

Since the high-rho mixing wall is the dominant cost (rho >= 0.7 needs
hundreds of lockstep backups for plain RVI), the sweep path defaults to
accel="auto" — the accelerated solver (rvi accel="mpi") whenever the
sweep reaches the slow-mixing regime, plain lockstep otherwise — and
each batch is internally re-ordered along (rho, w2) so the
anchor-interpolated warm starts chain along the rho axis: the ends of
the sorted batch are the extreme-rho specs, exactly where interpolation
buys the most.  Results always come back in the caller's original spec
order.

sweep_solve_modulated / sweep_bank(phases=...) are the exact MMPP-aware
mirrors: the same ordering, c_o-probe reuse, warm-start chaining and
adaptive-truncation machinery runs on the (phase, queue) product chain
(smdp.build_smdp_modulated_batched), producing (K, S) phase-indexed
policies the serving layer consumes as table stacks.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from .evaluate import (
    _finish_from_batch,
    evaluate_policy_banded,
    evaluate_policy_batched,
    evaluate_policy_modulated,
    evaluate_policy_modulated_batched,
    stationary_distribution_batched,
)
from .policies import greedy_policy
from .rvi import (
    ACCEL_RHO_THRESHOLD as _ACCEL_RHO_THRESHOLD,
    relative_value_iteration_batched,
    relative_value_iteration_modulated,
)
from .smdp import (
    PhaseConfig,
    SMDPSpec,
    build_smdp_batched,
    build_smdp_modulated_batched,
    modulated_spec,
    phase_rho,
)
from .solve import ModulatedSolveResult, SolveResult


def sweep_bank(
    base: SMDPSpec,
    lams: Sequence[float],
    w2s: Optional[Sequence[float]] = None,
    profiles: Optional[dict] = None,
    phases: Optional[PhaseConfig] = None,
    **solve_kw,
):
    """Solve a lambda x w2 (x service-profile) grid as an SMDPSchedulerBank.

    The serving-side entry point for regime-adaptive scheduling: the bank's
    keyed action tables are what AdaptiveController retunes against as the
    observed arrival rate (or the energy price) drifts.  ``w2s`` defaults
    to the base spec's w2 (a pure lambda grid).

    ``profiles`` adds the third bank axis: a mapping from a numeric
    service-profile id to the spec fields that profile overrides (a dict
    for dataclasses.replace — typically ``{"service": ..., "energy": ...}``
    from a profiled or roofline-derived model, core.profiles).  Keys become
    (lam, w2, profile) and the serving layer selects the slice by pinning
    the coordinate: ``bank.scheduler(lam=..., w2=..., profile=pid)`` or
    ``AdaptiveController(bank, w2=..., profile=pid)``.  All profiles must
    share b_max (the action axis cannot be padded).

    ``phases`` switches the bank to *exact MMPP-aware* solves: each lam is
    treated as the target mean rate, the PhaseConfig's per-phase rates are
    scaled to hit it (same burst ratio and switching dynamics), and every
    table in the bank becomes a (K, S) phase-indexed stack solved on the
    (phase, queue) product chain (sweep_solve_modulated).  Serving-side
    consumers pick the phase row via SMDPScheduler.phase, the oracle /
    belief schedulers, or the compiled phase lane.  Mutually exclusive
    with ``profiles``.
    """
    from repro.serving.scheduler import SMDPScheduler

    lams = list(lams)
    w2s = [base.w2] if w2s is None else list(w2s)
    if len(lams) == 0 or len(w2s) == 0:
        raise ValueError("sweep_bank needs at least one lam and one w2")
    if phases is not None:
        if profiles is not None:
            raise ValueError("phases= and profiles= are mutually exclusive")
        specs, phase_list, keys = [], [], []
        for lam in lams:
            ph = phases.scaled(float(lam) / phases.mean_rate)
            for w2 in w2s:
                specs.append(
                    modulated_spec(
                        dataclasses.replace(base, w2=float(w2)), ph
                    )
                )
                phase_list.append(ph)
                keys.append((float(lam), float(w2)))
        return SMDPScheduler.bank(
            sweep_solve_modulated(specs, phase_list, **solve_kw),
            keys=keys,
            key_names=("lam", "w2"),
        )
    variants = [(None, {})] if profiles is None else [
        (float(pid), dict(over)) for pid, over in profiles.items()
    ]
    if not variants:
        raise ValueError("profiles= must contain at least one profile")
    specs, keys = [], []
    for pid, over in variants:
        for lam in lams:
            for w2 in w2s:
                specs.append(
                    dataclasses.replace(
                        base, lam=float(lam), w2=float(w2), **over
                    )
                )
                keys.append(
                    (float(lam), float(w2))
                    if pid is None
                    else (float(lam), float(w2), pid)
                )
    key_names = ("lam", "w2") if profiles is None else ("lam", "w2", "profile")
    return SMDPScheduler.bank(
        sweep_solve(specs, **solve_kw), keys=keys, key_names=key_names
    )


def pad_specs(specs: Sequence[SMDPSpec]) -> List[SMDPSpec]:
    """Lift a mixed-truncation spec list to a shared s_max (batch padding).

    A larger truncation level only refines the approximation, so padding to
    the max is always sound.  b_max must already agree across specs — the
    action axis cannot be padded without changing feasible sets.
    """
    specs = list(specs)
    if not specs:
        return []
    b_maxes = {sp.b_max for sp in specs}
    if len(b_maxes) > 1:
        raise ValueError(f"sweep specs must share b_max; got {sorted(b_maxes)}")
    s_max = max(sp.s_max for sp in specs)
    # finite-buffer specs are never padded: their truncation level IS the
    # physical buffer (buffer == s_max is an exact-fold invariant)
    return [
        sp
        if sp.s_max == s_max or sp.buffer is not None
        else dataclasses.replace(sp, s_max=s_max)
        for sp in specs
    ]


def _greedy_c_o(batch) -> np.ndarray:
    """Per-spec abstract cost c_o = max(100, 2 * g_greedy) from a c_o=0 batch.

    The greedy gains of the whole probe batch come from one batched
    stationary solve; specs whose greedy chain degenerates keep the paper
    default of 100 (same fallback as the serial resolver).
    """
    pols = np.stack(
        [
            greedy_policy(sp.s_max, sp.b_min, sp.b_max)
            for sp in batch.specs
        ]
    )
    p = batch.policy_transitions_batched(pols)
    mu, ok = stationary_distribution_batched(p)
    out = np.empty(batch.n_specs)
    for i in range(batch.n_specs):
        if ok[i]:
            g = _finish_from_batch(batch, i, pols[i], mu[i]).g
        else:
            try:
                g = evaluate_policy_banded(batch, i, pols[i]).g
            except RuntimeError:
                g = 100.0
        out[i] = max(100.0, 2.0 * g)
    return out


def resolve_abstract_cost_batched(
    specs: Sequence[SMDPSpec],
) -> List[SMDPSpec]:
    """Batched solve.resolve_abstract_cost: c_o = max(100, 2 * g_greedy).

    One banded batch build of the c_o = 0 probes calibrates every spec's
    abstract cost (one batched stationary solve for all greedy gains).
    """
    specs = list(specs)
    probes = [dataclasses.replace(sp, c_o=0.0) for sp in specs]
    batch = build_smdp_batched(probes)
    c_os = _greedy_c_o(batch)
    return [
        dataclasses.replace(sp, c_o=float(c)) for sp, c in zip(specs, c_os)
    ]


#: below this batch width the anchor pre-solve costs more than it saves
_WARM_START_MIN = 6


def _warm_start_t(specs: Sequence[SMDPSpec], c_feat: np.ndarray) -> np.ndarray:
    """Per-spec interpolation coordinate t in [0, 1] along the anchor pair.

    The interpolation coordinate:

      * rho varies across the batch — project the normalized (rho, w2)
        parameter point onto the anchor segment (c_tilde is NOT affine in
        lambda: the arrival pmfs move with it, so cost-space projection
        would misplace lambda-swept specs);
      * rho constant (w2 / energy-profile sweeps) — project the cost
        features ``c_feat`` (finite c_tilde entries, flattened per spec)
        onto the anchor segment, which is exact for any parameter c_tilde
        is affine in, without knowing which one the caller swept.
    """
    rhos = np.array([sp.rho for sp in specs])
    w2s = np.array([sp.w2 for sp in specs])
    if abs(rhos[-1] - rhos[0]) > 1e-12:

        def norm(v):
            span = v[-1] - v[0]
            return (v - v[0]) / span if abs(span) > 1e-12 else np.zeros_like(v)

        theta = np.stack([norm(rhos), norm(w2s)], axis=1)  # (N, 2)
        d = theta[-1] - theta[0]
        return np.clip(theta @ d / float(d @ d), 0.0, 1.0)
    d = c_feat[-1] - c_feat[0]
    denom = float(d @ d)
    if denom <= 0.0:
        return np.zeros(len(specs))
    return np.clip((c_feat - c_feat[0]) @ d / denom, 0.0, 1.0)


def _anchor_warm_start(batch, eps: float, max_iter: int, **rvi_kw):
    """Interpolated h0 from solving the two end-of-batch anchor specs.

    Any h0 reaches the same fixed point — a good one just makes the
    batched RVI converge in far fewer lockstep iterations.  The batch is
    pre-sorted along (rho, w2) by sweep_solve, so the anchors are the
    extreme-rho specs and interpolation chains along the rho axis where
    mixing (and hence iteration count) is worst (coordinate: see
    _warm_start_t).
    """
    if batch.n_specs < _WARM_START_MIN:
        return None
    anchors = relative_value_iteration_batched(
        batch.take([0, batch.n_specs - 1]), eps=eps, max_iter=max_iter, **rvi_kw
    )
    mask = batch.feasible.all(axis=0)  # finite c_tilde in every spec
    t = _warm_start_t(batch.specs, batch.c_tilde[:, mask])
    return (1.0 - t)[:, None] * anchors.h[0] + t[:, None] * anchors.h[1]


def _anchor_warm_start_modulated(mbatch, eps: float, max_iter: int, **rvi_kw):
    """Modulated anchor warm start: h0 chains along rho per phase block.

    Identical discipline to _anchor_warm_start — the anchors are the
    extreme-(rho, w2) specs of the pre-sorted batch — with the (K, S)
    phase-blocked h interpolated jointly (every phase block shares the
    spec's interpolation coordinate, since the whole product chain moves
    with (rho, w2))."""
    if mbatch.n_specs < _WARM_START_MIN:
        return None
    anchors = relative_value_iteration_modulated(
        mbatch.take([0, mbatch.n_specs - 1]),
        eps=eps,
        max_iter=max_iter,
        **rvi_kw,
    )
    mask = mbatch.feasible.all(axis=0)  # (S, A) feasible in every spec
    c_feat = mbatch.c_tilde[:, :, mask].reshape(mbatch.n_specs, -1)
    t = _warm_start_t(mbatch.specs, c_feat)
    return (
        (1.0 - t)[:, None, None] * anchors.h[0]
        + t[:, None, None] * anchors.h[1]
    )


def sweep_solve(
    specs: Sequence[SMDPSpec],
    eps: float = 1e-2,
    max_iter: int = 10_000,
    delta: float = 1e-3,
    grow_factor: float = 1.5,
    max_s_max: int = 4096,
    auto_c_o: bool = True,
    accel: str = "auto",
    backup: str = "banded",
) -> List[SolveResult]:
    """Batched equivalent of solve.solve() over a list of specs.

    Returns one SolveResult per input spec, in input order; each matches the
    serial solver's output for the same spec to solver tolerance.  Specs with
    differing s_max are padded to the batch maximum first.  Results carry no
    dense tensors — ``result.mdp`` materializes one lazily if accessed.

    ``accel`` / ``backup`` are forwarded to the batched RVI (rvi module
    docstring).  The default "auto" routes through accel="mpi" whenever the
    sweep reaches into the slow-mixing regime (any rho >=
    _ACCEL_RHO_THRESHOLD) — breaking the high-rho mixing wall (tens of
    backups instead of hundreds) while staying bit-identical in policy to
    the scalar float64 solve() oracle — and stays on the plain lockstep
    path for fast-mixing sweeps where the polish is pure overhead.  Pass
    accel="none"/"mpi"/"anderson" to force a path.
    """
    specs = list(specs)
    flags = {sp.buffer is not None for sp in specs}
    if len(flags) > 1:
        raise ValueError(
            "sweep_solve cannot mix finite-buffer and tail-abstracted "
            "specs in one batch; solve the two families separately"
        )
    if flags and flags.pop():
        # finite-buffer solves: no abstract tail to calibrate, and Delta
        # is not a truncation error (B is physical) — never regrow
        auto_c_o = False
        delta = None
    specs = pad_specs(specs)
    if not specs:
        return []
    if accel == "auto":
        accel = (
            "mpi"
            if max(sp.rho for sp in specs) >= _ACCEL_RHO_THRESHOLD
            else "none"
        )
    # chain the work along rho (then w2) once, up front: the warm-start
    # anchors become the extreme-rho specs, where mixing is worst, and the
    # c_o probe batch can be reused (row-patched) as the first solve batch
    order = sorted(
        range(len(specs)), key=lambda i: (specs[i].rho, specs[i].w2)
    )
    prebuilt = None
    if auto_c_o:
        probe_batch = build_smdp_batched(
            [dataclasses.replace(specs[i], c_o=0.0) for i in order]
        )
        prebuilt = probe_batch.with_c_o(_greedy_c_o(probe_batch))
        pending = list(zip(order, prebuilt.specs))
    else:
        pending = [(i, specs[i]) for i in order]
    rvi_kw = dict(accel=accel, backup=backup)
    results: List[SolveResult] = [None] * len(specs)  # type: ignore[list-item]
    while pending:
        # group by truncation level: re-grown specs share their new s_max
        levels = sorted({sp.s_max for _, sp in pending})
        still_pending = []
        for s_max in levels:
            group = [(i, sp) for i, sp in pending if sp.s_max == s_max]
            group.sort(key=lambda t: (t[1].rho, t[1].w2))
            if (
                prebuilt is not None
                and len(group) == prebuilt.n_specs
                and all(a is b for (_, a), b in zip(group, prebuilt.specs))
            ):
                batch = prebuilt
            else:
                batch = build_smdp_batched([sp for _, sp in group])
            rvi = relative_value_iteration_batched(
                batch,
                eps=eps,
                max_iter=max_iter,
                h0=_anchor_warm_start(batch, eps, max_iter, **rvi_kw),
                **rvi_kw,
            )
            evs = evaluate_policy_batched(batch, rvi.policies)
            for row, (idx, sp) in enumerate(group):
                ev = evs[row]
                if delta is None or ev.delta < delta or sp.s_max >= max_s_max:
                    results[idx] = SolveResult(
                        spec=sp, rvi=rvi.unstack(row), eval=ev
                    )
                else:
                    still_pending.append(
                        (
                            idx,
                            dataclasses.replace(
                                sp,
                                s_max=min(
                                    int(np.ceil(sp.s_max * grow_factor)),
                                    max_s_max,
                                ),
                            ),
                        )
                    )
        prebuilt = None
        pending = still_pending
    return results


# ---------------------------------------------------------------------------
# Phase-modulated sweeps (exact MMPP-aware solves)
# ---------------------------------------------------------------------------


def _greedy_c_o_modulated(mbatch) -> np.ndarray:
    """Per-spec abstract cost c_o = max(100, 2 * g_greedy), modulated chain.

    The greedy policy is phase-independent (largest feasible batch now), so
    its (K, S) lift is the scalar table tiled across phases; gains come
    from the batched product-chain stationary solve."""
    K = mbatch.n_phases
    pols = np.stack(
        [
            np.tile(
                greedy_policy(sp.s_max, sp.b_min, sp.b_max)[None, :], (K, 1)
            )
            for sp in mbatch.specs
        ]
    )
    out = np.empty(mbatch.n_specs)
    try:
        evs = evaluate_policy_modulated_batched(mbatch, pols)
        for i, ev in enumerate(evs):
            out[i] = max(100.0, 2.0 * ev.g)
    except RuntimeError:
        for i in range(mbatch.n_specs):
            try:
                g = evaluate_policy_modulated(mbatch, i, pols[i]).g
            except RuntimeError:
                g = 100.0
            out[i] = max(100.0, 2.0 * g)
    return out


def sweep_solve_modulated(
    specs: Sequence[SMDPSpec],
    phases: Sequence[PhaseConfig],
    eps: float = 1e-2,
    max_iter: int = 10_000,
    delta: float = 1e-3,
    grow_factor: float = 1.5,
    max_s_max: int = 1024,
    auto_c_o: bool = True,
    accel: str = "auto",
) -> List[ModulatedSolveResult]:
    """Batched exact MMPP-aware solves over aligned (spec, phases) pairs.

    The modulated mirror of sweep_solve: specs are padded to a shared
    s_max, sorted along (rho, w2) so anchor warm starts chain along the
    rho axis per phase block, the c_o = 0 probe batch calibrates every
    abstract cost with one batched product-chain stationary solve (then
    row-patched via with_c_o, never rebuilt), and the paper's adaptive
    truncation rule regrows only the specs whose Delta (summed over every
    phase's overflow state) still exceeds ``delta``.  Results return in
    input order; each carries the (K, S) phase-indexed policy.

    ``phases`` may be one shared PhaseConfig or a sequence aligned with
    ``specs``.  ``max_s_max`` defaults lower than the scalar sweep: the
    product chain is K x larger per state and the exact solves are meant
    for policy tables, not tail asymptotics.
    """
    specs = list(specs)
    if not specs:
        return []
    if isinstance(phases, PhaseConfig):
        phases = [phases] * len(specs)
    phases = list(phases)
    if len(phases) != len(specs):
        raise ValueError(f"{len(phases)} phase configs for {len(specs)} specs")
    specs = pad_specs(specs)
    if accel == "auto":
        # the burst phase sets the mixing wall: key on max within-phase rho
        rho_z = max(phase_rho(sp, ph) for sp, ph in zip(specs, phases))
        accel = "mpi" if rho_z >= _ACCEL_RHO_THRESHOLD else "none"
    order = sorted(
        range(len(specs)), key=lambda i: (specs[i].rho, specs[i].w2)
    )
    prebuilt = None
    if auto_c_o:
        probe = build_smdp_modulated_batched(
            [dataclasses.replace(specs[i], c_o=0.0) for i in order],
            [phases[i] for i in order],
        )
        prebuilt = probe.with_c_o(_greedy_c_o_modulated(probe))
        pending = [
            (i, sp, phases[i]) for i, sp in zip(order, prebuilt.specs)
        ]
    else:
        pending = [(i, specs[i], phases[i]) for i in order]
    rvi_kw = dict(accel=accel)
    results: List[ModulatedSolveResult] = [None] * len(specs)  # type: ignore[list-item]
    while pending:
        levels = sorted({sp.s_max for _, sp, _ in pending})
        still_pending = []
        for s_max in levels:
            group = [(i, sp, ph) for i, sp, ph in pending if sp.s_max == s_max]
            group.sort(key=lambda t: (t[1].rho, t[1].w2))
            if (
                prebuilt is not None
                and len(group) == prebuilt.n_specs
                and all(a is b for (_, a, _), b in zip(group, prebuilt.specs))
            ):
                mbatch = prebuilt
            else:
                mbatch = build_smdp_modulated_batched(
                    [sp for _, sp, _ in group], [ph for _, _, ph in group]
                )
            rvi = relative_value_iteration_modulated(
                mbatch,
                eps=eps,
                max_iter=max_iter,
                h0=_anchor_warm_start_modulated(
                    mbatch, eps, max_iter, **rvi_kw
                ),
                **rvi_kw,
            )
            evs = evaluate_policy_modulated_batched(mbatch, rvi.policies)
            for row, (idx, sp, ph) in enumerate(group):
                ev = evs[row]
                if delta is None or ev.delta < delta or sp.s_max >= max_s_max:
                    results[idx] = ModulatedSolveResult(
                        spec=sp, phases=ph, rvi=rvi.unstack(row), eval=ev
                    )
                else:
                    still_pending.append(
                        (
                            idx,
                            dataclasses.replace(
                                sp,
                                s_max=min(
                                    int(np.ceil(sp.s_max * grow_factor)),
                                    max_s_max,
                                ),
                            ),
                            ph,
                        )
                    )
        prebuilt = None
        pending = still_pending
    return results


def solve_modulated(
    spec: SMDPSpec, phases: PhaseConfig, **kw
) -> ModulatedSolveResult:
    """Exact MMPP-aware solve of one spec (the N == 1 modulated sweep).

    ``spec.lam`` must equal ``phases.mean_rate`` (use smdp.modulated_spec).
    The K = 1 degenerate config reproduces the scalar solve() policy
    bit-for-bit — the refactor's safety rail, pinned by the test suite.
    """
    return sweep_solve_modulated([spec], phases, **kw)[0]
