"""Batched spec sweeps: solve a whole w2 / lambda / profile grid at once.

Every figure in the paper (Fig. 4/5/8/9, Table III) is a sweep over some
spec parameter.  Solving the points serially rebuilds dense (S, A, S)
tensors and re-dispatches RVI per point; here the grid is stacked into one
BatchedSMDP (smdp.build_smdp_batched) and solved by a single jitted,
vmapped banded-RVI while_loop (rvi.relative_value_iteration_batched).
Policy evaluation and the abstract-cost calibration run on the banded
transition structure too, so nothing on the sweep path is O(S^2) per spec.

The paper's adaptive truncation rule (Sec. V: accept when the tail
tolerance Delta^pi < delta, else grow s_max) is applied batch-wide: after
each batched solve only the specs whose Delta still exceeds delta are
regrown and re-solved together, so a sweep costs O(#rounds) jitted calls
instead of O(#specs x #rounds).

Since the high-rho mixing wall is the dominant cost (rho >= 0.7 needs
hundreds of lockstep backups for plain RVI), the sweep path defaults to
accel="auto" — the accelerated solver (rvi accel="mpi") whenever the
sweep reaches the slow-mixing regime, plain lockstep otherwise — and
each batch is internally re-ordered along (rho, w2) so the
anchor-interpolated warm starts chain along the rho axis: the ends of
the sorted batch are the extreme-rho specs, exactly where interpolation
buys the most.  Results always come back in the caller's original spec
order.

sweep_solve_modulated / sweep_bank(phases=...) are the exact MMPP-aware
mirrors: the same ordering, c_o-probe reuse, warm-start chaining and
adaptive-truncation machinery runs on the (phase, queue) product chain
(smdp.build_smdp_modulated_batched), producing (K, S) phase-indexed
policies the serving layer consumes as table stacks.

Long-horizon robustness (both sweep entry points):

  * guard=True (default) routes every batched solve through the rvi
    guardrail ladder — a poisoned or diverging spec degrades to slower
    solve paths / per-spec quarantine instead of NaN-ing the whole grid,
    and report_sink=[...] collects the merged SolveReport certificates;
  * checkpoint_dir=... makes the sweep durable and SIGTERM-preemptible:
    solved chunks persist through checkpoint.CheckpointManager and an
    identical re-run resumes bitwise-identically (see the "Durable,
    resumable sweeps" section below for the invariant).
"""
from __future__ import annotations

import dataclasses
import hashlib
import signal
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from .evaluate import (
    PolicyEval,
    _finish_from_batch,
    evaluate_policy_banded,
    evaluate_policy_batched,
    evaluate_policy_modulated,
    evaluate_policy_modulated_batched,
    stationary_distribution_batched,
)
from .policies import greedy_policy
from .rvi import (
    ACCEL_RHO_THRESHOLD as _ACCEL_RHO_THRESHOLD,
    RVIResult,
    SolveReport,
    relative_value_iteration_batched,
    relative_value_iteration_modulated,
)
from .smdp import (
    PhaseConfig,
    SMDPSpec,
    build_smdp_batched,
    build_smdp_modulated_batched,
    modulated_spec,
    phase_rho,
)
from .solve import ModulatedSolveResult, SolveResult


def sweep_bank(
    base: SMDPSpec,
    lams: Sequence[float],
    w2s: Optional[Sequence[float]] = None,
    profiles: Optional[dict] = None,
    phases: Optional[PhaseConfig] = None,
    **solve_kw,
):
    """Solve a lambda x w2 (x service-profile) grid as an SMDPSchedulerBank.

    The serving-side entry point for regime-adaptive scheduling: the bank's
    keyed action tables are what AdaptiveController retunes against as the
    observed arrival rate (or the energy price) drifts.  ``w2s`` defaults
    to the base spec's w2 (a pure lambda grid).

    ``profiles`` adds the third bank axis: a mapping from a numeric
    service-profile id to the spec fields that profile overrides (a dict
    for dataclasses.replace — typically ``{"service": ..., "energy": ...}``
    from a profiled or roofline-derived model, core.profiles).  Keys become
    (lam, w2, profile) and the serving layer selects the slice by pinning
    the coordinate: ``bank.scheduler(lam=..., w2=..., profile=pid)`` or
    ``AdaptiveController(bank, w2=..., profile=pid)``.  All profiles must
    share b_max (the action axis cannot be padded).

    ``phases`` switches the bank to *exact MMPP-aware* solves: each lam is
    treated as the target mean rate, the PhaseConfig's per-phase rates are
    scaled to hit it (same burst ratio and switching dynamics), and every
    table in the bank becomes a (K, S) phase-indexed stack solved on the
    (phase, queue) product chain (sweep_solve_modulated).  Serving-side
    consumers pick the phase row via SMDPScheduler.phase, the oracle /
    belief schedulers, or the compiled phase lane.  Mutually exclusive
    with ``profiles``.
    """
    from repro.serving.scheduler import SMDPScheduler

    lams = list(lams)
    w2s = [base.w2] if w2s is None else list(w2s)
    if len(lams) == 0 or len(w2s) == 0:
        raise ValueError("sweep_bank needs at least one lam and one w2")
    if phases is not None:
        if profiles is not None:
            raise ValueError("phases= and profiles= are mutually exclusive")
        specs, phase_list, keys = [], [], []
        for lam in lams:
            ph = phases.scaled(float(lam) / phases.mean_rate)
            for w2 in w2s:
                specs.append(
                    modulated_spec(
                        dataclasses.replace(base, w2=float(w2)), ph
                    )
                )
                phase_list.append(ph)
                keys.append((float(lam), float(w2)))
        return SMDPScheduler.bank(
            sweep_solve_modulated(specs, phase_list, **solve_kw),
            keys=keys,
            key_names=("lam", "w2"),
        )
    variants = [(None, {})] if profiles is None else [
        (float(pid), dict(over)) for pid, over in profiles.items()
    ]
    if not variants:
        raise ValueError("profiles= must contain at least one profile")
    specs, keys = [], []
    for pid, over in variants:
        for lam in lams:
            for w2 in w2s:
                specs.append(
                    dataclasses.replace(
                        base, lam=float(lam), w2=float(w2), **over
                    )
                )
                keys.append(
                    (float(lam), float(w2))
                    if pid is None
                    else (float(lam), float(w2), pid)
                )
    key_names = ("lam", "w2") if profiles is None else ("lam", "w2", "profile")
    return SMDPScheduler.bank(
        sweep_solve(specs, **solve_kw), keys=keys, key_names=key_names
    )


def pad_specs(specs: Sequence[SMDPSpec]) -> List[SMDPSpec]:
    """Lift a mixed-truncation spec list to a shared s_max (batch padding).

    A larger truncation level only refines the approximation, so padding to
    the max is always sound.  b_max must already agree across specs — the
    action axis cannot be padded without changing feasible sets.
    """
    specs = list(specs)
    if not specs:
        return []
    b_maxes = {sp.b_max for sp in specs}
    if len(b_maxes) > 1:
        raise ValueError(f"sweep specs must share b_max; got {sorted(b_maxes)}")
    s_max = max(sp.s_max for sp in specs)
    # finite-buffer specs are never padded: their truncation level IS the
    # physical buffer (buffer == s_max is an exact-fold invariant)
    return [
        sp
        if sp.s_max == s_max or sp.buffer is not None
        else dataclasses.replace(sp, s_max=s_max)
        for sp in specs
    ]


def _greedy_c_o(batch) -> np.ndarray:
    """Per-spec abstract cost c_o = max(100, 2 * g_greedy) from a c_o=0 batch.

    The greedy gains of the whole probe batch come from one batched
    stationary solve; specs whose greedy chain degenerates keep the paper
    default of 100 (same fallback as the serial resolver).
    """
    pols = np.stack(
        [
            greedy_policy(sp.s_max, sp.b_min, sp.b_max)
            for sp in batch.specs
        ]
    )
    p = batch.policy_transitions_batched(pols)
    mu, ok = stationary_distribution_batched(p)
    out = np.empty(batch.n_specs)
    for i in range(batch.n_specs):
        if ok[i]:
            g = _finish_from_batch(batch, i, pols[i], mu[i]).g
        else:
            try:
                g = evaluate_policy_banded(batch, i, pols[i]).g
            except RuntimeError:
                g = 100.0
        out[i] = max(100.0, 2.0 * g)
    return out


def resolve_abstract_cost_batched(
    specs: Sequence[SMDPSpec],
) -> List[SMDPSpec]:
    """Batched solve.resolve_abstract_cost: c_o = max(100, 2 * g_greedy).

    One banded batch build of the c_o = 0 probes calibrates every spec's
    abstract cost (one batched stationary solve for all greedy gains).
    """
    specs = list(specs)
    probes = [dataclasses.replace(sp, c_o=0.0) for sp in specs]
    batch = build_smdp_batched(probes)
    c_os = _greedy_c_o(batch)
    return [
        dataclasses.replace(sp, c_o=float(c)) for sp, c in zip(specs, c_os)
    ]


# ---------------------------------------------------------------------------
# Durable, resumable sweeps.
#
# A checkpointed sweep processes each round's level groups in fixed-size
# chunks of the (rho, w2)-sorted order and persists the full solver state
# after every chunk through checkpoint.CheckpointManager (atomic rename +
# per-array CRC).  The resume invariant is *bitwise identity*: a sweep that
# is killed and re-run with the same arguments and checkpoint_dir produces
# exactly the arrays a never-killed checkpointed run produces, because
#   * chunks are consecutive slices of a stably-sorted group, so the
#     unprocessed remainder of a round is a suffix of the processing plan
#     and re-chunking a suffix reproduces the original chunk boundaries;
#   * the current round's remaining queue and the next round's regrow queue
#     are persisted separately (merging them would reorder level groups);
#   * calibrated c_o values are persisted, and the c_o probe batch is never
#     reused as a solve batch under checkpointing, so every chunk batch is
#     rebuilt from its specs alone on both paths.
# ---------------------------------------------------------------------------

#: default specs per checkpointed chunk (checkpoint_dir set, chunk_size not)
_DEFAULT_CHUNK = 16


class SweepPreempted(RuntimeError):
    """A preemption signal (SIGTERM) arrived; progress is durable on disk.

    Raised only after the in-flight chunk's checkpoint finished its atomic
    rename, so the step named here holds every result solved so far.
    Re-running the same sweep call with the same checkpoint_dir resumes
    from it."""

    def __init__(self, checkpoint_dir, step: int):
        super().__init__(
            f"sweep preempted; progress saved to {checkpoint_dir} "
            f"(step {step})"
        )
        self.checkpoint_dir = str(checkpoint_dir)
        self.step = step


class _PreemptGuard:
    """SIGTERM -> save-and-exit flag (same discipline as training preempt).

    The handler only sets a flag; the sweep loop checks it after each
    chunk's checkpoint commits and raises SweepPreempted.  Installed only
    from the main thread (signal.signal raises ValueError elsewhere — a
    sweep running on a worker thread simply cannot be signal-preempted)."""

    def __init__(self, enabled: bool):
        self.hit = False
        self._old = None
        self._installed = False
        if enabled:
            try:
                self._old = signal.signal(signal.SIGTERM, self._handler)
                self._installed = True
            except ValueError:
                pass

    def _handler(self, signum, frame):
        self.hit = True

    def restore(self) -> None:
        if self._installed:
            signal.signal(signal.SIGTERM, self._old)


def _canon(obj, h) -> None:
    """Feed a canonical byte stream of obj into hash h.

    repr() is avoided for arrays (truncation) and bare objects (id()); spec
    trees bottom out at dataclasses / ndarrays / primitives, with qualified
    names as the last resort for callables."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        h.update(type(obj).__name__.encode())
        for f in dataclasses.fields(obj):
            h.update(f.name.encode())
            _canon(getattr(obj, f.name), h)
    elif isinstance(obj, np.ndarray):
        h.update(str(obj.dtype).encode())
        h.update(str(obj.shape).encode())
        h.update(np.ascontiguousarray(obj).tobytes())
    elif isinstance(obj, (list, tuple)):
        h.update(b"[")
        for it in obj:
            _canon(it, h)
        h.update(b"]")
    elif isinstance(obj, dict):
        h.update(b"{")
        for k in sorted(obj):
            h.update(str(k).encode())
            _canon(obj[k], h)
        h.update(b"}")
    elif isinstance(obj, (bool, int, float, str, bytes)) or obj is None:
        h.update(repr(obj).encode())
    else:
        h.update(
            getattr(obj, "__qualname__", type(obj).__qualname__).encode()
        )


def _fingerprint(*parts) -> bytes:
    h = hashlib.sha256()
    for p in parts:
        _canon(p, h)
    return h.digest()


class _SweepCheckpointer:
    """Sweep state through CheckpointManager, keyed by an argument hash.

    Flat payload schema (``//``-joined keys, via restore_flat):
      meta//fingerprint      sha256 of (specs, solver params) as uint8
      meta//c_o              (N,) calibrated abstract costs, batch order
      meta//pending_idx/_smax  current round's unprocessed queue
      meta//next_idx/_smax     next round's regrow queue
      done//<idx>//{policy,g,h,iterations,span,converged,smax,c_o,ev,mu}
    """

    def __init__(self, directory, fingerprint: bytes, keep_last_k: int):
        from repro.checkpoint import CheckpointManager

        self.dir = directory
        self.mgr = CheckpointManager(directory, keep_last_k=keep_last_k)
        self.fp = fingerprint
        self.step = 0

    def load(self) -> Optional[dict]:
        step = self.mgr.latest_step()
        if step is None:
            return None
        flat = self.mgr.restore_flat()
        if bytes(bytearray(flat["meta//fingerprint"])) != self.fp:
            raise ValueError(
                f"checkpoint in {self.dir} was written by a different sweep "
                "(the specs or solver parameters changed); pass a fresh "
                "checkpoint_dir or re-run with the original arguments"
            )
        self.step = step + 1
        return flat

    def save(self, tree: dict) -> None:
        # async: the fsync+rename overlaps the next chunk's solve (the host
        # copy is taken synchronously, so later mutation is safe); wait()
        # is the commit barrier before SweepPreempted / return
        tree["meta"]["fingerprint"] = np.frombuffer(self.fp, dtype=np.uint8)
        self.mgr.save(self.step, tree, async_=True)
        self.step += 1

    def wait(self) -> None:
        self.mgr.wait()


def _round_plan(
    pending: List[tuple], chunk_size: Optional[int]
) -> List[List[tuple]]:
    """Chunked processing plan for one sweep round.

    Items are (idx, spec, ...) tuples.  Groups by truncation level
    (ascending), stably sorts each group along (rho, w2) — restored queues
    arrive pre-sorted, so ties keep their saved order — and splits groups
    into consecutive chunks.  The resume invariant rides on this shape: the
    unprocessed remainder of a round is a suffix of the flattened plan, and
    re-planning a suffix reproduces the same chunk boundaries."""
    plan: List[List[tuple]] = []
    for s_max in sorted({it[1].s_max for it in pending}):
        group = [it for it in pending if it[1].s_max == s_max]
        group.sort(key=lambda it: (it[1].rho, it[1].w2))
        step = len(group) if chunk_size is None else int(chunk_size)
        for k in range(0, len(group), step):
            plan.append(group[k : k + step])
    return plan


def _nan_eval(n_states: int) -> PolicyEval:
    """Placeholder eval for rows the guard ladder could not heal."""
    nan = float("nan")
    return PolicyEval(
        g=nan,
        delta=nan,
        w_bar=nan,
        p_bar=nan,
        mu=np.full(n_states, np.nan),
        mean_batch=nan,
        throughput=nan,
    )


def _eval_healthy(
    batch,
    policies: np.ndarray,
    healthy: np.ndarray,
    batched_eval: Callable,
    n_states: Callable[[SMDPSpec], int],
) -> List[PolicyEval]:
    """Evaluate only ladder-healthy rows; failed rows get NaN placeholders.

    evaluate_* rejects the garbage policies a failed row carries, so those
    rows are masked out of the batched stationary solve entirely and come
    back as all-NaN PolicyEvals (the sweep accepts them without regrowing)."""
    if healthy.all():
        return batched_eval(batch, policies)
    evs: List[Optional[PolicyEval]] = [None] * len(healthy)
    ok = [int(i) for i in np.flatnonzero(healthy)]
    if ok:
        sub = batched_eval(batch.take(ok), policies[np.asarray(ok)])
        for j, e in zip(ok, sub):
            evs[j] = e
    return [
        e if e is not None else _nan_eval(n_states(batch.specs[j]))
        for j, e in enumerate(evs)
    ]


def _pack_result(res) -> dict:
    """SolveResult / ModulatedSolveResult -> flat-array checkpoint record."""
    rvi, ev = res.rvi, res.eval
    return {
        "policy": np.asarray(rvi.policy),
        "g": np.asarray(rvi.g, dtype=np.float64),
        "h": np.asarray(rvi.h, dtype=np.float64),
        "iterations": np.asarray(rvi.iterations, dtype=np.int64),
        "span": np.asarray(rvi.span, dtype=np.float64),
        "converged": np.asarray(rvi.converged),
        "smax": np.asarray(res.spec.s_max, dtype=np.int64),
        "c_o": np.asarray(res.spec.c_o, dtype=np.float64),
        "ev": np.asarray(
            [ev.g, ev.delta, ev.w_bar, ev.p_bar, ev.mean_batch, ev.throughput],
            dtype=np.float64,
        ),
        "mu": np.asarray(ev.mu, dtype=np.float64),
    }


def _unpack_result(flat: dict, idx: int, base_spec: SMDPSpec):
    """Checkpoint record -> (spec, RVIResult, PolicyEval) for spec ``idx``.

    float64/int64 arrays round-trip npz losslessly, so restored results are
    bitwise-identical to the in-memory ones the checkpointed run held
    (wall_time_s excepted — it is not persisted and restores as 0)."""
    p = f"done//{idx}//"
    spec = dataclasses.replace(
        base_spec, s_max=int(flat[p + "smax"]), c_o=float(flat[p + "c_o"])
    )
    rvi = RVIResult(
        policy=flat[p + "policy"],
        g=float(flat[p + "g"]),
        h=flat[p + "h"],
        iterations=int(flat[p + "iterations"]),
        span=float(flat[p + "span"]),
        converged=bool(flat[p + "converged"]),
        wall_time_s=0.0,
    )
    e = flat[p + "ev"]
    ev = PolicyEval(
        g=float(e[0]),
        delta=float(e[1]),
        w_bar=float(e[2]),
        p_bar=float(e[3]),
        mu=flat[p + "mu"],
        mean_batch=float(e[4]),
        throughput=float(e[5]),
    )
    return spec, rvi, ev


def _sweep_state(
    results: list, remaining: list, next_round: list, c_os
) -> dict:
    """Checkpoint tree for the sweep loop's full solver state."""
    meta = {
        "pending_idx": np.asarray([it[0] for it in remaining], dtype=np.int64),
        "pending_smax": np.asarray(
            [it[1].s_max for it in remaining], dtype=np.int64
        ),
        "next_idx": np.asarray([it[0] for it in next_round], dtype=np.int64),
        "next_smax": np.asarray(
            [it[1].s_max for it in next_round], dtype=np.int64
        ),
    }
    if c_os is not None:
        meta["c_o"] = np.asarray(c_os, dtype=np.float64)
    done = {
        str(i): _pack_result(r) for i, r in enumerate(results) if r is not None
    }
    return {"meta": meta, "done": done}


def _restored_report(
    results: list, idxs: List[int], eps: float
) -> Tuple[SolveReport, List[int]]:
    """Synthesize a report part for checkpoint-restored specs.

    Health is recomputed from the restored arrays; the rung history of the
    previous process is not persisted, so restored specs contribute
    certificates but no rung attribution to the merged report."""
    span = np.array([results[i].rvi.span for i in idxs])
    conv = np.array([results[i].rvi.converged for i in idxs])
    healthy = np.array(
        [
            bool(c)
            and np.isfinite(results[i].rvi.g)
            and bool(np.isfinite(results[i].rvi.h).all())
            for i, c in zip(idxs, conv)
        ],
        dtype=bool,
    )
    rep = SolveReport(
        eps=eps,
        span=span,
        converged=conv,
        healthy=healthy,
        failed=[k for k in range(len(idxs)) if not healthy[k]],
    )
    return rep, idxs


#: below this batch width the anchor pre-solve costs more than it saves
_WARM_START_MIN = 6


def _warm_start_t(specs: Sequence[SMDPSpec], c_feat: np.ndarray) -> np.ndarray:
    """Per-spec interpolation coordinate t in [0, 1] along the anchor pair.

    The interpolation coordinate:

      * rho varies across the batch — project the normalized (rho, w2)
        parameter point onto the anchor segment (c_tilde is NOT affine in
        lambda: the arrival pmfs move with it, so cost-space projection
        would misplace lambda-swept specs);
      * rho constant (w2 / energy-profile sweeps) — project the cost
        features ``c_feat`` (finite c_tilde entries, flattened per spec)
        onto the anchor segment, which is exact for any parameter c_tilde
        is affine in, without knowing which one the caller swept.
    """
    rhos = np.array([sp.rho for sp in specs])
    w2s = np.array([sp.w2 for sp in specs])
    if abs(rhos[-1] - rhos[0]) > 1e-12:

        def norm(v):
            span = v[-1] - v[0]
            return (v - v[0]) / span if abs(span) > 1e-12 else np.zeros_like(v)

        theta = np.stack([norm(rhos), norm(w2s)], axis=1)  # (N, 2)
        d = theta[-1] - theta[0]
        return np.clip(theta @ d / float(d @ d), 0.0, 1.0)
    d = c_feat[-1] - c_feat[0]
    denom = float(d @ d)
    if denom <= 0.0:
        return np.zeros(len(specs))
    return np.clip((c_feat - c_feat[0]) @ d / denom, 0.0, 1.0)


def _anchor_warm_start(batch, eps: float, max_iter: int, **rvi_kw):
    """Interpolated h0 from solving the two end-of-batch anchor specs.

    Any h0 reaches the same fixed point — a good one just makes the
    batched RVI converge in far fewer lockstep iterations.  The batch is
    pre-sorted along (rho, w2) by sweep_solve, so the anchors are the
    extreme-rho specs and interpolation chains along the rho axis where
    mixing (and hence iteration count) is worst (coordinate: see
    _warm_start_t).
    """
    if batch.n_specs < _WARM_START_MIN:
        return None
    anchors = relative_value_iteration_batched(
        batch.take([0, batch.n_specs - 1]), eps=eps, max_iter=max_iter, **rvi_kw
    )
    mask = batch.feasible.all(axis=0)  # finite c_tilde in every spec
    t = _warm_start_t(batch.specs, batch.c_tilde[:, mask])
    return (1.0 - t)[:, None] * anchors.h[0] + t[:, None] * anchors.h[1]


def _anchor_warm_start_modulated(mbatch, eps: float, max_iter: int, **rvi_kw):
    """Modulated anchor warm start: h0 chains along rho per phase block.

    Identical discipline to _anchor_warm_start — the anchors are the
    extreme-(rho, w2) specs of the pre-sorted batch — with the (K, S)
    phase-blocked h interpolated jointly (every phase block shares the
    spec's interpolation coordinate, since the whole product chain moves
    with (rho, w2))."""
    if mbatch.n_specs < _WARM_START_MIN:
        return None
    anchors = relative_value_iteration_modulated(
        mbatch.take([0, mbatch.n_specs - 1]),
        eps=eps,
        max_iter=max_iter,
        **rvi_kw,
    )
    mask = mbatch.feasible.all(axis=0)  # (S, A) feasible in every spec
    c_feat = mbatch.c_tilde[:, :, mask].reshape(mbatch.n_specs, -1)
    t = _warm_start_t(mbatch.specs, c_feat)
    return (
        (1.0 - t)[:, None, None] * anchors.h[0]
        + t[:, None, None] * anchors.h[1]
    )


def sweep_solve(
    specs: Sequence[SMDPSpec],
    eps: float = 1e-2,
    max_iter: int = 10_000,
    delta: float = 1e-3,
    grow_factor: float = 1.5,
    max_s_max: int = 4096,
    auto_c_o: bool = True,
    accel: str = "auto",
    backup: str = "banded",
    guard: bool = True,
    report_sink: Optional[list] = None,
    checkpoint_dir: Optional[str] = None,
    chunk_size: Optional[int] = None,
    keep_last_k: int = 3,
) -> List[SolveResult]:
    """Batched equivalent of solve.solve() over a list of specs.

    Returns one SolveResult per input spec, in input order; each matches the
    serial solver's output for the same spec to solver tolerance.  Specs with
    differing s_max are padded to the batch maximum first.  Results carry no
    dense tensors — ``result.mdp`` materializes one lazily if accessed.

    ``accel`` / ``backup`` are forwarded to the batched RVI (rvi module
    docstring).  The default "auto" routes through accel="mpi" whenever the
    sweep reaches into the slow-mixing regime (any rho >=
    _ACCEL_RHO_THRESHOLD) — breaking the high-rho mixing wall (tens of
    backups instead of hundreds) while staying bit-identical in policy to
    the scalar float64 solve() oracle — and stays on the plain lockstep
    path for fast-mixing sweeps where the polish is pure overhead.  Pass
    accel="none"/"mpi"/"anderson" to force a path.

    ``guard`` (default on) runs every batched solve through the rvi
    guardrail ladder: a NaN/Inf-poisoned or diverging spec is degraded
    through slower solve paths (and ultimately a per-spec scalar
    quarantine) instead of failing the whole grid; rows the full ladder
    cannot heal come back with NaN evals rather than raising.  Healthy
    batches return bit-identical results either way.  Pass a list as
    ``report_sink`` to receive one merged rvi.SolveReport for the sweep
    (per-spec residual certificates + which fallback rungs fired).

    ``checkpoint_dir`` makes the sweep durable: progress is persisted after
    every ``chunk_size`` specs (default 16) via checkpoint.CheckpointManager,
    a SIGTERM saves-and-raises SweepPreempted, and re-running the identical
    call with the same directory resumes — producing bitwise-identical
    results to a never-interrupted checkpointed run (wall_time_s excepted).
    A checkpoint written by different specs/parameters is rejected by
    fingerprint.
    """
    specs = list(specs)
    flags = {sp.buffer is not None for sp in specs}
    if len(flags) > 1:
        raise ValueError(
            "sweep_solve cannot mix finite-buffer and tail-abstracted "
            "specs in one batch; solve the two families separately"
        )
    if flags and flags.pop():
        # finite-buffer solves: no abstract tail to calibrate, and Delta
        # is not a truncation error (B is physical) — never regrow
        auto_c_o = False
        delta = None
    specs = pad_specs(specs)
    if not specs:
        return []
    if accel == "auto":
        accel = (
            "mpi"
            if max(sp.rho for sp in specs) >= _ACCEL_RHO_THRESHOLD
            else "none"
        )
    # chain the work along rho (then w2) once, up front: the warm-start
    # anchors become the extreme-rho specs, where mixing is worst, and the
    # c_o probe batch can be reused (row-patched) as the first solve batch
    order = sorted(
        range(len(specs)), key=lambda i: (specs[i].rho, specs[i].w2)
    )
    ckpt = state = None
    if checkpoint_dir is not None:
        if chunk_size is None:
            chunk_size = _DEFAULT_CHUNK
        ckpt = _SweepCheckpointer(
            checkpoint_dir,
            _fingerprint(
                specs,
                dict(
                    kind="sweep_solve",
                    eps=eps,
                    max_iter=max_iter,
                    delta=delta,
                    grow_factor=grow_factor,
                    max_s_max=max_s_max,
                    auto_c_o=auto_c_o,
                    accel=accel,
                    backup=backup,
                    guard=guard,
                    chunk_size=chunk_size,
                ),
            ),
            keep_last_k,
        )
        state = ckpt.load()
    prebuilt = c_os = None
    if auto_c_o:
        if state is not None:
            c_os = state["meta//c_o"]
            base = [
                dataclasses.replace(specs[i], c_o=float(c))
                for i, c in zip(order, c_os)
            ]
        else:
            probe_batch = build_smdp_batched(
                [dataclasses.replace(specs[i], c_o=0.0) for i in order]
            )
            c_os = _greedy_c_o(probe_batch)
            patched = probe_batch.with_c_o(c_os)
            base = list(patched.specs)
            if ckpt is None:
                # resumable runs always rebuild chunk batches from specs,
                # so a resumed first round matches the one-shot bit-for-bit
                prebuilt = patched
    else:
        base = [specs[i] for i in order]
    pending = list(zip(order, base))
    results: List[SolveResult] = [None] * len(specs)  # type: ignore[list-item]
    report_parts: List[Tuple[SolveReport, List[int]]] = []
    next_round: List[tuple] = []
    if state is not None:
        base_by_idx = dict(pending)
        done_idxs = sorted(
            {int(k.split("//")[1]) for k in state if k.startswith("done//")}
        )
        for idx in done_idxs:
            sp, rvi, ev = _unpack_result(state, idx, base_by_idx[idx])
            results[idx] = SolveResult(spec=sp, rvi=rvi, eval=ev)
        if guard and done_idxs:
            report_parts.append(_restored_report(results, done_idxs, eps))
        pending = [
            (int(i), dataclasses.replace(base_by_idx[int(i)], s_max=int(s)))
            for i, s in zip(
                state["meta//pending_idx"], state["meta//pending_smax"]
            )
        ]
        next_round = [
            (int(i), dataclasses.replace(base_by_idx[int(i)], s_max=int(s)))
            for i, s in zip(state["meta//next_idx"], state["meta//next_smax"])
        ]
    rvi_kw = dict(accel=accel, backup=backup)
    preempt = _PreemptGuard(ckpt is not None)
    try:
        while pending or next_round:
            if not pending:
                pending, next_round = next_round, []
            plan = _round_plan(pending, chunk_size)
            for ci, chunk in enumerate(plan):
                if (
                    prebuilt is not None
                    and len(chunk) == prebuilt.n_specs
                    and all(
                        a is b for (_, a), b in zip(chunk, prebuilt.specs)
                    )
                ):
                    batch = prebuilt
                else:
                    batch = build_smdp_batched([sp for _, sp in chunk])
                rvi = relative_value_iteration_batched(
                    batch,
                    eps=eps,
                    max_iter=max_iter,
                    h0=_anchor_warm_start(batch, eps, max_iter, **rvi_kw),
                    guard=guard,
                    **rvi_kw,
                )
                if rvi.report is not None:
                    healthy = rvi.report.healthy
                    report_parts.append(
                        (rvi.report, [idx for idx, _ in chunk])
                    )
                else:
                    healthy = np.ones(len(chunk), dtype=bool)
                evs = _eval_healthy(
                    batch,
                    rvi.policies,
                    healthy,
                    evaluate_policy_batched,
                    lambda sp: sp.s_max + 1,
                )
                for row, (idx, sp) in enumerate(chunk):
                    ev = evs[row]
                    if not healthy[row]:
                        # ladder-exhausted row: keep the NaN-flagged result
                        # (growing the truncation cannot heal divergence)
                        results[idx] = SolveResult(
                            spec=sp, rvi=rvi.unstack(row), eval=ev
                        )
                    elif (
                        delta is None
                        or ev.delta < delta
                        or sp.s_max >= max_s_max
                    ):
                        results[idx] = SolveResult(
                            spec=sp, rvi=rvi.unstack(row), eval=ev
                        )
                    else:
                        next_round.append(
                            (
                                idx,
                                dataclasses.replace(
                                    sp,
                                    s_max=min(
                                        int(np.ceil(sp.s_max * grow_factor)),
                                        max_s_max,
                                    ),
                                ),
                            )
                        )
                if ckpt is not None:
                    remaining = [it for ch in plan[ci + 1 :] for it in ch]
                    ckpt.save(
                        _sweep_state(results, remaining, next_round, c_os)
                    )
                    if preempt.hit and (remaining or next_round):
                        ckpt.wait()  # the named step must be durable
                        raise SweepPreempted(checkpoint_dir, ckpt.step - 1)
            prebuilt = None
            pending, next_round = next_round, []
    finally:
        preempt.restore()
        if ckpt is not None:
            ckpt.wait()
    if report_sink is not None:
        report_sink.append(
            SolveReport.merged(report_parts, len(specs), eps)
            if report_parts
            else _restored_report(results, list(range(len(specs))), eps)[0]
        )
    return results


# ---------------------------------------------------------------------------
# Phase-modulated sweeps (exact MMPP-aware solves)
# ---------------------------------------------------------------------------


def _greedy_c_o_modulated(mbatch) -> np.ndarray:
    """Per-spec abstract cost c_o = max(100, 2 * g_greedy), modulated chain.

    The greedy policy is phase-independent (largest feasible batch now), so
    its (K, S) lift is the scalar table tiled across phases; gains come
    from the batched product-chain stationary solve."""
    K = mbatch.n_phases
    pols = np.stack(
        [
            np.tile(
                greedy_policy(sp.s_max, sp.b_min, sp.b_max)[None, :], (K, 1)
            )
            for sp in mbatch.specs
        ]
    )
    out = np.empty(mbatch.n_specs)
    try:
        evs = evaluate_policy_modulated_batched(mbatch, pols)
        for i, ev in enumerate(evs):
            out[i] = max(100.0, 2.0 * ev.g)
    except RuntimeError:
        for i in range(mbatch.n_specs):
            try:
                g = evaluate_policy_modulated(mbatch, i, pols[i]).g
            except RuntimeError:
                g = 100.0
            out[i] = max(100.0, 2.0 * g)
    return out


def sweep_solve_modulated(
    specs: Sequence[SMDPSpec],
    phases: Sequence[PhaseConfig],
    eps: float = 1e-2,
    max_iter: int = 10_000,
    delta: float = 1e-3,
    grow_factor: float = 1.5,
    max_s_max: int = 1024,
    auto_c_o: bool = True,
    accel: str = "auto",
    guard: bool = True,
    report_sink: Optional[list] = None,
    checkpoint_dir: Optional[str] = None,
    chunk_size: Optional[int] = None,
    keep_last_k: int = 3,
) -> List[ModulatedSolveResult]:
    """Batched exact MMPP-aware solves over aligned (spec, phases) pairs.

    The modulated mirror of sweep_solve: specs are padded to a shared
    s_max, sorted along (rho, w2) so anchor warm starts chain along the
    rho axis per phase block, the c_o = 0 probe batch calibrates every
    abstract cost with one batched product-chain stationary solve (then
    row-patched via with_c_o, never rebuilt), and the paper's adaptive
    truncation rule regrows only the specs whose Delta (summed over every
    phase's overflow state) still exceeds ``delta``.  Results return in
    input order; each carries the (K, S) phase-indexed policy.

    ``phases`` may be one shared PhaseConfig or a sequence aligned with
    ``specs``.  ``max_s_max`` defaults lower than the scalar sweep: the
    product chain is K x larger per state and the exact solves are meant
    for policy tables, not tail asymptotics.

    ``guard`` / ``report_sink`` / ``checkpoint_dir`` / ``chunk_size`` /
    ``keep_last_k`` behave exactly as in sweep_solve: guardrail-laddered
    solves by default, and with a checkpoint_dir the sweep is durable,
    SIGTERM-preemptible, and resumes bitwise-identically.
    """
    specs = list(specs)
    if not specs:
        return []
    if isinstance(phases, PhaseConfig):
        phases = [phases] * len(specs)
    phases = list(phases)
    if len(phases) != len(specs):
        raise ValueError(f"{len(phases)} phase configs for {len(specs)} specs")
    specs = pad_specs(specs)
    if accel == "auto":
        # the burst phase sets the mixing wall: key on max within-phase rho
        rho_z = max(phase_rho(sp, ph) for sp, ph in zip(specs, phases))
        accel = "mpi" if rho_z >= _ACCEL_RHO_THRESHOLD else "none"
    order = sorted(
        range(len(specs)), key=lambda i: (specs[i].rho, specs[i].w2)
    )
    ckpt = state = None
    if checkpoint_dir is not None:
        if chunk_size is None:
            chunk_size = _DEFAULT_CHUNK
        ckpt = _SweepCheckpointer(
            checkpoint_dir,
            _fingerprint(
                specs,
                phases,
                dict(
                    kind="sweep_solve_modulated",
                    eps=eps,
                    max_iter=max_iter,
                    delta=delta,
                    grow_factor=grow_factor,
                    max_s_max=max_s_max,
                    auto_c_o=auto_c_o,
                    accel=accel,
                    guard=guard,
                    chunk_size=chunk_size,
                ),
            ),
            keep_last_k,
        )
        state = ckpt.load()
    prebuilt = c_os = None
    if auto_c_o:
        if state is not None:
            c_os = state["meta//c_o"]
            base = [
                dataclasses.replace(specs[i], c_o=float(c))
                for i, c in zip(order, c_os)
            ]
        else:
            probe = build_smdp_modulated_batched(
                [dataclasses.replace(specs[i], c_o=0.0) for i in order],
                [phases[i] for i in order],
            )
            c_os = _greedy_c_o_modulated(probe)
            patched = probe.with_c_o(c_os)
            base = list(patched.specs)
            if ckpt is None:
                prebuilt = patched
    else:
        base = [specs[i] for i in order]
    pending = [(i, sp, phases[i]) for i, sp in zip(order, base)]
    results: List[ModulatedSolveResult] = [None] * len(specs)  # type: ignore[list-item]
    report_parts: List[Tuple[SolveReport, List[int]]] = []
    next_round: List[tuple] = []
    if state is not None:
        base_by_idx = {i: sp for i, sp, _ in pending}
        done_idxs = sorted(
            {int(k.split("//")[1]) for k in state if k.startswith("done//")}
        )
        for idx in done_idxs:
            sp, rvi, ev = _unpack_result(state, idx, base_by_idx[idx])
            results[idx] = ModulatedSolveResult(
                spec=sp, phases=phases[idx], rvi=rvi, eval=ev
            )
        if guard and done_idxs:
            report_parts.append(_restored_report(results, done_idxs, eps))
        pending = [
            (
                int(i),
                dataclasses.replace(base_by_idx[int(i)], s_max=int(s)),
                phases[int(i)],
            )
            for i, s in zip(
                state["meta//pending_idx"], state["meta//pending_smax"]
            )
        ]
        next_round = [
            (
                int(i),
                dataclasses.replace(base_by_idx[int(i)], s_max=int(s)),
                phases[int(i)],
            )
            for i, s in zip(state["meta//next_idx"], state["meta//next_smax"])
        ]
    rvi_kw = dict(accel=accel)
    preempt = _PreemptGuard(ckpt is not None)
    try:
        while pending or next_round:
            if not pending:
                pending, next_round = next_round, []
            plan = _round_plan(pending, chunk_size)
            for ci, chunk in enumerate(plan):
                if (
                    prebuilt is not None
                    and len(chunk) == prebuilt.n_specs
                    and all(
                        a is b for (_, a, _), b in zip(chunk, prebuilt.specs)
                    )
                ):
                    mbatch = prebuilt
                else:
                    mbatch = build_smdp_modulated_batched(
                        [sp for _, sp, _ in chunk],
                        [ph for _, _, ph in chunk],
                    )
                rvi = relative_value_iteration_modulated(
                    mbatch,
                    eps=eps,
                    max_iter=max_iter,
                    h0=_anchor_warm_start_modulated(
                        mbatch, eps, max_iter, **rvi_kw
                    ),
                    guard=guard,
                    **rvi_kw,
                )
                if rvi.report is not None:
                    healthy = rvi.report.healthy
                    report_parts.append(
                        (rvi.report, [idx for idx, _, _ in chunk])
                    )
                else:
                    healthy = np.ones(len(chunk), dtype=bool)
                evs = _eval_healthy(
                    mbatch,
                    rvi.policies,
                    healthy,
                    evaluate_policy_modulated_batched,
                    lambda sp: mbatch.n_phases * (sp.s_max + 1),
                )
                for row, (idx, sp, ph) in enumerate(chunk):
                    ev = evs[row]
                    if not healthy[row]:
                        results[idx] = ModulatedSolveResult(
                            spec=sp, phases=ph, rvi=rvi.unstack(row), eval=ev
                        )
                    elif (
                        delta is None
                        or ev.delta < delta
                        or sp.s_max >= max_s_max
                    ):
                        results[idx] = ModulatedSolveResult(
                            spec=sp, phases=ph, rvi=rvi.unstack(row), eval=ev
                        )
                    else:
                        next_round.append(
                            (
                                idx,
                                dataclasses.replace(
                                    sp,
                                    s_max=min(
                                        int(np.ceil(sp.s_max * grow_factor)),
                                        max_s_max,
                                    ),
                                ),
                                ph,
                            )
                        )
                if ckpt is not None:
                    remaining = [it for ch in plan[ci + 1 :] for it in ch]
                    ckpt.save(
                        _sweep_state(results, remaining, next_round, c_os)
                    )
                    if preempt.hit and (remaining or next_round):
                        ckpt.wait()  # the named step must be durable
                        raise SweepPreempted(checkpoint_dir, ckpt.step - 1)
            prebuilt = None
            pending, next_round = next_round, []
    finally:
        preempt.restore()
        if ckpt is not None:
            ckpt.wait()
    if report_sink is not None:
        report_sink.append(
            SolveReport.merged(report_parts, len(specs), eps)
            if report_parts
            else _restored_report(results, list(range(len(specs))), eps)[0]
        )
    return results


def solve_modulated(
    spec: SMDPSpec, phases: PhaseConfig, **kw
) -> ModulatedSolveResult:
    """Exact MMPP-aware solve of one spec (the N == 1 modulated sweep).

    ``spec.lam`` must equal ``phases.mean_rate`` (use smdp.modulated_spec).
    The K = 1 degenerate config reproduces the scalar solve() policy
    bit-for-bit — the refactor's safety rail, pinned by the test suite.
    """
    return sweep_solve_modulated([spec], phases, **kw)[0]
