"""Batched spec sweeps: solve a whole w2 / lambda / profile grid at once.

Every figure in the paper (Fig. 4/5/8/9, Table III) is a sweep over some
spec parameter.  Solving the points serially rebuilds dense (S, A, S)
tensors and re-dispatches RVI per point; here the grid is stacked into one
BatchedSMDP (smdp.build_smdp_batched) and solved by a single jitted,
vmapped banded-RVI while_loop (rvi.relative_value_iteration_batched).
Policy evaluation and the abstract-cost calibration run on the banded
transition structure too, so nothing on the sweep path is O(S^2) per spec.

The paper's adaptive truncation rule (Sec. V: accept when the tail
tolerance Delta^pi < delta, else grow s_max) is applied batch-wide: after
each batched solve only the specs whose Delta still exceeds delta are
regrown and re-solved together, so a sweep costs O(#rounds) jitted calls
instead of O(#specs x #rounds).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from .evaluate import evaluate_policy_banded
from .policies import greedy_policy
from .rvi import relative_value_iteration_batched
from .smdp import SMDPSpec, build_smdp_batched
from .solve import SolveResult


def sweep_bank(
    base: SMDPSpec,
    lams: Sequence[float],
    w2s: Optional[Sequence[float]] = None,
    **solve_kw,
):
    """Solve a lambda x w2 grid and return it as an SMDPSchedulerBank.

    The serving-side entry point for regime-adaptive scheduling: the bank's
    (lam, w2)-keyed action tables are what AdaptiveController retunes
    against as the observed arrival rate (or the energy price) drifts.
    ``w2s`` defaults to the base spec's w2 (a pure lambda grid).
    """
    from repro.serving.scheduler import SMDPScheduler

    lams = list(lams)
    w2s = [base.w2] if w2s is None else list(w2s)
    if len(lams) == 0 or len(w2s) == 0:
        raise ValueError("sweep_bank needs at least one lam and one w2")
    specs, keys = [], []
    for lam in lams:
        for w2 in w2s:
            specs.append(
                dataclasses.replace(base, lam=float(lam), w2=float(w2))
            )
            keys.append((float(lam), float(w2)))
    return SMDPScheduler.bank(sweep_solve(specs, **solve_kw), keys=keys)


def pad_specs(specs: Sequence[SMDPSpec]) -> List[SMDPSpec]:
    """Lift a mixed-truncation spec list to a shared s_max (batch padding).

    A larger truncation level only refines the approximation, so padding to
    the max is always sound.  b_max must already agree across specs — the
    action axis cannot be padded without changing feasible sets.
    """
    specs = list(specs)
    if not specs:
        return []
    b_maxes = {sp.b_max for sp in specs}
    if len(b_maxes) > 1:
        raise ValueError(f"sweep specs must share b_max; got {sorted(b_maxes)}")
    s_max = max(sp.s_max for sp in specs)
    return [
        sp if sp.s_max == s_max else dataclasses.replace(sp, s_max=s_max)
        for sp in specs
    ]


def resolve_abstract_cost_batched(
    specs: Sequence[SMDPSpec],
) -> List[SMDPSpec]:
    """Batched solve.resolve_abstract_cost: c_o = max(100, 2 * g_greedy).

    One banded batch build of the c_o = 0 probes calibrates every spec's
    abstract cost; specs whose greedy chain degenerates keep the paper
    default of 100 (same fallback as the serial resolver).
    """
    specs = list(specs)
    probes = [dataclasses.replace(sp, c_o=0.0) for sp in specs]
    batch = build_smdp_batched(probes)
    out = []
    for i, sp in enumerate(specs):
        pol = greedy_policy(sp.s_max, sp.b_min, sp.b_max)
        try:
            g = evaluate_policy_banded(batch, i, pol).g
        except RuntimeError:
            g = 100.0
        out.append(dataclasses.replace(sp, c_o=max(100.0, 2.0 * g)))
    return out


#: below this batch width the anchor pre-solve costs more than it saves
_WARM_START_MIN = 6


def _anchor_warm_start(batch, eps: float, max_iter: int):
    """Interpolated h0 from solving the two end-of-batch anchor specs.

    c_tilde is affine in the swept parameter for the common sweeps (w2,
    energy-profile scale), so each spec's relative values are well
    approximated by interpolating between the solved anchors; projecting
    the cost tensors onto the anchor segment recovers the interpolation
    coordinate without knowing which parameter the caller swept.  Any h0
    reaches the same fixed point — a good one just makes the batched RVI
    converge in far fewer lockstep iterations.
    """
    if batch.n_specs < _WARM_START_MIN:
        return None
    anchors = relative_value_iteration_batched(
        batch.take([0, batch.n_specs - 1]), eps=eps, max_iter=max_iter
    )
    mask = batch.feasible.all(axis=0)  # finite c_tilde in every spec
    c = batch.c_tilde[:, mask]
    d = c[-1] - c[0]
    denom = float(d @ d)
    if denom <= 0.0:
        t = np.zeros(batch.n_specs)
    else:
        t = np.clip((c - c[0]) @ d / denom, 0.0, 1.0)
    return (1.0 - t)[:, None] * anchors.h[0] + t[:, None] * anchors.h[1]


def sweep_solve(
    specs: Sequence[SMDPSpec],
    eps: float = 1e-2,
    max_iter: int = 10_000,
    delta: float = 1e-3,
    grow_factor: float = 1.5,
    max_s_max: int = 4096,
    auto_c_o: bool = True,
) -> List[SolveResult]:
    """Batched equivalent of solve.solve() over a list of specs.

    Returns one SolveResult per input spec, in input order; each matches the
    serial solver's output for the same spec to solver tolerance.  Specs with
    differing s_max are padded to the batch maximum first.  Results carry no
    dense tensors — ``result.mdp`` materializes one lazily if accessed.
    """
    specs = pad_specs(specs)
    if not specs:
        return []
    if auto_c_o:
        specs = resolve_abstract_cost_batched(specs)
    pending = list(enumerate(specs))
    results: List[SolveResult] = [None] * len(specs)  # type: ignore[list-item]
    while pending:
        # group by truncation level: re-grown specs share their new s_max
        levels = sorted({sp.s_max for _, sp in pending})
        still_pending = []
        for s_max in levels:
            group = [(i, sp) for i, sp in pending if sp.s_max == s_max]
            batch = build_smdp_batched([sp for _, sp in group])
            rvi = relative_value_iteration_batched(
                batch,
                eps=eps,
                max_iter=max_iter,
                h0=_anchor_warm_start(batch, eps, max_iter),
            )
            for row, (idx, sp) in enumerate(group):
                ev = evaluate_policy_banded(batch, row, rvi.policies[row])
                if delta is None or ev.delta < delta or sp.s_max >= max_s_max:
                    results[idx] = SolveResult(
                        spec=sp, rvi=rvi.unstack(row), eval=ev
                    )
                else:
                    still_pending.append(
                        (
                            idx,
                            dataclasses.replace(
                                sp,
                                s_max=min(
                                    int(np.ceil(sp.s_max * grow_factor)),
                                    max_s_max,
                                ),
                            ),
                        )
                    )
        pending = still_pending
    return results
