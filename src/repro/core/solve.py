"""High-level solver: build -> RVI -> tail-tolerance check (paper Sec. V).

Implements the paper's adaptive truncation rule: accept the approximation
when Delta^pi < delta, else grow s_max and re-solve.  The abstract cost c_o
is what keeps the accepted s_max small (Table II).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .evaluate import PolicyEval, evaluate_policy
from .rvi import (  # noqa: F401  (SolveReport re-exported: guardrail record)
    RVIResult,
    SolveReport,
    relative_value_iteration,
)
from .smdp import PhaseConfig, SMDPSpec, TruncatedSMDP, build_smdp


@dataclasses.dataclass
class SolveResult:
    spec: SMDPSpec
    rvi: RVIResult
    eval: PolicyEval
    # dense tensors are only needed by a few consumers; sweeps skip them
    _mdp: Optional[TruncatedSMDP] = None

    @property
    def mdp(self) -> TruncatedSMDP:
        """The dense truncated SMDP (materialized on first access)."""
        if self._mdp is None:
            self._mdp = build_smdp(self.spec)
        return self._mdp

    @property
    def policy(self) -> np.ndarray:
        return self.rvi.policy

    def action(self, s: int) -> int:
        """Infinite-state policy pi_eps (eq. 30)."""
        s_max = self.spec.s_max
        return int(self.policy[min(s, s_max)])

    def action_table(self, upto: Optional[int] = None) -> np.ndarray:
        """Dense lookup table for the serving scheduler."""
        upto = upto if upto is not None else self.spec.s_max
        return np.array([self.action(s) for s in range(upto + 1)], dtype=np.int64)


@dataclasses.dataclass
class ModulatedSolveResult:
    """Solved phase-modulated SMDP: (K, S) policy over the product chain.

    The serving-side contract mirrors SolveResult — ``action_table()``
    returns the dense lookup table, here a (K, upto+1) phase-indexed stack
    that SMDPScheduler / the compiled phase lane consume directly.
    """

    spec: SMDPSpec
    phases: PhaseConfig
    rvi: RVIResult  # policy / h carry the (K, S) layout
    eval: PolicyEval

    @property
    def policy(self) -> np.ndarray:
        return self.rvi.policy  # (K, S)

    def action(self, z: int, s: int) -> int:
        """Infinite-state extension per phase (eq. 30 within each block)."""
        s_max = self.spec.s_max
        return int(self.policy[z, min(s, s_max)])

    def action_table(self, upto: Optional[int] = None) -> np.ndarray:
        """(K, upto + 1) phase-indexed lookup stack for the serving layer."""
        upto = upto if upto is not None else self.spec.s_max
        K = self.phases.n_phases
        return np.array(
            [[self.action(z, s) for s in range(upto + 1)] for z in range(K)],
            dtype=np.int64,
        )


def resolve_abstract_cost(spec: SMDPSpec) -> SMDPSpec:
    """Scale-aware default for the abstract cost c_o (beyond-paper).

    The paper fixes c_o ~ 100 for its cost scale (w2 <= 15).  For large
    energy weights the tail-cost estimate must grow with the objective
    scale, or the truncated model prefers parking at S_o ("always wait" —
    the failure mode the paper reports for underestimated c_o).  We bound
    the optimal average cost by the greedy policy's cost and set
    c_o = 2 * g_greedy: parked-at-S_o then always looks worse than serving.
    """
    from .policies import greedy_policy

    probe = dataclasses.replace(spec, c_o=0.0)
    mdp0 = build_smdp(probe)
    try:
        g = evaluate_policy(
            mdp0, greedy_policy(spec.s_max, spec.b_min, spec.b_max)
        ).g
    except RuntimeError:
        g = 100.0
    return dataclasses.replace(spec, c_o=max(100.0, 2.0 * g))


def solve(
    spec: SMDPSpec,
    eps: float = 1e-2,
    max_iter: int = 10_000,
    delta: Optional[float] = 1e-3,
    grow_factor: float = 1.5,
    max_s_max: int = 4096,
    backup: str = "banded",
    auto_c_o: bool = True,
    accel: str = "none",
) -> SolveResult:
    """Solve the dynamic-batching SMDP; auto-grow s_max until Delta < delta.

    The default (accel="none") is the plain float64 lockstep loop — the
    exact oracle every accelerated path is tested against; accel="mpi" /
    "anderson" route through the accelerated machinery (rvi docstring).
    """
    cur = spec
    if cur.buffer is not None:
        # finite-buffer solve: no abstract tail to calibrate, and Delta is
        # not a truncation error (B is physical) — never regrow
        auto_c_o = False
        delta = None
    if auto_c_o:
        cur = resolve_abstract_cost(cur)
    while True:
        mdp = build_smdp(cur)
        res = relative_value_iteration(
            mdp, eps=eps, max_iter=max_iter, backup=backup, accel=accel
        )
        ev = evaluate_policy(mdp, res.policy)
        if delta is None or ev.delta < delta or cur.s_max >= max_s_max:
            return SolveResult(spec=cur, rvi=res, eval=ev, _mdp=mdp)
        cur = dataclasses.replace(
            cur, s_max=min(int(np.ceil(cur.s_max * grow_factor)), max_s_max)
        )
