"""Relative value iteration (Algorithm 1) and App.-F baselines (AVI / API).

The discrete-time backup is

    J_{i+1}(s) = min_{a in A_s} { c~(s,a) + sum_j m~(j|s,a) H_i(j) }      (29)
    H_{i+1}(s) = J_{i+1}(s) - J_{i+1}(s*)

with span-based stopping.  Two backup implementations:

  * dense  — einsum against the (S, A, S) transition tensor;
  * banded — exploits the transition structure m(j|s,a) = p^{[a]}_{j-s+a}:
             per action the backup is a windowed correlation of H with the
             arrival pmf, an O(A*S*K) computation instead of O(A*S^2).
             This is the form the Pallas TPU kernel (kernels/bellman.py)
             implements; here it doubles as its jnp oracle.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .smdp import SMDPSpec, TruncatedSMDP, build_smdp


@dataclasses.dataclass
class RVIResult:
    policy: np.ndarray  # (S,) batch-size action per truncated state
    g: float  # average expected cost per unit time (g~ = g^)
    h: np.ndarray  # (S,) relative value function of the DTMDP
    iterations: int
    span: float
    converged: bool
    wall_time_s: float


# ---------------------------------------------------------------------------
# Backups
# ---------------------------------------------------------------------------


def dense_backup(c_tilde: jnp.ndarray, m_tilde: jnp.ndarray, h: jnp.ndarray):
    """Q(s,a) = c~(s,a) + sum_j m~(j|s,a) h(j); infeasible entries are +inf."""
    return c_tilde + jnp.einsum("saj,j->sa", m_tilde, h)


def banded_backup(
    c_tilde: jnp.ndarray,  # (S, A), +inf at infeasible
    pmfs: jnp.ndarray,  # (A, K+1) arrival pmfs (row 0 unused)
    tails: jnp.ndarray,  # (A, T) overflow mass per base state t
    scale: jnp.ndarray,  # (S, A) eta / y(s, a)
    s_max: int,
    h: jnp.ndarray,  # (S,) with h[-1] = h(S_o)
):
    """Structured backup; mathematically equal to dense_backup.

    For a != 0 and base t = s - a:
        (M^ h)(s) = sum_{k=0}^{s_max - t} p^{[a]}_k h(t + k) + tail(a,t) h(S_o)
    For a == 0: (M^ h)(s) = h(min(s+1, s_max -> S_o)); S_o self-loops.
    Discretized:  Q = c~ + scale * (M^ h) + (1 - scale) * h(s).
    """
    S = h.shape[0]
    A = pmfs.shape[0]
    T = s_max + 1  # base states 0..s_max
    K = pmfs.shape[1] - 1
    # windowed H matrix: Hwin[t, k] = h[t + k] masked to t + k <= s_max
    t_idx = jnp.arange(T)[:, None]
    k_idx = jnp.arange(K + 1)[None, :]
    j = t_idx + k_idx
    valid = j <= s_max
    hwin = jnp.where(valid, h[jnp.minimum(j, s_max)], 0.0)
    # G[t, a] = sum_k pmfs[a, k] hwin[t, k]  -> correlation as a matmul (MXU!)
    G = hwin @ pmfs.T  # (T, A)
    G = G + tails.T * h[S - 1]  # overflow mass towards S_o
    # scatter to (S, A): for state s and action a, base t = s_val(s) - a
    s_val = jnp.minimum(jnp.arange(S), s_max)  # S_o behaves as s_max
    base = s_val[:, None] - jnp.arange(A)[None, :]  # (S, A); <0 -> infeasible
    base_c = jnp.clip(base, 0, s_max)
    mh_serve = G[base_c, jnp.arange(A)[None, :]]  # (S, A)
    # a == 0 column: next state s+1 (or S_o)
    nxt = jnp.where(jnp.arange(S) < s_max, jnp.arange(S) + 1, S - 1)
    mh_wait = h[nxt]
    mh = mh_serve.at[:, 0].set(mh_wait)
    q = c_tilde + scale * mh + (1.0 - scale) * h[:, None]
    return q


def pallas_backup(
    c_tilde, pmfs, tails, scale, s_max: int, h,
):
    """banded_backup with the windowed-matmul core on the Pallas TPU kernel.

    Identical math; the G[t,a] correlation runs in kernels/bellman.py
    (interpret mode on CPU).  Used by backup="pallas".
    """
    from repro.kernels import ops as kops

    S = h.shape[0]
    A = pmfs.shape[0]
    T = s_max + 1
    K = pmfs.shape[1]
    h_main = jnp.zeros(T + K, dtype=jnp.float32).at[:T].set(h[:T].astype(jnp.float32))
    G = kops.bellman_backup(h_main, pmfs, tails.T, h[S - 1])  # (T, A)
    G = G.astype(h.dtype)
    s_val = jnp.minimum(jnp.arange(S), s_max)
    base = s_val[:, None] - jnp.arange(A)[None, :]
    base_c = jnp.clip(base, 0, s_max)
    mh_serve = G[base_c, jnp.arange(A)[None, :]]
    nxt = jnp.where(jnp.arange(S) < s_max, jnp.arange(S) + 1, S - 1)
    mh = mh_serve.at[:, 0].set(h[nxt])
    return c_tilde + scale * mh + (1.0 - scale) * h[:, None]


#: in-window pmf mass below this is dropped by the banded backups; the
#: overflow tails stay exact, so the induced backup error is O(BAND_TOL * |h|)
BAND_TOL = 1e-14


def trimmed_band(pm: np.ndarray, tol: float = BAND_TOL) -> int:
    """Width of the pmf band holding all but ``tol`` of every action's mass.

    ``pm`` is (..., A, K+1) with a zero row for a = 0.  The correlation in
    the banded backup is O(S * A * band), so trimming the vanishing tail of
    the arrival pmfs (their support is concentrated around lam * l(a))
    directly cuts every RVI iteration's work.
    """
    serve = pm[..., 1:, :]
    width = int((serve.cumsum(-1) < 1.0 - tol).sum(-1).max()) + 2
    return min(width, pm.shape[-1])


def make_banded_inputs(mdp: TruncatedSMDP):
    """Precompute (pmfs, tails, scale) for banded_backup from a built SMDP."""
    spec = mdp.spec
    # truncate pmf columns to k <= s_max (k larger always lands in S_o)
    pm = mdp.arrival_pmfs[:, : spec.s_max + 1].copy()
    # tails[a, t] = 1 - sum_{k <= s_max - t} p_k: reversed cumulative mass
    csum = np.cumsum(pm, axis=-1)
    tails = np.maximum(0.0, 1.0 - csum[:, ::-1])
    tails[0, :] = 0.0
    scale = mdp.eta / mdp.y
    return (
        jnp.asarray(pm, dtype=jnp.float64),
        jnp.asarray(tails, dtype=jnp.float64),
        jnp.asarray(scale, dtype=jnp.float64),
    )


# ---------------------------------------------------------------------------
# RVI driver
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("max_iter", "backup_kind", "s_max"))
def _rvi_loop(
    c_tilde,
    m_tilde,
    pmfs,
    tails,
    scale,
    eps: float,
    eps_rel: float,
    max_iter: int,
    backup_kind: str,
    s_max: int,
    ref_state: int = 0,
):
    S = c_tilde.shape[0]

    def backup(h):
        if backup_kind == "dense":
            return dense_backup(c_tilde, m_tilde, h)
        if backup_kind == "pallas":
            return pallas_backup(c_tilde, pmfs, tails, scale, s_max, h)
        return banded_backup(c_tilde, pmfs, tails, scale, s_max, h)

    def cond(carry):
        i, h, span, g = carry
        # relative criterion: costs scale with w2, so a purely absolute span
        # threshold stalls convergence detection for large weights
        thresh = jnp.maximum(eps, eps_rel * jnp.abs(g))
        return jnp.logical_and(i < max_iter, span >= thresh)

    def body(carry):
        i, h, _, _ = carry
        q = backup(h)
        j = jnp.min(q, axis=1)
        g = j[ref_state]
        h_new = j - g
        diff = h_new - h
        span = jnp.max(diff) - jnp.min(diff)
        return i + 1, h_new, span, g

    h0 = jnp.zeros(S, dtype=c_tilde.dtype)
    i, h, span, g = jax.lax.while_loop(cond, body, (0, h0, jnp.inf, 0.0))
    q = backup(h)
    policy = jnp.argmin(q, axis=1)
    return policy, g, h, i, span


def relative_value_iteration(
    mdp: TruncatedSMDP,
    eps: float = 1e-2,
    max_iter: int = 10_000,
    backup: str = "banded",
    eps_rel: float = 2e-4,
) -> RVIResult:
    """Solve the discretized MDP; the policy is eps-optimal for the SMDP."""
    t0 = time.perf_counter()
    c_tilde = jnp.asarray(mdp.c_tilde)
    if backup == "dense":
        m_tilde = jnp.asarray(mdp.m_tilde)
        pmfs = tails = scale = jnp.zeros((1, 1))
    else:
        m_tilde = jnp.zeros((1, 1, 1))
        pmfs, tails, scale = make_banded_inputs(mdp)
    policy, g, h, it, span = _rvi_loop(
        c_tilde,
        m_tilde,
        pmfs,
        tails,
        scale,
        eps,
        eps_rel,
        max_iter,
        backup,
        mdp.spec.s_max,
    )
    policy = np.asarray(policy)
    it = int(it)
    return RVIResult(
        policy=policy,
        g=float(g),
        h=np.asarray(h),
        iterations=it,
        span=float(span),
        converged=it < max_iter,
        wall_time_s=time.perf_counter() - t0,
    )


# ---------------------------------------------------------------------------
# Batched RVI: one jitted while_loop solves a whole spec sweep
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BatchedRVIResult:
    """Per-spec RVI outputs for a BatchedSMDP, leading axis = spec."""

    policies: np.ndarray  # (N, S)
    g: np.ndarray  # (N,)
    h: np.ndarray  # (N, S)
    iterations: np.ndarray  # (N,) iteration at which each spec first converged
    span: np.ndarray  # (N,)
    converged: np.ndarray  # (N,) bool
    wall_time_s: float

    def unstack(self, i: int) -> RVIResult:
        return RVIResult(
            policy=self.policies[i],
            g=float(self.g[i]),
            h=self.h[i],
            iterations=int(self.iterations[i]),
            span=float(self.span[i]),
            converged=bool(self.converged[i]),
            wall_time_s=self.wall_time_s / len(self.g),
        )


@partial(jax.jit, static_argnames=("max_iter", "s_max"))
def _rvi_loop_batched(
    c_tilde,  # (N, S, A)
    pmfs,  # (N, A, K+1)
    tails,  # (N, A, T)
    scale,  # (N, S, A)
    eps: float,
    eps_rel: float,
    max_iter: int,
    s_max: int,
    h0=None,  # (N, S) warm start; zeros when None
    ref_state: int = 0,
):
    """Vectorized Algorithm 1: every spec runs the banded backup in lockstep.

    The loop stops when EVERY spec's span is below its (relative) threshold;
    already-converged specs keep refining, which only tightens their h.
    """
    N, S, _ = c_tilde.shape
    backup = jax.vmap(banded_backup, in_axes=(0, 0, 0, 0, None, 0))

    def thresh(g):
        return jnp.maximum(eps, eps_rel * jnp.abs(g))

    def cond(carry):
        i, h, span, g, _ = carry
        return jnp.logical_and(i < max_iter, jnp.any(span >= thresh(g)))

    def body(carry):
        i, h, _, _, it_conv = carry
        q = backup(c_tilde, pmfs, tails, scale, s_max, h)  # (N, S, A)
        j = jnp.min(q, axis=-1)
        g = j[:, ref_state]
        h_new = j - g[:, None]
        diff = h_new - h
        span = jnp.max(diff, axis=-1) - jnp.min(diff, axis=-1)
        it_conv = jnp.where((span < thresh(g)) & (it_conv < 0), i + 1, it_conv)
        return i + 1, h_new, span, g, it_conv

    if h0 is None:
        h0 = jnp.zeros((N, S), dtype=c_tilde.dtype)
    init = (
        0,
        jnp.asarray(h0, dtype=c_tilde.dtype),
        jnp.full((N,), jnp.inf, dtype=c_tilde.dtype),
        jnp.zeros((N,), dtype=c_tilde.dtype),
        jnp.full((N,), -1, dtype=jnp.int32),
    )
    i, h, span, g, it_conv = jax.lax.while_loop(cond, body, init)
    q = backup(c_tilde, pmfs, tails, scale, s_max, h)
    policies = jnp.argmin(q, axis=-1)
    it_conv = jnp.where(it_conv < 0, i, it_conv)
    return policies, g, h, i, span, it_conv


def relative_value_iteration_batched(
    batch,  # BatchedSMDP
    eps: float = 1e-2,
    max_iter: int = 10_000,
    eps_rel: float = 2e-4,
    h0: Optional[np.ndarray] = None,
    mixed_precision: bool = True,
) -> BatchedRVIResult:
    """Solve every spec of a BatchedSMDP with one jitted banded-RVI call.

    ``h0`` (N, S) warm-starts the relative values (any h0 converges to the
    same fixed point; a good one — e.g. interpolated from solved sweep
    anchors — just gets there in far fewer lockstep iterations).

    With ``mixed_precision`` the bulk of the lockstep runs in float32 —
    halving the per-iteration memory traffic — and a float64 polish loop
    finishes from the float32 fixed point; the float32 stopping thresholds
    are floored above single-precision resolution so the first phase can
    never stall, and the final policy/gain always comes from the float64
    backup.
    """
    t0 = time.perf_counter()
    pm = batch.pmfs_banded
    arrs = (
        np.asarray(batch.c_tilde),
        np.asarray(pm[:, :, : trimmed_band(pm)]),
        np.asarray(batch.tails),
        np.asarray(batch.scale),
    )
    s_max = batch.specs[0].s_max
    if mixed_precision:
        # the float32 phase cannot resolve pmf mass below its epsilon anyway,
        # so it runs on a narrower band than the float64 polish
        pm32 = pm[:, :, : trimmed_band(pm, tol=1e-8)]
        coarse = _rvi_loop_batched(
            jnp.asarray(arrs[0], jnp.float32),
            jnp.asarray(pm32, jnp.float32),
            jnp.asarray(arrs[2], jnp.float32),
            jnp.asarray(arrs[3], jnp.float32),
            max(eps, 1e-4),
            max(eps_rel, 1e-5),
            max_iter,
            s_max,
            h0=None if h0 is None else jnp.asarray(h0, jnp.float32),
        )
        h0 = np.asarray(coarse[2], np.float64)
        it_coarse = int(coarse[3])
    else:
        it_coarse = 0
    policies, g, h, it, span, it_conv = _rvi_loop_batched(
        *(jnp.asarray(a, jnp.float64) for a in arrs),
        eps,
        eps_rel,
        max_iter,
        s_max,
        h0=None if h0 is None else jnp.asarray(h0, jnp.float64),
    )
    g = np.asarray(g)
    span = np.asarray(span)
    return BatchedRVIResult(
        policies=np.asarray(policies),
        g=g,
        h=np.asarray(h),
        iterations=np.asarray(it_conv) + it_coarse,
        span=span,
        converged=span < np.maximum(eps, eps_rel * np.abs(g)),
        wall_time_s=time.perf_counter() - t0,
    )


# ---------------------------------------------------------------------------
# Appendix-F baselines: approximate value / policy iteration on the
# *untruncated* associated DTMDP with an expanding state window.
# ---------------------------------------------------------------------------


def _untruncated_arrays(spec: SMDPSpec, n_states: int):
    """c~, p_k, y for states 0..n_states-1 of the untruncated DTMDP."""
    big = dataclasses.replace(spec, s_max=max(n_states - 2, spec.b_max), c_o=0.0)
    mdp = build_smdp(big)
    return mdp


def avi(
    spec: SMDPSpec,
    n_outer: int = 400,
    n0: int = 8,
    growth: int = 1,
    eval_s_max: int = 160,
) -> RVIResult:
    """Thomas–Stengos Scheme I: VI with an expanding state window.

    Iteration i backs up states {0..n0 + growth*i}; values outside the
    current window are taken as the boundary value (h of the largest known
    state), which mirrors the scheme's 'latter states see fewer backups'.
    """
    t0 = time.perf_counter()
    n_final = n0 + growth * n_outer + spec.b_max + 2
    mdp = _untruncated_arrays(spec, n_final + 2)
    n_states = mdp.n_states  # n_final + 2 (incl. S_o)
    c = np.where(mdp.feasible, mdp.c_tilde, np.inf)[: n_final + 1]
    m = mdp.m_tilde[: n_final + 1, :, :]  # (n_final+1, A, n_states)
    h = np.zeros(n_states)
    g = 0.0
    for i in range(n_outer):
        n_i = min(n0 + growth * i, n_final)
        q = c[: n_i + 1] + np.einsum("saj,j->sa", m[: n_i + 1, :, :], h)
        j = np.min(q, axis=1)
        g = j[0]
        h[: n_i + 1] = j - g
    q = c + np.einsum("saj,j->sa", m, h)
    policy = np.argmin(q, axis=1)
    pol = policy[: eval_s_max + 2].copy()
    pol[-1] = pol[eval_s_max]  # overflow state mirrors s_max
    return RVIResult(
        policy=pol,
        g=float(g),
        h=h[: eval_s_max + 2],
        iterations=n_outer,
        span=float("nan"),
        converged=True,
        wall_time_s=time.perf_counter() - t0,
    )


def api(
    spec: SMDPSpec,
    n_outer: int = 12,
    inner_per_outer: int = 20,
    n0: int = 8,
    growth: int = 1,
    eval_s_max: int = 160,
) -> RVIResult:
    """Thomas–Stengos Scheme IV: policy iteration with AVI inner evaluation."""
    t0 = time.perf_counter()
    max_inner = sum(inner_per_outer * (i + 1) for i in range(n_outer))
    n_final = n0 + growth * max_inner + spec.b_max + 2
    mdp = _untruncated_arrays(spec, n_final + 2)
    n_states = mdp.n_states
    c = np.where(mdp.feasible, mdp.c_tilde, np.inf)[: n_final + 1]
    m = mdp.m_tilde[: n_final + 1, :, :]
    policy = np.zeros(n_final + 1, dtype=np.int64)  # initial: always wait
    h = np.zeros(n_states)
    g = 0.0
    step = 0
    for outer in range(n_outer):
        # inner: approximate evaluation of `policy` with expanding window
        for _ in range(inner_per_outer * (outer + 1)):
            n_i = min(n0 + growth * step, n_final)
            step += 1
            rows = np.arange(n_i + 1)
            cp = c[rows, policy[: n_i + 1]]
            mp = m[rows, policy[: n_i + 1], :]
            j = cp + mp @ h
            g = j[0]
            h[: n_i + 1] = j - g
        # improvement
        q = c + np.einsum("saj,j->sa", m, h)
        policy = np.argmin(q, axis=1)
    pol = policy[: eval_s_max + 2].copy()
    pol[-1] = pol[eval_s_max]
    return RVIResult(
        policy=pol,
        g=float(g),
        h=h[: eval_s_max + 2],
        iterations=step,
        span=float("nan"),
        converged=True,
        wall_time_s=time.perf_counter() - t0,
    )
