"""Relative value iteration (Algorithm 1), accelerants, and App.-F baselines.

The discrete-time backup is

    J_{i+1}(s) = min_{a in A_s} { c~(s,a) + sum_j m~(j|s,a) H_i(j) }      (29)
    H_{i+1}(s) = J_{i+1}(s) - J_{i+1}(s*)

with span-based stopping.  Backup implementations:

  * dense  — einsum against the (S, A, S) transition tensor;
  * banded — exploits the transition structure m(j|s,a) = p^{[a]}_{j-s+a}:
             per action the backup is a windowed correlation of H with the
             arrival pmf, an O(A*S*K) computation instead of O(A*S^2).
             This is the form the Pallas TPU kernel (kernels/bellman.py)
             implements; here it doubles as its jnp oracle.
  * pallas — the same banded math with the windowed-matmul core on the
             Pallas kernel; the batched loop dispatches one spec-batched
             kernel launch per lockstep iteration (bellman_banded_batched).

Acceleration (``accel=`` on both RVI entry points)
--------------------------------------------------

At rho >= 0.7 the embedded chain mixes slowly and plain RVI needs many
hundreds of lockstep backups.  Classical fixes fail here in a specific
way: the iteration only converges *modulo constants* (H is a relative
value function, fixed up to an additive shift), so the natural metric is
the span seminorm  sp(x) = max(x) - min(x), under which the backup is
nonexpansive.  Momentum and textbook Anderson mixing form affine
combinations of past iterates whose *constant components* differ —
J_{i+1}(s*) drifts from step to step — so the extrapolated step picks up
an uncontrolled shift plus a secant direction fitted in a norm the
operator does not contract; the result is the divergence observed on
this repo's high-rho sweeps.  Two principled accelerants are provided:

  * accel="mpi" — batched modified policy iteration: every ``period``
    backups freeze the greedy policy and polish H by the *exact*
    gauge-fixed policy-evaluation linear solve (evaluate.
    policy_matrix_banded / policy_eval_linear, vmapped across the spec
    batch).  A polish is accepted per spec only if its one-step span
    residual shrinks (and the linear solve was finite — multichain
    degeneracies reject safely), so the iteration can never do worse
    than plain RVI.
  * accel="anderson" — span-seminorm-safe Anderson: the secant history
    is built from gauge-fixed iterates (H pinned to H(s*) = 0 before
    every difference), the least-squares step is Tikhonov-regularized,
    and each candidate is evaluated by one extra backup: it is taken
    only where its span residual does not exceed the plain backup's
    (rejection restarts the history).  Gauge-fixing removes the
    constant drift; rejection restores the monotone span decrease that
    makes plain RVI converge.

Both run float64 single-phase (they need tens of backups, so the f32
lockstep phase of the plain path buys nothing) and finish with an exact
linear-solve gain for the final greedy policy.  The scalar f64
``solve()`` path stays the untouched oracle these are tested against.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .evaluate import (
    policy_eval_linear,
    policy_matrix_banded,
    policy_matrix_banded_modulated,
)
from .smdp import SMDPSpec, TruncatedSMDP, build_smdp, phase_rho

#: rho at which the MPI polish starts paying for itself — below it plain
#: lockstep converges in ~100 backups and the polish machinery (anchor
#: accel solve, linear solves, extra jit phases) is pure overhead; above
#: it mixing slows exponentially and MPI wins big.  Shared by every
#: accel="auto" decision (sweep_solve and the modulated loops).
ACCEL_RHO_THRESHOLD = 0.5


@dataclasses.dataclass
class RVIResult:
    policy: np.ndarray  # (S,) batch-size action per truncated state
    g: float  # average expected cost per unit time (g~ = g^)
    h: np.ndarray  # (S,) relative value function of the DTMDP
    iterations: int
    span: float
    converged: bool
    wall_time_s: float


# ---------------------------------------------------------------------------
# Backups
# ---------------------------------------------------------------------------


def dense_backup(c_tilde: jnp.ndarray, m_tilde: jnp.ndarray, h: jnp.ndarray):
    """Q(s,a) = c~(s,a) + sum_j m~(j|s,a) h(j); infeasible entries are +inf."""
    return c_tilde + jnp.einsum("saj,j->sa", m_tilde, h)


def banded_backup(
    c_tilde: jnp.ndarray,  # (S, A), +inf at infeasible
    pmfs: jnp.ndarray,  # (A, K+1) arrival pmfs (row 0 unused)
    tails: jnp.ndarray,  # (A, T) overflow mass per base state t
    scale: jnp.ndarray,  # (S, A) eta / y(s, a)
    s_max: int,
    h: jnp.ndarray,  # (S,) with h[-1] = h(S_o)
):
    """Structured backup; mathematically equal to dense_backup.

    For a != 0 and base t = s - a:
        (M^ h)(s) = sum_{k=0}^{s_max - t} p^{[a]}_k h(t + k) + tail(a,t) h(S_o)
    For a == 0: (M^ h)(s) = h(min(s+1, s_max -> S_o)); S_o self-loops.
    Discretized:  Q = c~ + scale * (M^ h) + (1 - scale) * h(s).
    """
    S = h.shape[0]
    A = pmfs.shape[0]
    T = s_max + 1  # base states 0..s_max
    K = pmfs.shape[1] - 1
    # windowed H matrix: Hwin[t, k] = h[t + k] masked to t + k <= s_max
    t_idx = jnp.arange(T)[:, None]
    k_idx = jnp.arange(K + 1)[None, :]
    j = t_idx + k_idx
    valid = j <= s_max
    hwin = jnp.where(valid, h[jnp.minimum(j, s_max)], 0.0)
    # G[t, a] = sum_k pmfs[a, k] hwin[t, k]  -> correlation as a matmul (MXU!)
    G = hwin @ pmfs.T  # (T, A)
    G = G + tails.T * h[S - 1]  # overflow mass towards S_o
    # scatter to (S, A): for state s and action a, base t = s_val(s) - a
    s_val = jnp.minimum(jnp.arange(S), s_max)  # S_o behaves as s_max
    base = s_val[:, None] - jnp.arange(A)[None, :]  # (S, A); <0 -> infeasible
    base_c = jnp.clip(base, 0, s_max)
    mh_serve = G[base_c, jnp.arange(A)[None, :]]  # (S, A)
    # a == 0 column: next state s+1 (or S_o)
    nxt = jnp.where(jnp.arange(S) < s_max, jnp.arange(S) + 1, S - 1)
    mh_wait = h[nxt]
    mh = mh_serve.at[:, 0].set(mh_wait)
    q = c_tilde + scale * mh + (1.0 - scale) * h[:, None]
    return q


def pallas_backup(
    c_tilde, pmfs, tails, scale, s_max: int, h,
):
    """banded_backup with the windowed-matmul core on the Pallas TPU kernel.

    Identical math; the G[t,a] correlation runs in kernels/bellman.py
    (interpret mode on CPU).  Used by backup="pallas".
    """
    from repro.kernels import ops as kops

    S = h.shape[0]
    A = pmfs.shape[0]
    T = s_max + 1
    K = pmfs.shape[1]
    h_main = jnp.zeros(T + K, dtype=jnp.float32).at[:T].set(h[:T].astype(jnp.float32))
    G = kops.bellman_backup(h_main, pmfs, tails.T, h[S - 1])  # (T, A)
    G = G.astype(h.dtype)
    s_val = jnp.minimum(jnp.arange(S), s_max)
    base = s_val[:, None] - jnp.arange(A)[None, :]
    base_c = jnp.clip(base, 0, s_max)
    mh_serve = G[base_c, jnp.arange(A)[None, :]]
    nxt = jnp.where(jnp.arange(S) < s_max, jnp.arange(S) + 1, S - 1)
    mh = mh_serve.at[:, 0].set(h[nxt])
    return c_tilde + scale * mh + (1.0 - scale) * h[:, None]


def pallas_backup_batched(c_tilde, pmfs, tails, scale, s_max: int, h):
    """Spec-batched banded backup on the Pallas kernel (one launch per step).

    Identical math to vmap(banded_backup); the G[n,t,a] correlation runs in
    kernels/bellman.py::bellman_banded_batched with the spec axis as a grid
    dimension.  The kernel core is float32 — the batched driver keeps the
    exact final policy extraction on the float64 jnp path regardless.

    c_tilde/scale: (N, S, A); pmfs: (N, A, K); tails: (N, A, T); h: (N, S).
    """
    from repro.kernels import ops as kops

    N, S, A = c_tilde.shape
    T = s_max + 1
    K = pmfs.shape[2]
    h_main = jnp.zeros((N, T + K), dtype=jnp.float32)
    h_main = h_main.at[:, :T].set(h[:, :T].astype(jnp.float32))
    G = kops.bellman_backup_batched(
        h_main, pmfs, tails.transpose(0, 2, 1), h[:, S - 1]
    )  # (N, T, A)
    G = G.astype(h.dtype)
    s_val = jnp.minimum(jnp.arange(S), s_max)
    base = s_val[:, None] - jnp.arange(A)[None, :]
    base_c = jnp.clip(base, 0, s_max)
    mh_serve = G[:, base_c, jnp.arange(A)[None, :]]  # (N, S, A)
    nxt = jnp.where(jnp.arange(S) < s_max, jnp.arange(S) + 1, S - 1)
    mh = mh_serve.at[:, :, 0].set(h[:, nxt])
    return c_tilde + scale * mh + (1.0 - scale) * h[:, :, None]


def _batched_backup(backup_kind: str):
    """The (N, S, A) Q-backup for the batched loops (trace-time dispatch)."""
    if backup_kind == "pallas":
        return pallas_backup_batched
    return jax.vmap(banded_backup, in_axes=(0, 0, 0, 0, None, 0))


#: in-window pmf mass below this is dropped by the banded backups; the
#: overflow tails stay exact, so the induced backup error is O(BAND_TOL * |h|)
BAND_TOL = 1e-14


def trimmed_band(pm: np.ndarray, tol: float = BAND_TOL) -> int:
    """Width of the pmf band holding all but ``tol`` of every action's mass.

    ``pm`` is (..., A, K+1) with a zero row for a = 0.  The correlation in
    the banded backup is O(S * A * band), so trimming the vanishing tail of
    the arrival pmfs (their support is concentrated around lam * l(a))
    directly cuts every RVI iteration's work.
    """
    serve = pm[..., 1:, :]
    width = int((serve.cumsum(-1) < 1.0 - tol).sum(-1).max()) + 2
    return min(width, pm.shape[-1])


def make_banded_inputs(mdp: TruncatedSMDP):
    """Precompute (pmfs, tails, scale) for banded_backup from a built SMDP."""
    spec = mdp.spec
    # truncate pmf columns to k <= s_max (k larger always lands in S_o)
    pm = mdp.arrival_pmfs[:, : spec.s_max + 1].copy()
    # tails[a, t] = 1 - sum_{k <= s_max - t} p_k: reversed cumulative mass
    csum = np.cumsum(pm, axis=-1)
    tails = np.maximum(0.0, 1.0 - csum[:, ::-1])
    tails[0, :] = 0.0
    scale = mdp.eta / mdp.y
    return (
        jnp.asarray(pm, dtype=jnp.float64),
        jnp.asarray(tails, dtype=jnp.float64),
        jnp.asarray(scale, dtype=jnp.float64),
    )


# ---------------------------------------------------------------------------
# RVI driver
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("max_iter", "backup_kind", "s_max"))
def _rvi_loop(
    c_tilde,
    m_tilde,
    pmfs,
    tails,
    scale,
    eps: float,
    eps_rel: float,
    max_iter: int,
    backup_kind: str,
    s_max: int,
    ref_state: int = 0,
):
    S = c_tilde.shape[0]

    def backup(h):
        if backup_kind == "dense":
            return dense_backup(c_tilde, m_tilde, h)
        if backup_kind == "pallas":
            return pallas_backup(c_tilde, pmfs, tails, scale, s_max, h)
        return banded_backup(c_tilde, pmfs, tails, scale, s_max, h)

    def cond(carry):
        i, h, span, g = carry
        # relative criterion: costs scale with w2, so a purely absolute span
        # threshold stalls convergence detection for large weights
        thresh = jnp.maximum(eps, eps_rel * jnp.abs(g))
        return jnp.logical_and(i < max_iter, span >= thresh)

    def body(carry):
        i, h, _, _ = carry
        q = backup(h)
        j = jnp.min(q, axis=1)
        g = j[ref_state]
        h_new = j - g
        diff = h_new - h
        span = jnp.max(diff) - jnp.min(diff)
        return i + 1, h_new, span, g

    h0 = jnp.zeros(S, dtype=c_tilde.dtype)
    i, h, span, g = jax.lax.while_loop(cond, body, (0, h0, jnp.inf, 0.0))
    q = backup(h)
    policy = jnp.argmin(q, axis=1)
    return policy, g, h, i, span


def relative_value_iteration(
    mdp: TruncatedSMDP,
    eps: float = 1e-2,
    max_iter: int = 10_000,
    backup: str = "banded",
    eps_rel: float = 2e-4,
    accel: str = "none",
    accel_period: int = 6,
    accel_memory: int = 5,
    accel_safeguard: bool = True,
) -> RVIResult:
    """Solve the discretized MDP; the policy is eps-optimal for the SMDP.

    ``accel`` ("none" | "mpi" | "anderson") routes through the accelerated
    batched machinery with N = 1 (see relative_value_iteration_batched);
    the default stays the plain loop — the exact oracle path of solve().
    """
    t0 = time.perf_counter()
    if accel != "none":
        if backup == "dense":
            raise ValueError("accelerated RVI requires a banded backup")
        pmfs, tails, scale = make_banded_inputs(mdp)
        pm_full = np.asarray(pmfs)  # (A, s_max+1) f64
        pm_trim = pm_full[:, : trimmed_band(pm_full)]
        policies, g, h, span, it_conv, _, _ = _run_accel(
            jnp.asarray(mdp.c_tilde, jnp.float64)[None],
            jnp.asarray(pm_trim, jnp.float64)[None],
            jnp.asarray(tails, jnp.float64)[None],
            jnp.asarray(scale, jnp.float64)[None],
            mdp.spec.s_max,
            eps,
            eps_rel,
            max_iter,
            accel,
            backup,
            None,
            accel_period,
            accel_memory,
            accel_safeguard,
        )
        span_f = float(span[0])
        g_f = float(g[0])
        return RVIResult(
            policy=policies[0],
            g=g_f,
            h=h[0],
            iterations=int(it_conv[0]),
            span=span_f,
            converged=span_f < max(eps, eps_rel * abs(g_f)),
            wall_time_s=time.perf_counter() - t0,
        )
    c_tilde = jnp.asarray(mdp.c_tilde)
    if backup == "dense":
        m_tilde = jnp.asarray(mdp.m_tilde)
        pmfs = tails = scale = jnp.zeros((1, 1))
    else:
        m_tilde = jnp.zeros((1, 1, 1))
        pmfs, tails, scale = make_banded_inputs(mdp)
    policy, g, h, it, span = _rvi_loop(
        c_tilde,
        m_tilde,
        pmfs,
        tails,
        scale,
        eps,
        eps_rel,
        max_iter,
        backup,
        mdp.spec.s_max,
    )
    policy = np.asarray(policy)
    it = int(it)
    return RVIResult(
        policy=policy,
        g=float(g),
        h=np.asarray(h),
        iterations=it,
        span=float(span),
        converged=it < max_iter,
        wall_time_s=time.perf_counter() - t0,
    )


# ---------------------------------------------------------------------------
# Batched RVI: one jitted while_loop solves a whole spec sweep
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BatchedRVIResult:
    """Per-spec RVI outputs for a BatchedSMDP, leading axis = spec."""

    policies: np.ndarray  # (N, S)
    g: np.ndarray  # (N,)
    h: np.ndarray  # (N, S)
    iterations: np.ndarray  # (N,) backup count at which each spec converged
    span: np.ndarray  # (N,)
    converged: np.ndarray  # (N,) bool
    wall_time_s: float
    accel: str = "none"  # which accelerant produced this result
    accel_accepts: Optional[np.ndarray] = None  # (N,) accepted accel steps
    accel_rejects: Optional[np.ndarray] = None  # (N,) span-increasing steps
    #   (taken when safeguard is off, refused when it is on)
    report: Optional["SolveReport"] = None  # guard=True attaches certificates

    def unstack(self, i: int) -> RVIResult:
        return RVIResult(
            policy=self.policies[i],
            g=float(self.g[i]),
            h=self.h[i],
            iterations=int(self.iterations[i]),
            span=float(self.span[i]),
            converged=bool(self.converged[i]),
            wall_time_s=self.wall_time_s / len(self.g),
        )


# ---------------------------------------------------------------------------
# Guardrail ladder: per-spec NaN/Inf sentinels + divergence detection, with
# an automatic fallback ladder so one pathological spec degrades to a slower
# solve path (or a per-spec quarantine re-solve) instead of poisoning the
# whole vmapped batch.  Enabled with guard=True on both batched entry points;
# core.sweep turns it on by default.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SolveReport:
    """Residual certificates + guardrail record of one batched solve.

    ``span`` against ``eps`` (with the relative floor already folded into
    ``converged``) is the per-spec convergence certificate.  A spec is
    ``healthy`` when its g/h are finite AND it converged — a non-finite or
    still-growing span residual at the iteration cap is how divergence
    shows up, so the two sentinels together cover NaN/Inf poisoning and
    span-residual divergence alike.  ``rungs`` maps each fallback rung
    that fired to the spec rows it was applied to (in the order tried);
    ``quarantined`` rows were masked out of the batch and re-solved
    through the scalar float64 oracle path; ``failed`` rows stayed
    unhealthy after the entire ladder (their outputs carry NaN/Inf — the
    batch still completes, callers decide what to do with those rows).
    """

    eps: float
    span: np.ndarray  # (N,) final span residuals
    converged: np.ndarray  # (N,) bool
    healthy: np.ndarray  # (N,) bool — finite g/h and converged
    rungs: Dict[str, List[int]] = dataclasses.field(default_factory=dict)
    quarantined: List[int] = dataclasses.field(default_factory=list)
    failed: List[int] = dataclasses.field(default_factory=list)

    @property
    def any_fired(self) -> bool:
        return bool(self.rungs) or bool(self.quarantined)

    @staticmethod
    def merged(
        parts: Sequence[Tuple["SolveReport", Sequence[int]]],
        n: int,
        eps: float,
    ) -> "SolveReport":
        """Fold per-batch reports into one n-spec report (sweep rounds).

        ``parts`` pairs each report with the caller-level index of every
        batch row; later parts overwrite earlier ones per spec (a regrown
        spec's final solve wins), and a spec counts as failed only if its
        LAST solve left it unhealthy.
        """
        span = np.full(n, np.nan)
        converged = np.zeros(n, dtype=bool)
        healthy = np.zeros(n, dtype=bool)
        rungs: Dict[str, List[int]] = {}
        quarantined: List[int] = []
        ever_failed: set = set()
        for rep, rows in parts:
            rows = list(rows)
            span[rows] = rep.span
            converged[rows] = rep.converged
            healthy[rows] = rep.healthy
            for name, applied in rep.rungs.items():
                rungs.setdefault(name, []).extend(rows[i] for i in applied)
            quarantined.extend(rows[i] for i in rep.quarantined)
            ever_failed.update(rows[i] for i in rep.failed)
        return SolveReport(
            eps=eps,
            span=span,
            converged=converged,
            healthy=healthy,
            rungs=rungs,
            quarantined=sorted(set(quarantined)),
            failed=sorted(i for i in ever_failed if not healthy[i]),
        )


def _spec_health(res: BatchedRVIResult) -> np.ndarray:
    """(N,) bool NaN/Inf sentinel + divergence check per spec."""
    g = np.asarray(res.g, dtype=np.float64)
    h = np.asarray(res.h, dtype=np.float64).reshape(g.shape[0], -1)
    finite = np.isfinite(g) & np.isfinite(h).all(axis=-1)
    return finite & np.asarray(res.converged, dtype=bool)


def _writable(res: BatchedRVIResult) -> BatchedRVIResult:
    """Copy the per-spec arrays so ladder rungs can patch rows in place."""
    return dataclasses.replace(
        res,
        policies=np.array(res.policies),
        g=np.array(res.g, dtype=np.float64),
        h=np.array(res.h, dtype=np.float64),
        iterations=np.array(res.iterations),
        span=np.array(res.span, dtype=np.float64),
        converged=np.array(res.converged, dtype=bool),
    )


def _patch_rows(
    res: BatchedRVIResult, sub: BatchedRVIResult, dst: np.ndarray, src: np.ndarray
) -> None:
    res.policies[dst] = np.asarray(sub.policies)[src]
    res.g[dst] = np.asarray(sub.g)[src]
    res.h[dst] = np.asarray(sub.h)[src]
    res.iterations[dst] = np.asarray(sub.iterations)[src]
    res.span[dst] = np.asarray(sub.span)[src]
    res.converged[dst] = np.asarray(sub.converged)[src]


def _guarded_batched(
    batch,
    eps: float,
    max_iter: int,
    eps_rel: float,
    h0,
    mixed_precision: bool,
    accel: str,
    backup: str,
    accel_kw: dict,
) -> BatchedRVIResult:
    """Guardrail ladder around the batched RVI (see SolveReport).

    Rung order mirrors likely-culprit order: the Pallas kernel falls back
    to the jnp banded backup, the accelerant (and any caller-supplied warm
    start — a poisoned anchor h0 turns every row NaN) falls back to the
    plain lockstep loop, mixed precision falls back to single-phase
    float64, and rows that survive all of that are quarantined: masked out
    and re-solved one by one through the scalar float64 oracle path.  Only
    the unhealthy rows ride each rung, so a healthy batch pays one numpy
    health check and nothing else.
    """

    def run(b, h0_, mp, ac, bk):
        return relative_value_iteration_batched(
            b,
            eps=eps,
            max_iter=max_iter,
            eps_rel=eps_rel,
            h0=h0_,
            mixed_precision=mp,
            accel=ac,
            backup=bk,
            **accel_kw,
        )

    res = run(batch, h0, mixed_precision, accel, backup)
    healthy = _spec_health(res)
    rungs: Dict[str, List[int]] = {}
    quarantined: List[int] = []
    failed: List[int] = []
    if not healthy.all():
        res = _writable(res)
        bad = np.flatnonzero(~healthy)
        ladder = []
        bk = backup
        if bk == "pallas":
            ladder.append(
                ("backup_banded", dict(mp=mixed_precision, ac=accel, bk="banded", drop_h0=False))
            )
            bk = "banded"
        if accel != "none" or h0 is not None:
            ladder.append(
                ("plain_restart", dict(mp=mixed_precision, ac="none", bk=bk, drop_h0=True))
            )
        if mixed_precision:
            ladder.append(
                ("float64", dict(mp=False, ac="none", bk=bk, drop_h0=True))
            )
        for name, opt in ladder:
            if bad.size == 0:
                break
            sub = batch.take([int(i) for i in bad])
            sub_h0 = (
                None
                if (opt["drop_h0"] or h0 is None)
                else np.asarray(h0)[bad]
            )
            sub_res = run(sub, sub_h0, opt["mp"], opt["ac"], opt["bk"])
            ok = _spec_health(sub_res)
            rungs[name] = [int(i) for i in bad]
            if ok.any():
                _patch_rows(res, sub_res, bad[ok], np.flatnonzero(ok))
            bad = bad[~ok]
        if bad.size:
            rungs["quarantine"] = [int(i) for i in bad]
            for i in bad:
                i = int(i)
                quarantined.append(i)
                oracle = relative_value_iteration(
                    build_smdp(batch.specs[i]),
                    eps=eps,
                    max_iter=max_iter,
                    backup="banded",
                    eps_rel=eps_rel,
                    accel="none",
                )
                if (
                    np.isfinite(oracle.g)
                    and np.isfinite(oracle.h).all()
                    and oracle.converged
                ):
                    res.policies[i] = oracle.policy
                    res.g[i] = oracle.g
                    res.h[i] = oracle.h
                    res.iterations[i] = oracle.iterations
                    res.span[i] = oracle.span
                    res.converged[i] = True
                else:
                    failed.append(i)
        healthy = _spec_health(res)
    return dataclasses.replace(
        res,
        report=SolveReport(
            eps=eps,
            span=np.asarray(res.span),
            converged=np.asarray(res.converged),
            healthy=healthy,
            rungs=rungs,
            quarantined=quarantined,
            failed=failed,
        ),
    )


def _guarded_modulated(
    mbatch,
    eps: float,
    max_iter: int,
    eps_rel: float,
    h0,
    accel: str,
    accel_period: int,
) -> BatchedRVIResult:
    """Guardrail ladder for the modulated batched RVI.

    Same discipline as _guarded_batched with the rungs that apply to the
    product chain (always float64, no Pallas backup): the MPI accelerant
    and any caller h0 fall back to the plain lockstep loop, and rows still
    unhealthy are quarantined into single-spec plain-f64 re-solves — the
    oracle path the K = 1 bitwise tests pin the modulated solver against.
    """

    def run(b, h0_, ac):
        return relative_value_iteration_modulated(
            b,
            eps=eps,
            max_iter=max_iter,
            eps_rel=eps_rel,
            h0=h0_,
            accel=ac,
            accel_period=accel_period,
        )

    res = run(mbatch, h0, accel)
    healthy = _spec_health(res)
    rungs: Dict[str, List[int]] = {}
    quarantined: List[int] = []
    failed: List[int] = []
    if not healthy.all():
        res = _writable(res)
        bad = np.flatnonzero(~healthy)
        if accel != "none" or h0 is not None:
            sub_res = run(mbatch.take([int(i) for i in bad]), None, "none")
            ok = _spec_health(sub_res)
            rungs["plain_restart"] = [int(i) for i in bad]
            if ok.any():
                _patch_rows(res, sub_res, bad[ok], np.flatnonzero(ok))
            bad = bad[~ok]
        if bad.size:
            rungs["quarantine"] = [int(i) for i in bad]
            for i in bad:
                i = int(i)
                quarantined.append(i)
                oracle = run(mbatch.take([i]), None, "none")
                if _spec_health(oracle)[0]:
                    _patch_rows(res, oracle, np.array([i]), np.array([0]))
                else:
                    failed.append(i)
        healthy = _spec_health(res)
    return dataclasses.replace(
        res,
        report=SolveReport(
            eps=eps,
            span=np.asarray(res.span),
            converged=np.asarray(res.converged),
            healthy=healthy,
            rungs=rungs,
            quarantined=quarantined,
            failed=failed,
        ),
    )


@partial(jax.jit, static_argnames=("max_iter", "s_max", "backup_kind"))
def _rvi_loop_batched(
    c_tilde,  # (N, S, A)
    pmfs,  # (N, A, K+1)
    tails,  # (N, A, T)
    scale,  # (N, S, A)
    eps: float,
    eps_rel: float,
    max_iter: int,
    s_max: int,
    h0=None,  # (N, S) warm start; zeros when None
    ref_state: int = 0,
    backup_kind: str = "banded",
):
    """Vectorized Algorithm 1: every spec runs the banded backup in lockstep.

    The loop stops when EVERY spec's span is below its (relative) threshold;
    already-converged specs keep refining, which only tightens their h.
    """
    N, S, _ = c_tilde.shape
    backup = _batched_backup(backup_kind)

    def thresh(g):
        return jnp.maximum(eps, eps_rel * jnp.abs(g))

    def cond(carry):
        i, h, span, g, _ = carry
        return jnp.logical_and(i < max_iter, jnp.any(span >= thresh(g)))

    def body(carry):
        i, h, _, _, it_conv = carry
        q = backup(c_tilde, pmfs, tails, scale, s_max, h)  # (N, S, A)
        j = jnp.min(q, axis=-1)
        g = j[:, ref_state]
        h_new = j - g[:, None]
        diff = h_new - h
        span = jnp.max(diff, axis=-1) - jnp.min(diff, axis=-1)
        it_conv = jnp.where((span < thresh(g)) & (it_conv < 0), i + 1, it_conv)
        return i + 1, h_new, span, g, it_conv

    if h0 is None:
        h0 = jnp.zeros((N, S), dtype=c_tilde.dtype)
    init = (
        0,
        jnp.asarray(h0, dtype=c_tilde.dtype),
        jnp.full((N,), jnp.inf, dtype=c_tilde.dtype),
        jnp.zeros((N,), dtype=c_tilde.dtype),
        jnp.full((N,), -1, dtype=jnp.int32),
    )
    i, h, span, g, it_conv = jax.lax.while_loop(cond, body, init)
    q = backup(c_tilde, pmfs, tails, scale, s_max, h)
    policies = jnp.argmin(q, axis=-1)
    it_conv = jnp.where(it_conv < 0, i, it_conv)
    return policies, g, h, i, span, it_conv


# ---------------------------------------------------------------------------
# Accelerated batched loops (see module docstring): modified policy
# iteration with a banded linear-solve polish, and span-safe Anderson.
# Both count *backups* (the dominant cost) in ``nb`` and record per-spec
# acceptance/rejection of the accelerated steps.
# ---------------------------------------------------------------------------


def _span(diff):
    return jnp.max(diff, axis=-1) - jnp.min(diff, axis=-1)


@partial(jax.jit, static_argnames=("max_iter", "s_max", "backup_kind", "period"))
def _rvi_loop_batched_mpi(
    c_tilde,
    pmfs,
    tails,
    scale,
    eps: float,
    eps_rel: float,
    max_iter: int,
    s_max: int,
    backup_kind: str = "banded",
    period: int = 10,
    h0=None,
    ref_state: int = 0,
):
    """Batched modified policy iteration: RVI backups + periodic exact polish.

    Every ``period`` backups the greedy policy is frozen and h is replaced
    by its exact gauge-fixed policy evaluation (one vmapped banded linear
    solve), followed by one verification backup.  The polish is accepted
    per spec only where it is finite and shrinks the span residual, and
    never touches specs that already converged (per-spec masking) — so the
    loop is at worst plain RVI plus an amortized O(S^3/period) overhead.
    """
    N, S, A = c_tilde.shape
    backup = _batched_backup(backup_kind)
    mat = jax.vmap(policy_matrix_banded, in_axes=(0, 0, 0, None, 0))
    lin = jax.vmap(policy_eval_linear, in_axes=(0, 0, None))

    def bell(h):
        q = backup(c_tilde, pmfs, tails, scale, s_max, h)
        j = jnp.min(q, axis=-1)
        g = j[:, ref_state]
        return q, j - g[:, None], g

    def thresh(g):
        return jnp.maximum(eps, eps_rel * jnp.abs(g))

    def with_polish(args):
        q, hb, span, g, conv, nb, acc, rej = args
        pol = jnp.argmin(q, axis=-1)
        m_pi = mat(pmfs, tails, scale, s_max, pol)
        c_pi = jnp.take_along_axis(c_tilde, pol[..., None], axis=-1)[..., 0]
        g_pol, h_pol = lin(c_pi, m_pi, ref_state)
        _, hb2, g2 = bell(h_pol)
        span2 = _span(hb2 - h_pol)
        ok = (
            jnp.isfinite(g_pol)
            & jnp.all(jnp.isfinite(h_pol), axis=-1)
            & (span2 < span)
            & ~conv
        )
        h_out = jnp.where(ok[:, None], hb2, hb)
        return (
            h_out,
            jnp.where(ok, span2, span),
            jnp.where(ok, g2, g),
            nb + 1,
            acc + ok,
            rej + (~ok & ~conv),
        )

    def no_polish(args):
        _, hb, span, g, _, nb, acc, rej = args
        return hb, span, g, nb, acc, rej

    def cond(carry):
        it, _, _, span, g, _, _, _ = carry
        return jnp.logical_and(it < max_iter, jnp.any(span >= thresh(g)))

    def body(carry):
        it, nb, h, _, _, it_conv, acc, rej = carry
        q, hb, g = bell(h)
        nb = nb + 1
        span = _span(hb - h)
        conv = span < thresh(g)
        h_out, span_out, g_out, nb, acc, rej = jax.lax.cond(
            (it + 1) % period == 0,
            with_polish,
            no_polish,
            (q, hb, span, g, conv, nb, acc, rej),
        )
        it_conv = jnp.where(
            (span_out < thresh(g_out)) & (it_conv < 0), nb, it_conv
        )
        return it + 1, nb, h_out, span_out, g_out, it_conv, acc, rej

    if h0 is None:
        h0 = jnp.zeros((N, S), dtype=c_tilde.dtype)
    zi = jnp.zeros((N,), dtype=jnp.int32)
    init = (
        0,
        0,
        jnp.asarray(h0, dtype=c_tilde.dtype),
        jnp.full((N,), jnp.inf, dtype=c_tilde.dtype),
        jnp.zeros((N,), dtype=c_tilde.dtype),
        jnp.full((N,), -1, dtype=jnp.int32),
        zi,
        zi,
    )
    _, nb, h, span, g, it_conv, acc, rej = jax.lax.while_loop(cond, body, init)
    # exact final policy extraction always on the float64 jnp banded path
    q = _batched_backup("banded")(c_tilde, pmfs, tails, scale, s_max, h)
    policies = jnp.argmin(q, axis=-1)
    it_conv = jnp.where(it_conv < 0, nb, it_conv)
    return policies, g, h, nb, span, it_conv, acc, rej


@partial(
    jax.jit,
    static_argnames=("max_iter", "s_max", "backup_kind", "memory", "safeguard"),
)
def _rvi_loop_batched_anderson(
    c_tilde,
    pmfs,
    tails,
    scale,
    eps: float,
    eps_rel: float,
    max_iter: int,
    s_max: int,
    backup_kind: str = "banded",
    memory: int = 5,
    safeguard: bool = True,
    h0=None,
    ref_state: int = 0,
    reg: float = 1e-8,
):
    """Span-seminorm-safe Anderson acceleration of the batched RVI.

    Each iteration extrapolates a candidate from the last ``memory``
    gauge-fixed secant pairs (Tikhonov-regularized least squares), then
    evaluates it with one backup and accepts it per spec only where its
    span residual does not exceed the current one — the nonexpansiveness
    bound the plain backup satisfies by construction — so the safeguarded
    iteration is monotone in span and can never diverge.  Rejected specs
    fall back to the plain gauge-fixed backup step (one shared extra
    backup, paid only on iterations where some spec rejects) and restart
    their history.  With an empty history the candidate IS the plain step,
    so the scheme needs no warm-up special case.  ``safeguard=False``
    always takes the finite candidate: the known-divergent textbook
    variant, kept for the regression test.
    """
    N, S, A = c_tilde.shape
    M = memory
    backup = _batched_backup(backup_kind)

    def bell(h):
        q = backup(c_tilde, pmfs, tails, scale, s_max, h)
        j = jnp.min(q, axis=-1)
        g = j[:, ref_state]
        return j - g[:, None], g

    def thresh(g):
        return jnp.maximum(eps, eps_rel * jnp.abs(g))

    def cond(carry):
        it, _, _, _, g, span, _, _, _, _, _, _ = carry
        return jnp.logical_and(it < max_iter, jnp.any(span >= thresh(g)))

    def body(carry):
        it, nb, h, r, g, span, it_conv, dh, dr, valid, acc, rej = carry
        # plain step: h + r is the gauge-fixed backup of h (already computed)
        h_pl = h + r
        # Anderson candidate: regularized secant over gauge-fixed history
        # (empty history -> gamma = 0 -> the candidate is the plain step)
        vm = valid[..., None]
        rm = jnp.where(vm, dr, 0.0)  # (N, M, S)
        gram = jnp.einsum("nms,nks->nmk", rm, rm)
        rhs = jnp.einsum("nms,ns->nm", rm, r)
        tr = jnp.trace(gram, axis1=-2, axis2=-1)
        lam = (reg * tr / M + 1e-30)[:, None, None] * jnp.eye(
            M, dtype=c_tilde.dtype
        )
        gamma = jnp.linalg.solve(gram + lam, rhs[..., None])[..., 0]  # (N, M)
        h_cand = h_pl - jnp.einsum("nm,nms->ns", gamma, jnp.where(vm, dh, 0.0) + rm)
        h_cand = h_cand - h_cand[:, ref_state][:, None]  # pin the gauge
        hb_c, g_c = bell(h_cand)
        r_c = hb_c - h_cand
        span_c = _span(r_c)
        nb = nb + 1
        has_hist = valid.any(axis=-1)
        finite = jnp.all(jnp.isfinite(h_cand) & jnp.isfinite(r_c), axis=-1)
        worse = span_c > span  # the step the safeguard exists to refuse
        if safeguard:
            take = finite & ~worse
        else:
            take = finite & (has_hist | ~worse)
        rej = rej + (has_hist & finite & worse)
        acc = acc + (take & has_hist)

        def fallback(nb):
            # some spec refused its candidate: one shared plain backup
            hb_pl, g_pl = bell(h_pl)
            return hb_pl - h_pl, g_pl, nb + 1

        r_pl, g_pl, nb = jax.lax.cond(
            jnp.all(take),
            lambda nb: (r_c, g_c, nb),  # unused values; no extra backup
            fallback,
            nb,
        )
        h_new = jnp.where(take[:, None], h_cand, h_pl)
        r_new = jnp.where(take[:, None], r_c, r_pl)
        g_new = jnp.where(take, g_c, g_pl)
        span_new = jnp.where(take, span_c, _span(r_new))
        # history update: safe-mode rejection restarts the window
        reset = ~take if safeguard else jnp.zeros_like(take)
        valid = jnp.where(reset[:, None], False, valid)
        slot = it % M
        dh = dh.at[:, slot].set(h_new - h)
        dr = dr.at[:, slot].set(r_new - r)
        valid = valid.at[:, slot].set(True)
        it_conv = jnp.where(
            (span_new < thresh(g_new)) & (it_conv < 0), nb, it_conv
        )
        return it + 1, nb, h_new, r_new, g_new, span_new, it_conv, dh, dr, valid, acc, rej

    if h0 is None:
        h0 = jnp.zeros((N, S), dtype=c_tilde.dtype)
    h0 = jnp.asarray(h0, dtype=c_tilde.dtype)
    hb0, g0 = bell(h0)
    r0 = hb0 - h0
    zi = jnp.zeros((N,), dtype=jnp.int32)
    init = (
        0,
        1,
        h0,
        r0,
        g0,
        _span(r0),
        jnp.full((N,), -1, dtype=jnp.int32),
        jnp.zeros((N, M, S), dtype=c_tilde.dtype),
        jnp.zeros((N, M, S), dtype=c_tilde.dtype),
        jnp.zeros((N, M), dtype=bool),
        zi,
        zi,
    )
    out = jax.lax.while_loop(cond, body, init)
    _, nb, h, _, g, span, it_conv, _, _, _, acc, rej = out
    q = _batched_backup("banded")(c_tilde, pmfs, tails, scale, s_max, h)
    policies = jnp.argmin(q, axis=-1)
    it_conv = jnp.where(it_conv < 0, nb, it_conv)
    return policies, g, h, nb, span, it_conv, acc, rej


@partial(jax.jit, static_argnames=("s_max",))
def _exact_gain(c_tilde, pmfs, tails, scale, s_max, policies, ref_state=0):
    """Exact (linear-solve) gain + relative values of frozen greedy policies."""
    m_pi = jax.vmap(policy_matrix_banded, in_axes=(0, 0, 0, None, 0))(
        pmfs, tails, scale, s_max, policies
    )
    c_pi = jnp.take_along_axis(c_tilde, policies[..., None], axis=-1)[..., 0]
    return jax.vmap(policy_eval_linear, in_axes=(0, 0, None))(
        c_pi, m_pi, ref_state
    )


def _run_accel(
    c_tilde,  # (N, S, A) f64
    pmfs,  # (N, A, Kb) f64, band-trimmed
    tails,  # (N, A, T) f64
    scale,  # (N, S, A) f64
    s_max: int,
    eps: float,
    eps_rel: float,
    max_iter: int,
    accel: str,
    backup: str,
    h0,
    period: int,
    memory: int,
    safeguard: bool,
):
    """Shared driver for the accelerated loops + exact final gain.

    Returns (policies, g, h, span, it_conv, accepts, rejects) as numpy.
    ``g`` / ``h`` are the exact linear-solve evaluation of the final greedy
    policy wherever that solve is finite (it always is for the unichain
    policies RVI converges to); the loop's own fixed-point estimates back
    them up otherwise.
    """
    loop_args = (c_tilde, pmfs, tails, scale, eps, eps_rel, max_iter, s_max)
    if accel == "mpi":
        out = _rvi_loop_batched_mpi(
            *loop_args, backup_kind=backup, period=period, h0=h0
        )
    elif accel == "anderson":
        out = _rvi_loop_batched_anderson(
            *loop_args,
            backup_kind=backup,
            memory=memory,
            safeguard=safeguard,
            h0=h0,
        )
    else:
        raise ValueError(f"unknown accel {accel!r}")
    policies, g, h, _, span, it_conv, acc, rej = out
    g_exact, h_exact = _exact_gain(c_tilde, pmfs, tails, scale, s_max, policies)
    ok = np.isfinite(np.asarray(g_exact)) & np.isfinite(
        np.asarray(h_exact)
    ).all(axis=-1)
    g = np.where(ok, np.asarray(g_exact), np.asarray(g))
    h = np.where(ok[:, None], np.asarray(h_exact), np.asarray(h))
    return (
        np.asarray(policies),
        g,
        h,
        np.asarray(span),
        np.asarray(it_conv),
        np.asarray(acc),
        np.asarray(rej),
    )


def relative_value_iteration_batched(
    batch,  # BatchedSMDP
    eps: float = 1e-2,
    max_iter: int = 10_000,
    eps_rel: float = 2e-4,
    h0: Optional[np.ndarray] = None,
    mixed_precision: bool = True,
    accel: str = "none",
    backup: str = "banded",
    accel_period: int = 6,
    accel_memory: int = 5,
    accel_safeguard: bool = True,
    guard: bool = False,
) -> BatchedRVIResult:
    """Solve every spec of a BatchedSMDP with one jitted banded-RVI call.

    ``h0`` (N, S) warm-starts the relative values (any h0 converges to the
    same fixed point; a good one — e.g. interpolated from solved sweep
    anchors — just gets there in far fewer lockstep iterations).

    ``accel`` selects the solve path (see the module docstring):
      * "none"     — plain lockstep RVI.  With ``mixed_precision`` the bulk
        runs in float32 — halving the per-iteration memory traffic — and a
        float64 polish loop finishes from the float32 fixed point; the
        float32 stopping thresholds are floored above single-precision
        resolution so the first phase can never stall.
      * "mpi"      — modified policy iteration: every ``accel_period``
        backups, a vmapped exact policy-evaluation linear solve polishes h
        (per-spec safeguarded).  The high-rho default of the sweep engine.
      * "anderson" — span-safe restarted Anderson with ``accel_memory``
        secant pairs; ``accel_safeguard=False`` exposes the unsafeguarded
        (divergent) textbook variant for tests.
    Accelerated paths run float64 single-phase; ``iterations`` counts
    Bellman backups (including safeguard verification backups) so plain
    and accelerated counts are directly comparable.

    ``backup`` ("banded" | "pallas") picks the lockstep backup kernel; the
    final policy extraction and the float64 polish phase always use the
    float64 jnp banded path, so policies are bit-stable across backends.

    ``guard=True`` wraps the solve in the guardrail ladder (NaN/Inf
    sentinels, divergence detection, pallas->banded / accel->plain /
    f32->f64 fallbacks, per-spec quarantine re-solves) and attaches a
    SolveReport to the result; healthy batches return results identical
    to guard=False.
    """
    if guard:
        return _guarded_batched(
            batch,
            eps=eps,
            max_iter=max_iter,
            eps_rel=eps_rel,
            h0=h0,
            mixed_precision=mixed_precision,
            accel=accel,
            backup=backup,
            accel_kw=dict(
                accel_period=accel_period,
                accel_memory=accel_memory,
                accel_safeguard=accel_safeguard,
            ),
        )
    t0 = time.perf_counter()
    pm = batch.pmfs_banded
    arrs = (
        np.asarray(batch.c_tilde),
        np.asarray(pm[:, :, : trimmed_band(pm)]),
        np.asarray(batch.tails),
        np.asarray(batch.scale),
    )
    s_max = batch.specs[0].s_max
    if accel != "none":
        acc = rej = None
        it_accel = 0
        if mixed_precision:
            # accelerated f32 coarse phase on the narrow band: the floored
            # thresholds (see below) keep it from stalling, the per-spec
            # safeguards absorb any f32 conditioning loss in the polish
            pm32 = pm[:, :, : trimmed_band(pm, tol=1e-8)]
            _, _, h32, span32, it_conv32, acc, rej = _run_accel(
                jnp.asarray(arrs[0], jnp.float32),
                jnp.asarray(pm32, jnp.float32),
                jnp.asarray(arrs[2], jnp.float32),
                jnp.asarray(arrs[3], jnp.float32),
                s_max,
                max(eps, 1e-4),
                max(eps_rel, 1e-5),
                max_iter,
                accel,
                backup,
                None if h0 is None else jnp.asarray(h0, jnp.float32),
                accel_period,
                accel_memory,
                accel_safeguard,
            )
            h0 = h32.astype(np.float64)
            it_accel = int(it_conv32.max())
            # float64 finish: plain lockstep from the f32 fixed point (a
            # handful of backups), exact gain from the final greedy policy
            f64 = tuple(jnp.asarray(a, jnp.float64) for a in arrs)
            policies, g, h, _, span, it_conv = _rvi_loop_batched(
                *f64, eps, eps_rel, max_iter, s_max, h0=jnp.asarray(h0)
            )
            g_exact, h_exact = _exact_gain(*f64[:4], s_max, policies)
            ok = np.isfinite(np.asarray(g_exact)) & np.isfinite(
                np.asarray(h_exact)
            ).all(axis=-1)
            g = np.where(ok, np.asarray(g_exact), np.asarray(g))
            h = np.where(ok[:, None], np.asarray(h_exact), np.asarray(h))
            policies = np.asarray(policies)
            span = np.asarray(span)
            it_conv = np.asarray(it_conv) + it_accel
            acc, rej = np.asarray(acc), np.asarray(rej)
        else:
            policies, g, h, span, it_conv, acc, rej = _run_accel(
                *(jnp.asarray(a, jnp.float64) for a in arrs),
                s_max,
                eps,
                eps_rel,
                max_iter,
                accel,
                backup,
                None if h0 is None else jnp.asarray(h0, jnp.float64),
                accel_period,
                accel_memory,
                accel_safeguard,
            )
        return BatchedRVIResult(
            policies=policies,
            g=g,
            h=h,
            iterations=it_conv,
            span=span,
            converged=span < np.maximum(eps, eps_rel * np.abs(g)),
            wall_time_s=time.perf_counter() - t0,
            accel=accel,
            accel_accepts=acc,
            accel_rejects=rej,
        )
    if mixed_precision:
        # the float32 phase cannot resolve pmf mass below its epsilon anyway,
        # so it runs on a narrower band than the float64 polish
        pm32 = pm[:, :, : trimmed_band(pm, tol=1e-8)]
        coarse = _rvi_loop_batched(
            jnp.asarray(arrs[0], jnp.float32),
            jnp.asarray(pm32, jnp.float32),
            jnp.asarray(arrs[2], jnp.float32),
            jnp.asarray(arrs[3], jnp.float32),
            max(eps, 1e-4),
            max(eps_rel, 1e-5),
            max_iter,
            s_max,
            h0=None if h0 is None else jnp.asarray(h0, jnp.float32),
            backup_kind=backup,
        )
        h0 = np.asarray(coarse[2], np.float64)
        it_coarse = int(coarse[3])
    else:
        it_coarse = 0
    policies, g, h, it, span, it_conv = _rvi_loop_batched(
        *(jnp.asarray(a, jnp.float64) for a in arrs),
        eps,
        eps_rel,
        max_iter,
        s_max,
        h0=None if h0 is None else jnp.asarray(h0, jnp.float64),
    )
    g = np.asarray(g)
    span = np.asarray(span)
    return BatchedRVIResult(
        policies=np.asarray(policies),
        g=g,
        h=np.asarray(h),
        iterations=np.asarray(it_conv) + it_coarse,
        span=span,
        converged=span < np.maximum(eps, eps_rel * np.abs(g)),
        wall_time_s=time.perf_counter() - t0,
    )


# ---------------------------------------------------------------------------
# Phase-modulated RVI: the same lockstep/MPI machinery on the (phase, queue)
# product chain.  h carries a (K, S) phase-blocked layout; the backup is the
# phase-coupled windowed correlation (one einsum against the K x K
# matrix-valued arrival pmfs), the wait column mixes phases through the
# arrival-phase matrix, and the MPI polish reuses policy_eval_linear on the
# (K*S, K*S) banded policy matrix.  Nothing is densified beyond that.
# ---------------------------------------------------------------------------


def banded_backup_modulated(
    c_tilde: jnp.ndarray,  # (K, S, A), +inf at infeasible
    pmfs: jnp.ndarray,  # (A, K, K, Kb) phase-coupled arrival pmfs
    tails: jnp.ndarray,  # (A, K, K, T) overflow mass per base state
    wait_m: jnp.ndarray,  # (K, K) arrival-phase matrix (a = 0)
    scale: jnp.ndarray,  # (K, S, A) eta / y
    s_max: int,
    h: jnp.ndarray,  # (K, S) with h[:, -1] = h(z, S_o)
):
    """Phase-blocked structured backup; K = 1 degenerates to banded_backup.

    For a != 0 and base t = s - a:
        (M^ h)(z, s) = sum_{w,k<=s_max-t} p^{[a]}_k[z,w] h(w, t+k)
                       + sum_w tail[a,z,w,t] h(w, S_o)
    For a == 0: (M^ h)(z, s) = sum_w wait_m[z,w] h(w, min(s+1 -> S_o)).
    Discretized:  Q = c~ + scale * (M^ h) + (1 - scale) * h(z, s).
    """
    K, S, A = c_tilde.shape
    T = s_max + 1
    Kb = pmfs.shape[-1]
    t_idx = jnp.arange(T)[:, None]
    k_idx = jnp.arange(Kb)[None, :]
    j = t_idx + k_idx
    valid = j <= s_max
    hwin = jnp.where(valid[None], h[:, jnp.minimum(j, s_max)], 0.0)  # (K,T,Kb)
    # G[z, t, a] = sum_{w, k} pmfs[a, z, w, k] hwin[w, t, k]  (phase-coupled
    # correlation; the K = 1 slice is exactly banded_backup's hwin @ pmfs.T)
    G = jnp.einsum("azwk,wtk->zta", pmfs, hwin)
    G = G + jnp.einsum("azwt,w->zta", tails, h[:, S - 1])
    s_val = jnp.minimum(jnp.arange(S), s_max)
    base = jnp.clip(s_val[:, None] - jnp.arange(A)[None, :], 0, s_max)  # (S,A)
    mh_serve = G[:, base, jnp.arange(A)[None, :]]  # (K, S, A)
    nxt = jnp.where(jnp.arange(S) < s_max, jnp.arange(S) + 1, S - 1)
    mh_wait = wait_m @ h[:, nxt]  # (K, S)
    mh = mh_serve.at[:, :, 0].set(mh_wait)
    return c_tilde + scale * mh + (1.0 - scale) * h[:, :, None]


def trimmed_band_modulated(pm: np.ndarray, tol: float = BAND_TOL) -> int:
    """Band width holding all but ``tol`` of every (action, phase) row.

    ``pm`` is (N, A, K, K, T); the row mass sums over end phases w.  The
    overflow tails stay full-width (exact), so trimming only drops in-band
    mass below ``tol`` — the same guarantee as trimmed_band.
    """
    row = pm[:, 1:].sum(axis=3)  # (N, A-1, K, T): mass per (a, z) over w
    tot = row.sum(axis=-1, keepdims=True)
    width = int((np.cumsum(row, axis=-1) < tot - tol).sum(-1).max()) + 2
    return min(width, pm.shape[-1])


def _span_flat(diff):
    d = diff.reshape(diff.shape[0], -1)
    return jnp.max(d, axis=-1) - jnp.min(d, axis=-1)


@partial(jax.jit, static_argnames=("max_iter", "s_max"))
def _rvi_loop_modulated(
    c_tilde,  # (N, K, S, A)
    pmfs,  # (N, A, K, K, Kb)
    tails,  # (N, A, K, K, T)
    wait_m,  # (N, K, K)
    scale,  # (N, K, S, A)
    eps: float,
    eps_rel: float,
    max_iter: int,
    s_max: int,
    h0=None,  # (N, K, S) warm start
):
    """Vectorized lockstep RVI on the product chain (gauge at (z=0, s=0))."""
    N, K, S, _ = c_tilde.shape
    backup = jax.vmap(banded_backup_modulated, in_axes=(0, 0, 0, 0, 0, None, 0))

    def thresh(g):
        return jnp.maximum(eps, eps_rel * jnp.abs(g))

    def cond(carry):
        i, h, span, g, _ = carry
        return jnp.logical_and(i < max_iter, jnp.any(span >= thresh(g)))

    def body(carry):
        i, h, _, _, it_conv = carry
        q = backup(c_tilde, pmfs, tails, wait_m, scale, s_max, h)
        j = jnp.min(q, axis=-1)  # (N, K, S)
        g = j[:, 0, 0]
        h_new = j - g[:, None, None]
        span = _span_flat(h_new - h)
        it_conv = jnp.where((span < thresh(g)) & (it_conv < 0), i + 1, it_conv)
        return i + 1, h_new, span, g, it_conv

    if h0 is None:
        h0 = jnp.zeros((N, K, S), dtype=c_tilde.dtype)
    init = (
        0,
        jnp.asarray(h0, dtype=c_tilde.dtype),
        jnp.full((N,), jnp.inf, dtype=c_tilde.dtype),
        jnp.zeros((N,), dtype=c_tilde.dtype),
        jnp.full((N,), -1, dtype=jnp.int32),
    )
    i, h, span, g, it_conv = jax.lax.while_loop(cond, body, init)
    q = backup(c_tilde, pmfs, tails, wait_m, scale, s_max, h)
    policies = jnp.argmin(q, axis=-1)
    it_conv = jnp.where(it_conv < 0, i, it_conv)
    return policies, g, h, i, span, it_conv


@partial(jax.jit, static_argnames=("max_iter", "s_max", "period"))
def _rvi_loop_modulated_mpi(
    c_tilde,
    pmfs,
    tails,
    wait_m,
    scale,
    eps: float,
    eps_rel: float,
    max_iter: int,
    s_max: int,
    period: int = 6,
    h0=None,
):
    """Modulated modified policy iteration: lockstep + periodic exact polish.

    The polish freezes the greedy (K, S) policy and replaces h by its exact
    gauge-fixed evaluation on the (K*S, K*S) banded policy matrix — same
    per-spec safeguard discipline as _rvi_loop_batched_mpi (accepted only
    where finite and span-shrinking), so it can never do worse than plain
    lockstep on the product chain.
    """
    N, K, S, A = c_tilde.shape
    backup = jax.vmap(banded_backup_modulated, in_axes=(0, 0, 0, 0, 0, None, 0))
    mat = jax.vmap(
        policy_matrix_banded_modulated, in_axes=(0, 0, 0, 0, None, 0)
    )
    lin = jax.vmap(policy_eval_linear, in_axes=(0, 0, None))

    def bell(h):
        q = backup(c_tilde, pmfs, tails, wait_m, scale, s_max, h)
        j = jnp.min(q, axis=-1)
        g = j[:, 0, 0]
        return q, j - g[:, None, None], g

    def thresh(g):
        return jnp.maximum(eps, eps_rel * jnp.abs(g))

    def with_polish(args):
        q, hb, span, g, conv, nb, acc, rej = args
        pol = jnp.argmin(q, axis=-1)  # (N, K, S)
        m_pi = mat(pmfs, tails, wait_m, scale, s_max, pol)
        c_pi = jnp.take_along_axis(c_tilde, pol[..., None], axis=-1)[
            ..., 0
        ].reshape(N, K * S)
        g_pol, h_pol_flat = lin(c_pi, m_pi, 0)
        h_pol = h_pol_flat.reshape(N, K, S)
        _, hb2, g2 = bell(h_pol)
        span2 = _span_flat(hb2 - h_pol)
        ok = (
            jnp.isfinite(g_pol)
            & jnp.all(jnp.isfinite(h_pol_flat), axis=-1)
            & (span2 < span)
            & ~conv
        )
        h_out = jnp.where(ok[:, None, None], hb2, hb)
        return (
            h_out,
            jnp.where(ok, span2, span),
            jnp.where(ok, g2, g),
            nb + 1,
            acc + ok,
            rej + (~ok & ~conv),
        )

    def no_polish(args):
        _, hb, span, g, _, nb, acc, rej = args
        return hb, span, g, nb, acc, rej

    def cond(carry):
        it, _, _, span, g, _, _, _ = carry
        return jnp.logical_and(it < max_iter, jnp.any(span >= thresh(g)))

    def body(carry):
        it, nb, h, _, _, it_conv, acc, rej = carry
        q, hb, g = bell(h)
        nb = nb + 1
        span = _span_flat(hb - h)
        conv = span < thresh(g)
        h_out, span_out, g_out, nb, acc, rej = jax.lax.cond(
            (it + 1) % period == 0,
            with_polish,
            no_polish,
            (q, hb, span, g, conv, nb, acc, rej),
        )
        it_conv = jnp.where(
            (span_out < thresh(g_out)) & (it_conv < 0), nb, it_conv
        )
        return it + 1, nb, h_out, span_out, g_out, it_conv, acc, rej

    if h0 is None:
        h0 = jnp.zeros((N, K, S), dtype=c_tilde.dtype)
    zi = jnp.zeros((N,), dtype=jnp.int32)
    init = (
        0,
        0,
        jnp.asarray(h0, dtype=c_tilde.dtype),
        jnp.full((N,), jnp.inf, dtype=c_tilde.dtype),
        jnp.zeros((N,), dtype=c_tilde.dtype),
        jnp.full((N,), -1, dtype=jnp.int32),
        zi,
        zi,
    )
    _, nb, h, span, g, it_conv, acc, rej = jax.lax.while_loop(cond, body, init)
    q = jax.vmap(banded_backup_modulated, in_axes=(0, 0, 0, 0, 0, None, 0))(
        c_tilde, pmfs, tails, wait_m, scale, s_max, h
    )
    policies = jnp.argmin(q, axis=-1)
    it_conv = jnp.where(it_conv < 0, nb, it_conv)
    return policies, g, h, nb, span, it_conv, acc, rej


@partial(jax.jit, static_argnames=("s_max",))
def _exact_gain_modulated(
    c_tilde, pmfs, tails, wait_m, scale, s_max, policies, ref_state=0
):
    """Exact linear-solve gain + relative values of frozen (K, S) policies."""
    N, K, S, _ = c_tilde.shape
    m_pi = jax.vmap(
        policy_matrix_banded_modulated, in_axes=(0, 0, 0, 0, None, 0)
    )(pmfs, tails, wait_m, scale, s_max, policies)
    c_pi = jnp.take_along_axis(c_tilde, policies[..., None], axis=-1)[
        ..., 0
    ].reshape(N, K * S)
    g, h = jax.vmap(policy_eval_linear, in_axes=(0, 0, None))(
        c_pi, m_pi, ref_state
    )
    return g, h.reshape(N, K, S)


def relative_value_iteration_modulated(
    mbatch,  # ModulatedBatchedSMDP
    eps: float = 1e-2,
    max_iter: int = 10_000,
    eps_rel: float = 2e-4,
    h0: Optional[np.ndarray] = None,
    accel: str = "auto",
    accel_period: int = 6,
    guard: bool = False,
) -> BatchedRVIResult:
    """Solve every spec of a ModulatedBatchedSMDP (one jitted call, f64).

    Returns a BatchedRVIResult whose per-spec policy/h carry the (K, S)
    phase-blocked layout.  ``accel`` in {"none", "mpi", "auto"}; "auto"
    routes through the MPI polish once any spec's *within-phase* traffic
    intensity reaches the sweep threshold (bursty phases mix slowly even
    when the mean rho is small — the burst phase sets the wall, so the
    decision keys on max_z rho_z, not on the mean).  Modulated solves run
    float64 single-phase: product chains are small (K*S states) and the
    mixed-precision coarse loop buys nothing at these sizes.  g/h are
    replaced by the exact linear-solve evaluation of the final greedy
    policy wherever that solve is finite, exactly like the accelerated
    scalar paths.  ``guard=True`` wraps the solve in the guardrail ladder
    (see relative_value_iteration_batched) and attaches a SolveReport.
    """
    if guard:
        return _guarded_modulated(
            mbatch,
            eps=eps,
            max_iter=max_iter,
            eps_rel=eps_rel,
            h0=h0,
            accel=accel,
            accel_period=accel_period,
        )
    t0 = time.perf_counter()
    pm = mbatch.pmfs_banded
    band = trimmed_band_modulated(pm)
    args = (
        jnp.asarray(mbatch.c_tilde, jnp.float64),
        jnp.asarray(pm[..., :band], jnp.float64),
        jnp.asarray(mbatch.tails, jnp.float64),
        jnp.asarray(mbatch.wait_m, jnp.float64),
        jnp.asarray(mbatch.scale, jnp.float64),
    )
    s_max = mbatch.s_max
    if accel == "auto":
        rho_z = max(
            phase_rho(sp, ph) for sp, ph in zip(mbatch.specs, mbatch.phases)
        )
        accel = "mpi" if rho_z >= ACCEL_RHO_THRESHOLD else "none"
    h0j = None if h0 is None else jnp.asarray(h0, jnp.float64)
    acc = rej = None
    if accel == "mpi":
        out = _rvi_loop_modulated_mpi(
            *args, eps, eps_rel, max_iter, s_max, period=accel_period, h0=h0j
        )
        policies, g, h, _, span, it_conv, acc, rej = out
        acc, rej = np.asarray(acc), np.asarray(rej)
    elif accel == "none":
        policies, g, h, _, span, it_conv = _rvi_loop_modulated(
            *args, eps, eps_rel, max_iter, s_max, h0=h0j
        )
    else:
        raise ValueError(f"unknown accel {accel!r} for modulated RVI")
    g_exact, h_exact = _exact_gain_modulated(*args, s_max, policies)
    ok = np.isfinite(np.asarray(g_exact)) & np.isfinite(
        np.asarray(h_exact).reshape(mbatch.n_specs, -1)
    ).all(axis=-1)
    g = np.where(ok, np.asarray(g_exact), np.asarray(g))
    h = np.where(ok[:, None, None], np.asarray(h_exact), np.asarray(h))
    span = np.asarray(span)
    return BatchedRVIResult(
        policies=np.asarray(policies),
        g=g,
        h=h,
        iterations=np.asarray(it_conv),
        span=span,
        converged=span < np.maximum(eps, eps_rel * np.abs(g)),
        wall_time_s=time.perf_counter() - t0,
        accel=accel,
        accel_accepts=acc,
        accel_rejects=rej,
    )


# ---------------------------------------------------------------------------
# Appendix-F baselines: approximate value / policy iteration on the
# *untruncated* associated DTMDP with an expanding state window.
# ---------------------------------------------------------------------------


def _untruncated_arrays(spec: SMDPSpec, n_states: int):
    """c~, p_k, y for states 0..n_states-1 of the untruncated DTMDP."""
    big = dataclasses.replace(spec, s_max=max(n_states - 2, spec.b_max), c_o=0.0)
    mdp = build_smdp(big)
    return mdp


def avi(
    spec: SMDPSpec,
    n_outer: int = 400,
    n0: int = 8,
    growth: int = 1,
    eval_s_max: int = 160,
) -> RVIResult:
    """Thomas–Stengos Scheme I: VI with an expanding state window.

    Iteration i backs up states {0..n0 + growth*i}; values outside the
    current window are taken as the boundary value (h of the largest known
    state), which mirrors the scheme's 'latter states see fewer backups'.
    """
    t0 = time.perf_counter()
    n_final = n0 + growth * n_outer + spec.b_max + 2
    mdp = _untruncated_arrays(spec, n_final + 2)
    n_states = mdp.n_states  # n_final + 2 (incl. S_o)
    c = np.where(mdp.feasible, mdp.c_tilde, np.inf)[: n_final + 1]
    m = mdp.m_tilde[: n_final + 1, :, :]  # (n_final+1, A, n_states)
    h = np.zeros(n_states)
    g = 0.0
    for i in range(n_outer):
        n_i = min(n0 + growth * i, n_final)
        q = c[: n_i + 1] + np.einsum("saj,j->sa", m[: n_i + 1, :, :], h)
        j = np.min(q, axis=1)
        g = j[0]
        h[: n_i + 1] = j - g
    q = c + np.einsum("saj,j->sa", m, h)
    policy = np.argmin(q, axis=1)
    pol = policy[: eval_s_max + 2].copy()
    pol[-1] = pol[eval_s_max]  # overflow state mirrors s_max
    return RVIResult(
        policy=pol,
        g=float(g),
        h=h[: eval_s_max + 2],
        iterations=n_outer,
        span=float("nan"),
        converged=True,
        wall_time_s=time.perf_counter() - t0,
    )


def api(
    spec: SMDPSpec,
    n_outer: int = 12,
    inner_per_outer: int = 20,
    n0: int = 8,
    growth: int = 1,
    eval_s_max: int = 160,
) -> RVIResult:
    """Thomas–Stengos Scheme IV: policy iteration with AVI inner evaluation."""
    t0 = time.perf_counter()
    max_inner = sum(inner_per_outer * (i + 1) for i in range(n_outer))
    n_final = n0 + growth * max_inner + spec.b_max + 2
    mdp = _untruncated_arrays(spec, n_final + 2)
    n_states = mdp.n_states
    c = np.where(mdp.feasible, mdp.c_tilde, np.inf)[: n_final + 1]
    m = mdp.m_tilde[: n_final + 1, :, :]
    policy = np.zeros(n_final + 1, dtype=np.int64)  # initial: always wait
    h = np.zeros(n_states)
    g = 0.0
    step = 0
    for outer in range(n_outer):
        # inner: approximate evaluation of `policy` with expanding window
        for _ in range(inner_per_outer * (outer + 1)):
            n_i = min(n0 + growth * step, n_final)
            step += 1
            rows = np.arange(n_i + 1)
            cp = c[rows, policy[: n_i + 1]]
            mp = m[rows, policy[: n_i + 1], :]
            j = cp + mp @ h
            g = j[0]
            h[: n_i + 1] = j - g
        # improvement
        q = c + np.einsum("saj,j->sa", m, h)
        policy = np.argmin(q, axis=1)
    pol = policy[: eval_s_max + 2].copy()
    pol[-1] = pol[eval_s_max]
    return RVIResult(
        policy=pol,
        g=float(g),
        h=h[: eval_s_max + 2],
        iterations=step,
        span=float("nan"),
        converged=True,
        wall_time_s=time.perf_counter() - t0,
    )
