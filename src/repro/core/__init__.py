"""Core SMDP dynamic-batching library (the paper's contribution).

Numerical fidelity of the solver requires float64; we enable x64 here.  All
model/serving code specifies dtypes explicitly (bf16/f32), so this is safe.
"""
import jax

jax.config.update("jax_enable_x64", True)

from .service_models import (  # noqa: E402,F401
    AffineProfile,
    ConstantProfile,
    LogProfile,
    PiecewiseMaxProfile,
    ServiceModel,
    TableProfile,
    GOOGLENET_P4_LATENCY,
    GOOGLENET_P4_ENERGY,
    IDEAL_PARALLEL_LATENCY,
    LOG_ENERGY,
)
from .smdp import (  # noqa: E402,F401
    BatchedSMDP,
    ModulatedBatchedSMDP,
    PhaseConfig,
    SMDPSpec,
    TruncatedSMDP,
    build_smdp,
    build_smdp_batched,
    build_smdp_modulated,
    build_smdp_modulated_batched,
    modulated_spec,
)
from .rvi import (  # noqa: E402,F401
    BatchedRVIResult,
    RVIResult,
    SolveReport,
    relative_value_iteration,
    relative_value_iteration_batched,
    relative_value_iteration_modulated,
)
from .policies import (  # noqa: E402,F401
    static_policy,
    greedy_policy,
    q_policy,
    optimal_q_closed_form,
)
from .evaluate import (  # noqa: E402,F401
    PolicyEval,
    evaluate_policy,
    evaluate_policy_modulated,
)
from .solve import (  # noqa: E402,F401
    ModulatedSolveResult,
    SolveResult,
    solve,
)
from .sweep import (  # noqa: E402,F401
    SweepPreempted,
    pad_specs,
    solve_modulated,
    sweep_solve,
    sweep_solve_modulated,
)
