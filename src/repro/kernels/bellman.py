"""Pallas TPU kernel: banded RVI Bellman backup (the paper's compute core).

The discrete-time backup for serve actions is a *banded correlation*

    G[t, a] = sum_k p^{[a]}_k h(t + k) + tail(t, a) * h(S_o)

(repro.core.rvi.banded_backup).  The naive dense backup is an (S,A,S)
tensor contraction — O(A*S^2) and memory-bound.  On TPU we instead build
Hankel (sliding-window) tiles of h in VMEM and feed the MXU:

    grid (T/Tb, A/Ab); per tile:
        for each 128-wide k-chunk:
            hwin (Tb, 128) <- shifted slices of h  (VMEM-local construction)
            acc (Tb, Ab)  += hwin @ pmf_chunk.T    (MXU)
        out = acc + tails_tile * h_overflow

Arithmetic intensity rises from O(1) (dense, streaming the transition
tensor) to O(Tb*Ab/(Tb+Ab)) — the kernel is compute-bound for K >= 128.

Validated in interpret mode against ref.bellman_banded_ref.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TB = 128  # base-state tile
AB = 128  # action tile (A is padded up; extra actions have zero pmfs)
KB = 128  # k-chunk width


def auto_interpret(interpret: Optional[bool]) -> bool:
    """Resolve the backend-aware default: real lowering on TPU/GPU,
    interpret mode everywhere else (CPU has no Mosaic/Triton path)."""
    if interpret is None:
        return jax.default_backend() not in ("tpu", "gpu")
    return interpret


def _kernel(h_ref, pmf_ref, tail_ref, hso_ref, out_ref, *, k_pad: int):
    ti = pl.program_id(0)
    t0 = ti * TB
    h = h_ref[...]  # (T_pad + K_pad,) resident in VMEM
    acc = jnp.zeros((TB, AB), dtype=jnp.float32)
    for c in range(k_pad // KB):
        # Hankel tile: hwin[u, kk] = h[t0 + c*KB + kk + u]
        cols = [
            jax.lax.dynamic_slice(h, (t0 + c * KB + kk,), (TB,))
            for kk in range(KB)
        ]
        hwin = jnp.stack(cols, axis=1)  # (TB, KB)
        pmf_chunk = pmf_ref[:, c * KB : (c + 1) * KB]  # (AB, KB)
        acc = acc + jax.lax.dot_general(
            hwin,
            pmf_chunk,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    out_ref[...] = acc + tail_ref[...] * hso_ref[0, 0]


def bellman_banded(
    h_main, pmfs, tails, h_overflow, *, interpret: Optional[bool] = None
):
    """G[t, a] = sum_k pmfs[a,k] h_main[t+k] + tails[t,a] * h_overflow.

    h_main: (T + K,) f32 (zero-padded past s_max); pmfs: (A, K); tails: (T, A).
    Returns (T, A) f32.  ``interpret=None`` autodetects the backend
    (lowered on TPU/GPU, interpret on CPU).
    """
    interpret = auto_interpret(interpret)
    T, A = tails.shape
    K = pmfs.shape[1]
    t_pad = -(-T // TB) * TB
    a_pad = -(-A // AB) * AB
    k_pad = -(-K // KB) * KB
    h_p = jnp.zeros(t_pad + k_pad, jnp.float32).at[: h_main.shape[0]].set(
        h_main.astype(jnp.float32)
    )
    pmf_p = jnp.zeros((a_pad, k_pad), jnp.float32).at[:A, :K].set(
        pmfs.astype(jnp.float32)
    )
    tail_p = jnp.zeros((t_pad, a_pad), jnp.float32).at[:T, :A].set(
        tails.astype(jnp.float32)
    )
    hso = jnp.full((1, 1), h_overflow, jnp.float32)

    grid = (t_pad // TB, a_pad // AB)
    out = pl.pallas_call(
        functools.partial(_kernel, k_pad=k_pad),
        grid=grid,
        in_specs=[
            pl.BlockSpec((t_pad + k_pad,), lambda i, j: (0,)),
            pl.BlockSpec((AB, k_pad), lambda i, j: (j, 0)),
            pl.BlockSpec((TB, AB), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TB, AB), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t_pad, a_pad), jnp.float32),
        interpret=interpret,
    )(h_p, pmf_p, tail_p, hso)
    return out[:T, :A]


def _kernel_batched(h_ref, pmf_ref, tail_ref, hso_ref, out_ref, *, k_pad: int):
    # identical math to _kernel, one spec per leading grid step
    ti = pl.program_id(1)
    t0 = ti * TB
    h = h_ref[0]  # (T_pad + K_pad,) this spec's h, resident in VMEM
    acc = jnp.zeros((TB, AB), dtype=jnp.float32)
    for c in range(k_pad // KB):
        cols = [
            jax.lax.dynamic_slice(h, (t0 + c * KB + kk,), (TB,))
            for kk in range(KB)
        ]
        hwin = jnp.stack(cols, axis=1)  # (TB, KB)
        pmf_chunk = pmf_ref[0, :, c * KB : (c + 1) * KB]  # (AB, KB)
        acc = acc + jax.lax.dot_general(
            hwin,
            pmf_chunk,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    out_ref[0] = acc + tail_ref[0] * hso_ref[0, 0]


def bellman_banded_batched(
    h_main, pmfs, tails, h_overflow, *, interpret: Optional[bool] = None
):
    """Spec-batched bellman_banded: one kernel launch for a whole sweep.

    The spec axis is a third grid dimension — not a vmap of the scalar
    kernel — so a lowered TPU run walks N x (T/TB) x (A/AB) tiles of one
    pallas_call; this is what the batched RVI lockstep (rvi.
    relative_value_iteration_batched with backup="pallas") dispatches.

    h_main: (N, T + K); pmfs: (N, A, K); tails: (N, T, A); h_overflow: (N,).
    Returns (N, T, A) f32.
    """
    interpret = auto_interpret(interpret)
    N, T, A = tails.shape
    K = pmfs.shape[2]
    t_pad = -(-T // TB) * TB
    a_pad = -(-A // AB) * AB
    k_pad = -(-K // KB) * KB
    h_p = jnp.zeros((N, t_pad + k_pad), jnp.float32)
    h_p = h_p.at[:, : h_main.shape[1]].set(h_main.astype(jnp.float32))
    pmf_p = jnp.zeros((N, a_pad, k_pad), jnp.float32).at[:, :A, :K].set(
        pmfs.astype(jnp.float32)
    )
    tail_p = jnp.zeros((N, t_pad, a_pad), jnp.float32).at[:, :T, :A].set(
        tails.astype(jnp.float32)
    )
    hso = h_overflow.astype(jnp.float32).reshape(N, 1)

    grid = (N, t_pad // TB, a_pad // AB)
    out = pl.pallas_call(
        functools.partial(_kernel_batched, k_pad=k_pad),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t_pad + k_pad), lambda n, i, j: (n, 0)),
            pl.BlockSpec((1, AB, k_pad), lambda n, i, j: (n, j, 0)),
            pl.BlockSpec((1, TB, AB), lambda n, i, j: (n, i, j)),
            pl.BlockSpec((1, 1), lambda n, i, j: (n, 0)),
        ],
        out_specs=pl.BlockSpec((1, TB, AB), lambda n, i, j: (n, i, j)),
        out_shape=jax.ShapeDtypeStruct((N, t_pad, a_pad), jnp.float32),
        interpret=interpret,
    )(h_p, pmf_p, tail_p, hso)
    return out[:, :T, :A]
