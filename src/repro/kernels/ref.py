"""Pure-jnp oracles for every Pallas kernel (tests assert allclose vs these)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def bellman_banded_ref(h_main, pmfs, tails, h_overflow):
    """G[t, a] = sum_k pmfs[a, k] * h_main[t + k] + tails[t, a] * h_overflow.

    h_main: (T + K,) f32 — value function over states 0..s_max, zero-padded.
    pmfs:   (A, K) f32 — arrival pmfs per action.
    tails:  (T, A) f32 — overflow mass towards S_o per (base state, action).
    """
    T = tails.shape[0]
    K = pmfs.shape[1]
    idx = jnp.arange(T)[:, None] + jnp.arange(K)[None, :]
    hwin = h_main[idx]  # (T, K)
    return hwin @ pmfs.T + tails * h_overflow


def attention_ref(
    q, k, v, *, causal=True, softcap: Optional[float] = None, kv_len=None
):
    """Naive masked softmax attention.  q: (B,Sq,H,D), k/v: (B,Sk,KV,D)."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32))
    s = s / math.sqrt(D)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None] + (Sk - Sq)
    if kv_len is not None:
        mask &= k_pos[None, :] < kv_len
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, lengths, *, softcap=None):
    """Single-token GQA decode.  q: (B,H,D); caches: (B,S,KV,D); lengths: (B,)."""
    B, H, D = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, D)
    s = jnp.einsum(
        "bkgd,bskd->bkgs", qg.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) / math.sqrt(D)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    mask = jnp.arange(S)[None, :] < lengths[:, None]  # (B, S)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)
