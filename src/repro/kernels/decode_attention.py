"""Pallas TPU kernel: GQA flash-decode (one new token vs an S-deep KV cache).

Decode attention is memory-bound: per (batch, kv-head) we stream the cache
once through VMEM while the G grouped q-heads ride along (GQA means each KV
block is reused G times by the MXU — the only reuse available).  Grid
(B*KV, S/bk); the last dim iterates KV blocks sequentially with online
softmax in VMEM scratch.  Valid-length masking uses the per-batch `lengths`
vector (cache is a ring of capacity S, filled to lengths[b]).

Oracle: repro.kernels.ref.decode_attention_ref.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, bk: int, nk: int, kv_heads: int, softcap,
):
    bh = pl.program_id(0)
    kj = pl.program_id(1)
    b = bh // kv_heads

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]  # (G, d)
    k = k_ref[0]  # (bk, d)
    v = v_ref[0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * (1.0 / math.sqrt(q.shape[-1]))  # (G, bk)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    k_pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = k_pos < len_ref[b]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = m_new

    @pl.when(kj == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attention(
    q,  # (B, H, D) — one token per sequence
    k_cache,  # (B, S, KV, D)
    v_cache,
    lengths,  # (B,) int32 valid prefix per sequence
    *,
    softcap=None,
    block_k: int = 256,
    interpret: bool = True,
):
    B, H, D = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    bk = min(block_k, max(8, S))
    s_pad = -(-S // bk) * bk
    qh = q.reshape(B * KV, G, D)
    kh = jnp.moveaxis(k_cache, 2, 1).reshape(B * KV, S, D)
    vh = jnp.moveaxis(v_cache, 2, 1).reshape(B * KV, S, D)
    if s_pad != S:
        kh = jnp.pad(kh, ((0, 0), (0, s_pad - S), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, s_pad - S), (0, 0)))
    nk = s_pad // bk
    grid = (B * KV, nk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G, D), lambda bh, kj, lens: (bh, 0, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, kj, lens: (bh, kj, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, kj, lens: (bh, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda bh, kj, lens: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, bk=bk, nk=nk, kv_heads=KV, softcap=softcap),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * KV, G, D), q.dtype),
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qh, kh, vh)
    return out.reshape(B, H, D)
