"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels execute in interpret mode — the kernel body
runs as traced JAX ops, validating logic exactly; on TPU (`jax.devices()[0]
.platform == 'tpu'`) they compile to Mosaic.  ``interpret=None`` auto-detects.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from . import bellman as _bellman
from . import decode_attention as _decode
from . import flash_attention as _flash


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


@partial(jax.jit, static_argnames=("interpret",))
def _bellman_jit(h_main, pmfs, tails, h_overflow, interpret=True):
    return _bellman.bellman_banded(
        h_main, pmfs, tails, h_overflow, interpret=interpret
    )


def bellman_backup(h_main, pmfs, tails, h_overflow, interpret: Optional[bool] = None):
    """Banded RVI backup G[t,a] (see kernels/bellman.py).

    The bellman kernels resolve ``interpret=None`` via their own
    backend-aware default (lowered on TPU *and* GPU — the kernel is a plain
    tiled matmul loop — interpret on CPU).
    """
    return _bellman_jit(
        h_main, pmfs, tails, jnp.asarray(h_overflow, jnp.float32),
        interpret=_bellman.auto_interpret(interpret),
    )


@partial(jax.jit, static_argnames=("interpret",))
def _bellman_batched_jit(h_main, pmfs, tails, h_overflow, interpret=True):
    return _bellman.bellman_banded_batched(
        h_main, pmfs, tails, h_overflow, interpret=interpret
    )


def bellman_backup_batched(
    h_main, pmfs, tails, h_overflow, interpret: Optional[bool] = None
):
    """Spec-batched banded RVI backup G[n,t,a] (see kernels/bellman.py)."""
    return _bellman_batched_jit(
        h_main, pmfs, tails, jnp.asarray(h_overflow, jnp.float32),
        interpret=_bellman.auto_interpret(interpret),
    )


@partial(jax.jit, static_argnames=("causal", "softcap", "block_q", "block_k", "interpret"))
def _flash_jit(q, k, v, causal=True, softcap=None, block_q=128, block_k=128, interpret=True):
    return _flash.flash_attention(
        q, k, v, causal=causal, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )


def flash_attention(q, k, v, *, causal=True, softcap=None, block_q=128,
                    block_k=128, interpret: Optional[bool] = None):
    return _flash_jit(
        q, k, v, causal=causal, softcap=softcap, block_q=block_q,
        block_k=block_k, interpret=_auto_interpret(interpret),
    )


@partial(jax.jit, static_argnames=("softcap", "block_k", "interpret"))
def _decode_jit(q, k_cache, v_cache, lengths, softcap=None, block_k=256, interpret=True):
    return _decode.decode_attention(
        q, k_cache, v_cache, lengths, softcap=softcap, block_k=block_k,
        interpret=interpret,
    )


def decode_attention(q, k_cache, v_cache, lengths, *, softcap=None,
                     block_k=256, interpret: Optional[bool] = None):
    return _decode_jit(
        q, k_cache, v_cache, lengths, softcap=softcap, block_k=block_k,
        interpret=_auto_interpret(interpret),
    )
