"""Pallas TPU kernel: blockwise flash attention (prefill / train forward).

Tiling: grid (B*H, Sq/bq, Sk/bk).  The last grid dim iterates KV blocks with
('arbitrary') sequential semantics; online-softmax stats (m, l) and the
output accumulator live in VMEM scratch and persist across KV iterations.
GQA is handled in the k/v index_map (q head h reads kv head h // G).
f32 accumulation; bf16/f32 inputs.

Oracle: repro.kernels.ref.attention_ref.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, bq: int, bk: int, nk: int, causal: bool, softcap, sq: int, sk: int,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]  # (bq, d)
    k = k_ref[0]  # (bk, d)
    v = v_ref[0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * (1.0 / math.sqrt(q.shape[-1]))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < sk  # padding mask
    mask &= q_pos < sq
    if causal:
        mask &= k_pos <= q_pos + (sk - sq)  # bottom-right aligned causal
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_scr[...] = acc_scr[...] * corr + pv
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kj == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q,  # (B, Sq, H, D)
    k,  # (B, Sk, KV, D)
    v,
    *,
    causal: bool = True,
    softcap=None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
):
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(block_q, max(8, Sq))
    bk = min(block_k, max(8, Sk))
    sq_pad = -(-Sq // bq) * bq
    sk_pad = -(-Sk // bk) * bk
    # layout: (B*H, S, D) with heads folded into the batch grid dim
    qh = jnp.moveaxis(q, 2, 1).reshape(B * H, Sq, D)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * KV, Sk, D)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * KV, Sk, D)
    if sq_pad != Sq:
        qh = jnp.pad(qh, ((0, 0), (0, sq_pad - Sq), (0, 0)))
    if sk_pad != Sk:
        kh = jnp.pad(kh, ((0, 0), (0, sk_pad - Sk), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, sk_pad - Sk), (0, 0)))
    nk = sk_pad // bk
    grid = (B * H, sq_pad // bq, nk)

    def kv_index(bh, qi, kj):
        b, h = bh // H, bh % H
        return (b * KV + h // G, kj, 0)

    out = pl.pallas_call(
        functools.partial(
            _kernel, bq=bq, bk=bk, nk=nk, causal=causal, softcap=softcap,
            sq=Sq, sk=Sk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, bk, D), kv_index),
            pl.BlockSpec((1, bk, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, sq_pad, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qh, kh, vh)
    return jnp.moveaxis(out[:, :Sq].reshape(B, H, Sq, D), 1, 2)
