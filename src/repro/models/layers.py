"""Model-zoo building blocks (pure JAX, dtype-explicit).

Attention is implemented blockwise (online-softmax over KV chunks) so that
32k-token prefill and 4k training lower without materializing (S, S) logits.
This jnp implementation doubles as the oracle for the Pallas kernels in
repro.kernels.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.hints import BATCH, hint

from .config import ModelConfig

from repro.distributed import collectives as C

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mean) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(dt)


def apply_norm(cfg: ModelConfig, x, scale, bias=None):
    if cfg.norm == "rmsnorm":
        return rms_norm(x, scale)
    return layer_norm(x, scale, bias)


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE and M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D); positions: (B, S) int."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections):
    """Multimodal RoPE (qwen2-vl): positions3 (3, B, S) for (t, h, w);
    `sections` split the D/2 frequency dims among the three components."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    secs = jnp.concatenate(
        [jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)]
    )  # (D/2,) component selector in {0, 1, 2}
    pos_sel = jnp.take(positions3.astype(jnp.float32), secs, axis=0)  # (D/2, B, S)
    pos = jnp.moveaxis(pos_sel, 0, -1)  # (B, S, D/2)
    ang = pos * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention — jnp reference implementation
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _attn_mask(q_pos, k_pos, *, causal, window, chunk):
    """(Sq, Sk) boolean mask. window: sliding-window width; chunk: local-chunk."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < window
    if chunk is not None:
        m &= (q_pos[:, None] // chunk) == (k_pos[None, :] // chunk)
    return m


def flash_attention(
    q,  # (B, Sq, H, D)
    k,  # (B, Sk, KV, D)
    v,  # (B, Sk, KV, D)
    *,
    causal: bool = True,
    q_offset=0,  # scalar or (B,) — absolute position of q[0]
    kv_len=None,  # scalar or (B,) — valid KV prefix length (cache decode)
    window: Optional[int] = None,
    chunk: Optional[int] = None,
    softcap: Optional[float] = None,
    chunk_kv: int = 1024,
    chunk_q: int = 1024,
):
    """Blockwise online-softmax attention; f32 accumulation.

    Tiled over BOTH q (outer lax.map) and kv (inner lax.scan) so no (Sq, Sk)
    tensor is ever materialized — 32k-token prefill lowers with O(cq*ck)
    transients.  GQA folds H into (KV, G).  The kv body is remat'd so the
    backward pass recomputes per-chunk probabilities.
    """
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    valid_len = jnp.broadcast_to(jnp.asarray(Sk if kv_len is None else kv_len), (B,))
    q_pos_all = jnp.asarray(q_offset)[..., None] + jnp.arange(Sq)[None, :]
    q_pos_all = jnp.broadcast_to(q_pos_all, (B, Sq))

    cq = min(chunk_q, Sq)
    nq = (Sq + cq - 1) // cq
    pad_q = nq * cq - Sq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos_all = jnp.pad(q_pos_all, ((0, 0), (0, pad_q)))
    qg = q.reshape(B, nq, cq, KV, G, D).transpose(1, 0, 2, 3, 4, 5)
    qp = q_pos_all.reshape(B, nq, cq).transpose(1, 0, 2)

    ck = min(chunk_kv, Sk)
    nk = max(1, (Sk + ck - 1) // ck)
    pad_k = nk * ck - Sk
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    kc = jnp.moveaxis(k.reshape(B, nk, ck, KV, D), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nk, ck, KV, D), 1, 0)

    def q_block(args):
        qi, q_pos = args  # (B, cq, KV, G, D), (B, cq)

        @jax.checkpoint
        def body(carry, inp):
            m, l, acc = carry
            kj, vj, j = inp
            k_pos = j * ck + jnp.arange(ck)  # (ck,)
            # bf16 operands, f32 accumulation via preferred_element_type: an
            # explicit astype(f32) on kj would be hoisted out of the scan by
            # XLA into a full-cache f32 copy (4 GiB/layer at 32k decode).
            s = jnp.einsum(
                "bqkgd,bckd->bkgqc", qi, kj, preferred_element_type=jnp.float32
            ) * scale  # (B, KV, G, cq, ck)
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            mask = jnp.ones((B, cq, ck), dtype=bool)
            if causal:
                mask &= k_pos[None, None, :] <= q_pos[:, :, None]
            if window is not None:
                mask &= q_pos[:, :, None] - k_pos[None, None, :] < window
            if chunk is not None:
                mask &= (q_pos[:, :, None] // chunk) == (k_pos[None, None, :] // chunk)
            mask &= k_pos[None, None, :] < valid_len[:, None, None]
            s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum(
                "bkgqc,bckd->bkgqd", p.astype(vj.dtype), vj,
                preferred_element_type=jnp.float32,
            )
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, cq), NEG_INF, dtype=jnp.float32)
        l0 = jnp.zeros((B, KV, G, cq), dtype=jnp.float32)
        a0 = jnp.zeros((B, KV, G, cq, D), dtype=jnp.float32)
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B, KV, G, cq, D)
        return out

    if nq == 1:
        outs = q_block((qg[0], qp[0]))[None]
    else:
        outs = jax.lax.map(q_block, (qg, qp))  # (nq, B, KV, G, cq, D)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * cq, KV * G, D)
    return out[:, :Sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + flash)
# ---------------------------------------------------------------------------


def attention(
    cfg: ModelConfig,
    p,  # dict: wq (d,H,hd), wk (d,KV,hd), wv, wo (H,hd,d) [+ bq/bk/bv]
    x,  # (B, S, d)
    *,
    layer_is_local=False,
    positions=None,  # (B, S) or (3, B, S) for mrope
    kv_cache=None,  # dict(k, v, length) for decode/prefill-append
    causal=True,
    fresh_cache=False,  # static: cache length is known-0 (first prefill)
):
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = hint(q, BATCH, None, "model", None)
    k = hint(k, BATCH, None, "model", None)
    v = hint(v, BATCH, None, "model", None)

    if positions is None:
        base = 0 if kv_cache is None else kv_cache["length"]
        positions = jnp.asarray(base)[..., None] + jnp.arange(S)[None, :]
        positions = jnp.broadcast_to(positions, (B, S))
    if cfg.mrope_sections is not None:
        pos3 = positions if positions.ndim == 3 else jnp.broadcast_to(positions, (3, B, S))
        q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
        q_off = pos3[0, :, 0]
    elif cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        q_off = positions[:, 0]
    else:
        q_off = positions[:, 0]

    window = cfg.sliding_window if layer_is_local else None
    chunk = cfg.chunk_size if layer_is_local and cfg.layer_pattern == "chunked_full" else None
    if cfg.layer_pattern == "chunked_full" and layer_is_local:
        window = None  # llama4 local layers use chunked, not sliding

    if kv_cache is None:
        n_prev = (
            C.sharded_window_applicable(window, S)
            if (window is not None and causal and cfg.sharded_decode_attn)
            else 0
        )
        if n_prev:
            # halo-exchange sliding-window attention (§Perf E): fetch only
            # the predecessor shards the window can reach instead of the
            # full-sequence all-gather GSPMD would emit
            out = C.sharded_window_prefill_attention(
                q, k, v, window=window, n_prev=n_prev, softcap=cfg.attn_softcap
            )
        else:
            out = flash_attention(
                q, k, v,
                causal=causal,
                q_offset=0,
                window=window,
                chunk=chunk,
                softcap=cfg.attn_softcap,
                chunk_kv=cfg.attn_chunk_kv,
            )
        new_cache = None
    else:
        # append this step's K/V at position `length` then attend over prefix
        length = kv_cache["length"]
        zero = jnp.zeros((), dtype=jnp.asarray(length).dtype)
        idx = (zero, jnp.asarray(length, zero.dtype), zero, zero)
        kbuf = jax.lax.dynamic_update_slice(
            kv_cache["k"], k.astype(kv_cache["k"].dtype), idx
        )
        vbuf = jax.lax.dynamic_update_slice(
            kv_cache["v"], v.astype(kv_cache["v"].dtype), idx
        )
        kbuf = hint(kbuf, BATCH, "model", None, None)
        vbuf = hint(vbuf, BATCH, "model", None, None)
        # fresh-cache prefill (length statically 0): attention over the
        # buffer == attention over the current segment, so the halo
        # sliding-window path applies here too (§Perf E)
        fresh = fresh_cache or (
            (not isinstance(length, jax.core.Tracer)) and int(length) == 0
        )
        n_prev_pf = (
            C.sharded_window_applicable(window, S)
            if (fresh and S > 1 and window is not None and causal
                and cfg.sharded_decode_attn)
            else 0
        )
        if n_prev_pf:
            out = C.sharded_window_prefill_attention(
                q, k, v, window=window, n_prev=n_prev_pf,
                softcap=cfg.attn_softcap,
            )
        elif (
            cfg.sharded_decode_attn
            and S == 1
            and C.sharded_decode_applicable(q.shape, kbuf.shape[1])
        ):
            # seq-sharded flash-decode: O(B*H*D) wire cost instead of the
            # full-cache all-gather GSPMD would otherwise emit per layer
            out = C.sharded_flash_decode(
                q, kbuf, vbuf, length + S,
                softcap=cfg.attn_softcap, window=window, chunk=chunk,
            )
        else:
            out = flash_attention(
                q, kbuf, vbuf,
                causal=causal,
                q_offset=length,
                kv_len=length + S,
                window=window,
                chunk=chunk,
                softcap=cfg.attn_softcap,
                chunk_kv=cfg.attn_chunk_kv,
            )
        new_cache = {"k": kbuf, "v": vbuf, "length": length + S}
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    # residual stream is sequence-sharded over 'model' (Megatron-SP style):
    # activations per chip shrink 16x, which is what lets 4k-seq training of
    # 32B+ models fit v5e HBM (see EXPERIMENTS.md §Perf).
    return hint(out, BATCH, "model", None), new_cache


def cross_attention(cfg: ModelConfig, p, x, enc_out):
    """Whisper decoder cross-attention (no mask, no rope)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(x.dtype))
    out = flash_attention(q, k, v, causal=False, chunk_kv=cfg.attn_chunk_kv)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp(cfg: ModelConfig, p, x):
    if cfg.act in ("swiglu", "geglu"):
        gate = jnp.einsum("bsd,df->bsf", x, p["w1"].astype(x.dtype))
        up = jnp.einsum("bsd,df->bsf", x, p["w3"].astype(x.dtype))
        act = jax.nn.silu(gate) if cfg.act == "swiglu" else jax.nn.gelu(gate)
        h = act * up
    else:  # plain gelu MLP
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w1"].astype(x.dtype)))
    h = hint(h, BATCH, None, "model")
    return hint(jnp.einsum("bsf,fd->bsd", h, p["w2"].astype(x.dtype)), BATCH, "model", None)


# ---------------------------------------------------------------------------
# Mixture of Experts — grouped one-hot dispatch (GShard-style, SPMD-friendly)
# ---------------------------------------------------------------------------


def moe_ffn(cfg: ModelConfig, p, x):
    """x: (B, S, d) -> (B, S, d).  Experts dim is shardable over 'model'."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    capacity_factor = cfg.moe_capacity_factor
    T = B * S
    g = min(cfg.moe_group_size, T)
    # pad T to a multiple of g
    G = (T + g - 1) // g
    pad = G * g - T
    xt = x.reshape(T, d)
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    xg = hint(xt.reshape(G, g, d), BATCH, None, None)

    logits = jnp.einsum("Ggd,de->Gge", xg, p["router"].astype(x.dtype)).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)  # (G, g, E)
    cap = int(max(4, math.ceil(g * k / E * capacity_factor)))

    combine = jnp.zeros((G, g, E, cap), dtype=jnp.float32)
    gates_left = gates
    base = jnp.zeros((G, 1, E), dtype=jnp.float32)  # slots used by prior rounds
    for _ in range(k):
        idx = jnp.argmax(gates_left, axis=-1)  # (G, g)
        gate_val = jnp.take_along_axis(gates_left, idx[..., None], axis=-1)[..., 0]
        onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # (G, g, E)
        pos = (jnp.cumsum(onehot, axis=1) - 1.0 + base) * onehot  # slot in expert
        in_cap = (pos < cap) & (onehot > 0)
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
        combine = combine + (
            gate_val[..., None, None] * onehot[..., None] * pos_oh * in_cap[..., None]
        )
        base = base + jnp.sum(onehot, axis=1, keepdims=True)
        gates_left = gates_left * (1.0 - onehot)  # mask chosen expert
    combine = hint(combine, BATCH, None, None, None)
    dispatch = (combine > 0).astype(x.dtype)  # (G, g, E, C)

    # dispatch tokens to experts: (E, G, C, d).  EP when E divides 'model'
    # (llama4: 16 experts); otherwise d is TP-sharded (grok: 8 experts).
    xe = hint(
        jnp.einsum("GgEC,Ggd->EGCd", dispatch, xg), "model", BATCH, None, "model"
    )
    # expert FFN, vmapped over E via einsum with stacked weights
    if cfg.act in ("swiglu", "geglu"):
        gate_h = jnp.einsum("EGCd,Edf->EGCf", xe, p["w1"].astype(x.dtype))
        up_h = jnp.einsum("EGCd,Edf->EGCf", xe, p["w3"].astype(x.dtype))
        act = jax.nn.silu(gate_h) if cfg.act == "swiglu" else jax.nn.gelu(gate_h)
        h = act * up_h
    else:
        h = jax.nn.gelu(jnp.einsum("EGCd,Edf->EGCf", xe, p["w1"].astype(x.dtype)))
    h = hint(h, "model", BATCH, None, "model")
    ye = hint(
        jnp.einsum("EGCf,Efd->EGCd", h, p["w2"].astype(x.dtype)),
        "model", BATCH, None, "model",
    )
    # combine back
    y = hint(jnp.einsum("GgEC,EGCd->Ggd", combine.astype(x.dtype), ye), BATCH, None, None)
    # (reshaped back to (B, S, d) below; the block-output hint re-shards seq)
    y = y.reshape(G * g, d)[:T].reshape(B, S, d)

    if cfg.n_shared_experts:
        y = y + mlp(cfg, {"w1": p["sw1"], "w3": p.get("sw3"), "w2": p["sw2"]}, x)
    return y


# ---------------------------------------------------------------------------
# Mamba2 (SSD, chunked) — zamba2 backbone
# ---------------------------------------------------------------------------


def _causal_conv(x, w, state=None):
    """Depthwise causal conv along seq. x: (B, S, C), w: (K, C).

    state: (B, K-1, C) trailing inputs from the previous segment (decode).
    Returns (y, new_state)."""
    B, S, C = x.shape
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((B, K - 1, C), dtype=x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, S+K-1, C)
    y = sum(xp[:, i : i + S, :] * w[i][None, None, :].astype(x.dtype) for i in range(K))
    new_state = xp[:, S:, :] if K > 1 else state
    return y, new_state


def mamba2_block(cfg: ModelConfig, p, x, *, ssm_state=None, conv_state=None, chunk: int = 128):
    """Mamba2 block via the chunked SSD algorithm.

    x: (B, S, d).  State: (B, H, P, N) with H = n_ssm_heads, P = ssm_head_dim,
    N = ssm_state.  Returns (y, new_ssm_state, new_conv_state).
    """
    B, S, d = x.shape
    H, P, N = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    di = cfg.d_inner_ssm

    zxbcdt = hint(
        jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype)), BATCH, None, "model"
    )
    z, xbc, dt = jnp.split(zxbcdt, [di, di + di + 2 * N], axis=-1)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], conv_state)
    xbc = jax.nn.silu(xbc)
    xs, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)  # (B,S,di),(B,S,N),(B,S,N)
    xs = xs.reshape(B, S, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # (B,S,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (H,) negative
    # per-step log decay
    dA = dt * a[None, None, :]  # (B, S, H)  (log decay, <= 0)

    if ssm_state is None:
        ssm_state = jnp.zeros((B, H, P, N), dtype=jnp.float32)

    # pad S to multiple of chunk
    L = chunk if S >= chunk else S
    n_ch = (S + L - 1) // L
    pad = n_ch * L - S
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))

    def chunk_body(state, inp):
        xc, bc, cc, dac, dtc = inp  # (B,L,H,P),(B,L,N),(B,L,N),(B,L,H),(B,L,H)
        cum = jnp.cumsum(dac, axis=1)  # (B, L, H) cumulative log decay
        # intra-chunk: Att[i, j] = C_i . B_j * exp(cum_i - cum_j) * dt_j, j <= i
        cb = jnp.einsum("bin,bjn->bij", cc.astype(jnp.float32), bc.astype(jnp.float32))
        dec = cum[:, :, None, :] - cum[:, None, :, :]  # (B, L, L, H)
        li = jnp.tril(jnp.ones((L, L), dtype=bool))
        att = cb[..., None] * jnp.exp(jnp.where(li[None, :, :, None], dec, -jnp.inf))
        att = att * dtc[:, None, :, :]  # weight by dt_j
        y_intra = jnp.einsum("bijh,bjhp->bihp", att, xc.astype(jnp.float32))
        # inter-chunk: y_i += C_i . state * exp(cum_i)
        y_inter = jnp.einsum(
            "bin,bhpn,bih->bihp", cc.astype(jnp.float32), state, jnp.exp(cum)
        )
        # state update: state = state * exp(cum_L) + sum_j exp(cum_L - cum_j) dt_j x_j B_j
        tot = cum[:, -1, :]  # (B, H)
        w_j = jnp.exp(tot[:, None, :] - cum) * dtc  # (B, L, H)
        state_new = state * jnp.exp(tot)[:, :, None, None] + jnp.einsum(
            "blh,blhp,bln->bhpn", w_j, xc.astype(jnp.float32), bc.astype(jnp.float32)
        )
        return state_new, y_intra + y_inter

    xs_c = xs.reshape(B, n_ch, L, H, P).swapaxes(0, 1)
    Bm_c = Bm.reshape(B, n_ch, L, N).swapaxes(0, 1)
    Cm_c = Cm.reshape(B, n_ch, L, N).swapaxes(0, 1)
    dA_c = dA.reshape(B, n_ch, L, H).swapaxes(0, 1)
    dt_c = dt.reshape(B, n_ch, L, H).swapaxes(0, 1)
    ssm_state, ys = jax.lax.scan(chunk_body, ssm_state, (xs_c, Bm_c, Cm_c, dA_c, dt_c))
    y = ys.swapaxes(0, 1).reshape(B, n_ch * L, H, P)[:, :S]
    y = y + xs[:, :S].astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, di).astype(x.dtype) * jax.nn.silu(z)
    out = hint(jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype)), BATCH, "model", None)
    return out, ssm_state, conv_state


# ---------------------------------------------------------------------------
# RWKV6 (Finch) — time-mix (WKV6) + channel-mix
# ---------------------------------------------------------------------------


def _segmented_scan(step, carry, xs, seg: int, pad_values=None):
    """lax.scan with sqrt-remat: backward saves carries only at segment
    boundaries (S/seg states) and recomputes inside each segment (seg
    states live at once).  Peak carry memory drops from O(S) to
    O(S/seg + seg) — 32x for the rwkv6 train_4k cell (§Perf).

    pad_values: per-leaf constants for the tail padding, chosen so padded
    steps are identity on the carry (e.g. decay=1, k=v=0 for WKV)."""
    S = jax.tree.leaves(xs)[0].shape[0]
    nseg = max(1, (S + seg - 1) // seg)
    pad = nseg * seg - S
    if pad:
        if pad_values is None:
            pad_values = jax.tree.map(lambda a: 0.0, xs)
        xs = jax.tree.map(
            lambda a, pv: jnp.pad(
                a, ((0, pad),) + ((0, 0),) * (a.ndim - 1), constant_values=pv
            ),
            xs, pad_values,
        )
    xs_seg = jax.tree.map(lambda a: a.reshape((nseg, seg) + a.shape[1:]), xs)

    @jax.checkpoint
    def seg_body(c, xseg):
        return jax.lax.scan(step, c, xseg)

    carry, outs = jax.lax.scan(seg_body, carry, xs_seg)
    outs = jax.tree.map(
        lambda a: a.reshape((nseg * seg,) + a.shape[2:])[:S], outs
    )
    return carry, outs


def rwkv6_time_mix(cfg: ModelConfig, p, x, *, state=None, shift_state=None):
    """x: (B, S, d) -> (y, new_state, new_shift).

    state: (B, H, P, P) WKV state; shift_state: (B, 1, d) last token.
    Data-dependent decay w_t (Finch); u (bonus) per head-dim.
    """
    B, S, d = x.shape
    H = cfg.n_heads
    P = cfg.head_dim
    if shift_state is None:
        shift_state = jnp.zeros((B, 1, d), dtype=x.dtype)
    x_prev = jnp.concatenate([shift_state, x[:, :-1]], axis=1)
    new_shift = x[:, -1:, :]

    def lerp(name):
        mu = p[f"mu_{name}"].astype(x.dtype)  # (d,)
        return x + mu * (x_prev - x)

    r = jnp.einsum("bsd,dhp->bshp", lerp("r"), p["wr"].astype(x.dtype))
    kk = jnp.einsum("bsd,dhp->bshp", lerp("k"), p["wk"].astype(x.dtype))
    vv = jnp.einsum("bsd,dhp->bshp", lerp("v"), p["wv"].astype(x.dtype))
    g = jax.nn.silu(jnp.einsum("bsd,dhp->bshp", lerp("g"), p["wg"].astype(x.dtype)))
    # data-dependent decay via low-rank projection (Finch)
    wx = jnp.tanh(jnp.einsum("bsd,dr->bsr", lerp("w"), p["w_lora_a"].astype(x.dtype)))
    w_log = p["w_base"].astype(jnp.float32) + jnp.einsum(
        "bsr,rdp->bsdp", wx.astype(jnp.float32), p["w_lora_b"].astype(jnp.float32).reshape(p["w_lora_b"].shape[0], H, P)
    ).reshape(B, S, H, P)
    w = jnp.exp(-jnp.exp(w_log))  # (B, S, H, P) in (0, 1)
    u = p["u_bonus"].astype(jnp.float32).reshape(H, P)

    if state is None:
        state = jnp.zeros((B, H, P, P), dtype=jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp  # (B,H,P) each
        # stacked scan inputs stay in the model dtype (bf16 on TPU) and are
        # upcast per step: halves the stacked-residual memory of training
        # (EXPERIMENTS.md §Perf, rwkv6 train cell)
        rt = rt.astype(jnp.float32)
        kt = kt.astype(jnp.float32)
        vt = vt.astype(jnp.float32)
        wt = wt.astype(jnp.float32)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,P,P) outer k^T v
        out = jnp.einsum("bhp,bhpq->bhq", rt, s + u[None, :, :, None] * kv)
        s_new = wt[..., :, None] * s + kv
        return s_new, out

    rs = r.swapaxes(0, 1).reshape(S, B, H, P)
    ks = kk.swapaxes(0, 1).reshape(S, B, H, P)
    vs = vv.swapaxes(0, 1).reshape(S, B, H, P)
    # decay stays f32: bf16 cannot represent 1 - w for slow-decay channels
    ws = w.swapaxes(0, 1).reshape(S, B, H, P)
    if S <= 64:  # decode / short prefill: no segmentation overhead
        state, outs = jax.lax.scan(step, state, (rs, ks, vs, ws))
    else:
        state, outs = _segmented_scan(
            step, state, (rs, ks, vs, ws), seg=64,
            pad_values=(0.0, 0.0, 0.0, 1.0),  # decay=1: pads fix the state
        )
    y = outs.swapaxes(0, 1).reshape(B, S, H, P)
    y = rms_norm(y, p["ln_x"].astype(jnp.float32)).astype(x.dtype) * g
    y = jnp.einsum("bshp,hpd->bsd", y, p["wo"].astype(x.dtype))
    return y, state, new_shift


def rwkv6_channel_mix(cfg: ModelConfig, p, x, *, shift_state=None):
    B, S, d = x.shape
    if shift_state is None:
        shift_state = jnp.zeros((B, 1, d), dtype=x.dtype)
    x_prev = jnp.concatenate([shift_state, x[:, :-1]], axis=1)
    new_shift = x[:, -1:, :]
    mu_k = p["mu_ck"].astype(x.dtype)
    mu_r = p["mu_cr"].astype(x.dtype)
    xk = x + mu_k * (x_prev - x)
    xr = x + mu_r * (x_prev - x)
    kk = jnp.einsum("bsd,df->bsf", xk, p["ck"].astype(x.dtype))
    kk = jnp.square(jax.nn.relu(kk))
    kv = jnp.einsum("bsf,fd->bsd", kk, p["cv"].astype(x.dtype))
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["cr"].astype(x.dtype)))
    return rr * kv, new_shift
