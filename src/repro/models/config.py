"""Model configuration schema + the 10 assigned architectures.

One frozen dataclass covers every family (dense / moe / ssm / hybrid /
enc-dec / vlm); family-specific fields default off.  Each assigned arch gets
its exact published config plus a `reduced()` variant for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention flavor ---
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None  # gemma2: 50.0, grok: 30.0
    final_softcap: Optional[float] = None  # gemma2: 30.0
    sliding_window: Optional[int] = None  # gemma2 local layers
    layer_pattern: str = "full"  # full | local_global | chunked_full
    chunk_size: Optional[int] = None  # llama4 chunked-local attention
    rope_theta: float = 10_000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl (t, h, w)

    # --- mlp / norm ---
    act: str = "swiglu"  # swiglu | geglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    post_block_norm: bool = False  # gemma2 pre+post norms
    embed_scale: bool = False  # gemma2 scales embeddings by sqrt(d)
    tie_embeddings: bool = True

    # --- moe ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0  # llama4 shared expert
    moe_capacity_factor: float = 1.25  # GShard-style capacity (tokens dropped
    # beyond capacity); raise to ~E/top_k for drop-free routing
    moe_group_size: int = 1024  # tokens per dispatch group

    # --- ssm (mamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    shared_attn_every: int = 0  # zamba2: shared attn block every k ssm layers

    # --- rwkv6 ---
    rwkv: bool = False

    # --- enc-dec (whisper) ---
    n_encoder_layers: int = 0
    encoder_len: int = 0  # precomputed frame embeddings length (stub frontend)

    # --- vlm (qwen2-vl) ---
    n_patches: int = 0  # precomputed patch embeddings prepended (stub frontend)

    # --- serving/dry-run knobs ---
    attn_chunk_q: int = 1024  # blockwise-attention q tile
    attn_chunk_kv: int = 1024  # blockwise-attention kv tile
    sharded_decode_attn: bool = True  # shard_map flash-decode over seq-sharded
    # KV (EXPERIMENTS.md §Perf); False = baseline XLA-auto collectives

    @property
    def d_inner_ssm(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner_ssm // self.ssm_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """True if 500k-context decode is state-based (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs generate tokens (whisper is enc-dec)

    def n_params(self) -> float:
        """Approximate parameter count (embedding + blocks)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        qkv_o = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim + (
            self.n_heads * self.head_dim * d
        )
        mlp_mult = 3 if self.act in ("swiglu", "geglu") else 2
        mlp = mlp_mult * d * ff
        if self.rwkv:
            per_layer = 4 * d * d + 2 * d * self.d_ff  # rough: tmix + cmix
            total += self.n_layers * per_layer
        elif self.family in ("ssm", "hybrid"):
            di = self.d_inner_ssm
            per_layer = d * (2 * di + 2 * self.ssm_state * 2) + di * d + di * 3
            total += self.n_layers * per_layer
            if self.shared_attn_every:
                total += qkv_o + mlp  # one shared block
        elif self.n_experts:
            total += self.n_layers * (
                qkv_o + self.n_experts * mlp + self.n_shared_experts * mlp + d * self.n_experts
            )
        else:
            total += self.n_layers * (qkv_o + mlp)
        if self.n_encoder_layers:
            total += self.n_encoder_layers * (qkv_o + mlp)
            total += self.n_layers * qkv_o  # cross-attention
        return float(total)

    def n_params_active(self) -> float:
        """Active params per token (MoE: only top-k experts count)."""
        if not self.n_experts:
            return self.n_params()
        d, ff = self.d_model, self.d_ff
        mlp_mult = 3 if self.act in ("swiglu", "geglu") else 2
        mlp = mlp_mult * d * ff
        dense = self.n_params() - self.n_layers * self.n_experts * mlp
        return dense + self.n_layers * (self.top_k + self.n_shared_experts) * mlp

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=max(2, min(4, self.n_layers)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab_size=512,
            sliding_window=32 if self.sliding_window else None,
            chunk_size=32 if self.chunk_size else None,
            n_experts=min(self.n_experts, 4),
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=16 if self.ssm_state else self.ssm_head_dim,
            shared_attn_every=2 if self.shared_attn_every else 0,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            encoder_len=32 if self.encoder_len else 0,
            n_patches=8 if self.n_patches else 0,
            mrope_sections=(2, 3, 3) if self.mrope_sections else None,
            attn_chunk_q=16,
            attn_chunk_kv=16,
        )
