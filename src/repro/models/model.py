"""Model zoo: init / forward / train-loss / prefill / decode for all families.

Layers are stacked on a leading `n_layers` axis and iterated with lax.scan
(MaxText-style) so that 64-layer models lower to compact HLO.  Training wraps
the scanned block in jax.checkpoint (remat).

Cache conventions (decode):
  dense/moe/vlm : {"k": (L,B,S,KV,hd), "v": ..., "length": int32}
  encdec        : + {"enc_out": (B,T,d)}
  hybrid        : {"ssm": (L,B,H,P,N), "conv": (L,B,K-1,C), "attn": list of
                   per-occurrence {"k","v"}, "length": int32}
  ssm (rwkv)    : {"wkv": (L,B,H,P,P), "tshift": (L,B,1,d), "cshift": (L,B,1,d),
                   "length": int32}
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.hints import BATCH, hint

from .config import ModelConfig
from . import layers as L

PyTree = Any

# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def _norm_params(cfg: ModelConfig, key, shape_prefix, d, dtype):
    if cfg.norm == "rmsnorm":
        return {"s": jnp.zeros(shape_prefix + (d,), dtype)}
    return {
        "s": jnp.ones(shape_prefix + (d,), dtype),
        "b": jnp.zeros(shape_prefix + (d,), dtype),
    }


def _attn_params(cfg: ModelConfig, key, lead, dtype, std):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": jax.random.normal(ks[0], lead + (d, H, hd), dtype) * std,
        "wk": jax.random.normal(ks[1], lead + (d, KV, hd), dtype) * std,
        "wv": jax.random.normal(ks[2], lead + (d, KV, hd), dtype) * std,
        "wo": jax.random.normal(ks[3], lead + (H, hd, d), dtype) * std,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros(lead + (H, hd), dtype)
        p["bk"] = jnp.zeros(lead + (KV, hd), dtype)
        p["bv"] = jnp.zeros(lead + (KV, hd), dtype)
    return p


def _mlp_params(cfg: ModelConfig, key, lead, dtype, std, ff=None):
    d, ff = cfg.d_model, ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w1": jax.random.normal(ks[0], lead + (d, ff), dtype) * std,
        "w2": jax.random.normal(ks[1], lead + (ff, d), dtype) * std,
    }
    if cfg.act in ("swiglu", "geglu"):
        p["w3"] = jax.random.normal(ks[2], lead + (d, ff), dtype) * std
    return p


def _moe_params(cfg: ModelConfig, key, lead, dtype, std):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 7)
    p = {
        "router": jax.random.normal(ks[0], lead + (d, E), dtype) * std,
        "w1": jax.random.normal(ks[1], lead + (E, d, ff), dtype) * std,
        "w2": jax.random.normal(ks[2], lead + (E, ff, d), dtype) * std,
    }
    if cfg.act in ("swiglu", "geglu"):
        p["w3"] = jax.random.normal(ks[3], lead + (E, d, ff), dtype) * std
    if cfg.n_shared_experts:
        p["sw1"] = jax.random.normal(ks[4], lead + (d, ff), dtype) * std
        p["sw2"] = jax.random.normal(ks[5], lead + (ff, d), dtype) * std
        if cfg.act in ("swiglu", "geglu"):
            p["sw3"] = jax.random.normal(ks[6], lead + (d, ff), dtype) * std
    return p


def _mamba_params(cfg: ModelConfig, key, lead, dtype, std):
    d, di, N, H = cfg.d_model, cfg.d_inner_ssm, cfg.ssm_state, cfg.n_ssm_heads
    K = cfg.ssm_conv
    proj_out = 2 * di + 2 * N + H
    ks = jax.random.split(key, 3)
    return {
        "in_proj": jax.random.normal(ks[0], lead + (d, proj_out), dtype) * std,
        "out_proj": jax.random.normal(ks[1], lead + (di, d), dtype) * std,
        "conv_w": jax.random.normal(ks[2], lead + (K, di + 2 * N), dtype) * std,
        "dt_bias": jnp.full(lead + (H,), -4.6, dtype),  # softplus ~ 0.01
        "a_log": jnp.zeros(lead + (H,), dtype),  # A = -1
        "d_skip": jnp.ones(lead + (H,), dtype) * 0.1,
    }


def _rwkv_params(cfg: ModelConfig, key, lead, dtype, std):
    d, H, P, ff = cfg.d_model, cfg.n_heads, cfg.head_dim, cfg.d_ff
    R = 32  # decay LoRA rank
    ks = jax.random.split(key, 10)
    p = {
        "wr": jax.random.normal(ks[0], lead + (d, H, P), dtype) * std,
        "wk": jax.random.normal(ks[1], lead + (d, H, P), dtype) * std,
        "wv": jax.random.normal(ks[2], lead + (d, H, P), dtype) * std,
        "wg": jax.random.normal(ks[3], lead + (d, H, P), dtype) * std,
        "wo": jax.random.normal(ks[4], lead + (H, P, d), dtype) * std,
        "w_lora_a": jax.random.normal(ks[5], lead + (d, R), dtype) * std,
        "w_lora_b": jax.random.normal(ks[6], lead + (R, H * P), dtype) * std,
        "w_base": jnp.full(lead + (H, P), -0.6, dtype),
        "u_bonus": jnp.zeros(lead + (H, P), dtype),
        "ln_x": jnp.zeros(lead + (P,), dtype),
        "ck": jax.random.normal(ks[7], lead + (d, ff), dtype) * std,
        "cv": jax.random.normal(ks[8], lead + (ff, d), dtype) * std,
        "cr": jax.random.normal(ks[9], lead + (d, d), dtype) * std,
    }
    for name in ("r", "k", "v", "g", "w"):
        p[f"mu_{name}"] = jnp.full(lead + (d,), 0.5, dtype)
    p["mu_ck"] = jnp.full(lead + (d,), 0.5, dtype)
    p["mu_cr"] = jnp.full(lead + (d,), 0.5, dtype)
    return p


def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> PyTree:
    d = cfg.d_model
    std = 0.02
    keys = jax.random.split(key, 12)
    params: Dict[str, Any] = {
        "embed": jax.random.normal(keys[0], (cfg.vocab_size, d), dtype) * std,
    }
    params["final_norm"] = _norm_params(cfg, keys[1], (), d, dtype)
    if not cfg.tie_embeddings:
        params["out"] = jax.random.normal(keys[2], (d, cfg.vocab_size), dtype) * std

    Lc = (cfg.n_layers,)
    if cfg.rwkv:
        blk = _rwkv_params(cfg, keys[3], Lc, dtype, std)
        blk["ln1"] = _norm_params(cfg, keys[4], Lc, d, dtype)
        blk["ln2"] = _norm_params(cfg, keys[5], Lc, d, dtype)
        params["blocks"] = blk
        return params
    if cfg.family == "hybrid":
        blk = _mamba_params(cfg, keys[3], Lc, dtype, std)
        blk["ln1"] = _norm_params(cfg, keys[4], Lc, d, dtype)
        params["blocks"] = blk
        shared = _attn_params(cfg, keys[5], (), dtype, std)
        shared.update(_mlp_params(cfg, keys[6], (), dtype, std))
        shared["ln_a"] = _norm_params(cfg, keys[7], (), d, dtype)
        shared["ln_m"] = _norm_params(cfg, keys[8], (), d, dtype)
        params["shared_attn"] = shared
        return params
    if cfg.family == "encdec":
        Le = (cfg.n_encoder_layers,)
        enc = _attn_params(cfg, keys[3], Le, dtype, std)
        enc.update(_mlp_params(cfg, keys[4], Le, dtype, std))
        enc["ln1"] = _norm_params(cfg, keys[5], Le, d, dtype)
        enc["ln2"] = _norm_params(cfg, keys[6], Le, d, dtype)
        params["enc_blocks"] = enc
        params["enc_final_norm"] = _norm_params(cfg, keys[7], (), d, dtype)
        params["enc_pos"] = jax.random.normal(keys[8], (cfg.encoder_len, d), dtype) * std
        dec = _attn_params(cfg, keys[9], Lc, dtype, std)
        dec.update(_mlp_params(cfg, keys[10], Lc, dtype, std))
        xattn = _attn_params(cfg, keys[11], Lc, dtype, std)
        dec.update({f"x_{k}": v for k, v in xattn.items()})
        dec["ln1"] = _norm_params(cfg, keys[5], Lc, d, dtype)
        dec["ln2"] = _norm_params(cfg, keys[6], Lc, d, dtype)
        dec["lnx"] = _norm_params(cfg, keys[7], Lc, d, dtype)
        params["blocks"] = dec
        return params

    # dense / moe / vlm transformer
    blk = _attn_params(cfg, keys[3], Lc, dtype, std)
    if cfg.n_experts:
        blk.update(_moe_params(cfg, keys[4], Lc, dtype, std))
    else:
        blk.update(_mlp_params(cfg, keys[4], Lc, dtype, std))
    blk["ln1"] = _norm_params(cfg, keys[5], Lc, d, dtype)
    blk["ln2"] = _norm_params(cfg, keys[6], Lc, d, dtype)
    if cfg.post_block_norm:
        blk["ln1_post"] = _norm_params(cfg, keys[7], Lc, d, dtype)
        blk["ln2_post"] = _norm_params(cfg, keys[8], Lc, d, dtype)
    params["blocks"] = blk
    return params


def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16) -> PyTree:
    """ShapeDtypeStruct tree — no allocation (for dry-run lowering)."""
    return jax.eval_shape(lambda k: init_params(cfg, k, dtype), jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _pattern_unit(cfg: ModelConfig) -> int:
    """Layers per repeating pattern group (scan iterates over groups)."""
    if cfg.layer_pattern == "local_global":
        return 2  # (local, global)
    if cfg.layer_pattern == "chunked_full":
        return 4  # (chunked, chunked, chunked, full)
    return 1


def _unit_is_local(cfg: ModelConfig, u: int) -> bool:
    if cfg.layer_pattern == "local_global":
        return u == 0
    if cfg.layer_pattern == "chunked_full":
        return u != 3
    return False


def _transformer_block(cfg: ModelConfig, p, h, is_local: bool, kv_cache=None,
                       positions=None, fresh_cache=False):
    a_in = L.apply_norm(cfg, h, p["ln1"]["s"], p["ln1"].get("b"))
    a_out, new_cache = L.attention(
        cfg, p, a_in, layer_is_local=is_local, kv_cache=kv_cache,
        positions=positions, fresh_cache=fresh_cache,
    )
    if cfg.post_block_norm:
        a_out = L.apply_norm(cfg, a_out, p["ln1_post"]["s"], p["ln1_post"].get("b"))
    h = h + a_out
    m_in = L.apply_norm(cfg, h, p["ln2"]["s"], p["ln2"].get("b"))
    if cfg.n_experts:
        m_out = L.moe_ffn(cfg, p, m_in)
    else:
        m_out = L.mlp(cfg, p, m_in)
    if cfg.post_block_norm:
        m_out = L.apply_norm(cfg, m_out, p["ln2_post"]["s"], p["ln2_post"].get("b"))
    return h + m_out, new_cache


def _maybe_mixed_pattern(cfg: ModelConfig) -> bool:
    return cfg.layer_pattern in ("local_global", "chunked_full")


def _embed(cfg: ModelConfig, params, tokens, patches=None):
    # NOTE (§Perf, refuted hypothesis): batch-only sharding for rwkv was
    # tried to kill the per-layer seq all-gathers of the time scan; it made
    # the collective term WORSE (16s vs 9.4s) because the idle model axis
    # causes GSPMD to bounce activations instead.  Proper fix (future work):
    # channel-sharded WKV via shard_map.  Sequence-sharding stays.
    h = hint(params["embed"][tokens], BATCH, "model", None)
    if cfg.embed_scale:
        h = (h.astype(jnp.float32) * math.sqrt(cfg.d_model)).astype(h.dtype)
    if cfg.n_patches and patches is not None:
        np_ = patches.shape[1]
        h = jnp.concatenate([patches.astype(h.dtype), h[:, np_:, :]], axis=1)
    return h


def _unembed(cfg: ModelConfig, params, h):
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, params["out"])
    if cfg.final_softcap:
        logits = cfg.final_softcap * jnp.tanh(
            logits.astype(jnp.float32) / cfg.final_softcap
        ).astype(logits.dtype)
    return hint(logits, BATCH, None, "model")


def _mrope_positions(cfg: ModelConfig, B, S, offset=0):
    """Stub M-RoPE positions: text gets (t,t,t); patch region gets a 2-D grid."""
    pos = jnp.broadcast_to(jnp.arange(S)[None, :] + offset, (B, S))
    return jnp.stack([pos, pos, pos])  # (3, B, S)


def forward_lm(
    cfg: ModelConfig,
    params: PyTree,
    tokens,  # (B, S)
    *,
    patches=None,  # vlm stub input
    cache=None,
    remat: bool = False,
    fresh_cache: bool = False,
):
    """Dense / MoE / VLM decoder stack.  Returns (h_final, new_cache)."""
    B, S = tokens.shape
    h = _embed(cfg, params, tokens, patches)
    positions = None
    if cfg.mrope_sections is not None:
        off = 0 if cache is None else cache["length"]
        positions = _mrope_positions(cfg, B, S, off)

    blk = params["blocks"]
    U = _pattern_unit(cfg)
    G = cfg.n_layers // U
    assert G * U == cfg.n_layers, "n_layers must divide the layer pattern"
    blk_g = jax.tree.map(lambda x: x.reshape((G, U) + x.shape[1:]), blk)

    def body(carry, xs):
        h = carry
        if cache is None:
            p_g = xs
            for u in range(U):
                p = jax.tree.map(lambda x: x[u], p_g)
                h, _ = _transformer_block(
                    cfg, p, h, _unit_is_local(cfg, u), positions=positions
                )
            return h, None
        p_g, kg, vg = xs
        ks_out, vs_out = [], []
        for u in range(U):
            p = jax.tree.map(lambda x: x[u], p_g)
            kv = {"k": kg[u], "v": vg[u], "length": cache["length"]}
            h, nc = _transformer_block(
                cfg, p, h, _unit_is_local(cfg, u), kv_cache=kv,
                positions=positions, fresh_cache=fresh_cache,
            )
            ks_out.append(nc["k"])
            vs_out.append(nc["v"])
        return h, (jnp.stack(ks_out), jnp.stack(vs_out))

    body_fn = jax.checkpoint(body) if remat else body
    if cache is None:
        h, _ = jax.lax.scan(body_fn, h, blk_g)
        new_cache = None
    else:
        kc = cache["k"].reshape((G, U) + cache["k"].shape[1:])
        vc = cache["v"].reshape((G, U) + cache["v"].shape[1:])
        h, (ks, vs) = jax.lax.scan(body_fn, h, (blk_g, kc, vc))
        new_cache = {
            "k": ks.reshape(cache["k"].shape),
            "v": vs.reshape(cache["v"].shape),
            "length": cache["length"] + S,
        }
    h = L.apply_norm(cfg, h, params["final_norm"]["s"], params["final_norm"].get("b"))
    return h, new_cache


def forward_rwkv(cfg: ModelConfig, params, tokens, *, cache=None, remat=False):
    B, S = tokens.shape
    h = _embed(cfg, params, tokens)
    blk = params["blocks"]

    def body(carry, xs):
        h = carry
        p, wkv, tsh, csh = xs
        a_in = L.apply_norm(cfg, h, p["ln1"]["s"], p["ln1"].get("b"))
        y, wkv_n, tsh_n = L.rwkv6_time_mix(cfg, p, a_in, state=wkv, shift_state=tsh)
        h = h + y
        c_in = L.apply_norm(cfg, h, p["ln2"]["s"], p["ln2"].get("b"))
        y2, csh_n = L.rwkv6_channel_mix(cfg, p, c_in, shift_state=csh)
        return h + y2, (wkv_n, tsh_n, csh_n)

    body_fn = jax.checkpoint(body) if remat else body
    if cache is None:
        H, P, d = cfg.n_heads, cfg.head_dim, cfg.d_model
        wkv0 = jnp.zeros((cfg.n_layers, B, H, P, P), jnp.float32)
        tsh0 = jnp.zeros((cfg.n_layers, B, 1, d), h.dtype)
        csh0 = jnp.zeros((cfg.n_layers, B, 1, d), h.dtype)
    else:
        wkv0, tsh0, csh0 = cache["wkv"], cache["tshift"], cache["cshift"]
    h, (wkv, tsh, csh) = jax.lax.scan(body_fn, h, (blk, wkv0, tsh0, csh0))
    new_cache = {
        "wkv": wkv,
        "tshift": tsh,
        "cshift": csh,
        "length": (0 if cache is None else cache["length"]) + S,
    }
    h = L.apply_norm(cfg, h, params["final_norm"]["s"], params["final_norm"].get("b"))
    return h, new_cache


def forward_hybrid(cfg: ModelConfig, params, tokens, *, cache=None, remat=False):
    """Zamba2: mamba2 backbone + shared attention block every k layers."""
    B, S = tokens.shape
    h = _embed(cfg, params, tokens)
    blk = params["blocks"]
    shared = params["shared_attn"]
    k_every = cfg.shared_attn_every
    n_occ = cfg.n_layers // k_every
    length = 0 if cache is None else cache["length"]

    def mamba_body(carry, xs):
        h = carry
        p, ssm, conv = xs
        a_in = L.apply_norm(cfg, h, p["ln1"]["s"], p["ln1"].get("b"))
        y, ssm_n, conv_n = L.mamba2_block(cfg, p, a_in, ssm_state=ssm, conv_state=conv)
        return h + y, (ssm_n, conv_n)

    mamba_fn = jax.checkpoint(mamba_body) if remat else mamba_body

    if cache is None:
        H, P, N = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        C = cfg.d_inner_ssm + 2 * N
        ssm0 = jnp.zeros((cfg.n_layers, B, H, P, N), jnp.float32)
        conv0 = jnp.zeros((cfg.n_layers, B, cfg.ssm_conv - 1, C), h.dtype)
        attn_caches = [None] * n_occ
    else:
        ssm0, conv0 = cache["ssm"], cache["conv"]
        attn_caches = cache["attn"]

    ssm_out, conv_out, attn_out = [], [], []
    start = 0
    for occ in range(n_occ + 1):
        stop = min(start + k_every, cfg.n_layers)
        if stop > start:
            seg = jax.tree.map(lambda x: x[start:stop], blk)
            h, (ssm_n, conv_n) = jax.lax.scan(
                mamba_fn, h, (seg, ssm0[start:stop], conv0[start:stop])
            )
            ssm_out.append(ssm_n)
            conv_out.append(conv_n)
        if occ < n_occ:
            a_in = L.apply_norm(cfg, h, shared["ln_a"]["s"], shared["ln_a"].get("b"))
            kv = attn_caches[occ]
            if kv is not None:
                kv = {"k": kv["k"], "v": kv["v"], "length": length}
            y, nc = L.attention(cfg, shared, a_in, kv_cache=kv)
            h = h + y
            m_in = L.apply_norm(cfg, h, shared["ln_m"]["s"], shared["ln_m"].get("b"))
            h = h + L.mlp(cfg, shared, m_in)
            if nc is not None:
                attn_out.append({"k": nc["k"], "v": nc["v"]})
        start = stop
    h = L.apply_norm(cfg, h, params["final_norm"]["s"], params["final_norm"].get("b"))
    new_cache = {
        "ssm": jnp.concatenate(ssm_out, axis=0),
        "conv": jnp.concatenate(conv_out, axis=0),
        "attn": attn_out if attn_out else attn_caches,
        "length": length + S,
    }
    return h, new_cache


def forward_encoder(cfg: ModelConfig, params, frames, *, remat=False):
    """Whisper encoder over precomputed frame embeddings (stub frontend)."""
    h = frames + params["enc_pos"][None, : frames.shape[1], :].astype(frames.dtype)
    blk = params["enc_blocks"]

    def body(carry, p):
        h = carry
        a_in = L.apply_norm(cfg, h, p["ln1"]["s"], p["ln1"].get("b"))
        y, _ = L.attention(cfg, p, a_in, causal=False)
        h = h + y
        m_in = L.apply_norm(cfg, h, p["ln2"]["s"], p["ln2"].get("b"))
        return h + L.mlp(cfg, p, m_in), None

    body_fn = jax.checkpoint(body) if remat else body
    h, _ = jax.lax.scan(body_fn, h, blk)
    return L.apply_norm(
        cfg, h, params["enc_final_norm"]["s"], params["enc_final_norm"].get("b")
    )


def forward_encdec(cfg: ModelConfig, params, tokens, frames=None, *, cache=None, remat=False):
    B, S = tokens.shape
    if cache is not None and "enc_out" in cache:
        enc_out = cache["enc_out"]
    else:
        enc_out = forward_encoder(cfg, params, frames, remat=remat)
    h = _embed(cfg, params, tokens)
    blk = params["blocks"]

    def body(carry, xs):
        h = carry
        if cache is None:
            p = xs
            kv = None
        else:
            p, kl, vl = xs
            kv = {"k": kl, "v": vl, "length": cache["length"]}
        a_in = L.apply_norm(cfg, h, p["ln1"]["s"], p["ln1"].get("b"))
        y, nc = L.attention(cfg, p, a_in, kv_cache=kv)
        h = h + y
        x_in = L.apply_norm(cfg, h, p["lnx"]["s"], p["lnx"].get("b"))
        xp = {k[2:]: v for k, v in p.items() if k.startswith("x_")}
        h = h + L.cross_attention(cfg, xp, x_in, enc_out)
        m_in = L.apply_norm(cfg, h, p["ln2"]["s"], p["ln2"].get("b"))
        h = h + L.mlp(cfg, p, m_in)
        return h, (None if cache is None else (nc["k"], nc["v"]))

    body_fn = jax.checkpoint(body) if remat else body
    if cache is None:
        h, _ = jax.lax.scan(body_fn, h, blk)
        new_cache = None
    else:
        h, (ks, vs) = jax.lax.scan(body_fn, h, (blk, cache["k"], cache["v"]))
        new_cache = {
            "k": ks,
            "v": vs,
            "length": cache["length"] + S,
            "enc_out": enc_out,
        }
    h = L.apply_norm(cfg, h, params["final_norm"]["s"], params["final_norm"].get("b"))
    return h, new_cache


def forward(cfg: ModelConfig, params, batch: Dict[str, Any], *, cache=None,
            remat=False, fresh_cache=False):
    if cfg.rwkv:
        return forward_rwkv(cfg, params, batch["tokens"], cache=cache, remat=remat)
    if cfg.family == "hybrid":
        return forward_hybrid(cfg, params, batch["tokens"], cache=cache, remat=remat)
    if cfg.family == "encdec":
        return forward_encdec(
            cfg, params, batch["tokens"], batch.get("frames"), cache=cache, remat=remat
        )
    return forward_lm(
        cfg, params, batch["tokens"], patches=batch.get("patches"), cache=cache,
        remat=remat, fresh_cache=fresh_cache,
    )


# ---------------------------------------------------------------------------
# Losses and serving steps
# ---------------------------------------------------------------------------


def lm_loss(cfg: ModelConfig, params, batch, *, remat=True):
    """Next-token cross-entropy (predict t+1 from t).

    The gold logit is extracted with a one-hot contraction rather than
    take_along_axis: gathering along the vocab dim would force an all-gather
    of the (B, S, V) logits when V is sharded over 'model'.
    """
    h, _ = forward(cfg, params, batch, remat=remat)
    logits = _unembed(cfg, params, h[:, :-1, :]).astype(jnp.float32)
    targets = batch["tokens"][:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, cfg.vocab_size, dtype=logits.dtype)
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
    return jnp.mean(logz - gold)


def prefill(cfg: ModelConfig, params, batch, max_len: int, cache_dtype=jnp.bfloat16):
    """Run the prompt, build a KV/state cache of capacity max_len."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    cache = init_cache(cfg, B, max_len, dtype=cache_dtype)
    if cfg.family == "encdec":
        cache["enc_out"] = forward_encoder(cfg, params, batch["frames"])
    h, cache = forward(cfg, params, batch, cache=cache, fresh_cache=True)
    logits = _unembed(cfg, params, h[:, -1:, :])
    return logits, cache


def decode_step(cfg: ModelConfig, params, cache, tokens):
    """One token per sequence: tokens (B, 1) -> (logits (B,1,V), cache)."""
    h, cache = forward(cfg, params, {"tokens": tokens}, cache=cache)
    logits = _unembed(cfg, params, h[:, -1:, :])
    return logits, cache


def init_cache(cfg: ModelConfig, B: int, max_len: int, dtype=jnp.bfloat16):
    KV, hd, Ln = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    zero = jnp.asarray(0, jnp.int32)
    if cfg.rwkv:
        H, P, d = cfg.n_heads, cfg.head_dim, cfg.d_model
        return {
            "wkv": jnp.zeros((Ln, B, H, P, P), jnp.float32),
            "tshift": jnp.zeros((Ln, B, 1, d), dtype),
            "cshift": jnp.zeros((Ln, B, 1, d), dtype),
            "length": zero,
        }
    if cfg.family == "hybrid":
        H, P, N = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        C = cfg.d_inner_ssm + 2 * N
        n_occ = cfg.n_layers // cfg.shared_attn_every
        return {
            "ssm": jnp.zeros((Ln, B, H, P, N), jnp.float32),
            "conv": jnp.zeros((Ln, B, cfg.ssm_conv - 1, C), dtype),
            "attn": [
                {
                    "k": jnp.zeros((B, max_len, KV, hd), dtype),
                    "v": jnp.zeros((B, max_len, KV, hd), dtype),
                }
                for _ in range(n_occ)
            ],
            "length": zero,
        }
    return {
        "k": jnp.zeros((Ln, B, max_len, KV, hd), dtype),
        "v": jnp.zeros((Ln, B, max_len, KV, hd), dtype),
        "length": zero,
    }


def abstract_cache(cfg: ModelConfig, B: int, max_len: int, dtype=jnp.bfloat16):
    """ShapeDtypeStruct cache for dry-run decode lowering (no allocation)."""
    return jax.eval_shape(lambda: init_cache(cfg, B, max_len, dtype))
