"""Fault-tolerant numpy-based checkpointing (no orbax dependency).

Design for 1000+-node operation:
  * atomic: write to  step_<n>.tmp/  then os.rename -> step_<n>/  (a crashed
    save never shadows the previous checkpoint);
  * integrity: per-array CRC32 recorded in manifest.json and verified on
    restore;
  * elastic restart: arrays are saved UNSHARDED (gathered); restore takes a
    target sharding tree and device_puts onto the *current* mesh, so the chip
    count may change between runs;
  * bfloat16 is stored as a uint16 view (npz has no native bf16);
  * keep_last_k garbage collection;
  * async=True saves on a background thread (training continues).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any

_SEP = "//"


def _flatten(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep_last_k: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep_last_k
        self._thread: Optional[threading.Thread] = None

    # -- save -----------------------------------------------------------
    def save(self, step: int, tree: PyTree, *, async_: bool = False) -> None:
        host = {}
        flat, _ = _flatten(tree)
        for key, leaf in flat.items():
            arr = np.asarray(jax.device_get(leaf))
            host[key] = arr
        if async_:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, host)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: dict) -> None:
        tmp = self.dir / f"step_{step:010d}.tmp"
        final = self.dir / f"step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "arrays": {}}
        store = {}
        for key, arr in host.items():
            dtype = str(arr.dtype)
            if dtype == "bfloat16":
                view = arr.view(np.uint16)
            else:
                view = arr
            store[key] = view
            manifest["arrays"][key] = {
                "dtype": dtype,
                "shape": list(arr.shape),
                "crc32": zlib.crc32(np.ascontiguousarray(view).tobytes()),
            }
        np.savez(tmp / "arrays.npz", **store)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        with open(tmp / "manifest.json") as f:
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # -- restore ----------------------------------------------------------
    def all_steps(self):
        out = []
        for p in self.dir.iterdir():
            if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp"):
                out.append(int(p.name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        abstract_tree: PyTree,
        *,
        step: Optional[int] = None,
        shardings: Optional[PyTree] = None,
        verify: bool = True,
    ) -> PyTree:
        """Restore into the structure of `abstract_tree` (re-sharded if given).

        Elastic restart: `shardings` reflects the *current* mesh; arrays are
        placed per-leaf with device_put, so restarts may change chip count.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        data = np.load(d / "arrays.npz")
        flat_abs, treedef = _flatten(abstract_tree)
        flat_sh, _ = _flatten(shardings) if shardings is not None else ({}, None)
        leaves = []
        for key, leaf in flat_abs.items():
            meta = manifest["arrays"][key]
            arr = data[key]
            if verify:
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                if crc != meta["crc32"]:
                    raise IOError(f"checksum mismatch for {key} at step {step}")
            if meta["dtype"] == "bfloat16":
                import ml_dtypes

                arr = arr.view(ml_dtypes.bfloat16)
            if flat_sh:
                leaves.append(jax.device_put(arr, flat_sh[key]))
            else:
                leaves.append(jax.device_put(arr))
        keys = list(flat_abs.keys())
        order = {k: i for i, k in enumerate(keys)}
        return jax.tree_util.tree_unflatten(treedef, [leaves[order[k]] for k in keys])
