"""Fault-tolerant numpy-based checkpointing (no orbax dependency).

Design for 1000+-node operation:
  * atomic: write to  step_<n>.tmp/  then os.rename -> step_<n>/  (a crashed
    save never shadows the previous checkpoint);
  * integrity: per-array CRC32 recorded in manifest.json and verified on
    restore;
  * elastic restart: arrays are saved UNSHARDED (gathered); restore takes a
    target sharding tree and device_puts onto the *current* mesh, so the chip
    count may change between runs;
  * bfloat16 is stored as a uint16 view (npz has no native bf16);
  * keep_last_k garbage collection;
  * async=True saves on a background thread (training continues).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any

_SEP = "//"


class CheckpointCorruptError(IOError):
    """A checkpoint failed integrity verification.

    Raised (instead of a bare KeyError / numpy load failure) whenever the
    on-disk state of a step is unusable: a truncated or unparseable
    manifest.json, an array named by the restore tree but absent from the
    manifest or the npz payload, or a CRC32 mismatch.  The message always
    names the offending array (or file) and the step, so operators of
    long-horizon runs can tell a bad disk from a version skew at a glance.
    Subclasses IOError: existing ``except IOError`` recovery paths keep
    working.
    """


def _flatten(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep_last_k: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep_last_k
        self._thread: Optional[threading.Thread] = None
        # a crash mid-save leaves step_<n>.tmp/ behind; it never shadows a
        # finished checkpoint (the rename is the commit point) but it does
        # leak disk on every restart of a preempted job — sweep it here
        for p in self.dir.glob("step_*.tmp"):
            if p.is_dir():
                shutil.rmtree(p, ignore_errors=True)

    # -- save -----------------------------------------------------------
    def save(self, step: int, tree: PyTree, *, async_: bool = False) -> None:
        host = {}
        flat, _ = _flatten(tree)
        for key, leaf in flat.items():
            arr = np.asarray(jax.device_get(leaf))
            host[key] = arr
        if async_:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, host)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: dict) -> None:
        tmp = self.dir / f"step_{step:010d}.tmp"
        final = self.dir / f"step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "arrays": {}}
        store = {}
        for key, arr in host.items():
            dtype = str(arr.dtype)
            if dtype == "bfloat16":
                view = arr.view(np.uint16)
            else:
                view = arr
            store[key] = view
            manifest["arrays"][key] = {
                "dtype": dtype,
                "shape": list(arr.shape),
                "crc32": zlib.crc32(np.ascontiguousarray(view).tobytes()),
            }
        np.savez(tmp / "arrays.npz", **store)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        with open(tmp / "manifest.json") as f:
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # -- restore ----------------------------------------------------------
    def all_steps(self):
        out = []
        for p in self.dir.iterdir():
            if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp"):
                out.append(int(p.name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _load_step(self, step: int):
        """Manifest + npz handle for a step, with corruption surfaced."""
        d = self.dir / f"step_{step:010d}"
        try:
            manifest = json.loads((d / "manifest.json").read_text())
            arrays_meta = manifest["arrays"]
        except (OSError, json.JSONDecodeError, KeyError, TypeError) as e:
            raise CheckpointCorruptError(
                f"manifest.json at step {step} in {self.dir} is missing or "
                f"truncated ({type(e).__name__}: {e})"
            ) from e
        try:
            data = np.load(d / "arrays.npz")
        except Exception as e:
            raise CheckpointCorruptError(
                f"arrays.npz at step {step} in {self.dir} is unreadable "
                f"({type(e).__name__}: {e})"
            ) from e
        return arrays_meta, data

    def _read_array(self, arrays_meta, data, key: str, step: int, verify: bool):
        meta = arrays_meta.get(key)
        if meta is None:
            raise CheckpointCorruptError(
                f"array '{key}' missing from manifest at step {step} "
                f"in {self.dir}"
            )
        try:
            arr = data[key]
        except Exception as e:
            raise CheckpointCorruptError(
                f"array '{key}' unreadable in arrays.npz at step {step} "
                f"in {self.dir} ({type(e).__name__}: {e})"
            ) from e
        if verify:
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != meta["crc32"]:
                raise CheckpointCorruptError(
                    f"checksum mismatch for array '{key}' at step {step} "
                    f"in {self.dir}"
                )
        if meta["dtype"] == "bfloat16":
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)
        return arr

    def restore_flat(
        self, *, step: Optional[int] = None, verify: bool = True
    ) -> dict:
        """Restore every saved array as a flat {key: np.ndarray} dict.

        For consumers whose tree structure is data-dependent (e.g. a
        resumable sweep's per-spec result records): the saved keys ARE the
        structure, so no abstract tree is required.  Keys use the same
        ``//``-joined paths that save() flattens to; integrity checks match
        restore().
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        arrays_meta, data = self._load_step(step)
        return {
            key: self._read_array(arrays_meta, data, key, step, verify)
            for key in arrays_meta
        }

    def restore(
        self,
        abstract_tree: PyTree,
        *,
        step: Optional[int] = None,
        shardings: Optional[PyTree] = None,
        verify: bool = True,
    ) -> PyTree:
        """Restore into the structure of `abstract_tree` (re-sharded if given).

        Elastic restart: `shardings` reflects the *current* mesh; arrays are
        placed per-leaf with device_put, so restarts may change chip count.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        arrays_meta, data = self._load_step(step)
        flat_abs, treedef = _flatten(abstract_tree)
        flat_sh, _ = _flatten(shardings) if shardings is not None else ({}, None)
        leaves = []
        for key, leaf in flat_abs.items():
            arr = self._read_array(arrays_meta, data, key, step, verify)
            if flat_sh:
                leaves.append(jax.device_put(arr, flat_sh[key]))
            else:
                leaves.append(jax.device_put(arr))
        keys = list(flat_abs.keys())
        order = {k: i for i, k in enumerate(keys)}
        return jax.tree_util.tree_unflatten(treedef, [leaves[order[k]] for k in keys])
