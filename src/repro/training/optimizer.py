"""AdamW in pure JAX with configurable moment dtype.

For >=100B-parameter models, fp32 moments exceed v5e HBM at our sharding;
`moment_dtype=bfloat16` halves optimizer memory (a standard large-model
trick; quantization error is absorbed by Adam's normalization).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: Any = jnp.float32  # jnp.bfloat16 for huge models
    grad_clip: float = 1.0


def adamw_init(params: PyTree, cfg: AdamWConfig) -> PyTree:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    """Factored second-moment optimizer (Shazeer & Stern, 2018).

    Optimizer state is O(rows + cols) per matrix instead of O(rows * cols):
    the only way a 314B-parameter model trains on 256 v5e chips (16 GB HBM)
    together with its gradients and activations.
    """

    lr: float = 1e-3
    decay: float = 0.8  # beta2 exponent schedule base (hat-beta2_t)
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0


def adafactor_init(params: PyTree, cfg: AdafactorConfig) -> PyTree:
    def factors(p):
        if p.ndim < 2:
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {
            "vr": jnp.zeros(p.shape[:-1], jnp.float32),
            "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
        }

    return {
        "f": jax.tree.map(factors, params, is_leaf=lambda x: hasattr(x, "ndim")),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(
    grads: PyTree, opt_state: PyTree, params: PyTree, cfg: AdafactorConfig
):
    step = opt_state["step"] + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** (-cfg.decay)

    def upd(g, f, p):
        g = g.astype(jnp.float32)
        g2 = g * g + cfg.eps
        if p.ndim < 2:
            v = beta2 * f["v"] + (1 - beta2) * g2
            u = g * jax.lax.rsqrt(v + cfg.eps)
            newf = {"v": v}
        else:
            vr = beta2 * f["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * f["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = jnp.mean(vr, axis=-1, keepdims=True)
            u = (
                g
                * jax.lax.rsqrt(vr / jnp.maximum(denom, cfg.eps))[..., None]
                * jax.lax.rsqrt(vc)[..., None, :]
            )
            newf = {"vr": vr, "vc": vc}
        rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms_u / cfg.clip_threshold)
        p_new = p.astype(jnp.float32) - cfg.lr * (
            u + cfg.weight_decay * p.astype(jnp.float32)
        )
        return p_new.astype(p.dtype), newf

    # grads' treedef is used; opt_state["f"] is flattened *up to* it, so each
    # factor dict arrives whole at upd().
    out = jax.tree.map(upd, grads, opt_state["f"], params)
    is_pair = lambda t_: isinstance(t_, tuple)
    new_params = jax.tree.map(lambda t_: t_[0], out, is_leaf=is_pair)
    new_f = jax.tree.map(lambda t_: t_[1], out, is_leaf=is_pair)
    return new_params, {"f": new_f, "step": step}


def adamw_update(
    grads: PyTree, opt_state: PyTree, params: PyTree, cfg: AdamWConfig
):
    """Returns (new_params, new_opt_state, grad_norm)."""
    step = opt_state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-12))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m_new / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v_new / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        p_new = p.astype(jnp.float32) - cfg.lr * delta
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, gnorm


def opt_init(params: PyTree, cfg) -> PyTree:
    if isinstance(cfg, AdafactorConfig):
        return adafactor_init(params, cfg)
    return adamw_init(params, cfg)


def opt_update(grads: PyTree, opt_state: PyTree, params: PyTree, cfg):
    """Dispatch on optimizer config type; returns (params, opt_state, gnorm)."""
    if isinstance(cfg, AdafactorConfig):
        p, s = adafactor_update(grads, opt_state, params, cfg)
        return p, s, _global_norm(grads)
    return adamw_update(grads, opt_state, params, cfg)
