"""Deterministic synthetic token pipeline (seeded, shardable, restart-safe).

The stream is a pure function of (seed, step): restoring a checkpoint at
step N reproduces exactly the batches the crashed run would have seen — no
pipeline state to persist beyond the step counter.  A Zipf-ish marginal over
the vocab plus a short-range Markov blend gives the loss curve enough
structure to be a meaningful smoke-train signal (pure uniform tokens give a
flat loss == log V).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2


def batch_at_step(cfg: DataConfig, step: int) -> dict:
    """Batch for `step` (pure function — the restart-safety property)."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    k1, k2 = jax.random.split(key)
    # Zipf marginal via inverse-CDF on uniform
    ranks = jnp.arange(1, cfg.vocab_size + 1, dtype=jnp.float32)
    probs = ranks ** (-cfg.zipf_a)
    probs = probs / probs.sum()
    toks = jax.random.choice(
        k1, cfg.vocab_size, (cfg.global_batch, cfg.seq_len), p=probs
    )
    # short-range structure: with p=0.5, token t+1 = (token t + 1) mod V
    rep = jax.random.bernoulli(k2, 0.5, toks.shape)
    shifted = jnp.roll(toks, 1, axis=1) + 1
    toks = jnp.where(rep, shifted % cfg.vocab_size, toks)
    return {"tokens": toks.astype(jnp.int32)}


class DataIterator:
    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step

    def __next__(self) -> dict:
        b = batch_at_step(self.cfg, self.step)
        self.step += 1
        return b
