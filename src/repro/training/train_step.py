"""jit-able train step: loss + grads + AdamW (+optional grad compression)."""
from __future__ import annotations

from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig

from .optimizer import AdamWConfig, AdafactorConfig, opt_update


def make_train_step(
    cfg: ModelConfig,
    opt_cfg,  # AdamWConfig | AdafactorConfig
    *,
    remat: bool = True,
    n_micro: int = 1,
    accum_dtype=jnp.float32,
):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    n_micro > 1 enables microbatched gradient accumulation (f32 accumulator,
    sharded like the params): per-microbatch live activations shrink by
    n_micro, which is what fits 4k-seq training of 32B-314B models in v5e
    HBM on the fixed 16x16 mesh.
    """

    def loss_fn(params, batch):
        return M.lm_loss(cfg, params, batch, remat=remat)

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def reshape(x):
                b = x.shape[0]
                assert b % n_micro == 0, (b, n_micro)
                return x.reshape((n_micro, b // n_micro) + x.shape[1:])

            micro_batches = jax.tree.map(reshape, batch)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params
            )

            def micro(acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree.map(
                    lambda a, gi: a + (gi / n_micro).astype(accum_dtype), acc, g
                )
                return acc, l

            grads, losses = jax.lax.scan(micro, g0, micro_batches)
            loss = jnp.mean(losses)
        params, opt_state, gnorm = opt_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step
