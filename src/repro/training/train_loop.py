"""Restart-safe training loop: checkpoint/resume, preemption, straggler watch.

Fault-tolerance contract:
  * checkpoint every `ckpt_every` steps (async, atomic, keep-k) covering
    params + optimizer state + step;
  * the data pipeline is a pure function of step (training/data.py), so
    resume needs no pipeline state;
  * SIGTERM/SIGINT triggers a synchronous save then a clean exit
    (preemption-safe on spot/evictable capacity);
  * a per-step wall-clock watchdog flags straggler steps (z-score over a
    moving window) — on a real fleet this feeds the re-slicing controller.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.models import model as M
from repro.models.config import ModelConfig

from .data import DataConfig, batch_at_step
from .optimizer import AdamWConfig, opt_init
from .train_step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    keep_last_k: int = 3
    log_every: int = 10
    n_micro: int = 1
    straggler_window: int = 32
    straggler_zscore: float = 3.0


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        data: DataConfig,
        opt_cfg: Optional[AdamWConfig] = None,
        tcfg: Optional[TrainerConfig] = None,
        log_fn: Callable[[str], None] = print,
    ):
        self.cfg = cfg
        self.data = data
        self.opt_cfg = opt_cfg or AdamWConfig()
        self.tcfg = tcfg or TrainerConfig()
        self.log = log_fn
        self.manager = CheckpointManager(self.tcfg.ckpt_dir, self.tcfg.keep_last_k)
        self.step_fn = jax.jit(
            make_train_step(cfg, self.opt_cfg, remat=True, n_micro=self.tcfg.n_micro)
        )
        self._preempted = False
        self.step_times: list = []

    def _install_signal_handlers(self):
        def handler(signum, frame):  # pragma: no cover - signal path
            self._preempted = True

        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # non-main thread (tests)

    def init_or_restore(self, seed: int = 0):
        params = M.init_params(self.cfg, jax.random.PRNGKey(seed))
        opt_state = opt_init(params, self.opt_cfg)
        start = 0
        latest = self.manager.latest_step()
        if latest is not None:
            state = self.manager.restore({"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start = latest
            self.log(f"[trainer] resumed from step {start}")
        return params, opt_state, start

    def _watch_straggler(self, dt: float, step: int):
        w = self.step_times[-self.tcfg.straggler_window :]
        if len(w) >= 8:
            mu, sd = float(np.mean(w)), float(np.std(w) + 1e-9)
            if dt > mu + self.tcfg.straggler_zscore * sd:
                self.log(
                    f"[watchdog] step {step} took {dt:.3f}s "
                    f"(window mean {mu:.3f}s) — straggler suspected"
                )
        self.step_times.append(dt)

    def run(self, seed: int = 0):
        self._install_signal_handlers()
        params, opt_state, start = self.init_or_restore(seed)
        losses = []
        for step in range(start, self.tcfg.steps):
            batch = batch_at_step(self.data, step)
            t0 = time.perf_counter()
            params, opt_state, metrics = self.step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])  # blocks; acts as step barrier
            dt = time.perf_counter() - t0
            self._watch_straggler(dt, step)
            losses.append(loss)
            if step % self.tcfg.log_every == 0:
                self.log(f"[trainer] step {step} loss {loss:.4f} ({dt:.3f}s)")
            done = step + 1
            if done % self.tcfg.ckpt_every == 0 or done == self.tcfg.steps:
                self.manager.save(
                    done, {"params": params, "opt": opt_state}, async_=True
                )
            if self._preempted:
                self.log(f"[trainer] preemption signal at step {done}; saving")
                self.manager.wait()
                self.manager.save(done, {"params": params, "opt": opt_state})
                break
        self.manager.wait()
        return params, opt_state, losses
