"""Pluggable arrival processes for the unified serving kernel, both backends.

The serving engine (serving.engine) is one event-driven loop; what differs
between scenarios is *where the next request comes from*.  An
ArrivalProcess answers exactly that — `next(rng)` yields the next
ArrivalEvent (time, optional payload/deadline) or None when the stream is
exhausted — and carries its own snapshot()/restore() state so every arrival
mode is restart-safe through the engine's checkpointing.

Implemented processes:
  * PoissonProcess — the paper's M/G^[b]/1 arrival side (rate lambda);
  * MMPP2Process   — two-phase Markov-modulated Poisson (paper Sec. VIII's
    "temporal composition of Poisson periods"); MMPP2 holds the parameters;
  * DiurnalProcess — time-varying rate (sinusoidal or piecewise-linear
    ramp), sampled exactly by thinning against the peak rate;
  * TraceProcess   — replay of recorded arrival times or Request objects
    (executor mode and like-for-like scheduler comparisons).

`as_process` coerces a rate, an MMPP2, an array of times, or a Request list
into the right process, so engine call-sites stay terse.

PhaseBeliefFilter is the MMPP forward filter (posterior over the hidden
phase from observed inter-arrival gaps) behind the serving layer's
non-oracle phase-indexed schedulers (scheduler.BeliefPhaseScheduler and
AdaptiveController(phase_filter=...)).

The compiled backend (serving.compiled) replays every mode as a padded
sorted arrival array.  Two routes produce one:
  * eager pre-generation — `take(process, rng, ...)` drains the stateful
    numpy process up to a horizon/count, consuming exactly the draws the
    lazy engine path would (draw-for-draw parity with backend="python");
  * scan-compatible jax samplers — `poisson_times_jax` /`mmpp2_times_jax`
    generate whole seed batches on-device (the MMPP2 phase chain folded
    into the sampler's scan carry), for statistically-equivalent
    seeds x scenarios sweeps at device throughput.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class ArrivalEvent:
    """One arrival: absolute time plus optional request attributes."""

    time: float
    payload: object = None
    deadline: Optional[float] = None  # absolute-time SLO; None = engine default
    rid: Optional[int] = None  # None = engine assigns the next id


class ArrivalProcess:
    """Stateful generator of successive arrivals (monotone in time)."""

    name = "base"

    def next(self, rng: np.random.Generator) -> Optional[ArrivalEvent]:
        raise NotImplementedError  # pragma: no cover - interface

    @property
    def mean_rate(self) -> float:
        raise NotImplementedError  # pragma: no cover - interface

    def snapshot(self) -> dict:
        return {}

    def restore(self, state: dict) -> None:
        pass


class PoissonProcess(ArrivalProcess):
    """Homogeneous Poisson arrivals at rate lam (i.i.d. exponential gaps)."""

    name = "poisson"

    def __init__(self, lam: float):
        if lam <= 0:
            raise ValueError(f"lam must be positive, got {lam}")
        self.lam = float(lam)
        self._t = 0.0

    def next(self, rng: np.random.Generator) -> ArrivalEvent:
        self._t += rng.exponential(1.0 / self.lam)
        return ArrivalEvent(self._t)

    @property
    def mean_rate(self) -> float:
        return self.lam

    def snapshot(self) -> dict:
        return {"t": self._t}

    def restore(self, state: dict) -> None:
        self._t = state["t"]


@dataclasses.dataclass(frozen=True)
class MMPP2:
    """Two-phase MMPP: rates lam1 < lam2, mean phase dwell times t1, t2."""

    lam1: float
    lam2: float
    dwell1: float
    dwell2: float

    @property
    def mean_rate(self) -> float:
        p1 = self.dwell1 / (self.dwell1 + self.dwell2)
        return p1 * self.lam1 + (1 - p1) * self.lam2

    def process(self) -> "MMPP2Process":
        return MMPP2Process(self)

    def sample_arrivals(self, horizon: float, rng: np.random.Generator):
        """Arrival times in [0, horizon) and the phase trace.

        Thin wrapper over MMPP2Process so the eager and lazy paths share one
        generator (identical draws for every arrival below the horizon).
        """
        proc = MMPP2Process(self, log_switches=True)
        arrivals: List[float] = []
        while True:
            ev = proc.next(rng)
            if ev.time >= horizon:
                break
            arrivals.append(ev.time)
        return np.asarray(arrivals), list(proc.switch_log)


class MMPP2Process(ArrivalProcess):
    """Lazy MMPP(2) arrival generator; state = (phase, next switch time)."""

    name = "mmpp2"

    def __init__(self, mmpp: MMPP2, log_switches: bool = False):
        self.mmpp = mmpp
        self._t = 0.0
        self.phase = 0
        self._next_switch: Optional[float] = None  # drawn on first next()
        self.switch_log: List[Tuple[float, int]] = [(0.0, 0)] if log_switches else []
        self._log = log_switches

    def _rate(self) -> float:
        return self.mmpp.lam1 if self.phase == 0 else self.mmpp.lam2

    def _dwell(self) -> float:
        return self.mmpp.dwell1 if self.phase == 0 else self.mmpp.dwell2

    def next(self, rng: np.random.Generator) -> ArrivalEvent:
        if self._next_switch is None:
            self._next_switch = rng.exponential(self._dwell())
        while True:
            dt = rng.exponential(1.0 / self._rate())
            if self._t + dt >= self._next_switch:
                self._t = self._next_switch
                self.phase ^= 1
                if self._log:
                    self.switch_log.append((self._t, self.phase))
                self._next_switch = self._t + rng.exponential(self._dwell())
                continue
            self._t += dt
            return ArrivalEvent(self._t)

    @property
    def mean_rate(self) -> float:
        return self.mmpp.mean_rate

    def snapshot(self) -> dict:
        return {
            "t": self._t,
            "phase": self.phase,
            "next_switch": self._next_switch,
            "switch_log": list(self.switch_log),
        }

    def restore(self, state: dict) -> None:
        self._t = state["t"]
        self.phase = state["phase"]
        self._next_switch = state["next_switch"]
        self.switch_log = [tuple(x) for x in state["switch_log"]]


class DiurnalProcess(ArrivalProcess):
    """Time-varying Poisson arrivals: sinusoidal or piecewise-linear rate.

    rate(t) = base + amp * sin(2 pi (t + phase0) / period), or — when
    ``ramp`` is given — the cyclic piecewise-linear interpolation of
    [(tau_i, rate_i)] breakpoints over one period.  Sampling is exact via
    thinning against the peak rate (candidate gaps at rate_max, accepted
    with probability rate(t)/rate_max), so snapshot state is just the
    clock.  Closes the ROADMAP "richer arrival processes (diurnal ramps)"
    note; the scan-compatible jax mirror is diurnal_times_jax.
    """

    name = "diurnal"

    def __init__(
        self,
        base: float = 1.0,
        amp: float = 0.0,
        period: float = 86400.0,
        phase0: float = 0.0,
        ramp: Optional[Sequence[Tuple[float, float]]] = None,
    ):
        if period <= 0:
            raise ValueError(f"period must be positive, got {period}")
        self.period = float(period)
        self.phase0 = float(phase0)
        self.base = float(base)
        self.amp = float(amp)
        if ramp is not None:
            pts = sorted((float(t), float(r)) for t, r in ramp)
            if not pts:
                raise ValueError("ramp needs at least one breakpoint")
            if pts[0][0] < 0 or pts[-1][0] >= self.period:
                raise ValueError("ramp breakpoints must lie in [0, period)")
            self._taus = np.array([t for t, _ in pts])
            self._vals = np.array([r for _, r in pts])
            self.rate_max = float(self._vals.max())
            rate_min = float(self._vals.min())
        else:
            self._taus = self._vals = None
            self.rate_max = self.base + abs(self.amp)
            rate_min = self.base - abs(self.amp)
        if rate_min <= 0:
            raise ValueError("rate must stay positive over the whole cycle")
        self._t = 0.0

    def rate(self, t) -> np.ndarray:
        """Instantaneous arrival rate at (absolute) time t."""
        tau = np.mod(np.asarray(t, dtype=np.float64) + self.phase0, self.period)
        if self._taus is None:
            return self.base + self.amp * np.sin(2.0 * np.pi * tau / self.period)
        # cyclic linear interpolation: wrap the first breakpoint past the end
        taus = np.concatenate([self._taus, [self._taus[0] + self.period]])
        vals = np.concatenate([self._vals, [self._vals[0]]])
        return np.interp(
            np.where(tau < taus[0], tau + self.period, tau), taus, vals
        )

    @property
    def mean_rate(self) -> float:
        if self._taus is None:
            return self.base  # sine integrates to zero over a cycle
        grid = np.linspace(0.0, self.period, 4097)
        trapezoid = getattr(np, "trapezoid", None) or np.trapz
        return float(trapezoid(self.rate(grid - self.phase0), grid) / self.period)

    def next(self, rng: np.random.Generator) -> ArrivalEvent:
        while True:
            self._t += rng.exponential(1.0 / self.rate_max)
            if rng.uniform() * self.rate_max < float(self.rate(self._t)):
                return ArrivalEvent(self._t)

    def snapshot(self) -> dict:
        return {"t": self._t}

    def restore(self, state: dict) -> None:
        self._t = state["t"]


class TraceProcess(ArrivalProcess):
    """Replay a recorded arrival trace (times, or Request-like objects).

    Accepts an array of arrival times or a sequence of objects exposing
    .arrival (and optionally .payload / .deadline / .rid, e.g. engine
    Requests).  The same trace through two engine modes yields the same
    admission sequence — the basis of like-for-like scheduler comparisons.
    """

    name = "trace"

    def __init__(self, trace: Sequence):
        events: List[ArrivalEvent] = []
        for item in trace:
            if hasattr(item, "arrival"):
                events.append(
                    ArrivalEvent(
                        time=float(item.arrival),
                        payload=getattr(item, "payload", None),
                        deadline=getattr(item, "deadline", None),
                        rid=getattr(item, "rid", None),
                    )
                )
            else:
                events.append(ArrivalEvent(float(item)))
        self.events = sorted(events, key=lambda e: e.time)
        self._i = 0

    def next(self, rng: np.random.Generator) -> Optional[ArrivalEvent]:
        if self._i >= len(self.events):
            return None
        ev = self.events[self._i]
        self._i += 1
        return ev

    def drain(self) -> List[ArrivalEvent]:
        """Consume and return every remaining event (cursor to the end).

        The compiled backend materializes the whole remaining trace at
        once; paired with rewind() it is the batch equivalent of repeated
        next() calls, keeping the cursor authoritative.
        """
        evs = self.events[self._i:]
        self._i = len(self.events)
        return evs

    def rewind(self, n: int) -> None:
        """Push the last n consumed events back onto the stream."""
        if not 0 <= n <= self._i:
            raise ValueError(f"cannot rewind {n} of {self._i} consumed")
        self._i -= n

    @property
    def mean_rate(self) -> float:
        if len(self.events) < 2:
            return float("nan")
        span = self.events[-1].time - self.events[0].time
        return (len(self.events) - 1) / span if span > 0 else float("inf")

    def snapshot(self) -> dict:
        return {"i": self._i}

    def restore(self, state: dict) -> None:
        self._i = state["i"]


# Posterior-mass floor below which a propagated belief counts as degenerate
# (shared by the numpy filter and its jax mirror, belief_forward_jax).
_BELIEF_TINY = 1e-300


class PhaseBeliefFilter:
    """Forward filter for the hidden MMPP phase from observed arrivals.

    The exact Bayesian posterior over the modulating phase given the
    arrival times seen so far:  between arrivals the belief evolves by
    exp((R - Lambda) * gap) (phase diffusion weighted by "no arrival
    occurred"), and each arrival multiplies in the per-phase rates:

        b'  propto  b @ expm((R - Lambda) gap) @ Lambda.

    The matrix exponential is precomputed as an eigendecomposition of
    (R - Lambda), so each observation costs O(K^2).  This is the
    non-oracle counterpart of the true-phase trace: schedulers select the
    argmax-phase table (scheduler.BeliefPhaseScheduler,
    AdaptiveController(phase_filter=...)).
    """

    def __init__(self, rates, gen, t0: float = 0.0, b0=None):
        self.rates = np.asarray(rates, dtype=np.float64)
        self.gen = np.asarray(gen, dtype=np.float64)
        K = len(self.rates)
        if self.gen.shape != (K, K):
            raise ValueError(f"gen shape {self.gen.shape} != ({K}, {K})")
        sub = self.gen - np.diag(self.rates)  # (R - Lambda)
        d, V = np.linalg.eig(sub)
        self._d, self._V = d, V
        self._Vinv = np.linalg.inv(V)
        if b0 is None:
            # stationary phase distribution of the modulating chain
            a = self.gen.T.copy()
            a[-1, :] = 1.0
            rhs = np.zeros(K)
            rhs[-1] = 1.0
            try:
                b0 = np.clip(np.linalg.solve(a, rhs), 0.0, None)
            except np.linalg.LinAlgError:
                b0 = np.ones(K)
        self._b0 = np.asarray(b0, dtype=np.float64) / np.sum(b0)
        self.belief = self._b0.copy()
        self._last = float(t0)
        self._t0 = float(t0)
        self.n_observed = 0

    def _propagate(self, gap: float) -> np.ndarray:
        e = (self._V * np.exp(self._d * gap)) @ self._Vinv
        return np.real(self.belief @ e)

    def observe(self, t: float) -> None:
        """Fold in one arrival at absolute time t (monotone in t).

        Long inter-arrival gaps drive exp((R - Lambda) gap) toward zero
        and round-off can leave tiny negative / non-finite entries, so
        the propagated mass is clipped and renormalized *before* the
        rate reweighting; if the whole vector degenerates the belief
        falls back to the stationary phase distribution instead of
        emitting NaNs.
        """
        gap = max(float(t) - self._last, 0.0)
        p = self._propagate(gap)
        p = np.where(np.isfinite(p), np.clip(p, 0.0, None), 0.0)
        s = float(p.sum())
        if not np.isfinite(s) or s <= _BELIEF_TINY:
            p = self._b0  # degenerate propagation: stationary fallback
            s = float(p.sum())
        b = (p / s) * self.rates
        s2 = float(b.sum())
        if not np.isfinite(s2) or s2 <= _BELIEF_TINY:
            b = self._b0 * self.rates
            s2 = float(b.sum())
        self.belief = b / s2
        self._last = float(t)
        self.n_observed += 1

    @property
    def phase(self) -> int:
        """MAP phase under the current belief."""
        return int(np.argmax(self.belief))

    def snapshot(self) -> dict:
        return {
            "belief": self.belief.tolist(),
            "last": self._last,
            "n_observed": self.n_observed,
        }

    def restore(self, state: dict) -> None:
        self.belief = np.asarray(state["belief"], dtype=np.float64)
        self._last = state["last"]
        self.n_observed = state["n_observed"]


_belief_fwd_jit = None
_belief_fwd_vjit = None


def _get_belief_fwd(batched: bool):
    """Lazily build (and cache) the jitted belief-forward scan."""
    global _belief_fwd_jit, _belief_fwd_vjit
    if (_belief_fwd_vjit if batched else _belief_fwd_jit) is None:
        import jax
        import jax.numpy as jnp

        def fwd(times, b_init, t_init, d, V, Vinv, rates, b0):
            def step(carry, t):
                b, last = carry
                valid = jnp.isfinite(t)
                gap = jnp.where(valid, jnp.maximum(t - last, 0.0), 0.0)
                e = (V * jnp.exp(d * gap)) @ Vinv
                p = jnp.real(b.astype(V.dtype) @ e)
                p = jnp.where(jnp.isfinite(p), jnp.clip(p, 0.0, None), 0.0)
                s = jnp.sum(p)
                ok = jnp.isfinite(s) & (s > _BELIEF_TINY)
                p = jnp.where(ok, p, b0)
                s = jnp.where(ok, s, jnp.sum(b0))
                bn = (p / s) * rates
                s2 = jnp.sum(bn)
                ok2 = jnp.isfinite(s2) & (s2 > _BELIEF_TINY)
                bn = jnp.where(ok2, bn, b0 * rates)
                s2 = jnp.where(ok2, s2, jnp.sum(b0 * rates))
                bn = bn / s2
                b_new = jnp.where(valid, bn, b)
                last_new = jnp.where(valid, t, last)
                return (b_new, last_new), b_new

            (b_f, t_f), beliefs = jax.lax.scan(step, (b_init, t_init), times)
            return beliefs, (b_f, t_f)

        _belief_fwd_jit = jax.jit(fwd)
        _belief_fwd_vjit = jax.jit(
            jax.vmap(fwd, in_axes=(0,) + (None,) * 7)
        )
    return _belief_fwd_vjit if batched else _belief_fwd_jit


def belief_forward_jax(times, filt: PhaseBeliefFilter):
    """Phase-belief posteriors for a (padded) arrival-time vector, one scan.

    The jitted mirror of ``PhaseBeliefFilter.observe`` — same guarded
    op order (clip / renormalize / stationary fallback), so the rows are
    draw-for-draw equal to folding the numpy filter over the same times.
    The scan starts from ``filt``'s *current* (belief, last) state without
    mutating it, which is exactly what an engine run that resumes
    mid-stream needs.

    ``times`` may be 1-D ``(N,)`` or 2-D ``(S, N)`` (a seeds axis, e.g.
    stacked `mmpp2_times_jax` outputs); +inf / NaN padded slots keep the
    carry unchanged and repeat the previous belief row, so padded tails
    are harmless.  Returns ``(beliefs, (b_final, t_final))`` where
    ``beliefs[..., i, :]`` is the posterior just after observing
    ``times[..., i]``.  Feed ``beliefs`` straight into the compiled
    serving lane (`serving.compiled` ``phase_mode="belief_argmax"`` /
    ``"belief_mix"``).
    """
    import jax.numpy as jnp

    times = jnp.asarray(times, dtype=jnp.float64)
    if times.ndim not in (1, 2):
        raise ValueError(f"times must be 1-D or 2-D, got shape {times.shape}")
    fwd = _get_belief_fwd(batched=times.ndim == 2)
    return fwd(
        times,
        jnp.asarray(filt.belief, dtype=jnp.float64),
        jnp.asarray(filt._last, dtype=jnp.float64),
        jnp.asarray(filt._d, dtype=jnp.complex128),
        jnp.asarray(filt._V, dtype=jnp.complex128),
        jnp.asarray(filt._Vinv, dtype=jnp.complex128),
        jnp.asarray(filt.rates, dtype=jnp.float64),
        jnp.asarray(filt._b0, dtype=jnp.float64),
    )


def take(
    process: ArrivalProcess,
    rng: np.random.Generator,
    *,
    horizon: Optional[float] = None,
    n: Optional[int] = None,
) -> Tuple[List[ArrivalEvent], Optional[ArrivalEvent]]:
    """Eagerly drain a process: events below the bound + the first beyond.

    With ``horizon``, draws until the first event at or past it (that event
    is returned separately so the caller can push it back — exactly the
    peek-and-hold discipline of the lazy engine path, consuming exactly the
    same rng draws).  With ``n``, draws n events (or until exhaustion).
    """
    if (horizon is None) == (n is None):
        raise ValueError("exactly one of horizon= or n= required")
    events: List[ArrivalEvent] = []
    overshoot: Optional[ArrivalEvent] = None
    while True:
        ev = process.next(rng)
        if ev is None:
            break
        if horizon is not None and ev.time >= horizon:
            overshoot = ev
            break
        events.append(ev)
        if n is not None and len(events) >= n:
            break
    return events, overshoot


# ---------------------------------------------------------------------------
# Scan-compatible samplers (compiled-backend seed sweeps)
# ---------------------------------------------------------------------------


def poisson_times_jax(key, lam: float, n: int):
    """(n,) sorted Poisson arrival times: cumulative sum of Exp(lam) gaps.

    Pure jax (jit/vmap-safe): vmap over keys for a seeds axis.  Draws are
    statistically equivalent to PoissonProcess, not bit-equal (different
    generator) — use `take` for draw-for-draw parity with the Python loop.
    """
    import jax
    import jax.numpy as jnp

    gaps = jax.random.exponential(key, (n,), dtype=jnp.float64) / lam
    return jnp.cumsum(gaps)


def mmpp2_times_jax(key, mmpp: "MMPP2", n_steps: int, with_phases: bool = False):
    """MMPP(2) arrival times via one scan, phase chain in the carry.

    Each scan step draws one candidate exponential gap at the current
    phase's rate; if it crosses the pending phase switch the step emits no
    arrival and re-draws the dwell of the new phase (same competing-clocks
    construction as MMPP2Process.next).  Returns (times, mask): ``times``
    sorted ascending with non-arrivals pushed to +inf, ``mask`` marking the
    real arrivals (expected count ≈ n_steps * P(no switch per step)).
    vmap over keys for a seeds axis; feed `serving.compiled` directly.

    ``with_phases=True`` additionally returns the sampler-carry phase at
    each emitted arrival (same sorted order) — exactly what the compiled
    phase-indexed table lane (serving.compiled phases=) consumes for
    oracle-phase / exact-modulated policies.
    """
    import jax
    import jax.numpy as jnp

    lam = jnp.asarray([mmpp.lam1, mmpp.lam2], dtype=jnp.float64)
    dwell = jnp.asarray([mmpp.dwell1, mmpp.dwell2], dtype=jnp.float64)
    k0, kscan = jax.random.split(key)

    def step(carry, ks):
        t, phase, nsw = carry
        kg, kd = jax.random.split(ks)
        gap = jax.random.exponential(kg, dtype=jnp.float64) / lam[phase]
        switch = t + gap >= nsw
        new_phase = jnp.where(switch, 1 - phase, phase)
        t_new = jnp.where(switch, nsw, t + gap)
        nsw_new = jnp.where(
            switch,
            nsw + jax.random.exponential(kd, dtype=jnp.float64)
            * dwell[new_phase],
            nsw,
        )
        return (t_new, new_phase, nsw_new), (t_new, ~switch, new_phase)

    nsw0 = jax.random.exponential(k0, dtype=jnp.float64) * dwell[0]
    carry0 = (jnp.asarray(0.0, dtype=jnp.float64), jnp.asarray(0), nsw0)
    _, (times, emitted, phases) = jax.lax.scan(
        step, carry0, jax.random.split(kscan, n_steps)
    )
    order = jnp.argsort(jnp.where(emitted, times, jnp.inf))
    out = (jnp.where(emitted, times, jnp.inf)[order], emitted[order])
    if with_phases:
        return out + (phases[order].astype(jnp.int32),)
    return out


def diurnal_times_jax(key, proc: DiurnalProcess, n_steps: int):
    """Diurnal arrival times via one thinning scan (jit/vmap-safe).

    The jax mirror of DiurnalProcess.next: each step advances the clock by
    an Exp(rate_max) candidate gap and accepts it with probability
    rate(t)/rate_max.  Returns (times, mask) like mmpp2_times_jax
    (expected count ≈ n_steps * mean_rate / rate_max).
    """
    import jax
    import jax.numpy as jnp

    rmax = proc.rate_max
    period = proc.period
    phase0 = proc.phase0
    if proc._taus is None:
        base, amp = proc.base, proc.amp

        def rate(t):
            tau = jnp.mod(t + phase0, period)
            return base + amp * jnp.sin(2.0 * jnp.pi * tau / period)
    else:
        taus = jnp.asarray(
            np.concatenate([proc._taus, [proc._taus[0] + period]])
        )
        vals = jnp.asarray(np.concatenate([proc._vals, [proc._vals[0]]]))

        def rate(t):
            tau = jnp.mod(t + phase0, period)
            return jnp.interp(
                jnp.where(tau < taus[0], tau + period, tau), taus, vals
            )

    def step(t, ks):
        kg, ku = jax.random.split(ks)
        t = t + jax.random.exponential(kg, dtype=jnp.float64) / rmax
        accept = jax.random.uniform(ku, dtype=jnp.float64) * rmax < rate(t)
        return t, (t, accept)

    _, (times, emitted) = jax.lax.scan(
        step,
        jnp.asarray(0.0, dtype=jnp.float64),
        jax.random.split(key, n_steps),
    )
    order = jnp.argsort(jnp.where(emitted, times, jnp.inf))
    return jnp.where(emitted, times, jnp.inf)[order], emitted[order]


def as_process(x) -> ArrivalProcess:
    """Coerce a rate / MMPP2 / trace / process into an ArrivalProcess."""
    if isinstance(x, ArrivalProcess):
        return x
    if isinstance(x, MMPP2):
        return MMPP2Process(x)
    if isinstance(x, (int, float)):
        return PoissonProcess(float(x))
    if isinstance(x, (list, tuple, np.ndarray)):
        return TraceProcess(x)
    raise TypeError(f"cannot coerce {type(x).__name__} into an ArrivalProcess")
