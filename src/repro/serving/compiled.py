"""Compiled serving simulator: ONE jitted `lax.scan` decision-epoch kernel.

The Python engine (serving.engine._run_events) walks the queue one event at
a time — perfect for wall-clock executors and stateful online controllers,
hopeless for replication sweeps: a multi-seed bank comparison is minutes of
interpreter time while the solver finishes in milliseconds.  This module is
the compiled backend: the SAME decision-epoch semantics as `_run_events`,
expressed as a single `jax.lax.scan` step and `vmap`-ped across
(seeds x scenarios) x policy tables so an entire bank comparison is one
device dispatch.

Key representation choices:

  * Arrivals are a pre-sorted, +inf-padded array.  Requests are served FIFO
    and admitted in time order, so the queue at any moment is a contiguous
    window ``arrivals[n_served : n_admitted]`` — no ring buffer, just two
    carried indices.  Every arrival mode reduces to this form: traces
    directly, Poisson / MMPP2 via the scan-compatible samplers in
    serving.arrivals (the MMPP2 phase chain lives in that sampler's carry)
    or via eager numpy pre-generation when draw-for-draw parity with the
    Python engine is wanted (ServingEngine.run(backend="compiled")).
  * Policy tables always carry a phase axis inside the kernel: a (K, L)
    stack indexed by the phase of the *last admitted arrival* (a
    ``phases`` array aligned with the arrivals — from the MMPP2 sampler
    carry, an oracle switch trace, or all-zeros for the plain K = 1
    lane).  That is exactly the Python engine's oracle-phase discipline
    (observe_arrival on admission), so phase-indexed SMDP policies —
    OraclePhaseScheduler stacks and exact modulated (K, S) policies alike
    — run decision-for-decision inside the jitted scan.
  * One *event* per scan step — an O(1) admission pointer increment or a
    decision epoch — and a scalars-only carry; per-request accounting
    (latencies, the fixed-bin log-spaced histogram sketch, SLO misses) is
    reconstructed vectorized after the scan, so `run_grid` returns O(bins)
    aggregates per lane no matter the horizon and `record=True` yields the
    full decision/latency record for the equivalence harness.
  * Service times are ``means[a] * unit_draws[k]`` — every ServiceModel
    family is a unit-scale draw times the batch-size mean, so a shared draw
    sequence makes the compiled and Python backends decision-for-decision
    identical (the equivalence harness in serving.engine).
  * Scan length and array sizes are bucketed to powers of two and the
    actual epoch budget is a traced scalar, so re-runs at nearby sizes hit
    the jit cache; finished lanes freeze via a `done` flag, and a lane that
    runs out of steps is re-dispatched at a doubled length.

Termination mirrors the Python kernel exactly: a wait decision with no
live arrival left either drains the queue in b_max-capped batches
(drain=True) or terminates; an epoch budget caps the run regardless.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.service_models import ServiceModel  # noqa: F401  (x64 on import)

#: default fixed-bin latency sketch resolution (log-spaced bins)
DEFAULT_N_BINS = 256


def default_hist_edges(
    means: np.ndarray, n_bins: int = DEFAULT_N_BINS,
    lo_scale: float = 0.25, hi_scale: float = 2000.0,
) -> np.ndarray:
    """Log-spaced latency bin edges from the service-mean scale.

    Latencies are bounded below by (a fraction of) the single-request
    service time and above by queueing delay; ~4%-wide log bins over
    [means[1]/4, 2000 * means[b_max]] keep the sketch quantile error well
    inside the tolerance band tested against np.percentile.
    """
    lo = max(float(means[1]) * lo_scale, 1e-9)
    hi = max(float(means[-1]) * hi_scale, lo * 10.0)
    return np.geomspace(lo, hi, n_bins + 1)


def _bucket(n: int, floor: int = 256) -> int:
    """Smallest size >= n from {2^k, 3*2^k} (jit-cache friendly shapes).

    The half-step sizes bound the padding waste at 33% instead of 100% —
    scan steps are the whole cost of a frozen lane, so the finer ladder is
    worth the few extra jit cache entries.
    """
    b = floor
    while b < n:
        h = (b * 3) // 2
        if h >= n:
            return h
        b <<= 1
    return b


#: scan lengths that completed, keyed by problem shape — repeat dispatches
#: (benchmark loops, warmed sweeps) skip the escalation ladder entirely
_NSTEPS_CACHE: dict = {}


def _initial_steps(key, n_arr: int, max_eps: int, cap: int) -> int:
    # a completed run caches its exact-fit size (from the kernel's step
    # counter), so repeat dispatches carry no padding slack beyond the
    # bucket; a fresh shape starts from the typical-count heuristic
    # (admissions run _ADMIT_W-wide, epochs ~0.5 per arrival) and the
    # escalation loop covers the rare policies that need more
    cached = _NSTEPS_CACHE.get(key)
    if cached is not None:
        return min(cached, cap)
    return min(
        _bucket(
            n_arr // _ADMIT_W + max(256, min(max_eps, n_arr) // 2 + 2)
        ),
        cap,
    )


#: arrivals admitted per scan step (a dynamic_slice window): bursts cost
#: ceil(m / _ADMIT_W) steps instead of m.  Padded arrays must end in at
#: least this many +inf sentinels so the slice never clamps into real data.
_ADMIT_W = 4

#: record=True materializes several per-step trace arrays of the scan
#: length; past this many slots simulate_compiled raises instead of
#: allocating toward OOM (serving.fleet.FleetStream streams the same
#: aggregates in O(chunk) memory for arbitrarily long horizons)
MAX_RECORD_SLOTS = 1 << 20


def pad_arrivals(
    times, deadlines=None, size: Optional[int] = None, *, phases=None
):
    """Sort + pad an arrival-time array with +inf to a bucketed size.

    Returns (arrivals, deadlines) float64 arrays of length ``size`` (or the
    next power-of-two above len(times) plus the kernel's sentinel margin).
    Padded deadlines are +inf (never miss).  With ``phases`` (per-arrival
    phase ints for the phase-indexed table lane) a co-sorted, zero-padded
    int array is returned as a third element.
    """
    t = np.asarray(times, dtype=np.float64)
    finite = np.isfinite(t)  # idempotent: +inf padding is re-derived
    d = p = None
    if deadlines is not None:
        d = np.asarray(deadlines, dtype=np.float64)
        if len(d) != len(t):
            raise ValueError("deadlines must align with times")
        d = d[finite]
    if phases is not None:
        p = np.asarray(phases, dtype=np.int64)
        if len(p) != len(t):
            raise ValueError("phases must align with times")
        p = p[finite]
    t = t[finite]
    order = np.argsort(t, kind="stable")
    t = t[order]
    n = len(t)
    size = _bucket(n + _ADMIT_W) if size is None else size
    if size < n + _ADMIT_W:
        raise ValueError(
            f"pad size {size} < n_arrivals + {_ADMIT_W} = {n + _ADMIT_W}"
        )
    arr = np.full(size, np.inf)
    arr[:n] = t
    dl = np.full(size, np.inf)
    if d is not None:
        dl[:n] = d[order]
    if p is None:
        return arr, dl
    ph = np.zeros(size, dtype=np.int64)
    ph[:n] = p[order]
    return arr, dl, ph


def pad_arrivals_batch(traces, size: Optional[int] = None):
    """Pad several traces to one shared bucketed size: the (S, N) array
    `run_grid` wants for its seeds/scenarios axis.

    Derives the common size (largest trace plus the kernel's sentinel
    margin, bucketed) so callers never touch the sizing internals.
    """
    traces = [np.asarray(t, dtype=np.float64) for t in traces]
    if not traces:
        raise ValueError("pad_arrivals_batch needs at least one trace")
    if size is None:
        size = _bucket(max(len(t) for t in traces) + _ADMIT_W)
    return np.stack([pad_arrivals(t, size=size)[0] for t in traces])


@dataclasses.dataclass
class CompiledResult:
    """Aggregates of one compiled run (arrays already on host)."""

    t_final: float
    n_served: int
    n_batches: int
    n_epochs: int
    n_admitted: int
    energy: float
    lat_sum: float
    slo_miss: int
    terminated: bool  # stream exhausted (vs epoch budget reached)
    hist: np.ndarray  # (n_bins + 2,) counts; [0]=underflow, [-1]=overflow
    hist_edges: np.ndarray  # (n_bins + 1,)
    # record=True only:
    actions: Optional[np.ndarray] = None  # (n_epochs,) batch size, 0 = wait
    serve: Optional[np.ndarray] = None  # (n_epochs,) bool
    latencies: Optional[np.ndarray] = None  # (n_served,) in service order
    # adaptive lane only: final controller carry (engine state sync)
    adaptive_state: Optional[dict] = None
    # managed-queue lane (buffer= / shed_expired=) only:
    n_shed: int = 0  # arrivals refused by the finite waiting room
    n_expired: int = 0  # queued requests shed past their deadline
    queue_slots: Optional[np.ndarray] = None  # surviving queue, slot idxs

    @property
    def batch_sizes(self) -> np.ndarray:
        if self.actions is None:
            raise ValueError("run with record=True for per-epoch decisions")
        return self.actions[self.serve]


@dataclasses.dataclass
class AdaptiveLane:
    """Host-side lowering of an `AdaptiveController` for the scan kernel.

    Everything the in-carry controller needs, precomputed once: the bank
    stacked in sorted-key order, the per-key lambda coordinate plus the
    *pinned*-dimension squared scaled offsets (so the kernel's distance is
    ``sqrt(((lam_i - est) / lam_scale)^2 + aux_sq_i)`` — the same scaled
    Euclidean metric as `SMDPSchedulerBank.distances` over the
    {lam, **fixed} coordinate set), the EWMA constants, and the initial
    carry state extracted from the live controller (so a mid-stream engine
    run resumes exactly).  Window-mode estimators have no O(1) carry and
    stay on the Python backend.
    """

    tables: np.ndarray  # (P, K, L) bank stack, sorted-key order
    lam_keys: np.ndarray  # (P,) lambda coordinate per key
    aux_sq: np.ndarray  # (P,) pinned-dims squared scaled distance
    inv_scale: float  # 1 / lambda-dimension scale
    ewma: float
    margin: float
    min_dwell: float
    min_gap: float
    init_est: float  # estimator rate before any gap (NaN if none)
    sel0: int  # initial bank entry (index into sorted keys)
    gap_bar0: float  # NaN when the estimator has no gap average yet
    have_gap_bar0: bool
    last0: float  # NaN when no arrival observed yet
    have_last0: bool
    last_switch0: float
    n_switches0: int

    @classmethod
    def from_controller(cls, ctrl) -> "AdaptiveLane":
        est = ctrl.estimator
        if getattr(est, "window", None) is not None:
            raise TypeError(
                "compiled adaptive lane needs an EWMA RateEstimator; "
                "window-mode estimators stay on the Python backend"
            )
        bank = ctrl.bank
        unknown = set(ctrl.fixed) - set(bank.key_names)
        if unknown:
            raise ValueError(
                f"unknown key dims {unknown}; have {bank.key_names}"
            )
        _, stacked = bank.stacked()
        if stacked.ndim == 2:
            stacked = stacked[:, None, :]
        i_lam = bank.key_names.index("lam")
        pts, scales = bank._pts, bank._scales
        aux = np.zeros(len(pts))
        for i, name in enumerate(bank.key_names):
            if i != i_lam and name in ctrl.fixed:
                aux += ((pts[:, i] - ctrl.fixed[name]) / scales[i]) ** 2
        gap_bar = est._gap_bar
        last = est._last
        return cls(
            tables=stacked,
            lam_keys=pts[:, i_lam].copy(),
            aux_sq=aux,
            inv_scale=1.0 / float(scales[i_lam]),
            ewma=float(est.ewma),
            margin=float(ctrl.margin),
            min_dwell=float(ctrl.min_dwell),
            min_gap=float(est.min_gap),
            init_est=(
                float(est._init_rate) if est._init_rate else float("nan")
            ),
            sel0=int(bank._key_index[ctrl.key]),
            gap_bar0=float("nan") if gap_bar is None else float(gap_bar),
            have_gap_bar0=gap_bar is not None,
            last0=float("nan") if last is None else float(last),
            have_last0=last is not None,
            last_switch0=float(ctrl._last_switch),
            n_switches0=int(ctrl.n_switches),
        )

    def lowered(self):
        """The ``adap`` pytree `_scan_core` consumes (constants + carry0)."""
        i64 = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
        state0 = (
            jnp.asarray(self.gap_bar0, dtype=jnp.float64),
            jnp.asarray(self.have_gap_bar0),
            jnp.asarray(self.last0, dtype=jnp.float64),
            jnp.asarray(self.have_last0),
            jnp.asarray(self.sel0, dtype=i64),
            jnp.asarray(self.last_switch0, dtype=jnp.float64),
            jnp.asarray(self.n_switches0, dtype=i64),
        )
        return (
            jnp.asarray(self.lam_keys, dtype=jnp.float64),
            jnp.asarray(self.aux_sq, dtype=jnp.float64),
            jnp.asarray(self.inv_scale, dtype=jnp.float64),
            jnp.asarray(self.ewma, dtype=jnp.float64),
            jnp.asarray(self.margin, dtype=jnp.float64),
            jnp.asarray(self.min_dwell, dtype=jnp.float64),
            jnp.asarray(self.min_gap, dtype=jnp.float64),
            jnp.asarray(self.init_est, dtype=jnp.float64),
            state0,
        )


def _scan_core(
    table, arrivals, deadlines, phases, beliefs, draws, means, zeta, edges,
    t0, horizon, max_eps, drain, b_max, adap=None, buffer_cap=None,
    shed=None,
    *, n_steps: int, record: bool, mix: bool = False, adaptive: bool = False,
    qman: bool = False,
):
    """The event kernel: one scan step == one admission OR one epoch.

    Pure jax function; shapes only (no jit here — callers jit/vmap it).
    `arrivals` must be sorted with at least one trailing +inf sentinel.
    ``table`` is a (K, L) phase-indexed stack (K = 1 for plain policies)
    and ``phases`` the per-arrival phase ints aligned with ``arrivals``;
    the active row is the phase of the last admitted arrival — the Python
    engine's oracle-phase discipline (phase updates on admission).

    Two static knobs widen the lane to *online* (non-oracle) policies:

      * ``mix=True`` — belief-mixture action rule: instead of one phase
        row, the decision is ``round(sum_k beliefs[last_adm, k] *
        table[k, min(q, L-1)])`` with ``beliefs`` the (size, K) posterior
        rows aligned with ``arrivals`` (arrivals.belief_forward_jax) —
        the compiled `BeliefPhaseScheduler(mode="mix")`.  (The argmax
        rule needs no kernel support: it is just ``phases =
        argmax(beliefs)`` through the oracle plumbing.)
      * ``adaptive=True`` — ``table`` grows a leading bank axis
        (P, K, L) and the carry gains the AdaptiveController state (EWMA
        gap estimate, selected entry, hysteresis clock).  Each admission
        folds its arrival into the estimate and may retune ``sel`` —
        guarded by the relative margin and min-dwell exactly as
        `scheduler.AdaptiveController._maybe_retune` — so the bank
        retunes live inside the scan.  ``adap`` packs the lowered
        constants + initial state (`AdaptiveLane.carry()`).
      * ``qman=True`` — managed-queue lane for admission shedding: the
        carry gains an explicit admitted-slot queue (an index array plus
        head/tail pointers) because refusals and expiry breaks the plain
        ``arrivals[n_served:n_admitted]`` window contiguity.  Arrivals
        beyond ``buffer_cap`` queued requests are refused at the door
        (never observed by the adaptive estimator — the Python engine's
        offered-vs-admitted discipline), and with ``shed`` set every
        decision is preceded by dropping the expired *prefix* of the
        queue (deadlines must be nondecreasing in arrival order, which
        ``deadline = arrival + slo`` guarantees; the wrapper checks).  A
        step sheds at most ``_ADMIT_W`` expired requests; if more remain
        the step is a pure shed step — no decision epoch, clock
        unchanged — and the next step continues, so the eventual decide
        sees the fully swept queue exactly as the Python loop does.

    Two throughput-critical choices:

      * One *event* per step, not one epoch: when the next arrival is due
        (<= the clock) the step admits it — a single O(1) gather — and only
        otherwise takes a decision epoch.  Batch-admission inside an epoch
        would need a binary search over the arrival array every step; the
        event formulation replaces it with pointer increments, the same
        trick that makes the Python loop O(1) per event.
      * The scan carry is scalars-only (clock, window indices, energy): all
        per-request accounting — latencies, the histogram sketch, SLO
        misses — is reconstructed *after* the scan in one vectorized pass,
        by mapping each request slot to the serve epoch that completed it
        (a searchsorted into the cumulative batch sizes).

    A lane that exhausts n_steps before terminating or filling its epoch
    budget reports ``incomplete``; callers re-dispatch at a doubled step
    count (the scan is deterministic, so the prefix replays identically).
    """
    L = table.shape[-1]
    size = arrivals.shape[0]
    n_bins = edges.shape[0] - 1
    arr_adm = jnp.where(arrivals < horizon, arrivals, jnp.inf)
    n_draws = draws.shape[0]
    i64 = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32

    if adaptive:
        (lam_keys, aux_sq, inv_scale, ad_ewma, ad_margin, ad_min_dwell,
         ad_min_gap, ad_init_est, ad_state0) = adap

    def step(carry, _):
        (t, n_srv, n_adm, n_bat, n_eps, n_used, done), ad, qm = carry
        active = jnp.logical_not(done) & (n_eps < max_eps)
        # arrivals due by `now` are admitted before any decision is taken,
        # up to _ADMIT_W per step (they are a prefix of the sorted window;
        # the sentinel margin keeps the slice from clamping into real data)
        window = jax.lax.dynamic_slice(arr_adm, (n_adm,), (_ADMIT_W,))
        nxt = window[0]  # +inf once exhausted / beyond the horizon
        n_due = jnp.sum(window <= t).astype(i64)
        admit = active & (n_due > 0)
        dec = active & ~admit
        if qman:
            adm_idx, head, tail, last_adm, n_shd, n_exp = qm
            # door admission, one arrival at a time in time order: a
            # refusal checks the *running* queue length, exactly the
            # Python loop's per-arrival `len(queue) >= buffer`
            takes = []
            for j in range(_ADMIT_W):
                m = admit & (j < n_due)
                refuse = m & (tail - head >= buffer_cap)
                take = m & ~refuse
                adm_idx = adm_idx.at[jnp.where(take, tail, size)].set(
                    (n_adm + j).astype(jnp.int32), mode="drop"
                )
                tail = tail + take.astype(i64)
                n_shd = n_shd + refuse.astype(i64)
                last_adm = jnp.where(take, n_adm + j, last_adm)
                takes.append(take)
            q = tail - head
        else:
            q = n_adm - n_srv
        if adaptive:
            # fold each admitted arrival of this step into the controller
            # state, in time order — an unrolled masked pass over the
            # admission window, one EWMA update + hysteresis-guarded
            # retune per arrival, mirroring observe_arrival exactly
            gap_bar, have_gb, last_obs, have_last, sel, last_sw, n_sw = ad
            for j in range(_ADMIT_W):
                t_j = window[j]
                # refused arrivals are never observed (observe_arrival
                # runs on admission only in the Python engine)
                m = takes[j] if qman else admit & (j < n_due)
                gap = jnp.maximum(t_j - last_obs, ad_min_gap)
                upd = m & have_last
                gb_new = jnp.where(
                    have_gb, (1.0 - ad_ewma) * gap_bar + ad_ewma * gap, gap
                )
                gap_bar = jnp.where(upd, gb_new, gap_bar)
                have_gb = have_gb | upd
                last_obs = jnp.where(m, t_j, last_obs)
                have_last = have_last | m
                est = jnp.where(
                    have_gb,
                    1.0 / jnp.maximum(gap_bar, ad_min_gap),
                    ad_init_est,
                )
                dist = jnp.sqrt(((lam_keys - est) * inv_scale) ** 2 + aux_sq)
                cand = jnp.argmin(dist).astype(i64)
                switch = (
                    m
                    & (t_j - last_sw >= ad_min_dwell)
                    & jnp.isfinite(est)
                    & (cand != sel)
                    & (dist[cand] < (1.0 - ad_margin) * dist[sel])
                )
                n_sw = n_sw + switch.astype(i64)
                last_sw = jnp.where(switch, t_j, last_sw)
                sel = jnp.where(switch, cand, sel)
            ad = (gap_bar, have_gb, last_obs, have_last, sel, last_sw, n_sw)
            tab_kl = table[sel]  # the live bank entry, (K, L)
        else:
            tab_kl = table
        if qman:
            # expired-prefix sweep before the decision (deadlines are
            # nondecreasing in admission order, so expired requests are a
            # queue prefix); any shedding makes this a pure shed step —
            # the decision waits for the next step, clock unchanged
            e = jnp.asarray(0, dtype=i64)
            chain = dec & shed
            for j in range(_ADMIT_W):
                idx = adm_idx[jnp.clip(head + j, 0, size - 1)]
                chain = chain & (j < q) & (deadlines[idx] <= t)
                e = e + chain.astype(i64)
            dec_eff = dec & (e == 0)
        else:
            dec_eff = dec
        # phase of the last admitted arrival (before any admission this
        # reads the first arrival's phase; the queue is empty there, so
        # the decision is a forced wait whatever the row)
        if qman:
            last_i = jnp.clip(last_adm, 0, size - 1)
        else:
            last_i = jnp.clip(n_adm - 1, 0, size - 1)
        if mix:
            # belief-mixture action: posterior-weighted blend of the
            # per-phase actions, rounded — BeliefPhaseScheduler(mode="mix")
            a = jnp.round(
                jnp.sum(beliefs[last_i] * tab_kl[:, jnp.minimum(q, L - 1)])
            ).astype(i64)
        else:
            a = tab_kl[phases[last_i], jnp.minimum(q, L - 1)]
        a = jnp.clip(a, 0, jnp.minimum(q, b_max))
        live = jnp.isfinite(nxt)
        wait = dec_eff & (a == 0) & live
        term = dec_eff & (a == 0) & ~live & ((q == 0) | ~drain)
        a = jnp.where(
            dec_eff & (a == 0) & ~live & ~term, jnp.minimum(q, b_max), a
        )
        serve = dec_eff & ~wait & ~term
        a = a * serve
        svc = means[a] * draws[jnp.minimum(n_bat, n_draws - 1)]
        t_done = t + svc
        t_next = jnp.where(wait, nxt, jnp.where(serve, t_done, t))
        if qman:
            qm = (adm_idx, head + e + a, tail, last_adm, n_shd, n_exp + e)
        carry = ((
            t_next,
            n_srv + a,
            n_adm + jnp.where(admit, n_due, 0),
            n_bat + serve.astype(i64),
            n_eps + dec_eff.astype(i64),
            n_used + active.astype(i64),
            done | term,
        ), ad, qm)
        # (a > 0) <=> serve, so the aggregate path only needs (a, t_done) —
        # energy is summed from a_seq after the scan; the decision flag is
        # recorded only for the equivalence harness
        a32 = a.astype(jnp.int32)
        if qman:
            e32 = e.astype(jnp.int32)
            return carry, (
                (a32, e32, dec_eff, t_done) if record else (a32, e32, t_done)
            )
        return carry, ((a32, dec_eff, t_done) if record else (a32, t_done))

    zero = jnp.asarray(0, dtype=i64)
    qm0 = (
        (
            jnp.zeros(size, dtype=jnp.int32),  # admitted-slot queue
            zero,  # head: served + expired
            zero,  # tail: admitted
            jnp.asarray(-1, dtype=i64),  # last admitted arrival slot
            zero,  # door refusals
            zero,  # expired sheds
        )
        if qman
        else None
    )
    carry0 = ((
        jnp.asarray(t0, dtype=jnp.float64),
        zero, zero, zero, zero, zero,
        jnp.asarray(False),
    ), ad_state0 if adaptive else None, qm0)
    carry, outs = jax.lax.scan(step, carry0, None, length=n_steps, unroll=4)
    if qman:
        a_seq, e_seq, tdone_seq = (
            (outs[0], outs[1], outs[3]) if record else outs
        )
    else:
        a_seq, tdone_seq = (outs[0], outs[2]) if record else outs
    (t, n_srv, n_adm, n_bat, n_eps, n_used, done), ad_final, qm_final = carry

    # --- vectorized per-request reconstruction (one pass, no scan) -------
    # request slot j was completed by the serve step whose request interval
    # [cum_a - a, cum_a) contains j.  Interval starts are strictly
    # increasing over serve steps, so scattering each serve's step index at
    # its interval start and taking a running max assigns every slot its
    # completing step — O(size) instead of a per-slot binary search.
    energy = jnp.sum(zeta[a_seq])  # zeta[0] forced to 0 by the wrappers
    if qman:
        # managed-queue lane: the slot space is *admission order* (the
        # adm_idx queue), and steps consume a (served) + e (expired)
        # items from its head — a step does one or the other, so a done
        # slot's covering step tells served from expired apart
        adm_idx, head, tail, last_adm, n_shd, n_exp = qm_final
        tot = (a_seq + e_seq).astype(i64)
        cum = jnp.cumsum(tot)
        start = jnp.where(tot > 0, cum - tot, size)
        mark = jnp.zeros(size, dtype=jnp.int32).at[start].max(
            jnp.arange(n_steps, dtype=jnp.int32), mode="drop"
        )
        step_of = jax.lax.cummax(mark)
        completion = tdone_seq[step_of]
        slots = jnp.arange(size)
        arr_o = arrivals[adm_idx]
        dl_o = deadlines[adm_idx]
        valid = (slots < head) & (a_seq[step_of] > 0)  # done AND served
        lat = jnp.where(valid, completion - arr_o, 0.0)
        lat_sum = jnp.sum(lat)
        miss = jnp.sum(valid & (completion > dl_o))
    else:
        cum_a = jnp.cumsum(a_seq.astype(i64))
        start = jnp.where(a_seq > 0, cum_a - a_seq, size)  # non-serves drop
        mark = jnp.zeros(size, dtype=jnp.int32).at[start].max(
            jnp.arange(n_steps, dtype=jnp.int32), mode="drop"
        )
        epoch_of = jax.lax.cummax(mark)
        completion = tdone_seq[epoch_of]
        slots = jnp.arange(size)
        valid = slots < n_srv
        lat = jnp.where(valid, completion - arrivals, 0.0)
        lat_sum = jnp.sum(lat)
        miss = jnp.sum(valid & (completion > deadlines))
    bins = jnp.clip(jnp.searchsorted(edges, lat, side="right"), 0, n_bins + 1)
    hist = jnp.zeros(n_bins + 2, dtype=i64).at[
        jnp.where(valid, bins, 0)
    ].add(valid.astype(i64))

    agg = {
        "t_final": t, "n_served": n_srv, "n_admitted": n_adm,
        "n_batches": n_bat, "n_epochs": n_eps, "n_steps_used": n_used,
        "terminated": done,
        "incomplete": jnp.logical_not(done) & (n_eps < max_eps),
        "energy": energy, "lat_sum": lat_sum, "slo_miss": miss, "hist": hist,
    }
    if qman:
        # shed counters + final queue pointers (engine state sync: the
        # surviving queue is adm_idx[head:tail], in admission order)
        agg.update(
            n_shed=n_shd, n_expired=n_exp,
            qm_idx=adm_idx, qm_head=head, qm_tail=tail,
        )
    if adaptive:
        # final controller state (for the engine's post-run state sync)
        gap_bar, have_gb, last_obs, have_last, sel, last_sw, n_sw = ad_final
        agg.update(
            ad_gap_bar=gap_bar, ad_have_gap_bar=have_gb, ad_last=last_obs,
            ad_have_last=have_last, ad_sel=sel, ad_last_switch=last_sw,
            ad_n_switches=n_sw,
        )
    dec_seq = (outs[2] if qman else outs[1]) if record else None
    return (agg, (a_seq, dec_seq, lat, valid)) if record else agg


#: the phase_mode knob shared by simulate_compiled / run_grid / fleet:
#: "oracle" rows tables by the per-arrival true-phase ints, the belief
#: modes by the filtered posterior (argmax row / mixture action)
PHASE_MODES = ("oracle", "belief_argmax", "belief_mix")


def _check_phase_mode(phase_mode: str, beliefs, n_phases: int):
    """Validate the phase_mode / beliefs pairing; returns belief ndarray."""
    if phase_mode not in PHASE_MODES:
        raise ValueError(f"phase_mode must be one of {PHASE_MODES}")
    if phase_mode == "oracle":
        if beliefs is not None:
            raise ValueError('beliefs= needs phase_mode="belief_*"')
        return None
    if beliefs is None:
        raise ValueError(f'phase_mode="{phase_mode}" needs beliefs=')
    bel = np.asarray(beliefs, dtype=np.float64)
    if bel.shape[-1] != n_phases:
        raise ValueError(
            f"beliefs K={bel.shape[-1]} != table phase axis K={n_phases}"
        )
    return bel


def _coerce_adaptive(adaptive) -> Optional[AdaptiveLane]:
    if adaptive is None or isinstance(adaptive, AdaptiveLane):
        return adaptive
    return AdaptiveLane.from_controller(adaptive)


@partial(
    jax.jit,
    static_argnames=("n_steps", "record", "mix", "adaptive", "qman"),
)
def _simulate_jit(table, arrivals, deadlines, phases, beliefs, draws, means,
                  zeta, edges, t0, horizon, max_eps, drain, b_max, adap,
                  buffer_cap, shed, n_steps, record, mix, adaptive, qman):
    return _scan_core(
        table, arrivals, deadlines, phases, beliefs, draws, means, zeta,
        edges, t0, horizon, max_eps, drain, b_max, adap, buffer_cap, shed,
        n_steps=n_steps, record=record, mix=mix, adaptive=adaptive,
        qman=qman,
    )


def simulate_compiled(
    table,
    arrivals,
    *,
    means,
    zeta=None,
    draws=None,
    b_max: int,
    max_epochs: Optional[int] = None,
    t0: float = 0.0,
    horizon: Optional[float] = None,
    drain: bool = True,
    deadlines=None,
    phases=None,
    phase_mode: str = "oracle",
    beliefs=None,
    adaptive=None,
    buffer: Optional[int] = None,
    shed_expired: bool = False,
    hist_edges=None,
    record: bool = False,
    max_record_slots: Optional[int] = None,
) -> CompiledResult:
    """Run one policy table over one padded arrival trace, compiled.

    ``arrivals``/``deadlines`` may be raw times (padded internally) or
    already-padded arrays from `pad_arrivals`.  ``draws`` are unit-scale
    service draws (ones for deterministic service); service time of a batch
    of size a is ``means[a] * draws[n_batches_so_far]`` — exactly one draw
    consumed per serve epoch, matching the Python engine's rng discipline.

    ``table`` may be a (K, L) phase-indexed stack; who selects the row is
    the ``phase_mode`` knob:

      * ``"oracle"`` (default) — ``phases`` per-arrival true-phase ints
        (raw or pre-padded alongside ``arrivals``); the row is the phase
        of the last admitted arrival.
      * ``"belief_argmax"`` — ``beliefs`` (N, K) posterior rows aligned
        with ``arrivals`` (arrivals.belief_forward_jax); the argmax phase
        rows the stack: the compiled `BeliefPhaseScheduler`.
      * ``"belief_mix"`` — same ``beliefs``, but the action is the
        posterior-weighted mixture ``round(sum_k b_k table[k, q])``
        (`BeliefPhaseScheduler(mode="mix")`).

    ``adaptive`` (an `AdaptiveLane` or the `AdaptiveController` to lower)
    runs the bank-retuning controller *inside* the scan carry: ``table``
    may then be None (the lane's (P, K, L) bank stack is used) and the
    result carries ``adaptive_state`` — the final controller carry — for
    exact engine state sync.  Composes with any phase_mode (the phase axis
    rows each bank entry).

    ``buffer=B`` bounds the waiting room: arrivals finding B requests
    queued are refused at the door (counted in ``n_shed``, never observed
    by the adaptive estimator).  ``shed_expired=True`` drops queued
    requests whose deadline has passed before every decision epoch
    (``n_expired``); it requires deadlines nondecreasing in arrival order
    (``deadline = arrival + slo`` always is).  Either knob switches the
    kernel to the managed-queue lane (an explicit admitted-slot index
    queue in the carry) and the result gains ``queue_slots`` — the
    surviving queue as arrival-slot indices.  Belief lanes compose with
    ``shed_expired`` but not with ``buffer`` (the posterior folds admitted
    arrivals only, which a finite room makes decision-dependent).

    ``record=True`` materializes per-step trace buffers (actions,
    latencies) sized to the scan length.  That escalation is capped at
    ``max_record_slots`` (default `MAX_RECORD_SLOTS`): beyond it the call
    raises instead of silently allocating toward OOM — for longer
    horizons stream aggregates in O(chunk) memory with
    `serving.fleet.FleetStream` / `simulate_fleet_stream` instead.
    """
    lane = _coerce_adaptive(adaptive)
    if buffer is not None:
        if buffer < 0:
            raise ValueError(
                "buffer must be >= 0 (B = 0 sheds everything)"
            )
        if phase_mode != "oracle":
            raise ValueError(
                'buffer= composes with phase_mode="oracle" only: belief '
                "posteriors fold admitted arrivals, and admission under a "
                "finite waiting room is decision-dependent; run the "
                "Python backend"
            )
    qman = buffer is not None or bool(shed_expired)
    if lane is not None:
        table = lane.tables if table is None else np.asarray(
            table, dtype=np.int64
        )
        if table.ndim == 2:
            table = table[:, None, :]
        elif table.ndim != 3:
            raise ValueError(
                f"adaptive tables must be (P, L) or (P, K, L); "
                f"got {table.shape}"
            )
    else:
        table = np.asarray(table, dtype=np.int64)
        if table.ndim == 1:
            table = table[None]
        elif table.ndim != 2:
            raise ValueError(
                f"table must be (L,) or (K, L); got {table.shape}"
            )
    n_phases = table.shape[-2]
    bel = _check_phase_mode(phase_mode, beliefs, n_phases)
    if bel is not None:
        if phases is not None:
            raise ValueError("phases= and beliefs= are mutually exclusive")
        if bel.ndim != 2:
            raise ValueError(f"beliefs must be (N, K); got {bel.shape}")
    elif n_phases > 1 and phases is None and lane is None:
        raise ValueError("phase-indexed table needs phases= per arrival")
    arr = np.asarray(arrivals, dtype=np.float64)
    if bel is not None and len(bel) != len(arr):
        raise ValueError("beliefs must align with arrivals")
    if phase_mode == "belief_argmax":
        # the argmax rule is just an oracle-phase stream derived from the
        # posterior: reuse the whole phases plumbing, no kernel change
        phases = np.argmax(bel, axis=-1)
        bel = None
    mix = phase_mode == "belief_mix"
    if len(arr) < _ADMIT_W or not np.isinf(arr[-_ADMIT_W:]).all():
        raw = arr
        padded = pad_arrivals(raw, deadlines, phases=phases)
        if phases is None:
            arr, dl = padded
            ph = np.zeros(len(arr), dtype=np.int64)
        else:
            arr, dl, ph = padded
        if bel is not None:
            # co-sort/pad the posterior rows exactly like pad_arrivals
            finite = np.isfinite(raw)
            kept = bel[finite]
            order = np.argsort(raw[finite], kind="stable")
            bel = np.zeros((len(arr), bel.shape[1]))
            bel[: len(kept)] = kept[order]
    else:
        dl = (
            np.asarray(deadlines, dtype=np.float64)
            if deadlines is not None
            else np.full(len(arr), np.inf)
        )
        ph = (
            np.asarray(phases, dtype=np.int64)
            if phases is not None
            else np.zeros(len(arr), dtype=np.int64)
        )
        if len(ph) != len(arr):
            raise ValueError("padded phases must align with arrivals")
    if phases is not None and (ph.min() < 0 or ph.max() >= n_phases):
        raise ValueError(
            f"phases outside the table stack [0, {n_phases})"
        )
    n_arr = int(np.sum(np.isfinite(arr)))
    if shed_expired:
        # expired requests must form a queue *prefix* (the kernel sheds
        # from the head): deadlines nondecreasing in arrival order, which
        # deadline = arrival + slo satisfies by construction.  inf - inf
        # is NaN and NaN < 0 is False, so all-inf (no-deadline) runs pass.
        with np.errstate(invalid="ignore"):
            if np.any(np.diff(dl[:n_arr]) < 0):
                raise ValueError(
                    "shed_expired needs deadlines nondecreasing in arrival "
                    "order (deadline = arrival + slo always is); arbitrary "
                    "deadline orders run on the Python backend"
                )
    if max_epochs is None:
        max_eps = 2 * n_arr + 2
    else:
        max_eps = int(max_epochs)
    means = np.asarray(means, dtype=np.float64)
    zeta_a = (
        np.zeros(b_max + 1)
        if zeta is None
        else np.asarray(zeta, dtype=np.float64).copy()
    )
    zeta_a[0] = 0.0  # a = 0 never accounts energy (kernel sums zeta[a_seq])
    if draws is None:
        draws = np.ones(1)
    draws = np.asarray(draws, dtype=np.float64)
    edges = (
        default_hist_edges(means)
        if hist_edges is None
        else np.asarray(hist_edges, dtype=np.float64)
    )
    # one scan step per event: admissions + epochs.  Start from the typical
    # count and re-dispatch doubled if the lane ran out of steps (the cap
    # n_arr + max_eps + 1 is a hard upper bound: every step admits one of
    # n_arr arrivals or consumes one of max_eps epochs; the managed-queue
    # lane adds shed steps, each dropping >= 1 of at most n_arr requests).
    cap = _bucket((2 if qman else 1) * n_arr + max_eps + 1)
    ck = (
        "single", len(arr), table.shape, cap, mix, lane is not None,
        None if buffer is None else int(buffer), bool(shed_expired),
    )
    n_steps = _initial_steps(ck, n_arr, max_eps, cap)
    bel_j = (
        jnp.zeros((1, 1)) if bel is None else jnp.asarray(bel)
    )  # unused unless mix
    adap_j = None if lane is None else lane.lowered()
    if record:
        slots = (
            MAX_RECORD_SLOTS if max_record_slots is None
            else int(max_record_slots)
        )
        if n_steps > slots:
            raise ValueError(
                f"record=True needs at least {n_steps} trace slots for "
                f"{n_arr} arrivals, above max_record_slots={slots}; raise "
                "max_record_slots explicitly, or stream aggregates in "
                "O(chunk) memory with serving.fleet.FleetStream / "
                "simulate_fleet_stream"
            )
    # no buffer -> a cap the queue can never reach (the door never refuses)
    buf_cap = len(arr) + 1 if buffer is None else int(buffer)
    while True:
        out = _simulate_jit(
            jnp.asarray(table), jnp.asarray(arr), jnp.asarray(dl),
            jnp.asarray(ph), bel_j, jnp.asarray(draws), jnp.asarray(means),
            jnp.asarray(zeta_a), jnp.asarray(edges),
            float(t0), np.inf if horizon is None else float(horizon),
            max_eps, bool(drain), int(b_max), adap_j, buf_cap,
            bool(shed_expired), int(n_steps), bool(record), mix,
            lane is not None, qman,
        )
        agg = out[0] if record else out
        if n_steps >= cap or not bool(agg["incomplete"]):
            break
        nxt = min(2 * n_steps, cap)
        if record and nxt > slots:
            raise ValueError(
                f"record=True escalation wants {nxt} trace slots, above "
                f"max_record_slots={slots}; raise max_record_slots "
                "explicitly, or stream aggregates in O(chunk) memory with "
                "serving.fleet.FleetStream / simulate_fleet_stream"
            )
        n_steps = nxt
    _NSTEPS_CACHE[ck] = min(_bucket(int(agg["n_steps_used"]) + 1), cap)
    rec = out[1] if record else None
    agg = {k: np.asarray(v) for k, v in agg.items()}
    res = CompiledResult(
        t_final=float(agg["t_final"]),
        n_served=int(agg["n_served"]),
        n_batches=int(agg["n_batches"]),
        n_epochs=int(agg["n_epochs"]),
        n_admitted=int(agg["n_admitted"]),
        energy=float(agg["energy"]),
        lat_sum=float(agg["lat_sum"]),
        slo_miss=int(agg["slo_miss"]),
        terminated=bool(agg["terminated"]),
        hist=agg["hist"],
        hist_edges=edges,
    )
    if qman:
        res.n_shed = int(agg["n_shed"])
        res.n_expired = int(agg["n_expired"])
        res.queue_slots = np.asarray(agg["qm_idx"])[
            int(agg["qm_head"]): int(agg["qm_tail"])
        ].astype(np.int64)
    if lane is not None:
        res.adaptive_state = {
            "sel": int(agg["ad_sel"]),
            "gap_bar": float(agg["ad_gap_bar"]),
            "have_gap_bar": bool(agg["ad_have_gap_bar"]),
            "last": float(agg["ad_last"]),
            "have_last": bool(agg["ad_have_last"]),
            "last_switch": float(agg["ad_last_switch"]),
            "n_switches": int(agg["ad_n_switches"]),
        }
    if record:
        acts, dec, lat, valid = (np.asarray(x) for x in rec)
        res.actions = acts[dec].astype(np.int64)  # one entry per epoch
        res.serve = res.actions > 0
        res.latencies = lat[valid]  # arrival order == FIFO service order
    return res


@partial(jax.jit, static_argnames=("n_steps", "mix"))
def _grid_jit(tables, arrivals, deadlines, phases, beliefs, draws, means,
              zeta, edges, t0, horizon, max_eps, drain, b_max, n_steps, mix):
    def one(arr, dl, ph, bel, dr):
        return jax.vmap(
            lambda tab: _scan_core(
                tab, arr, dl, ph, bel, dr, means, zeta, edges, t0, horizon,
                max_eps, drain, b_max, n_steps=n_steps, record=False,
                mix=mix,
            )
        )(tables)

    return jax.vmap(one)(arrivals, deadlines, phases, beliefs, draws)


@partial(jax.jit, static_argnames=("n_steps", "mix"))
def _grid_adaptive_jit(tables, arrivals, deadlines, phases, beliefs, draws,
                       means, zeta, edges, t0, horizon, max_eps, drain,
                       b_max, adap, n_steps, mix):
    # the bank stack is the whole policy axis here (the controller selects
    # among its P entries live), so the vmap runs over trace lanes only
    def one(arr, dl, ph, bel, dr):
        return _scan_core(
            tables, arr, dl, ph, bel, dr, means, zeta, edges, t0, horizon,
            max_eps, drain, b_max, adap, n_steps=n_steps, record=False,
            mix=mix, adaptive=True,
        )

    return jax.vmap(one)(arrivals, deadlines, phases, beliefs, draws)


def run_grid(
    tables,
    arrivals,
    *,
    means,
    zeta=None,
    draws=None,
    b_max: int,
    max_epochs: Optional[int] = None,
    t0: float = 0.0,
    horizon: Optional[float] = None,
    drain: bool = True,
    deadlines=None,
    phases=None,
    phase_mode: str = "oracle",
    beliefs=None,
    hist_edges=None,
):
    """The vmapped sweep: (seeds x scenarios) traces x policy tables.

    ``tables``  — (P, L) stacked action tables (SMDPSchedulerBank.stacked()
    or scheduler.as_action_table per contender), or (P, K, L) phase-indexed
    stacks with ``phases`` = (S, N) per-arrival phase ints (pad_arrivals
    phases=, or the mmpp2_times_jax(with_phases=True) sampler carry);
    ``arrivals`` — (S, N) padded sorted traces (pad_arrivals per trace,
    common N); ``draws`` — (S, D) unit service draws per trace lane (ones
    for det service).

    ``phase_mode`` selects who rows the phase axis: ``"oracle"`` (the
    ``phases`` ints), or the belief lanes with ``beliefs`` = (S, N, K)
    posterior rows per trace (arrivals.belief_forward_jax over the padded
    batch) — ``"belief_argmax"`` rows by the MAP phase, ``"belief_mix"``
    blends the per-phase actions by the posterior.  This is the deployable
    (non-oracle) policy sweep at the same compiled throughput.

    One jitted dispatch returns dict of (S, P) aggregate arrays plus the
    (S, P, n_bins + 2) histogram sketch: everything a bank comparison needs
    (mean latency, power, weighted cost, sketch quantiles) without ever
    materializing per-request data.
    """
    tables = np.asarray(tables, dtype=np.int64)
    arr = np.asarray(arrivals, dtype=np.float64)
    if tables.ndim == 2:
        tables = tables[:, None, :]
    elif tables.ndim != 3:
        raise ValueError(
            f"tables must be (P, L) or (P, K, L); got {tables.shape}"
        )
    if arr.ndim != 2:
        raise ValueError("run_grid wants (S, N) arrivals")
    if arr.shape[1] < _ADMIT_W or not np.isinf(arr[:, -_ADMIT_W:]).all():
        raise ValueError("pad each trace with pad_arrivals first")
    bel = _check_phase_mode(phase_mode, beliefs, tables.shape[1])
    if bel is not None:
        if phases is not None:
            raise ValueError("phases= and beliefs= are mutually exclusive")
        if bel.ndim != 3 or bel.shape[:2] != arr.shape:
            raise ValueError(
                f"beliefs must be (S, N, K) aligned with arrivals "
                f"{arr.shape}; got {bel.shape}"
            )
        if phase_mode == "belief_argmax":
            phases = np.argmax(bel, axis=-1)
            bel = None
    elif tables.shape[1] > 1 and phases is None:
        raise ValueError("phase-indexed tables need phases= (S, N) ints")
    mix = phase_mode == "belief_mix"
    dl = (
        np.asarray(deadlines, dtype=np.float64)
        if deadlines is not None
        else np.full_like(arr, np.inf)
    )
    if phases is not None:
        ph = np.asarray(phases, dtype=np.int64)
        if ph.shape != arr.shape:
            raise ValueError(f"phases shape {ph.shape} != arrivals {arr.shape}")
        if ph.min() < 0 or ph.max() >= tables.shape[1]:
            raise ValueError(
                f"phases outside the table stack [0, {tables.shape[1]})"
            )
    else:
        ph = np.zeros(arr.shape, dtype=np.int64)
    means = np.asarray(means, dtype=np.float64)
    zeta_a = (
        np.zeros(b_max + 1)
        if zeta is None
        else np.asarray(zeta, dtype=np.float64).copy()
    )
    zeta_a[0] = 0.0  # a = 0 never accounts energy (kernel sums zeta[a_seq])
    if draws is None:
        draws = np.ones((arr.shape[0], 1))
    draws = np.asarray(draws, dtype=np.float64)
    n_arr_max = int(np.isfinite(arr).sum(axis=1).max())
    max_eps = 2 * n_arr_max + 2 if max_epochs is None else int(max_epochs)
    edges = (
        default_hist_edges(means)
        if hist_edges is None
        else np.asarray(hist_edges, dtype=np.float64)
    )
    cap = _bucket(n_arr_max + max_eps + 1)
    ck = ("grid", arr.shape, tables.shape, cap, mix)
    n_steps = _initial_steps(ck, n_arr_max, max_eps, cap)
    bel_j = (
        jnp.zeros((arr.shape[0], 1, 1)) if bel is None else jnp.asarray(bel)
    )  # unused unless mix
    while True:
        out = _grid_jit(
            jnp.asarray(tables), jnp.asarray(arr), jnp.asarray(dl),
            jnp.asarray(ph), bel_j, jnp.asarray(draws), jnp.asarray(means),
            jnp.asarray(zeta_a), jnp.asarray(edges),
            float(t0), np.inf if horizon is None else float(horizon),
            max_eps, bool(drain), int(b_max), int(n_steps), mix,
        )
        if n_steps >= cap or not bool(np.asarray(out["incomplete"]).any()):
            break
        n_steps = min(2 * n_steps, cap)
    _NSTEPS_CACHE[ck] = min(
        _bucket(int(np.asarray(out["n_steps_used"]).max()) + 1), cap
    )
    return _grid_post(out, edges, t0, zeta is not None)


def _grid_post(out, edges, t0, have_energy):
    """Host-side aggregate post-processing shared by the grid entries."""
    out = {k: np.asarray(v) for k, v in out.items()}
    out["hist_edges"] = edges
    with np.errstate(invalid="ignore", divide="ignore"):
        span = out["t_final"] - t0
        # starved lane (n_served == 0) -> NaN mean latency, not 0.0: a
        # zero would win every frontier argmin and poison plots silently
        out["w_mean"] = np.where(
            out["n_served"] > 0,
            out["lat_sum"] / np.maximum(out["n_served"], 1),
            np.nan,
        )
        # same convention as the engine's have_energy flag: a lane with no
        # energy source or no served batch reports NaN power, not 0
        out["power"] = np.where(
            have_energy & (out["n_batches"] > 0) & (span > 0),
            out["energy"] / span,
            np.nan,
        )
        # served requests + decision epochs: the event count a throughput
        # figure divides by (same definition as the BENCH_serving series)
        out["events_total"] = int(
            out["n_served"].sum() + out["n_epochs"].sum()
        )
    return out


def run_grid_adaptive(
    arrivals,
    *,
    adaptive,
    means,
    zeta=None,
    draws=None,
    b_max: int,
    max_epochs: Optional[int] = None,
    t0: float = 0.0,
    horizon: Optional[float] = None,
    drain: bool = True,
    deadlines=None,
    phases=None,
    phase_mode: str = "oracle",
    beliefs=None,
    hist_edges=None,
):
    """Seeds-vmapped adaptive dispatch: one controller config, S traces.

    The adaptive analogue of `run_grid`: every trace lane runs the
    in-carry `AdaptiveController` (``adaptive`` — an `AdaptiveLane` or the
    controller to lower) over the *whole* bank stack, retuning live, so
    the policy axis collapses into the carry and the vmap covers trace
    lanes only.  Each lane starts from the controller's current state —
    fresh controllers per seed, the replication-sweep semantics.  Returns
    the same dict as `run_grid` with (S,) aggregates plus the final
    per-lane controller state (``ad_*`` keys).  ``phase_mode`` /
    ``beliefs`` / ``phases`` row the bank entries' phase axis exactly as
    in `run_grid` (e.g. a belief-tracked phase row on top of bank
    retuning = AdaptiveController(phase_filter=...)).
    """
    lane = _coerce_adaptive(adaptive)
    tables = lane.tables
    arr = np.asarray(arrivals, dtype=np.float64)
    if arr.ndim != 2:
        raise ValueError("run_grid_adaptive wants (S, N) arrivals")
    if arr.shape[1] < _ADMIT_W or not np.isinf(arr[:, -_ADMIT_W:]).all():
        raise ValueError("pad each trace with pad_arrivals first")
    bel = _check_phase_mode(phase_mode, beliefs, tables.shape[1])
    if bel is not None:
        if phases is not None:
            raise ValueError("phases= and beliefs= are mutually exclusive")
        if bel.ndim != 3 or bel.shape[:2] != arr.shape:
            raise ValueError(
                f"beliefs must be (S, N, K) aligned with arrivals "
                f"{arr.shape}; got {bel.shape}"
            )
        if phase_mode == "belief_argmax":
            phases = np.argmax(bel, axis=-1)
            bel = None
    mix = phase_mode == "belief_mix"
    dl = (
        np.asarray(deadlines, dtype=np.float64)
        if deadlines is not None
        else np.full_like(arr, np.inf)
    )
    if phases is not None:
        ph = np.asarray(phases, dtype=np.int64)
        if ph.shape != arr.shape:
            raise ValueError(f"phases shape {ph.shape} != arrivals {arr.shape}")
        if ph.min() < 0 or ph.max() >= tables.shape[1]:
            raise ValueError(
                f"phases outside the table stack [0, {tables.shape[1]})"
            )
    else:
        ph = np.zeros(arr.shape, dtype=np.int64)
    means = np.asarray(means, dtype=np.float64)
    zeta_a = (
        np.zeros(b_max + 1)
        if zeta is None
        else np.asarray(zeta, dtype=np.float64).copy()
    )
    zeta_a[0] = 0.0
    if draws is None:
        draws = np.ones((arr.shape[0], 1))
    draws = np.asarray(draws, dtype=np.float64)
    n_arr_max = int(np.isfinite(arr).sum(axis=1).max())
    max_eps = 2 * n_arr_max + 2 if max_epochs is None else int(max_epochs)
    edges = (
        default_hist_edges(means)
        if hist_edges is None
        else np.asarray(hist_edges, dtype=np.float64)
    )
    cap = _bucket(n_arr_max + max_eps + 1)
    ck = ("grid_adaptive", arr.shape, tables.shape, cap, mix)
    n_steps = _initial_steps(ck, n_arr_max, max_eps, cap)
    bel_j = (
        jnp.zeros((arr.shape[0], 1, 1)) if bel is None else jnp.asarray(bel)
    )
    adap_j = lane.lowered()
    while True:
        out = _grid_adaptive_jit(
            jnp.asarray(tables), jnp.asarray(arr), jnp.asarray(dl),
            jnp.asarray(ph), bel_j, jnp.asarray(draws), jnp.asarray(means),
            jnp.asarray(zeta_a), jnp.asarray(edges),
            float(t0), np.inf if horizon is None else float(horizon),
            max_eps, bool(drain), int(b_max), adap_j, int(n_steps), mix,
        )
        if n_steps >= cap or not bool(np.asarray(out["incomplete"]).any()):
            break
        n_steps = min(2 * n_steps, cap)
    _NSTEPS_CACHE[ck] = min(
        _bucket(int(np.asarray(out["n_steps_used"]).max()) + 1), cap
    )
    return _grid_post(out, edges, t0, zeta is not None)
