"""Batch schedulers: the SMDP policy (the paper) + benchmark policies.

A scheduler answers one question at each decision epoch (batch completion,
or arrival-at-idle): given s queued requests, what batch size now?
`0` means wait for more arrivals.

A solved sweep (core.sweep.sweep_solve over a lambda / w2 grid) turns into
an SMDPSchedulerBank via SMDPScheduler.bank(): a keyed table bank the
serving layer hot-swaps when traffic or the energy-price weight shifts,
without re-solving online.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.solve import SolveResult


class Scheduler:
    name = "base"

    def decide(self, queue_len: int) -> int:  # pragma: no cover - interface
        raise NotImplementedError

    def snapshot(self) -> dict:
        return {}

    def restore(self, state: dict) -> None:
        pass


class SMDPScheduler(Scheduler):
    """Table-driven scheduler from a solved SMDP (paper eq. 30)."""

    name = "smdp"

    def __init__(self, solution: SolveResult):
        self.table = solution.action_table()
        self.s_max = len(self.table) - 1
        self._bank: Optional["SMDPSchedulerBank"] = None

    @classmethod
    def from_table(cls, table: np.ndarray) -> "SMDPScheduler":
        obj = cls.__new__(cls)
        obj.table = np.asarray(table, dtype=np.int64)
        obj.s_max = len(obj.table) - 1
        obj._bank = None
        return obj

    @classmethod
    def bank(
        cls,
        solutions: Sequence[SolveResult],
        keys: Optional[Sequence[Tuple[float, ...]]] = None,
        key_names: Tuple[str, ...] = ("lam", "w2"),
    ) -> "SMDPSchedulerBank":
        """Turn a solved sweep into a hot-swappable table bank.

        By default each solution is keyed by its spec's (lam, w2); pass
        explicit ``keys`` (tuples aligned with ``key_names``) to key on
        other sweep axes (e.g. service profile id).
        """
        if keys is None:
            keys = [
                tuple(float(getattr(sol.spec, n)) for n in key_names)
                for sol in solutions
            ]
        if len(keys) != len(solutions):
            raise ValueError("keys and solutions must align")
        tables = {}
        for key, sol in zip(keys, solutions):
            k = tuple(float(v) for v in key)
            if k in tables:
                raise ValueError(
                    f"duplicate bank key {k}: the sweep varies something "
                    f"{key_names} does not capture — pass explicit keys"
                )
            tables[k] = sol.action_table()
        return SMDPSchedulerBank(tables, key_names)

    def decide(self, queue_len: int) -> int:
        table = self.table  # single read: safe against concurrent swap_table
        return int(table[min(queue_len, len(table) - 1)])

    def swap_table(self, table: np.ndarray) -> None:
        """Hot-swap the action table (atomic from decide()'s point of view)."""
        self.table = np.asarray(table, dtype=np.int64)
        self.s_max = len(self.table) - 1

    def retune(self, **coords: float) -> Tuple[float, ...]:
        """Re-point at the bank entry nearest the observed operating point.

        Returns the selected key.  Requires the scheduler to have been
        minted by an SMDPSchedulerBank.
        """
        if self._bank is None:
            raise RuntimeError("scheduler has no attached bank; use bank()")
        key = self._bank.nearest(**coords)
        self.swap_table(self._bank.tables[key])
        return key


class SMDPSchedulerBank:
    """Keyed bank of solved SMDP action tables (one sweep, many regimes).

    ``tables`` maps key tuples (aligned with ``key_names``, e.g. (lam, w2))
    to dense action tables.  ``nearest`` picks the entry closest to an
    observed operating point so the serving layer can hot-swap policies as
    traffic or the energy price shifts, without re-solving online.
    """

    def __init__(
        self,
        tables: Dict[Tuple[float, ...], np.ndarray],
        key_names: Tuple[str, ...] = ("lam", "w2"),
    ):
        if not tables:
            raise ValueError("empty scheduler bank")
        self.key_names = tuple(key_names)
        self.tables = {
            tuple(float(v) for v in k): np.asarray(t, dtype=np.int64)
            for k, t in tables.items()
        }
        for key in self.tables:
            if len(key) != len(self.key_names):
                raise ValueError(f"key {key} does not match {self.key_names}")
        # per-dimension scale for the nearest-key metric (range, not |max|,
        # so sweeps over a narrow band around a large value still resolve)
        arr = np.array(sorted(self.tables), dtype=np.float64)
        span = arr.max(axis=0) - arr.min(axis=0)
        self._scales = np.where(span > 0, span, 1.0)

    def __len__(self) -> int:
        return len(self.tables)

    def keys(self):
        return sorted(self.tables)

    def nearest(self, **coords: float) -> Tuple[float, ...]:
        """Key closest to the given operating point (subset of dims OK)."""
        unknown = set(coords) - set(self.key_names)
        if unknown:
            raise ValueError(f"unknown key dims {unknown}; have {self.key_names}")
        if not coords:
            raise ValueError("need at least one coordinate")
        dims = [i for i, n in enumerate(self.key_names) if n in coords]
        target = np.array([coords[self.key_names[i]] for i in dims])
        keys = sorted(self.tables)
        pts = np.array(keys, dtype=np.float64)[:, dims]
        d = np.linalg.norm((pts - target[None, :]) / self._scales[dims], axis=1)
        return keys[int(np.argmin(d))]

    def scheduler(self, **coords: float) -> SMDPScheduler:
        """Mint an SMDPScheduler on the nearest entry, wired for retune()."""
        key = self.nearest(**coords)
        sch = SMDPScheduler.from_table(self.tables[key])
        sch._bank = self
        return sch


class StaticScheduler(Scheduler):
    """Fixed batch size b; waits until b requests are queued (Def. 1)."""

    def __init__(self, b: int):
        self.b = b
        self.name = f"static_{b}"

    def decide(self, queue_len: int) -> int:
        return self.b if queue_len >= self.b else 0


class GreedyScheduler(Scheduler):
    """Largest feasible batch now (Def. 2)."""

    name = "greedy"

    def __init__(self, b_min: int = 1, b_max: int = 32):
        self.b_min, self.b_max = b_min, b_max

    def decide(self, queue_len: int) -> int:
        if queue_len < self.b_min:
            return 0
        return min(queue_len, self.b_max)


class QPolicyScheduler(Scheduler):
    """Control-limit policy (Def. 3): serve min(s, B_max) iff s >= Q."""

    def __init__(self, q: int, b_max: int = 32):
        self.q, self.b_max = q, b_max
        self.name = f"qpolicy_{q}"

    def decide(self, queue_len: int) -> int:
        return min(queue_len, self.b_max) if queue_len >= self.q else 0
