"""Batch schedulers: the SMDP policy (the paper) + benchmark policies.

A scheduler answers one question at each decision epoch (batch completion,
or arrival-at-idle): given s queued requests, what batch size now?
`0` means wait for more arrivals.

A solved sweep (core.sweep.sweep_solve over a lambda / w2 / service-profile
grid) turns into an SMDPSchedulerBank via SMDPScheduler.bank() or
core.sweep.sweep_bank(): a keyed table bank the serving layer hot-swaps
when traffic, the energy-price weight, or the active service profile
shifts, without re-solving online.  AdaptiveController closes the loop: an
online arrival-rate estimate retunes the active table against the bank,
with hysteresis at regime boundaries; non-rate axes (w2, profile) are
pinned coordinates.

Two exports feed the compiled simulator (serving.compiled): ``stacked()``
turns a bank into one (P, L) array for the vmapped policy axis, and
``as_action_table()`` lowers any stateless scheduler (SMDP / static /
greedy / Q-policy) to the dense table the scan kernel indexes.

Phase axis (exact MMPP-aware serving)
-------------------------------------

Tables may carry a leading phase axis: a (K, L) stack — one row per
modulating phase — from core.solve_modulated / sweep_bank(phases=...), or
assembled per-phase (OraclePhaseScheduler).  SMDPScheduler holds a
``phase`` pointer into the stack; ``as_action_table()`` returns the stack
itself and the compiled lane (serving.compiled phases=) indexes the row by
the per-arrival phase.  Who sets the phase:

  * OraclePhaseScheduler — the true switch trace (estimation-free bound),
    with a vectorized ``phase_at`` for the compiled lane;
  * BeliefPhaseScheduler — the non-oracle counterpart: an MMPP forward
    filter (arrivals.PhaseBeliefFilter) tracks the phase posterior from
    inter-arrival gaps and the argmax phase selects the row (Python
    backend only — the belief is data-dependent state);
  * AdaptiveController(phase_filter=...) — belief-tracked phase row on top
    of online lambda-estimate bank retuning.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.solve import SolveResult


class Scheduler:
    name = "base"

    def decide(self, queue_len: int) -> int:  # pragma: no cover - interface
        raise NotImplementedError

    def snapshot(self) -> dict:
        return {}

    def restore(self, state: dict) -> None:
        pass


class SMDPScheduler(Scheduler):
    """Table-driven scheduler from a solved SMDP (paper eq. 30).

    The table may be 1-D (queue-indexed) or a (K, L) phase-indexed stack
    (core.ModulatedSolveResult.action_table); with a stack, ``phase``
    selects the active row — set it directly for a pinned regime, or let
    an oracle/belief wrapper drive it per arrival.
    """

    name = "smdp"

    def __init__(self, solution: SolveResult):
        self._set_table(solution.action_table())
        self._bank: Optional["SMDPSchedulerBank"] = None
        self.phase = 0

    def _set_table(self, table: np.ndarray) -> None:
        table = np.asarray(table, dtype=np.int64)
        if table.ndim not in (1, 2):
            raise ValueError(f"action table must be 1-D or (K, L); got {table.shape}")
        self.table = table
        self.s_max = table.shape[-1] - 1

    @property
    def n_phases(self) -> int:
        return 1 if self.table.ndim == 1 else self.table.shape[0]

    @classmethod
    def from_table(cls, table: np.ndarray) -> "SMDPScheduler":
        obj = cls.__new__(cls)
        obj._set_table(table)
        obj._bank = None
        obj.phase = 0
        return obj

    @classmethod
    def bank(
        cls,
        solutions: Sequence[SolveResult],
        keys: Optional[Sequence[Tuple[float, ...]]] = None,
        key_names: Tuple[str, ...] = ("lam", "w2"),
    ) -> "SMDPSchedulerBank":
        """Turn a solved sweep into a hot-swappable table bank.

        By default each solution is keyed by its spec's (lam, w2); pass
        explicit ``keys`` (tuples aligned with ``key_names``) to key on
        other sweep axes (e.g. service profile id).
        """
        if keys is None:
            keys = [
                tuple(float(getattr(sol.spec, n)) for n in key_names)
                for sol in solutions
            ]
        if len(keys) != len(solutions):
            raise ValueError("keys and solutions must align")
        tables = {}
        for key, sol in zip(keys, solutions):
            k = tuple(float(v) for v in key)
            if k in tables:
                raise ValueError(
                    f"duplicate bank key {k}: the sweep varies something "
                    f"{key_names} does not capture — pass explicit keys"
                )
            tables[k] = sol.action_table()
        return SMDPSchedulerBank(tables, key_names)

    def decide(self, queue_len: int) -> int:
        table = self.table  # single read: safe against concurrent swap_table
        if table.ndim == 1:
            row = table
        else:
            if not 0 <= self.phase < table.shape[0]:
                # same contract as the compiled lane's phases validation:
                # fail loudly instead of silently serving a clamped row
                raise ValueError(
                    f"phase {self.phase} outside table stack "
                    f"[0, {table.shape[0]})"
                )
            row = table[self.phase]
        return int(row[min(queue_len, len(row) - 1)])

    def phase_at(self, times) -> np.ndarray:
        """Per-arrival phases for the compiled lane: the pinned phase.

        Nothing updates ``phase`` during a plain SMDPScheduler run, so the
        compiled equivalent is a constant phase stream; oracle/belief
        wrappers override this with their own trace.
        """
        return np.full(len(times), int(self.phase), dtype=np.int64)

    def swap_table(self, table: np.ndarray) -> None:
        """Hot-swap the action table (atomic from decide()'s point of view).

        The phase pointer survives the swap: retuning the bank entry must
        not reset which regime row the phase tracker selected.
        """
        self._set_table(table)

    def retune(self, **coords: float) -> Tuple[float, ...]:
        """Re-point at the bank entry nearest the observed operating point.

        Returns the selected key.  Requires the scheduler to have been
        minted by an SMDPSchedulerBank.
        """
        if self._bank is None:
            raise RuntimeError("scheduler has no attached bank; use bank()")
        key = self._bank.nearest(**coords)
        self.swap_table(self._bank.tables[key])
        return key

    def snapshot(self) -> dict:
        return {"phase": self.phase}

    def restore(self, state: dict) -> None:
        self.phase = int(state.get("phase", 0))


class SMDPSchedulerBank:
    """Keyed bank of solved SMDP action tables (one sweep, many regimes).

    ``tables`` maps key tuples (aligned with ``key_names``, e.g. (lam, w2))
    to dense action tables.  ``nearest`` picks the entry closest to an
    observed operating point so the serving layer can hot-swap policies as
    traffic or the energy price shifts, without re-solving online.
    """

    def __init__(
        self,
        tables: Dict[Tuple[float, ...], np.ndarray],
        key_names: Tuple[str, ...] = ("lam", "w2"),
    ):
        if not tables:
            raise ValueError("empty scheduler bank")
        self.key_names = tuple(key_names)
        self.tables = {
            tuple(float(v) for v in k): np.asarray(t, dtype=np.int64)
            for k, t in tables.items()
        }
        for key, t in self.tables.items():
            if len(key) != len(self.key_names):
                raise ValueError(f"key {key} does not match {self.key_names}")
            if t.ndim not in (1, 2):
                raise ValueError(f"table for {key} must be 1-D or (K, L)")
        ndims = {t.ndim for t in self.tables.values()}
        phase_counts = {
            t.shape[0] for t in self.tables.values() if t.ndim == 2
        }
        if len(ndims) > 1 or len(phase_counts) > 1:
            raise ValueError(
                "bank tables must agree on the phase axis (all 1-D, or all "
                f"(K, L) with one K); got ndims {ndims}, K {phase_counts}"
            )
        self.n_phases = phase_counts.pop() if phase_counts else 1
        # the key set is immutable after construction: cache the sorted key
        # list and point matrix once, so nearest()/distance() stay cheap on
        # the per-arrival serving hot path
        self._sorted_keys = sorted(self.tables)
        self._key_index = {k: i for i, k in enumerate(self._sorted_keys)}
        self._pts = np.array(self._sorted_keys, dtype=np.float64)
        # per-dimension scale for the nearest-key metric (range, not |max|,
        # so sweeps over a narrow band around a large value still resolve)
        span = self._pts.max(axis=0) - self._pts.min(axis=0)
        self._scales = np.where(span > 0, span, 1.0)

    def __len__(self) -> int:
        return len(self.tables)

    def keys(self):
        return list(self._sorted_keys)

    def distances(self, **coords: float) -> np.ndarray:
        """Scaled distance of every key (in keys() order) to the point.

        The one metric behind nearest()/distance(); AdaptiveController's
        hysteresis reads the whole vector once per arrival instead of
        recomputing norms per key.
        """
        dims, target = self._resolve_coords(coords)
        pts = self._pts[:, dims]
        return np.linalg.norm(
            (pts - target[None, :]) / self._scales[dims], axis=1
        )

    def nearest(self, **coords: float) -> Tuple[float, ...]:
        """Key closest to the given operating point (subset of dims OK)."""
        return self._sorted_keys[int(np.argmin(self.distances(**coords)))]

    def distance(self, key: Tuple[float, ...], **coords: float) -> float:
        """Scaled distance of a bank key to an operating point."""
        key = tuple(float(v) for v in key)
        if key not in self.tables:
            raise KeyError(f"{key} not in bank")
        return float(self.distances(**coords)[self._key_index[key]])

    def _resolve_coords(self, coords: Dict[str, float]):
        unknown = set(coords) - set(self.key_names)
        if unknown:
            raise ValueError(f"unknown key dims {unknown}; have {self.key_names}")
        if not coords:
            raise ValueError("need at least one coordinate")
        dims = [i for i, n in enumerate(self.key_names) if n in coords]
        target = np.array([coords[self.key_names[i]] for i in dims])
        return dims, target

    def scheduler(self, **coords: float) -> SMDPScheduler:
        """Mint an SMDPScheduler on the nearest entry, wired for retune()."""
        key = self.nearest(**coords)
        sch = SMDPScheduler.from_table(self.tables[key])
        sch._bank = self
        return sch

    def stacked(self, keys=None):
        """(keys, stacked array): the bank as a dense policy axis.

        Tables shorter than the longest are padded by repeating their last
        entry — exactly the eq.-(30) extension decide() applies, so the
        padded row is decision-for-decision the same scheduler.  Row order
        follows ``keys`` (default: sorted keys()).  This is what the
        compiled simulator vmaps over for whole-bank comparisons: a
        (P, L) array for queue-indexed banks, (P, K, L) for phase-indexed
        ones (each entry a (K, L) stack — run_grid consumes either).
        """
        ks = [
            tuple(float(v) for v in k)
            for k in (self._sorted_keys if keys is None else keys)
        ]
        if not ks:
            raise ValueError("stacked() with an empty key list")
        missing = [k for k in ks if k not in self.tables]
        if missing:
            raise KeyError(f"keys not in bank: {missing}")
        L = max(self.tables[k].shape[-1] for k in ks)
        return ks, np.stack([_extend_last(self.tables[k], L) for k in ks])


class AdaptiveController(Scheduler):
    """Online regime adaptation: rate estimator -> bank retune, hysteresis.

    Wraps a bank-minted SMDPScheduler.  Every observed arrival updates a
    RateEstimator (serving.metrics); when the estimate drifts toward a
    different bank entry the controller retunes the scheduler onto it —
    guarded by a relative-margin hysteresis (the candidate key must be
    closer than (1 - margin) x the current key's distance) and a minimum
    dwell time between switches, so the table does not thrash at regime
    boundaries.  This is the paper's Sec.-VIII "detect the phase, apply the
    per-phase policy" run against a solved lambda x w2 sweep bank
    (core.sweep.sweep_bank) instead of hand-picked phase tables.
    """

    name = "smdp_adaptive"

    def __init__(
        self,
        bank: "SMDPSchedulerBank",
        *,
        estimator=None,
        ewma: float = 0.1,
        margin: float = 0.25,
        min_dwell: float = 0.0,
        init_rate: Optional[float] = None,
        phase_filter=None,  # arrivals.PhaseBeliefFilter for phase-axis banks
        **fixed: float,  # pinned non-rate coords, e.g. w2=1.0
    ):
        from .metrics import RateEstimator

        if "lam" not in bank.key_names:
            raise ValueError(f"bank has no 'lam' axis: {bank.key_names}")
        lam_keys = sorted({k[bank.key_names.index("lam")] for k in bank.keys()})
        if init_rate is None:
            init_rate = float(np.mean(lam_keys))
        self.bank = bank
        self.fixed = {k: float(v) for k, v in fixed.items()}
        self.estimator = estimator if estimator is not None else RateEstimator(
            ewma=ewma, init=init_rate
        )
        self.margin = margin
        self.min_dwell = min_dwell
        self.phase_filter = phase_filter
        rate0 = self.estimator.rate
        if not np.isfinite(rate0):  # custom estimator with no data yet
            rate0 = init_rate
        self.key = bank.nearest(lam=rate0, **self.fixed)
        self.scheduler = SMDPScheduler.from_table(bank.tables[self.key])
        self.scheduler._bank = bank
        if phase_filter is not None:
            self.scheduler.phase = phase_filter.phase
        self._last_switch = -float("inf")
        self.n_switches = 0

    def observe_arrival(self, t: float) -> None:
        self.estimator.observe(t)
        if self.phase_filter is not None:
            # belief row selection and lambda retuning move independently:
            # the filter reacts within a few gaps, the estimator/hysteresis
            # pair guards the (slower) bank-entry swap
            self.phase_filter.observe(t)
            self.scheduler.phase = self.phase_filter.phase
        self._maybe_retune(t)

    def _maybe_retune(self, t: float) -> None:
        if t - self._last_switch < self.min_dwell:
            return
        est = self.estimator.rate
        if not np.isfinite(est):
            return
        d = self.bank.distances(lam=est, **self.fixed)
        i_cand = int(np.argmin(d))
        cand = self.bank._sorted_keys[i_cand]
        if cand == self.key:
            return
        d_cur = float(d[self.bank._key_index[self.key]])
        d_cand = float(d[i_cand])
        if d_cand < (1.0 - self.margin) * d_cur:
            self.key = cand
            self.scheduler.swap_table(self.bank.tables[cand])
            self._last_switch = t
            self.n_switches += 1

    def decide(self, queue_len: int) -> int:
        return self.scheduler.decide(queue_len)

    def snapshot(self) -> dict:
        snap = {
            "estimator": self.estimator.snapshot(),
            "key": self.key,
            "last_switch": self._last_switch,
            "n_switches": self.n_switches,
            "phase": self.scheduler.phase,
        }
        if self.phase_filter is not None:
            snap["phase_filter"] = self.phase_filter.snapshot()
        return snap

    def restore(self, state: dict) -> None:
        self.estimator.restore(state["estimator"])
        self.key = tuple(float(v) for v in state["key"])
        self.scheduler.swap_table(self.bank.tables[self.key])
        self.scheduler.phase = int(state.get("phase", 0))
        if self.phase_filter is not None and "phase_filter" in state:
            self.phase_filter.restore(state["phase_filter"])
        self._last_switch = state["last_switch"]
        self.n_switches = state["n_switches"]


def _extend_last(t: np.ndarray, length: int) -> np.ndarray:
    """Extend a table along its last axis by repeating the final entry.

    The eq.-(30) infinite-state extension — the ONE padding rule every
    table stacking/padding path shares, so padded rows stay
    decision-for-decision identical to their originals.
    """
    width = length - t.shape[-1]
    if width <= 0:
        return t
    return np.concatenate([t, np.repeat(t[..., -1:], width, axis=-1)], axis=-1)


def _phase_stack(tables: Dict[int, np.ndarray]) -> np.ndarray:
    """(K, L) stack from a {phase: table} dict (contiguous 0..K-1 keys)."""
    keys = sorted(tables)
    if keys != list(range(len(keys))):
        raise ValueError(f"phase keys must be 0..K-1, got {keys}")
    tabs = [np.asarray(tables[k], dtype=np.int64) for k in keys]
    L = max(len(t) for t in tabs)
    return np.stack([_extend_last(t, L) for t in tabs])


def solve_phase_policies(base, rates: Dict[int, float]):
    """Offline: one SMDP solution per phase rate (paper Sec. VIII).

    The *heuristic* per-phase decomposition — each phase solved as an
    independent Poisson queue at its own rate.  The exact alternative is
    core.solve_modulated, which optimizes the (phase, queue) product chain
    jointly; benchmarks/mmpp_bursty.py tracks the gap between the two.
    """
    from repro.core.solve import solve

    tables = {}
    for phase, lam in rates.items():
        spec = dataclasses.replace(base, lam=lam)
        tables[phase] = solve(spec).action_table(spec.s_max)
    return tables


class PhaseAwareScheduler(AdaptiveController):
    """Per-phase SMDP tables selected by an EWMA rate estimator.

    A thin shim: the phase tables become a lambda-keyed SMDPSchedulerBank
    and AdaptiveController does the estimation + table swapping (margin 0 =
    always track the nearest phase rate, the original behaviour).
    """

    name = "smdp_phase"

    def __init__(self, tables: Dict[int, np.ndarray], rates: Dict[int, float],
                 ewma: float = 0.2):
        from .metrics import RateEstimator

        bank = SMDPSchedulerBank(
            {(float(rates[k]),): np.asarray(tables[k], dtype=np.int64)
             for k in rates},
            key_names=("lam",),
        )
        self._phase_of = {(float(lam),): phase for phase, lam in rates.items()}
        init = float(np.mean(list(rates.values())))
        super().__init__(
            bank,
            estimator=RateEstimator(ewma=ewma, init=init),
            margin=0.0,
            min_dwell=0.0,
            init_rate=init,
        )

    def current_phase(self) -> int:
        return self._phase_of[self.key]


class OraclePhaseScheduler(Scheduler):
    """Phase-aware with the true phase trace (estimation-free upper bound).

    Runs on both backends: the Python engine updates ``phase`` per admitted
    arrival (observe_arrival), and the compiled lane consumes the same
    information as a per-arrival phase array via ``phase_at`` +
    ``as_action_table`` (the (K, L) stack).
    """

    name = "smdp_oracle"

    def __init__(
        self,
        tables: Dict[int, np.ndarray],
        switch_log: Sequence[Tuple[float, int]],
    ):
        self.tables = {
            k: np.asarray(v, dtype=np.int64) for k, v in tables.items()
        }
        log = sorted(switch_log)
        self._switch_times = np.asarray([t for t, _ in log])
        self._phases = [p for _, p in log]
        self.phase = self._phases[0] if self._phases else 0

    def observe_arrival(self, t: float) -> None:
        if not self._phases:
            return
        i = int(np.searchsorted(self._switch_times, t, side="right")) - 1
        self.phase = self._phases[max(i, 0)]

    def phase_at(self, times) -> np.ndarray:
        """Vectorized phase lookup (the compiled lane's arrival phases)."""
        if not self._phases:
            return np.zeros(len(times), dtype=np.int64)
        i = np.searchsorted(self._switch_times, times, side="right") - 1
        return np.asarray(self._phases, dtype=np.int64)[np.maximum(i, 0)]

    def decide(self, queue_len: int) -> int:
        table = self.tables[self.phase]
        return int(table[min(queue_len, len(table) - 1)])

    def snapshot(self) -> dict:
        return {"phase": self.phase}

    def restore(self, state: dict) -> None:
        self.phase = state["phase"]


class BeliefPhaseScheduler(Scheduler):
    """Phase-indexed tables selected by the filtered phase posterior.

    The non-oracle counterpart of OraclePhaseScheduler: an MMPP forward
    filter (arrivals.PhaseBeliefFilter) turns observed inter-arrival gaps
    into a posterior over the hidden phase.  Two action rules:

      * ``mode="argmax"`` (default) — each decision uses the argmax-phase
        row of the (K, L) stack;
      * ``mode="mix"`` — the decision is the posterior-weighted mixture
        of the per-phase actions, ``round(sum_k b_k table[k, q])`` — a
        soft blend that hedges near-uniform beliefs instead of snapping
        to a row.

    Runs on both backends: the Python engine folds the filter per
    admitted arrival; the compiled lane precomputes the identical
    posterior rows with one jitted scan (arrivals.belief_forward_jax)
    and rows/blends the stack inside the kernel (serving.compiled
    ``phase_mode="belief_argmax"`` / ``"belief_mix"``) — the engine does
    this lowering automatically for backend="compiled".
    """

    name = "smdp_belief"

    def __init__(self, tables, phase_filter, mode: str = "argmax"):
        if isinstance(tables, dict):
            tables = _phase_stack(tables)
        self.tables = np.asarray(tables, dtype=np.int64)
        if self.tables.ndim != 2:
            raise ValueError("BeliefPhaseScheduler needs a (K, L) stack")
        if mode not in ("argmax", "mix"):
            raise ValueError(f'mode must be "argmax" or "mix", got {mode!r}')
        self.filter = phase_filter
        self.mode = mode
        if mode == "mix":
            self.name = "smdp_belief_mix"

    @property
    def phase(self) -> int:
        return min(self.filter.phase, self.tables.shape[0] - 1)

    def observe_arrival(self, t: float) -> None:
        self.filter.observe(t)

    def decide(self, queue_len: int) -> int:
        col = min(queue_len, self.tables.shape[1] - 1)
        if self.mode == "mix":
            # same op order as the compiled kernel's mix rule (round of
            # the posterior-weighted action), so both backends agree
            return int(np.round(np.dot(self.filter.belief,
                                       self.tables[:, col])))
        return int(self.tables[self.phase, col])

    def snapshot(self) -> dict:
        return {"filter": self.filter.snapshot()}

    def restore(self, state: dict) -> None:
        self.filter.restore(state["filter"])


class StaticScheduler(Scheduler):
    """Fixed batch size b; waits until b requests are queued (Def. 1)."""

    def __init__(self, b: int):
        self.b = b
        self.name = f"static_{b}"

    def decide(self, queue_len: int) -> int:
        return self.b if queue_len >= self.b else 0


class GreedyScheduler(Scheduler):
    """Largest feasible batch now (Def. 2)."""

    name = "greedy"

    def __init__(self, b_min: int = 1, b_max: int = 32):
        self.b_min, self.b_max = b_min, b_max

    def decide(self, queue_len: int) -> int:
        if queue_len < self.b_min:
            return 0
        return min(queue_len, self.b_max)


class QPolicyScheduler(Scheduler):
    """Control-limit policy (Def. 3): serve min(s, B_max) iff s >= Q."""

    def __init__(self, q: int, b_max: int = 32):
        self.q, self.b_max = q, b_max
        self.name = f"qpolicy_{q}"

    def decide(self, queue_len: int) -> int:
        return min(queue_len, self.b_max) if queue_len >= self.q else 0


def as_action_table(scheduler: Scheduler, b_max: int) -> np.ndarray:
    """Lower a stateless scheduler to the dense table decide() implements.

    The compiled simulator indexes ``table[min(s, len - 1)]`` — identical
    to each scheduler's decide() for every queue length, because all four
    families are constant beyond their largest interesting state.
    Phase-indexed schedulers lower to their (K, L) stack (the compiled
    phase lane selects the row via the per-arrival phase array the
    scheduler's ``phase_at`` provides).  Online-*estimating* schedulers
    (AdaptiveController, belief/rate tracking) have no static table and
    raise: they stay on the Python backend.
    """
    if isinstance(scheduler, OraclePhaseScheduler):
        return _phase_stack(scheduler.tables)
    if isinstance(scheduler, SMDPScheduler):
        return np.asarray(scheduler.table, dtype=np.int64)
    if isinstance(scheduler, StaticScheduler):
        s = np.arange(max(scheduler.b, b_max) + 1)
        return np.where(s >= scheduler.b, scheduler.b, 0).astype(np.int64)
    if isinstance(scheduler, GreedyScheduler):
        cap = min(scheduler.b_max, b_max)
        s = np.arange(max(scheduler.b_min, cap) + 1)
        return np.where(
            s >= scheduler.b_min, np.minimum(s, cap), 0
        ).astype(np.int64)
    if isinstance(scheduler, QPolicyScheduler):
        cap = min(scheduler.b_max, b_max)
        s = np.arange(max(scheduler.q, cap) + 1)
        return np.where(s >= scheduler.q, np.minimum(s, cap), 0).astype(
            np.int64
        )
    raise TypeError(
        f"{type(scheduler).__name__} has no static action table; "
        "online-adaptive schedulers lower through the engine's compiled "
        "belief/adaptive lanes (ServingEngine.run(backend='compiled'), "
        "serving.compiled AdaptiveLane / phase_mode) instead"
    )
