"""Batch schedulers: the SMDP policy (the paper) + benchmark policies.

A scheduler answers one question at each decision epoch (batch completion,
or arrival-at-idle): given s queued requests, what batch size now?
`0` means wait for more arrivals.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.solve import SolveResult


class Scheduler:
    name = "base"

    def decide(self, queue_len: int) -> int:  # pragma: no cover - interface
        raise NotImplementedError

    def snapshot(self) -> dict:
        return {}

    def restore(self, state: dict) -> None:
        pass


class SMDPScheduler(Scheduler):
    """Table-driven scheduler from a solved SMDP (paper eq. 30)."""

    name = "smdp"

    def __init__(self, solution: SolveResult):
        self.table = solution.action_table()
        self.s_max = len(self.table) - 1

    @classmethod
    def from_table(cls, table: np.ndarray) -> "SMDPScheduler":
        obj = cls.__new__(cls)
        obj.table = np.asarray(table, dtype=np.int64)
        obj.s_max = len(obj.table) - 1
        return obj

    def decide(self, queue_len: int) -> int:
        return int(self.table[min(queue_len, self.s_max)])


class StaticScheduler(Scheduler):
    """Fixed batch size b; waits until b requests are queued (Def. 1)."""

    def __init__(self, b: int):
        self.b = b
        self.name = f"static_{b}"

    def decide(self, queue_len: int) -> int:
        return self.b if queue_len >= self.b else 0


class GreedyScheduler(Scheduler):
    """Largest feasible batch now (Def. 2)."""

    name = "greedy"

    def __init__(self, b_min: int = 1, b_max: int = 32):
        self.b_min, self.b_max = b_min, b_max

    def decide(self, queue_len: int) -> int:
        if queue_len < self.b_min:
            return 0
        return min(queue_len, self.b_max)


class QPolicyScheduler(Scheduler):
    """Control-limit policy (Def. 3): serve min(s, B_max) iff s >= Q."""

    def __init__(self, q: int, b_max: int = 32):
        self.q, self.b_max = q, b_max
        self.name = f"qpolicy_{q}"

    def decide(self, queue_len: int) -> int:
        return min(queue_len, self.b_max) if queue_len >= self.q else 0
