"""Batch-service engine: ONE event semantics, two backends.

The paper's queue (M/G^[b]/1 under a batching policy), run as a serving
system.  A single Python kernel (`_run_events`) owns the queue / admission
/ drain / SLO / energy / metrics logic; the modes differ only in their
clock and in where arrivals come from (serving.arrivals.ArrivalProcess):

  * run()          — virtual clock, service times drawn from the profiled
    ServiceModel (G_b); arrivals from any ArrivalProcess (Poisson by
    default, MMPP2 or a recorded trace via `arrivals=`).
  * run_executor() — the wall-clock instance of the same loop: service time
    is the measured duration of a real model call, arrivals are replayed in
    real time.  The timer/sleeper pair is injectable, so the wall-clock path
    is testable against the virtual path decision-for-decision.

run(backend="compiled") executes the same decision-epoch semantics as one
jitted `lax.scan` (serving.compiled): arrivals are pre-generated from the
engine's own rng (draw-for-draw the stream the lazy path would consume;
over-drawn events are buffered and replayed to later runs), the scheduler
is lowered to its dense action table — phase-indexed (K, L) stacks
(OraclePhaseScheduler, exact modulated policies) lower together with their
per-arrival phase stream via the scheduler's phase_at — and the report is
decision-for-decision identical to the Python loop on the same trace —
`verify_backends` is the harness that asserts exactly that.  Use the
Python backend for wall-clock executors and online-*estimating* schedulers
(adaptive / belief tracking); the compiled backend for measurement-grade
replication (and serving.compiled.run_grid for whole seeds x scenarios x
policies sweeps in one dispatch).

Every mode streams per-batch observations into ServingMetrics (P² latency
quantiles, power; the compiled path reports quantiles from its fixed-bin
histogram sketch) and supports snapshot()/restore() — queue, clock, RNG,
scheduler and arrival-process state — so a restored engine reproduces an
uninterrupted run exactly, in every arrival mode.  Energy is accounted
whenever a source is available: a zeta(a) `energy_table` or a per-batch
`energy_model(a, service_time)` callback (the executor-mode option).

Degraded-mode admission control: ``buffer=B`` bounds the waiting room —
arrivals beyond B are refused at the door and counted in
``EngineReport.n_shed``; ``shed_expired=True`` drops queued requests whose
deadline has already passed at a decision epoch (``n_expired``).  Both run
on either backend: the compiled kernel switches to a managed-queue lane
(an explicit admitted-slot queue in the scan carry) when shedding is on,
decision-for-decision identical to the Python loop via `verify_backends`.
The one exception is ``buffer=`` with a belief-filtered scheduler, which
stays on the Python backend — the posterior folds admitted arrivals only,
and admission under a finite room is decision-dependent.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Deque, Dict, List, Optional

import numpy as np

from repro.core.service_models import ServiceModel

from .arrivals import (
    ArrivalEvent,
    ArrivalProcess,
    PoissonProcess,
    TraceProcess,
    as_process,
    take,
)
from .metrics import ServingMetrics, histogram_quantiles
from .scheduler import Scheduler


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float
    deadline: Optional[float] = None  # absolute time SLO
    payload: object = None  # e.g. prompt tokens for a real executor


@dataclasses.dataclass
class EngineReport:
    latencies: np.ndarray
    energy: float
    span: float
    n_served: int
    n_slo_miss: int
    mean_batch: float
    batch_sizes: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, dtype=np.int64)
    )
    metrics: Dict[str, float] = dataclasses.field(default_factory=dict)
    n_shed: int = 0  # arrivals refused by the finite waiting room
    n_expired: int = 0  # queued requests shed past their deadline

    @property
    def power(self) -> float:
        return self.energy / self.span if self.span > 0 else float("nan")

    def percentile(self, q):
        return np.percentile(self.latencies, q) if len(self.latencies) else np.nan

    def weighted_cost(self, w2: float) -> float:
        """The paper's objective: mean latency + w2 * power.

        w2 = 0 is pure latency and stays finite even when no energy source
        was configured (power = NaN).
        """
        w = float(np.mean(self.latencies)) if len(self.latencies) else float("nan")
        return w if w2 == 0 else w + w2 * self.power


class ServingEngine:
    def __init__(
        self,
        scheduler: Scheduler,
        *,
        b_max: int,
        lam: Optional[float] = None,
        arrivals: Optional[ArrivalProcess] = None,
        service: Optional[ServiceModel] = None,
        energy_table: Optional[np.ndarray] = None,  # zeta(a), a = 0..b_max
        energy_model: Optional[Callable[[int, float], float]] = None,
        executor: Optional[Callable[[List[Request]], None]] = None,
        slo: Optional[float] = None,  # relative deadline per request
        buffer: Optional[int] = None,  # finite waiting room B (None = inf)
        shed_expired: bool = False,  # drop queued requests past deadline
        seed: int = 0,
        timer: Callable[[], float] = time.perf_counter,
        sleeper: Callable[[float], None] = time.sleep,
    ):
        if (service is None) == (executor is None):
            raise ValueError("exactly one of service= or executor= required")
        if arrivals is None:
            if lam is None:
                raise ValueError("either lam= or arrivals= required")
            arrivals = PoissonProcess(lam)
        else:
            arrivals = as_process(arrivals)
        self.scheduler = scheduler
        self.arrivals = arrivals
        self.lam = float(lam) if lam is not None else arrivals.mean_rate
        self.b_max = b_max
        self.service = service
        self.energy_table = energy_table
        self.energy_model = energy_model
        self.executor = executor
        self.slo = slo
        if buffer is not None and buffer < 0:
            raise ValueError("buffer must be >= 0 (B = 0 sheds everything)")
        self.buffer = buffer
        self.shed_expired = bool(shed_expired)
        self.rng = np.random.default_rng(seed)
        self.queue: List[Request] = []
        self.t = 0.0
        self.next_rid = 0
        self._pending: Optional[Request] = None  # peeked, not yet admitted
        # events the compiled backend pre-drew from the process but did not
        # consume; replayed before the process is asked again, so the
        # arrival stream the engine sees stays identical to the lazy path
        # (a deque: a compiled run can buffer ~n_epochs events, and the
        # python loop then consumes them one per arrival)
        self._future: Deque[ArrivalEvent] = collections.deque()
        self._timer = timer
        self._sleeper = sleeper

    # --- state for restart (fault tolerance) ---------------------------
    def snapshot(self) -> dict:
        return {
            "t": self.t,
            "queue": [dataclasses.asdict(r) for r in self.queue],
            "pending": (
                dataclasses.asdict(self._pending) if self._pending else None
            ),
            "future": [dataclasses.asdict(ev) for ev in self._future],
            "next_rid": self.next_rid,
            "rng": self.rng.bit_generator.state,
            "sched": self.scheduler.snapshot(),
            "arrivals": self.arrivals.snapshot(),
        }

    def restore(self, snap: dict) -> None:
        self.t = snap["t"]
        self.queue = [Request(**r) for r in snap["queue"]]
        self._pending = Request(**snap["pending"]) if snap["pending"] else None
        self._future = collections.deque(
            ArrivalEvent(**ev) for ev in snap.get("future", [])
        )
        self.next_rid = snap["next_rid"]
        self.rng.bit_generator.state = snap["rng"]
        self.scheduler.restore(snap["sched"])
        self.arrivals.restore(snap["arrivals"])

    # --- arrival plumbing ------------------------------------------------
    def _to_request(self, ev) -> Request:
        rid = ev.rid if ev.rid is not None else self.next_rid
        deadline = ev.deadline
        if deadline is None and self.slo is not None:
            deadline = ev.time + self.slo
        self.next_rid = max(self.next_rid, rid + 1)
        return Request(rid, ev.time, deadline, ev.payload)

    def _peek(self) -> Optional[Request]:
        """Next un-admitted arrival (generated lazily, held until due)."""
        if self._pending is None:
            ev = (
                self._future.popleft()
                if self._future
                else self.arrivals.next(self.rng)
            )
            if ev is not None:
                self._pending = self._to_request(ev)
        return self._pending

    def _admit(self, r: Request) -> None:
        self.queue.append(r)
        observe = getattr(self.scheduler, "observe_arrival", None)
        if observe is not None:
            observe(r.arrival)

    def _zeta(self, a: int, svc: float) -> Optional[float]:
        if self.energy_model is not None:
            return float(self.energy_model(a, svc))
        if self.energy_table is not None:
            return float(self.energy_table[a])
        return None

    # --- the unified kernel ----------------------------------------------
    def _run_events(
        self,
        *,
        max_epochs: Optional[int],
        horizon: Optional[float],
        wall: bool,
        poll: float,
        drain: bool,
    ) -> EngineReport:
        """One event loop for every mode.

        Virtual clock (wall=False): time jumps between arrivals and sampled
        service completions.  Wall clock (wall=True): `now` is the injected
        timer, idle waits sleep, and service time is the executor's measured
        duration.  Everything else — admission, decision epochs, the capped
        drain, SLO / energy / metrics accounting — is shared.
        """
        lat: List[float] = []
        batches: List[int] = []
        metrics = ServingMetrics()
        energy = 0.0
        have_energy = False
        slo_miss = 0
        n_shed = 0
        n_expired = 0
        t0 = self.t
        wall0 = self._timer() if wall else 0.0
        epochs = 0
        while max_epochs is None or epochs < max_epochs:
            now = t0 + (self._timer() - wall0) if wall else self.t
            # admit every arrival due by `now` (bounded by the horizon)
            while True:
                nxt = self._peek()
                if (
                    nxt is None
                    or nxt.arrival > now
                    or (horizon is not None and nxt.arrival >= horizon)
                ):
                    break
                if self.buffer is not None and len(self.queue) >= self.buffer:
                    # finite waiting room: refused at the door, never seen
                    # by the scheduler (offered load, not admitted load)
                    n_shed += 1
                else:
                    self._admit(nxt)
                self._pending = None
            if self.shed_expired:
                keep = []
                for r in self.queue:
                    if r.deadline is not None and r.deadline <= now:
                        n_expired += 1  # unmeetable even with zero service
                    else:
                        keep.append(r)
                self.queue = keep
            a = self.scheduler.decide(len(self.queue))
            a = max(0, min(a, len(self.queue), self.b_max))
            epochs += 1
            if a == 0:
                nxt = self._peek()
                live = nxt is not None and (horizon is None or nxt.arrival < horizon)
                if live:
                    if wall:
                        self._sleeper(min(poll, max(0.0, nxt.arrival - now)))
                    else:
                        self.t = nxt.arrival
                    continue
                if not self.queue or not drain:
                    break
                a = min(len(self.queue), self.b_max)  # capped tail drain
            batch, self.queue = self.queue[:a], self.queue[a:]
            if wall:
                start = t0 + (self._timer() - wall0)  # not `now`: exclude
                self.executor(batch)                  # scheduling overhead
                done = t0 + (self._timer() - wall0)
                svc = done - start
            else:
                svc = float(self.service.sample(a, self.rng, 1)[0])
                done = self.t + svc
            self.t = done
            zeta = self._zeta(a, svc)
            if zeta is not None:
                energy += zeta
                have_energy = True
            batch_lats = []
            for r in batch:
                batch_lats.append(done - r.arrival)
                if r.deadline is not None and done > r.deadline:
                    slo_miss += 1
            lat.extend(batch_lats)
            batches.append(a)
            metrics.observe_batch(
                batch_lats,
                zeta if zeta is not None else float("nan"),
                done - t0,
            )
        return EngineReport(
            latencies=np.asarray(lat),
            energy=energy if have_energy else float("nan"),
            span=self.t - t0,
            n_served=len(lat),
            n_slo_miss=slo_miss,
            mean_batch=float(np.mean(batches)) if batches else 0.0,
            batch_sizes=np.asarray(batches, dtype=np.int64),
            metrics=metrics.report(),
            n_shed=n_shed,
            n_expired=n_expired,
        )

    # --- public modes ----------------------------------------------------
    def run(
        self,
        n_epochs: Optional[int] = 100_000,
        *,
        horizon: Optional[float] = None,
        drain: Optional[bool] = None,
        backend: str = "python",
    ) -> EngineReport:
        """Virtual-clock batch service loop (decision-epoch faithful).

        Runs for `n_epochs` decision epochs, or — with n_epochs=None — until
        the arrival stream ends (trace exhausted / `horizon` reached) and the
        queue has drained in b_max-capped batches.

        ``backend="compiled"`` executes the identical decision-epoch
        semantics as one jitted scan (serving.compiled): same decisions,
        same per-request latencies, same energy on the same arrival stream.
        Requirements: a table-representable scheduler (SMDP / static /
        greedy / Q-policy — online-adaptive controllers stay on the Python
        backend) and zeta-table (or absent) energy accounting.  With
        deterministic service the two backends are draw-for-draw
        reproductions of each other at equal seeds; stochastic service
        draws the same law from a differently-ordered stream (the compiled
        path blocks its unit draws up front).
        """
        if self.service is None:
            raise RuntimeError("run() needs service=; use run_executor()")
        if n_epochs is None and horizon is None and not isinstance(
            self.arrivals, TraceProcess
        ):
            raise ValueError("unbounded run: pass n_epochs= or horizon=")
        if drain is None:
            drain = n_epochs is None
        if backend == "compiled":
            return self._run_compiled(
                max_epochs=n_epochs, horizon=horizon, drain=drain
            )
        if backend != "python":
            raise ValueError(f"unknown backend {backend!r}")
        return self._run_events(
            max_epochs=n_epochs, horizon=horizon, wall=False, poll=0.0,
            drain=drain,
        )

    # --- the compiled backend --------------------------------------------
    def _collect_events(
        self, max_epochs: Optional[int], horizon: Optional[float],
        extend_from: Optional[int] = None,
    ) -> List[ArrivalEvent]:
        """Materialize the arrival stream the lazy path would consume.

        Buffered (`_future`) and already-peeked events come first; a trace
        contributes its remaining events; an infinite process is drained
        eagerly from the engine rng — up to the horizon (the overshoot
        event is buffered, mirroring the lazy peek-and-hold), or in bounded
        chunks that `_run_compiled` grows until the epoch budget is met.
        """
        events: List[ArrivalEvent] = []
        if self._pending is not None:
            r = self._pending
            events.append(
                ArrivalEvent(r.arrival, r.payload, r.deadline, r.rid)
            )
            self._pending = None
        events.extend(self._future)
        self._future.clear()
        proc = self.arrivals
        if isinstance(proc, TraceProcess):
            events.extend(proc.drain())
        elif horizon is not None:
            drawn, overshoot = take(proc, self.rng, horizon=horizon)
            events.extend(drawn)
            if overshoot is not None:
                events.append(overshoot)
        else:
            assert max_epochs is not None
            base = extend_from if extend_from is not None else 0
            target = max(1024, 2 * max_epochs)
            if extend_from is not None:
                target = max(target, 2 * extend_from)
            drawn, _ = take(proc, self.rng, n=max(target - base, 1024))
            events.extend(drawn)
        return events

    def _run_compiled(
        self,
        *,
        max_epochs: Optional[int],
        horizon: Optional[float],
        drain: bool,
        unit_draws: Optional[np.ndarray] = None,
    ) -> EngineReport:
        from .arrivals import belief_forward_jax
        from .compiled import AdaptiveLane, simulate_compiled
        from .scheduler import (
            AdaptiveController, BeliefPhaseScheduler, as_action_table,
        )

        if self.energy_model is not None and self.energy_table is None:
            raise ValueError(
                "compiled backend accounts energy via energy_table=; "
                "per-batch energy_model callbacks need backend='python'"
            )
        # online-adaptive schedulers lower to the compiled belief/adaptive
        # lanes: the bank-retuning controller runs inside the scan carry
        # (AdaptiveLane), the phase posterior is precomputed per trace
        # (belief_forward_jax) — both resumed from the live object's
        # current state and synced back after the run
        sched = self.scheduler
        lane = None
        belief_filter = None
        belief_mode = "argmax"
        phase_fn = None
        if isinstance(sched, AdaptiveController):
            lane = AdaptiveLane.from_controller(sched)
            table = None
            belief_filter = sched.phase_filter
            if belief_filter is None and lane.tables.shape[1] > 1:
                # phase-axis bank without a filter: the pinned phase row
                phase_fn = sched.scheduler.phase_at
        elif isinstance(sched, BeliefPhaseScheduler):
            table = sched.tables
            belief_filter = sched.filter
            belief_mode = sched.mode
        else:
            table = as_action_table(sched, self.b_max)
            # phase-indexed stacks need the per-arrival phase stream: the
            # scheduler provides it (oracle switch trace via phase_at, or
            # the pinned phase of a plain 2-D SMDP table)
            if table.ndim == 2:
                phase_fn = getattr(sched, "phase_at", None)
                if phase_fn is None:
                    raise TypeError(
                        f"{type(sched).__name__} has a phase-indexed "
                        "table but no phase_at(times); run backend='python'"
                    )
        if self.buffer is not None and belief_filter is not None:
            raise NotImplementedError(
                "buffer= with a belief-filtered scheduler needs "
                "backend='python': the posterior folds admitted arrivals "
                "only, and admission under a finite waiting room is "
                "decision-dependent (the compiled lane precomputes the "
                "posterior per arrival)"
            )
        means = np.asarray(
            [0.0]
            + [float(self.service.mean(b)) for b in range(1, self.b_max + 1)]
        )
        t0 = self.t
        queue0 = list(self.queue)
        self.queue = []
        queued_events = [
            ArrivalEvent(r.arrival, r.payload, r.deadline, r.rid)
            for r in queue0
        ]
        events = queued_events + self._collect_events(max_epochs, horizon)
        infinite = not isinstance(self.arrivals, TraceProcess) and (
            horizon is None
        )
        # the extension loop below only triggers on epoch-budgeted runs
        # (max_epochs set), so the budget — and hence the one unit-draw
        # block — is fixed up front: re-dispatches replay the exact same
        # service times, and the rng advances once per run, not per retry
        draws = unit_draws
        if draws is None:
            budget0 = (
                2 * len(events) + 2 if max_epochs is None else max_epochs
            )
            draws = self.service.unit_draws(self.rng, budget0)
        while True:
            n_arr = len(events)
            budget = 2 * n_arr + 2 if max_epochs is None else max_epochs
            times = np.asarray([ev.time for ev in events])
            deadlines = np.asarray(
                [
                    ev.deadline
                    if ev.deadline is not None
                    else (ev.time + self.slo if self.slo is not None
                          else np.inf)
                    for ev in events
                ]
            )
            # recomputed every escalation pass: extended streams get their
            # phases from the same (stateful) trace the python path reads,
            # and the belief rows from the filter's unchanged start state
            ph = None if phase_fn is None else phase_fn(times)
            bel = None
            pm = "oracle"
            if belief_filter is not None:
                bel_rows, _ = belief_forward_jax(times, belief_filter)
                bel = np.asarray(bel_rows)
                pm = (
                    "belief_mix" if belief_mode == "mix" else "belief_argmax"
                )
            res = simulate_compiled(
                table, times,
                means=means, zeta=self.energy_table, draws=draws,
                b_max=self.b_max, max_epochs=budget, t0=t0,
                horizon=horizon, drain=drain, deadlines=deadlines,
                phases=ph, phase_mode=pm, beliefs=bel, adaptive=lane,
                buffer=self.buffer, shed_expired=self.shed_expired,
                record=True,
            )
            if not (infinite and res.n_admitted >= n_arr):
                break
            # the pre-drawn stream ran dry: every event was admitted, so
            # some suffix of the run decided against a truncated future (a
            # frozen belief/phase row, a drain instead of a wait) that a
            # lazy engine — which keeps drawing — would never see.  Extend
            # the stream and re-run until a tail of events stays un-admitted
            # (the scan is deterministic, so the prefix replays identically;
            # arrival processes carry their own state — e.g. the MMPP2
            # phase — so the extension continues the exact same stream)
            events.extend(self._collect_events(
                max_epochs, None, extend_from=n_arr
            ))

        # --- sync engine state so later runs continue the same stream ----
        self.t = res.t_final
        admitted, future = events[: res.n_admitted], events[res.n_admitted:]
        # surviving queue: without shedding it is exactly the un-served
        # suffix; the managed-queue lane reports the survivors' slots
        # (door-refused and expired requests are gone).  rids count every
        # door-seen arrival either way — the Python loop assigns the rid
        # at peek, before the buffer check.
        if res.queue_slots is not None:
            surv = [int(i) for i in res.queue_slots]
        else:
            surv = list(range(res.n_served, len(admitted)))
        if any(ev.rid is not None for ev in admitted):
            reqs = [self._to_request(ev) for ev in admitted]
            self.queue = [reqs[i] for i in surv]
        else:
            base = self.next_rid
            self.next_rid = base + len(admitted)
            self.queue = [
                self._to_request(
                    dataclasses.replace(admitted[i], rid=base + i)
                )
                for i in surv
            ]
        if not isinstance(self.arrivals, TraceProcess):
            self._future = collections.deque(future)
        else:
            # un-admitted trace events stay in the trace: rewind its cursor
            # (the un-admitted tail is always a suffix of what drain() took,
            # since buffered/queued events precede trace events in time)
            self.arrivals.rewind(len(future))
        # sync the online-adaptive scheduler state the kernel carried: the
        # scheduler object ends the run exactly where the Python backend
        # would have left it (belief/estimator state, bank entry,
        # hysteresis clock), so later runs continue identically
        if belief_filter is not None and res.n_admitted > 0:
            belief_filter.belief = bel[res.n_admitted - 1].copy()
            belief_filter._last = float(times[res.n_admitted - 1])
            belief_filter.n_observed += res.n_admitted
            if isinstance(sched, AdaptiveController):
                sched.scheduler.phase = belief_filter.phase
        if lane is not None:
            st = res.adaptive_state
            bank = sched.bank
            sched.key = bank._sorted_keys[st["sel"]]
            sched.scheduler.swap_table(bank.tables[sched.key])
            est = sched.estimator
            est._gap_bar = st["gap_bar"] if st["have_gap_bar"] else None
            est._last = st["last"] if st["have_last"] else None
            # door-refused arrivals were never observed by the estimator
            est.n_observed += res.n_admitted - res.n_shed
            sched._last_switch = st["last_switch"]
            sched.n_switches = st["n_switches"]

        lat = res.latencies
        # a run with no served batch accounted no energy (NaN, like the
        # Python kernel's have_energy flag)
        energy = (
            res.energy
            if self.energy_table is not None and res.n_batches > 0
            else float("nan")
        )
        span = res.t_final - t0
        qs = histogram_quantiles(
            res.hist, res.hist_edges, [0.5, 0.95, 0.99]
        )
        # count-zero lanes: NaN, matching ServingMetrics.report and the
        # grid runners' w_mean convention
        mean_batch = (
            res.n_served / res.n_batches if res.n_batches > 0 else float("nan")
        )
        metrics = {
            "W_mean": (
                res.lat_sum / res.n_served
                if res.n_served > 0
                else float("nan")
            ),
            "P50": float(qs[0]),
            "P95": float(qs[1]),
            "P99": float(qs[2]),
            "power": energy / span if span > 0 else float("nan"),
            "mean_batch": mean_batch,
            "n_served": float(res.n_served),
        }
        return EngineReport(
            latencies=lat,
            energy=energy,
            span=span,
            n_served=res.n_served,
            n_slo_miss=res.slo_miss,
            mean_batch=mean_batch,
            batch_sizes=res.batch_sizes,
            metrics=metrics,
            n_shed=res.n_shed,
            n_expired=res.n_expired,
        )

    def run_executor(
        self, requests: List[Request], *, poll: float = 1e-4
    ) -> EngineReport:
        """Replay `requests` (arrival times in seconds) against a real model.

        The wall-clock instance of the same kernel: the scheduler is
        consulted whenever the server is idle; service time is the
        executor's measured wall time.  Replaces the engine's arrival
        process with a trace of the given requests.  Arrival times are
        relative to THIS call: the trace is shifted onto the engine clock,
        so reusing an engine for a second replay behaves like a fresh one
        (while self.t stays monotone for snapshot coherence).
        """
        if self.executor is None:
            raise RuntimeError("run_executor() needs executor=; use run()")
        trace = TraceProcess(requests)
        if self.t != 0.0:
            for ev in trace.events:
                ev.time += self.t
                if ev.deadline is not None:
                    ev.deadline += self.t
        self.arrivals = trace
        self._pending = None
        self._future.clear()  # replay replaces the arrival source wholesale
        return self._run_events(
            max_epochs=None, horizon=None, wall=True, poll=poll, drain=True
        )


# ---------------------------------------------------------------------------
# Compiled-vs-Python equivalence harness
# ---------------------------------------------------------------------------


class _ScriptedService:
    """ServiceModel stand-in replaying a shared unit-draw sequence.

    Every ServiceModel family factors as mean(b) * unit_draw, so feeding
    one pre-drawn sequence to both backends makes their service times — and
    hence every decision — identical even for stochastic families.  One
    draw is consumed per serve call, the Python kernel's exact discipline.
    """

    def __init__(self, base: ServiceModel, draws: np.ndarray):
        self.base = base
        self.draws = np.asarray(draws, dtype=np.float64)
        self.k = 0

    def mean(self, b):
        return self.base.mean(b)

    def sample(self, b: int, rng: np.random.Generator, n: int) -> np.ndarray:
        out = float(self.base.mean(b)) * self.draws[self.k: self.k + n]
        self.k += n
        return out


def verify_backends(
    table: np.ndarray,
    trace,
    *,
    service: ServiceModel,
    energy_table: Optional[np.ndarray] = None,
    b_max: int,
    n_epochs: Optional[int] = None,
    horizon: Optional[float] = None,
    drain: Optional[bool] = None,
    slo: Optional[float] = None,
    buffer: Optional[int] = None,
    shed_expired: bool = False,
    phases=None,
    scheduler=None,
    seed: int = 0,
    atol: float = 1e-9,
) -> Dict[str, object]:
    """Decision-for-decision harness: both backends on one shared trace.

    Runs the Python event loop and the compiled scan on the same arrival
    trace and the same unit service-draw sequence, then checks the batch
    schedule, per-request latencies, energy, SLO misses and span against
    each other.  Returns the two EngineReports plus the comparison verdict;
    raises AssertionError on any divergence (this is the acceptance gate
    for the compiled backend, run per arrival mode in the test suite).

    A (K, L) phase-indexed ``table`` plus per-arrival ``phases`` verifies
    the compiled phase lane: the Python side runs the oracle-phase path
    (OraclePhaseScheduler on the switch log the phase stream implies), the
    compiled side the phase-indexed table lookup — the acceptance gate for
    exact-modulated / oracle policies on the compiled backend.

    ``buffer=`` / ``shed_expired=`` arm the degraded-mode admission path
    on both backends and additionally assert the refusal and expiry
    counters match — the acceptance gate for the compiled managed-queue
    lane.

    ``scheduler`` — a zero-argument factory returning a fresh scheduler
    instance per backend — replaces ``table``/``phases`` and certifies
    *any* scheduler the engine can lower, in particular the online lanes:
    a `BeliefPhaseScheduler` factory pits the Python filter fold against
    the jitted belief scan + in-kernel row/mixture selection, an
    `AdaptiveController` factory pits the Python estimator/hysteresis
    loop against the in-carry adaptive kernel — the acceptance gate for
    the deployable (non-oracle) policies on the compiled backend.
    """
    from .scheduler import OraclePhaseScheduler, SMDPScheduler

    trace = list(np.asarray(trace, dtype=np.float64))
    if drain is None:
        drain = n_epochs is None
    budget = n_epochs if n_epochs is not None else 2 * len(trace) + 2
    draws = service.unit_draws(np.random.default_rng(seed), budget)
    if scheduler is not None:
        if table is not None or phases is not None:
            raise ValueError(
                "scheduler= (a fresh-instance factory) replaces "
                "table=/phases="
            )
        mk_sched = scheduler
    elif np.asarray(table).ndim == 2:
        table = np.asarray(table, dtype=np.int64)
        if phases is None:
            raise ValueError("a (K, L) table stack needs phases= per arrival")
        phases = np.asarray(phases, dtype=np.int64)
        if len(phases) != len(trace):
            raise ValueError("phases must align with the trace")
        # the switch log the per-arrival phase stream implies: an arrival's
        # phase is the phase at its own time, so logging changes *at*
        # arrival times reproduces the stream exactly on both backends
        log = [(trace[0], int(phases[0]))] if trace else []
        for t_a, p_a, p_prev in zip(trace[1:], phases[1:], phases[:-1]):
            if p_a != p_prev:
                log.append((float(t_a), int(p_a)))

        table_stack = table

        def mk_sched():
            return OraclePhaseScheduler(
                {z: table_stack[z] for z in range(table_stack.shape[0])}, log
            )
    else:
        table = np.asarray(table, dtype=np.int64)
        if phases is not None:
            raise ValueError("phases= needs a (K, L) phase-indexed table")

        def mk_sched():
            return SMDPScheduler.from_table(table)

    def engine(svc):
        return ServingEngine(
            mk_sched(),
            arrivals=TraceProcess(trace),
            b_max=b_max, service=svc, energy_table=energy_table,
            slo=slo, buffer=buffer, shed_expired=shed_expired, seed=seed,
        )

    rep_py = engine(_ScriptedService(service, draws)).run(
        n_epochs, horizon=horizon, drain=drain
    )
    rep_c = engine(service)._run_compiled(
        max_epochs=n_epochs, horizon=horizon, drain=drain, unit_draws=draws
    )
    np.testing.assert_array_equal(rep_py.batch_sizes, rep_c.batch_sizes)
    assert rep_py.n_served == rep_c.n_served
    np.testing.assert_allclose(rep_py.latencies, rep_c.latencies, atol=atol)
    assert rep_py.n_slo_miss == rep_c.n_slo_miss
    assert rep_py.n_shed == rep_c.n_shed
    assert rep_py.n_expired == rep_c.n_expired
    if energy_table is not None:
        np.testing.assert_allclose(rep_py.energy, rep_c.energy, atol=atol)
    np.testing.assert_allclose(rep_py.span, rep_c.span, atol=atol)
    return {
        "python": rep_py,
        "compiled": rep_c,
        "n_decisions": int(len(rep_py.batch_sizes)),
        "max_latency_err": float(
            np.max(np.abs(rep_py.latencies - rep_c.latencies))
            if rep_py.n_served
            else 0.0
        ),
    }
