"""Batch-service engine: ONE event-driven kernel behind every serving mode.

The paper's queue (M/G^[b]/1 under a batching policy), run as a serving
system.  A single kernel (`_run_events`) owns the queue / admission / drain
/ SLO / energy / metrics logic; the modes differ only in their clock and in
where arrivals come from (serving.arrivals.ArrivalProcess):

  * run()          — virtual clock, service times drawn from the profiled
    ServiceModel (G_b); arrivals from any ArrivalProcess (Poisson by
    default, MMPP2 or a recorded trace via `arrivals=`).
  * run_executor() — the wall-clock instance of the same loop: service time
    is the measured duration of a real model call, arrivals are replayed in
    real time.  The timer/sleeper pair is injectable, so the wall-clock path
    is testable against the virtual path decision-for-decision.

Every mode streams per-batch observations into ServingMetrics (P² latency
quantiles, power) and supports snapshot()/restore() — queue, clock,
RNG, scheduler and arrival-process state — so a restored engine reproduces
an uninterrupted run exactly, in every arrival mode.  Energy is accounted
whenever a source is available: a zeta(a) `energy_table` or a per-batch
`energy_model(a, service_time)` callback (the executor-mode option).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.service_models import ServiceModel

from .arrivals import ArrivalProcess, PoissonProcess, TraceProcess, as_process
from .metrics import ServingMetrics
from .scheduler import Scheduler


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float
    deadline: Optional[float] = None  # absolute time SLO
    payload: object = None  # e.g. prompt tokens for a real executor


@dataclasses.dataclass
class EngineReport:
    latencies: np.ndarray
    energy: float
    span: float
    n_served: int
    n_slo_miss: int
    mean_batch: float
    batch_sizes: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, dtype=np.int64)
    )
    metrics: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def power(self) -> float:
        return self.energy / self.span if self.span > 0 else float("nan")

    def percentile(self, q):
        return np.percentile(self.latencies, q) if len(self.latencies) else np.nan

    def weighted_cost(self, w2: float) -> float:
        """The paper's objective: mean latency + w2 * power.

        w2 = 0 is pure latency and stays finite even when no energy source
        was configured (power = NaN).
        """
        w = float(np.mean(self.latencies)) if len(self.latencies) else float("nan")
        return w if w2 == 0 else w + w2 * self.power


class ServingEngine:
    def __init__(
        self,
        scheduler: Scheduler,
        *,
        b_max: int,
        lam: Optional[float] = None,
        arrivals: Optional[ArrivalProcess] = None,
        service: Optional[ServiceModel] = None,
        energy_table: Optional[np.ndarray] = None,  # zeta(a), a = 0..b_max
        energy_model: Optional[Callable[[int, float], float]] = None,
        executor: Optional[Callable[[List[Request]], None]] = None,
        slo: Optional[float] = None,  # relative deadline per request
        seed: int = 0,
        timer: Callable[[], float] = time.perf_counter,
        sleeper: Callable[[float], None] = time.sleep,
    ):
        if (service is None) == (executor is None):
            raise ValueError("exactly one of service= or executor= required")
        if arrivals is None:
            if lam is None:
                raise ValueError("either lam= or arrivals= required")
            arrivals = PoissonProcess(lam)
        else:
            arrivals = as_process(arrivals)
        self.scheduler = scheduler
        self.arrivals = arrivals
        self.lam = float(lam) if lam is not None else arrivals.mean_rate
        self.b_max = b_max
        self.service = service
        self.energy_table = energy_table
        self.energy_model = energy_model
        self.executor = executor
        self.slo = slo
        self.rng = np.random.default_rng(seed)
        self.queue: List[Request] = []
        self.t = 0.0
        self.next_rid = 0
        self._pending: Optional[Request] = None  # peeked, not yet admitted
        self._timer = timer
        self._sleeper = sleeper

    # --- state for restart (fault tolerance) ---------------------------
    def snapshot(self) -> dict:
        return {
            "t": self.t,
            "queue": [dataclasses.asdict(r) for r in self.queue],
            "pending": (
                dataclasses.asdict(self._pending) if self._pending else None
            ),
            "next_rid": self.next_rid,
            "rng": self.rng.bit_generator.state,
            "sched": self.scheduler.snapshot(),
            "arrivals": self.arrivals.snapshot(),
        }

    def restore(self, snap: dict) -> None:
        self.t = snap["t"]
        self.queue = [Request(**r) for r in snap["queue"]]
        self._pending = Request(**snap["pending"]) if snap["pending"] else None
        self.next_rid = snap["next_rid"]
        self.rng.bit_generator.state = snap["rng"]
        self.scheduler.restore(snap["sched"])
        self.arrivals.restore(snap["arrivals"])

    # --- arrival plumbing ------------------------------------------------
    def _to_request(self, ev) -> Request:
        rid = ev.rid if ev.rid is not None else self.next_rid
        deadline = ev.deadline
        if deadline is None and self.slo is not None:
            deadline = ev.time + self.slo
        self.next_rid = max(self.next_rid, rid + 1)
        return Request(rid, ev.time, deadline, ev.payload)

    def _peek(self) -> Optional[Request]:
        """Next un-admitted arrival (generated lazily, held until due)."""
        if self._pending is None:
            ev = self.arrivals.next(self.rng)
            if ev is not None:
                self._pending = self._to_request(ev)
        return self._pending

    def _admit(self, r: Request) -> None:
        self.queue.append(r)
        observe = getattr(self.scheduler, "observe_arrival", None)
        if observe is not None:
            observe(r.arrival)

    def _zeta(self, a: int, svc: float) -> Optional[float]:
        if self.energy_model is not None:
            return float(self.energy_model(a, svc))
        if self.energy_table is not None:
            return float(self.energy_table[a])
        return None

    # --- the unified kernel ----------------------------------------------
    def _run_events(
        self,
        *,
        max_epochs: Optional[int],
        horizon: Optional[float],
        wall: bool,
        poll: float,
        drain: bool,
    ) -> EngineReport:
        """One event loop for every mode.

        Virtual clock (wall=False): time jumps between arrivals and sampled
        service completions.  Wall clock (wall=True): `now` is the injected
        timer, idle waits sleep, and service time is the executor's measured
        duration.  Everything else — admission, decision epochs, the capped
        drain, SLO / energy / metrics accounting — is shared.
        """
        lat: List[float] = []
        batches: List[int] = []
        metrics = ServingMetrics()
        energy = 0.0
        have_energy = False
        slo_miss = 0
        t0 = self.t
        wall0 = self._timer() if wall else 0.0
        epochs = 0
        while max_epochs is None or epochs < max_epochs:
            now = t0 + (self._timer() - wall0) if wall else self.t
            # admit every arrival due by `now` (bounded by the horizon)
            while True:
                nxt = self._peek()
                if (
                    nxt is None
                    or nxt.arrival > now
                    or (horizon is not None and nxt.arrival >= horizon)
                ):
                    break
                self._admit(nxt)
                self._pending = None
            a = self.scheduler.decide(len(self.queue))
            a = max(0, min(a, len(self.queue), self.b_max))
            epochs += 1
            if a == 0:
                nxt = self._peek()
                live = nxt is not None and (horizon is None or nxt.arrival < horizon)
                if live:
                    if wall:
                        self._sleeper(min(poll, max(0.0, nxt.arrival - now)))
                    else:
                        self.t = nxt.arrival
                    continue
                if not self.queue or not drain:
                    break
                a = min(len(self.queue), self.b_max)  # capped tail drain
            batch, self.queue = self.queue[:a], self.queue[a:]
            if wall:
                start = t0 + (self._timer() - wall0)  # not `now`: exclude
                self.executor(batch)                  # scheduling overhead
                done = t0 + (self._timer() - wall0)
                svc = done - start
            else:
                svc = float(self.service.sample(a, self.rng, 1)[0])
                done = self.t + svc
            self.t = done
            zeta = self._zeta(a, svc)
            if zeta is not None:
                energy += zeta
                have_energy = True
            batch_lats = []
            for r in batch:
                batch_lats.append(done - r.arrival)
                if r.deadline is not None and done > r.deadline:
                    slo_miss += 1
            lat.extend(batch_lats)
            batches.append(a)
            metrics.observe_batch(
                batch_lats,
                zeta if zeta is not None else float("nan"),
                done - t0,
            )
        return EngineReport(
            latencies=np.asarray(lat),
            energy=energy if have_energy else float("nan"),
            span=self.t - t0,
            n_served=len(lat),
            n_slo_miss=slo_miss,
            mean_batch=float(np.mean(batches)) if batches else 0.0,
            batch_sizes=np.asarray(batches, dtype=np.int64),
            metrics=metrics.report(),
        )

    # --- public modes ----------------------------------------------------
    def run(
        self,
        n_epochs: Optional[int] = 100_000,
        *,
        horizon: Optional[float] = None,
        drain: Optional[bool] = None,
    ) -> EngineReport:
        """Virtual-clock batch service loop (decision-epoch faithful).

        Runs for `n_epochs` decision epochs, or — with n_epochs=None — until
        the arrival stream ends (trace exhausted / `horizon` reached) and the
        queue has drained in b_max-capped batches.
        """
        if self.service is None:
            raise RuntimeError("run() needs service=; use run_executor()")
        if n_epochs is None and horizon is None and not isinstance(
            self.arrivals, TraceProcess
        ):
            raise ValueError("unbounded run: pass n_epochs= or horizon=")
        if drain is None:
            drain = n_epochs is None
        return self._run_events(
            max_epochs=n_epochs, horizon=horizon, wall=False, poll=0.0,
            drain=drain,
        )

    def run_executor(
        self, requests: List[Request], *, poll: float = 1e-4
    ) -> EngineReport:
        """Replay `requests` (arrival times in seconds) against a real model.

        The wall-clock instance of the same kernel: the scheduler is
        consulted whenever the server is idle; service time is the
        executor's measured wall time.  Replaces the engine's arrival
        process with a trace of the given requests.  Arrival times are
        relative to THIS call: the trace is shifted onto the engine clock,
        so reusing an engine for a second replay behaves like a fresh one
        (while self.t stays monotone for snapshot coherence).
        """
        if self.executor is None:
            raise RuntimeError("run_executor() needs executor=; use run()")
        trace = TraceProcess(requests)
        if self.t != 0.0:
            for ev in trace.events:
                ev.time += self.t
                if ev.deadline is not None:
                    ev.deadline += self.t
        self.arrivals = trace
        self._pending = None
        return self._run_events(
            max_epochs=None, horizon=None, wall=True, poll=poll, drain=True
        )
