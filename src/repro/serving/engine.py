"""Batch-service engine: the paper's queue, run as a serving system.

Two clocks:
  * mode="profiled"  — service times drawn from the profiled ServiceModel
    (G_b); this is the paper's M/G^[b]/1 queue driven by a scheduler, usable
    for any architecture via core.profiles (TPU-roofline l(b), zeta(b)).
  * mode="executor"  — service time is the measured wall-clock of a real
    model call (`executor(requests) -> None`); arrivals are replayed in
    wall-clock time.  examples/serve_llm.py wires a reduced model through
    this path.

Fault tolerance: the engine snapshot()/restore() covers the queue and clock
(restart-safe); requests carry deadlines and the report counts SLO misses.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import numpy as np

from repro.core.service_models import ServiceModel

from .scheduler import Scheduler


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float
    deadline: Optional[float] = None  # absolute time SLO
    payload: object = None  # e.g. prompt tokens for a real executor


@dataclasses.dataclass
class EngineReport:
    latencies: np.ndarray
    energy: float
    span: float
    n_served: int
    n_slo_miss: int
    mean_batch: float

    @property
    def power(self) -> float:
        return self.energy / self.span if self.span > 0 else float("nan")

    def percentile(self, q):
        return np.percentile(self.latencies, q) if len(self.latencies) else np.nan


class ServingEngine:
    def __init__(
        self,
        scheduler: Scheduler,
        *,
        lam: float,
        b_max: int,
        service: Optional[ServiceModel] = None,
        energy_table: Optional[np.ndarray] = None,  # zeta(a), a = 0..b_max
        executor: Optional[Callable[[List[Request]], None]] = None,
        slo: Optional[float] = None,  # relative deadline per request
        seed: int = 0,
    ):
        if (service is None) == (executor is None):
            raise ValueError("exactly one of service= or executor= required")
        self.scheduler = scheduler
        self.lam = lam
        self.b_max = b_max
        self.service = service
        self.energy_table = energy_table
        self.executor = executor
        self.slo = slo
        self.rng = np.random.default_rng(seed)
        self.queue: List[Request] = []
        self.t = 0.0
        self.next_rid = 0

    # --- state for restart (fault tolerance) ---------------------------
    def snapshot(self) -> dict:
        return {
            "t": self.t,
            "queue": [dataclasses.asdict(r) for r in self.queue],
            "next_rid": self.next_rid,
            "rng": self.rng.bit_generator.state,
            "sched": self.scheduler.snapshot(),
        }

    def restore(self, snap: dict) -> None:
        self.t = snap["t"]
        self.queue = [Request(**r) for r in snap["queue"]]
        self.next_rid = snap["next_rid"]
        self.rng.bit_generator.state = snap["rng"]
        self.scheduler.restore(snap["sched"])

    # --- simulated (profiled) clock -------------------------------------
    def _arrive(self, t: float, payload=None) -> None:
        dl = t + self.slo if self.slo else None
        self.queue.append(Request(self.next_rid, t, dl, payload))
        self.next_rid += 1

    def run(self, n_epochs: int = 100_000) -> EngineReport:
        """Profiled-clock batch service loop (decision-epoch faithful)."""
        assert self.service is not None
        lat: List[float] = []
        energy = 0.0
        batches = []
        slo_miss = 0
        t0 = self.t
        for _ in range(n_epochs):
            a = self.scheduler.decide(len(self.queue))
            a = min(a, len(self.queue))
            if a <= 0:
                dt = self.rng.exponential(1.0 / self.lam)
                self.t += dt
                self._arrive(self.t)
                continue
            svc = float(self.service.sample(a, self.rng, 1)[0])
            done = self.t + svc
            batch, self.queue = self.queue[:a], self.queue[a:]
            for r in batch:
                lat.append(done - r.arrival)
                if r.deadline is not None and done > r.deadline:
                    slo_miss += 1
            if self.energy_table is not None:
                energy += float(self.energy_table[a])
            batches.append(a)
            # arrivals during service
            n_arr = self.rng.poisson(self.lam * svc)
            offs = np.sort(self.rng.uniform(0.0, svc, size=n_arr))
            for o in offs:
                self._arrive(self.t + o)
            self.t = done
        return EngineReport(
            latencies=np.asarray(lat),
            energy=energy,
            span=self.t - t0,
            n_served=len(lat),
            n_slo_miss=slo_miss,
            mean_batch=float(np.mean(batches)) if batches else 0.0,
        )

    # --- wall-clock executor mode ---------------------------------------
    def run_executor(
        self, requests: List[Request], *, poll: float = 1e-4
    ) -> EngineReport:
        """Replay `requests` (arrival times in seconds) against a real model.

        The scheduler is consulted whenever the server is idle; service time
        is the executor's measured wall time.
        """
        assert self.executor is not None
        pending = sorted(requests, key=lambda r: r.arrival)
        lat: List[float] = []
        batches = []
        slo_miss = 0
        start = time.perf_counter()
        i = 0
        while i < len(pending) or self.queue:
            now = time.perf_counter() - start
            while i < len(pending) and pending[i].arrival <= now:
                self.queue.append(pending[i])
                i += 1
            a = self.scheduler.decide(len(self.queue))
            a = min(a, len(self.queue))
            if a <= 0:
                if i < len(pending):
                    time.sleep(min(poll, max(0.0, pending[i].arrival - now)))
                    continue
                a = len(self.queue)  # drain tail
                if a == 0:
                    break
            batch, self.queue = self.queue[:a], self.queue[a:]
            self.executor(batch)
            done = time.perf_counter() - start
            for r in batch:
                lat.append(done - r.arrival)
                if r.deadline is not None and done > r.deadline:
                    slo_miss += 1
            batches.append(a)
        span = time.perf_counter() - start
        return EngineReport(
            latencies=np.asarray(lat),
            energy=float("nan"),
            span=span,
            n_served=len(lat),
            n_slo_miss=slo_miss,
            mean_batch=float(np.mean(batches)) if batches else 0.0,
        )
