"""Bursty traffic: MMPP(2) arrivals + phase-aware SMDP scheduling.

The paper (Sec. VIII) proposes handling Markov-modulated Poisson traffic as
"temporal compositions of Poisson process periods ... by detecting phases
and applying the proposed method to each period."  This module implements
exactly that:

  * MMPP2 — a two-phase Markov-modulated Poisson arrival process;
  * PhaseAwareScheduler — one SMDP policy table per phase, an online
    rate estimator (EWMA of inter-arrival times) that selects the table;
  * solve_phase_policies — solves the SMDP once per phase rate offline.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.core.smdp import SMDPSpec
from repro.core.solve import solve

from .scheduler import Scheduler


@dataclasses.dataclass(frozen=True)
class MMPP2:
    """Two-phase MMPP: rates lam1 < lam2, mean phase dwell times t1, t2."""

    lam1: float
    lam2: float
    dwell1: float
    dwell2: float

    @property
    def mean_rate(self) -> float:
        p1 = self.dwell1 / (self.dwell1 + self.dwell2)
        return p1 * self.lam1 + (1 - p1) * self.lam2

    def sample_arrivals(self, horizon: float, rng: np.random.Generator):
        """Arrival times in [0, horizon) and the phase trace."""
        t = 0.0
        phase = 0
        arrivals: List[float] = []
        phases: List[Tuple[float, int]] = [(0.0, 0)]
        next_switch = rng.exponential(self.dwell1)
        while t < horizon:
            lam = self.lam1 if phase == 0 else self.lam2
            dt = rng.exponential(1.0 / lam)
            if t + dt >= next_switch:
                t = next_switch
                phase ^= 1
                phases.append((t, phase))
                next_switch = t + rng.exponential(
                    self.dwell1 if phase == 0 else self.dwell2
                )
                continue
            t += dt
            if t < horizon:
                arrivals.append(t)
        return np.asarray(arrivals), phases


def solve_phase_policies(base: SMDPSpec, rates: Dict[int, float]):
    """Offline: one SMDP solution per phase rate (paper Sec. VIII)."""
    tables = {}
    for phase, lam in rates.items():
        spec = dataclasses.replace(base, lam=lam)
        tables[phase] = solve(spec).action_table(spec.s_max)
    return tables


class PhaseAwareScheduler(Scheduler):
    """Switches between per-phase SMDP tables via an EWMA rate estimator."""

    name = "smdp_phase"

    def __init__(self, tables: Dict[int, np.ndarray], rates: Dict[int, float],
                 ewma: float = 0.2):
        self.tables = {k: np.asarray(v, dtype=np.int64) for k, v in tables.items()}
        self.rates = rates
        self.ewma = ewma
        self._rate_est = float(np.mean(list(rates.values())))
        self._last_arrival = None

    def observe_arrival(self, t: float) -> None:
        if self._last_arrival is not None:
            gap = max(t - self._last_arrival, 1e-9)
            inst = 1.0 / gap
            self._rate_est = (1 - self.ewma) * self._rate_est + self.ewma * inst
        self._last_arrival = t

    def current_phase(self) -> int:
        return min(self.rates, key=lambda k: abs(self.rates[k] - self._rate_est))

    def decide(self, queue_len: int) -> int:
        table = self.tables[self.current_phase()]
        return int(table[min(queue_len, len(table) - 1)])

    def snapshot(self) -> dict:
        return {"rate_est": self._rate_est, "last": self._last_arrival}

    def restore(self, state: dict) -> None:
        self._rate_est = state["rate_est"]
        self._last_arrival = state["last"]


def run_mmpp(
    scheduler: Scheduler,
    mmpp: MMPP2,
    service,
    energy_table: np.ndarray,
    b_max: int,
    horizon: float,
    seed: int = 0,
):
    """Event-driven MMPP batch-service run; returns (latencies, energy, span)."""
    rng = np.random.default_rng(seed)
    arrivals, _ = mmpp.sample_arrivals(horizon, rng)
    lat: List[float] = []
    energy = 0.0
    queue: List[float] = []
    i = 0
    t = 0.0
    n = len(arrivals)
    while i < n or queue:
        # admit everything that has arrived by t
        while i < n and arrivals[i] <= t:
            queue.append(arrivals[i])
            if hasattr(scheduler, "observe_arrival"):
                scheduler.observe_arrival(arrivals[i])
            i += 1
        a = min(scheduler.decide(len(queue)), len(queue))
        if a <= 0:
            if i < n:
                t = arrivals[i]
                continue
            a = min(len(queue), b_max)  # drain
            if a == 0:
                break
        svc = float(service.sample(a, rng, 1)[0])
        done = t + svc
        batch, queue = queue[:a], queue[a:]
        lat.extend(done - x for x in batch)
        energy += float(energy_table[a])
        t = done
    return np.asarray(lat), energy, t
