"""Bursty traffic: MMPP(2) phase handling on top of the unified engine.

The paper (Sec. VIII) proposes handling Markov-modulated Poisson traffic as
"temporal compositions of Poisson process periods ... by detecting phases
and applying the proposed method to each period."  The arrival process
itself lives in serving.arrivals (MMPP2 / MMPP2Process) and runs through
the one event-driven kernel in serving.engine; this module keeps the
phase-aware scheduling side:

  * PhaseAwareScheduler — a thin shim over SMDPSchedulerBank /
    AdaptiveController: one SMDP table per phase rate, selected online by a
    rate estimator (detect the phase, apply the per-phase policy);
  * OraclePhaseScheduler — the upper bound: reads the true phase trace
    instead of estimating it;
  * solve_phase_policies — solves the SMDP once per phase rate offline;
  * run_mmpp — back-compat wrapper: an MMPP2 run of the unified engine.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.smdp import SMDPSpec
from repro.core.solve import solve

from .arrivals import MMPP2, MMPP2Process  # noqa: F401  (re-export)
from .metrics import RateEstimator
from .scheduler import AdaptiveController, Scheduler, SMDPSchedulerBank


def solve_phase_policies(base: SMDPSpec, rates: Dict[int, float]):
    """Offline: one SMDP solution per phase rate (paper Sec. VIII)."""
    tables = {}
    for phase, lam in rates.items():
        spec = dataclasses.replace(base, lam=lam)
        tables[phase] = solve(spec).action_table(spec.s_max)
    return tables


class PhaseAwareScheduler(AdaptiveController):
    """Per-phase SMDP tables selected by an EWMA rate estimator.

    A thin shim: the phase tables become a lambda-keyed SMDPSchedulerBank
    and AdaptiveController does the estimation + table swapping (margin 0 =
    always track the nearest phase rate, the original behaviour).
    """

    name = "smdp_phase"

    def __init__(self, tables: Dict[int, np.ndarray], rates: Dict[int, float],
                 ewma: float = 0.2):
        bank = SMDPSchedulerBank(
            {(float(rates[k]),): np.asarray(tables[k], dtype=np.int64)
             for k in rates},
            key_names=("lam",),
        )
        self._phase_of = {(float(lam),): phase for phase, lam in rates.items()}
        init = float(np.mean(list(rates.values())))
        super().__init__(
            bank,
            estimator=RateEstimator(ewma=ewma, init=init),
            margin=0.0,
            min_dwell=0.0,
            init_rate=init,
        )

    def current_phase(self) -> int:
        return self._phase_of[self.key]


class OraclePhaseScheduler(Scheduler):
    """Phase-aware with the true phase trace (estimation-free upper bound)."""

    name = "smdp_oracle"

    def __init__(
        self,
        tables: Dict[int, np.ndarray],
        switch_log: Sequence[Tuple[float, int]],
    ):
        self.tables = {
            k: np.asarray(v, dtype=np.int64) for k, v in tables.items()
        }
        log = sorted(switch_log)
        self._switch_times = np.asarray([t for t, _ in log])
        self._phases = [p for _, p in log]
        self.phase = self._phases[0] if self._phases else 0

    def observe_arrival(self, t: float) -> None:
        if not self._phases:
            return
        i = int(np.searchsorted(self._switch_times, t, side="right")) - 1
        self.phase = self._phases[max(i, 0)]

    def decide(self, queue_len: int) -> int:
        table = self.tables[self.phase]
        return int(table[min(queue_len, len(table) - 1)])

    def snapshot(self) -> dict:
        return {"phase": self.phase}

    def restore(self, state: dict) -> None:
        self.phase = state["phase"]


def run_mmpp(
    scheduler: Scheduler,
    mmpp: MMPP2,
    service,
    energy_table: np.ndarray,
    b_max: int,
    horizon: float,
    seed: int = 0,
):
    """MMPP batch-service run on the unified engine kernel.

    Back-compat wrapper (returns (latencies, energy, span)); new code
    should build ServingEngine(arrivals=MMPP2Process(mmpp), ...) directly
    and keep the full EngineReport.
    """
    from .engine import ServingEngine

    eng = ServingEngine(
        scheduler,
        arrivals=MMPP2Process(mmpp),
        b_max=b_max,
        service=service,
        energy_table=energy_table,
        seed=seed,
    )
    rep = eng.run(n_epochs=None, horizon=horizon)
    return rep.latencies, rep.energy, rep.span
