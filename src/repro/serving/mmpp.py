"""Deprecated module: MMPP pieces moved to their natural homes.

MMPP2 has ONE home now — the arrival process (MMPP2 / MMPP2Process) lives
in serving.arrivals, and the phase-aware scheduling side
(PhaseAwareScheduler, OraclePhaseScheduler, BeliefPhaseScheduler,
solve_phase_policies) lives in serving.scheduler.  The exact MMPP-aware
solve (vs the per-phase heuristic this module pioneered) is
core.solve_modulated.  This shim re-exports the old names and will be
removed once no caller imports repro.serving.mmpp.

The DeprecationWarning fires on *attribute access* (module
``__getattr__``), not at import time — a plain ``import repro.serving``
(whose star-imports used to trip a module-level warn during collection)
stays warning-clean.
"""
from __future__ import annotations

import warnings

_MOVED = {
    "MMPP2": "arrivals",
    "MMPP2Process": "arrivals",
    "OraclePhaseScheduler": "scheduler",
    "PhaseAwareScheduler": "scheduler",
    "Scheduler": "scheduler",
    "solve_phase_policies": "scheduler",
}

_WARNED = False


def _warn_once():
    global _WARNED
    if not _WARNED:
        _WARNED = True
        warnings.warn(
            "repro.serving.mmpp is deprecated: import MMPP2/MMPP2Process "
            "from repro.serving.arrivals and the phase schedulers from "
            "repro.serving.scheduler (exact modulated solves: "
            "core.solve_modulated)",
            DeprecationWarning,
            stacklevel=3,
        )


def __getattr__(name: str):
    if name in _MOVED:
        _warn_once()
        from importlib import import_module

        mod = import_module(f".{_MOVED[name]}", __package__)
        val = getattr(mod, name)
        globals()[name] = val  # cache: warn once, resolve once
        return val
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_MOVED) | {"run_mmpp"})


def run_mmpp(
    scheduler,
    mmpp,
    service,
    energy_table,
    b_max: int,
    horizon: float,
    seed: int = 0,
):
    """MMPP batch-service run on the unified engine kernel.

    Back-compat wrapper (returns (latencies, energy, span)); new code
    should build ServingEngine(arrivals=MMPP2Process(mmpp), ...) directly
    and keep the full EngineReport.
    """
    _warn_once()
    from .arrivals import MMPP2Process
    from .engine import ServingEngine

    eng = ServingEngine(
        scheduler,
        arrivals=MMPP2Process(mmpp),
        b_max=b_max,
        service=service,
        energy_table=energy_table,
        seed=seed,
    )
    rep = eng.run(n_epochs=None, horizon=horizon)
    return rep.latencies, rep.energy, rep.span
