"""Deprecated module: MMPP pieces moved to their natural homes.

MMPP2 has ONE home now — the arrival process (MMPP2 / MMPP2Process) lives
in serving.arrivals, and the phase-aware scheduling side
(PhaseAwareScheduler, OraclePhaseScheduler, BeliefPhaseScheduler,
solve_phase_policies) lives in serving.scheduler.  The exact MMPP-aware
solve (vs the per-phase heuristic this module pioneered) is
core.solve_modulated.  This shim re-exports the old names and will be
removed once no caller imports repro.serving.mmpp.
"""
from __future__ import annotations

import warnings

from .arrivals import MMPP2, MMPP2Process  # noqa: F401
from .scheduler import (  # noqa: F401
    OraclePhaseScheduler,
    PhaseAwareScheduler,
    Scheduler,
    solve_phase_policies,
)

warnings.warn(
    "repro.serving.mmpp is deprecated: import MMPP2/MMPP2Process from "
    "repro.serving.arrivals and the phase schedulers from "
    "repro.serving.scheduler (exact modulated solves: core.solve_modulated)",
    DeprecationWarning,
    stacklevel=2,
)


def run_mmpp(
    scheduler: Scheduler,
    mmpp: MMPP2,
    service,
    energy_table,
    b_max: int,
    horizon: float,
    seed: int = 0,
):
    """MMPP batch-service run on the unified engine kernel.

    Back-compat wrapper (returns (latencies, energy, span)); new code
    should build ServingEngine(arrivals=MMPP2Process(mmpp), ...) directly
    and keep the full EngineReport.
    """
    from .engine import ServingEngine

    eng = ServingEngine(
        scheduler,
        arrivals=MMPP2Process(mmpp),
        b_max=b_max,
        service=service,
        energy_table=energy_table,
        seed=seed,
    )
    rep = eng.run(n_epochs=None, horizon=horizon)
    return rep.latencies, rep.energy, rep.span
