"""Slot-based KV/state cache pool for continuous serving.

The ServingEngine forms discrete batches (the paper's service model); this
pool manages the device-resident cache buffers those batches decode into:
fixed-capacity slots, free-list allocation, O(1) claim/release, utilization
accounting for admission control.  The allocation strategy mirrors paged
attention at slot granularity (a slot = one request's max_len cache) — page
granularity is a noted extension, not needed for fixed-budget decode
segments.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig


@dataclasses.dataclass
class SlotStats:
    capacity: int
    in_use: int

    @property
    def utilization(self) -> float:
        return self.in_use / self.capacity if self.capacity else 0.0


class KVCachePool:
    def __init__(
        self,
        cfg: ModelConfig,
        n_slots: int,
        max_len: int,
        dtype=jnp.bfloat16,
    ):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        # one batched cache of capacity n_slots; slots are batch rows
        self.cache = M.init_cache(cfg, n_slots, max_len, dtype=dtype)
        self._free: List[int] = list(range(n_slots))
        self._lengths = [0] * n_slots

    def claim(self, n: int) -> Optional[List[int]]:
        """Claim n slots (a decode batch); None if the pool is exhausted."""
        if len(self._free) < n:
            return None
        slots = [self._free.pop() for _ in range(n)]
        for s in slots:
            self._lengths[s] = 0
        return slots

    def release(self, slots: List[int]) -> None:
        for s in slots:
            if s in self._free:
                raise ValueError(f"double release of slot {s}")
            self._lengths[s] = 0
            self._free.append(s)

    def lengths(self) -> jnp.ndarray:
        return jnp.asarray(self._lengths, jnp.int32)

    def stats(self) -> SlotStats:
        return SlotStats(capacity=self.n_slots,
                         in_use=self.n_slots - len(self._free))

    def bytes_per_slot(self) -> int:
        leaves = jax.tree.leaves(
            jax.eval_shape(lambda: M.init_cache(self.cfg, 1, self.max_len))
        )
        return int(sum(l.size * l.dtype.itemsize for l in leaves))
