from .scheduler import (  # noqa: F401
    GreedyScheduler,
    SMDPScheduler,
    StaticScheduler,
    QPolicyScheduler,
)
from .engine import ServingEngine, Request, EngineReport  # noqa: F401
