"""Serving layer: one event-driven kernel, pluggable arrivals, bank retuning.

serving.engine runs every mode (profiled virtual clock, wall-clock
executor, MMPP / trace replay) through a single kernel; serving.arrivals
supplies the arrival processes; serving.scheduler holds the policy tables,
the solved-sweep banks, and the online AdaptiveController; serving.metrics
streams latency quantiles, power, and the arrival-rate estimate.
"""
from .arrivals import (  # noqa: F401
    ArrivalEvent,
    ArrivalProcess,
    MMPP2,
    MMPP2Process,
    PoissonProcess,
    TraceProcess,
    as_process,
)
from .scheduler import (  # noqa: F401
    AdaptiveController,
    GreedyScheduler,
    SMDPScheduler,
    SMDPSchedulerBank,
    StaticScheduler,
    QPolicyScheduler,
)
from .metrics import P2Quantile, RateEstimator, ServingMetrics  # noqa: F401
from .engine import ServingEngine, Request, EngineReport  # noqa: F401
